//! Kernel sweep: DR-SpMM forward/backward vs the cuSPARSE and GNNAdvisor
//! analogs across K values — a focused version of paper Fig. 11 on one
//! design (the full sweep lives in `cargo bench --bench fig11_kernel_sweep`).
//!
//! Run: `cargo run --release --example kernel_sweep [-- --fast]`

use dr_circuitgnn::bench::{measure, Table};
use dr_circuitgnn::datagen::{generate_design, table1_design, DesignSize};
use dr_circuitgnn::graph::EdgeType;
use dr_circuitgnn::sparse::{
    dr_spmm, dr_spmm_bwd, drelu, spmm_csr, spmm_csr_bwd, spmm_gnna, spmm_gnna_bwd, DegreeBuckets,
    GnnaConfig,
};
use dr_circuitgnn::tensor::Matrix;
use dr_circuitgnn::util::rng::Rng;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let scale = if fast { 0.1 } else { 0.5 };
    let reps = if fast { 3 } else { 7 };
    let dim = 64;

    let spec = table1_design(DesignSize::Medium, scale);
    let graphs = generate_design(&spec);
    let g = &graphs[0];
    println!(
        "design {} graph 0 at scale {scale}: {} cells / {} nets",
        spec.name, g.n_cells, g.n_nets
    );

    let mut rng = Rng::new(11);
    for edge in [EdgeType::Near, EdgeType::Pins, EdgeType::Pinned] {
        let adj = g.adj(edge).clone();
        let csc = adj.to_csc();
        let x = Matrix::randn(adj.cols, dim, 1.0, &mut rng);
        let dy = Matrix::randn(adj.rows, dim, 1.0, &mut rng);
        let buckets = DegreeBuckets::build(&adj);
        let cfg = GnnaConfig::default();

        let t_csr_f = measure(1, reps, || std::hint::black_box(spmm_csr(&adj, &x))).median;
        let t_csr_b = measure(1, reps, || std::hint::black_box(spmm_csr_bwd(&csc, &dy))).median;
        let t_gnna_f =
            measure(1, reps, || std::hint::black_box(spmm_gnna(&adj, &x, &cfg))).median;
        let t_gnna_b =
            measure(1, reps, || std::hint::black_box(spmm_gnna_bwd(&csc, &dy, &cfg))).median;

        let mut table = Table::new(
            &format!("{} ({}×{}, {} nnz, dim {dim})", edge.name(), adj.rows, adj.cols, adj.nnz()),
            &["K", "fwd ms", "bwd ms", "fwd vs cuSPARSE", "bwd vs cuSPARSE", "fwd vs GNNA", "bwd vs GNNA"],
        );
        for k in [2usize, 4, 8, 16, 32, 64] {
            let compressed = drelu(&x, k);
            let t_f =
                measure(1, reps, || std::hint::black_box(dr_spmm(&adj, &compressed, &buckets)))
                    .median;
            let t_b = measure(1, reps, || {
                std::hint::black_box(dr_spmm_bwd(&csc, &dy, &compressed))
            })
            .median;
            table.row(&[
                k.to_string(),
                format!("{:.2}", t_f * 1e3),
                format!("{:.2}", t_b * 1e3),
                format!("{:.2}x", t_csr_f / t_f),
                format!("{:.2}x", t_csr_b / t_b),
                format!("{:.2}x", t_gnna_f / t_f),
                format!("{:.2}x", t_gnna_b / t_b),
            ]);
        }
        table.print();
    }
}
