"""AOT path: artifacts lower, parse and carry consistent metadata."""

import os
import subprocess
import sys

import pytest

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def artifacts_built():
    return os.path.exists(os.path.join(ART_DIR, "hgnn_step_d64.hlo.txt"))


@pytest.fixture(scope="module", autouse=True)
def ensure_artifacts():
    """Build artifacts once if missing (same entry `make artifacts` uses)."""
    if not artifacts_built():
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", ART_DIR],
            cwd=os.path.join(os.path.dirname(__file__), ".."),
            check=True,
        )


EXPECTED = [
    "hgnn_step_d64",
    "hgnn_fwd_d64",
    "spmm_near_d64",
    "spmm_pinned_d64",
    "spmm_pins_d64",
]


@pytest.mark.parametrize("name", EXPECTED)
def test_artifact_files_exist_and_nonempty(name):
    hlo = os.path.join(ART_DIR, f"{name}.hlo.txt")
    meta = os.path.join(ART_DIR, f"{name}.meta")
    assert os.path.exists(hlo), hlo
    assert os.path.exists(meta), meta
    text = open(hlo).read()
    assert text.startswith("HloModule"), "artifact must be HLO text"
    assert len(text) > 1000


def test_step_meta_structure():
    meta = open(os.path.join(ART_DIR, "hgnn_step_d64.meta")).read()
    inputs = [l for l in meta.splitlines() if l.startswith("input ")]
    outputs = [l for l in meta.splitlines() if l.startswith("output ")]
    # 19 live params + 12 graph + 2 feats + y + mask = 35 inputs.
    assert len(inputs) == 35, len(inputs)
    # loss + 19 grads.
    assert len(outputs) == 20, len(outputs)
    assert any("bucket" in l for l in meta.splitlines() if l.startswith("note"))


def test_spmm_meta_shapes():
    meta = open(os.path.join(ART_DIR, "spmm_near_d64.meta")).read()
    lines = meta.splitlines()
    assert any(l.startswith("input idx 256 64") for l in lines), lines
    assert any(l.startswith("output y 256 64") for l in lines), lines


def test_hlo_text_mentions_no_dynamic_shapes():
    # Static-shape sanity: no parameter should be unbounded/dynamic.
    text = open(os.path.join(ART_DIR, "hgnn_fwd_d64.hlo.txt")).read()
    assert "<=?" not in text and "?x" not in text
