"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Includes hypothesis sweeps over shapes and k — the build-time gate that the
kernels the artifacts embed are numerically correct.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.drelu import drelu
from compile.kernels.dr_spmm import dr_spmm, dr_spmm_bwd, ell_spmm


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


class TestDrelu:
    def test_keeps_exactly_k(self):
        x = rand(0, 32, 16)
        y = drelu(x, 4)
        nz = (np.asarray(y) != 0).sum(axis=1)
        assert (nz == 4).all()

    def test_matches_ref_basic(self):
        x = rand(1, 64, 32)
        np.testing.assert_allclose(drelu(x, 8), ref.drelu_ref(x, 8), rtol=1e-6)

    def test_k_equals_dim_identity(self):
        x = rand(2, 10, 8)
        np.testing.assert_allclose(drelu(x, 8), x, rtol=1e-6)

    def test_kept_values_are_the_largest(self):
        x = rand(3, 20, 12)
        y = np.asarray(drelu(x, 3))
        xs = np.asarray(x)
        for r in range(20):
            kept = y[r][y[r] != 0]
            thresh = np.sort(xs[r])[-3]
            assert (kept >= thresh - 1e-6).all()

    def test_row_padding_path(self):
        # 300 rows with tile 256 forces the padding branch.
        x = rand(4, 300, 16)
        np.testing.assert_allclose(drelu(x, 5), ref.drelu_ref(x, 5), rtol=1e-6)

    def test_invalid_k_raises(self):
        with pytest.raises(ValueError):
            drelu(rand(5, 4, 4), 0)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(1, 80),
        d=st.integers(2, 48),
        k=st.integers(1, 48),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_matches_ref(self, n, d, k, seed):
        k = min(k, d)
        x = jax.random.normal(jax.random.PRNGKey(seed), (n, d), dtype=jnp.float32)
        np.testing.assert_allclose(drelu(x, k), ref.drelu_ref(x, k), rtol=1e-5, atol=1e-6)


class TestEllSpmm:
    def _graph(self, seed, rows, width, n_src):
        kidx, kval = jax.random.split(jax.random.PRNGKey(seed))
        idx = jax.random.randint(kidx, (rows, width), 0, n_src)
        val = jax.random.uniform(kval, (rows, width), dtype=jnp.float32)
        # Zero some slots to emulate ELL padding.
        val = jnp.where(val < 0.3, 0.0, val)
        return idx, val

    def test_matches_ref(self):
        idx, val = self._graph(0, 40, 8, 30)
        x = rand(1, 30, 16)
        np.testing.assert_allclose(
            ell_spmm(idx, val, x), ref.ell_spmm_ref(idx, val, x), rtol=1e-5, atol=1e-5
        )

    def test_row_padding_path(self):
        idx, val = self._graph(2, 200, 4, 64)
        x = rand(3, 64, 8)
        np.testing.assert_allclose(
            ell_spmm(idx, val, x), ref.ell_spmm_ref(idx, val, x), rtol=1e-5, atol=1e-5
        )

    def test_zero_values_contribute_nothing(self):
        idx = jnp.zeros((4, 3), dtype=jnp.int32)
        val = jnp.zeros((4, 3), dtype=jnp.float32)
        x = rand(4, 10, 6)
        assert np.abs(np.asarray(ell_spmm(idx, val, x))).max() == 0.0

    @settings(max_examples=20, deadline=None)
    @given(
        rows=st.integers(1, 64),
        width=st.integers(1, 12),
        n_src=st.integers(1, 64),
        d=st.integers(1, 32),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_matches_ref(self, rows, width, n_src, d, seed):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        idx = jax.random.randint(k1, (rows, width), 0, n_src)
        val = jax.random.uniform(k2, (rows, width), dtype=jnp.float32)
        x = jax.random.normal(k3, (n_src, d), dtype=jnp.float32)
        np.testing.assert_allclose(
            ell_spmm(idx, val, x), ref.ell_spmm_ref(idx, val, x), rtol=1e-4, atol=1e-4
        )


class TestDrSpmm:
    def test_forward_composition(self):
        idx = jax.random.randint(jax.random.PRNGKey(0), (20, 6), 0, 15)
        val = jax.random.uniform(jax.random.PRNGKey(1), (20, 6), dtype=jnp.float32)
        x = rand(2, 15, 16)
        got = dr_spmm(idx, val, drelu(x, 4))
        want = ref.dr_spmm_ref(idx, val, x, 4)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_backward_masks_to_forward_support(self):
        x = rand(3, 12, 8)
        keep = np.asarray(drelu(x, 3)) != 0
        idx_t = jax.random.randint(jax.random.PRNGKey(4), (12, 5), 0, 9)
        val_t = jax.random.uniform(jax.random.PRNGKey(5), (12, 5), dtype=jnp.float32)
        dy = rand(6, 9, 8)
        dx = np.asarray(dr_spmm_bwd(idx_t, val_t, dy, jnp.asarray(keep)))
        assert (dx[~keep] == 0).all()
        want = np.asarray(ref.dr_spmm_bwd_ref(idx_t, val_t, dy, jnp.asarray(keep)))
        np.testing.assert_allclose(dx, want, rtol=1e-5, atol=1e-5)

    def test_custom_vjp_chain_rule(self):
        """jax.grad through the custom aggregation equals the masked
        dense analytic gradient."""
        from compile.model import make_aggregate

        n_dst, n_src, d, width, k = 8, 10, 6, 4, 2
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
        idx = jax.random.randint(k1, (n_dst, width), 0, n_src)
        val = jax.random.uniform(k2, (n_dst, width), dtype=jnp.float32)
        x = jax.random.normal(k3, (n_src, d), dtype=jnp.float32)
        # Build exact transpose ELL of (idx, val).
        a = np.zeros((n_dst, n_src), dtype=np.float32)
        for r in range(n_dst):
            for w in range(width):
                a[r, int(idx[r, w])] += float(val[r, w])
        width_t = max((a != 0).sum(axis=0).max(), 1)
        idx_t = np.zeros((n_src, width_t), dtype=np.int32)
        val_t = np.zeros((n_src, width_t), dtype=np.float32)
        for j in range(n_src):
            nz = np.nonzero(a[:, j])[0]
            idx_t[j, : len(nz)] = nz
            val_t[j, : len(nz)] = a[nz, j]
        agg = make_aggregate(k)
        g = jax.grad(lambda xx: agg(idx, val, jnp.asarray(idx_t), jnp.asarray(val_t), xx).sum())(x)
        # Analytic: dX = (A^T · 1) masked to top-k support.
        keep = np.asarray(ref.drelu_mask_ref(x, k))
        want = (a.T @ np.ones((n_dst, d), dtype=np.float32)) * keep
        np.testing.assert_allclose(np.asarray(g), want, rtol=1e-4, atol=1e-4)
