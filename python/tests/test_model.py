"""L2 correctness: the JAX HGNN model — shapes, gradients, training descent."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import graph_spec as gs
from compile import model
from compile.kernels import ref


def tiny_graph(n_cell=12, n_net=6, w_near=4, w_pin=3, seed=0):
    """Random ELL-encoded heterograph with exact transposes."""
    rng = np.random.default_rng(seed)

    def ell_pair(rows, cols, width):
        """Random dest-major ELL + its exact source-major transpose.

        Rows are mean-normalised like the real training pipeline feeds its
        adjacencies (unnormalised aggregation diverges under plain GD).
        """
        a = np.zeros((rows, cols), dtype=np.float32)
        for r in range(rows):
            deg = rng.integers(1, width + 1)
            nbrs = rng.choice(cols, size=deg, replace=False)
            a[r, nbrs] = rng.uniform(0.5, 1.0, size=deg)
            a[r] /= a[r].sum()
        def to_ell(m, width):
            rr, cc = m.shape
            idx = np.zeros((rr, width), dtype=np.int32)
            val = np.zeros((rr, width), dtype=np.float32)
            for i in range(rr):
                nz = np.nonzero(m[i])[0][:width]
                idx[i, : len(nz)] = nz
                val[i, : len(nz)] = m[i, nz]
            return idx, val
        return a, to_ell(a, width), to_ell(a.T, width * 4)

    near_a, (near_idx, near_val), (near_idx_t, near_val_t) = ell_pair(n_cell, n_cell, w_near)
    pinned_a, (pinned_idx, pinned_val), (pinned_idx_t, pinned_val_t) = ell_pair(
        n_cell, n_net, w_pin
    )
    pins_a, (pins_idx, pins_val), (pins_idx_t, pins_val_t) = ell_pair(n_net, n_cell, w_pin)
    graph = {
        "near_idx": jnp.asarray(near_idx),
        "near_val": jnp.asarray(near_val),
        "near_idx_t": jnp.asarray(near_idx_t),
        "near_val_t": jnp.asarray(near_val_t),
        "pinned_idx": jnp.asarray(pinned_idx),
        "pinned_val": jnp.asarray(pinned_val),
        "pinned_idx_t": jnp.asarray(pinned_idx_t),
        "pinned_val_t": jnp.asarray(pinned_val_t),
        "pins_idx": jnp.asarray(pins_idx),
        "pins_val": jnp.asarray(pins_val),
        "pins_idx_t": jnp.asarray(pins_idx_t),
        "pins_val_t": jnp.asarray(pins_val_t),
    }
    return graph, (near_a, pinned_a, pins_a)


class TestModel:
    def setup_method(self):
        self.graph, self.dense = tiny_graph()
        key = jax.random.PRNGKey(0)
        self.params = model.init_params(key, 5, 4, 8)
        k1, k2, k3 = jax.random.split(key, 3)
        self.xc = jax.random.normal(k1, (12, 5), dtype=jnp.float32)
        self.xn = jax.random.normal(k2, (6, 4), dtype=jnp.float32)
        self.y = jax.random.uniform(k3, (12, 1), dtype=jnp.float32)
        self.mask = jnp.ones((12, 1), dtype=jnp.float32)

    def test_forward_shape(self):
        pred = model.forward(self.params, self.graph, self.xc, self.xn, 4, 4)
        assert pred.shape == (12, 1)
        assert np.isfinite(np.asarray(pred)).all()

    def test_forward_matches_dense_reference_full_k(self):
        """With k = hidden, the model must equal a dense-jnp re-implementation."""
        near_a, pinned_a, pins_a = self.dense
        p = self.params
        def dense_forward():
            xc = self.xc @ p["lin_cell"]["w"] + p["lin_cell"]["b"]
            xn = self.xn @ p["lin_net"]["w"] + p["lin_net"]["b"]
            def conv(cp, xc, xn):
                h_near = jnp.asarray(near_a) @ xc
                h_pinned = jnp.asarray(pinned_a) @ xn
                h_pins = jnp.asarray(pins_a) @ xc
                y_near = h_near @ cp["near"]["w"] + cp["near"]["b"]
                y_pinned = (
                    xc @ cp["pinned"]["w_self"]
                    + h_pinned @ cp["pinned"]["w_neigh"]
                    + cp["pinned"]["b"]
                )
                y_net = (
                    xn @ cp["pins"]["w_self"]
                    + h_pins @ cp["pins"]["w_neigh"]
                    + cp["pins"]["b"]
                )
                return jnp.maximum(y_near, y_pinned), y_net
            c1, n1 = conv(p["conv1"], xc, xn)
            c2, _ = conv(p["conv2"], c1, n1)
            return c2 @ p["out"]["w"] + p["out"]["b"]
        got = model.forward(self.params, self.graph, self.xc, self.xn, 8, 8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(dense_forward()), rtol=2e-4, atol=2e-4)

    def test_loss_scalar_and_masked(self):
        loss = model.loss_fn(
            self.params, self.graph, self.xc, self.xn, self.y, self.mask, 4, 4
        )
        assert loss.shape == ()
        # Masking out all rows → zero loss.
        zero = model.loss_fn(
            self.params, self.graph, self.xc, self.xn, self.y, jnp.zeros_like(self.mask), 4, 4
        )
        assert float(zero) == 0.0

    def test_gradient_descent_reduces_loss(self):
        params = self.params
        def loss_of(p):
            return model.loss_fn(p, self.graph, self.xc, self.xn, self.y, self.mask, 4, 4)
        l0 = float(loss_of(params))
        for _ in range(30):
            g = jax.grad(loss_of)(params)
            params = jax.tree_util.tree_map(lambda p, gg: p - 0.01 * gg, params, g)
        l1 = float(loss_of(params))
        assert l1 < l0 * 0.5, f"{l0} -> {l1}"

    def test_step_fn_positional_roundtrip(self):
        step = model.step_fn(4, 4)
        leaves = model.params_to_live_list(self.params)
        assert len(leaves) == 19
        graph_args = [self.graph[k].astype(jnp.float32) for k in model.GRAPH_KEYS]
        out = step(*leaves, *graph_args, self.xc, self.xn, self.y, self.mask)
        assert len(out) == 1 + len(model.LIVE_PARAM_KEYS)
        loss, *grads = out
        assert np.isfinite(float(loss))
        for leaf, grad in zip(leaves, grads):
            assert leaf.shape == grad.shape
        # At least one gradient is non-zero (signal flows).
        assert any(float(jnp.abs(g).max()) > 0 for g in grads)

    def test_live_param_list_roundtrip(self):
        live = model.params_to_live_list(self.params)
        rebuilt = model.params_from_live_list(live)
        # Dead params come back as zeros; live params round-trip exactly.
        assert float(jnp.abs(rebuilt["conv2"]["pins"]["w_self"]).max()) == 0.0
        np.testing.assert_array_equal(
            np.asarray(rebuilt["conv1"]["pins"]["w_self"]),
            np.asarray(self.params["conv1"]["pins"]["w_self"]),
        )

    def test_params_list_roundtrip(self):
        leaves = model.params_to_list(self.params)
        assert len(leaves) == 22
        rebuilt = model.params_from_list(leaves)
        for path in model.PARAM_KEYS:
            a = self.params
            b = rebuilt
            for key in path:
                a, b = a[key], b[key]
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_cast_graph_types(self):
        f32_graph = {k: v.astype(jnp.float32) for k, v in self.graph.items()}
        cast = model.cast_graph(f32_graph)
        for k, v in cast.items():
            if k.endswith("idx") or k.endswith("idx_t"):
                assert v.dtype == jnp.int32, k
            else:
                assert v.dtype == jnp.float32, k


class TestMaxMergeRef:
    def test_mask_matches_eq14(self):
        a = jnp.asarray([[1.0, 5.0], [0.0, 2.0]])
        b = jnp.asarray([[2.0, 3.0], [0.0, 4.0]])
        merged, mask = ref.max_merge_ref(a, b)
        np.testing.assert_array_equal(np.asarray(merged), [[2.0, 5.0], [0.0, 4.0]])
        np.testing.assert_array_equal(np.asarray(mask), [[0.0, 1.0], [1.0, 0.0]])
