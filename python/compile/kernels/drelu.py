"""Layer-1 Pallas kernel: D-ReLU row-wise top-k sparsification.

TPU adaptation of the paper's CUDA D-ReLU (DESIGN.md §Hardware-Adaptation):
the CUDA kernel binary-searches a per-row threshold within a warp; on TPU
the natural primitive is `lax.top_k` over a row tile resident in VMEM. The
grid iterates over row tiles so arbitrarily many rows stream through a
fixed VMEM footprint of TILE_ROWS × D × 4 bytes.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; numerics are validated against ref.drelu_ref by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step — 256×128 f32 = 128 KiB VMEM, far under budget.
TILE_ROWS = 256


def _drelu_kernel(k: int, x_ref, o_ref):
    x = x_ref[...]
    # Threshold = k-th largest per row (paper eq. 2). Implemented with a
    # full row sort rather than lax.top_k: top_k lowers to the `topk(...,
    # largest=true)` HLO op, which the downstream xla_extension 0.5.1 text
    # parser predates — `sort` round-trips fine and k ≤ D ≤ 128 keeps the
    # cost negligible.
    d = x.shape[-1]
    sorted_desc = -jnp.sort(-x, axis=-1)
    th = jax.lax.dynamic_slice_in_dim(sorted_desc, k - 1, 1, axis=1)
    # Keep count can exceed k on ties; break ties by column order like the
    # rust kernel: rank columns and keep the first k qualifying ones.
    qualifies = x >= th
    csum = jnp.cumsum(qualifies.astype(jnp.int32), axis=1)
    keep = qualifies & (csum <= k)
    o_ref[...] = jnp.where(keep, x, 0.0)


def drelu(x: jnp.ndarray, k: int, tile_rows: int = TILE_ROWS) -> jnp.ndarray:
    """Row-wise top-k masking as a Pallas kernel (dense masked output)."""
    n, d = x.shape
    k = int(min(k, d))
    if k <= 0:
        raise ValueError(f"k must be positive, got {k}")
    tile = min(tile_rows, n)
    if n % tile != 0:
        # Pad rows to a tile multiple; padded rows are discarded after.
        pad = tile - n % tile
        xp = jnp.pad(x, ((0, pad), (0, 0)))
        return drelu(xp, k, tile_rows)[:n]
    grid = (n // tile,)
    return pl.pallas_call(
        functools.partial(_drelu_kernel, k),
        grid=grid,
        in_specs=[pl.BlockSpec((tile, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x)
