"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel must match its
oracle to float tolerance under pytest (including hypothesis shape sweeps).
"""

import jax.numpy as jnp


def drelu_ref(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Row-wise top-k masking (paper eqs. 2-3).

    Keeps the k largest entries of each row (ties resolved toward earlier
    columns, matching the rust kernel), zeroes the rest. Returns the dense
    masked matrix — the CBSR decompression of the rust side.
    """
    n, d = x.shape
    k = min(k, d)
    # Rank entries: primary key value (desc), secondary column (asc).
    order = jnp.argsort(-x, axis=1, stable=True)  # column ids by rank
    ranks = jnp.argsort(order, axis=1, stable=True)  # rank of each column
    mask = ranks < k
    return jnp.where(mask, x, 0.0)


def drelu_mask_ref(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Boolean keep-mask matching drelu_ref's tie-breaking."""
    n, d = x.shape
    k = min(k, d)
    order = jnp.argsort(-x, axis=1, stable=True)
    ranks = jnp.argsort(order, axis=1, stable=True)
    return ranks < k


def ell_spmm_ref(idx: jnp.ndarray, val: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Dense reference of the ELL-format SpMM.

    idx: [rows, width] int32 neighbor ids (padding slots have val == 0)
    val: [rows, width] f32 edge values
    x:   [n_src, d] source embeddings
    out: [rows, d]   out[r] = sum_w val[r, w] * x[idx[r, w]]
    """
    return jnp.einsum("rw,rwd->rd", val, x[idx])


def dr_spmm_ref(idx, val, x, k: int):
    """D-ReLU sparsification followed by ELL aggregation (paper Alg. 1)."""
    return ell_spmm_ref(idx, val, drelu_ref(x, k))


def dr_spmm_bwd_ref(idx_t, val_t, dy, keep_mask):
    """Backward reference (paper Alg. 2): dX = A^T · dY masked to the
    forward D-ReLU support.

    idx_t/val_t: transposed adjacency in ELL (rows = source nodes)
    dy:          [n_dst, d] upstream gradient
    keep_mask:   [n_src, d] boolean D-ReLU keep mask from the forward pass
    """
    full = ell_spmm_ref(idx_t, val_t, dy)
    return jnp.where(keep_mask, full, 0.0)


def max_merge_ref(a: jnp.ndarray, b: jnp.ndarray):
    """Element-wise max with argmax mask (paper eqs. 8 & 14)."""
    mask = (a >= b).astype(a.dtype)
    return jnp.maximum(a, b), mask
