"""Layer-1 Pallas kernels: DR-SpMM forward and backward (paper §3.2–3.3).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the CUDA kernel's
warp-per-neighbor-group scheduling becomes a degree-bucketed ELLPACK
layout — each adjacency is stored as dense `[rows, width]` neighbor-id /
edge-value tiles (padding slots carry value 0, so they contribute nothing),
and the grid streams row tiles while the full source embedding table sits
in VMEM (≤ 10k × 128 f32 ≈ 5 MiB, inside the ~16 MiB budget; the BlockSpec
keeps per-step traffic at one row tile).

The CBSR k-sparsity appears as the D-ReLU-masked embedding: the fraction of
non-zero multiplies per gathered row is k/D, the same FLOP saving the CUDA
kernel gets from loading k values per neighbor.

Backward (Alg. 2) runs the identical kernel over the transposed ELL and
masks the result to the forward keep-mask — "reuse the CBSR indices".
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row tile per grid step.
TILE_ROWS = 128


def _ell_spmm_kernel(x_ref, idx_ref, val_ref, o_ref):
    """out[r] = Σ_w val[r, w] · x[idx[r, w]] for one row tile."""
    x = x_ref[...]  # full source table in VMEM
    idx = idx_ref[...]  # [tile, width]
    val = val_ref[...]
    gathered = x[idx]  # [tile, width, d]
    o_ref[...] = jnp.einsum("rw,rwd->rd", val, gathered)


def ell_spmm(
    idx: jnp.ndarray, val: jnp.ndarray, x: jnp.ndarray, tile_rows: int = TILE_ROWS
) -> jnp.ndarray:
    """ELL-format SpMM `Y = A · X` as a Pallas kernel.

    idx: [rows, width] int32, val: [rows, width] f32, x: [n_src, d].
    """
    rows, width = idx.shape
    n_src, d = x.shape
    tile = min(tile_rows, rows)
    if rows % tile != 0:
        pad = tile - rows % tile
        idx_p = jnp.pad(idx, ((0, pad), (0, 0)))
        val_p = jnp.pad(val, ((0, pad), (0, 0)))
        return ell_spmm(idx_p, val_p, x, tile_rows)[:rows]
    grid = (rows // tile,)
    return pl.pallas_call(
        _ell_spmm_kernel,
        grid=grid,
        in_specs=[
            # Whole embedding table resident per step (VMEM-persistent).
            pl.BlockSpec((n_src, d), lambda i: (0, 0)),
            pl.BlockSpec((tile, width), lambda i: (i, 0)),
            pl.BlockSpec((tile, width), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,
    )(x, idx, val)


def dr_spmm(idx, val, x_masked):
    """Forward DR-SpMM: aggregation over D-ReLU-masked embeddings.

    `x_masked` is the output of kernels.drelu.drelu (k non-zeros per row).
    """
    return ell_spmm(idx, val, x_masked)


def dr_spmm_bwd(idx_t, val_t, dy, keep_mask):
    """Backward DR-SpMM (Alg. 2): `dX = (Aᵀ · dY) ⊙ keep_mask`.

    idx_t/val_t: ELL of the transposed adjacency (rows = source nodes).
    keep_mask:   the forward D-ReLU support (CBSR indices, decompressed).
    """
    full = ell_spmm(idx_t, val_t, dy)
    return jnp.where(keep_mask, full, 0.0)
