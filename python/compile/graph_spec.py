"""Static bucket shapes shared by the AOT artifacts and the rust feeder.

HLO artifacts have static shapes; real graphs are padded into this bucket
by `rust/src/runtime/pad.rs` (extra cells/nets carry zero features, padded
ELL slots carry zero edge values, and the loss masks padded rows out).

Keep in sync with the `bucket` note lines written into each artifact's
`.meta` file — the rust side validates against those, not this file.
"""

# Node capacity of the bucket.
N_CELL = 256
N_NET = 128

# ELL widths (max neighbors per destination row; rust truncates beyond
# these and reports the truncation fraction).
W_NEAR = 64
W_PINS = 16  # pins: rows = nets (cell sources)
W_PINNED = 16  # pinned: rows = cells (net sources)

# Raw feature widths (match rust datagen::designs::{D_CELL_RAW, D_NET_RAW}).
D_CELL_RAW = 16
D_NET_RAW = 16

# Default K values baked into the artifacts (paper §4.3 optimum region).
K_CELL = 8
K_NET = 8
