"""Layer-2: the DR-CircuitGNN model in JAX (paper Fig. 1), calling the
Layer-1 Pallas kernels for every heterogeneous aggregation.

Mirrors the rust model exactly: per-type input Linear → two HeteroConv
blocks (GraphConv on `near`, SageConv on `pinned`/`pins`, cell-side max
merge, eq. 8) → Linear head on cells → masked MSE.

The aggregation op carries a custom VJP so the backward pass runs the
DR-SpMM backward kernel (Alg. 2: transposed ELL traversal + CBSR-mask
reuse) instead of differentiating through the Pallas forward.

Graph encoding (all static bucket shapes, see graph_spec.py): each edge
type contributes ELL (idx, val) for the forward direction and (idx_t,
val_t) for the transpose. Index arrays arrive as f32 (the rust runtime
feeds f32 only; ids < 2^24 are exact) and are cast to int32 here.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import dtypes

from .kernels.drelu import drelu
from .kernels.dr_spmm import dr_spmm, dr_spmm_bwd


def make_aggregate(k: int):
    """D-ReLU(k) + DR-SpMM with the Alg.-2 custom backward."""

    @jax.custom_vjp
    def aggregate(idx, val, idx_t, val_t, x):
        return dr_spmm(idx, val, drelu(x, k))

    def fwd(idx, val, idx_t, val_t, x):
        xm = drelu(x, k)
        keep = xm != 0.0  # decompressed CBSR indices (forward support)
        # Residuals carry static shapes for the zero cotangents (the
        # forward idx/val differ from idx_t/val_t on rectangular edges).
        return dr_spmm(idx, val, xm), (idx.shape, val.shape, idx_t, val_t, keep)

    def bwd(res, dy):
        idx_shape, val_shape, idx_t, val_t, keep = res
        dx = dr_spmm_bwd(idx_t, val_t, dy, keep)
        return (
            np.zeros(idx_shape, dtypes.float0),  # int inputs: float0 zeros
            jnp.zeros(val_shape),
            np.zeros(idx_t.shape, dtypes.float0),
            jnp.zeros_like(val_t),
            dx,
        )

    aggregate.defvjp(fwd, bwd)
    return aggregate


def init_params(rng: jax.Array, d_cell_raw: int, d_net_raw: int, hidden: int) -> dict:
    """He-initialised parameter pytree mirroring the rust model."""

    def he(key, din, dout):
        return jax.random.normal(key, (din, dout)) * jnp.sqrt(2.0 / din)

    keys = iter(jax.random.split(rng, 32))

    def linear(din, dout):
        return {"w": he(next(keys), din, dout), "b": jnp.zeros((dout,))}

    def sage(d_src, d_dst, dout):
        return {
            "w_self": he(next(keys), d_dst, dout),
            "w_neigh": he(next(keys), d_src, dout),
            "b": jnp.zeros((dout,)),
        }

    def conv(h):
        return {
            "near": linear(h, h),  # GraphConv weight
            "pinned": sage(h, h, h),
            "pins": sage(h, h, h),
        }

    return {
        "lin_cell": linear(d_cell_raw, hidden),
        "lin_net": linear(d_net_raw, hidden),
        "conv1": conv(hidden),
        "conv2": conv(hidden),
        "out": linear(hidden, 1),
    }


def hetero_conv(params, agg_cell, agg_net, graph, x_cell, x_net):
    """One HeteroConv block (paper eqs. 5–9)."""
    h_near = agg_cell(
        graph["near_idx"], graph["near_val"], graph["near_idx_t"], graph["near_val_t"], x_cell
    )
    h_pinned = agg_net(
        graph["pinned_idx"],
        graph["pinned_val"],
        graph["pinned_idx_t"],
        graph["pinned_val_t"],
        x_net,
    )
    h_pins = agg_cell(
        graph["pins_idx"], graph["pins_val"], graph["pins_idx_t"], graph["pins_val_t"], x_cell
    )
    y_near = h_near @ params["near"]["w"] + params["near"]["b"]
    p = params["pinned"]
    y_pinned = x_cell @ p["w_self"] + h_pinned @ p["w_neigh"] + p["b"]
    q = params["pins"]
    y_net = x_net @ q["w_self"] + h_pins @ q["w_neigh"] + q["b"]
    # eq. 8: element-wise max merge on the cell side.
    y_cell = jnp.maximum(y_near, y_pinned)
    return y_cell, y_net


def forward(params, graph, x_cell_raw, x_net_raw, k_cell: int, k_net: int):
    """Full model forward: per-cell congestion prediction."""
    agg_cell = make_aggregate(k_cell)
    agg_net = make_aggregate(k_net)
    xc = x_cell_raw @ params["lin_cell"]["w"] + params["lin_cell"]["b"]
    xn = x_net_raw @ params["lin_net"]["w"] + params["lin_net"]["b"]
    c1, n1 = hetero_conv(params["conv1"], agg_cell, agg_net, graph, xc, xn)
    c2, _n2 = hetero_conv(params["conv2"], agg_cell, agg_net, graph, c1, n1)
    return c2 @ params["out"]["w"] + params["out"]["b"]


def loss_fn(params, graph, x_cell_raw, x_net_raw, y_cell, cell_mask, k_cell, k_net):
    """Masked MSE over real (non-padded) cells."""
    pred = forward(params, graph, x_cell_raw, x_net_raw, k_cell, k_net)
    diff = (pred - y_cell) * cell_mask
    return jnp.sum(diff * diff) / jnp.maximum(jnp.sum(cell_mask), 1.0)


def cast_graph(graph_f32: dict) -> dict:
    """Cast f32-encoded index arrays to int32 (rust feeds f32 only)."""
    out = {}
    for name, arr in graph_f32.items():
        if name.endswith("idx") or name.endswith("idx_t"):
            out[name] = arr.astype(jnp.int32)
        else:
            out[name] = arr
    return out


# Canonical ordering of graph tensors for positional HLO inputs.
GRAPH_KEYS = [
    "near_idx",
    "near_val",
    "near_idx_t",
    "near_val_t",
    "pinned_idx",
    "pinned_val",
    "pinned_idx_t",
    "pinned_val_t",
    "pins_idx",
    "pins_val",
    "pins_idx_t",
    "pins_val_t",
]

# Canonical ordering of parameter leaves for positional HLO inputs.
PARAM_KEYS = [
    ("lin_cell", "w"),
    ("lin_cell", "b"),
    ("lin_net", "w"),
    ("lin_net", "b"),
    ("conv1", "near", "w"),
    ("conv1", "near", "b"),
    ("conv1", "pinned", "w_self"),
    ("conv1", "pinned", "w_neigh"),
    ("conv1", "pinned", "b"),
    ("conv1", "pins", "w_self"),
    ("conv1", "pins", "w_neigh"),
    ("conv1", "pins", "b"),
    ("conv2", "near", "w"),
    ("conv2", "near", "b"),
    ("conv2", "pinned", "w_self"),
    ("conv2", "pinned", "w_neigh"),
    ("conv2", "pinned", "b"),
    ("conv2", "pins", "w_self"),
    ("conv2", "pins", "w_neigh"),
    ("conv2", "pins", "b"),
    ("out", "w"),
    ("out", "b"),
]


# conv2's pins module feeds the (unused) final net embedding: Fig. 1 reads
# the congestion head off the cell path only, so these parameters carry no
# gradient. XLA eliminates dead inputs from the compiled executable, so the
# AOT artifacts expose only the LIVE parameters (the rust coordinator keeps
# the same convention).
DEAD_PARAM_KEYS = [
    ("conv2", "pins", "w_self"),
    ("conv2", "pins", "w_neigh"),
    ("conv2", "pins", "b"),
]
LIVE_PARAM_KEYS = [p for p in PARAM_KEYS if p not in DEAD_PARAM_KEYS]


def params_to_list(params: dict) -> list:
    """Flatten the parameter pytree in canonical order."""
    out = []
    for path in PARAM_KEYS:
        node = params
        for key in path:
            node = node[key]
        out.append(node)
    return out


def params_to_live_list(params: dict) -> list:
    """Flatten only the live (gradient-carrying) parameters."""
    out = []
    for path in LIVE_PARAM_KEYS:
        node = params
        for key in path:
            node = node[key]
        out.append(node)
    return out


def params_from_live_list(leaves: list) -> dict:
    """Rebuild the full pytree from live leaves, zero-filling dead params."""
    params: dict = {}
    for path, leaf in zip(LIVE_PARAM_KEYS, leaves):
        node = params
        for key in path[:-1]:
            node = node.setdefault(key, {})
        node[path[-1]] = leaf
    hidden = params["lin_cell"]["w"].shape[1]
    params["conv2"]["pins"] = {
        "w_self": jnp.zeros((hidden, hidden)),
        "w_neigh": jnp.zeros((hidden, hidden)),
        "b": jnp.zeros((hidden,)),
    }
    return params


def params_from_list(leaves: list) -> dict:
    """Inverse of params_to_list."""
    params: dict = {}
    for path, leaf in zip(PARAM_KEYS, leaves):
        node = params
        for key in path[:-1]:
            node = node.setdefault(key, {})
        node[path[-1]] = leaf
    return params


def step_fn(k_cell: int, k_net: int):
    """Positional (loss, grads) function suitable for AOT lowering.

    Signature: (p0..p18 live params, g0..g11, x_cell, x_net, y, mask) →
               (loss, grad_p0..grad_p18)
    Graph index arrays arrive as f32 and are cast inside.
    """

    def fn(*args):
        n_p = len(LIVE_PARAM_KEYS)
        n_g = len(GRAPH_KEYS)
        live = list(args[:n_p])
        graph_f32 = dict(zip(GRAPH_KEYS, args[n_p : n_p + n_g]))
        graph = cast_graph(graph_f32)
        x_cell, x_net, y, mask = args[n_p + n_g :]

        def loss_of(live_leaves):
            params = params_from_live_list(list(live_leaves))
            return loss_fn(params, graph, x_cell, x_net, y, mask, k_cell, k_net)

        loss, grads = jax.value_and_grad(loss_of)(tuple(live))
        return (loss, *grads)

    return fn


def fwd_fn(k_cell: int, k_net: int):
    """Positional inference function:
    (live params..., graph..., x_cell, x_net) → pred."""

    def fn(*args):
        n_p = len(LIVE_PARAM_KEYS)
        n_g = len(GRAPH_KEYS)
        params = params_from_live_list(list(args[:n_p]))
        graph = cast_graph(dict(zip(GRAPH_KEYS, args[n_p : n_p + n_g])))
        x_cell, x_net = args[n_p + n_g :]
        return (forward(params, graph, x_cell, x_net, k_cell, k_net),)

    return fn


def spmm_fn(k: int):
    """Standalone DR-SpMM artifact: (idx_f32, val, x) → (y,).

    Used by the rust parallel pipeline example to drive three independent
    PJRT executions (the cudaStream analog at the runtime level).
    """

    def fn(idx_f32, val, x):
        idx = idx_f32.astype(jnp.int32)
        return (dr_spmm(idx, val, drelu(x, k)),)

    return fn
