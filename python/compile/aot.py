"""AOT lowering: jax → StableHLO → XlaComputation → HLO *text* artifacts.

HLO text (NOT `.serialize()`): jax ≥0.5 emits HloModuleProto with 64-bit
instruction ids which the rust side's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/gen_hlo.py.

Emitted artifacts (each `<name>.hlo.txt` + `<name>.meta`):
  * hgnn_step_d{dim}  — fused train step: (params, graph, feats, y, mask)
                        → (loss, grads). The rust Adam applies the update.
  * hgnn_fwd_d{dim}   — inference forward → per-cell prediction.
  * spmm_{edge}_d{dim} — standalone DR-SpMM kernels for the parallel
                        pipeline example (one PJRT executable per edge type,
                        dispatched from three rust threads).

Usage: python -m compile.aot --out ../artifacts [--dim 64]
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import graph_spec as gs
from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_artifact(out_dir, name, lowered, input_specs, output_specs, notes):
    text = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)
    meta_path = os.path.join(out_dir, f"{name}.meta")
    with open(meta_path, "w") as f:
        for iname, shape in input_specs:
            f.write(f"input {iname} {' '.join(str(d) for d in shape)}\n")
        for oname, shape in output_specs:
            f.write(f"output {oname} {' '.join(str(d) for d in shape)}\n")
        for note in notes:
            f.write(f"note {note}\n")
    print(f"wrote {name}: {len(text)} chars, {len(input_specs)} inputs")


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def graph_specs():
    """(name, shape) for the 12 graph tensors, canonical order.

    All f32 — index tensors are f32-encoded (cast inside the model).
    Forward ELL is destination-major; transposed ELL is source-major.
    """
    shapes = {
        "near_idx": (gs.N_CELL, gs.W_NEAR),
        "near_val": (gs.N_CELL, gs.W_NEAR),
        "near_idx_t": (gs.N_CELL, gs.W_NEAR),
        "near_val_t": (gs.N_CELL, gs.W_NEAR),
        "pinned_idx": (gs.N_CELL, gs.W_PINNED),
        "pinned_val": (gs.N_CELL, gs.W_PINNED),
        "pinned_idx_t": (gs.N_NET, gs.W_PINS),
        "pinned_val_t": (gs.N_NET, gs.W_PINS),
        "pins_idx": (gs.N_NET, gs.W_PINS),
        "pins_val": (gs.N_NET, gs.W_PINS),
        "pins_idx_t": (gs.N_CELL, gs.W_PINNED),
        "pins_val_t": (gs.N_CELL, gs.W_PINNED),
    }
    return [(k, shapes[k]) for k in model.GRAPH_KEYS]


def param_specs(hidden):
    """(name, shape) for the 19 live parameter tensors, canonical order.

    conv2.pins is dead (see model.DEAD_PARAM_KEYS) and excluded — XLA would
    strip those inputs from the compiled executable anyway.
    """
    out = []
    for path in model.LIVE_PARAM_KEYS:
        name = ".".join(path)
        if path[0] == "lin_cell":
            shape = (gs.D_CELL_RAW, hidden) if path[-1] == "w" else (hidden,)
        elif path[0] == "lin_net":
            shape = (gs.D_NET_RAW, hidden) if path[-1] == "w" else (hidden,)
        elif path[0] == "out":
            shape = (hidden, 1) if path[-1] == "w" else (1,)
        else:  # conv blocks: all hidden×hidden weights / hidden biases
            shape = (hidden, hidden) if path[-1].startswith("w") else (hidden,)
        out.append((name, shape))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--dim", type=int, default=64, help="hidden width")
    ap.add_argument("--k-cell", type=int, default=gs.K_CELL)
    ap.add_argument("--k-net", type=int, default=gs.K_NET)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    hidden = args.dim

    p_specs = param_specs(hidden)
    g_specs = graph_specs()
    feat_specs = [
        ("x_cell", (gs.N_CELL, gs.D_CELL_RAW)),
        ("x_net", (gs.N_NET, gs.D_NET_RAW)),
    ]
    bucket_note = (
        f"bucket n_cell={gs.N_CELL} n_net={gs.N_NET} w_near={gs.W_NEAR} "
        f"w_pins={gs.W_PINS} w_pinned={gs.W_PINNED} hidden={hidden} "
        f"k_cell={args.k_cell} k_net={args.k_net}"
    )

    # ---- train step artifact ----
    step = model.step_fn(args.k_cell, args.k_net)
    step_inputs = (
        p_specs
        + g_specs
        + feat_specs
        + [("y_cell", (gs.N_CELL, 1)), ("cell_mask", (gs.N_CELL, 1))]
    )
    lowered = jax.jit(step).lower(*[f32(s) for _, s in step_inputs])
    step_outputs = [("loss", ())] + [(f"grad.{n}", s) for n, s in p_specs]
    write_artifact(
        args.out, f"hgnn_step_d{hidden}", lowered, step_inputs, step_outputs, [bucket_note]
    )

    # ---- inference forward artifact ----
    fwd = model.fwd_fn(args.k_cell, args.k_net)
    fwd_inputs = p_specs + g_specs + feat_specs
    lowered = jax.jit(fwd).lower(*[f32(s) for _, s in fwd_inputs])
    write_artifact(
        args.out,
        f"hgnn_fwd_d{hidden}",
        lowered,
        fwd_inputs,
        [("pred", (gs.N_CELL, 1))],
        [bucket_note],
    )

    # ---- standalone DR-SpMM kernels (parallel pipeline example) ----
    for edge, rows, width, n_src, k in [
        ("near", gs.N_CELL, gs.W_NEAR, gs.N_CELL, args.k_cell),
        ("pinned", gs.N_CELL, gs.W_PINNED, gs.N_NET, args.k_net),
        ("pins", gs.N_NET, gs.W_PINS, gs.N_CELL, args.k_cell),
    ]:
        fn = model.spmm_fn(k)
        inputs = [
            ("idx", (rows, width)),
            ("val", (rows, width)),
            ("x", (n_src, hidden)),
        ]
        lowered = jax.jit(fn).lower(*[f32(s) for _, s in inputs])
        write_artifact(
            args.out,
            f"spmm_{edge}_d{hidden}",
            lowered,
            inputs,
            [("y", (rows, hidden))],
            [f"edge {edge} k={k}", bucket_note],
        )


if __name__ == "__main__":
    main()
