//! loom models of the concurrency core (`docs/ANALYSIS.md`).
//!
//! Compiled only under `RUSTFLAGS="--cfg loom"` (which also makes Cargo
//! resolve the loom dependency — see `[target.'cfg(loom)'.dependencies]`).
//! Under that cfg, `util::sync` re-exports loom's `Mutex`/`Condvar`, so the
//! models below drive the *production* `Handoff` and `serve::Queue`
//! implementations — not copies — through every interleaving loom's model
//! checker can reach, under the C11 memory model:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --release --test loom_models
//! ```
//!
//! Close-on-unwind is modeled as an early drop of the RAII closer
//! (`HandoffCloser`) while the peer is blocked: unwinding runs exactly that
//! `Drop` impl, and loom cannot model a panicking thread directly. The
//! `Budget` lease accounting model replicates the `WorkerGuard`
//! enter/exit protocol from `util::pool` (fetch_add / fetch_max /
//! fetch_sub on the live/peak counters) with loom atomics, since the real
//! statics cannot be swapped per-model.
#![cfg(loom)]

use dr_circuitgnn::serve::Queue;
use dr_circuitgnn::util::pool::{Handoff, HandoffCloser};
use loom::sync::atomic::{AtomicUsize, Ordering};
use loom::sync::Arc;
use loom::thread;

#[test]
fn handoff_delivers_in_order_then_closes() {
    loom::model(|| {
        let h = Arc::new(Handoff::new());
        let producer = {
            let h = Arc::clone(&h);
            thread::spawn(move || {
                let closer = HandoffCloser(&h);
                h.put(1u32).expect("consumer alive");
                h.put(2u32).expect("consumer alive");
                drop(closer);
            })
        };
        assert_eq!(h.take(), Some(1));
        assert_eq!(h.take(), Some(2));
        // After the producer closes, take() must observe the shutdown —
        // no lost wakeup leaves the consumer blocked forever (loom would
        // report the deadlock).
        assert_eq!(h.take(), None);
        producer.join().unwrap();
    });
}

#[test]
fn handoff_close_on_unwind_releases_blocked_consumer() {
    loom::model(|| {
        let h = Arc::new(Handoff::<u32>::new());
        let producer = {
            let h = Arc::clone(&h);
            thread::spawn(move || {
                // A stage that "unwinds" before producing anything: the
                // RAII closer drops (the unwind path) without a put.
                let _closer = HandoffCloser(&h);
            })
        };
        // The consumer may already be blocked inside take() when the
        // closer fires — every interleaving must wake it with None.
        assert_eq!(h.take(), None);
        producer.join().unwrap();
    });
}

#[test]
fn handoff_close_then_drain_keeps_the_last_value() {
    loom::model(|| {
        let h = Arc::new(Handoff::new());
        let producer = {
            let h = Arc::clone(&h);
            thread::spawn(move || {
                h.put(7u32).expect("consumer alive");
                // Close with the value still (possibly) in the slot:
                // close-then-drain semantics must keep it takeable.
                h.close();
            })
        };
        assert_eq!(h.take(), Some(7));
        assert_eq!(h.take(), None);
        producer.join().unwrap();
    });
}

#[test]
fn queue_shutdown_while_blocked_loses_nothing() {
    loom::model(|| {
        let q = Arc::new(Queue::bounded(1));
        let producer = {
            let q = Arc::clone(&q);
            thread::spawn(move || {
                q.push(1u32).expect("queue open");
                q.close();
            })
        };
        // The consumer may block on an empty queue before the push, or
        // arrive after close: either way it must pop the item exactly
        // once and then observe shutdown — no deadlock, no lost item.
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
        producer.join().unwrap();
    });
}

#[test]
fn queue_bounded_push_blocks_then_completes() {
    loom::model(|| {
        let q = Arc::new(Queue::bounded(1));
        let producer = {
            let q = Arc::clone(&q);
            thread::spawn(move || {
                // Second push must block until the consumer frees the
                // single slot; close() drains gracefully afterwards.
                q.push(1u32).expect("queue open");
                q.push(2u32).expect("queue open");
                q.close();
            })
        };
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
        producer.join().unwrap();
    });
}

#[test]
fn queue_close_refuses_producers_but_drains_backlog() {
    loom::model(|| {
        let q = Arc::new(Queue::bounded(2));
        q.push(1u32).expect("queue open");
        let closer = {
            let q = Arc::clone(&q);
            thread::spawn(move || q.close())
        };
        // Racing a push against close: it either lands before the close
        // (and must then be popped) or is refused with the item handed
        // back — never silently dropped.
        let second_landed = q.push(2).is_ok();
        closer.join().unwrap();
        assert_eq!(q.pop(), Some(1));
        if second_landed {
            assert_eq!(q.pop(), Some(2));
        }
        assert_eq!(q.pop(), None);
    });
}

/// The `WorkerGuard` live/peak accounting protocol from `util::pool`,
/// replicated on loom atomics: enter = `live.fetch_add(1)` then
/// `peak.fetch_max(live_now)`, exit = `live.fetch_sub(1)`. The invariant
/// the thread-budget tests rely on — the peak never under-counts the
/// true high-water mark of concurrently live workers — must hold in
/// every interleaving, including the window between a worker's add and
/// its max.
#[test]
fn budget_lease_accounting_peak_never_undercounts() {
    loom::model(|| {
        let live = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let both_live = Arc::new(AtomicUsize::new(0));
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let live = Arc::clone(&live);
                let peak = Arc::clone(&peak);
                let both_live = Arc::clone(&both_live);
                thread::spawn(move || {
                    let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    if now == 2 {
                        // Witness: both workers were live at once.
                        both_live.store(1, Ordering::SeqCst);
                    }
                    live.fetch_sub(1, Ordering::SeqCst);
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(live.load(Ordering::SeqCst), 0, "every guard released its slot");
        let p = peak.load(Ordering::SeqCst);
        assert!(p >= 1 && p <= 2, "peak within the budget: {p}");
        if both_live.load(Ordering::SeqCst) == 1 {
            assert_eq!(p, 2, "observed concurrency must be reflected in the peak");
        }
    });
}
