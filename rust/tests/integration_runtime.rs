//! Integration: PJRT runtime — artifact discovery, compilation and
//! execution. Tests that need the AOT artifacts skip (with a notice) when
//! `make artifacts` hasn't run; the artifact-independent pieces always run.

use dr_circuitgnn::datagen::{generate_graph, GraphSpec};
use dr_circuitgnn::runtime::{pad_graph, ArtifactRegistry, Bucket, Runtime};
use dr_circuitgnn::util::rng::Rng;
use std::path::Path;

fn artifacts_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("hgnn_fwd_d64.hlo.txt").exists()
}

#[test]
fn pjrt_cpu_client_initialises() {
    // Without the `xla-backend` cargo feature the stub client reports
    // itself unavailable; that is the expected (skipping) behaviour on CI.
    match Runtime::cpu() {
        Ok(rt) => {
            assert!(rt.device_count() >= 1);
            assert!(!rt.platform().is_empty());
        }
        Err(e) => eprintln!("skipping: PJRT unavailable ({e})"),
    }
}

#[test]
fn registry_scans_and_parses_meta() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let reg = ArtifactRegistry::scan(&artifacts_dir()).unwrap();
    for name in ["hgnn_step_d64", "hgnn_fwd_d64", "spmm_near_d64"] {
        assert!(reg.contains(name), "missing {name}");
    }
    let meta = reg.meta("hgnn_step_d64").unwrap();
    assert_eq!(meta.inputs.len(), 35); // 19 live params + 12 graph + 4
    assert_eq!(meta.outputs.len(), 20); // loss + 19 grads
    let note = meta.notes.iter().find(|n| n.starts_with("bucket")).unwrap();
    let bucket = Bucket::parse_note(note).unwrap();
    assert_eq!(bucket.hidden, 64);
}

#[test]
fn spmm_artifact_executes_and_matches_native_kernel() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let reg = ArtifactRegistry::scan(&artifacts_dir()).unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo_text(&reg.hlo_path("spmm_near_d64")).unwrap();

    // Bucket-shaped inputs from a real padded graph.
    let meta = reg.meta("spmm_near_d64").unwrap();
    let note = meta.notes.iter().find(|n| n.starts_with("bucket")).unwrap();
    let bucket = Bucket::parse_note(note).unwrap();
    let mut rng = Rng::new(5);
    let g = generate_graph(
        &GraphSpec {
            n_cells: bucket.n_cell - 8,
            n_nets: bucket.n_net - 8,
            target_near: (bucket.n_cell - 8) * 16,
            target_pins: (bucket.n_net - 8) * 2,
            d_cell: 16,
            d_net: 16,
        },
        0,
        &mut rng,
    );
    let padded = pad_graph(&g, bucket).unwrap();
    let x = dr_circuitgnn::tensor::Matrix::randn(bucket.n_cell, bucket.hidden, 1.0, &mut rng);
    let outputs = exe
        .run_matrices(&[&padded.graph_tensors[0], &padded.graph_tensors[1], &x])
        .expect("spmm artifact run");
    assert_eq!(outputs.len(), 1);
    let y = &outputs[0];
    assert_eq!(y.len(), bucket.n_cell * bucket.hidden);
    assert!(y.iter().all(|v| v.is_finite()));

    // Cross-check vs the native rust DR-SpMM on the same (normalised) graph.
    let mut near = g.near.clone();
    near.normalize_gcn();
    let compressed = dr_circuitgnn::sparse::drelu(&x, bucket.k_cell);
    // Native kernel over real rows only (artifact computed padded rows too).
    let buckets = dr_circuitgnn::sparse::DegreeBuckets::build(&near);
    // x restricted to real cells for the native path.
    let x_real = x.gather_rows(&(0..g.n_cells).collect::<Vec<_>>());
    let compressed_real = dr_circuitgnn::sparse::drelu(&x_real, bucket.k_cell);
    let y_native = dr_circuitgnn::sparse::dr_spmm(&near, &compressed_real, &buckets);
    let mut max_err = 0f32;
    for r in 0..g.n_cells {
        for c in 0..bucket.hidden {
            let a = y[r * bucket.hidden + c];
            let b = y_native.at(r, c);
            max_err = max_err.max((a - b).abs());
        }
    }
    assert!(
        max_err < 2e-3,
        "PJRT artifact vs native DR-SpMM max err {max_err}"
    );
    let _ = compressed;
}

#[test]
fn fwd_artifact_executes_with_padded_graph() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let reg = ArtifactRegistry::scan(&artifacts_dir()).unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo_text(&reg.hlo_path("hgnn_fwd_d64")).unwrap();
    let meta = reg.meta("hgnn_fwd_d64").unwrap();
    let note = meta.notes.iter().find(|n| n.starts_with("bucket")).unwrap();
    let bucket = Bucket::parse_note(note).unwrap();

    let mut rng = Rng::new(6);
    let g = generate_graph(
        &GraphSpec {
            n_cells: bucket.n_cell / 2,
            n_nets: bucket.n_net / 2,
            target_near: (bucket.n_cell / 2) * 12,
            target_pins: (bucket.n_net / 2) * 2,
            d_cell: 16,
            d_net: 16,
        },
        0,
        &mut rng,
    );
    let p = pad_graph(&g, bucket).unwrap();

    // 19 live parameters with artifact shapes.
    let mut inputs: Vec<(Vec<f32>, Vec<i64>)> = Vec::new();
    for (_, dims) in meta.inputs.iter().take(19) {
        let numel: i64 = dims.iter().product::<i64>().max(1);
        let mut data = vec![0f32; numel as usize];
        for v in data.iter_mut() {
            *v = rng.normal() * 0.1;
        }
        inputs.push((data, dims.clone()));
    }
    for m in &p.graph_tensors {
        inputs.push((m.data.clone(), vec![m.rows as i64, m.cols as i64]));
    }
    inputs.push((p.x_cell.data.clone(), vec![p.x_cell.rows as i64, p.x_cell.cols as i64]));
    inputs.push((p.x_net.data.clone(), vec![p.x_net.rows as i64, p.x_net.cols as i64]));
    let refs: Vec<(&[f32], &[i64])> =
        inputs.iter().map(|(d, s)| (d.as_slice(), s.as_slice())).collect();
    let out = exe.run(&refs).expect("fwd artifact run");
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), bucket.n_cell);
    assert!(out[0].iter().all(|v| v.is_finite()));
}
