//! Integration: the engine subsystem — registry parsing, per-edge-type
//! kernel selection, the `"auto"` policy on the seed datagen designs, and
//! plan caching (CSC/bucket construction once per graph, not per step).

use dr_circuitgnn::datagen::{generate_design, table1_designs};
use dr_circuitgnn::engine::{plan_counters, Engine, EngineBuilder, KernelSpec};
use dr_circuitgnn::graph::EdgeType;
use dr_circuitgnn::nn::{mse, DrCircuitGnn};
use dr_circuitgnn::util::rng::Rng;
use std::sync::Mutex;

/// The plan counters are process-global; tests in this binary run on
/// threads, so every test that builds plans takes this lock to keep the
/// exact-count assertions meaningful.
static COUNTER_GUARD: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    COUNTER_GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn registry_is_the_single_parse_point() {
    let _g = lock();
    // Canonical names and aliases resolve; junk is rejected with the
    // vocabulary listed.
    assert_eq!(KernelSpec::parse("cusparse").unwrap(), KernelSpec::Csr);
    assert_eq!(KernelSpec::parse("GNNAdvisor").unwrap(), KernelSpec::Gnna);
    assert_eq!(KernelSpec::parse("DR-SpMM").unwrap(), KernelSpec::Dr);
    assert_eq!(KernelSpec::parse("ellpack").unwrap(), KernelSpec::Ell);
    assert_eq!(KernelSpec::parse("blocked-csr").unwrap(), KernelSpec::Bcsr);
    assert_eq!(KernelSpec::parse("auto").unwrap(), KernelSpec::Auto);
    let err = KernelSpec::parse("nope").unwrap_err();
    for name in ["csr", "gnna", "dr", "ell", "bcsr", "auto"] {
        assert!(err.contains(name), "{err}");
    }
}

#[test]
fn per_edge_type_kernel_selection() {
    let _g = lock();
    let designs = table1_designs(0.02);
    let graphs = generate_design(&designs[0]);
    let g = &graphs[0];
    let engine = Engine::builder()
        .kernel_for(EdgeType::Near, "dr")
        .kernel_for(EdgeType::Pins, "csr")
        .kernel_for(EdgeType::Pinned, "gnna")
        .k_cell(4)
        .build(g);
    assert_eq!(engine.kernel_name(EdgeType::Near), "dr");
    assert_eq!(engine.kernel_name(EdgeType::Pins), "csr");
    assert_eq!(engine.kernel_name(EdgeType::Pinned), "gnna");
    // And the mixed engine actually runs a model step.
    let mut rng = Rng::new(1);
    let mut model = DrCircuitGnn::new(g.x_cell.cols, g.x_net.cols, 16, &mut rng);
    let pred = model.forward(&engine, g);
    assert_eq!(pred.rows, g.n_cells);
    let (_, dp) = mse(&pred, &g.y_cell);
    model.backward(&engine, &dp);
}

/// Acceptance: `"auto"` must select DR or CSR — never the GNNA analog —
/// for the low-degree `pins`/`pinned` matrices of every seed datagen
/// design (paper Fig. 4: GNNA's fixed groups are mostly padding there).
#[test]
fn auto_selects_dr_or_csr_for_low_degree_pins_and_pinned() {
    let _g = lock();
    for spec in table1_designs(0.05) {
        let graphs = generate_design(&spec);
        for g in &graphs {
            let engine = EngineBuilder::auto().build(g);
            for e in [EdgeType::Pins, EdgeType::Pinned] {
                let picked = engine.kernel_name(e);
                assert_ne!(
                    picked,
                    "gnna",
                    "{} graph {} {}: auto must not pick GNNA (avg degree {:.1})",
                    spec.name,
                    g.id,
                    e.name(),
                    g.adj(e).avg_degree()
                );
                assert!(
                    picked == "dr" || picked == "csr",
                    "{} graph {} {}: picked {picked}",
                    spec.name,
                    g.id,
                    e.name()
                );
            }
        }
    }
}

/// Acceptance: plan construction (CSC transpose + degree buckets) happens
/// once per graph per kernel at `build`, and never again across forward/
/// backward steps — the plan/execute split's whole point.
#[test]
fn plans_built_once_per_graph_not_per_step() {
    let _g = lock();
    let designs = table1_designs(0.02);
    let graphs = generate_design(&designs[1]);

    let c0 = plan_counters();
    let engines: Vec<Engine> =
        graphs.iter().map(|g| EngineBuilder::dr(4, 4).build(g)).collect();
    let built = plan_counters().since(&c0);
    assert_eq!(built.plans, 3 * graphs.len(), "3 plans (edge types) per graph");
    assert_eq!(built.cscs, 3 * graphs.len(), "one CSC per plan");
    assert_eq!(built.buckets, 3 * graphs.len(), "DR plans carry buckets");
    assert_eq!(built.groups, 0, "no GNNA schedules for a DR engine");

    // Train-style loop: many epochs over the same engines.
    let mut rng = Rng::new(2);
    let g0 = &graphs[0];
    let mut model = DrCircuitGnn::new(g0.x_cell.cols, g0.x_net.cols, 16, &mut rng);
    let c1 = plan_counters();
    for _ in 0..5 {
        for (g, engine) in graphs.iter().zip(&engines) {
            let pred = model.forward(engine, g);
            let (_, dp) = mse(&pred, &g.y_cell);
            model.backward(engine, &dp);
        }
    }
    let during = plan_counters().since(&c1);
    assert_eq!(
        during.plans, 0,
        "no CSC/bucket/group construction during training steps: {during:?}"
    );
}

#[test]
fn gnna_engine_plans_carry_group_schedules() {
    let _g = lock();
    let designs = table1_designs(0.02);
    let g = &generate_design(&designs[0])[0];
    let c0 = plan_counters();
    let engine = EngineBuilder::gnna(Default::default()).build(g);
    let built = plan_counters().since(&c0);
    assert_eq!(built.plans, 3);
    assert_eq!(built.groups, 3, "one fwd+bwd group schedule per edge type");
    assert_eq!(built.buckets, 0);
    assert_eq!(engine.describe(), "GNNA");
}

/// The PR-7 backends through the whole stack: plan-time payloads are
/// built exactly once per graph, and a full model forward agrees with
/// the CSR reference engine.
#[test]
fn ell_and_bcsr_engines_plan_once_and_match_csr() {
    let _g = lock();
    let designs = table1_designs(0.02);
    let g = &generate_design(&designs[0])[0];

    let c0 = plan_counters();
    let ell = EngineBuilder::default().kernel("ell").build(g);
    let built = plan_counters().since(&c0);
    assert_eq!(built.plans, 3);
    assert_eq!(built.ells, 3, "one ELL layout per edge type");
    assert_eq!(built.blocks, 0, "no block schedules for an ELL engine");
    assert_eq!(ell.describe(), "ELLPACK");

    let c1 = plan_counters();
    let bcsr = EngineBuilder::default().kernel("bcsr").build(g);
    let built = plan_counters().since(&c1);
    assert_eq!(built.plans, 3);
    assert_eq!(built.blocks, 3, "one block schedule per edge type");
    assert_eq!(built.ells, 0, "no ELL layouts for a BCSR engine");
    assert_eq!(bcsr.describe(), "Blocked-CSR");

    let csr = EngineBuilder::csr().build(g);
    let mut rng = Rng::new(3);
    let mut model = DrCircuitGnn::new(g.x_cell.cols, g.x_net.cols, 16, &mut rng);
    let p_csr = model.forward(&csr, g);
    let p_ell = model.forward(&ell, g);
    let p_bcsr = model.forward(&bcsr, g);
    assert_eq!(p_csr.data.len(), p_ell.data.len());
    for i in 0..p_csr.data.len() {
        assert!(
            (p_csr.data[i] - p_ell.data[i]).abs() <= 1e-5,
            "ell diverges from csr at {i}: {} vs {}",
            p_ell.data[i],
            p_csr.data[i]
        );
        assert_eq!(
            p_csr.data[i].to_bits(),
            p_bcsr.data[i].to_bits(),
            "bcsr must be bitwise-identical to csr at {i}"
        );
    }
}

#[test]
fn engine_describe_reflects_resolution() {
    let _g = lock();
    let designs = table1_designs(0.02);
    let g = &generate_design(&designs[0])[0];
    assert_eq!(EngineBuilder::csr().build(g).describe(), "cuSPARSE");
    assert_eq!(EngineBuilder::dr(8, 8).build(g).describe(), "DR-SpMM");
    // Auto resolves to concrete names — never "auto".
    let auto = EngineBuilder::auto().build(g);
    for e in EdgeType::ALL {
        assert_ne!(auto.kernel_name(e), "auto");
    }
}
