//! Integration: the serve loop (ISSUE 6) — N jobs through the bounded
//! queue must produce bit-identical per-job reports to N standalone runs,
//! at every worker count, under a starved thread budget, and with the
//! plan store attached.

use dr_circuitgnn::datagen::{generate_graph, GraphSpec};
use dr_circuitgnn::engine::EngineBuilder;
use dr_circuitgnn::fleet::PlanCache;
use dr_circuitgnn::graph::HeteroGraph;
use dr_circuitgnn::serve::{parse_jobs, JobSpec, ServeConfig, ServeReport, Server};
use dr_circuitgnn::util::pool::Budget;
use dr_circuitgnn::util::rng::Rng;
use std::path::PathBuf;
use std::sync::Arc;

fn catalog() -> Vec<(String, Vec<HeteroGraph>)> {
    let mut rng = Rng::new(5);
    ["alpha", "beta", "gamma"]
        .iter()
        .enumerate()
        .map(|(i, name)| {
            let spec = GraphSpec {
                n_cells: 36 + 4 * i,
                n_nets: 14 + 2 * i,
                target_near: 220,
                target_pins: 60,
                d_cell: 6,
                d_net: 6,
            };
            let graphs = (0..2).map(|j| generate_graph(&spec, j, &mut rng)).collect();
            (name.to_string(), graphs)
        })
        .collect()
}

fn jobs() -> Vec<JobSpec> {
    parse_jobs(
        "design=alpha epochs=2 seed=1\n\
         design=beta  epochs=2 seed=2 hidden=16\n\
         design=gamma epochs=3 seed=3\n\
         design=alpha epochs=2 seed=4 fleet=2\n\
         design=beta  epochs=2 seed=5\n",
    )
    .unwrap()
}

fn run(catalog: &[(String, Vec<HeteroGraph>)], workers: usize, queue_cap: usize) -> ServeReport {
    let cache = Arc::new(PlanCache::new(EngineBuilder::dr(4, 4)));
    let server = Server::new(catalog, cache);
    server.run(&jobs(), &ServeConfig { workers, queue_cap }).unwrap()
}

fn trace(report: &ServeReport) -> Vec<(usize, Vec<u64>, u64)> {
    report
        .results
        .iter()
        .map(|r| {
            (
                r.id,
                r.report.epoch_losses.iter().map(|v| v.to_bits()).collect(),
                r.report.test_scores.mae.to_bits(),
            )
        })
        .collect()
}

/// The determinism gate: any worker count and queue depth produces the
/// same bits as the single-worker (fully sequential) reference — job
/// interleaving over the shared cache must never leak between jobs.
#[test]
fn concurrent_serving_matches_sequential_bitwise() {
    let catalog = catalog();
    let reference = trace(&run(&catalog, 1, 16));
    assert_eq!(reference.len(), 5);
    for workers in [2usize, 4] {
        for queue_cap in [1usize, 16] {
            let got = trace(&run(&catalog, workers, queue_cap));
            assert_eq!(
                got, reference,
                "{workers} workers / queue cap {queue_cap} diverged from sequential"
            );
        }
    }
}

/// Same gate under a starved two-thread budget — the CI
/// `DRCG_THREADS=2` lane runs this file, so fairness degradation
/// (workers sharing one lease) must not move a bit either.
#[test]
fn starved_budget_serving_matches_sequential_bitwise() {
    let catalog = catalog();
    let reference = trace(&run(&catalog, 1, 16));
    let starved = Budget::new(2).with(|| trace(&run(&catalog, 4, 2)));
    assert_eq!(starved, reference, "starved budget diverged from sequential");
}

/// FIFO admission + fair workers: every job completes exactly once,
/// results come back sorted by id, and the shared cache dedupes repeat
/// designs across jobs.
#[test]
fn all_jobs_complete_once_and_share_the_cache() {
    let catalog = catalog();
    let report = run(&catalog, 3, 2);
    let ids: Vec<usize> = report.results.iter().map(|r| r.id).collect();
    assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    assert!(report.workers >= 1 && report.workers <= 3);
    // 3 designs × 2 graphs = 6 unique engines; 5 jobs over them.
    assert_eq!(report.cache.unique(), 6);
    assert!(report.cache.hits > 0, "repeat designs must hit the shared cache");
    for r in &report.results {
        assert!(r.total_seconds >= r.train_seconds);
        assert!(r.queue_seconds >= 0.0);
        assert_eq!(r.report.epoch_losses.len(), r.job.epochs);
    }
}

/// Serve over a disk-backed cache: a second server over the same store
/// directory warm-starts every plan — zero cold builds across the whole
/// run — and still reproduces the first run's bits.
#[test]
fn serve_warm_starts_from_a_plan_store() {
    let dir: PathBuf =
        std::env::temp_dir().join(format!("drcg-it-serve-store-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let catalog = catalog();

    let cold = {
        let cache = Arc::new(PlanCache::backed_by(EngineBuilder::dr(4, 4), &dir).unwrap());
        let server = Server::new(&catalog, cache);
        server.run(&jobs(), &ServeConfig { workers: 2, queue_cap: 4 }).unwrap()
    };
    assert_eq!(cold.cache.misses, 6, "cold serve builds every unique plan");
    assert_eq!(cold.cache.disk_stores, 6);

    let warm = {
        let cache = Arc::new(PlanCache::backed_by(EngineBuilder::dr(4, 4), &dir).unwrap());
        let server = Server::new(&catalog, cache);
        server.run(&jobs(), &ServeConfig { workers: 2, queue_cap: 4 }).unwrap()
    };
    assert_eq!(warm.cache.misses, 0, "warm serve builds zero plans cold");
    assert_eq!(warm.cache.disk_loads, 6, "every plan loads from the store");
    assert!(warm.warm_rate() > 0.99, "all lookups warm: {}", warm.warm_rate());
    assert_eq!(trace(&warm), trace(&cold), "warm start changed serve numerics");
    std::fs::remove_dir_all(&dir).ok();
}
