//! Integration: trainer + metrics + K-profiler over Mini-CircuitNet.

use dr_circuitgnn::datagen::mini_circuitnet;
use dr_circuitgnn::engine::EngineBuilder;
use dr_circuitgnn::nn::HomoKind;
use dr_circuitgnn::train::kprofile::{candidate_ks, profile_optimal_k, to_type_ks};
use dr_circuitgnn::train::{TrainConfig, Trainer};

fn cfg(epochs: usize) -> TrainConfig {
    TrainConfig {
        epochs,
        lr: 5e-3,
        weight_decay: 0.0,
        hidden: 24,
        seed: 3,
        parallel: false,
        epoch_pipeline: false,
        log_every: 0,
        ..TrainConfig::dr_default()
    }
}

#[test]
fn dr_training_end_to_end_with_metrics() {
    let (train, test) = mini_circuitnet(6, 0.04, 31);
    let (_m, report) = Trainer::train_dr(&train, &test, &EngineBuilder::dr(6, 6), &cfg(10));
    assert_eq!(report.epoch_losses.len(), 10);
    assert!(report.epoch_losses.last().unwrap() < &report.epoch_losses[0]);
    let s = report.test_scores;
    for v in [s.pearson, s.spearman, s.kendall] {
        assert!((-1.0..=1.0).contains(&v), "correlation out of range: {v}");
    }
    assert!(s.mae >= 0.0 && s.rmse >= s.mae * 0.5);
    // Learnable signal: after training, rank correlation should be
    // positive on held-out designs.
    assert!(s.spearman > 0.0, "spearman {}", s.spearman);
}

#[test]
fn homo_and_dr_comparable_pipeline() {
    let (train, test) = mini_circuitnet(6, 0.04, 33);
    let (_g, homo) = Trainer::train_homo(HomoKind::Sage, &train, &test, &cfg(8));
    let (_d, dr) = Trainer::train_dr(&train, &test, &EngineBuilder::dr(6, 6), &cfg(8));
    // Both produce usable predictors on the same data.
    assert!(homo.test_scores.spearman.is_finite());
    assert!(dr.test_scores.spearman.is_finite());
    assert!(dr.params > homo.params, "hetero model is larger (paper: ≈2x)");
}

#[test]
fn kprofiler_selects_valid_k_per_subgraph() {
    let (train, _) = mini_circuitnet(2, 0.04, 35);
    let g = train.graphs().next().unwrap();
    let profiles = profile_optimal_k(g, 32, 2, 1);
    for p in &profiles {
        assert_eq!(p.timings.len(), candidate_ks(32).len());
        assert!(candidate_ks(32).contains(&p.best_k));
    }
    let (k_cell, k_net) = to_type_ks(&profiles);
    assert!(k_cell >= 2 && k_net >= 2);
    // The profiled optimum should beat the worst candidate meaningfully.
    let near = &profiles[0];
    let best = near.timings.iter().map(|&(_, t)| t).fold(f64::INFINITY, f64::min);
    let worst = near.timings.iter().map(|&(_, t)| t).fold(0.0, f64::max);
    assert!(worst >= best, "profiling must discriminate candidates");
}

#[test]
fn training_deterministic_given_seed() {
    let (train, test) = mini_circuitnet(4, 0.03, 41);
    let (_a, r1) = Trainer::train_dr(&train, &test, &EngineBuilder::dr(4, 4), &cfg(4));
    let (_b, r2) = Trainer::train_dr(&train, &test, &EngineBuilder::dr(4, 4), &cfg(4));
    for (x, y) in r1.epoch_losses.iter().zip(&r2.epoch_losses) {
        assert!((x - y).abs() < 1e-10, "training must be deterministic");
    }
}
