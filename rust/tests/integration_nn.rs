//! Integration: full model forward/backward across engines on generated
//! circuit graphs; gradient flow and engine consistency.

use dr_circuitgnn::datagen::{generate_graph, GraphSpec};
use dr_circuitgnn::engine::EngineBuilder;
use dr_circuitgnn::nn::{homogenize, mse, Adam, DrCircuitGnn, HomoGnn, HomoKind};
use dr_circuitgnn::sparse::GnnaConfig;
use dr_circuitgnn::util::math::assert_allclose;
use dr_circuitgnn::util::rng::Rng;

fn graph() -> dr_circuitgnn::graph::HeteroGraph {
    let mut rng = Rng::new(5);
    generate_graph(
        &GraphSpec {
            n_cells: 400,
            n_nets: 200,
            target_near: 8_000,
            target_pins: 600,
            d_cell: 16,
            d_net: 16,
        },
        0,
        &mut rng,
    )
}

#[test]
fn dr_model_trains_on_generated_graph_all_engines() {
    let g = graph();
    for builder in [
        EngineBuilder::csr(),
        EngineBuilder::gnna(GnnaConfig::default()),
        EngineBuilder::dr(8, 8),
        EngineBuilder::auto(),
    ] {
        let engine = builder.build(&g);
        let mut rng = Rng::new(1);
        let mut model = DrCircuitGnn::new(16, 16, 32, &mut rng);
        let mut opt = Adam::new(5e-3, 0.0);
        let mut losses = Vec::new();
        for _ in 0..12 {
            let pred = model.forward(&engine, &g);
            let (loss, dp) = mse(&pred, &g.y_cell);
            model.backward(&engine, &dp);
            opt.step(&mut model.params_mut());
            Adam::zero_grad(&mut model.params_mut());
            losses.push(loss);
        }
        assert!(
            losses.last().unwrap() < &losses[0],
            "{}: {:?}",
            engine.describe(),
            losses
        );
    }
}

#[test]
fn csr_and_full_k_dr_produce_identical_training() {
    let g = graph();
    let csr_engine = EngineBuilder::csr().build(&g);
    let dr_engine = EngineBuilder::dr(16, 16).build(&g); // k = hidden: no sparsification
    let mut rng = Rng::new(2);
    let m0 = DrCircuitGnn::new(16, 16, 16, &mut rng);
    let mut a = m0.clone();
    let mut b = m0.clone();
    let pa = a.forward(&csr_engine, &g);
    let pb = b.forward(&dr_engine, &g);
    // Same predictions except: baseline path uses plain ReLU between
    // layers, DR path does not — so compare only through one layer by
    // checking both are finite and same shape, then compare grads flow.
    assert_eq!(pa.rows, pb.rows);
    assert!(pa.data.iter().all(|v| v.is_finite()));
    assert!(pb.data.iter().all(|v| v.is_finite()));
}

#[test]
fn parallel_and_sequential_training_bitwise_match() {
    let g = graph();
    let seq_engine = EngineBuilder::dr(4, 4).build(&g);
    let par_engine = EngineBuilder::dr(4, 4).parallel(true).build(&g);
    let mut rng = Rng::new(3);
    let model = DrCircuitGnn::new(16, 16, 32, &mut rng);
    let mut seq = model.clone();
    let mut par = model.clone();
    for _ in 0..3 {
        let ps = seq.forward(&seq_engine, &g);
        let pp = par.forward(&par_engine, &g);
        assert_eq!(ps.data, pp.data, "parallel must not change numerics");
        let (_, ds) = mse(&ps, &g.y_cell);
        seq.backward(&seq_engine, &ds);
        par.backward(&par_engine, &ds);
    }
    // Gradients identical too.
    for (a, b) in seq.params_mut().iter().zip(par.params_mut().iter()) {
        assert_allclose(&a.grad.data, &b.grad.data, 1e-6, 1e-6);
    }
}

#[test]
fn homo_baselines_on_homogenized_circuit_graph() {
    let g = graph();
    let view = homogenize(&g);
    assert_eq!(view.n, g.n_cells + g.n_nets);
    for kind in [HomoKind::Gcn, HomoKind::Sage, HomoKind::Gat] {
        let mut rng = Rng::new(4);
        let mut model = HomoGnn::new(kind, view.x.cols, 16, &mut rng);
        let pred = model.forward(&view);
        assert_eq!(pred.rows, g.n_cells);
        let (_, dp) = mse(&pred, &g.y_cell);
        model.backward(&view, &dp);
        // All params received gradient signal somewhere.
        let total_grad: f32 =
            model.params_mut().iter().map(|p| p.grad.frob_norm()).sum();
        assert!(total_grad > 0.0, "{}: zero gradient", kind.name());
    }
}

#[test]
fn dr_param_count_roughly_double_homo() {
    let g = graph();
    let view = homogenize(&g);
    let mut rng = Rng::new(6);
    let mut dr = DrCircuitGnn::new(16, 16, 64, &mut rng);
    let mut gcn = HomoGnn::new(HomoKind::Gcn, view.x.cols, 64, &mut rng);
    let ratio = dr.numel() as f64 / gcn.numel() as f64;
    assert!(
        ratio > 1.5 && ratio < 6.0,
        "paper says ≈2x params; got ratio {ratio:.2}"
    );
}
