//! Thread-accounting suite for the cooperative budget
//! (`util::pool::Budget`): fleet workers × §3.4 edge lanes × kernel
//! `parallel_for` must never keep more threads live than the root budget,
//! for any worker count, schedule mode or kernel mix — and a budget of 1
//! must degenerate every primitive to inline execution.
//!
//! This is its own test binary (= its own process) on purpose: the
//! live/peak worker counters are process-global, so the tests serialize
//! through a file-local mutex and no other binary's threads can interfere
//! (sibling binaries run as separate processes).
//!
//! CI additionally runs this suite and `integration_fleet` under
//! `DRCG_THREADS=2` — a deliberately starved root budget — to prove the
//! fleet's determinism and the budget invariant hold when leases are tight.

use dr_circuitgnn::datagen::{generate_graph, GraphSpec};
use dr_circuitgnn::engine::{Engine, EngineBuilder};
use dr_circuitgnn::fleet::Fleet;
use dr_circuitgnn::graph::{EdgeType, HeteroGraph};
use dr_circuitgnn::nn::DrCircuitGnn;
use dr_circuitgnn::sched::{run_fleet_e2e_steps, run_lanes, ScheduleMode};
use dr_circuitgnn::util::pool::{
    self, bounded_map, join_all, live_workers, num_threads, parallel_for, peak_workers,
    reset_peak_workers, Budget,
};
use dr_circuitgnn::util::rng::Rng;
use std::sync::Mutex;

/// Serializes the tests: the peak counter is process-global.
static ACCOUNTING_GUARD: Mutex<()> = Mutex::new(());

fn guard() -> std::sync::MutexGuard<'static, ()> {
    ACCOUNTING_GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

/// Big enough that every kernel's row dispatch clears the sequential
/// cutoff (256) — otherwise the budget has nothing to constrain.
fn test_graph(n_cells: usize, seed: u64) -> HeteroGraph {
    let mut rng = Rng::new(seed);
    generate_graph(
        &GraphSpec {
            n_cells,
            n_nets: n_cells / 2,
            target_near: n_cells * 10,
            target_pins: n_cells,
            d_cell: 6,
            d_net: 6,
        },
        0,
        &mut rng,
    )
}

/// The kernel mixes the acceptance criterion names: pure DR / GNNA / CSR
/// plus a mixed per-edge engine.
fn engine_mixes() -> Vec<(&'static str, EngineBuilder)> {
    vec![
        ("dr", EngineBuilder::dr(4, 4).parallel(true)),
        ("csr", Engine::builder().kernel("csr").parallel(true)),
        ("gnna", Engine::builder().kernel("gnna").parallel(true)),
        (
            "mixed",
            EngineBuilder::csr()
                .kernel_for(EdgeType::Near, "dr")
                .kernel_for(EdgeType::Pinned, "gnna")
                .k_cell(4)
                .parallel(true),
        ),
    ]
}

/// Fleet × parallel lanes × kernels: peak live threads (spawned workers
/// plus the driving thread) must stay within the ambient budget for every
/// kernel mix and every budget, including budgets far below the requested
/// worker count.
#[test]
fn fleet_lanes_kernels_never_exceed_budget() {
    let _serial = guard();
    let graphs: Vec<HeteroGraph> = (0..5).map(|i| test_graph(500, 20 + i)).collect();
    // Few graphs + large budget pushes the surplus down into lanes and
    // kernels (three-level nesting); many graphs + small budget starves
    // the lower levels. The invariant must hold across the whole grid.
    for n_graphs in [1usize, 2, 5] {
        for budget in [1usize, 2, 3, 8] {
            for (name, engine) in engine_mixes() {
                let gs = &graphs[..n_graphs];
                assert_eq!(live_workers(), 0, "leaked workers before {name}/{budget}");
                reset_peak_workers();
                let timings = Budget::new(budget).with(|| {
                    run_fleet_e2e_steps(gs, 32, &engine, ScheduleMode::Parallel, 8, 42)
                });
                assert_eq!(timings.len(), gs.len());
                assert_eq!(live_workers(), 0, "leaked workers after {name}/{budget}");
                let peak = peak_workers();
                assert!(
                    peak + 1 <= budget,
                    "budget violated: kernel={name} graphs={n_graphs} \
                     budget={budget} peak spawned={peak}"
                );
            }
        }
    }
}

/// With a budget ≥ 2 the fleet really does go concurrent — the accounting
/// must observe at least one spawned worker (guards against the counters
/// silently measuring nothing).
#[test]
fn accounting_observes_spawned_workers() {
    let _serial = guard();
    let graphs: Vec<HeteroGraph> = (0..4).map(|i| test_graph(300, 50 + i)).collect();
    reset_peak_workers();
    Budget::new(4).with(|| {
        run_fleet_e2e_steps(
            &graphs,
            16,
            &EngineBuilder::dr(4, 4),
            ScheduleMode::Sequential,
            4,
            7,
        )
    });
    // bounded_map leases min(4 workers, 4 graphs, budget 4) = 4
    // participants = caller + 3 spawned.
    assert!(peak_workers() >= 1, "no worker was ever observed live");
    assert!(peak_workers() + 1 <= 4, "peak {} exceeds the budget of 4", peak_workers());
}

/// Fleet training under a constrained budget: bit-identical gradients and
/// losses (the `fleet(N) ≡ sequential` guarantee survives any budget), and
/// the budget invariant holds through model forward/backward, not just the
/// e2e rig.
#[test]
fn fleet_gradients_bitwise_invariant_and_within_budget() {
    let _serial = guard();
    let g = test_graph(300, 3);
    let fleet = Fleet::builder(EngineBuilder::dr(4, 4).parallel(true))
        .parts(4)
        .workers(8)
        .build(std::slice::from_ref(&g));
    let mut rng = Rng::new(5);
    let model = DrCircuitGnn::new(6, 6, 8, &mut rng);
    let base = fleet.gradients(&model); // unconstrained reference
    for budget in [1usize, 2, 4] {
        reset_peak_workers();
        let got = Budget::new(budget).with(|| fleet.gradients(&model));
        assert!(
            peak_workers() + 1 <= budget,
            "budget={budget} peak spawned={}",
            peak_workers()
        );
        assert_eq!(got.loss, base.loss, "budget={budget}");
        assert_eq!(got.subgraph_losses, base.subgraph_losses, "budget={budget}");
        assert_eq!(got.grads.len(), base.grads.len());
        for (a, b) in got.grads.iter().zip(&base.grads) {
            assert_eq!(a.data, b.data, "budget={budget}");
        }
    }
}

/// `DRCG_THREADS=1` semantics: a budget of 1 degenerates every layer —
/// pool primitives, lanes, kernels, the fleet — to inline execution with
/// zero spawned threads.
#[test]
fn budget_of_one_spawns_nothing_anywhere() {
    let _serial = guard();
    assert_eq!(live_workers(), 0);
    reset_peak_workers();
    let before = peak_workers();
    Budget::new(1).with(|| {
        parallel_for(50_000, |_| {});
        let v = bounded_map(6, 6, |i| i);
        assert_eq!(v, (0..6).collect::<Vec<_>>());
        let lanes = run_lanes(ScheduleMode::Parallel, vec![|| 1, || 2, || 3]);
        assert_eq!(lanes, vec![1, 2, 3]);
        let tasks: Vec<_> = (0..3).map(|i| move || i).collect();
        assert_eq!(join_all(tasks), vec![0, 1, 2]);
        let g = test_graph(400, 9);
        let t = run_fleet_e2e_steps(
            std::slice::from_ref(&g),
            16,
            &EngineBuilder::dr(4, 4),
            ScheduleMode::Parallel,
            4,
            1,
        );
        assert_eq!(t.len(), 1);
    });
    assert_eq!(peak_workers(), before, "budget 1 must never spawn a thread");
    assert_eq!(live_workers(), 0);
}

/// The root budget initializes exactly once (first use wins) and honors
/// `DRCG_THREADS` — the CI lane that sets `DRCG_THREADS=2` exercises the
/// env path end to end.
#[test]
fn root_budget_initializes_once_and_honors_env() {
    let _serial = guard();
    let n = num_threads();
    assert!(n >= 1);
    if let Ok(s) = std::env::var("DRCG_THREADS") {
        assert_eq!(n, s.trim().parse::<usize>().unwrap(), "root must equal DRCG_THREADS");
    }
    assert_eq!(Budget::root().threads(), n);
    // Re-initializing to the same value is idempotent; a different value
    // is rejected loudly instead of silently resizing live budgets.
    assert!(pool::set_root_threads(n).is_ok());
    let err = pool::set_root_threads(n + 1).unwrap_err();
    assert!(err.contains("already initialized"), "{err}");
    assert_eq!(num_threads(), n);
}
