//! Golden-trace regression harness (ISSUE 5): the gate for every future
//! scheduler change.
//!
//! A short fleet training run over the three Table-1 seed designs is
//! traced — per (epoch, design): the design loss and the L2 norm of the
//! reduced fleet gradient, both as exact f64 bit patterns — and asserted
//! equal across **three schedules**:
//!
//! * `sequential` — 1 worker, serial epoch loop (the reference);
//! * `fleet`      — 4 workers, serial epoch loop;
//! * `pipelined`  — 4 workers, `sched::run_epoch_pipeline` (design N+1's
//!   prepare overlapping design N's execute + optimizer step).
//!
//! The agreed trace is then compared bit-for-bit against the committed
//! fixture `tests/golden/epoch_traces.txt` (see `tests/golden/README.md`
//! for the bootstrap/regeneration workflow). The csr/dr kernels accumulate
//! in a fixed order and the thread budget never changes numerics, so the
//! trace is identical on any machine, core count, or `DRCG_THREADS`.

use dr_circuitgnn::datagen::{generate_design, table1_designs};
use dr_circuitgnn::engine::EngineBuilder;
use dr_circuitgnn::fleet::{Fleet, FleetGradients, FleetPipeline};
use dr_circuitgnn::graph::HeteroGraph;
use dr_circuitgnn::nn::{Adam, DrCircuitGnn};
use dr_circuitgnn::sched::ScheduleMode;
use dr_circuitgnn::util::rng::Rng;
use std::path::PathBuf;

const EPOCHS: usize = 3;
const SCALE: f64 = 0.02;
const HIDDEN: usize = 16;
const SEED: u64 = 42;

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/epoch_traces.txt")
}

/// The datagen-driven side of the harness: regenerate the three seed
/// designs exactly as the fixture was produced (design seeds are baked
/// into `table1_designs`; the dataset is fully determined by `SCALE`).
fn seed_designs() -> Vec<Vec<HeteroGraph>> {
    table1_designs(SCALE).iter().map(generate_design).collect()
}

fn seed_model(designs: &[Vec<HeteroGraph>]) -> DrCircuitGnn {
    let g0 = &designs[0][0];
    let mut rng = Rng::new(SEED);
    DrCircuitGnn::new(g0.x_cell.cols, g0.x_net.cols, HIDDEN, &mut rng)
}

fn engine() -> EngineBuilder {
    EngineBuilder::dr(4, 4)
}

/// L2 norm of the reduced fleet gradient, accumulated in f64 in parameter
/// order (deterministic).
fn grad_norm(grads: &FleetGradients) -> f64 {
    grads
        .grads
        .iter()
        .flat_map(|m| m.data.iter())
        .map(|&v| (v as f64) * (v as f64))
        .sum::<f64>()
        .sqrt()
}

/// One trace line: exact f64 bit patterns (hex), stable across platforms.
fn line(epoch: usize, design: usize, loss: f64, gnorm: f64) -> String {
    format!("e{epoch} d{design} loss={:016x} gnorm={:016x}", loss.to_bits(), gnorm.to_bits())
}

/// Trace one schedule through the production [`FleetPipeline`] driver —
/// the exact layout `Trainer::train_dr_fleet` runs, for both modes. The
/// sequential reference, the fleet schedule, and the pipelined schedule
/// differ only in worker count and [`ScheduleMode`].
fn trace(designs: &[Vec<HeteroGraph>], workers: usize, mode: ScheduleMode) -> Vec<String> {
    let pipeline = FleetPipeline::new(
        Fleet::builder(engine()).workers(workers),
        designs.iter().map(|gs| gs.as_slice()).collect(),
    );
    let mut model = seed_model(designs);
    let mut opt = Adam::new(2e-4, 1e-5);
    let mut out = Vec::new();
    for epoch in 0..EPOCHS {
        let run = pipeline.run_epoch(mode, |d, fleet, staged| {
            let grads = fleet.gradients_staged(staged, &model);
            let gnorm = grad_norm(&grads);
            let step = fleet.apply_update(&mut model, &mut opt, grads);
            line(epoch, d, step.loss, gnorm)
        });
        out.extend(run.results);
    }
    out
}

#[test]
fn all_schedules_reproduce_the_golden_traces() {
    let designs = seed_designs();
    assert_eq!(designs.len(), 3, "three seed designs");

    let sequential = trace(&designs, 1, ScheduleMode::Sequential);
    let fleet = trace(&designs, 4, ScheduleMode::Sequential);
    let pipelined = trace(&designs, 4, ScheduleMode::Parallel);
    assert_eq!(sequential, fleet, "fleet schedule must match the sequential reference");
    assert_eq!(sequential, pipelined, "pipelined schedule must match the sequential reference");

    let body = format!("{}\n", sequential.join("\n"));
    let content = format!(
        "# Golden epoch traces — see tests/golden/README.md.\n\
         # config: table1_designs({SCALE}), dr(4,4), hidden {HIDDEN}, seed {SEED}, \
         {EPOCHS} epochs, Adam(2e-4, 1e-5)\n{body}"
    );

    let path = fixture_path();
    let update = std::env::var("DRCG_UPDATE_GOLDEN").is_ok_and(|v| v == "1");
    let require = std::env::var("DRCG_REQUIRE_GOLDEN").is_ok_and(|v| v == "1");
    match std::fs::read_to_string(&path) {
        // Hard mode (CI sets DRCG_REQUIRE_GOLDEN=1): a missing fixture is
        // a failure, not a bootstrap — otherwise every fresh checkout
        // would silently re-baseline and the cross-commit gate would be
        // vacuous. Generate locally with `cargo test --test
        // integration_golden` and commit the file.
        Err(e) if require => panic!(
            "golden fixture {} unreadable ({e}) under DRCG_REQUIRE_GOLDEN=1 — \
             run `cargo test -q --test integration_golden` without the variable \
             to bootstrap it, then commit it (see tests/golden/README.md)",
            path.display()
        ),
        Ok(existing) if !update => {
            let want: Vec<&str> =
                existing.lines().filter(|l| !l.trim_start().starts_with('#')).collect();
            let got: Vec<&str> = sequential.iter().map(String::as_str).collect();
            assert_eq!(
                got, want,
                "trace diverged from {} — a scheduler/kernel change moved the numerics. \
                 If (and only if) the change is an intentional numerics change, regenerate \
                 with DRCG_UPDATE_GOLDEN=1 (see tests/golden/README.md).",
                path.display()
            );
        }
        _ => {
            std::fs::write(&path, &content)
                .unwrap_or_else(|e| panic!("cannot write {}: {e}", path.display()));
            eprintln!(
                "bootstrapped: wrote {} ({} trace lines) — commit this fixture",
                path.display(),
                sequential.len()
            );
        }
    }
}

/// Activation checkpointing (ISSUE 10) must reproduce the golden trace bit
/// for bit: recomputing activations in backward is a memory strategy, not
/// a numerics change, so a checkpointed model walks the exact trajectory
/// the committed fixture pins.
#[test]
fn checkpointed_model_reproduces_the_golden_trace() {
    let designs = seed_designs();
    let plain = trace(&designs, 4, ScheduleMode::Sequential);

    let pipeline = FleetPipeline::new(
        Fleet::builder(engine()).workers(4),
        designs.iter().map(|gs| gs.as_slice()).collect(),
    );
    let mut model = seed_model(&designs);
    model.set_checkpoint(true);
    let mut opt = Adam::new(2e-4, 1e-5);
    let mut ckpt = Vec::new();
    for epoch in 0..EPOCHS {
        let run = pipeline.run_epoch(ScheduleMode::Sequential, |d, fleet, staged| {
            let grads = fleet.gradients_staged(staged, &model);
            let gnorm = grad_norm(&grads);
            let step = fleet.apply_update(&mut model, &mut opt, grads);
            line(epoch, d, step.loss, gnorm)
        });
        ckpt.extend(run.results);
    }
    assert_eq!(plain, ckpt, "checkpointing must not move a bit of the golden trace");
}

/// The golden trace must also be invariant under a starved thread budget —
/// the property that lets the `DRCG_THREADS=2` CI lane run this harness.
#[test]
fn golden_traces_are_budget_invariant() {
    use dr_circuitgnn::util::pool::Budget;
    let designs = seed_designs();
    let wide = trace(&designs, 4, ScheduleMode::Parallel);
    let starved = Budget::new(1).with(|| trace(&designs, 4, ScheduleMode::Parallel));
    assert_eq!(wide, starved, "thread budget must never move a bit");
}
