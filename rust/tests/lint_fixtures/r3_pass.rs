// R3 must pass: the one documented poisoning policy — recover the guard
// with into_inner() and keep going.
use std::sync::{Condvar, Mutex};

pub fn recovering(m: &Mutex<Vec<u32>>) -> usize {
    m.lock().unwrap_or_else(|e| e.into_inner()).len()
}

pub fn split(m: &Mutex<Vec<u32>>) -> usize {
    m.lock()
        .unwrap_or_else(|e| e.into_inner())
        .len()
}

pub fn consume(m: Mutex<Vec<u32>>) -> Vec<u32> {
    m.into_inner().unwrap_or_else(|e| e.into_inner())
}

pub fn waiting(m: &Mutex<bool>, c: &Condvar) {
    let mut g = m.lock().unwrap_or_else(|e| e.into_inner());
    while !*g {
        g = c.wait(g).unwrap_or_else(|e| e.into_inner());
    }
}

pub fn unrelated_unwrap(v: Option<u32>) -> u32 {
    v.unwrap()
}
