// R2 must fire twice outside util::pool: raw fan-out and a new
// cross-thread capability.
pub fn fan_out() {
    std::thread::scope(|s| {
        s.spawn(|| {});
    });
}

pub struct Wrapper(pub *mut u8);

// SAFETY: documented, so R1 passes — R2 must still reject the capability.
unsafe impl Send for Wrapper {}
