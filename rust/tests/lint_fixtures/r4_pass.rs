// R4 must pass: ordered containers in trace paths; wall clocks and hash
// maps confined to test modules.
use std::collections::BTreeMap;

pub fn degree_histogram(degrees: &[u32]) -> BTreeMap<u32, usize> {
    let mut h = BTreeMap::new();
    for &d in degrees {
        *h.entry(d).or_insert(0) += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;
    use std::time::Instant;

    #[test]
    fn timing_scratch_is_fine_in_tests() {
        let t = Instant::now();
        let mut h = HashMap::new();
        h.insert(1u32, t);
    }
}
