// R2 must pass: the budgeted primitives are the sanctioned fan-out, and
// test modules may spawn scratch threads.
pub fn fan_out(n: usize) {
    crate::util::pool::parallel_for(n, |_i| {});
}

#[cfg(test)]
mod tests {
    #[test]
    fn scratch_threads_are_fine_in_tests() {
        std::thread::scope(|s| {
            s.spawn(|| {});
        });
    }
}
