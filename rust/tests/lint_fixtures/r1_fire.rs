// R1 must fire: unsafe without a SAFETY comment anywhere nearby.
pub fn scatter(p: *mut f32, i: usize, v: f32) {
    let q = p;

    unsafe { *q.add(i) = v };
}

pub struct RawCell(pub *mut u8);

unsafe impl Send for RawCell {}
