// R1 must pass: every unsafe documents its disjointness contract.
pub fn scatter(p: *mut f32, i: usize, v: f32) {
    // SAFETY: index i is owned exclusively by this caller.
    unsafe { *p.add(i) = v };
}

pub fn gather(p: *const f32, i: usize) -> f32 {
    // A comment line in between is fine:
    // SAFETY: i is in bounds by the caller's contract.
    unsafe { *p.add(i) }
}

// Doc text that merely mentions unsafe code must not trip the rule.
pub fn safe_mention() {}
