// R4 must fire when this file sits in a golden-trace directory: hash-map
// iteration order and wall-clock reads are nondeterminism sources.
use std::collections::HashMap;
use std::time::Instant;

pub fn degree_histogram(degrees: &[u32]) -> HashMap<u32, usize> {
    let mut h = HashMap::new();
    for &d in degrees {
        *h.entry(d).or_insert(0) += 1;
    }
    h
}

pub fn stamp() -> Instant {
    Instant::now()
}
