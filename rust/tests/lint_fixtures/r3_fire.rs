// R3 must fire on every bare poison-unwrap, including the split
// builder-style call and the condvar wait.
use std::sync::{Condvar, Mutex};

pub fn bare(m: &Mutex<Vec<u32>>) -> usize {
    m.lock().unwrap().len()
}

pub fn expecting(m: &Mutex<Vec<u32>>) -> usize {
    m.lock().expect("poisoned").len()
}

pub fn split(m: &Mutex<Vec<u32>>) -> usize {
    m.lock()
        .unwrap()
        .len()
}

pub fn consume(m: Mutex<Vec<u32>>) -> Vec<u32> {
    m.into_inner().unwrap()
}

pub fn waiting(m: &Mutex<bool>, c: &Condvar) {
    let mut g = m.lock().unwrap_or_else(|e| e.into_inner());
    while !*g {
        g = c.wait(g).unwrap();
    }
}
