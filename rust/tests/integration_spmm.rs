//! Integration: the kernel zoo agrees on realistic circuit graphs and the
//! D-ReLU/CBSR contract holds end to end.

use dr_circuitgnn::datagen::{generate_design, table1_designs};
use dr_circuitgnn::graph::EdgeType;
use dr_circuitgnn::sparse::{
    dr_spmm, dr_spmm_bwd, drelu, spmm_csr, spmm_csr_bwd, spmm_gnna, spmm_gnna_bwd, DegreeBuckets,
    GnnaConfig,
};
use dr_circuitgnn::tensor::Matrix;
use dr_circuitgnn::util::math::assert_allclose;
use dr_circuitgnn::util::rng::Rng;

fn test_graph() -> dr_circuitgnn::graph::HeteroGraph {
    generate_design(&table1_designs(0.03).remove(1)).remove(0)
}

#[test]
fn all_kernels_agree_on_circuit_adjacencies() {
    let g = test_graph();
    let mut rng = Rng::new(1);
    let cfg = GnnaConfig::default();
    for edge in [EdgeType::Near, EdgeType::Pins, EdgeType::Pinned] {
        let adj = g.adj(edge);
        let x = Matrix::randn(adj.cols, 32, 1.0, &mut rng);
        let dense = spmm_csr(adj, &x);
        let gnna = spmm_gnna(adj, &x, &cfg);
        assert_allclose(&gnna.data, &dense.data, 1e-3, 1e-3);
        // DR with k = D reproduces the dense result exactly.
        let full = drelu(&x, 32);
        let buckets = DegreeBuckets::build(adj);
        let dr = dr_spmm(adj, &full, &buckets);
        assert_allclose(&dr.data, &dense.data, 1e-3, 1e-3);
        // DR with k < D equals dense SpMM over the masked embedding.
        let part = drelu(&x, 8);
        let dr8 = dr_spmm(adj, &part, &buckets);
        let masked = spmm_csr(adj, &part.to_dense());
        assert_allclose(&dr8.data, &masked.data, 1e-3, 1e-3);
    }
}

#[test]
fn backward_kernels_agree_on_circuit_adjacencies() {
    let g = test_graph();
    let mut rng = Rng::new(2);
    let cfg = GnnaConfig::default();
    for edge in [EdgeType::Near, EdgeType::Pins, EdgeType::Pinned] {
        let adj = g.adj(edge);
        let csc = adj.to_csc();
        let dy = Matrix::randn(adj.rows, 16, 1.0, &mut rng);
        let dense = spmm_csr_bwd(&csc, &dy);
        let gnna = spmm_gnna_bwd(&csc, &dy, &cfg);
        assert_allclose(&gnna.data, &dense.data, 1e-3, 1e-3);
        let x = Matrix::randn(adj.cols, 16, 1.0, &mut rng);
        let fwd = drelu(&x, 16);
        let dr = dr_spmm_bwd(&csc, &dy, &fwd).to_dense();
        assert_allclose(&dr.data, &dense.data, 1e-3, 1e-3);
    }
}

#[test]
fn cbsr_compression_ratio_and_flop_saving() {
    let g = test_graph();
    let mut rng = Rng::new(3);
    let x = Matrix::randn(g.n_cells, 64, 1.0, &mut rng);
    for k in [2usize, 8, 32] {
        let c = drelu(&x, k);
        c.validate().unwrap();
        assert_eq!(c.stored(), g.n_cells * k);
        assert!((c.density() - k as f64 / 64.0).abs() < 1e-12);
    }
}

#[test]
fn degree_buckets_cover_and_respect_thresholds() {
    let g = test_graph();
    for edge in [EdgeType::Near, EdgeType::Pins, EdgeType::Pinned] {
        let adj = g.adj(edge);
        let b = DegreeBuckets::build(adj);
        let (l, m, h) = b.counts();
        assert_eq!(l + m + h, adj.rows);
        for &r in &b.order[..l] {
            assert!(adj.degree(r as usize) < b.t_low);
        }
        for &r in &b.order[l + m..] {
            assert!(adj.degree(r as usize) >= b.t_high);
        }
    }
}

#[test]
fn drelu_then_backward_masks_round_trip() {
    let g = test_graph();
    let mut rng = Rng::new(4);
    let x = Matrix::randn(g.n_nets, 24, 1.0, &mut rng);
    let fwd = drelu(&x, 6);
    let dy = Matrix::ones(g.n_nets, 24);
    let dx = dr_circuitgnn::sparse::drelu_backward(&dy, &fwd);
    for r in 0..g.n_nets {
        assert_eq!(dx.row(r).iter().filter(|&&v| v != 0.0).count(), 6);
    }
}
