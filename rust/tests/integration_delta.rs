//! Integration: incremental ECO delta updates (ISSUE 8).
//!
//! Three contracts are gated here:
//!
//! 1. **Patch ≡ rebuild** — `graph::delta::apply` produces a graph
//!    bit-identical (CSR arrays, hashes, features) to rebuilding from
//!    patched triplets from scratch, for random patches including the
//!    empty patch and pins↔pinned-coupled edits (a property test against
//!    an independent triplet model).
//! 2. **Repair ≡ cold build** — an incrementally repaired `Engine` is
//!    bit-identical to a cold build of the patched graph for every kernel
//!    in the registry, and the global plan counters prove the repair
//!    cold-built nothing.
//! 3. **ECO ≡ re-partition** — routing a parent-level ECO through the
//!    partition maps and restaging only touched subgraphs reproduces a
//!    full re-partition of the patched parent exactly; an identity ECO
//!    changes nothing (all cache hits, bit-identical training — the
//!    golden traces in `tests/golden/` stay valid by construction).

use dr_circuitgnn::datagen::{
    generate_design, generate_eco, generate_graph, table1_designs, EcoSpec, GraphSpec,
};
use dr_circuitgnn::engine::{plan_counters, Engine, EngineBuilder, KernelSpec, REGISTRY};
use dr_circuitgnn::fleet::{apply_eco, Fleet, Lookup, PlanCache};
use dr_circuitgnn::graph::{
    apply_delta, partition_with_map, Csr, DeltaPatch, EdgeOp, EdgeType, HeteroGraph,
};
use dr_circuitgnn::nn::{Adam, DrCircuitGnn};
use dr_circuitgnn::tensor::Matrix;
use dr_circuitgnn::util::proptest::{check, Gen};
use dr_circuitgnn::util::rng::Rng;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Mutex;

/// The plan counters are process-global; tests in this binary run on
/// threads, so every test that builds plans takes this lock to keep the
/// exact-count assertions meaningful.
static COUNTER_GUARD: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    COUNTER_GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

fn ensure(cond: bool, msg: impl Fn() -> String) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg())
    }
}

fn same_csr(got: &Csr, want: &Csr, tag: &str) -> Result<(), String> {
    ensure(got.rows == want.rows && got.cols == want.cols, || format!("{tag}: shape"))?;
    ensure(got.indptr == want.indptr, || format!("{tag}: indptr"))?;
    ensure(got.indices == want.indices, || format!("{tag}: indices"))?;
    let same_bits = got.values.len() == want.values.len()
        && got.values.iter().zip(&want.values).all(|(a, b)| a.to_bits() == b.to_bits());
    ensure(same_bits, || format!("{tag}: value bits"))
}

fn same_f32_bits(got: &[f32], want: &[f32], tag: &str) -> Result<(), String> {
    let same = got.len() == want.len()
        && got.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits());
    ensure(same, || format!("{tag}: bits differ"))
}

// ---------------------------------------------------------------------------
// 1. Patch ≡ rebuild, against an independent triplet model.
// ---------------------------------------------------------------------------

/// The from-scratch reference: edge maps + feature matrices, rebuilt into
/// a `HeteroGraph` through `Csr::from_triplets` — the same constructor the
/// datagen pipeline uses, and deliberately *not* the delta code path.
#[derive(Clone)]
struct TripletModel {
    n_cells: usize,
    n_nets: usize,
    near: BTreeMap<(usize, usize), f32>,
    pins: BTreeMap<(usize, usize), f32>,
    x_cell: Matrix,
    x_net: Matrix,
    y_cell: Matrix,
}

impl TripletModel {
    fn random(g: &mut Gen) -> TripletModel {
        let n_cells = g.sized(2, 40);
        let n_nets = g.sized(1, 20);
        let mut near = BTreeMap::new();
        for _ in 0..g.rng.below(4 * n_cells) {
            let r = g.rng.below(n_cells);
            let c = g.rng.below(n_cells);
            if r != c {
                near.insert((r, c), g.rng.uniform(0.1, 2.0));
            }
        }
        let mut pins = BTreeMap::new();
        for _ in 0..g.rng.below(3 * n_nets + 1) {
            pins.insert((g.rng.below(n_nets), g.rng.below(n_cells)), g.rng.uniform(0.1, 2.0));
        }
        TripletModel {
            n_cells,
            n_nets,
            near,
            pins,
            x_cell: Matrix::from_vec(n_cells, 3, g.normal_vec(n_cells * 3)),
            x_net: Matrix::from_vec(n_nets, 3, g.normal_vec(n_nets * 3)),
            y_cell: Matrix::from_vec(n_cells, 1, g.normal_vec(n_cells)),
        }
    }

    fn graph(&self) -> HeteroGraph {
        let near_t: Vec<(usize, usize, f32)> =
            self.near.iter().map(|(&(r, c), &w)| (r, c, w)).collect();
        let pins_t: Vec<(usize, usize, f32)> =
            self.pins.iter().map(|(&(n, c), &w)| (n, c, w)).collect();
        let pins = Csr::from_triplets(self.n_nets, self.n_cells, &pins_t);
        let pinned = pins.transpose();
        HeteroGraph {
            id: 0,
            n_cells: self.n_cells,
            n_nets: self.n_nets,
            near: Csr::from_triplets(self.n_cells, self.n_cells, &near_t),
            pins,
            pinned,
            x_cell: self.x_cell.clone(),
            x_net: self.x_net.clone(),
            y_cell: self.y_cell.clone(),
        }
    }
}

/// A random valid patch and the model with the same edits applied. Ops
/// target the pins relation through *both* frames (Pins: net→cell and
/// Pinned: cell→net) to exercise the mirroring; one shared used-set keyed
/// in pins coordinates keeps targets distinct across frames, matching the
/// patch's own duplicate rule.
fn random_patch(g: &mut Gen, m: &TripletModel) -> (DeltaPatch, TripletModel) {
    let mut patch = DeltaPatch::new();
    let mut next = m.clone();
    let mut used_near: BTreeSet<(usize, usize)> = BTreeSet::new();
    let mut used_pins: BTreeSet<(usize, usize)> = BTreeSet::new();
    let n_ops = g.rng.below(14); // 0 ⇒ the identity patch is covered too
    for _ in 0..n_ops {
        match g.rng.below(8) {
            0 | 1 => {
                // near add (sometimes a zero-weight no-op add)
                let r = g.rng.below(m.n_cells);
                let c = g.rng.below(m.n_cells);
                if m.near.contains_key(&(r, c)) || used_near.contains(&(r, c)) {
                    continue;
                }
                let w = if g.rng.below(6) == 0 { 0.0 } else { g.rng.uniform(0.1, 2.0) };
                used_near.insert((r, c));
                patch = patch.add_edge(EdgeType::Near, r, c, w);
                if w != 0.0 {
                    next.near.insert((r, c), w);
                }
            }
            2 => {
                // near remove
                let keys: Vec<_> =
                    m.near.keys().filter(|k| !used_near.contains(k)).copied().collect();
                if keys.is_empty() {
                    continue;
                }
                let (r, c) = *g.pick(&keys);
                used_near.insert((r, c));
                patch = patch.remove_edge(EdgeType::Near, r, c);
                next.near.remove(&(r, c));
            }
            3 => {
                // near reweight (sometimes to exact zero = removal)
                let keys: Vec<_> =
                    m.near.keys().filter(|k| !used_near.contains(k)).copied().collect();
                if keys.is_empty() {
                    continue;
                }
                let (r, c) = *g.pick(&keys);
                let w = if g.rng.below(4) == 0 { 0.0 } else { g.rng.uniform(0.1, 2.0) };
                used_near.insert((r, c));
                patch = patch.reweight_edge(EdgeType::Near, r, c, w);
                if w == 0.0 {
                    next.near.remove(&(r, c));
                } else {
                    next.near.insert((r, c), w);
                }
            }
            4 | 5 => {
                // pins add/remove in the Pins frame (net, cell)
                let net = g.rng.below(m.n_nets);
                let cell = g.rng.below(m.n_cells);
                if used_pins.contains(&(net, cell)) {
                    continue;
                }
                used_pins.insert((net, cell));
                if m.pins.contains_key(&(net, cell)) {
                    patch = patch.remove_edge(EdgeType::Pins, net, cell);
                    next.pins.remove(&(net, cell));
                } else {
                    let w = g.rng.uniform(0.1, 2.0);
                    patch = patch.add_edge(EdgeType::Pins, net, cell, w);
                    next.pins.insert((net, cell), w);
                }
            }
            6 | 7 => {
                // the same relation edited through the Pinned frame
                // (cell, net) — must mirror into both matrices
                let net = g.rng.below(m.n_nets);
                let cell = g.rng.below(m.n_cells);
                if used_pins.contains(&(net, cell)) {
                    continue;
                }
                used_pins.insert((net, cell));
                if m.pins.contains_key(&(net, cell)) {
                    let w = g.rng.uniform(0.1, 2.0);
                    patch = patch
                        .edge(EdgeType::Pinned, EdgeOp::Reweight { row: cell, col: net, w });
                    next.pins.insert((net, cell), w);
                } else {
                    let w = g.rng.uniform(0.1, 2.0);
                    patch = patch.edge(EdgeType::Pinned, EdgeOp::Add { row: cell, col: net, w });
                    next.pins.insert((net, cell), w);
                }
            }
            _ => unreachable!(),
        }
    }
    if g.bool() {
        let cell = g.rng.below(m.n_cells);
        let row = g.normal_vec(3);
        patch = patch.set_x_cell(cell, row.clone());
        next.x_cell.row_mut(cell).copy_from_slice(&row);
    }
    if g.bool() {
        let net = g.rng.below(m.n_nets);
        let row = g.normal_vec(3);
        patch = patch.set_x_net(net, row.clone());
        next.x_net.row_mut(net).copy_from_slice(&row);
    }
    if g.bool() {
        let cell = g.rng.below(m.n_cells);
        let y = g.rng.uniform(-1.0, 1.0);
        patch = patch.set_y_cell(cell, y);
        next.y_cell.row_mut(cell)[0] = y;
    }
    (patch, next)
}

#[test]
fn prop_apply_equals_from_scratch_rebuild() {
    check("delta::apply≡rebuild", 80, 0xDE17A, |g| {
        let m = TripletModel::random(g);
        let (patch, want_model) = random_patch(g, &m);
        let got = apply_delta(&m.graph(), &patch)
            .map_err(|e| format!("apply failed: {e}\npatch: {}", patch.describe()))?;
        got.validate().map_err(|e| format!("patched graph invalid: {e}"))?;
        let want = want_model.graph();
        same_csr(&got.near, &want.near, "near")?;
        same_csr(&got.pins, &want.pins, "pins")?;
        same_csr(&got.pinned, &want.pinned, "pinned")?;
        ensure(got.adjacency_hash() == want.adjacency_hash(), || "adjacency_hash".into())?;
        same_f32_bits(&got.x_cell.data, &want.x_cell.data, "x_cell")?;
        same_f32_bits(&got.x_net.data, &want.x_net.data, "x_net")?;
        same_f32_bits(&got.y_cell.data, &want.y_cell.data, "y_cell")
    });
}

// ---------------------------------------------------------------------------
// 2. Repair ≡ cold build, for every registry kernel, counters proving it.
// ---------------------------------------------------------------------------

fn repair_fixture() -> (HeteroGraph, DeltaPatch, HeteroGraph) {
    let parent = generate_graph(
        &GraphSpec {
            n_cells: 150,
            n_nets: 70,
            target_near: 900,
            target_pins: 220,
            d_cell: 5,
            d_net: 5,
        },
        0,
        &mut Rng::new(11),
    );
    let patch = generate_eco(&parent, &EcoSpec::new(0.04, 7));
    let patched = apply_delta(&parent, &patch).expect("generated ECOs apply");
    (parent, patch, patched)
}

fn assert_engines_bit_identical(a: &Engine, b: &Engine, g: &HeteroGraph, tag: &str) {
    for e in EdgeType::ALL {
        let (pa, pb) = (a.plan(e), b.plan(e));
        assert_eq!(pa.adj.indptr, pb.adj.indptr, "{tag} {} adj indptr", e.name());
        assert_eq!(pa.adj.indices, pb.adj.indices, "{tag} {} adj indices", e.name());
        same_f32_bits(&pa.adj.values, &pb.adj.values, "adj values")
            .unwrap_or_else(|m| panic!("{tag} {}: {m}", e.name()));
        assert_eq!(pa.csc.indptr, pb.csc.indptr, "{tag} {} csc indptr", e.name());
        assert_eq!(pa.csc.indices, pb.csc.indices, "{tag} {} csc indices", e.name());
        same_f32_bits(&pa.csc.values, &pb.csc.values, "csc values")
            .unwrap_or_else(|m| panic!("{tag} {}: {m}", e.name()));
    }
    // End to end: a full model forward is bitwise identical.
    let mut rng = Rng::new(3);
    let model = DrCircuitGnn::new(g.x_cell.cols, g.x_net.cols, 8, &mut rng);
    let pred_a = model.clone().forward(a, g);
    let pred_b = model.clone().forward(b, g);
    same_f32_bits(&pred_a.data, &pred_b.data, "forward")
        .unwrap_or_else(|m| panic!("{tag}: {m}"));
}

#[test]
fn repaired_plans_match_cold_builds_for_every_registry_kernel() {
    let _g = lock();
    let (parent, patch, patched) = repair_fixture();
    for entry in REGISTRY {
        let builder = Engine::builder().kernel(entry.name).k_cell(4).k_net(4);
        let old = builder.build(&parent);
        let before = plan_counters();
        let (repaired, stats) = builder.repair(&old, &patched, &patch);
        let during = plan_counters().since(&before);
        // The only-touched-structures proof: repair never cold-builds a
        // plan (`plans == 0` while `repairs > 0`). The auto policy may
        // legitimately flip a kernel choice on the patched adjacency,
        // which routes through the rebuild tier — cold plans there must
        // match the rebuilt count exactly and nothing else.
        if entry.spec == KernelSpec::Auto {
            assert_eq!(during.plans, stats.plans_rebuilt, "{}: {}", entry.name, stats.describe());
        } else {
            assert_eq!(during.plans, 0, "{}: repair cold-built a plan", entry.name);
            assert_eq!(stats.plans_rebuilt, 0, "{}", entry.name);
        }
        assert_eq!(during.repairs, stats.plans_repaired, "{}", entry.name);
        assert_eq!(
            stats.plans_reused + stats.plans_repaired + stats.plans_rebuilt,
            3,
            "{}: every edge type classified once: {}",
            entry.name,
            stats.describe()
        );
        let cold = builder.build(&patched);
        assert_engines_bit_identical(&repaired, &cold, &patched, entry.name);
    }
}

// ---------------------------------------------------------------------------
// 3. ECO routing ≡ full re-partition; identity ECO changes nothing.
// ---------------------------------------------------------------------------

#[test]
fn prop_routed_eco_equals_full_repartition() {
    let _g = lock();
    check("apply_eco≡repartition", 10, 0xEC0, |g| {
        let n_cells = g.sized(40, 160);
        let parent = generate_graph(
            &GraphSpec {
                n_cells,
                n_nets: n_cells / 2,
                target_near: n_cells * 5,
                target_pins: n_cells + n_cells / 3,
                d_cell: 4,
                d_net: 4,
            },
            0,
            &mut Rng::new(g.rng.next_u64()),
        );
        let parts = *g.pick(&[2usize, 3, 5]);
        let subs = partition_with_map(&parent, parts);
        let churn = *g.pick(&[0.01f64, 0.05]);
        let patch = generate_eco(&parent, &EcoSpec::new(churn, g.rng.next_u64()));

        let cache = PlanCache::new(EngineBuilder::csr());
        for (sub, _) in &subs {
            cache.engine_for(sub); // warm: the patched path must repair
        }
        let outcome = apply_eco(&parent, &subs, &patch, &cache)
            .map_err(|e| format!("apply_eco failed: {e}\npatch: {}", patch.describe()))?;

        let fresh = partition_with_map(&apply_delta(&parent, &patch).unwrap(), parts);
        ensure(outcome.subgraphs.len() == fresh.len(), || "partition count".into())?;
        for (i, (got, (want, want_map))) in
            outcome.subgraphs.iter().zip(&fresh).enumerate()
        {
            let tag = |what: &str| {
                format!(
                    "partition {i} ({:?}) {what} diverged from full repartition\npatch: {}",
                    got.lookup,
                    patch.describe()
                )
            };
            ensure(got.graph.adjacency_hash() == want.adjacency_hash(), || tag("adjacency"))?;
            same_f32_bits(&got.graph.x_cell.data, &want.x_cell.data, "x_cell")
                .map_err(|_| tag("x_cell"))?;
            same_f32_bits(&got.graph.x_net.data, &want.x_net.data, "x_net")
                .map_err(|_| tag("x_net"))?;
            same_f32_bits(&got.graph.y_cell.data, &want.y_cell.data, "y_cell")
                .map_err(|_| tag("y_cell"))?;
            ensure(got.map.cell_ids == want_map.cell_ids, || tag("cell map"))?;
            ensure(got.map.net_ids == want_map.net_ids, || tag("net map"))?;
        }
        // Cost discipline: every partition was served from the cache —
        // hits for untouched, repairs (or re-materialisation) otherwise;
        // a delta never re-plans everything.
        ensure(
            outcome.report.untouched + outcome.report.patched + outcome.report.restaged
                == subs.len(),
            || "partition accounting".into(),
        )
    });
}

/// The identity ECO is free and exact: all cache hits, nothing evicted,
/// and training on the "updated" fleet is bit-identical to the original —
/// which is why the committed golden traces in `tests/golden/` need no
/// regeneration for this PR.
#[test]
fn identity_eco_is_free_and_preserves_training_bits() {
    let _g = lock();
    let graphs = generate_design(&table1_designs(0.02)[0]);
    let parent = graphs.into_iter().max_by_key(|g| g.n_cells).expect("design graphs");
    let subs = partition_with_map(&parent, 3);
    let cache = PlanCache::new(EngineBuilder::dr(4, 4));
    for (sub, _) in &subs {
        cache.engine_for(sub);
    }

    let outcome = apply_eco(&parent, &subs, &DeltaPatch::new(), &cache).expect("identity");
    let r = outcome.report;
    assert_eq!(
        (r.untouched, r.patched, r.restaged, r.evicted),
        (subs.len(), 0, 0, 0),
        "{}",
        r.describe()
    );
    assert!(outcome.subgraphs.iter().all(|s| s.lookup == Lookup::Hit));
    assert_eq!(outcome.parent.adjacency_hash(), parent.adjacency_hash());

    let train = |graphs: &[HeteroGraph]| -> Vec<f64> {
        let fleet = Fleet::builder(EngineBuilder::dr(4, 4)).workers(2).build(graphs);
        let mut rng = Rng::new(42);
        let mut model =
            DrCircuitGnn::new(parent.x_cell.cols, parent.x_net.cols, 16, &mut rng);
        let mut opt = Adam::new(2e-4, 1e-5);
        (0..3).map(|_| fleet.step(&mut model, &mut opt).loss).collect()
    };
    let original: Vec<HeteroGraph> = subs.iter().map(|(g, _)| g.clone()).collect();
    let updated: Vec<HeteroGraph> =
        outcome.subgraphs.iter().map(|s| s.graph.clone()).collect();
    assert_eq!(train(&original), train(&updated), "identity ECO changed training");
}

/// The canonical-form bugfix (exact-zero merged entries dropped in
/// `Csr::sort_and_dedup`) is a no-op for every seed design — the datagen
/// pipeline never emits zero weights — so all committed golden traces
/// remain valid without regeneration. This pins that reasoning.
#[test]
fn seed_designs_are_already_canonical() {
    for spec in table1_designs(0.02) {
        for g in generate_design(&spec) {
            for (name, adj) in
                [("near", &g.near), ("pins", &g.pins), ("pinned", &g.pinned)]
            {
                assert!(
                    adj.is_canonical(),
                    "{} graph {} {name}: seed adjacency not canonical",
                    spec.name,
                    g.id
                );
                assert!(
                    adj.values.iter().all(|w| *w != 0.0),
                    "{} graph {} {name}: zero stored weight",
                    spec.name,
                    g.id
                );
            }
        }
    }
}
