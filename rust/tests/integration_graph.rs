//! Integration: datagen → graph substrate invariants at realistic scale.

use dr_circuitgnn::datagen::{generate_design, mini_circuitnet, table1_designs};
use dr_circuitgnn::graph::partition::partition;
use dr_circuitgnn::graph::stats::ImbalanceStats;
use dr_circuitgnn::graph::EdgeType;

#[test]
fn table1_designs_generate_and_validate_at_small_scale() {
    for spec in table1_designs(0.05) {
        let graphs = generate_design(&spec);
        assert_eq!(graphs.len(), spec.graphs.len());
        for (g, gs) in graphs.iter().zip(&spec.graphs) {
            g.validate().unwrap();
            assert_eq!(g.n_cells, gs.n_cells);
            assert_eq!(g.n_nets, gs.n_nets);
            // Edge counts within 5% of the scaled targets.
            let near_err =
                (g.near.nnz() as f64 - gs.target_near as f64).abs() / gs.target_near as f64;
            assert!(near_err < 0.05, "{}: near {} vs {}", spec.name, g.near.nnz(), gs.target_near);
            assert_eq!(g.pins.nnz(), gs.target_pins);
        }
    }
}

#[test]
fn fig4_degree_shape_holds_per_design() {
    for spec in table1_designs(0.05) {
        let g = &generate_design(&spec)[0];
        let near = ImbalanceStats::of(g.adj(EdgeType::Near));
        let pins = ImbalanceStats::of(g.adj(EdgeType::Pins));
        let pinned = ImbalanceStats::of(g.adj(EdgeType::Pinned));
        assert!(near.avg_degree > 5.0 * pins.avg_degree);
        assert!(near.avg_degree > 5.0 * pinned.avg_degree);
        // Power-law evil rows on pins (nets with huge fanout).
        assert!(pins.imbalance > 2.0, "{}: pins imbalance {}", spec.name, pins.imbalance);
    }
}

#[test]
fn mini_circuitnet_generates_split_and_labels() {
    let (train, test) = mini_circuitnet(18, 0.03, 7);
    assert_eq!(train.designs.len(), 15);
    assert_eq!(test.designs.len(), 3);
    for g in train.graphs().chain(test.graphs()) {
        g.validate().unwrap();
        // Labels vary (learnable target).
        let mean = g.y_cell.mean();
        let var: f32 = g
            .y_cell
            .data
            .iter()
            .map(|v| (v - mean) * (v - mean))
            .sum::<f32>()
            / g.y_cell.data.len() as f32;
        assert!(var > 1e-6, "labels must vary");
    }
}

#[test]
fn partitioner_conserves_nodes_and_validates() {
    let spec = table1_designs(0.05).remove(0);
    let g = generate_design(&spec).remove(0);
    let parts = partition(&g, 3);
    let cells: usize = parts.iter().map(|p| p.n_cells).sum();
    assert_eq!(cells, g.n_cells);
    for p in &parts {
        p.validate().unwrap();
        // Partition keeps CircuitNet-ish density.
        assert!(p.near.avg_degree() <= g.near.avg_degree() + 1.0);
    }
}

#[test]
fn pins_pinned_transposition_invariant_everywhere() {
    let (train, _) = mini_circuitnet(6, 0.03, 9);
    for g in train.graphs() {
        assert!(g.pinned.is_transpose_of(&g.pins));
        assert!(g.pins.is_transpose_of(&g.pinned));
        assert!(g.near.is_transpose_of(&g.near), "near symmetric");
    }
}
