//! Integration: the persistent plan store (ISSUE 6) — warm starts must be
//! bit-identical to cold builds, corruption must be loud-then-cold, and
//! training through a disk-backed [`PlanCache`] must not move a single
//! bit whether the store is present, absent, or corrupted.

use dr_circuitgnn::datagen::{generate_graph, Dataset, GraphSpec};
use dr_circuitgnn::engine::{plan_counters, Engine, EngineBuilder, PlanStore};
use dr_circuitgnn::fleet::{FleetSpec, PlanCache};
use dr_circuitgnn::graph::{EdgeType, HeteroGraph};
use dr_circuitgnn::sparse::GnnaConfig;
use dr_circuitgnn::tensor::Matrix;
use dr_circuitgnn::train::{TrainConfig, Trainer};
use dr_circuitgnn::util::proptest::check;
use dr_circuitgnn::util::rng::Rng;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// The plan counters are process-global; tests in this binary run on
/// threads, so tests asserting exact counter deltas serialize through
/// this lock.
static COUNTER_GUARD: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    COUNTER_GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("drcg-it-planstore-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn graph(seed: u64, n_cells: usize, n_nets: usize) -> HeteroGraph {
    let mut rng = Rng::new(seed);
    let spec = GraphSpec {
        n_cells,
        n_nets,
        target_near: n_cells * 6,
        target_pins: n_nets * 4,
        d_cell: 6,
        d_net: 6,
    };
    generate_graph(&spec, 0, &mut rng)
}

/// Forward every edge type through both engines with the same inputs and
/// assert bit-identical aggregates — the plan/execute contract a
/// round-tripped plan must honour.
fn assert_execute_identical(a: &Engine, b: &Engine, g: &HeteroGraph, seed: u64) {
    let mut rng = Rng::new(seed);
    for e in EdgeType::ALL {
        assert_eq!(a.kernel_name(e), b.kernel_name(e), "kernel drift on {}", e.name());
        let x = Matrix::randn(g.adj(e).cols, 8, 1.0, &mut rng);
        let src = e.endpoints().0;
        let prep_a = a.sparsify(&x, src);
        let prep_b = b.sparsify(&x, src);
        let (ha, _) = a.aggregate_with(e, &x, prep_a.as_ref());
        let (hb, _) = b.aggregate_with(e, &x, prep_b.as_ref());
        let bits_a: Vec<u32> = ha.data.iter().map(|v| v.to_bits()).collect();
        let bits_b: Vec<u32> = hb.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits_a, bits_b, "aggregate diverged on {}", e.name());
    }
}

/// Round-trip property across every kernel family and random topologies:
/// store a built engine, load it back, and the loaded engine must execute
/// bit-identically — with zero Alg. 1 stage 1 plan builds on the load.
#[test]
fn proptest_roundtrip_executes_bit_identically() {
    let _g = lock();
    let dir = tmp_dir("proptest");
    check("planstore-roundtrip", 12, 0xD5C6, |gen| {
        let n_cells = gen.sized(20, 80);
        let n_nets = gen.sized(8, 30);
        let g = graph(gen.rng.next_u64(), n_cells, n_nets);
        let builder = match gen.usize_in(0, 3) {
            0 => EngineBuilder::csr(),
            1 => EngineBuilder::gnna(GnnaConfig::default()),
            2 => EngineBuilder::dr(4, 4),
            _ => EngineBuilder::auto(),
        }
        .parallel(gen.bool());
        let store = PlanStore::open(&dir, &builder).map_err(|e| e.to_string())?;
        let built = builder.build(&g);
        store.store(&g, &built).map_err(|e| e.to_string())?;

        let before = plan_counters();
        let loaded = store
            .load(&g, &builder)
            .map_err(|e| e.to_string())?
            .ok_or("stored plan not found on load")?;
        let during = plan_counters().since(&before);
        if during.plans != 0 || during.cscs != 0 || during.buckets != 0 || during.groups != 0 {
            return Err(format!("warm load built plans: {during:?}"));
        }
        assert_execute_identical(&built, &loaded, &g, gen.rng.next_u64());
        Ok(())
    });
    std::fs::remove_dir_all(&dir).ok();
}

/// A plan file renamed onto another adjacency's key must be rejected
/// loudly — content addressing is verified on load, never trusted from
/// the filename.
#[test]
fn hash_mismatch_is_rejected_loudly() {
    let _g = lock();
    let dir = tmp_dir("hash-mismatch");
    let builder = EngineBuilder::dr(4, 4);
    let store = PlanStore::open(&dir, &builder).unwrap();
    let g1 = graph(1, 40, 16);
    let g2 = graph(2, 40, 16);
    store.store(&g1, &builder.build(&g1)).unwrap();
    // Masquerade g1's plan as g2's.
    std::fs::copy(
        store.plan_path(g1.adjacency_hash()),
        store.plan_path(g2.adjacency_hash()),
    )
    .unwrap();
    let err = store.load(&g2, &builder).unwrap_err();
    assert!(err.contains("adjacency hash"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

fn corrupt_one_plan_file(dir: &Path) -> PathBuf {
    let path = std::fs::read_dir(dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| p.extension().is_some_and(|x| x == "plan"))
        .expect("a .plan file to corrupt");
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();
    path
}

/// Corruption through the cache: the backed cache must detect the bad
/// file (checksum), rebuild cold, and re-persist — the store heals, and
/// the rebuilt engine matches a never-corrupted build bit for bit.
#[test]
fn corrupted_store_rebuilds_cold_and_heals() {
    let _g = lock();
    let dir = tmp_dir("corrupt-heal");
    let builder = EngineBuilder::dr(4, 4);
    let g = graph(7, 40, 16);

    let cold = PlanCache::backed_by(builder.clone(), &dir).unwrap();
    let reference = cold.engine_for(&g);
    assert_eq!(cold.stats().disk_stores, 1);

    corrupt_one_plan_file(&dir);

    let healed = PlanCache::backed_by(builder.clone(), &dir).unwrap();
    let rebuilt = healed.engine_for(&g);
    let s = healed.stats();
    assert_eq!(s.disk_loads, 0, "corrupted file must not load");
    assert_eq!(s.misses, 1, "must rebuild cold");
    assert_eq!(s.disk_stores, 1, "must re-persist the healed plan");
    assert_execute_identical(&reference, &rebuilt, &g, 99);

    // And the store is healed: a third cache loads warm.
    let warm = PlanCache::backed_by(builder, &dir).unwrap();
    let loaded = warm.engine_for(&g);
    assert_eq!(warm.stats().disk_loads, 1);
    assert_eq!(warm.stats().misses, 0);
    assert_execute_identical(&reference, &loaded, &g, 100);
    std::fs::remove_dir_all(&dir).ok();
}

/// Truncated files are rejected the same way — loudly, then cold.
#[test]
fn truncated_store_rebuilds_cold() {
    let _g = lock();
    let dir = tmp_dir("truncate");
    let builder = EngineBuilder::gnna(GnnaConfig::default());
    let g = graph(3, 40, 16);
    let store = PlanStore::open(&dir, &builder).unwrap();
    store.store(&g, &builder.build(&g)).unwrap();
    let path = store.plan_path(g.adjacency_hash());
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 3]).unwrap();
    assert!(store.load(&g, &builder).is_err(), "truncated plan must error");

    let cache = PlanCache::backed_by(builder, &dir).unwrap();
    let _ = cache.engine_for(&g);
    assert_eq!(cache.stats().misses, 1);
    assert_eq!(cache.stats().disk_stores, 1);
    std::fs::remove_dir_all(&dir).ok();
}

fn four_graph_dataset() -> Dataset {
    Dataset {
        name: "planstore-it".into(),
        designs: vec![
            ("d0".into(), vec![graph(10, 36, 14), graph(11, 44, 18)]),
            ("d1".into(), vec![graph(12, 40, 16), graph(13, 48, 20)]),
        ],
    }
}

fn train_once(cache: &Arc<PlanCache>, data: &Dataset) -> Vec<f64> {
    let cfg = TrainConfig {
        epochs: 2,
        lr: 2e-4,
        weight_decay: 1e-5,
        hidden: 16,
        seed: 42,
        parallel: false,
        epoch_pipeline: false,
        log_every: 0,
        ..TrainConfig::dr_default()
    };
    let spec = FleetSpec::parse("2").unwrap();
    let (_m, report) =
        Trainer::train_dr_fleet_cached(data, data, cache.builder(), &cfg, &spec, cache);
    report.epoch_losses
}

/// The acceptance gate: training traces are bit-identical with the store
/// off, cold, warm, and corrupted — and the warm run performs zero
/// Alg. 1 stage 1 plan builds, by both the cache's stats and the
/// engine's global counters.
#[test]
fn training_is_bit_identical_across_store_states() {
    let _g = lock();
    let dir = tmp_dir("train-states");
    let data = four_graph_dataset();
    let builder = EngineBuilder::dr(4, 4);

    // Store off: plain in-memory cache.
    let off = Arc::new(PlanCache::new(builder.clone()));
    let losses_off = train_once(&off, &data);
    assert_eq!(off.stats().disk_loads + off.stats().disk_stores, 0);

    // Cold: backed cache over an empty directory builds and persists.
    let cold = Arc::new(PlanCache::backed_by(builder.clone(), &dir).unwrap());
    let losses_cold = train_once(&cold, &data);
    assert_eq!(cold.stats().misses, 4, "four unique adjacencies built cold");
    assert_eq!(cold.stats().disk_stores, 4);
    assert_eq!(cold.stats().disk_loads, 0);

    // Warm: a fresh process-equivalent (new cache, same dir) loads all
    // four plans and builds none — zero stage-1 plan work end to end.
    let warm = Arc::new(PlanCache::backed_by(builder.clone(), &dir).unwrap());
    let before = plan_counters();
    let losses_warm = train_once(&warm, &data);
    let during = plan_counters().since(&before);
    assert_eq!(warm.stats().disk_loads, 4, "every plan loaded warm");
    assert_eq!(warm.stats().misses, 0, "zero plans built cold on the warm run");
    assert_eq!(during.plans, 0, "global counters agree: zero plan builds");
    assert_eq!(during.cscs + during.buckets + during.groups, 0);

    // Corrupted: flip a byte in one plan; the run must warn, rebuild that
    // plan cold, and still produce the identical trace.
    corrupt_one_plan_file(&dir);
    let hurt = Arc::new(PlanCache::backed_by(builder, &dir).unwrap());
    let losses_hurt = train_once(&hurt, &data);
    assert_eq!(hurt.stats().misses, 1, "exactly the corrupted plan rebuilds");
    assert_eq!(hurt.stats().disk_loads, 3);

    let bits = |xs: &[f64]| xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&losses_off), bits(&losses_cold), "store-on changed numerics");
    assert_eq!(bits(&losses_off), bits(&losses_warm), "warm start changed numerics");
    assert_eq!(bits(&losses_off), bits(&losses_hurt), "corruption recovery changed numerics");
    std::fs::remove_dir_all(&dir).ok();
}

/// K profiles persisted by profile-k must round-trip bit-exactly and only
/// influence `auto`-kernel builds (explicit kernel choices keep their
/// explicitly-configured K values).
#[test]
fn persisted_k_profiles_feed_only_auto_builds() {
    let _g = lock();
    let dir = tmp_dir("kprof");
    let g = graph(21, 40, 16);
    let auto = EngineBuilder::auto().k_cell(8).k_net(8);
    let store = PlanStore::open(&dir, &auto).unwrap();
    let rec = dr_circuitgnn::engine::KProfileRecord {
        dim: 16,
        edges: [
            (4, vec![(2, 3e-3), (4, 1e-3), (8, 2e-3)]),
            (4, vec![(2, 2e-3), (4, 1e-3), (8, 4e-3)]),
            (2, vec![(2, 1e-3), (4, 5e-3), (8, 6e-3)]),
        ],
    };
    store.store_profile(g.adjacency_hash(), &rec).unwrap();
    let back = store.load_profile(g.adjacency_hash()).unwrap().unwrap();
    assert_eq!(back.dim, rec.dim);
    assert_eq!(back.type_ks(), rec.type_ks());

    // Auto builds pick the measured Ks up through the store…
    let eff = store.effective_builder(&auto, &g);
    let (kc, kn) = rec.type_ks();
    assert_eq!(eff.k_for(dr_circuitgnn::graph::NodeType::Cell), kc);
    assert_eq!(eff.k_for(dr_circuitgnn::graph::NodeType::Net), kn);
    // …explicit kernel choices don't.
    let explicit = EngineBuilder::dr(8, 8);
    let store2 = PlanStore::open(&dir, &explicit).unwrap();
    store2.store_profile(g.adjacency_hash(), &rec).unwrap();
    let eff2 = store2.effective_builder(&explicit, &g);
    assert_eq!(eff2.k_for(dr_circuitgnn::graph::NodeType::Cell), 8);
    std::fs::remove_dir_all(&dir).ok();
}
