//! Integration: §3.4 scheduler — parallel vs sequential timing structure,
//! timeline capture, and the Fig. 12 measurement rig.

use dr_circuitgnn::datagen::{generate_graph, GraphSpec};
use dr_circuitgnn::engine::EngineBuilder;
use dr_circuitgnn::sched::{run_e2e_step, ScheduleMode};
use dr_circuitgnn::sparse::GnnaConfig;
use dr_circuitgnn::util::rng::Rng;

fn graph(n: usize) -> dr_circuitgnn::graph::HeteroGraph {
    let mut rng = Rng::new(8);
    generate_graph(
        &GraphSpec {
            n_cells: n,
            n_nets: n / 2,
            target_near: n * 30,
            target_pins: (n / 2) * 3,
            d_cell: 8,
            d_net: 8,
        },
        0,
        &mut rng,
    )
}

#[test]
fn e2e_step_runs_for_every_engine_and_mode() {
    let g = graph(400);
    for engine in [
        EngineBuilder::csr(),
        EngineBuilder::gnna(GnnaConfig::default()),
        EngineBuilder::dr(4, 4),
    ] {
        for mode in [ScheduleMode::Sequential, ScheduleMode::Parallel] {
            let t = run_e2e_step(&g, 32, &engine, mode, 1);
            assert!(t.total > 0.0 && t.busy > 0.0);
            assert_eq!(t.timeline.events().len(), 10); // act + 3 lanes × 3 phases
            assert_eq!(t.engine, engine.describe());
        }
    }
}

#[test]
fn parallel_reduces_makespan_on_large_graph() {
    if std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) < 2 {
        eprintln!("skipping: single-core machine, no true parallelism available");
        return;
    }
    let g = graph(3000);
    let engine = EngineBuilder::csr();
    // Median of 3 to de-noise.
    let median = |mode: ScheduleMode| {
        let mut s: Vec<f64> =
            (0..3).map(|r| run_e2e_step(&g, 64, &engine, mode, r as u64).total).collect();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s[1]
    };
    let seq = median(ScheduleMode::Sequential);
    let par = median(ScheduleMode::Parallel);
    // Small tolerance: the test harness may be running other suites.
    assert!(
        par < seq * 1.05,
        "parallel ({par:.4}s) must beat sequential ({seq:.4}s) on a multicore box"
    );
}

#[test]
fn timeline_lanes_overlap_only_in_parallel_mode() {
    // Best of several runs: the test harness itself runs suites in
    // parallel, so a single run can be starved of cores.
    let g = graph(1500);
    let seq = run_e2e_step(&g, 64, &EngineBuilder::csr(), ScheduleMode::Sequential, 2);
    let par_best = (0..4)
        .map(|r| {
            run_e2e_step(&g, 64, &EngineBuilder::csr(), ScheduleMode::Parallel, 2 + r)
                .timeline
                .overlap_factor()
        })
        .fold(0.0, f64::max);
    assert!(seq.timeline.overlap_factor() < 1.2, "{}", seq.timeline.overlap_factor());
    if std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) >= 2 {
        assert!(par_best > 1.05, "parallel overlap best {par_best}");
    }
}

#[test]
fn fig12_savings_decompose() {
    // kernel-only and parallel savings must both be measurable and the
    // combined run faster than the baseline. Medians over several runs to
    // survive a loaded test machine.
    // Compare the *kernel* phases (fwd+bwd across lanes) — the step total
    // also contains engine-identical init copies whose timing noise on a
    // loaded single-core test machine swamps the kernel-level saving
    // (the wall-clock decomposition is the fig12_breakdown bench's job).
    let g = graph(4000);
    let kernel_time = |engine: &EngineBuilder, mode: ScheduleMode| {
        let mut s: Vec<f64> = (0..5)
            .map(|r| {
                let t = run_e2e_step(&g, 64, engine, mode, 3 + r);
                t.lane_phases.iter().map(|(_, f, b)| f + b).sum::<f64>()
            })
            .collect();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s[s.len() / 2]
    };
    let base = kernel_time(&EngineBuilder::csr(), ScheduleMode::Sequential);
    let kernel = kernel_time(&EngineBuilder::dr(8, 8), ScheduleMode::Sequential);
    let both = kernel_time(&EngineBuilder::dr(8, 8), ScheduleMode::Parallel);
    assert!(base > 0.0 && kernel > 0.0 && both > 0.0);
    assert!(
        kernel < base,
        "DR kernels ({kernel:.4}s) must beat baseline kernels ({base:.4}s)"
    );
}

#[test]
fn lane_phases_sum_close_to_busy_time() {
    let g = graph(800);
    let t = run_e2e_step(&g, 32, &EngineBuilder::dr(4, 4), ScheduleMode::Sequential, 4);
    let phases: f64 =
        t.lane_phases.iter().map(|(i, f, b)| i + f + b).sum();
    // Busy time = lane spans + the shared activation span, so it bounds
    // the lane-phase sum from above (modulo timer noise).
    assert!(phases <= t.busy + 1e-3, "phases {phases} vs busy {}", t.busy);
    assert!(t.busy - phases < 0.6 * t.busy.max(1e-6) + 1e-3);
}
