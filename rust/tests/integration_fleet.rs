//! Integration: the fleet subsystem — batched multi-subgraph training.
//!
//! Acceptance (ISSUE 2):
//! * fleet(N workers) ≡ sequential training on the seed designs — loss
//!   curves match within 1e-6 for every worker count, including more
//!   workers than subgraphs;
//! * the shared plan cache plans once per *unique* subgraph adjacency
//!   (content-hash keyed), and a mutated adjacency invalidates the hash.

use dr_circuitgnn::datagen::mini_circuitnet;
use dr_circuitgnn::engine::{plan_counters, EngineBuilder};
use dr_circuitgnn::fleet::{Fleet, FleetSpec};
use dr_circuitgnn::graph::partition::partition;
use dr_circuitgnn::nn::{mse, Adam, DrCircuitGnn};
use dr_circuitgnn::train::{TrainConfig, Trainer};
use dr_circuitgnn::util::rng::Rng;
use std::sync::Mutex;

/// The plan counters are process-global; tests in this binary run on
/// threads, so exact-count assertions take this lock (same convention as
/// `tests/integration_engine.rs`).
static COUNTER_GUARD: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    COUNTER_GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

fn fast_cfg(epochs: usize) -> TrainConfig {
    TrainConfig {
        epochs,
        lr: 5e-3,
        weight_decay: 0.0,
        hidden: 16,
        seed: 1,
        parallel: false,
        epoch_pipeline: false,
        log_every: 0,
        ..TrainConfig::dr_default()
    }
}

/// Acceptance: fleet(N workers) produces the same loss curve as sequential
/// (1-worker) execution on the seed designs, within 1e-6, for worker
/// counts below, at and above the subgraph count.
#[test]
fn fleet_loss_curves_match_sequential_on_seed_designs() {
    let _g = lock();
    let (train, test) = mini_circuitnet(6, 0.02, 11);
    let cfg = fast_cfg(4);
    let (_m, sequential) = Trainer::train_dr_fleet(
        &train,
        &test,
        &EngineBuilder::dr(4, 4),
        &cfg,
        &FleetSpec::parse("1").unwrap(),
    );
    for spec in ["2", "4", "32"] {
        let (_m, fleet) = Trainer::train_dr_fleet(
            &train,
            &test,
            &EngineBuilder::dr(4, 4),
            &cfg,
            &FleetSpec::parse(spec).unwrap(),
        );
        assert_eq!(fleet.epoch_losses.len(), sequential.epoch_losses.len());
        for (epoch, (a, b)) in
            fleet.epoch_losses.iter().zip(&sequential.epoch_losses).enumerate()
        {
            assert!(
                (a - b).abs() < 1e-6,
                "spec {spec}, epoch {epoch}: fleet {a} vs sequential {b}"
            );
        }
    }
}

/// The same guarantee at the gradient level, against a hand-written
/// single-engine sequential reference (no fleet machinery at all).
#[test]
fn fleet_gradients_match_handwritten_sequential_reference() {
    let _g = lock();
    let (train, _test) = mini_circuitnet(3, 0.02, 7);
    let graphs = &train.designs[0].1;
    let mut rng = Rng::new(3);
    let g0 = &graphs[0];
    let model = DrCircuitGnn::new(g0.x_cell.cols, g0.x_net.cols, 16, &mut rng);

    // Reference: sequential loop, one engine at a time, grads summed in
    // subgraph order with cell-share scaling.
    let builder = EngineBuilder::dr(4, 4);
    let total_cells: usize = graphs.iter().map(|g| g.n_cells).sum();
    let mut ref_grads: Vec<dr_circuitgnn::tensor::Matrix> = Vec::new();
    let mut ref_loss = 0f64;
    for g in graphs {
        let engine = builder.build(g);
        let mut replica = model.clone();
        let pred = replica.forward(&engine, g);
        let (loss, dp) = mse(&pred, &g.y_cell);
        let w = g.n_cells as f32 / total_cells as f32;
        replica.backward(&engine, &dp.scale(w));
        ref_loss += w as f64 * loss as f64;
        let grads: Vec<_> = replica.params_mut().iter().map(|p| p.grad.clone()).collect();
        if ref_grads.is_empty() {
            ref_grads = grads;
        } else {
            for (a, b) in ref_grads.iter_mut().zip(&grads) {
                a.add_inplace(b);
            }
        }
    }

    for workers in [1, 3, 8] {
        let fleet = Fleet::builder(builder.clone()).workers(workers).build(graphs);
        let got = fleet.gradients(&model);
        assert!((got.loss - ref_loss).abs() < 1e-6, "workers {workers}");
        assert_eq!(got.grads.len(), ref_grads.len());
        for (pi, (a, b)) in got.grads.iter().zip(&ref_grads).enumerate() {
            for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
                assert!(
                    (x - y).abs() < 1e-6,
                    "workers {workers}, param {pi}, idx {i}: {x} vs {y}"
                );
            }
        }
    }
}

/// Fleet steps advance the model identically for any worker count.
#[test]
fn fleet_steps_update_identically_across_worker_counts() {
    let _g = lock();
    let (train, _test) = mini_circuitnet(3, 0.02, 9);
    let graphs = &train.designs[0].1;
    let mut rng = Rng::new(5);
    let g0 = &graphs[0];
    let model0 = DrCircuitGnn::new(g0.x_cell.cols, g0.x_net.cols, 12, &mut rng);
    let run = |workers: usize| {
        let fleet =
            Fleet::builder(EngineBuilder::dr(3, 3)).workers(workers).parts(2).build(graphs);
        let mut model = model0.clone();
        let mut opt = Adam::new(1e-2, 0.0);
        (0..5).map(|_| fleet.step(&mut model, &mut opt).loss).collect::<Vec<f64>>()
    };
    let base = run(1);
    for workers in [2, 7] {
        let losses = run(workers);
        for (a, b) in losses.iter().zip(&base) {
            assert!((a - b).abs() < 1e-6, "workers {workers}: {a} vs {b}");
        }
    }
}

/// Acceptance: two content-identical subgraphs in a fleet trigger exactly
/// one plan (3 kernel plans, one per edge type); mutating an adjacency
/// invalidates the content hash and re-plans.
#[test]
fn plan_cache_plans_once_per_unique_subgraph() {
    let _g = lock();
    let (train, _test) = mini_circuitnet(2, 0.02, 13);
    let graphs = &train.designs[0].1;
    let g = &graphs[0];

    // A design with a duplicated subgraph: same adjacency, new features.
    let mut twin = g.clone();
    twin.x_cell = twin.x_cell.scale(0.5);
    assert_eq!(twin.adjacency_hash(), g.adjacency_hash());
    let design = vec![g.clone(), twin];

    let c0 = plan_counters();
    let fleet = Fleet::builder(EngineBuilder::dr(4, 4)).workers(2).build(&design);
    let built = plan_counters().since(&c0);
    assert_eq!(fleet.n_subgraphs(), 2);
    assert_eq!(fleet.cache_stats().unique(), 1, "one unique adjacency");
    assert_eq!(fleet.cache_stats().hits, 1);
    assert_eq!(built.plans, 3, "exactly one plan per edge type for the pair");
    assert_eq!(built.cscs, 3);

    // Mutating the adjacency invalidates the hash: the fleet re-plans.
    let mut mutated = g.clone();
    mutated.near.values[0] += 1.0;
    assert_ne!(mutated.adjacency_hash(), g.adjacency_hash());
    let design = vec![g.clone(), mutated];
    let c1 = plan_counters();
    let fleet = Fleet::builder(EngineBuilder::dr(4, 4)).build(&design);
    let built = plan_counters().since(&c1);
    assert_eq!(fleet.cache_stats().unique(), 2, "mutated adjacency must miss");
    assert_eq!(built.plans, 6, "3 plans per unique subgraph");
}

/// Plan construction happens only at fleet build, never during steps.
#[test]
fn fleet_steps_build_no_plans() {
    let _g = lock();
    let (train, _test) = mini_circuitnet(2, 0.02, 17);
    let graphs = &train.designs[0].1;
    let fleet = Fleet::builder(EngineBuilder::dr(4, 4)).workers(2).build(graphs);
    let mut rng = Rng::new(1);
    let g0 = &graphs[0];
    let mut model = DrCircuitGnn::new(g0.x_cell.cols, g0.x_net.cols, 8, &mut rng);
    let mut opt = Adam::new(1e-3, 0.0);
    let c0 = plan_counters();
    for _ in 0..3 {
        fleet.step(&mut model, &mut opt);
    }
    let during = plan_counters().since(&c0);
    assert_eq!(during.plans, 0, "fleet steps must reuse cached plans: {during:?}");
}

/// Edge cases: a single-subgraph fleet and re-partitioning with more parts
/// than cells both work, and partition counts compose with worker counts.
#[test]
fn fleet_edge_cases_single_subgraph_and_overpartition() {
    let _g = lock();
    let (train, _test) = mini_circuitnet(2, 0.02, 19);
    let g = train.designs[0].1[0].clone();
    let mut rng = Rng::new(2);
    let model = DrCircuitGnn::new(g.x_cell.cols, g.x_net.cols, 8, &mut rng);

    // parts = 1: the fleet is the graph itself.
    let single = Fleet::builder(EngineBuilder::dr(3, 3)).parts(1).workers(4).build(
        std::slice::from_ref(&g),
    );
    assert_eq!(single.n_subgraphs(), 1);
    let lone = single.gradients(&model);
    assert!(lone.loss.is_finite());

    // More workers than subgraphs: the pool clamps, the reduction stays
    // in subgraph order, results are identical.
    let parts = partition(&g, 8);
    assert!(!parts.is_empty() && parts.len() <= 8);
    let a = Fleet::builder(EngineBuilder::dr(3, 3)).workers(1).build(&parts);
    let b = Fleet::builder(EngineBuilder::dr(3, 3)).workers(64).build(&parts);
    let ga = a.gradients(&model);
    let gb = b.gradients(&model);
    assert!((ga.loss - gb.loss).abs() < 1e-9);
    for (x, y) in ga.grads.iter().zip(&gb.grads) {
        assert_eq!(x.data, y.data);
    }
}
