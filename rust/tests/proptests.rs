//! Property-based tests over random instances (util::proptest harness).
//!
//! Each property runs across seeded random graphs/matrices with sizes
//! growing over the run, and reports a replayable seed on failure.

use dr_circuitgnn::engine::{
    registry, AggCache, EngineBuilder, Gradient, KernelSpec, REGISTRY,
};
use dr_circuitgnn::fleet::Fleet;
use dr_circuitgnn::graph::partition::partition;
use dr_circuitgnn::graph::{Cbsr, Csr, EdgeType, HeteroGraph};
use dr_circuitgnn::nn::{mse, DrCircuitGnn};
use dr_circuitgnn::sparse::{
    dr_spmm, dr_spmm_bwd, drelu, spmm_csr, spmm_csr_bwd, spmm_dense_ref, spmm_gnna, DegreeBuckets,
    GnnaConfig,
};
use dr_circuitgnn::tensor::{matmul, matmul_a_bt, matmul_at_b, Matrix};
use dr_circuitgnn::util::proptest::{check, prop_allclose, Gen};
use std::sync::Arc;

fn random_csr(g: &mut Gen, rows: usize, cols: usize, max_deg: usize) -> Csr {
    let mut t = Vec::new();
    for r in 0..rows {
        let deg = g.rng.below(max_deg + 1);
        for _ in 0..deg {
            t.push((r, g.rng.below(cols), g.rng.uniform(0.1, 2.0)));
        }
    }
    Csr::from_triplets(rows, cols, &t)
}

#[test]
fn prop_spmm_kernels_match_dense_reference() {
    check("spmm≡dense", 40, 0xA11CE, |g| {
        let rows = g.sized(1, 60);
        let cols = g.sized(1, 60);
        let d = g.sized(1, 48);
        let adj = random_csr(g, rows, cols, 6);
        let x = Matrix::from_vec(cols, d, g.normal_vec(cols * d));
        let want = spmm_dense_ref(&adj, &x);
        prop_allclose(&spmm_csr(&adj, &x).data, &want.data, 1e-3, 1e-3)?;
        let cfg = GnnaConfig { group_size: *g.pick(&[2usize, 8, 32]), dim_worker: 16 };
        prop_allclose(&spmm_gnna(&adj, &x, &cfg).data, &want.data, 1e-3, 1e-3)
    });
}

#[test]
fn prop_dr_spmm_equals_masked_dense_spmm() {
    check("dr_spmm≡spmm∘drelu", 40, 0xB0B, |g| {
        let rows = g.sized(1, 50);
        let cols = g.sized(2, 50);
        let d = g.sized(2, 40);
        let k = g.usize_in(1, d);
        let adj = random_csr(g, rows, cols, 5);
        let x = Matrix::from_vec(cols, d, g.normal_vec(cols * d));
        let compressed = drelu(&x, k);
        compressed.validate().map_err(|e| e.to_string())?;
        let buckets = DegreeBuckets::build(&adj);
        let got = dr_spmm(&adj, &compressed, &buckets);
        let want = spmm_csr(&adj, &compressed.to_dense());
        prop_allclose(&got.data, &want.data, 1e-3, 1e-3)
    });
}

#[test]
fn prop_drelu_row_invariants() {
    check("drelu row invariants", 60, 0xD0D0, |g| {
        let n = g.sized(1, 40);
        let d = g.sized(1, 64);
        let k = g.usize_in(1, d);
        let x = Matrix::from_vec(n, d, g.normal_vec(n * d));
        let c = drelu(&x, k);
        c.validate().map_err(|e| e.to_string())?;
        for r in 0..n {
            // Sum of kept values equals sum of the k largest.
            let mut sorted: Vec<f32> = x.row(r).to_vec();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let top: f32 = sorted[..k].iter().sum();
            let kept: f32 = c.row_values(r).iter().sum();
            if (top - kept).abs() > 1e-3 * (1.0 + top.abs()) {
                return Err(format!("row {r}: kept {kept} vs top-k {top}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_backward_is_adjoint_of_forward() {
    // <A·X, Y> == <X, Aᵀ·Y> for the dense kernels (exact adjointness).
    check("spmm adjoint", 30, 0xADD, |g| {
        let rows = g.sized(1, 40);
        let cols = g.sized(1, 40);
        let d = g.sized(1, 24);
        let adj = random_csr(g, rows, cols, 5);
        let x = Matrix::from_vec(cols, d, g.normal_vec(cols * d));
        let y = Matrix::from_vec(rows, d, g.normal_vec(rows * d));
        let ax = spmm_csr(&adj, &x);
        let aty = spmm_csr_bwd(&adj.to_csc(), &y);
        let lhs: f64 = ax.data.iter().zip(&y.data).map(|(a, b)| (a * b) as f64).sum();
        let rhs: f64 = x.data.iter().zip(&aty.data).map(|(a, b)| (a * b) as f64).sum();
        if (lhs - rhs).abs() > 1e-2 * (1.0 + lhs.abs()) {
            return Err(format!("<Ax,y>={lhs} vs <x,Aᵀy>={rhs}"));
        }
        Ok(())
    });
}

#[test]
fn prop_dr_backward_masked_adjoint() {
    // <A·X̃, Y> == <X̃, (Aᵀ·Y)|支持> where X̃ is the CBSR embedding.
    check("dr adjoint", 30, 0xFADE, |g| {
        let rows = g.sized(1, 30);
        let cols = g.sized(2, 30);
        let d = g.sized(2, 24);
        let k = g.usize_in(1, d);
        let adj = random_csr(g, rows, cols, 4);
        let x = Matrix::from_vec(cols, d, g.normal_vec(cols * d));
        let compressed = drelu(&x, k);
        let buckets = DegreeBuckets::build(&adj);
        let y = Matrix::from_vec(rows, d, g.normal_vec(rows * d));
        let fwd = dr_spmm(&adj, &compressed, &buckets);
        let bwd = dr_spmm_bwd(&adj.to_csc(), &y, &compressed);
        let lhs: f64 = fwd.data.iter().zip(&y.data).map(|(a, b)| (a * b) as f64).sum();
        let rhs: f64 =
            bwd.values.iter().zip(&compressed.values).map(|(a, b)| (a * b) as f64).sum();
        if (lhs - rhs).abs() > 1e-2 * (1.0 + lhs.abs()) {
            return Err(format!("{lhs} vs {rhs}"));
        }
        Ok(())
    });
}

#[test]
fn prop_csr_transpose_involution_and_csc_roundtrip() {
    check("csr transforms", 50, 0x7777, |g| {
        let rows = g.sized(1, 50);
        let cols = g.sized(1, 50);
        let adj = random_csr(g, rows, cols, 6);
        if adj.transpose().transpose() != adj {
            return Err("transpose involution failed".into());
        }
        if adj.to_csc().to_csr() != adj {
            return Err("csc round trip failed".into());
        }
        if !adj.transpose().is_transpose_of(&adj) {
            return Err("is_transpose_of failed".into());
        }
        Ok(())
    });
}

#[test]
fn prop_matmul_variants_consistent() {
    check("matmul variants", 40, 0x3A3A, |g| {
        let m = g.sized(1, 30);
        let k = g.sized(1, 30);
        let n = g.sized(1, 30);
        let a = Matrix::from_vec(m, k, g.normal_vec(m * k));
        let b = Matrix::from_vec(k, n, g.normal_vec(k * n));
        let c = matmul(&a, &b);
        prop_allclose(&matmul_at_b(&a.transpose(), &b).data, &c.data, 1e-3, 1e-3)?;
        prop_allclose(&matmul_a_bt(&a, &b.transpose()).data, &c.data, 1e-3, 1e-3)
    });
}

#[test]
fn prop_cbsr_dense_roundtrip() {
    check("cbsr roundtrip", 40, 0xCB56, |g| {
        let n = g.sized(1, 30);
        let d = g.sized(1, 40);
        let k = g.usize_in(1, d);
        let x = Matrix::from_vec(n, d, g.normal_vec(n * d));
        let c = drelu(&x, k);
        let dense = c.to_dense();
        // Dense reconstruction keeps values at exactly the kept indices.
        for r in 0..n {
            for (t, &col) in c.row_indices(r).iter().enumerate() {
                if dense.at(r, col as usize) != c.row_values(r)[t] {
                    return Err(format!("row {r} col {col} mismatch"));
                }
            }
        }
        let nnz = dense.data.iter().filter(|&&v| v != 0.0).count();
        if nnz > n * k {
            return Err(format!("too many nonzeros: {nnz} > {}", n * k));
        }
        Ok(())
    });
}

#[allow(unused)]
fn unused_cbsr(c: &Cbsr) {}

/// Random valid heterograph: square `near`, bipartite `pins` with its
/// transpose `pinned`, random features of width `d`.
fn random_heterograph(g: &mut Gen, d: usize) -> HeteroGraph {
    let n_cells = g.sized(2, 30);
    let n_nets = g.sized(1, 15);
    let near = random_csr(g, n_cells, n_cells, 4);
    let pins = random_csr(g, n_nets, n_cells, 3);
    let pinned = pins.transpose();
    let x_cell = Matrix::from_vec(n_cells, d, g.normal_vec(n_cells * d));
    let x_net = Matrix::from_vec(n_nets, d, g.normal_vec(n_nets * d));
    let hg = HeteroGraph {
        id: 0,
        n_cells,
        n_nets,
        near,
        pins,
        pinned,
        x_cell,
        x_net,
        y_cell: Matrix::zeros(n_cells, 1),
    };
    hg.validate().expect("random heterograph must be valid");
    hg
}

/// Every registered concrete kernel, driven through the Engine facade,
/// must match the dense reference on each edge type of a random
/// heterograph (DR against the D-ReLU'd dense source).
#[test]
fn prop_engine_kernels_match_dense_reference() {
    check("engine≡dense", 30, 0xE9E1, |g| {
        let d = g.sized(2, 24);
        let k = g.usize_in(1, d);
        let hg = random_heterograph(g, d);
        for name in ["csr", "gnna", "dr", "ell", "bcsr"] {
            let eng = EngineBuilder::default()
                .kernel(name)
                .k_cell(k)
                .k_net(k)
                .build(&hg);
            for e in EdgeType::ALL {
                let x = hg.src_features(e);
                let (got, _) = eng.aggregate(e, x);
                // Reference over the engine's own (normalised) adjacency;
                // DR consumes the D-ReLU'd source.
                let adj = eng.plan(e).adj.clone();
                let src = if name == "dr" { drelu(x, k.min(x.cols)).to_dense() } else { x.clone() };
                let want = spmm_dense_ref(&adj, &src);
                prop_allclose(&got.data, &want.data, 1e-3, 1e-3)
                    .map_err(|m| format!("{name}/{} fwd: {m}", e.name()))?;
            }
        }
        Ok(())
    });
}

/// Finite-difference check of every registered kernel's backward pass.
///
/// Iterates the registry itself (skipping the `auto` policy, which resolves
/// to one of the concrete entries), so a new `KernelEntry` + impl inherits
/// this correctness gate with no test changes. The kernels are linear in
/// their source operand, so central differences are exact up to f32
/// rounding:
/// * dense-source kernels are perturbed in `x` and checked against the
///   dense gradient;
/// * sparsified-source kernels (`needs_sparsified`) are perturbed in the
///   CBSR values — the operand Alg. 2 actually differentiates — and
///   checked against the compressed gradient.
#[test]
fn prop_registry_kernel_backwards_match_finite_differences() {
    check("kernel bwd≡FD", 20, 0xFD01, |g| {
        let rows = g.sized(2, 30);
        let cols = g.sized(2, 30);
        let d = g.sized(2, 16);
        let adj = random_csr(g, rows, cols, 4);
        let x = Matrix::from_vec(cols, d, g.normal_vec(cols * d));
        let dy = Matrix::from_vec(rows, d, g.normal_vec(rows * d));
        let k = g.usize_in(1, d);
        let gnna_cfg = GnnaConfig::default();
        let h = 0.5f32; // linear in the source ⇒ any step is exact
        // Weighted output functional f(src) = Σ dy ⊙ forward(src),
        // accumulated in f64 so FD error stays at product-rounding level.
        let f_of = |y: &Matrix| -> f64 {
            y.data.iter().zip(&dy.data).map(|(a, b)| (a * b) as f64).sum()
        };
        for entry in REGISTRY {
            if entry.spec == KernelSpec::Auto {
                continue;
            }
            let kernel = registry::instantiate(entry.spec, EdgeType::Near, &adj, &gnna_cfg);
            let plan = kernel.plan(adj.clone());
            if kernel.needs_sparsified() {
                let cbsr = Arc::new(drelu(&x, k));
                let (_, cache) = kernel.forward(&plan, &x, Some(&cbsr));
                let grad = match kernel.backward(&plan, &dy, &cache) {
                    Gradient::Compressed(c) => c,
                    Gradient::Dense(_) => {
                        return Err(format!("{}: expected compressed gradient", entry.name))
                    }
                };
                for i in probe_indices(g, cbsr.values.len()) {
                    let mut plus = (*cbsr).clone();
                    plus.values[i] += h;
                    let mut minus = (*cbsr).clone();
                    minus.values[i] -= h;
                    let (yp, _) = kernel.forward(&plan, &x, Some(&Arc::new(plus)));
                    let (ym, _) = kernel.forward(&plan, &x, Some(&Arc::new(minus)));
                    let fd = ((f_of(&yp) - f_of(&ym)) / (2.0 * h as f64)) as f32;
                    let got = grad.values[i];
                    if (fd - got).abs() > 1e-2 + 1e-2 * got.abs() {
                        return Err(format!(
                            "{} value[{i}]: FD {fd} vs backward {got}",
                            entry.name
                        ));
                    }
                }
            } else {
                let (_, cache) = kernel.forward(&plan, &x, None);
                let grad = kernel.backward(&plan, &dy, &cache).into_dense();
                for i in probe_indices(g, x.data.len()) {
                    let mut plus = x.clone();
                    plus.data[i] += h;
                    let mut minus = x.clone();
                    minus.data[i] -= h;
                    let (yp, _) = kernel.forward(&plan, &plus, None);
                    let (ym, _) = kernel.forward(&plan, &minus, None);
                    let fd = ((f_of(&yp) - f_of(&ym)) / (2.0 * h as f64)) as f32;
                    let got = grad.data[i];
                    if (fd - got).abs() > 1e-2 + 1e-2 * got.abs() {
                        return Err(format!(
                            "{} x[{i}]: FD {fd} vs backward {got}",
                            entry.name
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Up to 24 probe indices over `[0, n)` (all of them when n ≤ 24).
fn probe_indices(g: &mut Gen, n: usize) -> Vec<usize> {
    if n == 0 {
        Vec::new()
    } else if n <= 24 {
        (0..n).collect()
    } else {
        (0..24).map(|_| g.rng.below(n)).collect()
    }
}

/// Fleet-parallel training must equal single-engine-sequential training:
/// for any partition count and any worker count (including 1 and more
/// workers than subgraphs), the fleet's loss and reduced gradients match a
/// plain sequential loop over the same subgraphs within 1e-6.
#[test]
fn prop_fleet_gradients_equal_sequential_for_any_partition_and_worker_count() {
    check("fleet≡sequential", 12, 0xF1EE7, |g| {
        let d = 6usize;
        let mut hg = random_heterograph(g, d);
        hg.y_cell = Matrix::from_vec(hg.n_cells, 1, g.normal_vec(hg.n_cells));
        let parts = g.usize_in(1, 4);
        let workers = *g.pick(&[1usize, 2, 3, 16]);
        let kernel = *g.pick(&["csr", "dr", "gnna"]);
        let builder = EngineBuilder::default().kernel(kernel).k_cell(3).k_net(3);

        let subgraphs = partition(&hg, parts);
        let mut rng = dr_circuitgnn::util::rng::Rng::new(0xAB ^ g.case as u64);
        let model = DrCircuitGnn::new(d, d, 8, &mut rng);

        // Single-engine-sequential reference over the same subgraphs.
        let total_cells: usize = subgraphs.iter().map(|s| s.n_cells).sum();
        let mut ref_loss = 0f64;
        let mut ref_grads: Vec<Matrix> = Vec::new();
        for s in &subgraphs {
            let engine = builder.build(s);
            let mut replica = model.clone();
            let pred = replica.forward(&engine, s);
            let (loss, dp) = mse(&pred, &s.y_cell);
            let w = s.n_cells as f32 / total_cells as f32;
            replica.backward(&engine, &dp.scale(w));
            ref_loss += w as f64 * loss as f64;
            let grads: Vec<Matrix> =
                replica.params_mut().iter().map(|p| p.grad.clone()).collect();
            if ref_grads.is_empty() {
                ref_grads = grads;
            } else {
                for (a, b) in ref_grads.iter_mut().zip(&grads) {
                    a.add_inplace(b);
                }
            }
        }

        let fleet = Fleet::builder(builder).workers(workers).build(&subgraphs);
        let got = fleet.gradients(&model);
        if (got.loss - ref_loss).abs() > 1e-6 {
            return Err(format!(
                "parts {parts} workers {workers} {kernel}: loss {} vs {ref_loss}",
                got.loss
            ));
        }
        if got.grads.len() != ref_grads.len() {
            return Err("gradient structure mismatch".into());
        }
        for (pi, (a, b)) in got.grads.iter().zip(&ref_grads).enumerate() {
            prop_allclose(&a.data, &b.data, 1e-6, 1e-6)
                .map_err(|m| format!("parts {parts} workers {workers} param {pi}: {m}"))?;
        }
        Ok(())
    });
}

/// Worker count never changes fleet numerics — bit-identical gradients.
#[test]
fn prop_fleet_worker_count_invariance_is_exact() {
    check("fleet workers exact", 10, 0xF1EE8, |g| {
        let d = 6usize;
        let mut hg = random_heterograph(g, d);
        hg.y_cell = Matrix::from_vec(hg.n_cells, 1, g.normal_vec(hg.n_cells));
        let subgraphs = partition(&hg, g.usize_in(1, 3));
        let mut rng = dr_circuitgnn::util::rng::Rng::new(0xCD ^ g.case as u64);
        let model = DrCircuitGnn::new(d, d, 8, &mut rng);
        let builder = EngineBuilder::dr(3, 3);
        let base = Fleet::builder(builder.clone()).workers(1).build(&subgraphs).gradients(&model);
        for workers in [2, 9] {
            let fleet = Fleet::builder(builder.clone()).workers(workers).build(&subgraphs);
            let got = fleet.gradients(&model);
            if got.loss != base.loss {
                return Err(format!("workers {workers}: loss {} vs {}", got.loss, base.loss));
            }
            for (a, b) in got.grads.iter().zip(&base.grads) {
                if a.data != b.data {
                    return Err(format!("workers {workers}: gradient bits differ"));
                }
            }
        }
        Ok(())
    });
}

/// Epoch pipelining ≡ serial epochs, bitwise (ISSUE 5): for arbitrary
/// partition counts, worker counts, and thread budgets, `K` epochs driven
/// through `sched::run_epoch_pipeline` (prepare overlapped with execute)
/// leave the model with **bit-identical parameters** to the same `K`
/// epochs of plain serial `Fleet::step` calls, and produce the same loss
/// sequence. Kernels are restricted to the bitwise-deterministic ones
/// (csr/dr — GNNA's atomic adds are only tolerance-deterministic).
#[test]
fn prop_epoch_pipeline_equals_serial_epochs() {
    use dr_circuitgnn::fleet::FleetPipeline;
    use dr_circuitgnn::nn::Adam;
    use dr_circuitgnn::sched::ScheduleMode;
    use dr_circuitgnn::util::pool::Budget;

    check("pipeline≡serial", 8, 0x51BE, |g| {
        let d = 6usize;
        let n_designs = g.usize_in(1, 3);
        let parts = g.usize_in(1, 3);
        let workers = *g.pick(&[1usize, 2, 5]);
        let budget = *g.pick(&[1usize, 2, 4]);
        let kernel = *g.pick(&["csr", "dr"]);
        let epochs = 2usize;
        let designs: Vec<Vec<HeteroGraph>> = (0..n_designs)
            .map(|_| {
                let mut hg = random_heterograph(g, d);
                hg.y_cell = Matrix::from_vec(hg.n_cells, 1, g.normal_vec(hg.n_cells));
                vec![hg]
            })
            .collect();
        let builder =
            EngineBuilder::default().kernel(kernel).k_cell(3).k_net(3).parallel(true);
        let fleet_builder = Fleet::builder(builder.clone()).workers(workers).parts(parts);
        let mut rng = dr_circuitgnn::util::rng::Rng::new(0x5E ^ g.case as u64);
        let model0 = DrCircuitGnn::new(d, d, 8, &mut rng);

        // Serial reference: per-design fleets, prepare+execute fused.
        let mut serial_model = model0.clone();
        let mut serial_opt = Adam::new(5e-3, 0.0);
        let mut serial_losses = Vec::new();
        let fleets: Vec<Fleet> = designs.iter().map(|gs| fleet_builder.build(gs)).collect();
        for _ in 0..epochs {
            for fleet in &fleets {
                serial_losses.push(fleet.step(&mut serial_model, &mut serial_opt).loss);
            }
        }

        // Pipelined run under the sampled budget, through the production
        // FleetPipeline driver (lazy builds via a shared cache in the
        // prepare stage, execute on the caller). Note the serial
        // reference above used the fused in-place input path while this
        // runs on staged copies — the comparison also gates staged ≡
        // in-place.
        let mut piped_model = model0.clone();
        let mut piped_opt = Adam::new(5e-3, 0.0);
        let mut piped_losses = Vec::new();
        Budget::new(budget).with(|| {
            let pipeline = FleetPipeline::new(
                fleet_builder.clone(),
                designs.iter().map(|gs| gs.as_slice()).collect(),
            );
            for _ in 0..epochs {
                let run = pipeline.run_epoch(ScheduleMode::Parallel, |_, fleet, staged| {
                    fleet.execute(staged, &mut piped_model, &mut piped_opt).loss
                });
                piped_losses.extend(run.results);
            }
        });

        if serial_losses.len() != piped_losses.len() {
            return Err(format!(
                "loss sequence lengths diverged: {} vs {} (designs {n_designs}, \
                 parts {parts}, workers {workers}, budget {budget}, {kernel})",
                serial_losses.len(),
                piped_losses.len()
            ));
        }
        if serial_losses
            .iter()
            .zip(&piped_losses)
            .any(|(a, b)| a.to_bits() != b.to_bits())
        {
            return Err(format!(
                "losses diverged (designs {n_designs}, parts {parts}, workers {workers}, \
                 budget {budget}, {kernel}): {serial_losses:?} vs {piped_losses:?}"
            ));
        }
        for (pi, (a, b)) in serial_model
            .params_mut()
            .iter()
            .zip(piped_model.params_mut().iter())
            .enumerate()
        {
            if a.value.data != b.value.data {
                return Err(format!(
                    "param {pi} bits diverged after {epochs} epochs (designs {n_designs}, \
                     parts {parts}, workers {workers}, budget {budget}, {kernel})"
                ));
            }
        }
        Ok(())
    });
}

/// Window-sampled fleets (ISSUE 10): sampling is a pure function of
/// `(seed, epoch, graph id)` — two calls return identical windows — and an
/// owned fleet over the sampled windows keeps the deterministic-reduction
/// guarantee: loss and gradients are bit-identical for every worker count
/// and thread budget.
#[test]
fn prop_window_sampled_fleet_is_worker_invariant_and_seed_deterministic() {
    use dr_circuitgnn::datagen::sample_windows;
    use dr_circuitgnn::util::pool::Budget;

    check("windows≡workers", 10, 0x3196D0, |g| {
        let d = 6usize;
        let mut hg = random_heterograph(g, d);
        hg.y_cell = Matrix::from_vec(hg.n_cells, 1, g.normal_vec(hg.n_cells));
        let count = g.usize_in(1, 3);
        let cells = g.usize_in(2, hg.n_cells);
        let seed = 0x57A5 ^ g.case as u64;
        let epoch = g.usize_in(0, 3);

        // Seed-determinism: resampling with the same key is bit-identical.
        let mut windows = sample_windows(&hg, count, cells, seed, epoch);
        let again = sample_windows(&hg, count, cells, seed, epoch);
        if windows.len() != count || again.len() != count {
            return Err(format!("expected {count} windows, got {}", windows.len()));
        }
        for (a, b) in windows.iter().zip(&again) {
            if a.n_cells != b.n_cells
                || a.near != b.near
                || a.pins != b.pins
                || a.x_cell.data != b.x_cell.data
                || a.y_cell.data != b.y_cell.data
            {
                return Err("resampling with the same (seed, epoch, id) diverged".into());
            }
        }

        // Worker/budget invariance of the owned fleet over the windows.
        for (i, w) in windows.iter_mut().enumerate() {
            w.id = i;
        }
        let builder = Fleet::builder(EngineBuilder::dr(3, 3));
        let mut rng = dr_circuitgnn::util::rng::Rng::new(0xEF ^ g.case as u64);
        let model = DrCircuitGnn::new(d, d, 8, &mut rng);
        let base = builder.clone().workers(1).build_owned(windows.clone()).gradients(&model);
        for (workers, budget) in [(2usize, 4usize), (5, 1), (16, 2)] {
            let fleet = builder.clone().workers(workers).build_owned(windows.clone());
            let got = Budget::new(budget).with(|| fleet.gradients(&model));
            if got.loss.to_bits() != base.loss.to_bits() {
                return Err(format!(
                    "workers {workers} budget {budget}: loss {} vs {}",
                    got.loss, base.loss
                ));
            }
            for (a, b) in got.grads.iter().zip(&base.grads) {
                if a.data != b.data {
                    return Err(format!("workers {workers} budget {budget}: gradient bits"));
                }
            }
        }
        Ok(())
    });
}

/// Activation checkpointing (ISSUE 10) is a pure recomputation strategy:
/// for **every registered concrete kernel** (the registry iterated like
/// the FD gate, so new entries inherit this check), a checkpointed model
/// produces bit-identical predictions and parameter gradients to its
/// uncheckpointed clone. Engines are built without §3.4 lane parallelism,
/// where even GNNA's atomic accumulation runs in one deterministic order.
#[test]
fn prop_checkpointed_backward_is_bitwise_for_every_registry_kernel() {
    check("ckpt≡plain", 12, 0xC4B7, |g| {
        let d = 6usize;
        let mut hg = random_heterograph(g, d);
        hg.y_cell = Matrix::from_vec(hg.n_cells, 1, g.normal_vec(hg.n_cells));
        let k = g.usize_in(1, 4);
        for entry in REGISTRY {
            if entry.spec == KernelSpec::Auto {
                continue;
            }
            let eng = EngineBuilder::default()
                .kernel(entry.name)
                .k_cell(k)
                .k_net(k)
                .build(&hg);
            let mut rng = dr_circuitgnn::util::rng::Rng::new(0x11 ^ g.case as u64);
            let mut plain = DrCircuitGnn::new(d, d, 8, &mut rng);
            let mut ckpt = plain.clone();
            ckpt.set_checkpoint(true);

            let pred_p = plain.forward(&eng, &hg);
            let pred_c = ckpt.forward(&eng, &hg);
            if pred_p.data != pred_c.data {
                return Err(format!("{}: checkpointed forward bits diverged", entry.name));
            }
            let (_, dp) = mse(&pred_p, &hg.y_cell);
            plain.backward(&eng, &dp);
            ckpt.backward(&eng, &dp);
            for (pi, (a, b)) in
                plain.params_mut().iter().zip(ckpt.params_mut().iter()).enumerate()
            {
                if a.grad.data != b.grad.data {
                    return Err(format!(
                        "{} param {pi}: checkpointed gradient bits diverged",
                        entry.name
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Backward gradients through the Engine must agree with the dense
/// transpose reference — exactly for csr/gnna, masked to the forward CBSR
/// support for DR.
#[test]
fn prop_engine_backward_gradients_agree() {
    check("engine bwd≡denseᵀ", 30, 0xE9E2, |g| {
        let d = g.sized(2, 20);
        let k = g.usize_in(1, d);
        let hg = random_heterograph(g, d);
        for name in ["csr", "gnna", "dr", "ell", "bcsr"] {
            let eng = EngineBuilder::default()
                .kernel(name)
                .k_cell(k)
                .k_net(k)
                .build(&hg);
            for e in EdgeType::ALL {
                let x = hg.src_features(e);
                let (_, cache) = eng.aggregate(e, x);
                let adj = eng.plan(e).adj.clone();
                let dy = Matrix::from_vec(adj.rows, d, g.normal_vec(adj.rows * d));
                let got = eng.aggregate_backward(e, &dy, &cache);
                let mut want = spmm_dense_ref(&adj.transpose(), &dy);
                if name == "dr" {
                    // D-ReLU subgradient: only the kept coordinates of
                    // each source row receive gradient.
                    let fwd = match &cache {
                        AggCache::Cbsr(c) => c,
                        AggCache::None => unreachable!("DR caches its CBSR"),
                    };
                    for r in 0..want.rows {
                        let kept = fwd.row_indices(r);
                        for c in 0..want.cols {
                            if !kept.contains(&(c as u32)) {
                                *want.at_mut(r, c) = 0.0;
                            }
                        }
                    }
                }
                prop_allclose(&got.data, &want.data, 1e-3, 1e-3)
                    .map_err(|m| format!("{name}/{} bwd: {m}", e.name()))?;
            }
        }
        Ok(())
    });
}
