//! drcg-lint self-tests: every rule must both fire on its failing fixture
//! and stay silent on its passing fixture, the allowlist grammar must
//! reject unjustified entries, and — the live gate — the real source tree
//! must lint clean under the committed allowlist. Runs as a plain
//! `cargo test`; the CI `analysis` job additionally runs the CLI so the
//! gate exists even for toolchains that skip tests. See `docs/ANALYSIS.md`.

use dr_circuitgnn::analysis::{
    check_registry_planstore, kernel_spec_variants, lint_file, lint_tree, Allowlist,
};
use std::path::Path;

fn rules_of(diags: &[dr_circuitgnn::analysis::Diagnostic]) -> Vec<&'static str> {
    diags.iter().map(|d| d.rule).collect()
}

// --- R1: SAFETY contracts --------------------------------------------------

#[test]
fn r1_fires_on_undocumented_unsafe() {
    // Scanned under the pool path so R2 stays out of the way.
    let diags = lint_file("util/pool.rs", include_str!("lint_fixtures/r1_fire.rs"));
    assert_eq!(rules_of(&diags), vec!["R1", "R1"], "{diags:?}");
    assert_eq!(diags[0].line, 5);
    assert_eq!(diags[1].line, 10);
}

#[test]
fn r1_passes_documented_unsafe() {
    let diags = lint_file("util/pool.rs", include_str!("lint_fixtures/r1_pass.rs"));
    assert!(diags.is_empty(), "{diags:?}");
}

// --- R2: fan-out confinement -----------------------------------------------

#[test]
fn r2_fires_outside_the_pool() {
    let diags = lint_file("serve/helper.rs", include_str!("lint_fixtures/r2_fire.rs"));
    assert_eq!(rules_of(&diags), vec!["R2", "R2"], "{diags:?}");
}

#[test]
fn r2_exempts_the_pool_itself() {
    // The same offending source is legal inside util::pool — that is
    // where the budgeted substrate and SendPtr live.
    let diags = lint_file("util/pool.rs", include_str!("lint_fixtures/r2_fire.rs"));
    assert!(diags.iter().all(|d| d.rule != "R2"), "{diags:?}");
}

#[test]
fn r2_passes_budgeted_fanout_and_test_threads() {
    let diags = lint_file("serve/helper.rs", include_str!("lint_fixtures/r2_pass.rs"));
    assert!(diags.is_empty(), "{diags:?}");
}

// --- R3: poisoning policy --------------------------------------------------

#[test]
fn r3_fires_on_every_bare_poison_unwrap() {
    let diags = lint_file("serve/helper.rs", include_str!("lint_fixtures/r3_fire.rs"));
    assert_eq!(rules_of(&diags), vec!["R3"; 5], "{diags:?}");
    // The split builder-style call is attributed to the `.lock()` line.
    assert!(diags.iter().any(|d| d.excerpt.contains("m.lock()")), "{diags:?}");
}

#[test]
fn r3_passes_into_inner_recovery() {
    let diags = lint_file("serve/helper.rs", include_str!("lint_fixtures/r3_pass.rs"));
    assert!(diags.is_empty(), "{diags:?}");
}

// --- R4: determinism of trace paths ----------------------------------------

#[test]
fn r4_fires_in_golden_trace_dirs() {
    let diags = lint_file("sparse/fixture.rs", include_str!("lint_fixtures/r4_fire.rs"));
    assert_eq!(rules_of(&diags), vec!["R4"; 4], "{diags:?}");
    let lines: Vec<usize> = diags.iter().map(|d| d.line).collect();
    assert_eq!(lines, vec![3, 6, 7, 15], "{diags:?}");
}

#[test]
fn r4_is_scoped_to_trace_feeding_dirs() {
    // The very same source is fine outside sparse/tensor/nn/graph/
    // engine/train — the serve loop may read clocks.
    let diags = lint_file("serve/fixture.rs", include_str!("lint_fixtures/r4_fire.rs"));
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn r4_passes_ordered_containers_and_test_clocks() {
    let diags = lint_file("sparse/fixture.rs", include_str!("lint_fixtures/r4_pass.rs"));
    assert!(diags.is_empty(), "{diags:?}");
}

// --- R5: registry/plan-store exhaustiveness ---------------------------------

const MINI_REGISTRY: &str = r#"
pub enum KernelSpec {
    /// baseline
    Csr,
    Dr,
    Ell,
}
"#;

#[test]
fn r5_parses_the_variant_list() {
    assert_eq!(kernel_spec_variants(MINI_REGISTRY), vec!["Csr", "Dr", "Ell"]);
}

#[test]
fn r5_fires_on_a_missing_serializer_arm() {
    let planstore = "fn missing_payload(s: KernelSpec) {\n\
                     match s { KernelSpec::Csr => {} KernelSpec::Dr => {} }\n}";
    let diags = check_registry_planstore(MINI_REGISTRY, planstore);
    assert_eq!(rules_of(&diags), vec!["R5"], "{diags:?}");
    assert!(diags[0].message.contains("KernelSpec::Ell"), "{diags:?}");
}

#[test]
fn r5_passes_a_complete_arm_set() {
    let planstore = "fn missing_payload(s: KernelSpec) {\n\
                     match s { KernelSpec::Csr => {} KernelSpec::Dr => {} \
                     KernelSpec::Ell => {} }\n}";
    assert!(check_registry_planstore(MINI_REGISTRY, planstore).is_empty());
}

// --- Allowlist grammar ------------------------------------------------------

#[test]
fn allowlist_requires_a_written_justification() {
    assert!(Allowlist::parse("R2 serve/mod.rs thread::scope").is_err());
    assert!(Allowlist::parse("R2 serve/mod.rs thread::scope -- ").is_err());
    assert!(Allowlist::parse("R2 serve/mod.rs -- reason with no needle").is_err());
    let ok = Allowlist::parse(
        "# comment\n\nR2 serve/mod.rs thread::scope -- workers are the budget roots\n",
    )
    .unwrap();
    assert_eq!(ok.entries.len(), 1);
    assert_eq!(ok.entries[0].needle, "thread::scope");
}

#[test]
fn stale_allowlist_entries_fail_the_tree_scan() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut allow =
        Allowlist::load(&Path::new(env!("CARGO_MANIFEST_DIR")).join("lint-allow.txt")).unwrap();
    allow.entries.push(dr_circuitgnn::analysis::AllowEntry {
        rule: "R3".to_string(),
        path: "does/not/exist.rs".to_string(),
        needle: "never".to_string(),
        reason: "stale on purpose".to_string(),
    });
    let report = lint_tree(&src, &allow).unwrap();
    assert_eq!(report.stale.len(), 1, "exactly the planted entry is stale");
    assert!(!report.is_clean());
}

// --- The live gate ----------------------------------------------------------

/// The real tree lints clean under the committed allowlist — the same
/// check CI's `analysis` job runs via the CLI, enforced here so any plain
/// `cargo test` catches a violation before it lands.
#[test]
fn the_source_tree_is_clean() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let allow = Allowlist::load(&manifest.join("lint-allow.txt")).unwrap();
    let report = lint_tree(&manifest.join("src"), &allow).unwrap();
    assert!(
        report.is_clean(),
        "drcg-lint findings:\n{}\nstale allowlist entries: {:?}",
        report
            .diagnostics
            .iter()
            .map(|d| format!("{d}\n    --> {}", d.excerpt))
            .collect::<Vec<_>>()
            .join("\n"),
        report.stale
    );
    assert!(report.files_scanned > 40, "walked the real tree");
    // Both standing exemptions are still load-bearing.
    assert_eq!(report.allowlisted.len(), 2, "{:?}", report.allowlisted);
}
