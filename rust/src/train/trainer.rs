//! Training loops for DR-CircuitGNN and the homogeneous baselines.
//!
//! Hyper-parameters default to the paper's §4.1 setup: DR-CircuitGNN with
//! 2 layers, lr 2e-4, weight decay 1e-5; baselines with 3 layers, lr 1e-3,
//! weight decay 2e-4, 50 epochs, GraphSAGE in 'mean' mode.
//!
//! Kernel selection comes in as an [`EngineBuilder`]; the trainer builds
//! one [`Engine`](crate::engine::Engine) per training graph up front
//! (paper Alg. 1 stage 1 — plans are cached across every epoch and layer).

use super::metrics::EvalScores;
use crate::datagen::{sample_windows, Dataset, WindowSpec};
use crate::engine::{Engine, EngineBuilder};
use crate::fleet::{CacheStats, Fleet, FleetPipeline, FleetSpec, PlanCache};
use crate::nn::model::{homogenize, HomoView};
use crate::nn::{mse, Adam, DrCircuitGnn, HomoGnn, HomoKind};
use crate::sched::{pipeline_will_overlap, run_epoch_pipeline, ScheduleMode};
use crate::util::rng::Rng;
use crate::util::timer::time_it;
use std::sync::Arc;

/// Training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub lr: f32,
    pub weight_decay: f32,
    pub hidden: usize,
    pub seed: u64,
    /// §3.4 parallel subgraph aggregation (DR model only).
    pub parallel: bool,
    /// Fleet-level epoch pipelining (fleet mode only): overlap design
    /// N+1's prepare stage (lazy fleet build through a shared plan cache +
    /// feature staging) with design N's execute + optimizer step, via
    /// [`crate::sched::run_epoch_pipeline`]. Loss curves and parameters
    /// are bit-identical to the serial epoch schedule — prepare reads no
    /// state the optimizer writes (gated by `tests/integration_golden.rs`).
    pub epoch_pipeline: bool,
    /// Window/neighbor sampling (fleet mode only): when `On`, every epoch
    /// each parent graph contributes freshly sampled window subgraphs
    /// ([`crate::datagen::sample_windows`], seeded by
    /// `(cfg.seed, epoch, graph id)`) and the fleet trains on those instead
    /// of the full graphs — the million-node path where staging a whole
    /// design would not fit. Deterministic reduction is preserved: losses
    /// and parameters are bit-identical for any worker count at a fixed
    /// seed.
    pub window: WindowSpec,
    /// Activation checkpointing ([`DrCircuitGnn::set_checkpoint`], DR model
    /// only): forward keeps layer-boundary activations only, backward
    /// recomputes each layer's internal state. Bit-identical results,
    /// ≈ one extra forward of compute, intra-layer caches live one layer
    /// at a time.
    pub checkpoint: bool,
    pub log_every: usize,
}

impl TrainConfig {
    /// Paper defaults for DR-CircuitGNN.
    pub fn dr_default() -> TrainConfig {
        TrainConfig {
            epochs: 50,
            lr: 2e-4,
            weight_decay: 1e-5,
            hidden: 64,
            seed: 42,
            parallel: false,
            epoch_pipeline: false,
            window: WindowSpec::Off,
            checkpoint: false,
            log_every: 10,
        }
    }

    /// Paper defaults for the homogeneous baselines.
    pub fn homo_default() -> TrainConfig {
        TrainConfig { lr: 1e-3, weight_decay: 2e-4, ..TrainConfig::dr_default() }
    }
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub epoch_losses: Vec<f64>,
    /// Scores averaged over the test graphs.
    pub test_scores: EvalScores,
    pub per_graph_scores: Vec<EvalScores>,
    pub train_seconds: f64,
    pub params: usize,
    /// Per-epoch prepare/execute overlap factors (busy/makespan over the
    /// two pipeline lanes), populated only by the epoch-pipelined fleet
    /// trainer; > 1 means design N+1's prepare genuinely overlapped
    /// design N's execute in that epoch. Empty for every other mode.
    pub epoch_overlap: Vec<f64>,
    /// Plan-cache lookups this run performed while building its training
    /// engines (`unique()` = engines materialised; `misses` = Alg. 1
    /// stage 1 plans built cold, `disk_loads` = warm loads from a
    /// `--plan-store`). Zero for the homogeneous baselines, which have no
    /// engine layer.
    pub plan_cache: CacheStats,
}

pub struct Trainer;

impl Trainer {
    /// Train DR-CircuitGNN on a dataset; evaluates on `test` afterwards.
    pub fn train_dr(
        train: &Dataset,
        test: &Dataset,
        engine: &EngineBuilder,
        cfg: &TrainConfig,
    ) -> (DrCircuitGnn, TrainReport) {
        let cache = PlanCache::new(engine.clone().parallel(cfg.parallel));
        Self::train_dr_cached(train, test, engine, cfg, &cache)
    }

    /// [`Trainer::train_dr`] with every engine resolved through a
    /// caller-owned [`PlanCache`] — possibly disk-backed
    /// ([`PlanCache::backed_by`]), so a warm restart builds zero Alg. 1
    /// stage 1 plans. Test-set engines resolve through the same cache, so
    /// the warm-start property covers evaluation too. The cache must have
    /// been created from `engine` with `cfg.parallel` applied.
    pub fn train_dr_cached(
        train: &Dataset,
        test: &Dataset,
        engine: &EngineBuilder,
        cfg: &TrainConfig,
        cache: &PlanCache,
    ) -> (DrCircuitGnn, TrainReport) {
        let mut rng = Rng::new(cfg.seed);
        // Raw feature dims from the first graph.
        let first = train.graphs().next().expect("empty training set");
        let (dc, dn) = (first.x_cell.cols, first.x_net.cols);
        let mut model = DrCircuitGnn::new(dc, dn, cfg.hidden, &mut rng);
        model.set_checkpoint(cfg.checkpoint);
        let params = model.numel();
        let mut opt = Adam::new(cfg.lr, cfg.weight_decay);

        let builder = engine.clone().parallel(cfg.parallel);
        assert!(
            cache.compatible_with(&builder),
            "plan cache built from a different engine configuration"
        );
        // Resolve every graph's engine once (paper Alg. 1 stage 1):
        // normalisation, CSC transposition and kernel schedules are paid
        // here — or loaded from the backing store — never per step.
        let mut plan_cache = CacheStats::default();
        let engines: Vec<Vec<Arc<Engine>>> = train
            .designs
            .iter()
            .map(|(_, gs)| {
                gs.iter()
                    .map(|g| {
                        let (eng, lookup) = cache.engine_for_traced(g);
                        plan_cache.record(lookup);
                        eng
                    })
                    .collect()
            })
            .collect();

        let mut epoch_losses = Vec::with_capacity(cfg.epochs);
        let (_, secs) = time_it(|| {
            for epoch in 0..cfg.epochs {
                let mut epoch_loss = 0f64;
                let mut count = 0usize;
                for (di, (_, graphs)) in train.designs.iter().enumerate() {
                    for (gi, g) in graphs.iter().enumerate() {
                        let eng = &engines[di][gi];
                        let pred = model.forward(eng, g);
                        let (loss, dp) = mse(&pred, &g.y_cell);
                        model.backward(eng, &dp);
                        opt.step(&mut model.params_mut());
                        Adam::zero_grad(&mut model.params_mut());
                        epoch_loss += loss as f64;
                        count += 1;
                    }
                }
                let avg = epoch_loss / count.max(1) as f64;
                epoch_losses.push(avg);
                if cfg.log_every > 0 && epoch % cfg.log_every == 0 {
                    crate::info!("epoch {epoch:3}: loss {avg:.6}");
                }
            }
        });

        let (test_scores, per_graph_scores) = Self::eval_dr_cached(&mut model, test, cache);
        (
            model,
            TrainReport {
                epoch_losses,
                test_scores,
                per_graph_scores,
                train_seconds: secs,
                params,
                epoch_overlap: Vec::new(),
                plan_cache,
            },
        )
    }

    /// Train DR-CircuitGNN in fleet mode: one [`Fleet`] per design, one
    /// optimizer step per design per epoch over the deterministically
    /// reduced design gradient (vs. [`Trainer::train_dr`]'s one step per
    /// graph — fleet mode is gradient accumulation across a design's
    /// subgraphs, executed concurrently).
    ///
    /// Loss curves are identical for every worker count of `spec` — the
    /// reduction happens in subgraph index order regardless of which worker
    /// finished first (asserted in `tests/integration_fleet.rs`).
    ///
    /// Both epoch schedules run through one [`FleetPipeline`] driver (the
    /// same layout the fig13 bench, golden harness and proptests
    /// exercise); `cfg.epoch_pipeline` selects the parallel mode, where
    /// design N+1's prepare stage — its lazy fleet build against one plan
    /// cache **shared across all designs** (first epoch; content-identical
    /// subgraphs of different designs plan once) plus its feature staging
    /// (every epoch) — runs on a leased budget share while design N
    /// executes and takes its optimizer step on the calling thread. The
    /// prepare stage reads only dataset state, so the loss curve and final
    /// parameters are bit-identical to the serial schedule
    /// (`tests/integration_golden.rs`, `tests/proptests.rs`); the achieved
    /// overlap lands in [`TrainReport::epoch_overlap`].
    pub fn train_dr_fleet(
        train: &Dataset,
        test: &Dataset,
        engine: &EngineBuilder,
        cfg: &TrainConfig,
        spec: &FleetSpec,
    ) -> (DrCircuitGnn, TrainReport) {
        let cache = Arc::new(PlanCache::new(engine.clone().parallel(cfg.parallel)));
        Self::train_dr_fleet_cached(train, test, engine, cfg, spec, &cache)
    }

    /// [`Trainer::train_dr_fleet`] over a caller-owned, possibly shared
    /// and/or disk-backed [`PlanCache`]. This is the serve loop's job
    /// body: every concurrent job resolves through one cross-design cache,
    /// and because fleet execution is bit-identical for any worker
    /// count/budget and the cache returns the same planned engines
    /// regardless of who triggered the build, a job's report equals the
    /// standalone run's bit for bit. The cache must have been created from
    /// `engine` with `cfg.parallel` applied (panics otherwise).
    pub fn train_dr_fleet_cached(
        train: &Dataset,
        test: &Dataset,
        engine: &EngineBuilder,
        cfg: &TrainConfig,
        spec: &FleetSpec,
        cache: &Arc<PlanCache>,
    ) -> (DrCircuitGnn, TrainReport) {
        let mut rng = Rng::new(cfg.seed);
        let first = train.graphs().next().expect("empty training set");
        let (dc, dn) = (first.x_cell.cols, first.x_net.cols);
        let mut model = DrCircuitGnn::new(dc, dn, cfg.hidden, &mut rng);
        model.set_checkpoint(cfg.checkpoint);
        let params = model.numel();
        let mut opt = Adam::new(cfg.lr, cfg.weight_decay);

        let builder = engine.clone().parallel(cfg.parallel);
        let fleet_builder = Fleet::builder(builder).spec(spec);
        let design_graphs: Vec<&[crate::graph::HeteroGraph]> =
            train.designs.iter().map(|(_, gs)| gs.as_slice()).collect();
        let n_designs = design_graphs.len();

        // Window-sampling mode: every epoch, each design's prepare stage
        // samples fresh window subgraphs from its parent graphs, cuts them
        // (`cut_partition` semantics), builds an *owned* fleet over them
        // and stages its features — all weight-independent, so the stage
        // keeps the pipeline's no-weight-reads invariant and may overlap
        // the previous design's execute. Execute runs on this thread in
        // design order with the usual deterministic reduction, so losses
        // and parameters are bit-identical for any worker count or budget
        // at a fixed `cfg.seed`.
        if let WindowSpec::On { count, cells } = cfg.window {
            if spec.parts().is_some() {
                crate::warn!(
                    "[fleet {}] window mode ignores the partition request — \
                     sampled windows are the subgraphs",
                    spec.describe()
                );
            }
            let mode = if cfg.epoch_pipeline {
                ScheduleMode::Parallel
            } else {
                ScheduleMode::Sequential
            };
            let stage_copies = pipeline_will_overlap(n_designs, mode);
            let mut epoch_losses = Vec::with_capacity(cfg.epochs);
            let mut epoch_overlap = Vec::new();
            let mut plan_cache = CacheStats::default();
            let (_, secs) = time_it(|| {
                for epoch in 0..cfg.epochs {
                    let fb = &fleet_builder;
                    let graphs = &design_graphs;
                    let run = run_epoch_pipeline(
                        n_designs,
                        mode,
                        |d| {
                            let mut windows = Vec::new();
                            for g in graphs[d] {
                                windows.extend(sample_windows(g, count, cells, cfg.seed, epoch));
                            }
                            // Fleet-wide ids across the design's parents.
                            for (i, w) in windows.iter_mut().enumerate() {
                                w.id = i;
                            }
                            let fleet = fb.build_owned(windows);
                            let staged = if stage_copies {
                                fleet.prepare()
                            } else {
                                fleet.prepare_in_place()
                            };
                            (fleet, staged)
                        },
                        |_, (fleet, staged)| {
                            plan_cache = plan_cache.plus(&fleet.cache_stats());
                            fleet.execute(&staged, &mut model, &mut opt).loss
                        },
                    );
                    let avg = run.results.iter().sum::<f64>() / n_designs.max(1) as f64;
                    epoch_losses.push(avg);
                    if cfg.epoch_pipeline {
                        epoch_overlap.push(run.overlap_factor());
                    }
                    if cfg.log_every > 0 && epoch % cfg.log_every == 0 {
                        crate::info!(
                            "[fleet {} window {}] epoch {epoch:3}: loss {avg:.6}",
                            spec.describe(),
                            cfg.window.describe()
                        );
                    }
                }
            });
            let (test_scores, per_graph_scores) = Self::eval_dr_cached(&mut model, test, cache);
            return (
                model,
                TrainReport {
                    epoch_losses,
                    test_scores,
                    per_graph_scores,
                    train_seconds: secs,
                    params,
                    epoch_overlap,
                    plan_cache,
                },
            );
        }

        // One driver for both schedules: fleets built lazily inside the
        // prepare stage (epoch 0's Alg. 1 stage 1 planning overlaps
        // execution under the parallel mode) against one plan cache
        // shared across all designs; later epochs' prepare re-stages
        // features only. The two modes differ *only* in where prepare
        // runs — execute owns the model/optimizer on this thread either
        // way, so loss curves are bit-identical.
        let pipeline = FleetPipeline::with_cache(fleet_builder, design_graphs, Arc::clone(cache));
        let mode = if cfg.epoch_pipeline {
            ScheduleMode::Parallel
        } else {
            // Serial schedule: build (plan) everything up front so
            // train_seconds keeps the same boundary as train_dr — only
            // the pipelined mode leaves builds inside the loop, where
            // overlapping epoch-0 planning with execution is the point.
            pipeline.build_all();
            ScheduleMode::Sequential
        };
        let mut epoch_losses = Vec::with_capacity(cfg.epochs);
        let mut epoch_overlap = Vec::new();
        let (_, secs) = time_it(|| {
            for epoch in 0..cfg.epochs {
                let run = pipeline.run_epoch(mode, |_, fleet, staged| {
                    fleet.execute(staged, &mut model, &mut opt).loss
                });
                let avg = run.results.iter().sum::<f64>() / n_designs.max(1) as f64;
                epoch_losses.push(avg);
                if cfg.epoch_pipeline {
                    epoch_overlap.push(run.overlap_factor());
                }
                if cfg.log_every > 0 && epoch % cfg.log_every == 0 {
                    if cfg.epoch_pipeline {
                        crate::info!(
                            "[fleet {} pipelined] epoch {epoch:3}: loss {avg:.6} \
                             (overlap {:.2}×)",
                            spec.describe(),
                            run.overlap_factor()
                        );
                    } else {
                        crate::info!(
                            "[fleet {}] epoch {epoch:3}: loss {avg:.6}",
                            spec.describe()
                        );
                    }
                }
            }
        });

        // This run's share of the shared cache's lookups: summed from the
        // per-fleet tallies (exact under concurrent cache users — see
        // `FleetBuilder::build_with_cache`).
        let plan_cache = (0..pipeline.n_designs())
            .filter_map(|d| pipeline.fleet(d))
            .fold(CacheStats::default(), |acc, f| acc.plus(&f.cache_stats()));
        let (test_scores, per_graph_scores) = Self::eval_dr_cached(&mut model, test, cache);
        (
            model,
            TrainReport {
                epoch_losses,
                test_scores,
                per_graph_scores,
                train_seconds: secs,
                params,
                epoch_overlap,
                plan_cache,
            },
        )
    }

    /// Evaluate a trained DR model on a dataset.
    pub fn eval_dr(
        model: &mut DrCircuitGnn,
        data: &Dataset,
        engine: &EngineBuilder,
    ) -> (EvalScores, Vec<EvalScores>) {
        Self::eval_dr_cached(model, data, &PlanCache::new(engine.clone()))
    }

    /// [`Trainer::eval_dr`] resolving test-graph engines through a plan
    /// cache, so evaluation shares plans with training (and with the
    /// backing store, when present).
    pub fn eval_dr_cached(
        model: &mut DrCircuitGnn,
        data: &Dataset,
        cache: &PlanCache,
    ) -> (EvalScores, Vec<EvalScores>) {
        let mut per_graph = Vec::new();
        for (_, graphs) in &data.designs {
            for g in graphs {
                let eng = cache.engine_for(g);
                let pred = model.forward(&eng, g);
                per_graph.push(EvalScores::compute(&pred.data, &g.y_cell.data));
            }
        }
        (EvalScores::average(&per_graph), per_graph)
    }

    /// Train a homogeneous baseline (GCN / SAGE / GAT).
    pub fn train_homo(
        kind: HomoKind,
        train: &Dataset,
        test: &Dataset,
        cfg: &TrainConfig,
    ) -> (HomoGnn, TrainReport) {
        let mut rng = Rng::new(cfg.seed);
        let views: Vec<Vec<HomoView>> = train
            .designs
            .iter()
            .map(|(_, gs)| gs.iter().map(homogenize).collect())
            .collect();
        let d_in = views[0][0].x.cols;
        let mut model = HomoGnn::new(kind, d_in, cfg.hidden, &mut rng);
        let params = model.numel();
        let mut opt = Adam::new(cfg.lr, cfg.weight_decay);

        let mut epoch_losses = Vec::with_capacity(cfg.epochs);
        let (_, secs) = time_it(|| {
            for epoch in 0..cfg.epochs {
                let mut epoch_loss = 0f64;
                let mut count = 0usize;
                for (di, (_, graphs)) in train.designs.iter().enumerate() {
                    for (gi, g) in graphs.iter().enumerate() {
                        let view = &views[di][gi];
                        let pred = model.forward(view);
                        let (loss, dp) = mse(&pred, &g.y_cell);
                        model.backward(view, &dp);
                        opt.step(&mut model.params_mut());
                        Adam::zero_grad(&mut model.params_mut());
                        epoch_loss += loss as f64;
                        count += 1;
                    }
                }
                let avg = epoch_loss / count.max(1) as f64;
                epoch_losses.push(avg);
                if cfg.log_every > 0 && epoch % cfg.log_every == 0 {
                    crate::info!("[{}] epoch {epoch:3}: loss {avg:.6}", kind.name());
                }
            }
        });

        let (test_scores, per_graph_scores) = Self::eval_homo(&mut model, test);
        (
            model,
            TrainReport {
                epoch_losses,
                test_scores,
                per_graph_scores,
                train_seconds: secs,
                params,
                epoch_overlap: Vec::new(),
                plan_cache: CacheStats::default(),
            },
        )
    }

    pub fn eval_homo(model: &mut HomoGnn, data: &Dataset) -> (EvalScores, Vec<EvalScores>) {
        let mut per_graph = Vec::new();
        for (_, graphs) in &data.designs {
            for g in graphs {
                let view = homogenize(g);
                let pred = model.forward(&view);
                per_graph.push(EvalScores::compute(&pred.data, &g.y_cell.data));
            }
        }
        (EvalScores::average(&per_graph), per_graph)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::mini_circuitnet;
    use crate::engine::EngineBuilder;

    fn tiny_sets() -> (Dataset, Dataset) {
        mini_circuitnet(6, 0.02, 11)
    }

    fn fast_cfg() -> TrainConfig {
        TrainConfig {
            epochs: 8,
            lr: 5e-3,
            weight_decay: 0.0,
            hidden: 16,
            seed: 1,
            parallel: false,
            epoch_pipeline: false,
            window: WindowSpec::Off,
            checkpoint: false,
            log_every: 0,
        }
    }

    #[test]
    fn dr_training_reduces_loss_and_scores_populate() {
        let (train, test) = tiny_sets();
        let (_m, report) =
            Trainer::train_dr(&train, &test, &EngineBuilder::dr(4, 4), &fast_cfg());
        assert_eq!(report.epoch_losses.len(), 8);
        assert!(
            report.epoch_losses.last().unwrap() < &report.epoch_losses[0],
            "{:?}",
            report.epoch_losses
        );
        assert!(!report.per_graph_scores.is_empty());
        assert!(report.params > 0);
        assert!(report.test_scores.rmse.is_finite());
    }

    #[test]
    fn homo_training_works_for_gcn() {
        let (train, test) = tiny_sets();
        let (_m, report) = Trainer::train_homo(HomoKind::Gcn, &train, &test, &fast_cfg());
        assert!(report.epoch_losses.last().unwrap() < &report.epoch_losses[0]);
    }

    #[test]
    fn parallel_training_matches_sequential_losses() {
        let (train, test) = tiny_sets();
        let mut cfg = fast_cfg();
        cfg.epochs = 3;
        let (_m1, r1) = Trainer::train_dr(&train, &test, &EngineBuilder::dr(4, 4), &cfg);
        let mut cfg2 = cfg.clone();
        cfg2.parallel = true;
        let (_m2, r2) = Trainer::train_dr(&train, &test, &EngineBuilder::dr(4, 4), &cfg2);
        for (a, b) in r1.epoch_losses.iter().zip(&r2.epoch_losses) {
            assert!((a - b).abs() < 1e-9, "parallel changed numerics: {a} vs {b}");
        }
    }

    #[test]
    fn fleet_training_loss_curve_is_worker_count_invariant() {
        let (train, test) = tiny_sets();
        let mut cfg = fast_cfg();
        cfg.epochs = 3;
        let one = FleetSpec::parse("1").unwrap();
        let four = FleetSpec::parse("4").unwrap();
        let (_m1, r1) =
            Trainer::train_dr_fleet(&train, &test, &EngineBuilder::dr(4, 4), &cfg, &one);
        let (_m2, r2) =
            Trainer::train_dr_fleet(&train, &test, &EngineBuilder::dr(4, 4), &cfg, &four);
        assert_eq!(r1.epoch_losses.len(), 3);
        for (a, b) in r1.epoch_losses.iter().zip(&r2.epoch_losses) {
            assert!((a - b).abs() < 1e-9, "workers changed numerics: {a} vs {b}");
        }
        assert!(r1.test_scores.rmse.is_finite());
    }

    #[test]
    fn fleet_training_descends() {
        let (train, test) = tiny_sets();
        let spec = FleetSpec::parse("2x2").unwrap();
        let (_m, report) =
            Trainer::train_dr_fleet(&train, &test, &EngineBuilder::dr(4, 4), &fast_cfg(), &spec);
        assert!(
            report.epoch_losses.last().unwrap() < &report.epoch_losses[0],
            "{:?}",
            report.epoch_losses
        );
    }

    /// The epoch-pipelined fleet schedule must reproduce the serial fleet
    /// schedule bit for bit: same losses every epoch, same final weights.
    #[test]
    fn epoch_pipelined_fleet_matches_serial_fleet_bitwise() {
        let (train, test) = tiny_sets();
        let mut cfg = fast_cfg();
        cfg.epochs = 4;
        let spec = FleetSpec::parse("2x2").unwrap();
        let (mut serial_model, serial) =
            Trainer::train_dr_fleet(&train, &test, &EngineBuilder::dr(4, 4), &cfg, &spec);
        assert!(serial.epoch_overlap.is_empty(), "serial mode records no overlap");
        let mut piped_cfg = cfg.clone();
        piped_cfg.epoch_pipeline = true;
        let (mut piped_model, piped) =
            Trainer::train_dr_fleet(&train, &test, &EngineBuilder::dr(4, 4), &piped_cfg, &spec);
        assert_eq!(serial.epoch_losses, piped.epoch_losses, "losses must be bit-identical");
        assert_eq!(piped.epoch_overlap.len(), 4, "one overlap factor per epoch");
        // Overlap magnitude is timing-dependent (tiny test workloads are
        // dominated by wakeup latency) — the fig13 bench and the sched
        // tests assert the >1 overlap on real spans; here just sanity.
        assert!(piped.epoch_overlap.iter().all(|o| o.is_finite() && *o > 0.0));
        for (a, b) in serial_model
            .params_mut()
            .iter()
            .zip(piped_model.params_mut().iter())
        {
            assert_eq!(a.value.data, b.value.data, "parameters must be bit-identical");
        }
    }

    /// Under a starved budget the pipeline degenerates to the inline
    /// schedule — numerics must not move.
    #[test]
    fn epoch_pipelined_fleet_is_budget_invariant() {
        use crate::util::pool::Budget;
        let (train, test) = tiny_sets();
        let mut cfg = fast_cfg();
        cfg.epochs = 2;
        cfg.epoch_pipeline = true;
        let spec = FleetSpec::parse("4x2").unwrap();
        let (_, wide) =
            Trainer::train_dr_fleet(&train, &test, &EngineBuilder::dr(4, 4), &cfg, &spec);
        let (_, starved) = Budget::new(1).with(|| {
            Trainer::train_dr_fleet(&train, &test, &EngineBuilder::dr(4, 4), &cfg, &spec)
        });
        assert_eq!(wide.epoch_losses, starved.epoch_losses);
    }

    /// Window-sampled training must keep the fleet guarantees: losses and
    /// final parameters bit-identical for any worker count at a fixed
    /// sampling seed, identical across reruns, and identical between the
    /// serial and pipelined epoch schedules.
    #[test]
    fn window_training_is_worker_invariant_and_seed_deterministic() {
        let (train, test) = tiny_sets();
        let mut cfg = fast_cfg();
        cfg.epochs = 3;
        cfg.window = WindowSpec::parse("2x40").unwrap();
        let engine = EngineBuilder::dr(4, 4);
        let run = |spec: &str, pipelined: bool| {
            let mut c = cfg.clone();
            c.epoch_pipeline = pipelined;
            let spec = FleetSpec::parse(spec).unwrap();
            Trainer::train_dr_fleet(&train, &test, &engine, &c, &spec)
        };
        let (mut m1, r1) = run("1", false);
        assert_eq!(r1.epoch_losses.len(), 3);
        assert!(r1.epoch_losses.iter().all(|l| l.is_finite()));
        for (tag, (mut m, r)) in [
            ("workers=4", run("4", false)),
            ("rerun", run("1", false)),
            ("pipelined", run("1", true)),
        ] {
            assert_eq!(r1.epoch_losses, r.epoch_losses, "{tag}: losses diverge");
            for (a, b) in m1.params_mut().iter().zip(m.params_mut().iter()) {
                assert_eq!(a.value.data, b.value.data, "{tag}: params diverge");
            }
        }
    }

    /// A different sampling seed must actually change the windows (and the
    /// loss curve) — sampling is seeded, not frozen.
    #[test]
    fn window_training_varies_with_seed() {
        let (train, test) = tiny_sets();
        let mut cfg = fast_cfg();
        cfg.epochs = 2;
        cfg.window = WindowSpec::parse("2x40").unwrap();
        let spec = FleetSpec::parse("2").unwrap();
        let (_, r1) = Trainer::train_dr_fleet(&train, &test, &EngineBuilder::dr(4, 4), &cfg, &spec);
        let mut cfg2 = cfg.clone();
        cfg2.seed = 999;
        let (_, r2) =
            Trainer::train_dr_fleet(&train, &test, &EngineBuilder::dr(4, 4), &cfg2, &spec);
        assert_ne!(r1.epoch_losses, r2.epoch_losses, "seed must steer the sampled windows");
    }

    /// `--checkpoint on` must not move a single bit of the training
    /// trajectory, in full-graph and in window-sampled fleet mode.
    #[test]
    fn checkpointed_fleet_training_matches_default_bitwise() {
        let (train, test) = tiny_sets();
        let mut cfg = fast_cfg();
        cfg.epochs = 3;
        let spec = FleetSpec::parse("2x2").unwrap();
        let engine = EngineBuilder::dr(4, 4);
        for window in ["off", "2x40"] {
            cfg.window = WindowSpec::parse(window).unwrap();
            let (mut plain_model, plain) =
                Trainer::train_dr_fleet(&train, &test, &engine, &cfg, &spec);
            let mut ckpt_cfg = cfg.clone();
            ckpt_cfg.checkpoint = true;
            let (mut ckpt_model, ckpt) =
                Trainer::train_dr_fleet(&train, &test, &engine, &ckpt_cfg, &spec);
            assert_eq!(plain.epoch_losses, ckpt.epoch_losses, "window={window}");
            for (a, b) in plain_model.params_mut().iter().zip(ckpt_model.params_mut().iter()) {
                assert_eq!(a.value.data, b.value.data, "window={window}: params diverge");
            }
        }
    }

    #[test]
    fn cached_trainers_match_uncached_and_report_cache_stats() {
        let (train, test) = tiny_sets();
        let mut cfg = fast_cfg();
        cfg.epochs = 2;
        let engine = EngineBuilder::dr(4, 4);
        let (_, base) = Trainer::train_dr(&train, &test, &engine, &cfg);
        assert!(base.plan_cache.unique() > 0, "training must materialise engines");
        assert_eq!(base.plan_cache.disk_loads, 0, "no store configured");

        let spec = FleetSpec::parse("2").unwrap();
        let cache = Arc::new(PlanCache::new(engine.clone()));
        let (_, cached) =
            Trainer::train_dr_fleet_cached(&train, &test, &engine, &cfg, &spec, &cache);
        let (_, fresh) = Trainer::train_dr_fleet(&train, &test, &engine, &cfg, &spec);
        assert_eq!(cached.epoch_losses, fresh.epoch_losses);
        assert_eq!(cached.plan_cache, fresh.plan_cache);
        assert!(cached.plan_cache.unique() > 0);
    }

    #[test]
    #[should_panic(expected = "different engine configuration")]
    fn cached_trainer_rejects_mismatched_cache() {
        let (train, test) = tiny_sets();
        let cache = PlanCache::new(EngineBuilder::csr());
        let _ = Trainer::train_dr_cached(
            &train,
            &test,
            &EngineBuilder::dr(4, 4),
            &fast_cfg(),
            &cache,
        );
    }

    #[test]
    fn auto_engine_trains_end_to_end() {
        let (train, test) = tiny_sets();
        let mut cfg = fast_cfg();
        cfg.epochs = 3;
        let (_m, report) = Trainer::train_dr(&train, &test, &EngineBuilder::auto(), &cfg);
        assert!(report.epoch_losses.iter().all(|l| l.is_finite()));
    }
}
