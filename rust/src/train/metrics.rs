//! Evaluation metrics (paper §4.1): Pearson, Spearman, Kendall rank
//! correlations — the EDA-preferred rank metrics — plus MAE and RMSE.

/// Pearson correlation coefficient.
pub fn pearson(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 0.0;
    }
    let ma = a.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
    let mb = b.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
    let (mut cov, mut va, mut vb) = (0f64, 0f64, 0f64);
    for i in 0..n {
        let da = a[i] as f64 - ma;
        let db = b[i] as f64 - mb;
        cov += da * db;
        va += da * da;
        vb += db * db;
    }
    if va <= 0.0 || vb <= 0.0 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// Fractional ranks with ties averaged (the standard competition-free rank).
fn ranks(xs: &[f32]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| xs[i].partial_cmp(&xs[j]).unwrap_or(std::cmp::Ordering::Equal));
    let mut out = vec![0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            out[k] = avg;
        }
        i = j + 1;
    }
    out
}

/// Spearman rank correlation (Pearson over tie-averaged ranks).
pub fn spearman(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let ra: Vec<f32> = ranks(a).iter().map(|&x| x as f32).collect();
    let rb: Vec<f32> = ranks(b).iter().map(|&x| x as f32).collect();
    pearson(&ra, &rb)
}

/// Kendall tau-b via merge-sort inversion counting — O(n log n), with tie
/// corrections, matching scipy's `kendalltau`.
pub fn kendall(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 0.0;
    }
    // Sort by a (ties broken by b).
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| {
        a[i].partial_cmp(&a[j])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(b[i].partial_cmp(&b[j]).unwrap_or(std::cmp::Ordering::Equal))
    });
    let bs: Vec<f32> = idx.iter().map(|&i| b[i]).collect();
    let asrt: Vec<f32> = idx.iter().map(|&i| a[i]).collect();

    // Tie counts in a, in b, and joint.
    fn tie_sum(xs: &[f32]) -> (f64, f64) {
        // returns (Σ t(t-1)/2, count of groups) over tie groups
        let mut sorted = xs.to_vec();
        sorted.sort_by(|x, y| x.partial_cmp(y).unwrap_or(std::cmp::Ordering::Equal));
        let mut s = 0f64;
        let mut i = 0;
        while i < sorted.len() {
            let mut j = i;
            while j + 1 < sorted.len() && sorted[j + 1] == sorted[i] {
                j += 1;
            }
            let t = (j - i + 1) as f64;
            s += t * (t - 1.0) / 2.0;
            i = j + 1;
        }
        (s, 0.0)
    }
    let (tie_a, _) = tie_sum(a);
    let (tie_b, _) = tie_sum(b);
    // Joint ties (pairs tied in both).
    let mut joint = 0f64;
    {
        let mut i = 0;
        while i < n {
            let mut j = i;
            while j + 1 < n && asrt[j + 1] == asrt[i] {
                j += 1;
            }
            // Within an a-tie group, count b-ties.
            let (jt, _) = tie_sum(&bs[i..=j]);
            joint += jt;
            i = j + 1;
        }
    }

    // Count discordant pairs = inversions of bs restricted to strict a-order.
    // Standard trick: merge-sort inversions of bs counts pairs (i<j) with
    // bs[i] > bs[j]; pairs tied in a must be excluded — they were sorted by
    // b ascending within the group so they contribute no inversions.
    let mut arr: Vec<f32> = bs.clone();
    let mut buf = vec![0f32; n];
    let discordant = merge_count(&mut arr, &mut buf) as f64;

    let total = n as f64 * (n as f64 - 1.0) / 2.0;
    let concordant = total - discordant - tie_a - tie_b + joint;
    // tau-b
    let num = concordant - discordant;
    let den = ((total - tie_a) * (total - tie_b)).sqrt();
    if den <= 0.0 {
        return 0.0;
    }
    num / den
}

/// Merge sort counting strict inversions.
fn merge_count(a: &mut [f32], buf: &mut [f32]) -> u64 {
    let n = a.len();
    if n <= 1 {
        return 0;
    }
    let mid = n / 2;
    let (l, r) = a.split_at_mut(mid);
    let mut inv = merge_count(l, buf) + merge_count(r, buf);
    // merge
    let (mut i, mut j, mut k) = (0usize, 0usize, 0usize);
    while i < l.len() && j < r.len() {
        if l[i] <= r[j] {
            buf[k] = l[i];
            i += 1;
        } else {
            buf[k] = r[j];
            inv += (l.len() - i) as u64;
            j += 1;
        }
        k += 1;
    }
    while i < l.len() {
        buf[k] = l[i];
        i += 1;
        k += 1;
    }
    while j < r.len() {
        buf[k] = r[j];
        j += 1;
        k += 1;
    }
    a.copy_from_slice(&buf[..n]);
    inv
}

/// Mean absolute error.
pub fn mae(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).sum::<f64>() / a.len() as f64
}

/// Root mean squared error.
pub fn rmse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    (a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>() / a.len() as f64)
        .sqrt()
}

/// The Table-2 metric bundle.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EvalScores {
    pub pearson: f64,
    pub spearman: f64,
    pub kendall: f64,
    pub mae: f64,
    pub rmse: f64,
}

impl EvalScores {
    pub fn compute(pred: &[f32], target: &[f32]) -> EvalScores {
        EvalScores {
            pearson: pearson(pred, target),
            spearman: spearman(pred, target),
            kendall: kendall(pred, target),
            mae: mae(pred, target),
            rmse: rmse(pred, target),
        }
    }

    /// Average a set of per-design scores (how the paper reports Table 2).
    ///
    /// An empty slice is a loud error: it means eval ran over zero designs
    /// (an empty test split) and any reported numbers would be silent
    /// `default()` zeros masquerading as real correlations.
    pub fn average(scores: &[EvalScores]) -> EvalScores {
        assert!(
            !scores.is_empty(),
            "EvalScores::average over an empty slice — eval ran on zero designs \
             (the test split must be non-empty)"
        );
        let n = scores.len() as f64;
        EvalScores {
            pearson: scores.iter().map(|s| s.pearson).sum::<f64>() / n,
            spearman: scores.iter().map(|s| s.spearman).sum::<f64>() / n,
            kendall: scores.iter().map(|s| s.kendall).sum::<f64>() / n,
            mae: scores.iter().map(|s| s.mae).sum::<f64>() / n,
            rmse: scores.iter().map(|s| s.rmse).sum::<f64>() / n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_perfect_and_inverse() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [2.0f32, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-9);
        let c = [4.0f32, 3.0, 2.0, 1.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn pearson_constant_input_is_zero() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn spearman_monotone_nonlinear_is_one() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        let b = [1.0f32, 8.0, 27.0, 64.0, 125.0]; // cubic: same order
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn spearman_handles_ties() {
        let a = [1.0f32, 2.0, 2.0, 3.0];
        let b = [1.0f32, 2.0, 2.0, 3.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn kendall_known_value() {
        // scipy.stats.kendalltau([1,2,3,4],[1,2,4,3]) = 0.6666...
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [1.0f32, 2.0, 4.0, 3.0];
        assert!((kendall(&a, &b) - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn kendall_with_ties_matches_scipy() {
        // tau-b: C=5, D=0, tie_a=1 → 5/sqrt(5·6) = 0.912870929...
        // (matches scipy.stats.kendalltau([1,2,2,3],[1,3,2,4]))
        let a = [1.0f32, 2.0, 2.0, 3.0];
        let b = [1.0f32, 3.0, 2.0, 4.0];
        assert!((kendall(&a, &b) - 5.0 / 30f64.sqrt()).abs() < 1e-9, "{}", kendall(&a, &b));
    }

    #[test]
    fn kendall_reverse_is_minus_one() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        let b = [5.0f32, 4.0, 3.0, 2.0, 1.0];
        assert!((kendall(&a, &b) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn error_metrics() {
        let a = [1.0f32, 2.0];
        let b = [2.0f32, 4.0];
        assert!((mae(&a, &b) - 1.5).abs() < 1e-12);
        assert!((rmse(&a, &b) - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn bundle_and_average() {
        let s1 = EvalScores::compute(&[1.0, 2.0, 3.0], &[1.0, 2.0, 3.0]);
        assert!((s1.pearson - 1.0).abs() < 1e-9);
        assert_eq!(s1.mae, 0.0);
        let s2 = EvalScores { pearson: 0.0, spearman: 0.0, kendall: 0.0, mae: 1.0, rmse: 1.0 };
        let avg = EvalScores::average(&[s1, s2]);
        assert!((avg.pearson - 0.5).abs() < 1e-9);
        assert!((avg.mae - 0.5).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "empty slice")]
    fn average_of_nothing_is_a_loud_error() {
        EvalScores::average(&[]);
    }

    #[test]
    fn ranks_tie_averaging() {
        let r = ranks(&[10.0, 20.0, 20.0, 30.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }
}
