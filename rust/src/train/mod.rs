//! Training stack: metrics, trainer loops, and the §4.3 K-profiler.

pub mod kprofile;
pub mod metrics;
pub mod trainer;

pub use kprofile::{profile_optimal_k, KProfile};
pub use metrics::{kendall, mae, pearson, rmse, spearman, EvalScores};
pub use trainer::{TrainConfig, TrainReport, Trainer};
