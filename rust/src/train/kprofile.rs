//! Optimal-K profiling (paper §4.3).
//!
//! During preprocessing the paper measures DR-SpMM under each candidate
//! K ∈ {2, 4, 8, 16, 32, 64} (powers of two below the embedding width, to
//! keep warp partitions regular) for every subgraph, and applies the argmin
//! to end-to-end training. A one-time cost far below the training savings.
//!
//! The profiler drives the engine's [`DrKernel`] through its plan/execute
//! API: the plan (CSC + degree buckets) is built once per adjacency and
//! shared by every candidate K, exactly like a training run would.
//!
//! We profile time-to-solution of the forward+backward kernel pair, with a
//! small quality floor: candidates below `min_k` can be excluded by callers
//! that care about accuracy (Fig. 10 shows scores stable across K, so the
//! default profile is pure speed).

use crate::engine::{AggCache, DrKernel, KProfileRecord, SpmmKernel};
use crate::graph::{Csr, EdgeType, HeteroGraph};
use crate::sparse::drelu;
use crate::tensor::Matrix;
use crate::util::rng::Rng;
use crate::util::timer::time_it;
use std::sync::Arc;

/// Candidate K values (paper §4.3).
pub fn candidate_ks(dim: usize) -> Vec<usize> {
    [2usize, 4, 8, 16, 32, 64].iter().copied().filter(|&k| k <= dim).collect()
}

/// Profiling result for one subgraph.
#[derive(Clone, Debug)]
pub struct KProfile {
    pub edge: EdgeType,
    pub dim: usize,
    /// (k, median seconds fwd+bwd) per candidate.
    pub timings: Vec<(usize, f64)>,
    pub best_k: usize,
}

/// Profile one adjacency at one embedding width; `reps` timed repetitions.
pub fn profile_adj(
    adj: &Csr,
    edge: EdgeType,
    dim: usize,
    reps: usize,
    rng: &mut Rng,
) -> KProfile {
    let x = Matrix::randn(adj.cols, dim, 1.0, rng);
    let dy = Matrix::randn(adj.rows, dim, 1.0, rng);
    let kernel = DrKernel;
    // Plan once (Alg. 1 stage 1); shared across every candidate K.
    let plan = kernel.plan(adj.clone());
    let mut timings = Vec::new();
    for k in candidate_ks(dim) {
        let prep = Arc::new(drelu(&x, k));
        let cache = AggCache::Cbsr(prep.clone());
        // Warm-up once, then take the median of `reps`.
        let _ = kernel.forward(&plan, &x, Some(&prep));
        let mut samples = Vec::with_capacity(reps);
        for _ in 0..reps.max(1) {
            let (_, t_f) = time_it(|| kernel.forward(&plan, &x, Some(&prep)));
            let (_, t_b) = time_it(|| kernel.backward(&plan, &dy, &cache));
            samples.push(t_f + t_b);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        timings.push((k, samples[samples.len() / 2]));
    }
    let best_k = timings
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .map(|&(k, _)| k)
        .unwrap_or(2);
    KProfile { edge, dim, timings, best_k }
}

/// Profile all three edge types of a graph; returns (k_near, k_pins,
/// k_pinned) optima. Note pins/pinned share K with their source node type
/// in training (cell / net); this function reports per-edge optima which
/// the trainer maps to (k_cell, k_net).
pub fn profile_optimal_k(g: &HeteroGraph, dim: usize, reps: usize, seed: u64) -> [KProfile; 3] {
    let mut rng = Rng::new(seed);
    [
        profile_adj(&g.near, EdgeType::Near, dim, reps, &mut rng),
        profile_adj(&g.pins, EdgeType::Pins, dim, reps, &mut rng),
        profile_adj(&g.pinned, EdgeType::Pinned, dim, reps, &mut rng),
    ]
}

/// Package a graph's three per-edge profiles as the persistable record the
/// plan store reads and writes (`kprof-<adjhash>.txt`); the record owns
/// the K-selection rule ([`KProfileRecord::type_ks`]) so profiling runs
/// and warm loads resolve `auto` K values identically.
pub fn to_record(profiles: &[KProfile; 3]) -> KProfileRecord {
    KProfileRecord {
        dim: profiles[0].dim,
        edges: [
            (profiles[0].best_k, profiles[0].timings.clone()),
            (profiles[1].best_k, profiles[1].timings.clone()),
            (profiles[2].best_k, profiles[2].timings.clone()),
        ],
    }
}

/// Map the three per-edge optima to the two per-node-type Ks used by the
/// engine: cell-source edges are near & pins; net-source is pinned.
/// Delegates to [`KProfileRecord::type_ks`] — the single selection rule.
pub fn to_type_ks(profiles: &[KProfile; 3]) -> (usize, usize) {
    to_record(profiles).type_ks()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_graph() -> HeteroGraph {
        let mut rng = Rng::new(7);
        let spec = crate::datagen::GraphSpec {
            n_cells: 300,
            n_nets: 150,
            target_near: 9000,
            target_pins: 450,
            d_cell: 8,
            d_net: 8,
        };
        crate::datagen::generate_graph(&spec, 0, &mut rng)
    }

    #[test]
    fn candidates_respect_dim() {
        assert_eq!(candidate_ks(64), vec![2, 4, 8, 16, 32, 64]);
        assert_eq!(candidate_ks(16), vec![2, 4, 8, 16]);
        assert_eq!(candidate_ks(3), vec![2]);
    }

    #[test]
    fn profile_produces_all_candidates() {
        let g = small_graph();
        let mut rng = Rng::new(1);
        let p = profile_adj(&g.near, EdgeType::Near, 32, 1, &mut rng);
        assert_eq!(p.timings.len(), candidate_ks(32).len());
        assert!(candidate_ks(32).contains(&p.best_k));
        assert!(p.timings.iter().all(|&(_, t)| t > 0.0));
    }

    #[test]
    fn full_graph_profile_and_type_mapping() {
        let g = small_graph();
        let profiles = profile_optimal_k(&g, 16, 1, 3);
        assert_eq!(profiles[0].edge, EdgeType::Near);
        let (k_cell, k_net) = to_type_ks(&profiles);
        assert!(candidate_ks(16).contains(&k_cell));
        assert!(candidate_ks(16).contains(&k_net));
        // The persistable record carries the same data and rule.
        let rec = to_record(&profiles);
        assert_eq!(rec.dim, 16);
        assert_eq!(rec.edges[2].0, profiles[2].best_k);
        assert_eq!(rec.type_ks(), (k_cell, k_net));
    }
}
