//! The [`SpmmKernel`] trait and its three implementations (paper §3).
//!
//! The trait enforces a **plan/execute split**: [`SpmmKernel::plan`] performs
//! the per-graph precomputation a kernel needs — the CSC transpose every
//! backward pass traverses (Alg. 2 stage 1), the degree-bucket schedule of
//! DR-SpMM (Alg. 1 stage 1) and the neighbor groups of the GNNAdvisor
//! analog — exactly once per graph; [`SpmmKernel::forward`] and
//! [`SpmmKernel::backward`] take the cached [`KernelPlan`] and do no setup
//! work at all. Global [`plan_counters`] instrument plan construction so the
//! once-per-graph property is verifiable (see `fig12_breakdown` and
//! `tests/integration_engine.rs`).

use crate::graph::{Cbsr, Csc, Csr};
use crate::sparse::{
    dr_spmm, dr_spmm_bwd, spmm_bcsr, spmm_bcsr_bwd, spmm_csr, spmm_csr_bwd, spmm_ell,
    spmm_gnna_bwd_planned, spmm_gnna_planned, BlockSchedule, DegreeBuckets, EllLayout, GnnaConfig,
    NeighborGroups,
};
use crate::tensor::Matrix;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

static PLANS_BUILT: AtomicUsize = AtomicUsize::new(0);
static CSCS_BUILT: AtomicUsize = AtomicUsize::new(0);
static BUCKETS_BUILT: AtomicUsize = AtomicUsize::new(0);
static GROUPS_BUILT: AtomicUsize = AtomicUsize::new(0);
static ELLS_BUILT: AtomicUsize = AtomicUsize::new(0);
static BLOCKS_BUILT: AtomicUsize = AtomicUsize::new(0);
static REPAIRS_BUILT: AtomicUsize = AtomicUsize::new(0);

/// Snapshot of the process-wide plan-construction counters.
///
/// Take one snapshot before and one after a region and subtract with
/// [`PlanCounters::since`] to count how many plans (and which of their
/// expensive parts) were built inside it. This is how the "CSC + buckets
/// built once per graph, not once per layer per step" claim is asserted.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanCounters {
    /// Total [`KernelPlan`]s constructed.
    pub plans: usize,
    /// CSC transposes built (one per plan).
    pub cscs: usize,
    /// Degree-bucket schedules built (DR plans).
    pub buckets: usize,
    /// Neighbor-group schedules built (GNNA plans; counts fwd+bwd as one).
    pub groups: usize,
    /// ELL slot layouts built (ELL plans).
    pub ells: usize,
    /// Blocked-CSR schedules built (BCSR plans; counts fwd+bwd as one).
    pub blocks: usize,
    /// Plans *repaired* incrementally from an ECO delta
    /// ([`crate::engine::repair`]) instead of cold-built. Repairs bump this
    /// counter only — a delta replan region showing `repairs > 0` with
    /// `plans == 0` proves no cold build happened.
    pub repairs: usize,
}

impl PlanCounters {
    /// Counter deltas accumulated since an earlier snapshot.
    pub fn since(&self, earlier: &PlanCounters) -> PlanCounters {
        PlanCounters {
            plans: self.plans - earlier.plans,
            cscs: self.cscs - earlier.cscs,
            buckets: self.buckets - earlier.buckets,
            groups: self.groups - earlier.groups,
            ells: self.ells - earlier.ells,
            blocks: self.blocks - earlier.blocks,
            repairs: self.repairs - earlier.repairs,
        }
    }
}

/// Read the process-wide plan-construction counters.
pub fn plan_counters() -> PlanCounters {
    PlanCounters {
        plans: PLANS_BUILT.load(Ordering::Relaxed),
        cscs: CSCS_BUILT.load(Ordering::Relaxed),
        buckets: BUCKETS_BUILT.load(Ordering::Relaxed),
        groups: GROUPS_BUILT.load(Ordering::Relaxed),
        ells: ELLS_BUILT.load(Ordering::Relaxed),
        blocks: BLOCKS_BUILT.load(Ordering::Relaxed),
        repairs: REPAIRS_BUILT.load(Ordering::Relaxed),
    }
}

/// Record one incremental plan repair (called by [`crate::engine::repair`];
/// deliberately NOT any of the cold-build counters, so counter snapshots
/// can prove a replan region did repairs only).
pub(crate) fn count_plan_repair() {
    REPAIRS_BUILT.fetch_add(1, Ordering::Relaxed);
}

/// Per-graph, per-edge-type precomputed kernel state.
///
/// Owns the (already normalised) destination-major adjacency plus whatever
/// the owning kernel's `plan()` chose to precompute. Replaces the eager
/// everything-for-everyone `GraphCtx` the crate used before: a CSR-only
/// engine no longer pays for degree buckets it never reads.
#[derive(Clone, Debug)]
pub struct KernelPlan {
    /// Normalised adjacency (rows = destination nodes).
    pub adj: Csr,
    /// CSC form of `adj` — the backward traversal order (Alg. 2 stage 1).
    pub csc: Csc,
    /// Degree-bucket schedule (DR-SpMM's Alg. 1 stage 1).
    pub buckets: Option<DegreeBuckets>,
    /// GNNA-analog neighbor groups, forward and backward.
    pub gnna: Option<GnnaPlan>,
    /// Width-capped lossless ELL slot layout (ELL kernel forward).
    pub ell: Option<EllLayout>,
    /// Blocked-CSR row-block × feature-tile schedule (BCSR kernel).
    pub blocks: Option<BlockSchedule>,
}

/// The GNNA kernel's cached schedules: forward groups over the adjacency
/// and backward groups over its transpose. The backward runs straight over
/// the plan's CSC arrays (they *are* the transpose's CSR arrays), so no
/// second copy of the matrix is stored.
#[derive(Clone, Debug)]
pub struct GnnaPlan {
    pub fwd_groups: NeighborGroups,
    pub bwd_groups: NeighborGroups,
}

impl KernelPlan {
    /// Base plan: CSC transposition only (what every kernel's backward needs).
    pub fn base(adj: Csr) -> KernelPlan {
        let csc = adj.to_csc();
        PLANS_BUILT.fetch_add(1, Ordering::Relaxed);
        CSCS_BUILT.fetch_add(1, Ordering::Relaxed);
        KernelPlan { adj, csc, buckets: None, gnna: None, ell: None, blocks: None }
    }

    /// Add the DR-SpMM degree-bucket schedule.
    pub fn with_buckets(mut self) -> KernelPlan {
        self.buckets = Some(DegreeBuckets::build(&self.adj));
        BUCKETS_BUILT.fetch_add(1, Ordering::Relaxed);
        self
    }

    /// Add the GNNA neighbor-group schedules (forward + backward).
    pub fn with_gnna(mut self, cfg: &GnnaConfig) -> KernelPlan {
        let fwd_groups = NeighborGroups::build(&self.adj, cfg);
        // The CSC's indptr is the transpose's row pointer: grouping it
        // schedules the backward without materialising a second matrix.
        let bwd_groups = NeighborGroups::build_from_indptr(&self.csc.indptr, cfg);
        GROUPS_BUILT.fetch_add(1, Ordering::Relaxed);
        self.gnna = Some(GnnaPlan { fwd_groups, bwd_groups });
        self
    }

    /// Add the width-capped lossless ELL slot layout (ELL kernel forward).
    pub fn with_ell(mut self) -> KernelPlan {
        let width = EllLayout::capped_width(&self.adj);
        self.ell = Some(EllLayout::build(&self.adj, width));
        ELLS_BUILT.fetch_add(1, Ordering::Relaxed);
        self
    }

    /// Add the blocked-CSR row-block schedule (forward + backward).
    pub fn with_blocks(mut self) -> KernelPlan {
        self.blocks = Some(BlockSchedule::build(&self.adj, &self.csc));
        BLOCKS_BUILT.fetch_add(1, Ordering::Relaxed);
        self
    }
}

/// Forward-pass cache per aggregation. The CBSR is shared (`Arc`) between
/// the edges that consume the same node type's sparsified embedding.
#[derive(Clone, Debug)]
pub enum AggCache {
    None,
    Cbsr(Arc<Cbsr>),
}

/// A kernel's native backward output: the dense baselines produce a dense
/// `dX`, DR-SpMM produces the compressed gradient aligned with the forward
/// CBSR (Alg. 2). Callers that need dense call [`Gradient::into_dense`].
#[derive(Clone, Debug)]
pub enum Gradient {
    Dense(Matrix),
    Compressed(Cbsr),
}

impl Gradient {
    /// Decompress (no-op for already-dense gradients).
    pub fn into_dense(self) -> Matrix {
        match self {
            Gradient::Dense(m) => m,
            Gradient::Compressed(c) => c.to_dense(),
        }
    }
}

/// One SpMM kernel family behind the plan/execute split.
///
/// `forward` computes `Y = Ā · X` and `backward` computes `dX = Āᵀ · dY`,
/// both against a [`KernelPlan`] the same kernel built via `plan()`.
/// Implementations parallelize through [`crate::util::pool`], which sizes
/// every dispatch to the calling thread's ambient
/// [`crate::util::pool::Budget`] — a kernel running inside a fleet worker
/// or a §3.4 edge lane consumes that scope's thread share, never the whole
/// machine, and its output is bit-identical for any budget.
pub trait SpmmKernel: Send + Sync + std::fmt::Debug {
    /// Canonical registry name (`"csr"`, `"gnna"`, `"dr"`).
    fn name(&self) -> &'static str;

    /// Paper-facing display name (`"cuSPARSE"`, `"GNNA"`, `"DR-SpMM"`).
    fn display_name(&self) -> &'static str;

    /// Build the per-graph plan from a normalised adjacency (Alg. 1 stage 1).
    fn plan(&self, adj: Csr) -> KernelPlan;

    /// Whether `forward` consumes a D-ReLU-sparsified (CBSR) source.
    fn needs_sparsified(&self) -> bool {
        false
    }

    /// `Y = Ā · X`. `prep` carries the shared CBSR for sparsifying kernels
    /// (built once per node type per layer by `Engine::sparsify`); dense
    /// kernels ignore it. Returns the aggregate plus the backward cache.
    fn forward(
        &self,
        plan: &KernelPlan,
        x: &Matrix,
        prep: Option<&Arc<Cbsr>>,
    ) -> (Matrix, AggCache);

    /// `dX = Āᵀ · dY` in the kernel's native gradient representation.
    fn backward(&self, plan: &KernelPlan, dy: &Matrix, cache: &AggCache) -> Gradient;
}

/// cuSPARSE-analog baseline: row-parallel dense CSR SpMM.
#[derive(Clone, Copy, Debug, Default)]
pub struct CsrKernel;

impl SpmmKernel for CsrKernel {
    fn name(&self) -> &'static str {
        "csr"
    }

    fn display_name(&self) -> &'static str {
        "cuSPARSE"
    }

    fn plan(&self, adj: Csr) -> KernelPlan {
        KernelPlan::base(adj)
    }

    fn forward(
        &self,
        plan: &KernelPlan,
        x: &Matrix,
        _prep: Option<&Arc<Cbsr>>,
    ) -> (Matrix, AggCache) {
        (spmm_csr(&plan.adj, x), AggCache::None)
    }

    fn backward(&self, plan: &KernelPlan, dy: &Matrix, _cache: &AggCache) -> Gradient {
        Gradient::Dense(spmm_csr_bwd(&plan.csc, dy))
    }
}

/// GNNAdvisor-analog: neighbor-group SpMM with cached group schedules.
#[derive(Clone, Copy, Debug, Default)]
pub struct GnnaKernel {
    pub cfg: GnnaConfig,
}

impl GnnaKernel {
    pub fn new(cfg: GnnaConfig) -> GnnaKernel {
        GnnaKernel { cfg }
    }
}

impl SpmmKernel for GnnaKernel {
    fn name(&self) -> &'static str {
        "gnna"
    }

    fn display_name(&self) -> &'static str {
        "GNNA"
    }

    fn plan(&self, adj: Csr) -> KernelPlan {
        KernelPlan::base(adj).with_gnna(&self.cfg)
    }

    fn forward(
        &self,
        plan: &KernelPlan,
        x: &Matrix,
        _prep: Option<&Arc<Cbsr>>,
    ) -> (Matrix, AggCache) {
        let gp = plan.gnna.as_ref().expect("plan was not built by the GNNA kernel");
        (spmm_gnna_planned(&plan.adj, x, &self.cfg, &gp.fwd_groups), AggCache::None)
    }

    fn backward(&self, plan: &KernelPlan, dy: &Matrix, _cache: &AggCache) -> Gradient {
        let gp = plan.gnna.as_ref().expect("plan was not built by the GNNA kernel");
        Gradient::Dense(spmm_gnna_bwd_planned(&plan.csc, dy, &self.cfg, &gp.bwd_groups))
    }
}

/// The paper's kernel pair: D-ReLU-sparsified CBSR source, degree-bucketed
/// forward (Alg. 1) and index-reusing compressed backward (Alg. 2).
#[derive(Clone, Copy, Debug, Default)]
pub struct DrKernel;

impl SpmmKernel for DrKernel {
    fn name(&self) -> &'static str {
        "dr"
    }

    fn display_name(&self) -> &'static str {
        "DR-SpMM"
    }

    fn plan(&self, adj: Csr) -> KernelPlan {
        KernelPlan::base(adj).with_buckets()
    }

    fn needs_sparsified(&self) -> bool {
        true
    }

    fn forward(
        &self,
        plan: &KernelPlan,
        _x: &Matrix,
        prep: Option<&Arc<Cbsr>>,
    ) -> (Matrix, AggCache) {
        let compressed =
            prep.expect("DR kernel requires a D-ReLU sparsified source (Engine::sparsify)").clone();
        let buckets = plan.buckets.as_ref().expect("plan was not built by the DR kernel");
        let h = dr_spmm(&plan.adj, &compressed, buckets);
        (h, AggCache::Cbsr(compressed))
    }

    fn backward(&self, plan: &KernelPlan, dy: &Matrix, cache: &AggCache) -> Gradient {
        match cache {
            AggCache::Cbsr(fwd) => Gradient::Compressed(dr_spmm_bwd(&plan.csc, dy, fwd)),
            AggCache::None => panic!("DR backward requires the forward CBSR cache"),
        }
    }
}

/// Width-capped lossless ELL: dense slot layout with a branch-free inner
/// loop for low-variance degree profiles; edges past the cap run through
/// the overflow side-list, so no edge is ever dropped.
#[derive(Clone, Copy, Debug, Default)]
pub struct EllKernel;

impl SpmmKernel for EllKernel {
    fn name(&self) -> &'static str {
        "ell"
    }

    fn display_name(&self) -> &'static str {
        "ELLPACK"
    }

    fn plan(&self, adj: Csr) -> KernelPlan {
        KernelPlan::base(adj).with_ell()
    }

    fn forward(
        &self,
        plan: &KernelPlan,
        x: &Matrix,
        _prep: Option<&Arc<Cbsr>>,
    ) -> (Matrix, AggCache) {
        let ell = plan.ell.as_ref().expect("plan was not built by the ELL kernel");
        (spmm_ell(ell, x), AggCache::None)
    }

    fn backward(&self, plan: &KernelPlan, dy: &Matrix, _cache: &AggCache) -> Gradient {
        // The backward traversal is column-major either way; the SIMD'd
        // CSC walk is the natural transpose of the ELL forward.
        Gradient::Dense(spmm_csr_bwd(&plan.csc, dy))
    }
}

/// Blocked-CSR: nnz-balanced row blocks × feature-dim tiles keep hot `X`
/// rows cache-resident across a block. Bit-identical to the CSR baseline.
#[derive(Clone, Copy, Debug, Default)]
pub struct BcsrKernel;

impl SpmmKernel for BcsrKernel {
    fn name(&self) -> &'static str {
        "bcsr"
    }

    fn display_name(&self) -> &'static str {
        "Blocked-CSR"
    }

    fn plan(&self, adj: Csr) -> KernelPlan {
        KernelPlan::base(adj).with_blocks()
    }

    fn forward(
        &self,
        plan: &KernelPlan,
        x: &Matrix,
        _prep: Option<&Arc<Cbsr>>,
    ) -> (Matrix, AggCache) {
        let sched = plan.blocks.as_ref().expect("plan was not built by the BCSR kernel");
        (spmm_bcsr(&plan.adj, x, sched), AggCache::None)
    }

    fn backward(&self, plan: &KernelPlan, dy: &Matrix, _cache: &AggCache) -> Gradient {
        let sched = plan.blocks.as_ref().expect("plan was not built by the BCSR kernel");
        Gradient::Dense(spmm_bcsr_bwd(&plan.csc, dy, sched))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::drelu;
    use crate::util::math::assert_allclose;
    use crate::util::rng::Rng;

    fn random_csr(rows: usize, cols: usize, max_deg: usize, rng: &mut Rng) -> Csr {
        let mut t = Vec::new();
        for r in 0..rows {
            for _ in 0..rng.range(0, max_deg + 1) {
                t.push((r, rng.below(cols), rng.uniform(0.5, 1.5)));
            }
        }
        Csr::from_triplets(rows, cols, &t)
    }

    #[test]
    fn all_kernels_agree_on_dense_input() {
        let mut rng = Rng::new(1);
        let a = random_csr(30, 20, 5, &mut rng);
        let x = Matrix::randn(20, 12, 1.0, &mut rng);
        let kernels: Vec<Box<dyn SpmmKernel>> = vec![
            Box::new(CsrKernel),
            Box::new(GnnaKernel::new(GnnaConfig::default())),
            Box::new(EllKernel),
            Box::new(BcsrKernel),
        ];
        let reference = spmm_csr(&a, &x);
        for k in &kernels {
            let plan = k.plan(a.clone());
            let (y, _) = k.forward(&plan, &x, None);
            assert_allclose(&y.data, &reference.data, 1e-3, 1e-3);
        }
        // DR with k = D must also match.
        let dr = DrKernel;
        let plan = dr.plan(a.clone());
        let prep = Arc::new(drelu(&x, x.cols));
        let (y, cache) = dr.forward(&plan, &x, Some(&prep));
        assert_allclose(&y.data, &reference.data, 1e-3, 1e-3);
        // Backward parity (DR at full k is unmasked).
        let dy = Matrix::randn(30, 12, 1.0, &mut rng);
        let want = spmm_csr_bwd(&a.to_csc(), &dy);
        for k in &kernels {
            let plan = k.plan(a.clone());
            let got = k.backward(&plan, &dy, &AggCache::None).into_dense();
            assert_allclose(&got.data, &want.data, 1e-3, 1e-3);
        }
        let got = dr.backward(&plan, &dy, &cache).into_dense();
        assert_allclose(&got.data, &want.data, 1e-3, 1e-3);
    }

    #[test]
    fn plans_carry_only_what_each_kernel_needs() {
        let mut rng = Rng::new(2);
        let a = random_csr(10, 10, 3, &mut rng);
        let p_csr = CsrKernel.plan(a.clone());
        assert!(p_csr.buckets.is_none() && p_csr.gnna.is_none());
        let p_gnna = GnnaKernel::default().plan(a.clone());
        assert!(p_gnna.buckets.is_none() && p_gnna.gnna.is_some());
        let p_dr = DrKernel.plan(a.clone());
        assert!(p_dr.buckets.is_some() && p_dr.gnna.is_none());
        let p_ell = EllKernel.plan(a.clone());
        assert!(p_ell.ell.is_some() && p_ell.blocks.is_none() && p_ell.buckets.is_none());
        let p_bcsr = BcsrKernel.plan(a);
        assert!(p_bcsr.blocks.is_some() && p_bcsr.ell.is_none() && p_bcsr.gnna.is_none());
    }

    #[test]
    fn bcsr_is_bitwise_csr_through_the_trait() {
        let mut rng = Rng::new(5);
        let a = random_csr(40, 30, 6, &mut rng);
        let x = Matrix::randn(30, 20, 1.0, &mut rng);
        let dy = Matrix::randn(40, 20, 1.0, &mut rng);
        let csr_plan = CsrKernel.plan(a.clone());
        let bcsr_plan = BcsrKernel.plan(a);
        let (want, _) = CsrKernel.forward(&csr_plan, &x, None);
        let (got, _) = BcsrKernel.forward(&bcsr_plan, &x, None);
        assert_eq!(got.data, want.data);
        let want_dx = CsrKernel.backward(&csr_plan, &dy, &AggCache::None).into_dense();
        let got_dx = BcsrKernel.backward(&bcsr_plan, &dy, &AggCache::None).into_dense();
        assert_eq!(got_dx.data, want_dx.data);
    }

    #[test]
    fn ell_plan_is_lossless_even_with_hub_rows() {
        // One 40-neighbor hub among degree-2 rows: the capped width must
        // push the hub's tail into the overflow list, not drop it.
        let mut t: Vec<(usize, usize, f32)> =
            (0..40usize).map(|c| (0usize, c, 0.5f32)).collect();
        for r in 1..20usize {
            t.push((r, r, 1.0));
            t.push((r, r + 20, 1.0));
        }
        let a = Csr::from_triplets(20, 40, &t);
        let plan = EllKernel.plan(a.clone());
        let ell = plan.ell.as_ref().unwrap();
        assert!(ell.width < 40, "cap must not follow the hub (got {})", ell.width);
        assert!(ell.overflow_nnz() > 0);
        let x = Matrix::ones(40, 8);
        let (got, _) = EllKernel.forward(&plan, &x, None);
        let want = spmm_csr(&a, &x);
        crate::util::math::assert_allclose(&got.data, &want.data, 1e-6, 1e-6);
    }

    #[test]
    fn counters_track_plan_construction() {
        let mut rng = Rng::new(3);
        let a = random_csr(8, 8, 2, &mut rng);
        let before = plan_counters();
        let _p1 = CsrKernel.plan(a.clone());
        let _p2 = DrKernel.plan(a);
        let delta = plan_counters().since(&before);
        // Other tests run concurrently, so assert lower bounds only here;
        // the exact-count assertions live in tests/integration_engine.rs
        // behind a lock.
        assert!(delta.plans >= 2 && delta.cscs >= 2 && delta.buckets >= 1);
    }

    #[test]
    fn gnna_planned_backward_matches_ad_hoc() {
        let mut rng = Rng::new(4);
        let a = random_csr(12, 7, 4, &mut rng);
        let kernel = GnnaKernel::default();
        let plan = kernel.plan(a.clone());
        let dy = Matrix::randn(12, 9, 1.0, &mut rng);
        let got = kernel.backward(&plan, &dy, &AggCache::None).into_dense();
        let want = crate::sparse::spmm_gnna_bwd(&a.to_csc(), &dy, &kernel.cfg);
        assert_eq!(got.data, want.data);
    }

    #[test]
    #[should_panic(expected = "sparsified source")]
    fn dr_forward_without_prep_panics() {
        let a = Csr::from_triplets(2, 2, &[(0, 1, 1.0)]);
        let plan = DrKernel.plan(a);
        let x = Matrix::ones(2, 4);
        DrKernel.forward(&plan, &x, None);
    }
}
