//! The `"auto"` kernel policy — paper Fig. 4, programmatically.
//!
//! The paper's analysis shows the right SpMM kernel depends on the edge
//! type's degree profile: the `near` (cell↔cell) adjacency is dense-ish
//! (mode ≈ 50) with hub rows, while `pins`/`pinned` concentrate at degree
//! 2–4 with a power-law tail — where GNNAdvisor's fixed 32-slot neighbor
//! groups are mostly padding and its analog loses even to the cuSPARSE
//! baseline (Table 3). This module encodes that guidance as a decision
//! procedure over [`ImbalanceStats`], so `Engine::build` can pick a kernel
//! per edge type without a hand-written table.

use super::registry::KernelSpec;
use crate::graph::stats::ImbalanceStats;
use crate::graph::{Csr, EdgeType};
use crate::sparse::WARP_SIZE;

/// Minimum average degree for the GNNA analog to usefully fill its fixed
/// 32-slot neighbor groups. Below this most group slots are predicated
/// padding — the §2.3 under-utilisation that sinks GNNA on `pins`/`pinned`.
pub const GNNA_MIN_AVG_DEGREE: f64 = (WARP_SIZE / 4) as f64;

/// max/avg degree ratio above which a static row schedule tail-lags on
/// "evil rows" (§2.3) and DR-SpMM's degree-bucketed dynamic schedule wins.
pub const EVIL_ROW_IMBALANCE: f64 = 4.0;

/// Below this average degree even CBSR construction isn't amortised by the
/// per-edge k-sparse saving; plain row-parallel CSR is the cheapest choice.
pub const DR_MIN_AVG_DEGREE: f64 = 2.0;

/// max/avg degree ratio at or below which the profile is uniform enough
/// for ELL: the width cap (2× avg) covers every row, so the dense slot
/// loop is branch-free with bounded padding and an empty overflow list.
pub const ELL_MAX_IMBALANCE: f64 = 1.5;

/// Average degree from which blocked-CSR's row-block × feature-tile reuse
/// beats plain group scheduling on balanced-but-not-uniform rows: a warp's
/// worth of neighbors per row means each hot `X` row is re-read often
/// enough that keeping it cache-resident pays.
pub const BCSR_MIN_AVG_DEGREE: f64 = WARP_SIZE as f64;

/// One auto-selection outcome, with the rationale for logs and tables.
#[derive(Clone, Debug)]
pub struct AutoDecision {
    pub edge: EdgeType,
    pub spec: KernelSpec,
    pub reason: String,
}

/// Pick a concrete kernel for one edge type from its adjacency's degree
/// profile. Never returns [`KernelSpec::Auto`].
pub fn auto_select(adj: &Csr, edge: EdgeType) -> AutoDecision {
    let s = ImbalanceStats::of(adj);
    let (spec, reason) = if s.avg_degree < DR_MIN_AVG_DEGREE {
        (
            KernelSpec::Csr,
            format!(
                "avg degree {:.1} < {DR_MIN_AVG_DEGREE}: too sparse to amortise CBSR; \
                 row-parallel CSR",
                s.avg_degree
            ),
        )
    } else if s.avg_degree < GNNA_MIN_AVG_DEGREE {
        (
            KernelSpec::Dr,
            format!(
                "avg degree {:.1} < {GNNA_MIN_AVG_DEGREE}: GNNA groups would be mostly \
                 padding; DR buckets absorb the skew",
                s.avg_degree
            ),
        )
    } else if s.imbalance > EVIL_ROW_IMBALANCE {
        (
            KernelSpec::Dr,
            format!(
                "imbalance {:.1} > {EVIL_ROW_IMBALANCE}: evil rows need the \
                 degree-bucketed dynamic schedule",
                s.imbalance
            ),
        )
    } else if s.imbalance <= ELL_MAX_IMBALANCE {
        (
            KernelSpec::Ell,
            format!(
                "avg degree {:.1}, imbalance {:.1} <= {ELL_MAX_IMBALANCE}: low-variance \
                 dense profile; width-capped ELL padding is bounded and the dense \
                 slot loop is branch-free",
                s.avg_degree, s.imbalance
            ),
        )
    } else if s.avg_degree >= BCSR_MIN_AVG_DEGREE {
        (
            KernelSpec::Bcsr,
            format!(
                "avg degree {:.1} >= {BCSR_MIN_AVG_DEGREE}, imbalance {:.1}: wide \
                 balanced rows; row-block x feature-tile keeps hot X rows in cache",
                s.avg_degree, s.imbalance
            ),
        )
    } else {
        (
            KernelSpec::Gnna,
            format!(
                "avg degree {:.1}, imbalance {:.1}: dense balanced rows fill \
                 neighbor groups",
                s.avg_degree, s.imbalance
            ),
        )
    };
    AutoDecision { edge, spec, reason }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph_with_degrees(degs: &[usize]) -> Csr {
        let cols = *degs.iter().max().unwrap_or(&1) + 1;
        let mut t = Vec::new();
        for (r, &d) in degs.iter().enumerate() {
            for c in 0..d {
                t.push((r, c, 1.0));
            }
        }
        Csr::from_triplets(degs.len(), cols, &t)
    }

    #[test]
    fn near_empty_matrix_gets_csr() {
        let adj = graph_with_degrees(&[1, 1, 0, 1]);
        let d = auto_select(&adj, EdgeType::Pinned);
        assert_eq!(d.spec, KernelSpec::Csr, "{}", d.reason);
    }

    #[test]
    fn low_degree_pins_profile_never_gets_gnna() {
        // The pins/pinned profile: degrees 2–4 with a power-law tail.
        let adj = graph_with_degrees(&[2, 3, 2, 4, 3, 2, 2, 30]);
        let d = auto_select(&adj, EdgeType::Pins);
        assert_ne!(d.spec, KernelSpec::Gnna, "{}", d.reason);
        assert_eq!(d.spec, KernelSpec::Dr);
    }

    #[test]
    fn uniform_dense_rows_get_ell() {
        // Zero-variance degree profile: the ELL width cap covers every
        // row, so the branch-free dense loop wins.
        let adj = graph_with_degrees(&[40; 16]);
        let d = auto_select(&adj, EdgeType::Near);
        assert_eq!(d.spec, KernelSpec::Ell, "{}", d.reason);
        assert!(d.reason.contains("ELL") || d.reason.contains("low-variance"), "{}", d.reason);
    }

    #[test]
    fn dense_varied_rows_still_get_gnna() {
        // avg 16.25, max 30 → imbalance ≈ 1.85: too varied for ELL, too
        // narrow for BCSR, not skewed enough for DR buckets.
        let adj = graph_with_degrees(&[10, 20, 10, 20, 30, 10, 20, 10]);
        let d = auto_select(&adj, EdgeType::Near);
        assert_eq!(d.spec, KernelSpec::Gnna, "{}", d.reason);
    }

    #[test]
    fn wide_balanced_rows_get_bcsr() {
        // avg 65, max 100 → imbalance ≈ 1.54: past the ELL uniformity bar
        // but wide enough that cache tiling pays.
        let adj = graph_with_degrees(&[30, 100, 30, 100]);
        let d = auto_select(&adj, EdgeType::Near);
        assert_eq!(d.spec, KernelSpec::Bcsr, "{}", d.reason);
        assert!(d.reason.contains("cache"), "{}", d.reason);
    }

    #[test]
    fn dense_but_skewed_rows_get_dr() {
        // avg ≈ 33, max = 300: hub rows → dynamic buckets.
        let mut degs = vec![16; 18];
        degs.push(300);
        let adj = graph_with_degrees(&degs);
        let d = auto_select(&adj, EdgeType::Near);
        assert_eq!(d.spec, KernelSpec::Dr, "{}", d.reason);
    }

    #[test]
    fn decision_is_never_auto() {
        for degs in [&[0usize; 4][..], &[3; 8], &[50; 8], &[1, 100, 1, 1]] {
            let adj = graph_with_degrees(degs);
            assert_ne!(auto_select(&adj, EdgeType::Near).spec, KernelSpec::Auto);
        }
    }
}
