//! Kernel registry — the single place kernel-name strings are interpreted.
//!
//! Every surface that accepts a kernel name (the `--kernel` CLI flag, the
//! `kernel.kind` config key, bench environment knobs, the builder's
//! `kernel_for`) parses through [`KernelSpec::parse`], so the accepted
//! vocabulary and its aliases live in exactly one table: [`REGISTRY`].

use super::auto::auto_select;
use super::kernel::{BcsrKernel, CsrKernel, DrKernel, EllKernel, GnnaKernel, SpmmKernel};
use crate::graph::{Csr, EdgeType};
use crate::sparse::GnnaConfig;
use std::sync::Arc;

/// A parsed kernel selection. `Auto` is a *policy*, not a kernel: it
/// resolves to one of the concrete specs per edge type at `Engine::build`
/// time by inspecting the adjacency's degree profile (paper Fig. 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelSpec {
    /// cuSPARSE-analog baseline.
    Csr,
    /// GNNAdvisor analog.
    Gnna,
    /// D-ReLU + DR-SpMM (the paper's kernels).
    Dr,
    /// Width-capped lossless ELL (dense slots + overflow side-list).
    Ell,
    /// Blocked-CSR (row blocks × feature-dim tiles).
    Bcsr,
    /// Per-edge-type automatic selection from degree statistics.
    Auto,
}

impl KernelSpec {
    /// Every variant, in registry order — the exhaustiveness tests pair
    /// this with [`REGISTRY`] so a half-registered backend cannot land.
    pub const ALL: &'static [KernelSpec] = &[
        KernelSpec::Csr,
        KernelSpec::Gnna,
        KernelSpec::Dr,
        KernelSpec::Ell,
        KernelSpec::Bcsr,
        KernelSpec::Auto,
    ];
}

/// One registry row: canonical name, accepted aliases, one-line summary.
pub struct KernelEntry {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    pub summary: &'static str,
    pub spec: KernelSpec,
}

/// The kernel vocabulary. Order is the order help text lists them in.
pub const REGISTRY: &[KernelEntry] = &[
    KernelEntry {
        name: "csr",
        aliases: &["cusparse"],
        summary: "cuSPARSE-analog row-parallel dense SpMM",
        spec: KernelSpec::Csr,
    },
    KernelEntry {
        name: "gnna",
        aliases: &["gnnadvisor"],
        summary: "GNNAdvisor-analog neighbor-group SpMM",
        spec: KernelSpec::Gnna,
    },
    KernelEntry {
        name: "dr",
        aliases: &["drspmm", "dr-spmm"],
        summary: "D-ReLU sparsification + DR-SpMM (the paper's kernels)",
        spec: KernelSpec::Dr,
    },
    KernelEntry {
        name: "ell",
        aliases: &["ellpack"],
        summary: "width-capped lossless ELL: branch-free dense slots + overflow list",
        spec: KernelSpec::Ell,
    },
    KernelEntry {
        name: "bcsr",
        aliases: &["blocked-csr", "blockedcsr"],
        summary: "blocked-CSR: row blocks x feature tiles for L1/L2 reuse",
        spec: KernelSpec::Bcsr,
    },
    KernelEntry {
        name: "auto",
        aliases: &[],
        summary: "per-edge-type selection from degree statistics (Fig. 4)",
        spec: KernelSpec::Auto,
    },
];

/// Canonical kernel names, for help text and error messages.
pub fn known_names() -> Vec<&'static str> {
    REGISTRY.iter().map(|e| e.name).collect()
}

impl KernelSpec {
    /// Parse a kernel name or alias (case-insensitive). This is the only
    /// parse point in the crate.
    pub fn parse(s: &str) -> Result<KernelSpec, String> {
        let needle = s.trim().to_ascii_lowercase();
        for entry in REGISTRY {
            if entry.name == needle || entry.aliases.contains(&needle.as_str()) {
                return Ok(entry.spec);
            }
        }
        Err(format!(
            "unknown kernel '{s}' (expected one of: {})",
            known_names().join(", ")
        ))
    }

    /// Canonical registry name.
    pub fn name(&self) -> &'static str {
        match self {
            KernelSpec::Csr => "csr",
            KernelSpec::Gnna => "gnna",
            KernelSpec::Dr => "dr",
            KernelSpec::Ell => "ell",
            KernelSpec::Bcsr => "bcsr",
            KernelSpec::Auto => "auto",
        }
    }

    /// Paper-facing display name.
    pub fn display_name(&self) -> &'static str {
        match self {
            KernelSpec::Csr => "cuSPARSE",
            KernelSpec::Gnna => "GNNA",
            KernelSpec::Dr => "DR-SpMM",
            KernelSpec::Ell => "ELLPACK",
            KernelSpec::Bcsr => "Blocked-CSR",
            KernelSpec::Auto => "auto",
        }
    }
}

/// Instantiate a concrete kernel for one edge of a graph. `Auto` is
/// resolved against the adjacency's degree profile; the other specs map
/// directly to their constructor.
pub fn instantiate(
    spec: KernelSpec,
    edge: EdgeType,
    adj: &Csr,
    gnna: &GnnaConfig,
) -> Arc<dyn SpmmKernel> {
    let resolved = match spec {
        KernelSpec::Auto => auto_select(adj, edge).spec,
        concrete => concrete,
    };
    match resolved {
        KernelSpec::Csr => Arc::new(CsrKernel),
        KernelSpec::Gnna => Arc::new(GnnaKernel::new(*gnna)),
        KernelSpec::Dr => Arc::new(DrKernel),
        KernelSpec::Ell => Arc::new(EllKernel),
        KernelSpec::Bcsr => Arc::new(BcsrKernel),
        KernelSpec::Auto => unreachable!("auto_select returns a concrete spec"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_canonical_names_and_aliases() {
        assert_eq!(KernelSpec::parse("csr").unwrap(), KernelSpec::Csr);
        assert_eq!(KernelSpec::parse("cuSPARSE").unwrap(), KernelSpec::Csr);
        assert_eq!(KernelSpec::parse("GNNA").unwrap(), KernelSpec::Gnna);
        assert_eq!(KernelSpec::parse("gnnadvisor").unwrap(), KernelSpec::Gnna);
        assert_eq!(KernelSpec::parse("dr").unwrap(), KernelSpec::Dr);
        assert_eq!(KernelSpec::parse("DR-SpMM").unwrap(), KernelSpec::Dr);
        assert_eq!(KernelSpec::parse("drspmm").unwrap(), KernelSpec::Dr);
        assert_eq!(KernelSpec::parse(" auto ").unwrap(), KernelSpec::Auto);
    }

    #[test]
    fn parse_error_lists_known_names() {
        let err = KernelSpec::parse("???").unwrap_err();
        for name in known_names() {
            assert!(err.contains(name), "error must mention '{name}': {err}");
        }
    }

    #[test]
    fn every_entry_round_trips() {
        for entry in REGISTRY {
            assert_eq!(KernelSpec::parse(entry.name).unwrap(), entry.spec);
            assert_eq!(entry.spec.name(), entry.name);
            for alias in entry.aliases {
                assert_eq!(KernelSpec::parse(alias).unwrap(), entry.spec);
            }
        }
    }

    #[test]
    fn registry_is_exhaustive_over_kernel_specs() {
        // Every variant has exactly one registry row and vice versa, so a
        // half-registered backend (variant without a row, or a row whose
        // spec duplicates another's) cannot compile-and-pass.
        assert_eq!(REGISTRY.len(), KernelSpec::ALL.len());
        for spec in KernelSpec::ALL {
            let rows: Vec<_> = REGISTRY.iter().filter(|e| e.spec == *spec).collect();
            assert_eq!(rows.len(), 1, "{spec:?} must have exactly one registry row");
            assert_eq!(rows[0].name, spec.name());
        }
        // Names and aliases are globally unique across the table.
        let mut seen = std::collections::HashSet::new();
        for entry in REGISTRY {
            assert!(seen.insert(entry.name), "duplicate name '{}'", entry.name);
            for alias in entry.aliases {
                assert!(seen.insert(alias), "duplicate alias '{alias}'");
            }
        }
    }

    #[test]
    fn new_backends_parse_and_round_trip() {
        assert_eq!(KernelSpec::parse("ell").unwrap(), KernelSpec::Ell);
        assert_eq!(KernelSpec::parse("ELLPACK").unwrap(), KernelSpec::Ell);
        assert_eq!(KernelSpec::parse("bcsr").unwrap(), KernelSpec::Bcsr);
        assert_eq!(KernelSpec::parse("blocked-csr").unwrap(), KernelSpec::Bcsr);
        assert_eq!(KernelSpec::parse("blockedcsr").unwrap(), KernelSpec::Bcsr);
    }

    #[test]
    fn instantiate_concrete_specs() {
        let adj = Csr::from_triplets(2, 2, &[(0, 1, 1.0)]);
        let cfg = GnnaConfig::default();
        // Every concrete spec instantiates a kernel whose name round-trips
        // back through parse to the same spec.
        for &spec in KernelSpec::ALL.iter().filter(|s| **s != KernelSpec::Auto) {
            let k = instantiate(spec, EdgeType::Near, &adj, &cfg);
            assert_eq!(k.name(), spec.name());
            assert_eq!(KernelSpec::parse(k.name()).unwrap(), spec);
        }
        // Auto resolves to something concrete.
        let k = instantiate(KernelSpec::Auto, EdgeType::Pins, &adj, &cfg);
        assert_ne!(k.name(), "auto");
    }
}
