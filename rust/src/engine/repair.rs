//! Incremental [`KernelPlan`] repair: replan only what an ECO touched.
//!
//! A full [`EngineBuilder::build`] re-runs Alg. 1 stage 1 for every edge
//! type — CSC transpose, degree buckets, neighbor groups, ELL layout,
//! block schedule — even when an ECO edited a handful of rows.
//! [`EngineBuilder::repair`] takes the old engine plus the patch and
//! rebuilds **only touched structures**, in three escalating tiers per
//! edge type:
//!
//! 1. **Reuse** — the patch doesn't touch the edge type (or normalization
//!    erased the edit: both normalizations are structure-only, so a pure
//!    reweight changes nothing): the old plan is carried over by
//!    `Arc::clone`, zero bytes copied. Provable with `Arc::ptr_eq`.
//! 2. **Repair** — same kernel, some rows changed: the expensive per-nnz
//!    structures are *spliced* (CSC: only columns referenced by a dirty
//!    row are re-merged, clean columns are memcpy'd; ELL: only dirty rows'
//!    slot slabs and overflow segments are rewritten), and the cheap
//!    O(rows) schedules (degree buckets, neighbor groups, block bounds)
//!    are regenerated directly — deliberately *without* the cold-build
//!    counters, so [`plan_counters`] snapshots prove a repair region did
//!    `repairs > 0, plans == 0`.
//! 3. **Rebuild** — the builder now resolves a different kernel for the
//!    patched adjacency (an `auto` flip): cold `plan()`, counted as such.
//!
//! Every tier is bit-identical to `EngineBuilder::build` on the patched
//! graph — same arrays, same forward/backward outputs — asserted by
//! `tests/integration_delta.rs` across the whole kernel REGISTRY.

use super::kernel::{count_plan_repair, GnnaPlan, KernelPlan};
use super::{edge_index, normalized_adjacency, Engine, EngineBuilder};
use crate::graph::delta::DeltaPatch;
use crate::graph::{Csc, Csr, EdgeType, HeteroGraph};
use crate::sparse::{BlockSchedule, DegreeBuckets, EllLayout, NeighborGroups};
use std::sync::Arc;

/// What one [`EngineBuilder::repair`] call did, per structure. The
/// granularity proof: `plans_reused + plans_repaired + plans_rebuilt == 3`
/// always, and a small ECO shows `rows_dirty ≪ rows_total`,
/// `csc_cols_spliced ≪ csc_cols_copied`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RepairStats {
    /// Plans carried over untouched (`Arc::clone`, tier 1).
    pub plans_reused: usize,
    /// Plans incrementally repaired (tier 2).
    pub plans_repaired: usize,
    /// Plans cold-rebuilt because the resolved kernel changed (tier 3).
    pub plans_rebuilt: usize,
    /// Adjacency rows across repaired edge types.
    pub rows_total: usize,
    /// Rows whose normalized adjacency actually changed (bitwise).
    pub rows_dirty: usize,
    /// CSC columns copied wholesale from the old plan.
    pub csc_cols_copied: usize,
    /// CSC columns re-merged because a dirty row referenced them.
    pub csc_cols_spliced: usize,
    /// ELL rows whose dense slots/overflow were rewritten.
    pub ell_rows_spliced: usize,
    /// ELL layouts rebuilt in full (the capped width moved).
    pub ell_full_rebuilds: usize,
}

impl RepairStats {
    /// One-line summary for logs and the fig14 bench JSON.
    pub fn describe(&self) -> String {
        format!(
            "repair: {} reused / {} repaired / {} rebuilt plans; \
             {}/{} dirty rows; csc {} spliced / {} copied cols; \
             ell {} rows spliced, {} full rebuilds",
            self.plans_reused,
            self.plans_repaired,
            self.plans_rebuilt,
            self.rows_dirty,
            self.rows_total,
            self.csc_cols_spliced,
            self.csc_cols_copied,
            self.ell_rows_spliced,
            self.ell_full_rebuilds
        )
    }

    /// Field-wise sum (fleet ECO aggregates per-subgraph repairs).
    pub fn plus(&self, other: &RepairStats) -> RepairStats {
        RepairStats {
            plans_reused: self.plans_reused + other.plans_reused,
            plans_repaired: self.plans_repaired + other.plans_repaired,
            plans_rebuilt: self.plans_rebuilt + other.plans_rebuilt,
            rows_total: self.rows_total + other.rows_total,
            rows_dirty: self.rows_dirty + other.rows_dirty,
            csc_cols_copied: self.csc_cols_copied + other.csc_cols_copied,
            csc_cols_spliced: self.csc_cols_spliced + other.csc_cols_spliced,
            ell_rows_spliced: self.ell_rows_spliced + other.ell_rows_spliced,
            ell_full_rebuilds: self.ell_full_rebuilds + other.ell_full_rebuilds,
        }
    }
}

impl EngineBuilder {
    /// Repair `old` (built by this builder for the pre-patch graph) into
    /// an engine for the patched graph `g`, rebuilding only structures the
    /// patch touched. Bit-identical to `self.build(g)` in every array and
    /// every forward/backward output.
    ///
    /// `g` must be the *already patched* graph (`delta::apply` output) and
    /// `patch` the delta that produced it; node counts must be unchanged
    /// (a delta never grows a design).
    pub fn repair(
        &self,
        old: &Engine,
        g: &HeteroGraph,
        patch: &DeltaPatch,
    ) -> (Engine, RepairStats) {
        assert_eq!(
            (old.n_cells, old.n_nets),
            (g.n_cells, g.n_nets),
            "repair: node counts must be unchanged under a delta"
        );
        let mut stats = RepairStats::default();
        let mut kernels = Vec::with_capacity(3);
        let mut plans = Vec::with_capacity(3);
        for e in EdgeType::ALL {
            let i = edge_index(e);
            if !patch.touches(e) {
                kernels.push(Arc::clone(&old.kernels[i]));
                plans.push(Arc::clone(&old.plans[i]));
                stats.plans_reused += 1;
                continue;
            }
            let adj = normalized_adjacency(g, e);
            let kernel = self.resolve_kernel(e, &adj);
            if kernel.name() != old.kernels[i].name() {
                // The selection policy flipped under the new degree
                // profile — the old plan's payload is for another kernel.
                plans.push(Arc::new(kernel.plan(adj)));
                kernels.push(kernel);
                stats.plans_rebuilt += 1;
                continue;
            }
            let old_plan = &old.plans[i];
            let dirty = dirty_rows(&old_plan.adj, &adj);
            stats.rows_total += adj.rows;
            stats.rows_dirty += dirty.len();
            if dirty.is_empty() {
                // Normalization is structure-only; a pure reweight leaves
                // the normalized adjacency — hence the whole plan — intact.
                kernels.push(Arc::clone(&old.kernels[i]));
                plans.push(Arc::clone(&old.plans[i]));
                stats.plans_reused += 1;
                continue;
            }
            plans.push(Arc::new(self.repair_plan(old_plan, adj, &dirty, &mut stats)));
            kernels.push(kernel);
            stats.plans_repaired += 1;
            count_plan_repair();
        }
        let kernels: [_; 3] = kernels.try_into().expect("three edge types");
        let plans: [_; 3] = plans.try_into().expect("three edge types");
        (
            Engine {
                kernels,
                plans,
                k_cell: self.k_cell,
                k_net: self.k_net,
                parallel: self.parallel,
                n_cells: g.n_cells,
                n_nets: g.n_nets,
            },
            stats,
        )
    }

    /// Tier-2 repair of one plan: splice the per-nnz structures, regenerate
    /// the O(rows) schedules. Bypasses `KernelPlan::base`/`with_*` on
    /// purpose — repairs must not register as cold builds.
    fn repair_plan(
        &self,
        old: &KernelPlan,
        adj: Csr,
        dirty: &[usize],
        stats: &mut RepairStats,
    ) -> KernelPlan {
        let csc = splice_csc(&old.adj, &old.csc, &adj, dirty, stats);
        let buckets = old
            .buckets
            .as_ref()
            .map(|b| DegreeBuckets::build_with(&adj, b.t_low, b.t_high));
        let gnna = old.gnna.as_ref().map(|_| GnnaPlan {
            fwd_groups: NeighborGroups::build(&adj, &self.gnna),
            bwd_groups: NeighborGroups::build_from_indptr(&csc.indptr, &self.gnna),
        });
        let ell = old.ell.as_ref().map(|e| splice_ell(e, &adj, dirty, stats));
        let blocks = old.blocks.as_ref().map(|_| BlockSchedule::build(&adj, &csc));
        KernelPlan { adj, csc, buckets, gnna, ell, blocks }
    }
}

/// Rows whose normalized adjacency changed, bitwise (value comparison via
/// `to_bits`, so even a `-0.0` → `+0.0` flip counts), ascending.
pub fn dirty_rows(old: &Csr, new: &Csr) -> Vec<usize> {
    assert_eq!((old.rows, old.cols), (new.rows, new.cols), "dirty_rows: shape changed");
    (0..old.rows)
        .filter(|&r| {
            let a = old.row_range(r);
            let b = new.row_range(r);
            old.indices[a.clone()] != new.indices[b.clone()]
                || old.values[a]
                    .iter()
                    .zip(&new.values[b])
                    .any(|(x, y)| x.to_bits() != y.to_bits())
        })
        .collect()
}

/// Splice a CSC: columns untouched by any dirty row are copied wholesale;
/// a touched column re-merges its old entries from clean rows with the
/// dirty rows' new entries, in ascending row order — exactly the order
/// [`Csr::to_csc`] produces, so the result is bit-identical to a cold
/// transpose of `new_adj`.
fn splice_csc(
    old_adj: &Csr,
    old_csc: &Csc,
    new_adj: &Csr,
    dirty: &[usize],
    stats: &mut RepairStats,
) -> Csc {
    let cols = new_adj.cols;
    let mut dirty_row = vec![false; new_adj.rows];
    let mut col_dirty = vec![false; cols];
    for &r in dirty {
        dirty_row[r] = true;
        for p in old_adj.row_range(r) {
            col_dirty[old_adj.indices[p] as usize] = true;
        }
        for p in new_adj.row_range(r) {
            col_dirty[new_adj.indices[p] as usize] = true;
        }
    }
    // Dirty rows' new entries, bucketed per column; ascending row order is
    // inherited from iterating `dirty` ascending.
    let mut added: Vec<Vec<(u32, f32)>> = vec![Vec::new(); cols];
    for &r in dirty {
        for p in new_adj.row_range(r) {
            added[new_adj.indices[p] as usize].push((r as u32, new_adj.values[p]));
        }
    }

    let mut indptr = vec![0usize; cols + 1];
    let mut indices = Vec::with_capacity(new_adj.nnz());
    let mut values = Vec::with_capacity(new_adj.nnz());
    for c in 0..cols {
        if !col_dirty[c] {
            let range = old_csc.indptr[c]..old_csc.indptr[c + 1];
            indices.extend_from_slice(&old_csc.indices[range.clone()]);
            values.extend_from_slice(&old_csc.values[range]);
            stats.csc_cols_copied += 1;
        } else {
            let (mut q, end) = (old_csc.indptr[c], old_csc.indptr[c + 1]);
            let add = &added[c];
            let mut ai = 0;
            loop {
                // Old entries from dirty rows are superseded by `add`.
                while q < end && dirty_row[old_csc.indices[q] as usize] {
                    q += 1;
                }
                match (q < end, ai < add.len()) {
                    (false, false) => break,
                    (true, false) => {
                        indices.push(old_csc.indices[q]);
                        values.push(old_csc.values[q]);
                        q += 1;
                    }
                    (false, true) => {
                        indices.push(add[ai].0);
                        values.push(add[ai].1);
                        ai += 1;
                    }
                    (true, true) => {
                        // Distinct rows by construction (clean vs dirty).
                        if old_csc.indices[q] < add[ai].0 {
                            indices.push(old_csc.indices[q]);
                            values.push(old_csc.values[q]);
                            q += 1;
                        } else {
                            indices.push(add[ai].0);
                            values.push(add[ai].1);
                            ai += 1;
                        }
                    }
                }
            }
            stats.csc_cols_spliced += 1;
        }
        indptr[c + 1] = indices.len();
    }
    Csc { rows: new_adj.rows, cols, indptr, indices, values }
}

/// Splice an ELL layout: if the capped width moved, a full rebuild is
/// unavoidable (every row's slab shifts); otherwise only dirty rows'
/// dense slabs and overflow segments are rewritten — matching
/// [`EllLayout::build`] bit-for-bit (padding slots are `idx 0 / val 0.0`).
fn splice_ell(old: &EllLayout, new_adj: &Csr, dirty: &[usize], stats: &mut RepairStats) -> EllLayout {
    let width = EllLayout::capped_width(new_adj);
    if width != old.width {
        stats.ell_full_rebuilds += 1;
        return EllLayout::build(new_adj, width);
    }
    let rows = new_adj.rows;
    let mut dirty_row = vec![false; rows];
    for &r in dirty {
        dirty_row[r] = true;
    }
    let mut idx = old.idx.clone();
    let mut val = old.val.clone();
    let mut ofl_indptr = Vec::with_capacity(rows + 1);
    let mut ofl_indices = Vec::new();
    let mut ofl_values = Vec::new();
    ofl_indptr.push(0);
    for r in 0..rows {
        if !dirty_row[r] {
            let range = old.ofl_indptr[r]..old.ofl_indptr[r + 1];
            ofl_indices.extend_from_slice(&old.ofl_indices[range.clone()]);
            ofl_values.extend_from_slice(&old.ofl_values[range]);
        } else {
            idx[r * width..(r + 1) * width].fill(0);
            val[r * width..(r + 1) * width].fill(0.0);
            for (slot, p) in new_adj.row_range(r).enumerate() {
                if slot < width {
                    idx[r * width + slot] = new_adj.indices[p];
                    val[r * width + slot] = new_adj.values[p];
                } else {
                    ofl_indices.push(new_adj.indices[p]);
                    ofl_values.push(new_adj.values[p]);
                }
            }
            stats.ell_rows_spliced += 1;
        }
        ofl_indptr.push(ofl_indices.len());
    }
    EllLayout {
        rows,
        cols: new_adj.cols,
        width,
        idx,
        val,
        ofl_indptr,
        ofl_indices,
        ofl_values,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::delta::DeltaPatch;
    use crate::tensor::Matrix;

    fn toy_graph() -> HeteroGraph {
        let near = Csr::from_triplets(
            4,
            4,
            &[(0, 1, 1.0), (1, 0, 1.0), (1, 2, 1.0), (2, 1, 1.0), (2, 3, 1.0), (3, 2, 1.0)],
        );
        let pins = Csr::from_triplets(
            3,
            4,
            &[(0, 0, 1.0), (0, 1, 1.0), (1, 1, 1.0), (1, 2, 1.0), (2, 2, 1.0), (2, 3, 1.0)],
        );
        let pinned = pins.transpose();
        HeteroGraph {
            id: 0,
            n_cells: 4,
            n_nets: 3,
            near,
            pins,
            pinned,
            x_cell: Matrix::from_fn(4, 6, |r, c| ((r * 6 + c) as f32) / 10.0 - 1.0),
            x_net: Matrix::from_fn(3, 6, |r, c| ((r * 6 + c) as f32) / 8.0 - 1.0),
            y_cell: Matrix::zeros(4, 1),
        }
    }

    fn assert_plans_bit_identical(a: &Engine, b: &Engine) {
        for e in EdgeType::ALL {
            let (pa, pb) = (a.plan(e), b.plan(e));
            assert_eq!(pa.adj, pb.adj, "{e:?} adj");
            assert_eq!(pa.csc.indptr, pb.csc.indptr, "{e:?} csc indptr");
            assert_eq!(pa.csc.indices, pb.csc.indices, "{e:?} csc indices");
            assert_eq!(
                pa.csc.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                pb.csc.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{e:?} csc values"
            );
            match (&pa.buckets, &pb.buckets) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    assert_eq!(x.order, y.order, "{e:?} bucket order");
                    assert_eq!((x.low, x.medium, x.high), (y.low, y.medium, y.high));
                    assert_eq!((x.t_low, x.t_high), (y.t_low, y.t_high));
                }
                _ => panic!("{e:?}: bucket presence differs"),
            }
            match (&pa.gnna, &pb.gnna) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    assert_eq!(x.fwd_groups.export(), y.fwd_groups.export(), "{e:?} fwd groups");
                    assert_eq!(x.bwd_groups.export(), y.bwd_groups.export(), "{e:?} bwd groups");
                }
                _ => panic!("{e:?}: gnna presence differs"),
            }
            assert_eq!(pa.ell, pb.ell, "{e:?} ell");
            assert_eq!(pa.blocks, pb.blocks, "{e:?} blocks");
        }
    }

    #[test]
    fn repair_matches_cold_build_for_every_registry_kernel() {
        let g = toy_graph();
        let patch = DeltaPatch::new()
            .add_edge(EdgeType::Near, 0, 3, 0.5)
            .remove_edge(EdgeType::Near, 1, 2)
            .remove_edge(EdgeType::Pins, 0, 1)
            .add_edge(EdgeType::Pins, 0, 3, 1.0);
        let patched = patch.apply(&g).unwrap();
        for entry in crate::engine::REGISTRY {
            let builder = Engine::builder().kernel(entry.name).k_cell(3).k_net(3);
            let old = builder.build(&g);
            let (repaired, stats) = builder.repair(&old, &patched, &patch);
            let cold = builder.build(&patched);
            assert_plans_bit_identical(&repaired, &cold);
            assert_eq!(
                stats.plans_reused + stats.plans_repaired + stats.plans_rebuilt,
                3,
                "{}: every edge type accounted for",
                entry.name
            );
            if entry.spec != crate::engine::KernelSpec::Auto {
                // (auto may legitimately flip kernels → rebuilt tier.)
                assert!(stats.rows_dirty > 0, "{}: {stats:?}", entry.name);
            }
            // Forward outputs are bitwise equal too.
            for e in EdgeType::ALL {
                let x = patched.src_features(e);
                let prep_r = repaired.sparsify(x, e.endpoints().0);
                let prep_c = cold.sparsify(x, e.endpoints().0);
                let (yr, _) = repaired.aggregate_with(e, x, prep_r.as_ref());
                let (yc, _) = cold.aggregate_with(e, x, prep_c.as_ref());
                assert_eq!(yr.data, yc.data, "{}/{e:?}", entry.name);
            }
        }
    }

    #[test]
    fn untouched_edges_share_the_old_plan_by_pointer() {
        let g = toy_graph();
        let patch = DeltaPatch::new().add_edge(EdgeType::Near, 0, 2, 0.25);
        let patched = patch.apply(&g).unwrap();
        let builder = Engine::builder().kernel("dr").k_cell(3).k_net(3);
        let old = builder.build(&g);
        let (repaired, stats) = builder.repair(&old, &patched, &patch);
        assert!(Arc::ptr_eq(repaired.plan_shared(EdgeType::Pins), old.plan_shared(EdgeType::Pins)));
        assert!(Arc::ptr_eq(
            repaired.plan_shared(EdgeType::Pinned),
            old.plan_shared(EdgeType::Pinned)
        ));
        assert!(!Arc::ptr_eq(
            repaired.plan_shared(EdgeType::Near),
            old.plan_shared(EdgeType::Near)
        ));
        assert_eq!((stats.plans_reused, stats.plans_repaired, stats.plans_rebuilt), (2, 1, 0));
        assert_plans_bit_identical(&repaired, &builder.build(&patched));
    }

    #[test]
    fn reweight_only_patches_reuse_every_plan() {
        // Both normalizations are structure-only, so a pure reweight
        // leaves all three normalized adjacencies bit-identical.
        let g = toy_graph();
        let patch = DeltaPatch::new()
            .reweight_edge(EdgeType::Near, 0, 1, 5.0)
            .reweight_edge(EdgeType::Pins, 1, 2, 0.5);
        let patched = patch.apply(&g).unwrap();
        let builder = Engine::builder().kernel("csr");
        let old = builder.build(&g);
        let (repaired, stats) = builder.repair(&old, &patched, &patch);
        for e in EdgeType::ALL {
            assert!(Arc::ptr_eq(repaired.plan_shared(e), old.plan_shared(e)), "{e:?}");
        }
        assert_eq!(stats.plans_reused, 3);
        assert_eq!(stats.plans_repaired, 0);
    }

    #[test]
    fn dirty_rows_is_bitwise() {
        let a = Csr::from_triplets(3, 3, &[(0, 1, 1.0), (1, 2, 2.0)]);
        assert!(dirty_rows(&a, &a.clone()).is_empty());
        let b = Csr::from_triplets(3, 3, &[(0, 1, 1.0), (1, 2, 2.5)]);
        assert_eq!(dirty_rows(&a, &b), vec![1]);
        let c = Csr::from_triplets(3, 3, &[(0, 2, 1.0), (1, 2, 2.0)]);
        assert_eq!(dirty_rows(&a, &c), vec![0]);
        // −0.0 vs +0.0 compare equal as f32 but differ in bits — the
        // detector must flag the row (canonical matrices never hold zeros,
        // but the contract is bitwise, not approximate).
        let p = Csr { rows: 1, cols: 1, indptr: vec![0, 1], indices: vec![0], values: vec![1.0] };
        let mut q = p.clone();
        q.values[0] = f32::from_bits(p.values[0].to_bits() ^ 0x8000_0000);
        assert_eq!(dirty_rows(&p, &q), vec![0]);
    }

    #[test]
    fn splice_csc_handles_emptied_and_new_columns() {
        // Remove row 1's only entry and give row 0 a new column.
        let old = Csr::from_triplets(3, 4, &[(0, 0, 1.0), (1, 3, 2.0), (2, 0, 3.0)]);
        let new = Csr::from_triplets(3, 4, &[(0, 0, 1.0), (0, 2, 4.0), (2, 0, 3.0)]);
        let dirty = dirty_rows(&old, &new);
        assert_eq!(dirty, vec![0, 1]);
        let mut stats = RepairStats::default();
        let spliced = splice_csc(&old, &old.to_csc(), &new, &dirty, &mut stats);
        let want = new.to_csc();
        assert_eq!(spliced.indptr, want.indptr);
        assert_eq!(spliced.indices, want.indices);
        assert_eq!(spliced.values, want.values);
        assert!(stats.csc_cols_spliced >= 2 && stats.csc_cols_copied >= 1, "{stats:?}");
    }
}
