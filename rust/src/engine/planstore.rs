//! Persistent plan store: versioned on-disk [`KernelPlan`]s (ROADMAP item 1).
//!
//! Alg. 1 stage 1 (CSC transposition, DR degree bucketing, GNNA neighbor
//! grouping) is pure preprocessing, yet an in-memory
//! [`PlanCache`](crate::fleet::PlanCache) dies with the process and every
//! restart re-pays it for every design. The store serializes each planned
//! [`Engine`] next to the generated datasets, keyed by
//! [`HeteroGraph::adjacency_hash`] **and** the full
//! [`EngineBuilder`] configuration signature, so a warm process performs
//! zero plan builds for designs it has seen before.
//!
//! Format and trust rules:
//!
//! * One file per (adjacency, configuration):
//!   `plan-<adjhash>-<sighash>.plan`. Little-endian, magic `DRCGPLAN`,
//!   a format version, the builder's explicit versioned signature
//!   ([`EngineBuilder::signature`]) verbatim, the three per-edge records
//!   (resolved kernel name, normalised CSR, CSC, optional degree buckets,
//!   optional neighbor groups, optional ELL layout, optional block
//!   schedule), and a trailing FNV-1a checksum over everything before it.
//! * Any mismatch — magic, version, signature, adjacency hash, checksum,
//!   structural invariants, or a kernel name that no longer matches what
//!   the builder resolves for that adjacency — is a **loud error**: the
//!   caller logs it and rebuilds cold. A stored plan is never silently
//!   trusted.
//! * Loading reconstructs plans by struct literal and does **not** touch
//!   the global [`plan_counters`](crate::engine::plan_counters) — warm
//!   starts are observable as zero plan builds.
//!
//! The store also persists §4.3 K profiles (`kprof-<adjhash>.txt`): when the
//! builder uses the `auto` kernel policy and a measured profile exists for a
//! design, [`PlanStore::effective_builder`] substitutes the measured
//! per-node-type K optima for the Fig. 4 threshold guess — applied
//! identically on cold builds and warm loads so both paths stay
//! bit-identical. See `docs/SERVE.md` for versioning rules.

use super::{edge_index, Engine, EngineBuilder, GnnaPlan, KernelPlan, KernelSpec};
use crate::graph::csr::{fnv_mix, FNV_OFFSET};
use crate::graph::{Csc, Csr, EdgeType, HeteroGraph};
use crate::sparse::{BlockSchedule, DegreeBuckets, EllLayout, NeighborGroups};
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

const MAGIC: &[u8; 8] = b"DRCGPLAN";
/// v1: csr/gnna/dr payloads keyed by the builder's `Debug` string.
/// v2: explicit [`EngineBuilder::signature`] keys + ELL layout and
/// blocked-CSR schedule payloads. v1 files are rejected loudly (the caller
/// rebuilds cold and overwrites them).
const VERSION: u32 = 2;
const PROFILE_MAGIC: &str = "DRCGKPROF v1";

/// Unique suffix for temp files so concurrent writers never collide.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// A directory of serialized plans for one engine configuration.
///
/// The signature is [`EngineBuilder::signature`] — an explicit versioned
/// rendering of the semantically relevant builder state (never the `Debug`
/// derive, whose field drift would silently invalidate or alias stores) —
/// so plans built under different kernel choices, K values, GNNA
/// parameters or schedule modes can never be confused, even in a shared
/// directory.
pub struct PlanStore {
    dir: PathBuf,
    signature: String,
}

impl PlanStore {
    /// Open (creating if needed) a plan store rooted at `dir` for plans
    /// built by `builder`.
    pub fn open(dir: &Path, builder: &EngineBuilder) -> Result<PlanStore, String> {
        fs::create_dir_all(dir)
            .map_err(|e| format!("plan store: cannot create {}: {e}", dir.display()))?;
        Ok(PlanStore { dir: dir.to_path_buf(), signature: builder.signature() })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn signature(&self) -> &str {
        &self.signature
    }

    /// Path of the plan file for an adjacency hash under this configuration.
    pub fn plan_path(&self, adj_hash: u64) -> PathBuf {
        let sig_hash = hash_bytes(self.signature.as_bytes());
        self.dir.join(format!("plan-{adj_hash:016x}-{sig_hash:016x}.plan"))
    }

    /// Path of the §4.3 K-profile file for an adjacency hash. Profiles are
    /// configuration-independent (they measure the adjacency), so the name
    /// carries no signature hash.
    pub fn profile_path(&self, adj_hash: u64) -> PathBuf {
        self.dir.join(format!("kprof-{adj_hash:016x}.txt"))
    }

    /// The builder a cold build *or* a warm load should plan with: when the
    /// configuration resolves kernels automatically and a measured K profile
    /// exists for this design, the measured per-node-type optima replace the
    /// configured K values. Profile read errors are logged and ignored (the
    /// threshold guess still works).
    pub fn effective_builder(&self, builder: &EngineBuilder, g: &HeteroGraph) -> EngineBuilder {
        let uses_auto = EdgeType::ALL.iter().any(|&e| builder.spec_for(e) == KernelSpec::Auto);
        if !uses_auto {
            return builder.clone();
        }
        match self.load_profile(g.adjacency_hash()) {
            Ok(Some(rec)) => {
                let (k_cell, k_net) = rec.type_ks();
                crate::debug!(
                    "plan store: applying measured K profile for {:016x}: k_cell={} k_net={}",
                    g.adjacency_hash(),
                    k_cell,
                    k_net
                );
                builder.clone().k_cell(k_cell).k_net(k_net)
            }
            Ok(None) => builder.clone(),
            Err(e) => {
                crate::warn!("plan store: ignoring unreadable K profile: {e}");
                builder.clone()
            }
        }
    }

    /// Serialize a planned engine for `g`. Writes to a temp file and
    /// renames, so readers never observe a half-written plan.
    pub fn store(&self, g: &HeteroGraph, engine: &Engine) -> Result<PathBuf, String> {
        let adj_hash = g.adjacency_hash();
        let mut w = Writer::new();
        w.bytes(MAGIC);
        w.u32(VERSION);
        w.blob(self.signature.as_bytes());
        w.u64(adj_hash);
        w.u64(g.n_cells as u64);
        w.u64(g.n_nets as u64);
        for e in EdgeType::ALL {
            let i = edge_index(e);
            w.blob(engine.kernels[i].name().as_bytes());
            let plan = &engine.plans[i];
            write_csr(&mut w, &plan.adj);
            write_csc(&mut w, &plan.csc);
            match &plan.buckets {
                Some(b) => {
                    w.u8(1);
                    write_buckets(&mut w, b);
                }
                None => w.u8(0),
            }
            match &plan.gnna {
                Some(gp) => {
                    w.u8(1);
                    write_groups(&mut w, &gp.fwd_groups);
                    write_groups(&mut w, &gp.bwd_groups);
                }
                None => w.u8(0),
            }
            match &plan.ell {
                Some(ell) => {
                    w.u8(1);
                    write_ell(&mut w, ell);
                }
                None => w.u8(0),
            }
            match &plan.blocks {
                Some(b) => {
                    w.u8(1);
                    write_blocks(&mut w, b);
                }
                None => w.u8(0),
            }
        }
        let checksum = hash_bytes(&w.buf);
        w.u64(checksum);

        let path = self.plan_path(adj_hash);
        let tmp = self.dir.join(format!(
            ".tmp-{:016x}-{}-{}",
            adj_hash,
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let mut f = fs::File::create(&tmp)
            .map_err(|e| format!("plan store: cannot create {}: {e}", tmp.display()))?;
        f.write_all(&w.buf)
            .and_then(|_| f.sync_all())
            .map_err(|e| format!("plan store: cannot write {}: {e}", tmp.display()))?;
        drop(f);
        fs::rename(&tmp, &path)
            .map_err(|e| format!("plan store: cannot rename into {}: {e}", path.display()))?;
        Ok(path)
    }

    /// Load the stored engine for `g` under `builder`, if present.
    ///
    /// `Ok(None)` means no file exists (a cold miss). Every other failure —
    /// corruption, truncation, a stale signature, an adjacency-hash
    /// mismatch, or a kernel choice the builder no longer resolves to — is
    /// a loud `Err` so the caller can log it and rebuild cold. `builder`
    /// should be the [`effective_builder`](Self::effective_builder) so warm
    /// loads apply measured K profiles exactly like cold builds do.
    pub fn load(&self, g: &HeteroGraph, builder: &EngineBuilder) -> Result<Option<Engine>, String> {
        let adj_hash = g.adjacency_hash();
        let path = self.plan_path(adj_hash);
        let buf = match fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(format!("plan store: cannot read {}: {e}", path.display())),
        };
        self.decode(&buf, g, builder)
            .map(Some)
            .map_err(|e| format!("plan store: rejecting {}: {e}", path.display()))
    }

    fn decode(
        &self,
        buf: &[u8],
        g: &HeteroGraph,
        builder: &EngineBuilder,
    ) -> Result<Engine, String> {
        if buf.len() < MAGIC.len() + 8 {
            return Err("truncated (shorter than header + checksum)".into());
        }
        let (body, tail) = buf.split_at(buf.len() - 8);
        let stored_sum = u64::from_le_bytes(tail.try_into().unwrap());
        if hash_bytes(body) != stored_sum {
            return Err("checksum mismatch (corrupted or truncated)".into());
        }
        let mut r = Reader::new(body);
        if r.bytes(MAGIC.len())? != MAGIC.as_slice() {
            return Err("bad magic (not a plan file)".into());
        }
        let version = r.u32()?;
        if version != VERSION {
            return Err(format!("format version {version}, this build reads {VERSION}"));
        }
        let sig = r.blob()?;
        if sig != self.signature.as_bytes() {
            return Err(format!(
                "stale configuration signature (stored under a different EngineBuilder): \
                 stored {:?}, expected {:?}",
                String::from_utf8_lossy(&sig),
                self.signature
            ));
        }
        let adj_hash = r.u64()?;
        if adj_hash != g.adjacency_hash() {
            return Err(format!(
                "adjacency hash mismatch: stored {adj_hash:016x}, graph is {:016x}",
                g.adjacency_hash()
            ));
        }
        let n_cells = r.u64()? as usize;
        let n_nets = r.u64()? as usize;
        if n_cells != g.n_cells || n_nets != g.n_nets {
            return Err(format!(
                "shape mismatch: stored {n_cells} cells / {n_nets} nets, \
                 graph has {} / {}",
                g.n_cells, g.n_nets
            ));
        }

        let mut kernels = Vec::with_capacity(3);
        let mut plans = Vec::with_capacity(3);
        for e in EdgeType::ALL {
            let name = String::from_utf8(r.blob()?)
                .map_err(|_| "kernel name is not UTF-8".to_string())?;
            let adj = read_csr(&mut r)?;
            let csc = read_csc(&mut r)?;
            if csc.rows != adj.rows || csc.cols != adj.cols || csc.indices.len() != adj.nnz() {
                return Err(format!("{}: CSC does not match CSR shape/nnz", e.name()));
            }
            let buckets = if r.u8()? == 1 {
                Some(read_buckets(&mut r, adj.rows)?)
            } else {
                None
            };
            let gnna = if r.u8()? == 1 {
                let fwd_groups = read_groups(&mut r, adj.nnz())?;
                let bwd_groups = read_groups(&mut r, adj.nnz())?;
                Some(GnnaPlan { fwd_groups, bwd_groups })
            } else {
                None
            };
            let ell = if r.u8()? == 1 { Some(read_ell(&mut r, &adj)?) } else { None };
            let blocks = if r.u8()? == 1 {
                Some(read_blocks(&mut r, adj.rows, adj.cols)?)
            } else {
                None
            };

            // Re-resolve the kernel the builder would pick for this
            // adjacency today and require it to match what was stored —
            // this catches auto-policy drift that the signature alone
            // cannot (the signature says "auto", not which kernel auto
            // chose). The resolved kernel also guarantees bit-identity
            // with a cold build (same GnnaConfig, same dispatch).
            let kernel = builder.resolve_kernel(e, &adj);
            if kernel.name() != name {
                return Err(format!(
                    "{}: stored kernel '{}' but the builder now resolves '{}'",
                    e.name(),
                    name,
                    kernel.name()
                ));
            }
            let spec = KernelSpec::parse(&name).map_err(|_| {
                format!("{}: stored kernel name '{name}' is not in the registry", e.name())
            })?;
            if let Some(missing) =
                missing_payload(spec, buckets.is_some(), gnna.is_some(), ell.is_some(), blocks.is_some())
            {
                return Err(format!(
                    "{}: {} plan is missing {missing}",
                    e.name(),
                    name.to_ascii_uppercase()
                ));
            }
            if let Some(gp) = &gnna {
                let gs = builder.gnna_cfg().group_size;
                if gp.fwd_groups.group_size() != gs || gp.bwd_groups.group_size() != gs {
                    return Err(format!(
                        "{}: stored group size {} does not match configured {gs}",
                        e.name(),
                        gp.fwd_groups.group_size()
                    ));
                }
            }
            kernels.push(kernel);
            // Struct-literal reconstruction: deliberately bypasses
            // `KernelPlan::base` so warm loads register zero plan builds.
            plans.push(std::sync::Arc::new(KernelPlan {
                adj,
                csc,
                buckets,
                gnna,
                ell,
                blocks,
            }));
        }
        if !r.is_empty() {
            return Err(format!("{} trailing bytes after the last edge record", r.remaining()));
        }

        let kernels: [_; 3] = kernels.try_into().expect("three edge records");
        let plans: [_; 3] = plans.try_into().expect("three edge records");
        Ok(Engine {
            kernels,
            plans,
            k_cell: builder.k_for(crate::graph::NodeType::Cell),
            k_net: builder.k_for(crate::graph::NodeType::Net),
            parallel: builder.is_parallel(),
            n_cells,
            n_nets,
        })
    }

    /// Persist a measured §4.3 K profile for an adjacency.
    pub fn store_profile(&self, adj_hash: u64, rec: &KProfileRecord) -> Result<PathBuf, String> {
        let mut text = String::new();
        text.push_str(PROFILE_MAGIC);
        text.push('\n');
        text.push_str(&format!("dim {}\n", rec.dim));
        for (i, (best, timings)) in rec.edges.iter().enumerate() {
            text.push_str(&format!("edge {i} best {best}\n"));
            for &(k, t) in timings {
                // f64 bits in hex: timings round-trip exactly, so the
                // geometric-mean K choice is identical on every read.
                text.push_str(&format!("k {k} {:016x}\n", t.to_bits()));
            }
        }
        let path = self.profile_path(adj_hash);
        let tmp = self.dir.join(format!(
            ".tmp-kprof-{:016x}-{}-{}",
            adj_hash,
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        fs::write(&tmp, text)
            .map_err(|e| format!("plan store: cannot write {}: {e}", tmp.display()))?;
        fs::rename(&tmp, &path)
            .map_err(|e| format!("plan store: cannot rename into {}: {e}", path.display()))?;
        Ok(path)
    }

    /// Load the measured K profile for an adjacency, if present.
    pub fn load_profile(&self, adj_hash: u64) -> Result<Option<KProfileRecord>, String> {
        let path = self.profile_path(adj_hash);
        let text = match fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(format!("cannot read {}: {e}", path.display())),
        };
        parse_profile(&text)
            .map(Some)
            .map_err(|e| format!("malformed profile {}: {e}", path.display()))
    }
}

/// A persisted §4.3 K profile: per-edge candidate timings and the argmin,
/// in [`EdgeType::ALL`] order (near, pins, pinned).
///
/// Lives here (not in `train::kprofile`) so the engine layer can apply
/// profiles without depending on the trainer; `kprofile::to_type_ks`
/// delegates to [`KProfileRecord::type_ks`] for the one mapping rule.
#[derive(Clone, Debug, PartialEq)]
pub struct KProfileRecord {
    /// Embedding width the profile was measured at.
    pub dim: usize,
    /// Per edge: (best K, [(candidate K, median seconds fwd+bwd)]).
    pub edges: [(usize, Vec<(usize, f64)>); 3],
}

impl KProfileRecord {
    /// Map per-edge optima to the engine's per-node-type Ks: cell
    /// embeddings feed `near` and `pins`, so the cell K is the joint
    /// argmin under the geometric mean of the two edges' timings; net
    /// embeddings feed only `pinned`, whose argmin is used directly.
    pub fn type_ks(&self) -> (usize, usize) {
        let (near_best, near_t) = &self.edges[0];
        let (_, pins_t) = &self.edges[1];
        let (pinned_best, _) = &self.edges[2];
        let mut best = (*near_best, f64::INFINITY);
        for &(k, t_near) in near_t {
            if let Some(&(_, t_pins)) = pins_t.iter().find(|&&(kk, _)| kk == k) {
                let joint = (t_near * t_pins).sqrt();
                if joint < best.1 {
                    best = (k, joint);
                }
            }
        }
        (best.0, *pinned_best)
    }
}

/// Decode-side payload validation, exhaustive over [`KernelSpec`].
///
/// This is the single place a new registry backend declares which optional
/// plan section it must find on disk: the compiler enforces a new variant
/// gets an arm, lint rule R5 (`docs/ANALYSIS.md`) enforces this function
/// keeps naming every `KernelSpec::` variant, and
/// `every_kernel_spec_has_a_payload_arm` pins the arm semantics at runtime.
/// Returns the human-readable name of the payload `spec` requires but the
/// decoded record lacks, or `None` when the record is complete.
fn missing_payload(
    spec: KernelSpec,
    buckets: bool,
    gnna: bool,
    ell: bool,
    blocks: bool,
) -> Option<&'static str> {
    match spec {
        // CSR stores no side payload: the normalised CSR/CSC pair is enough.
        KernelSpec::Csr => None,
        KernelSpec::Dr if !buckets => Some("degree buckets"),
        KernelSpec::Gnna if !gnna => Some("neighbor groups"),
        KernelSpec::Ell if !ell => Some("the slot layout"),
        KernelSpec::Bcsr if !blocks => Some("the block schedule"),
        KernelSpec::Dr | KernelSpec::Gnna | KernelSpec::Ell | KernelSpec::Bcsr => None,
        // Auto is a policy, not a kernel: it resolves before storage, and a
        // stored "auto" name would already have failed the resolve-match
        // check against the builder.
        KernelSpec::Auto => None,
    }
}

fn parse_profile(text: &str) -> Result<KProfileRecord, String> {
    let mut lines = text.lines();
    if lines.next() != Some(PROFILE_MAGIC) {
        return Err(format!("missing '{PROFILE_MAGIC}' header"));
    }
    let dim_line = lines.next().ok_or("missing dim line")?;
    let dim = dim_line
        .strip_prefix("dim ")
        .and_then(|d| d.parse::<usize>().ok())
        .ok_or_else(|| format!("bad dim line '{dim_line}'"))?;
    let mut edges: Vec<(usize, Vec<(usize, f64)>)> = Vec::new();
    for line in lines {
        if let Some(rest) = line.strip_prefix("edge ") {
            let mut it = rest.split_whitespace();
            let idx: usize =
                it.next().and_then(|s| s.parse().ok()).ok_or("bad edge index")?;
            if idx != edges.len() || it.next() != Some("best") {
                return Err(format!("edge records out of order at '{line}'"));
            }
            let best: usize =
                it.next().and_then(|s| s.parse().ok()).ok_or("bad best K")?;
            edges.push((best, Vec::new()));
        } else if let Some(rest) = line.strip_prefix("k ") {
            let mut it = rest.split_whitespace();
            let k: usize = it.next().and_then(|s| s.parse().ok()).ok_or("bad candidate K")?;
            let bits = it
                .next()
                .and_then(|s| u64::from_str_radix(s, 16).ok())
                .ok_or("bad timing bits")?;
            edges
                .last_mut()
                .ok_or("candidate before any edge record")?
                .1
                .push((k, f64::from_bits(bits)));
        } else if !line.trim().is_empty() {
            return Err(format!("unrecognized line '{line}'"));
        }
    }
    let edges: [(usize, Vec<(usize, f64)>); 3] =
        edges.try_into().map_err(|_| "expected exactly 3 edge records".to_string())?;
    Ok(KProfileRecord { dim, edges })
}

fn hash_bytes(b: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &x in b {
        h = fnv_mix(h, x as u64);
    }
    h
}

// ---------------------------------------------------------------------------
// Little-endian writer / bounds-checked reader
// ---------------------------------------------------------------------------

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    fn blob(&mut self, b: &[u8]) {
        self.u64(b.len() as u64);
        self.bytes(b);
    }
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(b: &'a [u8]) -> Reader<'a> {
        Reader { b, pos: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.b.len() {
            return Err(format!(
                "truncated: wanted {n} bytes at offset {}, only {} remain",
                self.pos,
                self.b.len() - self.pos
            ));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn len_prefix(&mut self, elem_size: usize) -> Result<usize, String> {
        let n = self.u64()? as usize;
        // Reject absurd lengths before allocating (a flipped length byte
        // must fail loudly, not OOM).
        if n.saturating_mul(elem_size) > self.b.len() - self.pos {
            return Err(format!("length {n} exceeds remaining bytes"));
        }
        Ok(n)
    }

    fn blob(&mut self) -> Result<Vec<u8>, String> {
        let n = self.len_prefix(1)?;
        Ok(self.bytes(n)?.to_vec())
    }

    fn u64s(&mut self) -> Result<Vec<usize>, String> {
        let n = self.len_prefix(8)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u64()? as usize);
        }
        Ok(v)
    }

    fn u32s(&mut self) -> Result<Vec<u32>, String> {
        let n = self.len_prefix(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u32()?);
        }
        Ok(v)
    }

    fn f32s(&mut self) -> Result<Vec<f32>, String> {
        Ok(self.u32s()?.into_iter().map(f32::from_bits).collect())
    }

    fn is_empty(&self) -> bool {
        self.pos == self.b.len()
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }
}

// ---------------------------------------------------------------------------
// Per-structure codecs
// ---------------------------------------------------------------------------

fn write_sparse(w: &mut Writer, rows: usize, cols: usize, indptr: &[usize], indices: &[u32], values: &[f32]) {
    w.u64(rows as u64);
    w.u64(cols as u64);
    w.u64(indptr.len() as u64);
    for &p in indptr {
        w.u64(p as u64);
    }
    w.u64(indices.len() as u64);
    for &i in indices {
        w.u32(i);
    }
    w.u64(values.len() as u64);
    for &v in values {
        w.u32(v.to_bits());
    }
}

fn write_csr(w: &mut Writer, m: &Csr) {
    write_sparse(w, m.rows, m.cols, &m.indptr, &m.indices, &m.values);
}

fn write_csc(w: &mut Writer, m: &Csc) {
    write_sparse(w, m.rows, m.cols, &m.indptr, &m.indices, &m.values);
}

/// Shared structural validation for both orientations: `major` is the
/// pointered dimension (rows for CSR, cols for CSC), `minor` the indexed one.
fn read_sparse(
    r: &mut Reader,
    what: &str,
) -> Result<(usize, usize, Vec<usize>, Vec<u32>, Vec<f32>), String> {
    let rows = r.u64()? as usize;
    let cols = r.u64()? as usize;
    let indptr = r.u64s()?;
    let indices = r.u32s()?;
    let values = r.f32s()?;
    let nnz = indptr.last().copied().unwrap_or(0);
    if indices.len() != nnz || values.len() != nnz {
        return Err(format!(
            "{what}: indptr says {nnz} entries but indices/values hold {}/{}",
            indices.len(),
            values.len()
        ));
    }
    if indptr.windows(2).any(|w| w[0] > w[1]) {
        return Err(format!("{what}: indptr is not monotone"));
    }
    Ok((rows, cols, indptr, indices, values))
}

fn read_csr(r: &mut Reader) -> Result<Csr, String> {
    let (rows, cols, indptr, indices, values) = read_sparse(r, "CSR")?;
    if indptr.len() != rows + 1 {
        return Err(format!("CSR: indptr length {} for {rows} rows", indptr.len()));
    }
    if indices.iter().any(|&c| c as usize >= cols) {
        return Err("CSR: column index out of bounds".into());
    }
    Ok(Csr { rows, cols, indptr, indices, values })
}

fn read_csc(r: &mut Reader) -> Result<Csc, String> {
    let (rows, cols, indptr, indices, values) = read_sparse(r, "CSC")?;
    if indptr.len() != cols + 1 {
        return Err(format!("CSC: indptr length {} for {cols} cols", indptr.len()));
    }
    if indices.iter().any(|&row| row as usize >= rows) {
        return Err("CSC: row index out of bounds".into());
    }
    Ok(Csc { rows, cols, indptr, indices, values })
}

fn write_buckets(w: &mut Writer, b: &DegreeBuckets) {
    w.u64(b.order.len() as u64);
    for &r in &b.order {
        w.u32(r);
    }
    for (start, grain) in [b.low, b.medium, b.high] {
        w.u64(start as u64);
        w.u64(grain as u64);
    }
    w.u64(b.t_low as u64);
    w.u64(b.t_high as u64);
}

fn read_buckets(r: &mut Reader, rows: usize) -> Result<DegreeBuckets, String> {
    let n = r.len_prefix(4)?;
    let mut order = Vec::with_capacity(n);
    for _ in 0..n {
        order.push(r.u32()?);
    }
    if order.len() != rows {
        return Err(format!("buckets: order holds {} rows, adjacency has {rows}", order.len()));
    }
    if order.iter().any(|&row| row as usize >= rows.max(1)) {
        return Err("buckets: row id out of bounds".into());
    }
    let mut seg = [(0usize, 0usize); 3];
    for s in &mut seg {
        *s = (r.u64()? as usize, r.u64()? as usize);
    }
    let [low, medium, high] = seg;
    if !(low.0 <= medium.0 && medium.0 <= high.0 && high.0 <= order.len()) {
        return Err("buckets: segment offsets out of order".into());
    }
    let t_low = r.u64()? as usize;
    let t_high = r.u64()? as usize;
    if t_low >= t_high {
        return Err("buckets: t_low >= t_high".into());
    }
    Ok(DegreeBuckets { order, low, medium, high, t_low, t_high })
}

fn write_groups(w: &mut Writer, g: &NeighborGroups) {
    w.u64(g.group_size() as u64);
    let parts = g.export();
    w.u64(parts.len() as u64);
    for (row, start, len, shared) in parts {
        w.u32(row);
        w.u32(start);
        w.u32(len);
        w.u8(shared as u8);
    }
}

fn write_ell(w: &mut Writer, ell: &EllLayout) {
    w.u64(ell.rows as u64);
    w.u64(ell.cols as u64);
    w.u64(ell.width as u64);
    w.u64(ell.idx.len() as u64);
    for &i in &ell.idx {
        w.u32(i);
    }
    w.u64(ell.val.len() as u64);
    for &v in &ell.val {
        w.u32(v.to_bits());
    }
    w.u64(ell.ofl_indptr.len() as u64);
    for &p in &ell.ofl_indptr {
        w.u64(p as u64);
    }
    w.u64(ell.ofl_indices.len() as u64);
    for &i in &ell.ofl_indices {
        w.u32(i);
    }
    w.u64(ell.ofl_values.len() as u64);
    for &v in &ell.ofl_values {
        w.u32(v.to_bits());
    }
}

fn read_ell(r: &mut Reader, adj: &Csr) -> Result<EllLayout, String> {
    let rows = r.u64()? as usize;
    let cols = r.u64()? as usize;
    let width = r.u64()? as usize;
    let idx = r.u32s()?;
    let val = r.f32s()?;
    let ofl_indptr = r.u64s()?;
    let ofl_indices = r.u32s()?;
    let ofl_values = r.f32s()?;
    if rows != adj.rows || cols != adj.cols {
        return Err(format!(
            "ELL: stored shape {rows}x{cols}, adjacency is {}x{}",
            adj.rows, adj.cols
        ));
    }
    let slots = rows.checked_mul(width).ok_or("ELL: rows * width overflows")?;
    if idx.len() != slots || val.len() != slots {
        return Err(format!(
            "ELL: {rows}x{width} layout needs {slots} slots, stored {}/{}",
            idx.len(),
            val.len()
        ));
    }
    if idx.iter().any(|&c| c as usize >= cols) {
        return Err("ELL: slot index out of bounds".into());
    }
    if ofl_indptr.len() != rows + 1 || ofl_indptr.first() != Some(&0) {
        return Err("ELL: overflow indptr malformed".into());
    }
    if ofl_indptr.windows(2).any(|w| w[0] > w[1]) {
        return Err("ELL: overflow indptr is not monotone".into());
    }
    let ofl_nnz = *ofl_indptr.last().unwrap();
    if ofl_indices.len() != ofl_nnz || ofl_values.len() != ofl_nnz {
        return Err(format!(
            "ELL: overflow indptr says {ofl_nnz} entries but arrays hold {}/{}",
            ofl_indices.len(),
            ofl_values.len()
        ));
    }
    if ofl_indices.iter().any(|&c| c as usize >= cols) {
        return Err("ELL: overflow index out of bounds".into());
    }
    // Losslessness cross-check: every edge past the width cap of each
    // adjacency row must be in the overflow list, nothing more or less.
    for row in 0..rows {
        let want = adj.row_range(row).len().saturating_sub(width);
        if ofl_indptr[row + 1] - ofl_indptr[row] != want {
            return Err(format!(
                "ELL: row {row} overflow holds {} edges, adjacency needs {want}",
                ofl_indptr[row + 1] - ofl_indptr[row]
            ));
        }
    }
    Ok(EllLayout { rows, cols, width, idx, val, ofl_indptr, ofl_indices, ofl_values })
}

fn write_blocks(w: &mut Writer, b: &BlockSchedule) {
    w.u64(b.tile as u64);
    w.u64(b.fwd.len() as u64);
    for &x in &b.fwd {
        w.u32(x);
    }
    w.u64(b.bwd.len() as u64);
    for &x in &b.bwd {
        w.u32(x);
    }
}

fn read_blocks(r: &mut Reader, fwd_rows: usize, bwd_rows: usize) -> Result<BlockSchedule, String> {
    let tile = r.u64()? as usize;
    if tile == 0 {
        return Err("blocks: feature tile width is zero".into());
    }
    let fwd = r.u32s()?;
    let bwd = r.u32s()?;
    for (bounds, rows, what) in [(&fwd, fwd_rows, "fwd"), (&bwd, bwd_rows, "bwd")] {
        if bounds.first() != Some(&0) || bounds.last().copied() != Some(rows as u32) {
            return Err(format!("blocks: {what} bounds do not span 0..{rows}"));
        }
        if bounds.windows(2).any(|w| w[0] >= w[1]) {
            return Err(format!("blocks: {what} bounds are not strictly increasing"));
        }
    }
    Ok(BlockSchedule { fwd, bwd, tile })
}

fn read_groups(r: &mut Reader, nnz: usize) -> Result<NeighborGroups, String> {
    let group_size = r.u64()? as usize;
    if group_size == 0 {
        return Err("groups: group_size is zero".into());
    }
    let n = r.len_prefix(13)?;
    let mut parts = Vec::with_capacity(n);
    for _ in 0..n {
        let row = r.u32()?;
        let start = r.u32()?;
        let len = r.u32()?;
        let shared = match r.u8()? {
            0 => false,
            1 => true,
            x => return Err(format!("groups: bad shared flag {x}")),
        };
        if len as usize > group_size || (start as usize) + (len as usize) > nnz {
            return Err("groups: tile exceeds edge array".into());
        }
        parts.push((row, start, len, shared));
    }
    Ok(NeighborGroups::from_parts(group_size, &parts))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("drcg-planstore-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn random_graph(seed: u64) -> HeteroGraph {
        let mut rng = Rng::new(seed);
        let spec = crate::datagen::GraphSpec {
            n_cells: 40,
            n_nets: 16,
            target_near: 240,
            target_pins: 64,
            d_cell: 6,
            d_net: 6,
        };
        crate::datagen::generate_graph(&spec, 0, &mut rng)
    }

    fn engines_agree(a: &Engine, b: &Engine, g: &HeteroGraph) {
        for e in EdgeType::ALL {
            assert_eq!(a.kernel_name(e), b.kernel_name(e));
            let x = g.src_features(e);
            let prep_a = a.sparsify(x, e.endpoints().0);
            let prep_b = b.sparsify(x, e.endpoints().0);
            let (ya, _) = a.aggregate_with(e, x, prep_a.as_ref());
            let (yb, _) = b.aggregate_with(e, x, prep_b.as_ref());
            assert_eq!(ya.data, yb.data, "{} forward differs", e.name());
        }
    }

    /// R5 cross-check (see `docs/ANALYSIS.md`): every registry variant has
    /// a decode-validation arm, and the arm demands exactly the payload
    /// `store()` writes for that kernel. A backend added to [`KernelSpec`]
    /// without deciding its payload fails to compile (`missing_payload` is
    /// exhaustive); one whose arm is wrong fails here.
    #[test]
    fn every_kernel_spec_has_a_payload_arm() {
        for &spec in KernelSpec::ALL {
            // With no payloads present, exactly the plan-carrying kernels
            // must complain...
            let missing = missing_payload(spec, false, false, false, false);
            match spec {
                KernelSpec::Csr | KernelSpec::Auto => assert!(
                    missing.is_none(),
                    "{spec:?} needs no side payload but demanded {missing:?}"
                ),
                KernelSpec::Dr | KernelSpec::Gnna | KernelSpec::Ell | KernelSpec::Bcsr => {
                    assert!(missing.is_some(), "{spec:?} must require its plan payload")
                }
            }
            // ...and with every payload present, nothing may complain.
            assert_eq!(missing_payload(spec, true, true, true, true), None);
        }
    }

    #[test]
    fn round_trip_all_kernel_families() {
        let dir = tmp_dir("roundtrip");
        let g = random_graph(11);
        for builder in [
            EngineBuilder::csr(),
            EngineBuilder::gnna(crate::sparse::GnnaConfig { group_size: 8, dim_worker: 8 }),
            EngineBuilder::dr(2, 2),
            EngineBuilder::default().kernel("ell"),
            EngineBuilder::default().kernel("bcsr"),
            EngineBuilder::auto(),
        ] {
            let store = PlanStore::open(&dir, &builder).unwrap();
            let built = builder.build(&g);
            store.store(&g, &built).unwrap();
            let loaded = store.load(&g, &builder).unwrap().expect("stored plan present");
            engines_agree(&built, &loaded, &g);
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn loads_register_zero_plan_builds() {
        let dir = tmp_dir("zero-builds");
        let g = random_graph(12);
        let builder = EngineBuilder::dr(2, 2);
        let store = PlanStore::open(&dir, &builder).unwrap();
        store.store(&g, &builder.build(&g)).unwrap();
        // Exact zero-build counting lives in tests/integration_planstore.rs
        // behind the counter lock; here confirm the reconstructed plans are
        // structurally complete without calling KernelPlan::base.
        let loaded = store.load(&g, &builder).unwrap().unwrap();
        assert!(loaded.plan(EdgeType::Near).buckets.is_some());
        assert_eq!(loaded.plan(EdgeType::Near).csc.rows, loaded.plan(EdgeType::Near).adj.rows);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_file_is_a_clean_miss() {
        let dir = tmp_dir("miss");
        let builder = EngineBuilder::csr();
        let store = PlanStore::open(&dir, &builder).unwrap();
        assert!(store.load(&random_graph(13), &builder).unwrap().is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn different_signature_uses_a_different_file() {
        let dir = tmp_dir("sig");
        let g = random_graph(14);
        let b1 = EngineBuilder::dr(2, 2);
        let b2 = EngineBuilder::dr(2, 4);
        let s1 = PlanStore::open(&dir, &b1).unwrap();
        let s2 = PlanStore::open(&dir, &b2).unwrap();
        assert_ne!(s1.plan_path(1), s2.plan_path(1));
        s1.store(&g, &b1.build(&g)).unwrap();
        // The second configuration misses cleanly: its keyed file is absent.
        assert!(s2.load(&g, &b2).unwrap().is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_and_truncated_files_error_loudly() {
        let dir = tmp_dir("corrupt");
        let g = random_graph(15);
        let builder = EngineBuilder::dr(2, 2);
        let store = PlanStore::open(&dir, &builder).unwrap();
        store.store(&g, &builder.build(&g)).unwrap();
        let path = store.plan_path(g.adjacency_hash());
        let bytes = fs::read(&path).unwrap();

        // Flip a byte in the middle: checksum must catch it.
        let mut flipped = bytes.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0xFF;
        fs::write(&path, &flipped).unwrap();
        let err = store.load(&g, &builder).unwrap_err();
        assert!(err.contains("checksum"), "unexpected error: {err}");

        // Truncate: also loud.
        fs::write(&path, &bytes[..bytes.len() / 3]).unwrap();
        assert!(store.load(&g, &builder).is_err());

        // Not even a header.
        fs::write(&path, b"oops").unwrap();
        assert!(store.load(&g, &builder).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn store_keys_on_the_explicit_builder_signature() {
        let dir = tmp_dir("explicit-sig");
        let builder = EngineBuilder::dr(2, 2);
        let store = PlanStore::open(&dir, &builder).unwrap();
        // The key is EngineBuilder::signature(), never the Debug string.
        assert_eq!(store.signature(), builder.signature());
        assert!(store.signature().starts_with("drcg-engine-config-v1 "));
        assert_ne!(store.signature(), format!("{builder:?}"));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn old_format_version_is_rejected_loudly_then_rebuilds() {
        let dir = tmp_dir("oldver");
        let g = random_graph(19);
        let builder = EngineBuilder::dr(2, 2);
        let store = PlanStore::open(&dir, &builder).unwrap();
        store.store(&g, &builder.build(&g)).unwrap();
        let path = store.plan_path(g.adjacency_hash());
        let mut bytes = fs::read(&path).unwrap();
        // Rewrite the version field (bytes 8..12, after the magic) to the
        // retired v1 and recompute the trailing checksum, simulating a
        // store written by the previous format.
        bytes[8..12].copy_from_slice(&1u32.to_le_bytes());
        let body_len = bytes.len() - 8;
        let sum = hash_bytes(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&sum.to_le_bytes());
        fs::write(&path, &bytes).unwrap();
        // Loud (names the versions), not a panic and not a silent miss...
        let err = store.load(&g, &builder).unwrap_err();
        assert!(err.contains("format version 1"), "unexpected error: {err}");
        // ...then cold: rebuilding and re-storing restores warm loads.
        store.store(&g, &builder.build(&g)).unwrap();
        assert!(store.load(&g, &builder).unwrap().is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn ell_and_bcsr_missing_payloads_are_rejected() {
        // A stored ELL/BCSR record whose optional payload was stripped
        // (all presence flags 0, checksum valid) must be rejected for the
        // missing payload, never execute as a partial plan.
        let dir = tmp_dir("payloads");
        let g = random_graph(20);
        for (name, needle) in [("ell", "slot layout"), ("bcsr", "block schedule")] {
            let builder = EngineBuilder::default().kernel(name);
            let store = PlanStore::open(&dir, &builder).unwrap();
            let engine = builder.build(&g);
            let mut w = Writer::new();
            w.bytes(MAGIC);
            w.u32(VERSION);
            w.blob(store.signature().as_bytes());
            w.u64(g.adjacency_hash());
            w.u64(g.n_cells as u64);
            w.u64(g.n_nets as u64);
            for e in EdgeType::ALL {
                let i = edge_index(e);
                w.blob(engine.kernels[i].name().as_bytes());
                write_csr(&mut w, &engine.plans[i].adj);
                write_csc(&mut w, &engine.plans[i].csc);
                for _ in 0..4 {
                    w.u8(0); // buckets / gnna / ell / blocks all absent
                }
            }
            let checksum = hash_bytes(&w.buf);
            w.u64(checksum);
            fs::write(store.plan_path(g.adjacency_hash()), &w.buf).unwrap();
            let err = store.load(&g, &builder).unwrap_err();
            assert!(err.contains(needle), "{name}: unexpected error: {err}");
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn hash_mismatch_is_rejected() {
        let dir = tmp_dir("hash");
        let g = random_graph(16);
        let other = random_graph(17);
        assert_ne!(g.adjacency_hash(), other.adjacency_hash());
        let builder = EngineBuilder::csr();
        let store = PlanStore::open(&dir, &builder).unwrap();
        store.store(&g, &builder.build(&g)).unwrap();
        // Masquerade g's plan under other's key.
        fs::copy(store.plan_path(g.adjacency_hash()), store.plan_path(other.adjacency_hash()))
            .unwrap();
        let err = store.load(&other, &builder).unwrap_err();
        assert!(err.contains("adjacency hash mismatch"), "unexpected error: {err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn profile_round_trips_bit_exactly() {
        let dir = tmp_dir("profile");
        let builder = EngineBuilder::auto();
        let store = PlanStore::open(&dir, &builder).unwrap();
        let rec = KProfileRecord {
            dim: 16,
            edges: [
                (8, vec![(2, 0.125), (4, 0.5), (8, 0.0625)]),
                (4, vec![(2, 0.3), (4, 0.1), (8, 0.9)]),
                (2, vec![(2, 1e-9), (4, 2e-9), (8, 3e-9)]),
            ],
        };
        store.store_profile(42, &rec).unwrap();
        let back = store.load_profile(42).unwrap().unwrap();
        assert_eq!(back, rec);
        assert!(store.load_profile(43).unwrap().is_none());
        // Malformed profile errors loudly.
        fs::write(store.profile_path(44), "not a profile").unwrap();
        assert!(store.load_profile(44).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn type_ks_geometric_mean_rule() {
        let rec = KProfileRecord {
            dim: 16,
            edges: [
                // near alone prefers 2, pins alone prefers 8; jointly K=4
                // wins under the geometric mean.
                (2, vec![(2, 1.0), (4, 2.0), (8, 10.0)]),
                (8, vec![(2, 10.0), (4, 2.0), (8, 1.0)]),
                (4, vec![(2, 5.0), (4, 1.0), (8, 5.0)]),
            ],
        };
        assert_eq!(rec.type_ks(), (4, 4));
    }

    #[test]
    fn effective_builder_applies_profile_only_under_auto() {
        let dir = tmp_dir("effective");
        let auto = EngineBuilder::auto();
        let store = PlanStore::open(&dir, &auto).unwrap();
        let g = random_graph(18);
        let rec = KProfileRecord {
            dim: 8,
            edges: [
                (4, vec![(2, 2.0), (4, 1.0)]),
                (4, vec![(2, 2.0), (4, 1.0)]),
                (2, vec![(2, 1.0), (4, 2.0)]),
            ],
        };
        store.store_profile(g.adjacency_hash(), &rec).unwrap();
        let eff = store.effective_builder(&auto, &g);
        assert_eq!(eff.k_for(crate::graph::NodeType::Cell), 4);
        assert_eq!(eff.k_for(crate::graph::NodeType::Net), 2);
        // A pinned configuration keeps its explicit Ks.
        let dr = EngineBuilder::dr(16, 16);
        let eff = store.effective_builder(&dr, &g);
        assert_eq!(eff, dr);
        let _ = fs::remove_dir_all(&dir);
    }
}
