//! The execution engine: planned, per-edge-type kernel dispatch.
//!
//! This subsystem unifies what used to be two parallel kernel selectors
//! (`sparse::KernelKind` and `nn::MessageEngine`) behind one facade:
//!
//! * [`SpmmKernel`] — one kernel family behind a **plan/execute split**:
//!   `plan(adj)` precomputes the per-graph state (CSC transpose, degree
//!   buckets, GNNA neighbor groups) once, `forward`/`backward` run against
//!   the cached [`KernelPlan`].
//! * [`registry`] — the single parse point for kernel-name strings
//!   (`"csr" | "gnna" | "dr" | "auto"` plus aliases).
//! * [`Engine`] / [`EngineBuilder`] — the facade: a builder configures a
//!   kernel **per edge type**, the node-type K values for D-ReLU, and the
//!   §3.4 parallel aggregation mode; `build(&graph)` normalises the three
//!   adjacencies, resolves `"auto"` against their degree profiles, and
//!   plans every kernel exactly once.
//! * [`auto`] — the Fig. 4 selection policy (`"auto"`).
//! * [`planstore`] — versioned on-disk plans keyed by adjacency content
//!   hash + builder signature, so warm restarts skip Alg. 1 stage 1
//!   entirely (see `docs/SERVE.md`).
//!
//! Threading: the engine never spawns threads of its own — kernel
//! dispatches and the §3.4 parallel lanes all draw on the calling thread's
//! cooperative budget ([`crate::util::pool::Budget`]), so stacking the
//! engine under fleet workers cannot oversubscribe the machine.
//!
//! ```no_run
//! # use dr_circuitgnn::engine::Engine;
//! # use dr_circuitgnn::graph::EdgeType;
//! # let graph: dr_circuitgnn::graph::HeteroGraph = unimplemented!();
//! let engine = Engine::builder()
//!     .kernel("auto")
//!     .kernel_for(EdgeType::Near, "dr")
//!     .k_cell(24)
//!     .parallel(true)
//!     .build(&graph);
//! ```
//!
//! See `docs/ENGINE.md` for the full API walkthrough.

pub mod auto;
pub mod kernel;
pub mod planstore;
pub mod registry;
pub mod repair;

pub use auto::{auto_select, AutoDecision};
pub use kernel::{
    plan_counters, AggCache, BcsrKernel, CsrKernel, DrKernel, EllKernel, GnnaKernel, GnnaPlan,
    Gradient, KernelPlan, PlanCounters, SpmmKernel,
};
pub use planstore::{KProfileRecord, PlanStore};
pub use registry::{known_names, KernelEntry, KernelSpec, REGISTRY};
pub use repair::RepairStats;

use crate::graph::{Cbsr, Csr, EdgeType, HeteroGraph, NodeType};
use crate::sparse::{drelu, GnnaConfig};
use crate::tensor::Matrix;
use std::sync::Arc;

/// Index of an edge type in the engine's internal arrays
/// (the [`EdgeType::ALL`] order: near, pins, pinned).
#[inline]
fn edge_index(e: EdgeType) -> usize {
    match e {
        EdgeType::Near => 0,
        EdgeType::Pins => 1,
        EdgeType::Pinned => 2,
    }
}

/// Normalise a graph's three adjacencies the way every execution path
/// does ([`EdgeType::ALL`] order): symmetric GCN normalisation for `near`,
/// row-mean for `pins`/`pinned`. Shared by [`EngineBuilder::build`] and the
/// scheduler rig so the bench measures the exact matrices training uses.
pub fn normalized_adjacencies(g: &HeteroGraph) -> [Csr; 3] {
    [
        normalized_adjacency(g, EdgeType::Near),
        normalized_adjacency(g, EdgeType::Pins),
        normalized_adjacency(g, EdgeType::Pinned),
    ]
}

/// Normalise one edge type's adjacency (the per-edge unit behind
/// [`normalized_adjacencies`]; the incremental plan repair uses it to
/// renormalise only the touched edge types).
pub fn normalized_adjacency(g: &HeteroGraph, e: EdgeType) -> Csr {
    let mut adj = g.adj(e).clone();
    match e {
        EdgeType::Near => adj.normalize_gcn(),
        EdgeType::Pins | EdgeType::Pinned => adj.normalize_rows(),
    }
    adj
}

/// Display label for a resolved kernel triple ([`EdgeType::ALL`] order):
/// a single display name when all edges agree, `edge=name` pairs otherwise.
pub fn kernel_label(kernels: [&dyn SpmmKernel; 3]) -> String {
    label_from_names(kernels.map(|k| (k.name(), k.display_name())))
}

/// The one display convention behind [`kernel_label`] and
/// [`EngineBuilder::describe`]: `(canonical, display)` name pairs in
/// [`EdgeType::ALL`] order.
fn label_from_names(names: [(&str, &str); 3]) -> String {
    if names.iter().all(|(n, _)| *n == names[0].0) {
        names[0].1.to_string()
    } else {
        EdgeType::ALL
            .iter()
            .zip(names)
            .map(|(e, (n, _))| format!("{}={n}", e.name()))
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// Reusable engine configuration. One builder can `build()` an [`Engine`]
/// per graph of a dataset; the kernel choices, K values and schedule mode
/// are shared, the plans are per graph. Equality is structural over the
/// whole configuration — the fleet's shared plan cache uses it to refuse
/// serving engines planned under different settings.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EngineBuilder {
    default: KernelSpec,
    per_edge: [Option<KernelSpec>; 3],
    k_cell: usize,
    k_net: usize,
    gnna: GnnaConfig,
    parallel: bool,
}

impl Default for EngineBuilder {
    fn default() -> EngineBuilder {
        EngineBuilder {
            default: KernelSpec::Dr,
            per_edge: [None; 3],
            k_cell: 8,
            k_net: 8,
            gnna: GnnaConfig::default(),
            parallel: false,
        }
    }
}

impl EngineBuilder {
    /// cuSPARSE-analog baseline for every edge type.
    pub fn csr() -> EngineBuilder {
        EngineBuilder::default().kernel_spec(KernelSpec::Csr)
    }

    /// GNNAdvisor analog for every edge type.
    pub fn gnna(cfg: GnnaConfig) -> EngineBuilder {
        EngineBuilder::default().kernel_spec(KernelSpec::Gnna).gnna_config(cfg)
    }

    /// The paper's engine: D-ReLU + DR-SpMM with per-node-type K (§3.1).
    pub fn dr(k_cell: usize, k_net: usize) -> EngineBuilder {
        EngineBuilder::default().kernel_spec(KernelSpec::Dr).k_cell(k_cell).k_net(k_net)
    }

    /// Per-edge-type automatic selection (paper Fig. 4).
    pub fn auto() -> EngineBuilder {
        EngineBuilder::default().kernel_spec(KernelSpec::Auto)
    }

    /// Set the kernel for every edge type by registry name.
    ///
    /// Panics on an unknown name — parse user input with
    /// [`KernelSpec::parse`] first if you need a recoverable error.
    pub fn kernel(self, name: &str) -> EngineBuilder {
        match KernelSpec::parse(name) {
            Ok(spec) => self.kernel_spec(spec),
            Err(e) => panic!("EngineBuilder::kernel: {e}"),
        }
    }

    /// Set the kernel for every edge type.
    pub fn kernel_spec(mut self, spec: KernelSpec) -> EngineBuilder {
        self.default = spec;
        self
    }

    /// Override the kernel for one edge type by registry name (panics on an
    /// unknown name, like [`EngineBuilder::kernel`]).
    pub fn kernel_for(self, e: EdgeType, name: &str) -> EngineBuilder {
        match KernelSpec::parse(name) {
            Ok(spec) => self.kernel_spec_for(e, spec),
            Err(err) => panic!("EngineBuilder::kernel_for: {err}"),
        }
    }

    /// Override the kernel for one edge type.
    pub fn kernel_spec_for(mut self, e: EdgeType, spec: KernelSpec) -> EngineBuilder {
        self.per_edge[edge_index(e)] = Some(spec);
        self
    }

    /// D-ReLU K for cell embeddings (clamped to the width at sparsify time).
    pub fn k_cell(mut self, k: usize) -> EngineBuilder {
        self.k_cell = k.max(1);
        self
    }

    /// D-ReLU K for net embeddings.
    pub fn k_net(mut self, k: usize) -> EngineBuilder {
        self.k_net = k.max(1);
        self
    }

    /// GNNAdvisor runtime parameters for GNNA-kernel edges.
    pub fn gnna_config(mut self, cfg: GnnaConfig) -> EngineBuilder {
        self.gnna = cfg;
        self
    }

    /// Enable the §3.4 parallel aggregation mode (one lane per edge type).
    pub fn parallel(mut self, on: bool) -> EngineBuilder {
        self.parallel = on;
        self
    }

    /// The spec configured for an edge type (per-edge override or default).
    pub fn spec_for(&self, e: EdgeType) -> KernelSpec {
        self.per_edge[edge_index(e)].unwrap_or(self.default)
    }

    /// Explicit versioned configuration signature — the plan-store and
    /// plan-cache key. Built field-by-field from the semantically relevant
    /// state (NOT `format!("{self:?}")`: Debug-derive drift would silently
    /// invalidate every stored plan, and a field missing from Debug could
    /// alias two configurations). Two builders that resolve to the same
    /// effective configuration (e.g. a per-edge override equal to the
    /// default) produce the same signature. The exact string is pinned by
    /// a golden test; bump the leading version tag on any change.
    pub fn signature(&self) -> String {
        format!(
            "drcg-engine-config-v1 near={} pins={} pinned={} k_cell={} k_net={} \
             gnna_group={} gnna_dim={} parallel={}",
            self.spec_for(EdgeType::Near).name(),
            self.spec_for(EdgeType::Pins).name(),
            self.spec_for(EdgeType::Pinned).name(),
            self.k_cell,
            self.k_net,
            self.gnna.group_size,
            self.gnna.dim_worker,
            self.parallel,
        )
    }

    /// The D-ReLU K configured for a node type.
    pub fn k_for(&self, nt: NodeType) -> usize {
        match nt {
            NodeType::Cell => self.k_cell,
            NodeType::Net => self.k_net,
        }
    }

    pub fn gnna_cfg(&self) -> &GnnaConfig {
        &self.gnna
    }

    pub fn is_parallel(&self) -> bool {
        self.parallel
    }

    /// Resolve the concrete kernel for one edge of a graph (`"auto"`
    /// inspects the adjacency's degree statistics).
    pub fn resolve_kernel(&self, e: EdgeType, adj: &Csr) -> Arc<dyn SpmmKernel> {
        registry::instantiate(self.spec_for(e), e, adj, &self.gnna)
    }

    /// One-line description of the configured kernels (display names; a
    /// single name when all edges agree, `edge=name` pairs otherwise).
    pub fn describe(&self) -> String {
        label_from_names(
            [EdgeType::Near, EdgeType::Pins, EdgeType::Pinned]
                .map(|e| (self.spec_for(e).name(), self.spec_for(e).display_name())),
        )
    }

    /// Build a graph-bound engine: normalise the three adjacencies, resolve
    /// `"auto"`, and plan each edge's kernel exactly once (Alg. 1 stage 1).
    pub fn build(&self, g: &HeteroGraph) -> Engine {
        let [near, pins, pinned] = normalized_adjacencies(g);
        let k_near = self.resolve_kernel(EdgeType::Near, &near);
        let k_pins = self.resolve_kernel(EdgeType::Pins, &pins);
        let k_pinned = self.resolve_kernel(EdgeType::Pinned, &pinned);
        let plans = [
            Arc::new(k_near.plan(near)),
            Arc::new(k_pins.plan(pins)),
            Arc::new(k_pinned.plan(pinned)),
        ];
        Engine {
            kernels: [k_near, k_pins, k_pinned],
            plans,
            k_cell: self.k_cell,
            k_net: self.k_net,
            parallel: self.parallel,
            n_cells: g.n_cells,
            n_nets: g.n_nets,
        }
    }
}

/// A graph-bound execution engine: one resolved kernel + cached plan per
/// edge type, the per-node-type D-ReLU K values, and the schedule mode.
///
/// Replaces the old `(GraphCtx, MessageEngine)` pair: the per-graph state
/// and the kernel choice now travel together, and only the state each
/// kernel actually needs is precomputed.
#[derive(Debug)]
pub struct Engine {
    kernels: [Arc<dyn SpmmKernel>; 3],
    plans: [Arc<KernelPlan>; 3],
    k_cell: usize,
    k_net: usize,
    parallel: bool,
    n_cells: usize,
    n_nets: usize,
}

impl Engine {
    /// Start configuring an engine.
    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// The resolved kernel driving an edge type.
    pub fn kernel(&self, e: EdgeType) -> &dyn SpmmKernel {
        &*self.kernels[edge_index(e)]
    }

    /// Canonical name of the resolved kernel for an edge type.
    pub fn kernel_name(&self, e: EdgeType) -> &'static str {
        self.kernel(e).name()
    }

    /// The cached plan for an edge type.
    pub fn plan(&self, e: EdgeType) -> &KernelPlan {
        &self.plans[edge_index(e)]
    }

    /// The shared handle to an edge type's plan. Plans live behind `Arc` so
    /// the incremental repair path ([`crate::engine::repair`]) can carry
    /// untouched plans into the repaired engine without copying a byte —
    /// and so tests can prove the reuse with `Arc::ptr_eq`.
    pub fn plan_shared(&self, e: EdgeType) -> &Arc<KernelPlan> {
        &self.plans[edge_index(e)]
    }

    /// Normalised adjacency for an edge type.
    pub fn adj(&self, e: EdgeType) -> &Csr {
        &self.plan(e).adj
    }

    pub fn n_cells(&self) -> usize {
        self.n_cells
    }

    pub fn n_nets(&self) -> usize {
        self.n_nets
    }

    /// §3.4 parallel aggregation mode.
    pub fn is_parallel(&self) -> bool {
        self.parallel
    }

    /// D-ReLU K for a node type.
    pub fn k_for(&self, nt: NodeType) -> usize {
        match nt {
            NodeType::Cell => self.k_cell,
            NodeType::Net => self.k_net,
        }
    }

    /// Whether any edge's kernel consumes D-ReLU-sparsified sources. When
    /// true the D-ReLU *is* the model's activation (§3.1); when false the
    /// model applies a plain inter-layer ReLU.
    pub fn uses_drelu(&self) -> bool {
        self.kernels.iter().any(|k| k.needs_sparsified())
    }

    /// Whether this engine sparsifies a node type's embedding (i.e. some
    /// edge consuming it runs a DR kernel). The model uses this per node
    /// type: a sparsified type's activation is the D-ReLU inside its
    /// aggregations, an unsparsified type gets the plain inter-layer ReLU.
    /// (In a mixed engine, a *dense* kernel reading a sparsified type's
    /// tensor sees the raw pre-activation values — the same convention the
    /// pure-DR path uses for SageConv self-paths; the cell-side max merge
    /// keeps that path nonlinear.)
    pub fn sparsifies(&self, nt: NodeType) -> bool {
        Self::edges_with_source(nt).iter().any(|&e| self.kernel(e).needs_sparsified())
    }

    /// One-line description of the *resolved* kernels.
    pub fn describe(&self) -> String {
        kernel_label([&*self.kernels[0], &*self.kernels[1], &*self.kernels[2]])
    }

    /// Edge types whose aggregation reads a node type's embedding.
    fn edges_with_source(nt: NodeType) -> &'static [EdgeType] {
        match nt {
            NodeType::Cell => &[EdgeType::Near, EdgeType::Pins],
            NodeType::Net => &[EdgeType::Pinned],
        }
    }

    /// Sparsify one node type's embedding (D-ReLU → CBSR) iff some
    /// consuming edge's kernel needs it. The CBSR is built **once per node
    /// type per layer** and shared by every consumer (§3.1 — `x_cell` is
    /// sparsified once for both `near` and `pins`, not twice).
    pub fn sparsify(&self, x: &Matrix, nt: NodeType) -> Option<Arc<Cbsr>> {
        if !self.sparsifies(nt) {
            return None;
        }
        let k = self.k_for(nt).clamp(1, x.cols);
        Some(Arc::new(drelu(x, k)))
    }

    /// Aggregate `h = Ā · x_src` for one edge type; sparsifies internally.
    /// Hot paths sparsify once per node type and use
    /// [`Engine::aggregate_with`] instead.
    pub fn aggregate(&self, e: EdgeType, x_src: &Matrix) -> (Matrix, AggCache) {
        let prep = self.sparsify(x_src, e.endpoints().0);
        self.aggregate_with(e, x_src, prep.as_ref())
    }

    /// Aggregate with a pre-sparsified source (see [`Engine::sparsify`]).
    pub fn aggregate_with(
        &self,
        e: EdgeType,
        x_src: &Matrix,
        prep: Option<&Arc<Cbsr>>,
    ) -> (Matrix, AggCache) {
        let i = edge_index(e);
        self.kernels[i].forward(&self.plans[i], x_src, prep)
    }

    /// Backward of the aggregation: dense `dX_src = Āᵀ · dH`, using the
    /// forward cache. DR gradients are masked to the CBSR support (the
    /// D-ReLU subgradient, Alg. 2 reusing forward indices).
    pub fn aggregate_backward(&self, e: EdgeType, dh: &Matrix, cache: &AggCache) -> Matrix {
        self.aggregate_backward_raw(e, dh, cache).into_dense()
    }

    /// Backward in the kernel's native gradient representation (compressed
    /// CBSR for DR) — what the kernel-level benches time.
    pub fn aggregate_backward_raw(&self, e: EdgeType, dh: &Matrix, cache: &AggCache) -> Gradient {
        let i = edge_index(e);
        self.kernels[i].backward(&self.plans[i], dh, cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::HeteroGraph;
    use crate::util::math::assert_allclose;

    fn toy_graph() -> HeteroGraph {
        let near = Csr::from_triplets(
            3,
            3,
            &[(0, 1, 1.0), (1, 0, 1.0), (1, 2, 1.0), (2, 1, 1.0)],
        );
        let pins =
            Csr::from_triplets(2, 3, &[(0, 0, 1.0), (0, 1, 1.0), (1, 1, 1.0), (1, 2, 1.0)]);
        let pinned = pins.transpose();
        HeteroGraph {
            id: 0,
            n_cells: 3,
            n_nets: 2,
            near,
            pins,
            pinned,
            x_cell: Matrix::from_vec(3, 4, (0..12).map(|i| (i as f32) / 6.0 - 1.0).collect()),
            x_net: Matrix::from_vec(2, 4, (0..8).map(|i| (i as f32) / 4.0 - 1.0).collect()),
            y_cell: Matrix::zeros(3, 1),
        }
    }

    #[test]
    fn builder_defaults_and_shorthands() {
        let b = Engine::builder();
        assert_eq!(b.spec_for(EdgeType::Near), KernelSpec::Dr);
        assert_eq!(EngineBuilder::csr().spec_for(EdgeType::Pins), KernelSpec::Csr);
        assert_eq!(
            EngineBuilder::gnna(GnnaConfig::default()).spec_for(EdgeType::Pinned),
            KernelSpec::Gnna
        );
        assert_eq!(EngineBuilder::auto().spec_for(EdgeType::Near), KernelSpec::Auto);
        let b = EngineBuilder::dr(4, 2);
        assert_eq!(b.k_for(NodeType::Cell), 4);
        assert_eq!(b.k_for(NodeType::Net), 2);
    }

    #[test]
    fn per_edge_overrides_resolve() {
        let g = toy_graph();
        let eng = Engine::builder()
            .kernel("csr")
            .kernel_for(EdgeType::Near, "dr")
            .kernel_for(EdgeType::Pins, "gnna")
            .build(&g);
        assert_eq!(eng.kernel_name(EdgeType::Near), "dr");
        assert_eq!(eng.kernel_name(EdgeType::Pins), "gnna");
        assert_eq!(eng.kernel_name(EdgeType::Pinned), "csr");
        assert!(eng.uses_drelu());
        assert_eq!(eng.describe(), "near=dr,pins=gnna,pinned=csr");
    }

    #[test]
    #[should_panic(expected = "unknown kernel")]
    fn builder_panics_on_unknown_kernel() {
        let _ = Engine::builder().kernel("warp9");
    }

    #[test]
    fn aggregate_shapes_all_kernels() {
        let g = toy_graph();
        for name in ["csr", "gnna", "dr", "ell", "bcsr"] {
            let eng = Engine::builder().kernel(name).k_cell(2).k_net(2).build(&g);
            let (h_near, _) = eng.aggregate(EdgeType::Near, &g.x_cell);
            assert_eq!((h_near.rows, h_near.cols), (3, 4), "{name}");
            let (h_pins, _) = eng.aggregate(EdgeType::Pins, &g.x_cell);
            assert_eq!((h_pins.rows, h_pins.cols), (2, 4), "{name}");
            let (h_pinned, _) = eng.aggregate(EdgeType::Pinned, &g.x_net);
            assert_eq!((h_pinned.rows, h_pinned.cols), (3, 4), "{name}");
        }
    }

    #[test]
    fn dr_full_k_matches_csr_engine() {
        let g = toy_graph();
        let csr = EngineBuilder::csr().build(&g);
        let dr = EngineBuilder::dr(4, 4).build(&g);
        for e in EdgeType::ALL {
            let x = g.src_features(e);
            let (a, _) = csr.aggregate(e, x);
            let (b, cache) = dr.aggregate(e, x);
            assert_allclose(&a.data, &b.data, 1e-5, 1e-5);
            let dy = Matrix::ones(a.rows, a.cols);
            let ga = csr.aggregate_backward(e, &dy, &AggCache::None);
            let gb = dr.aggregate_backward(e, &dy, &cache);
            assert_allclose(&ga.data, &gb.data, 1e-5, 1e-5);
        }
    }

    #[test]
    fn sparsify_only_when_a_consumer_needs_it() {
        let g = toy_graph();
        let csr = EngineBuilder::csr().build(&g);
        assert!(csr.sparsify(&g.x_cell, NodeType::Cell).is_none());
        // DR only on pinned (net source): cell embeddings stay dense.
        let eng = Engine::builder()
            .kernel("csr")
            .kernel_for(EdgeType::Pinned, "dr")
            .k_net(2)
            .build(&g);
        assert!(eng.sparsify(&g.x_cell, NodeType::Cell).is_none());
        let net = eng.sparsify(&g.x_net, NodeType::Net).unwrap();
        assert_eq!(net.k, 2);
    }

    #[test]
    fn signature_is_pinned_and_explicit() {
        // Golden strings: any change to the signature scheme must be a
        // loud, deliberate version bump — it invalidates on-disk plans.
        assert_eq!(
            EngineBuilder::default().signature(),
            "drcg-engine-config-v1 near=dr pins=dr pinned=dr k_cell=8 k_net=8 \
             gnna_group=32 gnna_dim=32 parallel=false"
        );
        assert_eq!(
            EngineBuilder::dr(2, 4).parallel(true).signature(),
            "drcg-engine-config-v1 near=dr pins=dr pinned=dr k_cell=2 k_net=4 \
             gnna_group=32 gnna_dim=32 parallel=true"
        );
        assert_eq!(
            Engine::builder().kernel("ell").kernel_for(EdgeType::Pins, "bcsr").signature(),
            "drcg-engine-config-v1 near=ell pins=bcsr pinned=ell k_cell=8 k_net=8 \
             gnna_group=32 gnna_dim=32 parallel=false"
        );
    }

    #[test]
    fn signature_ignores_representation_not_semantics() {
        // A per-edge override equal to the default is the same effective
        // configuration → same signature (Debug would disagree) ...
        let plain = EngineBuilder::csr();
        let aliased = EngineBuilder::csr().kernel_for(EdgeType::Near, "csr");
        assert_ne!(format!("{plain:?}"), format!("{aliased:?}"));
        assert_eq!(plain.signature(), aliased.signature());
        // ... while every semantic field changes it.
        let base = EngineBuilder::default();
        for other in [
            base.clone().kernel("ell"),
            base.clone().kernel_spec_for(EdgeType::Pinned, KernelSpec::Bcsr),
            base.clone().k_cell(3),
            base.clone().k_net(5),
            base.clone().gnna_config(GnnaConfig { group_size: 16, dim_worker: 32 }),
            base.clone().parallel(true),
        ] {
            assert_ne!(base.signature(), other.signature());
        }
    }

    #[test]
    fn ell_and_bcsr_engines_match_csr_engine() {
        let g = toy_graph();
        let csr = EngineBuilder::csr().build(&g);
        for name in ["ell", "bcsr"] {
            let eng = Engine::builder().kernel(name).build(&g);
            for e in EdgeType::ALL {
                let x = g.src_features(e);
                let (want, _) = csr.aggregate(e, x);
                let (got, cache) = eng.aggregate(e, x);
                assert_allclose(&got.data, &want.data, 1e-6, 1e-6);
                let dy = Matrix::ones(want.rows, want.cols);
                let gw = csr.aggregate_backward(e, &dy, &AggCache::None);
                let gg = eng.aggregate_backward(e, &dy, &cache);
                assert_allclose(&gg.data, &gw.data, 1e-6, 1e-6);
            }
        }
    }

    #[test]
    fn k_clamps_to_embedding_width() {
        let g = toy_graph();
        let eng = EngineBuilder::dr(64, 64).build(&g);
        let c = eng.sparsify(&g.x_cell, NodeType::Cell).unwrap();
        assert_eq!(c.k, g.x_cell.cols);
    }
}
