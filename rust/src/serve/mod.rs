//! Long-running serve loop: from benchmark binary to traffic-serving
//! system.
//!
//! The trainer and benches run one (design, model-config) pair and exit —
//! every invocation pays Alg. 1 stage 1 planning from scratch. This
//! subsystem keeps the process resident and treats training requests as
//! *jobs*:
//!
//! * **Admission** — jobs enter a bounded MPMC [`queue::Queue`]; producers
//!   block when the backlog is full, so a burst degrades latency, not
//!   memory.
//! * **Multiplexing** — all jobs share one [`PlanCache`] (optionally
//!   disk-backed via [`crate::engine::PlanStore`]), so the second job on a
//!   design reuses the first job's engines, and a `--plan-store` makes
//!   even the *first* job warm across process restarts.
//! * **Fairness** — `workers` OS threads pop jobs FIFO under equal
//!   [`Budget`] shares: a long job occupies one worker's share, never the
//!   whole machine, and queue order bounds every job's wait by the jobs
//!   ahead of it. No job starves.
//! * **Determinism** — each job trains with its own seeded RNG through
//!   [`Trainer::train_dr_fleet_cached`]; engines are read-only at forward
//!   time, so a job's [`TrainReport`] is bit-identical to a standalone run
//!   of the same spec regardless of worker count, budget, or queue
//!   interleaving (gated by `tests/integration_serve.rs`).
//!
//! See `docs/SERVE.md` for the full walkthrough.

pub mod job;
pub mod queue;

pub use job::{parse_jobs, JobSpec};
pub use queue::Queue;

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::fleet::{CacheStats, PlanCache};
use crate::graph::HeteroGraph;
use crate::train::{TrainReport, Trainer};
use crate::util::pool::Budget;

/// Serve-loop shape: worker threads and queue capacity.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Concurrent job workers (clamped to ≥ 1). Each gets an equal share
    /// of the ambient [`Budget`].
    pub workers: usize,
    /// Queue capacity; producers block (admission control) when full.
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig { workers: 2, queue_cap: 16 }
    }
}

/// Outcome of one job: the training report plus serve-side timings.
#[derive(Clone, Debug)]
pub struct JobResult {
    /// Position of the job in the submitted jobs list (results are
    /// returned sorted by this id, whatever order workers finished in).
    pub id: usize,
    pub job: JobSpec,
    /// Seconds between enqueue and a worker picking the job up.
    pub queue_seconds: f64,
    /// Seconds the job spent training.
    pub train_seconds: f64,
    /// Seconds between enqueue and completion.
    pub total_seconds: f64,
    /// This job's plan-cache traffic (`hits` = engines another job or
    /// graph already materialised; `misses` = cold plan builds;
    /// `disk_loads` = warm loads from the backing store).
    pub cache: CacheStats,
    pub report: TrainReport,
}

/// Whole-run summary returned by [`Server::run`].
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Per-job results, sorted by job id.
    pub results: Vec<JobResult>,
    /// Wall-clock seconds for the whole run (enqueue of the first job to
    /// completion of the last).
    pub wall_seconds: f64,
    /// Cache traffic across the whole run (delta over the shared cache,
    /// so pre-warmed entries from before the run don't count).
    pub cache: CacheStats,
    /// Worker threads actually spawned (≤ `ServeConfig::workers`, capped
    /// by the ambient budget's concurrency lease).
    pub workers: usize,
}

impl ServeReport {
    /// Fraction of engine lookups served without building a plan
    /// (memory hits + disk loads over all lookups).
    pub fn warm_rate(&self) -> f64 {
        let lookups = self.cache.lookups();
        if lookups == 0 {
            return 0.0;
        }
        (self.cache.hits + self.cache.disk_loads) as f64 / lookups as f64
    }
}

/// A resident training service over a fixed design catalog and one shared
/// plan cache.
pub struct Server<'a> {
    catalog: &'a [(String, Vec<HeteroGraph>)],
    cache: Arc<PlanCache>,
}

/// What travels through the queue: (job id, spec, enqueue instant).
type Queued = (usize, JobSpec, Instant);

impl<'a> Server<'a> {
    /// A server over `catalog` designs, multiplexing every job through
    /// `cache`. The cache's engine configuration is the server's: jobs
    /// choose training hyper-parameters, not kernels, so all jobs stay
    /// plan-compatible with the shared cache.
    pub fn new(catalog: &'a [(String, Vec<HeteroGraph>)], cache: Arc<PlanCache>) -> Server<'a> {
        Server { catalog, cache }
    }

    /// The shared plan cache (e.g. to snapshot [`PlanCache::stats`]).
    pub fn cache(&self) -> &Arc<PlanCache> {
        &self.cache
    }

    /// Run `jobs` to completion and return per-job results sorted by job
    /// id. Jobs naming a design the catalog lacks are rejected up front —
    /// before any work starts — so a typo'd jobs file fails fast instead
    /// of half-running.
    pub fn run(&self, jobs: &[JobSpec], cfg: &ServeConfig) -> Result<ServeReport, String> {
        for (i, job) in jobs.iter().enumerate() {
            if !self.catalog.iter().any(|(name, _)| *name == job.design) {
                return Err(format!(
                    "job {} requests unknown design `{}` (catalog: {})",
                    i,
                    job.design,
                    self.catalog
                        .iter()
                        .map(|(n, _)| n.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                ));
            }
        }
        if jobs.is_empty() {
            return Err("no jobs to serve".to_string());
        }

        // One single-design Dataset per catalog entry, built once and
        // shared by reference across every job that names it.
        let datasets: Vec<crate::datagen::Dataset> = self
            .catalog
            .iter()
            .map(|(name, graphs)| crate::datagen::Dataset {
                name: name.clone(),
                designs: vec![(name.clone(), graphs.clone())],
            })
            .collect();

        let stats_before = self.cache.stats();
        let queue: Queue<Queued> = Queue::bounded(cfg.queue_cap);
        let results: Mutex<Vec<JobResult>> = Mutex::new(Vec::with_capacity(jobs.len()));

        // Equal budget shares per worker: concurrency never exceeds the
        // ambient budget, and each worker's jobs run under `share`, so
        // nested fleet/engine parallelism stays within its lane.
        let (workers, share) = Budget::current().lease(cfg.workers.max(1));

        let started = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..workers {
                let queue = &queue;
                let results = &results;
                let datasets = &datasets;
                s.spawn(move || {
                    share.with(|| {
                        while let Some((id, job, enqueued)) = queue.pop() {
                            let queue_seconds = enqueued.elapsed().as_secs_f64();
                            let result =
                                self.run_job(id, job, queue_seconds, enqueued, datasets);
                            // Poisoning: recover via `into_inner()` (lint
                            // rule R3) — one panicking worker must not
                            // discard every other worker's finished
                            // results. A single Vec::push either lands or
                            // doesn't; the panicked job is simply absent.
                            results
                                .lock()
                                .unwrap_or_else(|e| e.into_inner())
                                .push(result);
                        }
                    })
                });
            }
            // The caller's thread is the producer: push FIFO, blocking
            // when the queue is full (admission control), then close so
            // workers drain the backlog and exit.
            for (id, job) in jobs.iter().enumerate() {
                queue
                    .push((id, job.clone(), Instant::now()))
                    .map_err(|_| "job queue closed before all jobs were admitted")
                    .expect("serve queue closed early");
            }
            queue.close();
        });
        let wall_seconds = started.elapsed().as_secs_f64();

        // Same recovery at collection: the guard is gone (scope joined all
        // workers), so a poisoned flag only records that some job panicked
        // — every result that was pushed is still intact.
        let mut results = results.into_inner().unwrap_or_else(|e| e.into_inner());
        results.sort_by_key(|r| r.id);
        Ok(ServeReport {
            results,
            wall_seconds,
            cache: self.cache.stats().since(&stats_before),
            workers,
        })
    }

    fn run_job(
        &self,
        id: usize,
        job: JobSpec,
        queue_seconds: f64,
        enqueued: Instant,
        datasets: &[crate::datagen::Dataset],
    ) -> JobResult {
        let dataset = datasets
            .iter()
            .find(|d| d.name == job.design)
            .expect("designs validated before enqueue");
        let builder = self.cache.builder().clone();
        let cfg = job.train_config(builder.is_parallel());
        let t0 = Instant::now();
        let (_model, report) = Trainer::train_dr_fleet_cached(
            dataset,
            dataset,
            &builder,
            &cfg,
            &job.fleet,
            &self.cache,
        );
        let train_seconds = t0.elapsed().as_secs_f64();
        JobResult {
            id,
            job,
            queue_seconds,
            train_seconds,
            total_seconds: enqueued.elapsed().as_secs_f64(),
            cache: report.plan_cache,
            report,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineBuilder;
    use crate::util::rng::Rng;

    fn catalog() -> Vec<(String, Vec<HeteroGraph>)> {
        let mut rng = Rng::new(11);
        let spec = crate::datagen::GraphSpec {
            n_cells: 40,
            n_nets: 16,
            target_near: 240,
            target_pins: 64,
            d_cell: 6,
            d_net: 6,
        };
        ["alpha", "beta"]
            .iter()
            .enumerate()
            .map(|(i, name)| {
                let graphs = (0..2)
                    .map(|j| crate::datagen::generate_graph(&spec, i * 10 + j, &mut rng))
                    .collect();
                (name.to_string(), graphs)
            })
            .collect()
    }

    fn jobs() -> Vec<JobSpec> {
        parse_jobs(
            "design=alpha epochs=2 seed=1\n\
             design=beta epochs=2 seed=2\n\
             design=alpha epochs=2 seed=3 hidden=16\n",
        )
        .unwrap()
    }

    #[test]
    fn serve_matches_standalone_runs_bitwise() {
        let catalog = catalog();
        let jobs = jobs();
        let builder = EngineBuilder::csr();

        let served = {
            let server = Server::new(&catalog, Arc::new(PlanCache::new(builder.clone())));
            server
                .run(&jobs, &ServeConfig { workers: 2, queue_cap: 2 })
                .unwrap()
        };
        assert_eq!(served.results.len(), jobs.len());

        for (i, r) in served.results.iter().enumerate() {
            assert_eq!(r.id, i, "results sorted by job id");
            // Standalone: same spec, fresh cache, direct trainer call.
            let dataset = crate::datagen::Dataset {
                name: jobs[i].design.clone(),
                designs: vec![catalog
                    .iter()
                    .find(|(n, _)| *n == jobs[i].design)
                    .cloned()
                    .unwrap()],
            };
            let cache = Arc::new(PlanCache::new(builder.clone()));
            let cfg = jobs[i].train_config(builder.is_parallel());
            let (_m, standalone) = Trainer::train_dr_fleet_cached(
                &dataset,
                &dataset,
                &builder,
                &cfg,
                &jobs[i].fleet,
                &cache,
            );
            assert_eq!(
                r.report.epoch_losses, standalone.epoch_losses,
                "job {i} diverged from its standalone run"
            );
            assert_eq!(r.report.test_scores.mae, standalone.test_scores.mae);
        }

        // Three jobs over two designs × two graphs: the shared cache
        // materialises 4 engines; the repeat-design job hits memory.
        assert_eq!(served.cache.unique(), 4);
        assert!(served.cache.hits > 0, "repeat design should hit the cache");
        assert_eq!(served.cache.disk_loads, 0, "no store attached");
        assert!(served.warm_rate() > 0.0);
    }

    #[test]
    fn unknown_design_is_rejected_before_any_work() {
        let catalog = catalog();
        let server = Server::new(&catalog, Arc::new(PlanCache::new(EngineBuilder::csr())));
        let jobs = parse_jobs("design=ghost\n").unwrap();
        let err = server.run(&jobs, &ServeConfig::default()).unwrap_err();
        assert!(err.contains("ghost"), "{err}");
        assert!(err.contains("alpha"), "error lists the catalog: {err}");
        assert_eq!(server.cache().stats().lookups(), 0);
    }
}
