//! Bounded MPMC job queue for the serve loop.
//!
//! A deliberately small Condvar queue (in the spirit of
//! [`crate::util::pool::Handoff`], which carries exactly one item between
//! the scheduler's two pipeline lanes): a `Mutex<VecDeque>` with one
//! condvar for consumers and one for producers. Producers block while the
//! queue is at capacity — admission control, so a burst of jobs cannot
//! balloon memory — and consumers block while it is empty. `close()`
//! drains gracefully: producers are refused immediately, consumers keep
//! popping until the backlog is empty and then observe `None`.
//!
//! FIFO order is guaranteed for the queue itself; with several workers the
//! *completion* order is of course up to the scheduler, which is why
//! [`super::ServeReport`] sorts results by job id.

use crate::util::sync::{Condvar, Mutex};
use std::collections::VecDeque;

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer multi-consumer FIFO queue.
pub struct Queue<T> {
    cap: usize,
    state: Mutex<State<T>>,
    /// Signalled when an item is pushed or the queue is closed.
    not_empty: Condvar,
    /// Signalled when an item is popped or the queue is closed.
    not_full: Condvar,
}

impl<T> Queue<T> {
    /// A queue holding at most `cap` items (clamped to ≥ 1).
    pub fn bounded(cap: usize) -> Queue<T> {
        Queue {
            cap: cap.max(1),
            state: Mutex::new(State { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Capacity the queue was built with.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Items currently queued (racy by nature; for reporting only).
    ///
    /// Poisoning policy (repo-wide, lint rule R3): every lock in this
    /// queue recovers the guard with `into_inner()` rather than
    /// cascading a worker's panic into every other producer and
    /// consumer. The state is panic-safe by construction: each
    /// critical section is a single `VecDeque` operation or a single
    /// flag write, both of which either happen entirely or not at all —
    /// there is no intermediate state a panicking thread could leak.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap_or_else(|e| e.into_inner()).items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Block until there is room, then enqueue `v`. Returns `Err(v)` if
    /// the queue was closed — the item is handed back so the producer can
    /// report it as rejected rather than silently dropped.
    pub fn push(&self, v: T) -> Result<(), T> {
        // Poisoning: recover via `into_inner()` — see [`Queue::len`].
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if st.closed {
                return Err(v);
            }
            if st.items.len() < self.cap {
                st.items.push_back(v);
                drop(st);
                self.not_empty.notify_one();
                return Ok(());
            }
            st = self.not_full.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Block until an item is available and dequeue it. Returns `None`
    /// once the queue is closed *and* drained — the worker shutdown
    /// signal.
    pub fn pop(&self) -> Option<T> {
        // Poisoning: recover via `into_inner()` — see [`Queue::len`].
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(v) = st.items.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Some(v);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Close the queue: future `push`es fail, `pop` drains the backlog
    /// then returns `None`. Idempotent.
    pub fn close(&self) {
        // Poisoning: recover via `into_inner()` — close() is how the
        // server shuts the queue down after a failure, so it must work
        // even when the poisoning panic was the failure (see [`Queue::len`]).
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.closed = true;
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn fifo_single_threaded() {
        let q = Queue::bounded(4);
        for i in 0..4 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 4);
        for i in 0..4 {
            assert_eq!(q.pop(), Some(i));
        }
        q.close();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_drains_backlog_then_stops() {
        let q = Queue::bounded(8);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert_eq!(q.push(3), Err(3));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None); // idempotent after drain
    }

    #[test]
    fn bounded_push_blocks_until_a_pop_frees_a_slot() {
        let q = Queue::bounded(1);
        q.push(0usize).unwrap();
        let produced = AtomicUsize::new(0);
        std::thread::scope(|s| {
            s.spawn(|| {
                // Blocks: queue is full until the main thread pops.
                q.push(1).unwrap();
                produced.store(1, Ordering::SeqCst);
                q.push(2).unwrap();
                produced.store(2, Ordering::SeqCst);
                q.close();
            });
            assert_eq!(q.pop(), Some(0));
            assert_eq!(q.pop(), Some(1));
            assert_eq!(q.pop(), Some(2));
            assert_eq!(q.pop(), None);
        });
        assert_eq!(produced.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn many_producers_many_consumers_lose_nothing() {
        let q = Queue::bounded(2);
        let total: usize = 4 * 25;
        let sum = AtomicUsize::new(0);
        let popped = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for p in 0..4 {
                let q = &q;
                s.spawn(move || {
                    for i in 0..25 {
                        q.push(p * 25 + i).unwrap();
                    }
                });
            }
            let consumers: Vec<_> = (0..3)
                .map(|_| {
                    s.spawn(|| {
                        while let Some(v) = q.pop() {
                            sum.fetch_add(v, Ordering::SeqCst);
                            popped.fetch_add(1, Ordering::SeqCst);
                        }
                    })
                })
                .collect();
            // Producers run to completion before close() so no push fails.
            while popped.load(Ordering::SeqCst) < total {
                std::thread::yield_now();
            }
            q.close();
            for c in consumers {
                c.join().unwrap();
            }
        });
        assert_eq!(popped.load(Ordering::SeqCst), total);
        assert_eq!(sum.load(Ordering::SeqCst), (0..total).sum::<usize>());
    }
}
