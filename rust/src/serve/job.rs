//! Job descriptions for the serve loop.
//!
//! A jobs file is plain text, one job per line, `key=value` pairs
//! separated by whitespace — the same philosophy as the key=value config
//! files [`crate::config`] reads: no new dependency for a format this
//! small, and every key mirrors a CLI flag so a job line reads like a
//! `train` invocation.
//!
//! ```text
//! # design is the only required key; the rest default like `train`.
//! design=riscv_core epochs=8 seed=7
//! design=dsp_block  epochs=4 hidden=16 fleet=2x2
//! ```

use crate::fleet::FleetSpec;
use crate::train::TrainConfig;

/// One (design, model-config) unit of work.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    /// Catalog design name this job trains on.
    pub design: String,
    pub epochs: usize,
    pub lr: f32,
    pub weight_decay: f32,
    pub hidden: usize,
    pub seed: u64,
    /// Fleet schedule for the job's subgraphs (`"1"` = one worker).
    pub fleet: FleetSpec,
}

impl Default for JobSpec {
    fn default() -> JobSpec {
        JobSpec {
            design: String::new(),
            epochs: 5,
            lr: 2e-4,
            weight_decay: 1e-5,
            hidden: 32,
            seed: 42,
            fleet: FleetSpec::On { workers: 1, parts: None },
        }
    }
}

impl JobSpec {
    /// Parse one jobs-file line. `Ok(None)` for blank lines and `#`
    /// comments; `Err` names the offending key so a typo in a 50-line
    /// jobs file is findable.
    pub fn parse(line: &str) -> Result<Option<JobSpec>, String> {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            return Ok(None);
        }
        let mut job = JobSpec::default();
        for tok in line.split_whitespace() {
            let (key, val) = tok
                .split_once('=')
                .ok_or_else(|| format!("expected key=value, got `{tok}`"))?;
            match key {
                "design" => job.design = val.to_string(),
                "epochs" => job.epochs = parse_num(key, val)?,
                "lr" => job.lr = parse_num(key, val)?,
                "weight-decay" | "weight_decay" => {
                    job.weight_decay = parse_num(key, val)?;
                }
                "hidden" => job.hidden = parse_num(key, val)?,
                "seed" => job.seed = parse_num(key, val)?,
                "fleet" => {
                    job.fleet =
                        FleetSpec::parse(val).map_err(|e| format!("fleet: {e}"))?;
                }
                other => return Err(format!("unknown job key `{other}`")),
            }
        }
        if job.design.is_empty() {
            return Err("job line is missing `design=`".to_string());
        }
        if job.epochs == 0 {
            return Err("epochs must be ≥ 1".to_string());
        }
        Ok(Some(job))
    }

    /// The [`TrainConfig`] this job trains under. Serve jobs always run
    /// the serial (deterministic-by-construction) epoch schedule; graph
    /// parallelism is the engine builder's choice, shared across jobs so
    /// every job is plan-compatible with the one shared cache.
    pub fn train_config(&self, parallel: bool) -> TrainConfig {
        TrainConfig {
            epochs: self.epochs,
            lr: self.lr,
            weight_decay: self.weight_decay,
            hidden: self.hidden,
            seed: self.seed,
            parallel,
            epoch_pipeline: false,
            window: crate::datagen::WindowSpec::Off,
            checkpoint: false,
            log_every: 0,
        }
    }
}

/// Parse a whole jobs file; errors are prefixed with their line number.
pub fn parse_jobs(text: &str) -> Result<Vec<JobSpec>, String> {
    let mut jobs = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if let Some(job) =
            JobSpec::parse(line).map_err(|e| format!("jobs file line {}: {e}", i + 1))?
        {
            jobs.push(job);
        }
    }
    if jobs.is_empty() {
        return Err("jobs file contains no jobs".to_string());
    }
    Ok(jobs)
}

fn parse_num<T: std::str::FromStr>(key: &str, val: &str) -> Result<T, String> {
    val.parse().map_err(|_| format!("{key}: invalid value `{val}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_fill_unset_keys() {
        let job = JobSpec::parse("design=alpha").unwrap().unwrap();
        assert_eq!(job.design, "alpha");
        assert_eq!(job.epochs, 5);
        assert_eq!(job.hidden, 32);
        assert_eq!(job.seed, 42);
        assert_eq!(job.fleet, FleetSpec::On { workers: 1, parts: None });
    }

    #[test]
    fn explicit_keys_override_defaults() {
        let job = JobSpec::parse("design=b epochs=8 lr=0.001 weight-decay=0 hidden=16 seed=7 fleet=2x2")
            .unwrap()
            .unwrap();
        assert_eq!(job.epochs, 8);
        assert_eq!(job.lr, 0.001);
        assert_eq!(job.weight_decay, 0.0);
        assert_eq!(job.hidden, 16);
        assert_eq!(job.seed, 7);
        assert_eq!(job.fleet, FleetSpec::On { workers: 2, parts: Some(2) });
    }

    #[test]
    fn comments_and_blanks_are_skipped() {
        assert_eq!(JobSpec::parse("").unwrap(), None);
        assert_eq!(JobSpec::parse("   ").unwrap(), None);
        assert_eq!(JobSpec::parse("# design=ghost").unwrap(), None);
    }

    #[test]
    fn bad_lines_error_loudly() {
        assert!(JobSpec::parse("epochs=3").unwrap_err().contains("design"));
        assert!(JobSpec::parse("design=a epochs=zero").unwrap_err().contains("epochs"));
        assert!(JobSpec::parse("design=a turbo=1").unwrap_err().contains("turbo"));
        assert!(JobSpec::parse("design=a epochs").unwrap_err().contains("key=value"));
        assert!(JobSpec::parse("design=a epochs=0").unwrap_err().contains("≥ 1"));
    }

    #[test]
    fn jobs_file_reports_line_numbers() {
        let text = "design=a\n\n# comment\ndesign=b epochs=2\n";
        let jobs = parse_jobs(text).unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[1].design, "b");

        let err = parse_jobs("design=a\nnonsense\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        assert!(parse_jobs("# only comments\n").unwrap_err().contains("no jobs"));
    }
}
