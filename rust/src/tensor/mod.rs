//! Dense tensor algebra substrate.
//!
//! A row-major f32 matrix with the operations the NN stack needs: blocked and
//! threaded matmul (plus `A^T B` and `A B^T` variants used by manual
//! backward passes), elementwise maps, reductions, and broadcasting adds.
//! Built from scratch because `ndarray` is unavailable offline.

mod matrix;
mod ops;

pub use matrix::Matrix;
pub use ops::{matmul, matmul_at_b, matmul_a_bt};
