//! Row-major dense matrix.

use crate::util::rng::Rng;

/// Dense row-major `rows × cols` f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn ones(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![1.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "data length must be rows*cols");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Standard-normal entries scaled by `std`.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for x in m.data.iter_mut() {
            *x = rng.normal() * std;
        }
        m
    }

    /// He initialisation for a `fan_in → fan_out` weight.
    pub fn he_init(fan_in: usize, fan_out: usize, rng: &mut Rng) -> Matrix {
        let mut m = Matrix::zeros(fan_in, fan_out);
        rng.fill_he(&mut m.data, fan_in);
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness.
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in self.data.iter_mut() {
            *x = f(*x);
        }
    }

    pub fn zip_map(&self, other: &Matrix, f: impl Fn(f32, f32) -> f32) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        self.zip_map(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        self.zip_map(other, |a, b| a - b)
    }

    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        self.zip_map(other, |a, b| a * b)
    }

    pub fn add_inplace(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&self, s: f32) -> Matrix {
        self.map(|x| x * s)
    }

    pub fn scale_inplace(&mut self, s: f32) {
        self.map_inplace(|x| x * s);
    }

    /// Add a 1×cols bias row to every row.
    pub fn add_bias(&self, bias: &[f32]) -> Matrix {
        assert_eq!(bias.len(), self.cols);
        let mut out = self.clone();
        for r in 0..out.rows {
            let row = out.row_mut(r);
            for (x, b) in row.iter_mut().zip(bias) {
                *x += b;
            }
        }
        out
    }

    /// Column sums (gradient of a broadcast bias).
    pub fn col_sum(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.cols];
        for r in 0..self.rows {
            for (o, x) in out.iter_mut().zip(self.row(r)) {
                *o += x;
            }
        }
        out
    }

    /// Element-wise maximum with a mask output: `mask[i]=1` where self wins.
    /// This is the paper's eq. (8)/(14) merge of the cell node's two updates.
    pub fn max_merge(&self, other: &Matrix) -> (Matrix, Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = Matrix::zeros(self.rows, self.cols);
        let mut mask = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.data.len() {
            if self.data[i] >= other.data[i] {
                out.data[i] = self.data[i];
                mask.data[i] = 1.0;
            } else {
                out.data[i] = other.data[i];
            }
        }
        (out, mask)
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    /// Horizontal concatenation `[self | other]`.
    pub fn hconcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows);
        let cols = self.cols + other.cols;
        let mut out = Matrix::zeros(self.rows, cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
            out.row_mut(r)[self.cols..].copy_from_slice(other.row(r));
        }
        out
    }

    /// Split `[A | B]` back into A (first `cols_a` columns) and B.
    pub fn hsplit(&self, cols_a: usize) -> (Matrix, Matrix) {
        assert!(cols_a <= self.cols);
        let cols_b = self.cols - cols_a;
        let mut a = Matrix::zeros(self.rows, cols_a);
        let mut b = Matrix::zeros(self.rows, cols_b);
        for r in 0..self.rows {
            a.row_mut(r).copy_from_slice(&self.row(r)[..cols_a]);
            b.row_mut(r).copy_from_slice(&self.row(r)[cols_a..]);
        }
        (a, b)
    }

    /// Take a subset of rows.
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (o, &i) in idx.iter().enumerate() {
            out.row_mut(o).copy_from_slice(self.row(i));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.at(0, 2), 3.0);
        assert_eq!(m.at(1, 0), 4.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Matrix::from_vec(2, 2, vec![1.0]);
    }

    #[test]
    fn transpose_round_trip() {
        let mut rng = Rng::new(4);
        let m = Matrix::randn(37, 53, 1.0, &mut rng);
        let tt = m.transpose().transpose();
        assert_eq!(m, tt);
        assert_eq!(m.at(5, 7), m.transpose().at(7, 5));
    }

    #[test]
    fn elementwise_ops() {
        let a = Matrix::from_vec(1, 3, vec![1., -2., 3.]);
        let b = Matrix::from_vec(1, 3, vec![2., 2., 2.]);
        assert_eq!(a.add(&b).data, vec![3., 0., 5.]);
        assert_eq!(a.sub(&b).data, vec![-1., -4., 1.]);
        assert_eq!(a.hadamard(&b).data, vec![2., -4., 6.]);
        assert_eq!(a.scale(2.0).data, vec![2., -4., 6.]);
    }

    #[test]
    fn bias_and_colsum_are_adjoint() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let with_bias = a.add_bias(&[10., 20.]);
        assert_eq!(with_bias.data, vec![11., 22., 13., 24.]);
        assert_eq!(a.col_sum(), vec![4., 6.]);
    }

    #[test]
    fn max_merge_and_mask() {
        let a = Matrix::from_vec(1, 3, vec![1., 5., 2.]);
        let b = Matrix::from_vec(1, 3, vec![3., 4., 2.]);
        let (m, mask) = a.max_merge(&b);
        assert_eq!(m.data, vec![3., 5., 2.]);
        // ties go to self (>=)
        assert_eq!(mask.data, vec![0., 1., 1.]);
    }

    #[test]
    fn concat_split_round_trip() {
        let mut rng = Rng::new(8);
        let a = Matrix::randn(5, 3, 1.0, &mut rng);
        let b = Matrix::randn(5, 4, 1.0, &mut rng);
        let (a2, b2) = a.hconcat(&b).hsplit(3);
        assert_eq!(a, a2);
        assert_eq!(b, b2);
    }

    #[test]
    fn gather_rows_selects() {
        let m = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let g = m.gather_rows(&[2, 0]);
        assert_eq!(g.data, vec![5., 6., 1., 2.]);
    }
}
