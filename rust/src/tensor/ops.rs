//! Blocked, threaded dense matmul kernels.
//!
//! Three variants cover everything the manual backward passes need without
//! materialising transposes:
//!   * `matmul(A, B)      = A · B`
//!   * `matmul_at_b(A, B) = Aᵀ · B`   (weight gradients: Xᵀ · dY)
//!   * `matmul_a_bt(A, B) = A · Bᵀ`   (input gradients: dY · Wᵀ)
//!
//! The inner kernel is an i-k-j loop over the row-major layout (unit-stride
//! on B and C), parallelised over row blocks of the output.

use super::Matrix;
use crate::util::pool::parallel_for_chunks;

/// `C = A · B` with shape check.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul: inner dims {} vs {}", a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    let c_ptr = crate::util::pool::SendPtr(c.data.as_mut_ptr());
    parallel_for_chunks(m, |lo, hi| {
        let cp = c_ptr;
        for i in lo..hi {
            let arow = &a.data[i * k..(i + 1) * k];
            // SAFETY: row i of C is written only by this chunk's owner.
            let crow =
                unsafe { std::slice::from_raw_parts_mut(cp.0.add(i * n), n) };
            for (kk, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = &b.data[kk * n..(kk + 1) * n];
                axpy(aik, brow, crow);
            }
        }
    });
    c
}

/// `C = Aᵀ · B` where A is m×k, B is m×n, C is k×n.
/// Parallelised over k-blocks of the output, scanning A,B by rows.
pub fn matmul_at_b(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "matmul_at_b: outer dims {} vs {}", a.rows, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(k, n);
    let c_ptr = crate::util::pool::SendPtr(c.data.as_mut_ptr());
    // Each worker owns a contiguous block of C rows (i.e. columns of A).
    parallel_for_chunks(k, |lo, hi| {
        let cp = c_ptr;
        for row in 0..m {
            let arow = &a.data[row * k..(row + 1) * k];
            let brow = &b.data[row * n..(row + 1) * n];
            for kk in lo..hi {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue;
                }
                // SAFETY: C rows [lo,hi) owned exclusively by this worker.
                let crow =
                    unsafe { std::slice::from_raw_parts_mut(cp.0.add(kk * n), n) };
                axpy(aik, brow, crow);
            }
        }
    });
    c
}

/// `C = A · Bᵀ` where A is m×k, B is n×k, C is m×n. Dot-product kernel.
pub fn matmul_a_bt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "matmul_a_bt: inner dims {} vs {}", a.cols, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Matrix::zeros(m, n);
    let c_ptr = crate::util::pool::SendPtr(c.data.as_mut_ptr());
    parallel_for_chunks(m, |lo, hi| {
        let cp = c_ptr;
        for i in lo..hi {
            let arow = &a.data[i * k..(i + 1) * k];
            // SAFETY: C rows [lo,hi) owned exclusively by this worker; c
            // outlives the scoped threads.
            let crow =
                unsafe { std::slice::from_raw_parts_mut(cp.0.add(i * n), n) };
            for (j, cij) in crow.iter_mut().enumerate() {
                let brow = &b.data[j * k..(j + 1) * k];
                *cij = dot(arow, brow);
            }
        }
    });
    c
}

/// `y += alpha * x`, the innermost kernel. Written to auto-vectorise.
#[inline]
fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    // Chunked so LLVM emits fused SIMD without bounds checks.
    let n = x.len();
    let (x8, xr) = x.split_at(n - n % 8);
    let (y8, yr) = y.split_at_mut(n - n % 8);
    for (xc, yc) in x8.chunks_exact(8).zip(y8.chunks_exact_mut(8)) {
        for i in 0..8 {
            yc[i] += alpha * xc[i];
        }
    }
    for (xi, yi) in xr.iter().zip(yr.iter_mut()) {
        *yi += alpha * xi;
    }
}

/// Dense dot product.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let mut acc = [0.0f32; 8];
    let (x8, xr) = x.split_at(n - n % 8);
    let (y8, yr) = y.split_at(n - n % 8);
    for (xc, yc) in x8.chunks_exact(8).zip(y8.chunks_exact(8)) {
        for i in 0..8 {
            acc[i] += xc[i] * yc[i];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for (xi, yi) in xr.iter().zip(yr) {
        s += xi * yi;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::assert_allclose;
    use crate::util::rng::Rng;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(10);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (33, 17, 65), (128, 64, 32)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            let r = naive_matmul(&a, &b);
            assert_allclose(&c.data, &r.data, 1e-4, 1e-4);
        }
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let mut rng = Rng::new(11);
        let a = Matrix::randn(29, 13, 1.0, &mut rng);
        let b = Matrix::randn(29, 21, 1.0, &mut rng);
        let fast = matmul_at_b(&a, &b);
        let slow = matmul(&a.transpose(), &b);
        assert_allclose(&fast.data, &slow.data, 1e-4, 1e-4);
    }

    #[test]
    fn a_bt_matches_explicit_transpose() {
        let mut rng = Rng::new(12);
        let a = Matrix::randn(19, 23, 1.0, &mut rng);
        let b = Matrix::randn(31, 23, 1.0, &mut rng);
        let fast = matmul_a_bt(&a, &b);
        let slow = matmul(&a, &b.transpose());
        assert_allclose(&fast.data, &slow.data, 1e-4, 1e-4);
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(13);
        let a = Matrix::randn(9, 9, 1.0, &mut rng);
        let eye = Matrix::from_fn(9, 9, |r, c| if r == c { 1.0 } else { 0.0 });
        assert_allclose(&matmul(&a, &eye).data, &a.data, 1e-6, 0.0);
        assert_allclose(&matmul(&eye, &a).data, &a.data, 1e-6, 0.0);
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn shape_mismatch_panics() {
        matmul(&Matrix::zeros(2, 3), &Matrix::zeros(4, 2));
    }

    #[test]
    fn dot_small() {
        assert_eq!(dot(&[1., 2., 3.], &[4., 5., 6.]), 32.0);
        let x: Vec<f32> = (0..19).map(|i| i as f32).collect();
        let expect: f32 = x.iter().map(|v| v * v).sum();
        assert_eq!(dot(&x, &x), expect);
    }

    #[test]
    fn large_threaded_path() {
        let mut rng = Rng::new(14);
        let a = Matrix::randn(300, 40, 0.5, &mut rng);
        let b = Matrix::randn(40, 50, 0.5, &mut rng);
        let c = matmul(&a, &b);
        let r = naive_matmul(&a, &b);
        assert_allclose(&c.data, &r.data, 1e-3, 1e-3);
    }
}
