//! Losses.

use crate::tensor::Matrix;

/// Mean-squared error: returns `(loss, d_pred)` where
/// `loss = mean((pred − target)²)` and `d_pred = 2(pred − target)/N`.
pub fn mse(pred: &Matrix, target: &Matrix) -> (f32, Matrix) {
    assert_eq!((pred.rows, pred.cols), (target.rows, target.cols), "mse: shape mismatch");
    let n = pred.data.len().max(1) as f32;
    let mut grad = Matrix::zeros(pred.rows, pred.cols);
    let mut loss = 0f32;
    for i in 0..pred.data.len() {
        let diff = pred.data[i] - target.data[i];
        loss += diff * diff;
        grad.data[i] = 2.0 * diff / n;
    }
    (loss / n, grad)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_at_equality() {
        let a = Matrix::from_vec(2, 1, vec![1.0, 2.0]);
        let (l, g) = mse(&a, &a);
        assert_eq!(l, 0.0);
        assert!(g.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn matches_hand_computed() {
        let p = Matrix::from_vec(1, 2, vec![1.0, 3.0]);
        let t = Matrix::from_vec(1, 2, vec![0.0, 1.0]);
        let (l, g) = mse(&p, &t);
        assert!((l - (1.0 + 4.0) / 2.0).abs() < 1e-6);
        assert_eq!(g.data, vec![1.0, 2.0]);
    }

    #[test]
    fn gradient_finite_difference() {
        let p = Matrix::from_vec(1, 3, vec![0.5, -1.0, 2.0]);
        let t = Matrix::from_vec(1, 3, vec![1.0, 0.0, 2.0]);
        let (_, g) = mse(&p, &t);
        let eps = 1e-3f32;
        for i in 0..3 {
            let mut pp = p.clone();
            pp.data[i] += eps;
            let mut pm = p.clone();
            pm.data[i] -= eps;
            let fd = (mse(&pp, &t).0 - mse(&pm, &t).0) / (2.0 * eps);
            assert!((fd - g.data[i]).abs() < 1e-3);
        }
    }
}
