//! Dense linear layer with manual backward.

use super::Param;
use crate::tensor::{matmul, matmul_a_bt, matmul_at_b, Matrix};
use crate::util::rng::Rng;

/// `y = x · W + b`, caching `x` for the backward pass.
#[derive(Clone, Debug)]
pub struct Linear {
    pub w: Param,
    pub b: Param,
    cached_x: Option<Matrix>,
}

impl Linear {
    pub fn new(d_in: usize, d_out: usize, rng: &mut Rng) -> Linear {
        Linear {
            w: Param::new(Matrix::he_init(d_in, d_out, rng)),
            b: Param::new(Matrix::zeros(1, d_out)),
            cached_x: None,
        }
    }

    pub fn forward(&mut self, x: &Matrix) -> Matrix {
        let y = matmul(x, &self.w.value).add_bias(&self.b.value.data);
        self.cached_x = Some(x.clone());
        y
    }

    /// Inference-only forward (no cache).
    pub fn forward_inference(&self, x: &Matrix) -> Matrix {
        matmul(x, &self.w.value).add_bias(&self.b.value.data)
    }

    /// Accumulates dW, db; returns dX.
    pub fn backward(&mut self, dy: &Matrix) -> Matrix {
        let x = self.cached_x.as_ref().expect("backward before forward");
        self.w.grad.add_inplace(&matmul_at_b(x, dy));
        let db = dy.col_sum();
        for (g, d) in self.b.grad.data.iter_mut().zip(&db) {
            *g += d;
        }
        matmul_a_bt(dy, &self.w.value)
    }

    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        vec![&mut self.w, &mut self.b]
    }

    pub fn numel(&self) -> usize {
        self.w.numel() + self.b.numel()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::assert_allclose;

    /// loss = sum(y) finite-difference check of dW, db, dX.
    #[test]
    fn finite_difference_gradients() {
        let mut rng = Rng::new(1);
        let mut layer = Linear::new(4, 3, &mut rng);
        let x = Matrix::randn(5, 4, 1.0, &mut rng);
        let _ = layer.forward(&x);
        let dy = Matrix::ones(5, 3);
        let dx = layer.backward(&dy);
        let eps = 1e-3f32;

        // dW
        for i in 0..layer.w.value.data.len() {
            let mut lp = layer.clone();
            lp.w.value.data[i] += eps;
            let mut lm = layer.clone();
            lm.w.value.data[i] -= eps;
            let fp: f32 = lp.forward_inference(&x).data.iter().sum();
            let fm: f32 = lm.forward_inference(&x).data.iter().sum();
            let fd = (fp - fm) / (2.0 * eps);
            assert!(
                (fd - layer.w.grad.data[i]).abs() < 2e-2,
                "dW[{i}]: fd {fd} vs {}",
                layer.w.grad.data[i]
            );
        }
        // db
        for i in 0..3 {
            let mut lp = layer.clone();
            lp.b.value.data[i] += eps;
            let mut lm = layer.clone();
            lm.b.value.data[i] -= eps;
            let fp: f32 = lp.forward_inference(&x).data.iter().sum();
            let fm: f32 = lm.forward_inference(&x).data.iter().sum();
            let fd = (fp - fm) / (2.0 * eps);
            assert!((fd - layer.b.grad.data[i]).abs() < 2e-2);
        }
        // dX
        for i in 0..x.data.len() {
            let mut xp = x.clone();
            xp.data[i] += eps;
            let mut xm = x.clone();
            xm.data[i] -= eps;
            let fp: f32 = layer.forward_inference(&xp).data.iter().sum();
            let fm: f32 = layer.forward_inference(&xm).data.iter().sum();
            let fd = (fp - fm) / (2.0 * eps);
            assert!((fd - dx.data[i]).abs() < 2e-2, "dX[{i}]");
        }
    }

    #[test]
    fn forward_matches_inference() {
        let mut rng = Rng::new(2);
        let mut layer = Linear::new(6, 2, &mut rng);
        let x = Matrix::randn(3, 6, 1.0, &mut rng);
        let a = layer.forward(&x);
        let b = layer.forward_inference(&x);
        assert_allclose(&a.data, &b.data, 1e-6, 0.0);
    }

    #[test]
    fn grads_accumulate_across_calls() {
        let mut rng = Rng::new(3);
        let mut layer = Linear::new(2, 2, &mut rng);
        let x = Matrix::ones(1, 2);
        let dy = Matrix::ones(1, 2);
        let _ = layer.forward(&x);
        layer.backward(&dy);
        let g1 = layer.w.grad.clone();
        let _ = layer.forward(&x);
        layer.backward(&dy);
        assert_allclose(&layer.w.grad.data, &g1.scale(2.0).data, 1e-6, 0.0);
    }

    #[test]
    #[should_panic(expected = "backward before forward")]
    fn backward_without_forward_panics() {
        let mut rng = Rng::new(4);
        let mut layer = Linear::new(2, 2, &mut rng);
        layer.backward(&Matrix::ones(1, 2));
    }
}
