//! Activations: plain ReLU (baselines) and the D-ReLU gate (paper §3.1).

use crate::graph::Cbsr;
use crate::sparse::{drelu, drelu_backward};
use crate::tensor::Matrix;

/// Standard ReLU with cached mask.
#[derive(Clone, Debug, Default)]
pub struct Relu {
    mask: Option<Vec<bool>>,
}

impl Relu {
    pub fn new() -> Relu {
        Relu { mask: None }
    }

    pub fn forward(&mut self, x: &Matrix) -> Matrix {
        let mut y = x.clone();
        let mut mask = vec![false; x.data.len()];
        for (i, v) in y.data.iter_mut().enumerate() {
            if *v > 0.0 {
                mask[i] = true;
            } else {
                *v = 0.0;
            }
        }
        self.mask = Some(mask);
        y
    }

    /// Cache-free forward (checkpointed paths recompute the mask later).
    /// Bit-identical to [`Relu::forward`].
    pub fn forward_inference(&self, x: &Matrix) -> Matrix {
        let mut y = x.clone();
        for v in y.data.iter_mut() {
            if *v <= 0.0 {
                *v = 0.0;
            }
        }
        y
    }

    pub fn backward(&self, dy: &Matrix) -> Matrix {
        let mask = self.mask.as_ref().expect("backward before forward");
        let mut dx = dy.clone();
        for (g, &m) in dx.data.iter_mut().zip(mask) {
            if !m {
                *g = 0.0;
            }
        }
        dx
    }
}

/// D-ReLU gate: row-wise top-k sparsification producing a CBSR activation.
///
/// Forward yields the CBSR (fed straight into DR-SpMM); backward masks the
/// upstream gradient to the kept coordinates (eq. 3's subgradient).
#[derive(Clone, Debug)]
pub struct DReluGate {
    pub k: usize,
    cached: Option<Cbsr>,
}

impl DReluGate {
    pub fn new(k: usize) -> DReluGate {
        DReluGate { k, cached: None }
    }

    pub fn forward(&mut self, x: &Matrix) -> Cbsr {
        let out = drelu(x, self.k.min(x.cols));
        self.cached = Some(out.clone());
        out
    }

    /// Dense upstream gradient → dense input gradient (masked).
    pub fn backward(&self, dy: &Matrix) -> Matrix {
        let fwd = self.cached.as_ref().expect("backward before forward");
        drelu_backward(dy, fwd)
    }

    /// Compressed upstream gradient (aligned with the forward CBSR) →
    /// dense input gradient. Used when the consumer was DR-SpMM whose
    /// backward already returns CBSR-shaped gradients.
    pub fn backward_compressed(&self, dy: &Cbsr) -> Matrix {
        let fwd = self.cached.as_ref().expect("backward before forward");
        assert_eq!(dy.n, fwd.n);
        assert_eq!(dy.k, fwd.k);
        assert_eq!(dy.indices, fwd.indices, "gradient must align with forward CBSR");
        dy.to_dense()
    }

    pub fn cached(&self) -> Option<&Cbsr> {
        self.cached.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn relu_forward_backward() {
        let mut relu = Relu::new();
        let x = Matrix::from_vec(1, 4, vec![-1.0, 2.0, 0.0, 3.0]);
        let y = relu.forward(&x);
        assert_eq!(y.data, vec![0.0, 2.0, 0.0, 3.0]);
        let dx = relu.backward(&Matrix::ones(1, 4));
        assert_eq!(dx.data, vec![0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn drelu_gate_roundtrip() {
        let mut rng = Rng::new(1);
        let mut gate = DReluGate::new(3);
        let x = Matrix::randn(6, 10, 1.0, &mut rng);
        let c = gate.forward(&x);
        assert_eq!(c.k, 3);
        let dy = Matrix::ones(6, 10);
        let dx = gate.backward(&dy);
        // Gradient only at kept positions: 3 per row.
        for r in 0..6 {
            assert_eq!(dx.row(r).iter().filter(|&&v| v != 0.0).count(), 3);
        }
    }

    #[test]
    fn drelu_gate_clamps_k_to_dim() {
        let mut gate = DReluGate::new(100);
        let x = Matrix::ones(2, 4);
        let c = gate.forward(&x);
        assert_eq!(c.k, 4);
    }

    #[test]
    fn compressed_backward_matches_dense() {
        let mut rng = Rng::new(2);
        let mut gate = DReluGate::new(2);
        let x = Matrix::randn(4, 6, 1.0, &mut rng);
        let fwd = gate.forward(&x);
        // A CBSR gradient aligned with fwd.
        let mut gc = fwd.clone();
        for v in gc.values.iter_mut() {
            *v = 1.0;
        }
        let via_compressed = gate.backward_compressed(&gc);
        // Dense equivalent: ones at kept positions.
        let dy = Matrix::ones(4, 6);
        let via_dense = gate.backward(&dy);
        assert_eq!(via_compressed.data, via_dense.data);
    }
}
