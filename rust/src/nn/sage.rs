//! SageConv (GraphSAGE-mean) — the HeteroConv's pins/pinned modules.
//!
//! `Y = X_dst · W_self + (Ā · X_src) · W_neigh + b` with Ā row-normalised
//! (mean aggregation). In the heterogeneous case the destination and source
//! node sets differ (`pins`: cells → nets), so the layer takes both feature
//! matrices. The heterogeneous path aggregates through the engine and uses
//! [`SageConv::forward_from_agg`]; the homogeneous baseline runs the fused
//! path against a cached [`KernelPlan`].

use super::Param;
use crate::engine::{AggCache, CsrKernel, KernelPlan, SpmmKernel};
use crate::tensor::{matmul, matmul_a_bt, matmul_at_b, Matrix};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SageConv {
    pub w_self: Param,
    pub w_neigh: Param,
    pub b: Param,
    cached_x_dst: Option<Matrix>,
    cached_h: Option<Matrix>,
}

impl SageConv {
    /// `d_src` — source feature width; `d_dst` — destination feature width.
    pub fn new(d_src: usize, d_dst: usize, d_out: usize, rng: &mut Rng) -> SageConv {
        SageConv {
            w_self: Param::new(Matrix::he_init(d_dst, d_out, rng)),
            w_neigh: Param::new(Matrix::he_init(d_src, d_out, rng)),
            b: Param::new(Matrix::zeros(1, d_out)),
            cached_x_dst: None,
            cached_h: None,
        }
    }

    /// Forward from a precomputed aggregation `h = Ā · X_src` (lets the
    /// heterogeneous engine swap kernels).
    pub fn forward_from_agg(&mut self, x_dst: &Matrix, h: Matrix) -> Matrix {
        let y = matmul(x_dst, &self.w_self.value)
            .add(&matmul(&h, &self.w_neigh.value))
            .add_bias(&self.b.value.data);
        self.cached_x_dst = Some(x_dst.clone());
        self.cached_h = Some(h);
        y
    }

    /// Cache-free variant of [`SageConv::forward_from_agg`] for
    /// checkpointed forwards (bit-identical output, nothing stored).
    pub fn forward_from_agg_inference(&self, x_dst: &Matrix, h: &Matrix) -> Matrix {
        matmul(x_dst, &self.w_self.value)
            .add(&matmul(h, &self.w_neigh.value))
            .add_bias(&self.b.value.data)
    }

    /// Fused forward against a planned adjacency.
    pub fn forward(&mut self, plan: &KernelPlan, x_src: &Matrix, x_dst: &Matrix) -> Matrix {
        let (h, _) = CsrKernel.forward(plan, x_src, None);
        self.forward_from_agg(x_dst, h)
    }

    /// Backward: accumulates weight grads; returns `(dX_dst, dH)` where the
    /// caller turns dH into dX_src via its aggregation backward.
    pub fn backward_to_agg(&mut self, dy: &Matrix) -> (Matrix, Matrix) {
        let x_dst = self.cached_x_dst.as_ref().expect("backward before forward");
        let h = self.cached_h.as_ref().expect("backward before forward");
        self.w_self.grad.add_inplace(&matmul_at_b(x_dst, dy));
        self.w_neigh.grad.add_inplace(&matmul_at_b(h, dy));
        for (g, d) in self.b.grad.data.iter_mut().zip(dy.col_sum()) {
            *g += d;
        }
        let dx_dst = matmul_a_bt(dy, &self.w_self.value);
        let dh = matmul_a_bt(dy, &self.w_neigh.value);
        (dx_dst, dh)
    }

    /// Full dense backward against the planned adjacency:
    /// returns (dX_dst, dX_src).
    pub fn backward(&mut self, plan: &KernelPlan, dy: &Matrix) -> (Matrix, Matrix) {
        let (dx_dst, dh) = self.backward_to_agg(dy);
        let dx_src = CsrKernel.backward(plan, &dh, &AggCache::None).into_dense();
        (dx_dst, dx_src)
    }

    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        vec![&mut self.w_self, &mut self.w_neigh, &mut self.b]
    }

    pub fn numel(&self) -> usize {
        self.w_self.numel() + self.w_neigh.numel() + self.b.numel()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;

    /// Bipartite adjacency: 3 dst rows, 4 src cols.
    fn bip() -> KernelPlan {
        let mut m = Csr::from_triplets(
            3,
            4,
            &[(0, 0, 1.0), (0, 1, 1.0), (1, 2, 1.0), (2, 1, 1.0), (2, 3, 1.0)],
        );
        m.normalize_rows();
        CsrKernel.plan(m)
    }

    #[test]
    fn forward_shapes_hetero() {
        let mut rng = Rng::new(1);
        let mut layer = SageConv::new(5, 6, 2, &mut rng);
        let x_src = Matrix::randn(4, 5, 1.0, &mut rng);
        let x_dst = Matrix::randn(3, 6, 1.0, &mut rng);
        let y = layer.forward(&bip(), &x_src, &x_dst);
        assert_eq!((y.rows, y.cols), (3, 2));
    }

    #[test]
    fn finite_difference_all_grads() {
        let mut rng = Rng::new(2);
        let plan = bip();
        let mut layer = SageConv::new(3, 4, 2, &mut rng);
        let x_src = Matrix::randn(4, 3, 1.0, &mut rng);
        let x_dst = Matrix::randn(3, 4, 1.0, &mut rng);
        let _ = layer.forward(&plan, &x_src, &x_dst);
        let dy = Matrix::ones(3, 2);
        let (dx_dst, dx_src) = layer.backward(&plan, &dy);
        let eps = 1e-3f32;
        let loss = |l: &SageConv, xs: &Matrix, xd: &Matrix| -> f32 {
            let (h, _) = CsrKernel.forward(&plan, xs, None);
            matmul(xd, &l.w_self.value)
                .add(&matmul(&h, &l.w_neigh.value))
                .add_bias(&l.b.value.data)
                .data
                .iter()
                .sum()
        };
        for i in 0..layer.w_neigh.value.data.len() {
            let mut lp = layer.clone();
            lp.w_neigh.value.data[i] += eps;
            let mut lm = layer.clone();
            lm.w_neigh.value.data[i] -= eps;
            let fd = (loss(&lp, &x_src, &x_dst) - loss(&lm, &x_src, &x_dst)) / (2.0 * eps);
            assert!((fd - layer.w_neigh.grad.data[i]).abs() < 2e-2, "dW_neigh[{i}]");
        }
        for i in 0..x_src.data.len() {
            let mut xp = x_src.clone();
            xp.data[i] += eps;
            let mut xm = x_src.clone();
            xm.data[i] -= eps;
            let fd = (loss(&layer, &xp, &x_dst) - loss(&layer, &xm, &x_dst)) / (2.0 * eps);
            assert!((fd - dx_src.data[i]).abs() < 2e-2, "dX_src[{i}]");
        }
        for i in 0..x_dst.data.len() {
            let mut xp = x_dst.clone();
            xp.data[i] += eps;
            let mut xm = x_dst.clone();
            xm.data[i] -= eps;
            let fd = (loss(&layer, &x_src, &xp) - loss(&layer, &x_src, &xm)) / (2.0 * eps);
            assert!((fd - dx_dst.data[i]).abs() < 2e-2, "dX_dst[{i}]");
        }
    }

    #[test]
    fn param_count() {
        let mut rng = Rng::new(3);
        let layer = SageConv::new(3, 4, 2, &mut rng);
        assert_eq!(layer.numel(), 3 * 2 + 4 * 2 + 2);
    }
}
