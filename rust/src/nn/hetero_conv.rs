//! HeteroConv — one heterogeneous message-passing block (paper Fig. 1, §3.2).
//!
//! Three submodules, one per edge type:
//! * `near`   (cell→cell): [`GraphConv`]
//! * `pinned` (net→cell):  [`SageConv`]
//! * `pins`   (cell→net):  [`SageConv`]
//!
//! The two cell-side updates merge with element-wise `max` (eq. 8); the
//! backward pass routes gradient through the cached argmax mask (eqs. 12–14).
//!
//! The aggregation kernel is pluggable via [`MessageEngine`], which is how
//! the benchmarks swap cuSPARSE-analog / GNNA-analog / DR-SpMM paths, and
//! `parallel` mode runs the three edge-type aggregations concurrently —
//! the §3.4 cudaStream analog (see also [`crate::sched`]).

use super::gcn::GraphConv;
use super::sage::SageConv;
use crate::graph::{Cbsr, Csc, Csr, EdgeType, HeteroGraph};
use crate::sparse::{
    dr_spmm, dr_spmm_bwd, drelu, spmm_csr, spmm_csr_bwd, spmm_gnna, spmm_gnna_bwd, DegreeBuckets,
    GnnaConfig,
};
use crate::tensor::Matrix;
use crate::util::pool::join_all;
use crate::util::rng::Rng;

/// Pre-processed per-graph state: normalised adjacencies, their CSC forms
/// and degree-bucket schedules (paper Alg. 1 stage 1 — built once).
#[derive(Clone, Debug)]
pub struct GraphCtx {
    /// GCN-normalised near (cell→cell).
    pub near: Csr,
    pub near_csc: Csc,
    pub near_buckets: DegreeBuckets,
    /// Row-normalised pinned (net→cell destination-major).
    pub pinned: Csr,
    pub pinned_csc: Csc,
    pub pinned_buckets: DegreeBuckets,
    /// Row-normalised pins (cell→net destination-major).
    pub pins: Csr,
    pub pins_csc: Csc,
    pub pins_buckets: DegreeBuckets,
}

impl GraphCtx {
    pub fn new(g: &HeteroGraph) -> GraphCtx {
        let mut near = g.near.clone();
        near.normalize_gcn();
        let mut pinned = g.pinned.clone();
        pinned.normalize_rows();
        let mut pins = g.pins.clone();
        pins.normalize_rows();
        GraphCtx {
            near_csc: near.to_csc(),
            near_buckets: DegreeBuckets::build(&near),
            near,
            pinned_csc: pinned.to_csc(),
            pinned_buckets: DegreeBuckets::build(&pinned),
            pinned,
            pins_csc: pins.to_csc(),
            pins_buckets: DegreeBuckets::build(&pins),
            pins,
        }
    }

    pub fn adj(&self, e: EdgeType) -> (&Csr, &Csc, &DegreeBuckets) {
        match e {
            EdgeType::Near => (&self.near, &self.near_csc, &self.near_buckets),
            EdgeType::Pinned => (&self.pinned, &self.pinned_csc, &self.pinned_buckets),
            EdgeType::Pins => (&self.pins, &self.pins_csc, &self.pins_buckets),
        }
    }
}

/// The pluggable aggregation kernel.
#[derive(Clone, Debug)]
pub enum MessageEngine {
    /// cuSPARSE-analog dense SpMM (the DGL baseline path).
    Csr,
    /// GNNAdvisor-analog neighbor-group SpMM.
    Gnna(GnnaConfig),
    /// The paper's path: D-ReLU sparsification + DR-SpMM, with node-type
    /// specific K values (§3.1: different K for cell and net embeddings).
    Dr { k_cell: usize, k_net: usize },
}

impl MessageEngine {
    pub fn dr(k_cell: usize, k_net: usize) -> MessageEngine {
        MessageEngine::Dr { k_cell, k_net }
    }

    pub fn name(&self) -> &'static str {
        match self {
            MessageEngine::Csr => "cuSPARSE",
            MessageEngine::Gnna(_) => "GNNA",
            MessageEngine::Dr { .. } => "DR-SpMM",
        }
    }

    /// Sparsify one node type's embedding (D-ReLU → CBSR), shared by every
    /// edge whose source is that type — the paper applies D-ReLU *per node
    /// type per layer*, not per edge (§Perf L3-2: sparsifying `x_cell` once
    /// for both `near` and `pins` instead of twice).
    pub fn sparsify(
        &self,
        x: &Matrix,
        nt: crate::graph::NodeType,
    ) -> Option<std::sync::Arc<Cbsr>> {
        match (self, nt) {
            (MessageEngine::Dr { k_cell, .. }, crate::graph::NodeType::Cell) => {
                Some(std::sync::Arc::new(drelu(x, (*k_cell).clamp(1, x.cols))))
            }
            (MessageEngine::Dr { k_net, .. }, crate::graph::NodeType::Net) => {
                Some(std::sync::Arc::new(drelu(x, (*k_net).clamp(1, x.cols))))
            }
            _ => None,
        }
    }

    /// Aggregate `h = Ā · x_src` for one edge type; returns the dense
    /// aggregate plus the cache its backward needs. Convenience wrapper
    /// that sparsifies internally — hot paths use [`Self::aggregate_with`].
    pub fn aggregate(&self, ctx: &GraphCtx, e: EdgeType, x_src: &Matrix) -> (Matrix, AggCache) {
        let prep = self.sparsify(x_src, e.endpoints().0);
        self.aggregate_with(ctx, e, x_src, prep.as_ref())
    }

    /// Aggregate with a pre-sparsified source (see [`Self::sparsify`]).
    pub fn aggregate_with(
        &self,
        ctx: &GraphCtx,
        e: EdgeType,
        x_src: &Matrix,
        prep: Option<&std::sync::Arc<Cbsr>>,
    ) -> (Matrix, AggCache) {
        let (adj, _, buckets) = ctx.adj(e);
        match self {
            MessageEngine::Csr => (spmm_csr(adj, x_src), AggCache::None),
            MessageEngine::Gnna(cfg) => (spmm_gnna(adj, x_src, cfg), AggCache::None),
            MessageEngine::Dr { .. } => {
                let compressed =
                    prep.expect("DR aggregation requires a sparsified source").clone();
                let h = dr_spmm(adj, &compressed, buckets);
                (h, AggCache::Cbsr(compressed))
            }
        }
    }

    /// Backward of the aggregation: `dX_src = Āᵀ · dH` (dense), using the
    /// forward cache. For DR, gradient is masked to the CBSR support — the
    /// D-ReLU subgradient (Alg. 2 reusing forward indices).
    pub fn aggregate_backward(
        &self,
        ctx: &GraphCtx,
        e: EdgeType,
        dh: &Matrix,
        cache: &AggCache,
    ) -> Matrix {
        let (_, csc, _) = ctx.adj(e);
        match (self, cache) {
            (MessageEngine::Csr, _) => spmm_csr_bwd(csc, dh),
            (MessageEngine::Gnna(cfg), _) => spmm_gnna_bwd(csc, dh, cfg),
            (MessageEngine::Dr { .. }, AggCache::Cbsr(fwd)) => {
                dr_spmm_bwd(csc, dh, fwd).to_dense()
            }
            (MessageEngine::Dr { .. }, AggCache::None) => {
                panic!("DR backward requires the forward CBSR cache")
            }
        }
    }
}

/// Forward-pass cache per aggregation. The CBSR is shared (`Arc`) between
/// the edges that consume the same node type's sparsified embedding.
#[derive(Clone, Debug)]
pub enum AggCache {
    None,
    Cbsr(std::sync::Arc<Cbsr>),
}

/// One heterogeneous convolution block.
#[derive(Clone, Debug)]
pub struct HeteroConv {
    /// cell→cell module.
    pub near: GraphConv,
    /// net→cell module.
    pub pinned: SageConv,
    /// cell→net module.
    pub pins: SageConv,
    /// Run the three edge-type aggregations concurrently (§3.4).
    pub parallel: bool,
    /// Cached argmax mask of the cell-side max merge.
    mask: Option<Matrix>,
    caches: Option<[AggCache; 3]>,
}

impl HeteroConv {
    /// `d_cell`/`d_net` input widths; both outputs have width `d_out`.
    pub fn new(d_cell: usize, d_net: usize, d_out: usize, rng: &mut Rng) -> HeteroConv {
        HeteroConv {
            near: GraphConv::new(d_cell, d_out, rng),
            pinned: SageConv::new(d_net, d_cell, d_out, rng),
            pins: SageConv::new(d_cell, d_net, d_out, rng),
            parallel: false,
            mask: None,
            caches: None,
        }
    }

    /// Forward: returns `(y_cell, y_net)`.
    pub fn forward(
        &mut self,
        ctx: &GraphCtx,
        engine: &MessageEngine,
        x_cell: &Matrix,
        x_net: &Matrix,
    ) -> (Matrix, Matrix) {
        // D-ReLU once per node type (paper §3.1), then three independent
        // SpMM aggregations — the §3.4 concurrency opportunity.
        let prep_cell = engine.sparsify(x_cell, crate::graph::NodeType::Cell);
        let prep_net = engine.sparsify(x_net, crate::graph::NodeType::Net);
        let [(h_near, c_near), (h_pinned, c_pinned), (h_pins, c_pins)] = if self.parallel {
            let results = join_all(vec![
                Box::new(|| engine.aggregate_with(ctx, EdgeType::Near, x_cell, prep_cell.as_ref()))
                    as Box<dyn FnOnce() -> (Matrix, AggCache) + Send>,
                Box::new(|| {
                    engine.aggregate_with(ctx, EdgeType::Pinned, x_net, prep_net.as_ref())
                }),
                Box::new(|| engine.aggregate_with(ctx, EdgeType::Pins, x_cell, prep_cell.as_ref())),
            ]);
            let mut it = results.into_iter();
            [it.next().unwrap(), it.next().unwrap(), it.next().unwrap()]
        } else {
            [
                engine.aggregate_with(ctx, EdgeType::Near, x_cell, prep_cell.as_ref()),
                engine.aggregate_with(ctx, EdgeType::Pinned, x_net, prep_net.as_ref()),
                engine.aggregate_with(ctx, EdgeType::Pins, x_cell, prep_cell.as_ref()),
            ]
        };
        let y_near = self.near.forward_from_agg(h_near);
        let y_pinned = self.pinned.forward_from_agg(x_cell, h_pinned);
        let y_net = self.pins.forward_from_agg(x_net, h_pins);
        // eq. 8: cell receives max(near-update, pinned-update).
        let (y_cell, mask) = y_near.max_merge(&y_pinned);
        self.mask = Some(mask);
        self.caches = Some([c_near, c_pinned, c_pins]);
        (y_cell, y_net)
    }

    /// Backward: returns `(dx_cell, dx_net)` and accumulates module grads.
    pub fn backward(
        &mut self,
        ctx: &GraphCtx,
        engine: &MessageEngine,
        dy_cell: &Matrix,
        dy_net: &Matrix,
    ) -> (Matrix, Matrix) {
        let mask = self.mask.take().expect("backward before forward");
        let caches = self.caches.take().expect("backward before forward");
        // eqs. 12–14: route the cell gradient through the max mask.
        let d_near_out = dy_cell.hadamard(&mask);
        let d_pinned_out = dy_cell.zip_map(&mask, |g, m| g * (1.0 - m));

        // Module backward up to the aggregations (dense matmuls).
        let dh_near = self.near.backward_to_agg(&d_near_out);
        let (dx_cell_self, dh_pinned) = self.pinned.backward_to_agg(&d_pinned_out);
        let (dx_net_self, dh_pins) = self.pins.backward_to_agg(dy_net);

        // Aggregation backward (the SpMM-heavy part) — parallelisable.
        let [c_near, c_pinned, c_pins] = &caches;
        let (g_near, g_pinned, g_pins) = if self.parallel {
            let results = join_all(vec![
                Box::new(|| engine.aggregate_backward(ctx, EdgeType::Near, &dh_near, c_near))
                    as Box<dyn FnOnce() -> Matrix + Send>,
                Box::new(|| {
                    engine.aggregate_backward(ctx, EdgeType::Pinned, &dh_pinned, c_pinned)
                }),
                Box::new(|| engine.aggregate_backward(ctx, EdgeType::Pins, &dh_pins, c_pins)),
            ]);
            let mut it = results.into_iter();
            (it.next().unwrap(), it.next().unwrap(), it.next().unwrap())
        } else {
            (
                engine.aggregate_backward(ctx, EdgeType::Near, &dh_near, c_near),
                engine.aggregate_backward(ctx, EdgeType::Pinned, &dh_pinned, c_pinned),
                engine.aggregate_backward(ctx, EdgeType::Pins, &dh_pins, c_pins),
            )
        };
        // dX_cell: near aggregation (cell src) + pinned self-path (cell dst)
        //          + pins aggregation (cell src).
        let mut dx_cell = g_near;
        dx_cell.add_inplace(&dx_cell_self);
        dx_cell.add_inplace(&g_pins);
        // dX_net: pinned aggregation (net src) + pins self-path (net dst).
        let mut dx_net = g_pinned;
        dx_net.add_inplace(&dx_net_self);
        (dx_cell, dx_net)
    }

    pub fn params_mut(&mut self) -> Vec<&mut super::Param> {
        let mut p = self.near.params_mut();
        p.extend(self.pinned.params_mut());
        p.extend(self.pins.params_mut());
        p
    }

    pub fn numel(&self) -> usize {
        self.near.numel() + self.pinned.numel() + self.pins.numel()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::assert_allclose;

    fn toy() -> HeteroGraph {
        let near = Csr::from_triplets(
            3,
            3,
            &[(0, 1, 1.0), (1, 0, 1.0), (1, 2, 1.0), (2, 1, 1.0)],
        );
        let pins =
            Csr::from_triplets(2, 3, &[(0, 0, 1.0), (0, 1, 1.0), (1, 1, 1.0), (1, 2, 1.0)]);
        let pinned = pins.transpose();
        HeteroGraph {
            id: 0,
            n_cells: 3,
            n_nets: 2,
            near,
            pins,
            pinned,
            x_cell: Matrix::from_vec(3, 4, (0..12).map(|i| (i as f32) / 6.0 - 1.0).collect()),
            x_net: Matrix::from_vec(2, 4, (0..8).map(|i| (i as f32) / 4.0 - 1.0).collect()),
            y_cell: Matrix::zeros(3, 1),
        }
    }

    #[test]
    fn forward_shapes_all_engines() {
        let g = toy();
        let ctx = GraphCtx::new(&g);
        let mut rng = Rng::new(1);
        for engine in [
            MessageEngine::Csr,
            MessageEngine::Gnna(GnnaConfig::default()),
            MessageEngine::dr(2, 2),
        ] {
            let mut layer = HeteroConv::new(4, 4, 5, &mut rng);
            let (yc, yn) = layer.forward(&ctx, &engine, &g.x_cell, &g.x_net);
            assert_eq!((yc.rows, yc.cols), (3, 5), "{}", engine.name());
            assert_eq!((yn.rows, yn.cols), (2, 5), "{}", engine.name());
        }
    }

    #[test]
    fn parallel_forward_bitwise_equals_sequential() {
        let g = toy();
        let ctx = GraphCtx::new(&g);
        let mut rng = Rng::new(2);
        let layer = HeteroConv::new(4, 4, 6, &mut rng);
        for engine in [MessageEngine::Csr, MessageEngine::dr(2, 3)] {
            let mut seq = layer.clone();
            seq.parallel = false;
            let mut par = layer.clone();
            par.parallel = true;
            let (yc1, yn1) = seq.forward(&ctx, &engine, &g.x_cell, &g.x_net);
            let (yc2, yn2) = par.forward(&ctx, &engine, &g.x_cell, &g.x_net);
            assert_eq!(yc1.data, yc2.data, "{}", engine.name());
            assert_eq!(yn1.data, yn2.data);
            // And backward too.
            let dyc = Matrix::ones(3, 6);
            let dyn_ = Matrix::ones(2, 6);
            let (a1, b1) = seq.backward(&ctx, &engine, &dyc, &dyn_);
            let (a2, b2) = par.backward(&ctx, &engine, &dyc, &dyn_);
            assert_eq!(a1.data, a2.data);
            assert_eq!(b1.data, b2.data);
        }
    }

    /// Finite-difference check of the full block (inputs + a weight) for
    /// the dense engine (the DR engine's D-ReLU is piecewise constant in
    /// its index set, so FD holds a.e. — checked separately with fixed k=D).
    #[test]
    fn finite_difference_inputs_csr_engine() {
        let g = toy();
        let ctx = GraphCtx::new(&g);
        let mut rng = Rng::new(3);
        let layer0 = HeteroConv::new(4, 4, 3, &mut rng);
        let engine = MessageEngine::Csr;
        let mut layer = layer0.clone();
        let _ = layer.forward(&ctx, &engine, &g.x_cell, &g.x_net);
        let dyc = Matrix::ones(3, 3);
        let dyn_ = Matrix::ones(2, 3);
        let (dxc, dxn) = layer.backward(&ctx, &engine, &dyc, &dyn_);
        let eps = 1e-3f32;
        let loss = |xc: &Matrix, xn: &Matrix| -> f32 {
            let mut l = layer0.clone();
            let (yc, yn) = l.forward(&ctx, &engine, xc, xn);
            yc.data.iter().sum::<f32>() + yn.data.iter().sum::<f32>()
        };
        for i in 0..g.x_cell.data.len() {
            let mut xp = g.x_cell.clone();
            xp.data[i] += eps;
            let mut xm = g.x_cell.clone();
            xm.data[i] -= eps;
            let fd = (loss(&xp, &g.x_net) - loss(&xm, &g.x_net)) / (2.0 * eps);
            assert!((fd - dxc.data[i]).abs() < 3e-2, "dx_cell[{i}]: {fd} vs {}", dxc.data[i]);
        }
        for i in 0..g.x_net.data.len() {
            let mut xp = g.x_net.clone();
            xp.data[i] += eps;
            let mut xm = g.x_net.clone();
            xm.data[i] -= eps;
            let fd = (loss(&g.x_cell, &xp) - loss(&g.x_cell, &xm)) / (2.0 * eps);
            assert!((fd - dxn.data[i]).abs() < 3e-2, "dx_net[{i}]: {fd} vs {}", dxn.data[i]);
        }
    }

    /// With k = D the DR engine must agree exactly with the dense path.
    #[test]
    fn dr_engine_full_k_matches_csr_engine() {
        let g = toy();
        let ctx = GraphCtx::new(&g);
        let mut rng = Rng::new(4);
        let layer0 = HeteroConv::new(4, 4, 3, &mut rng);
        let mut a = layer0.clone();
        let mut b = layer0.clone();
        let (yc1, yn1) = a.forward(&ctx, &MessageEngine::Csr, &g.x_cell, &g.x_net);
        let (yc2, yn2) = b.forward(&ctx, &MessageEngine::dr(4, 4), &g.x_cell, &g.x_net);
        assert_allclose(&yc1.data, &yc2.data, 1e-5, 1e-5);
        assert_allclose(&yn1.data, &yn2.data, 1e-5, 1e-5);
        let dyc = Matrix::ones(3, 3);
        let dyn_ = Matrix::ones(2, 3);
        let (ga1, gb1) = a.backward(&ctx, &MessageEngine::Csr, &dyc, &dyn_);
        let (ga2, gb2) = b.backward(&ctx, &MessageEngine::dr(4, 4), &dyc, &dyn_);
        assert_allclose(&ga1.data, &ga2.data, 1e-5, 1e-5);
        assert_allclose(&gb1.data, &gb2.data, 1e-5, 1e-5);
    }

    #[test]
    fn dr_engine_gradient_masked_to_support() {
        let g = toy();
        let ctx = GraphCtx::new(&g);
        let engine = MessageEngine::dr(2, 2);
        let (_, cache) = engine.aggregate(&ctx, EdgeType::Near, &g.x_cell);
        let dh = Matrix::ones(3, 4);
        let dx = engine.aggregate_backward(&ctx, EdgeType::Near, &dh, &cache);
        // Each source row's gradient support ≤ k = 2.
        for r in 0..3 {
            assert!(dx.row(r).iter().filter(|&&v| v != 0.0).count() <= 2);
        }
    }
}
