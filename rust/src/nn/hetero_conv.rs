//! HeteroConv — one heterogeneous message-passing block (paper Fig. 1, §3.2).
//!
//! Three submodules, one per edge type:
//! * `near`   (cell→cell): [`GraphConv`]
//! * `pinned` (net→cell):  [`SageConv`]
//! * `pins`   (cell→net):  [`SageConv`]
//!
//! The two cell-side updates merge with element-wise `max` (eq. 8); the
//! backward pass routes gradient through the cached argmax mask (eqs. 12–14).
//!
//! All aggregations dispatch through an [`Engine`]: the engine owns the
//! kernel per edge type (cuSPARSE-analog / GNNA-analog / DR-SpMM, possibly
//! mixed), the shared D-ReLU sparsification per node type, and the §3.4
//! parallel mode that runs the three edge-type aggregations concurrently —
//! the cudaStream analog (see also [`crate::sched`]). The lanes and the
//! kernels inside them draw on the caller's cooperative thread budget
//! ([`crate::util::pool::Budget`]): inside a fleet worker this layer uses
//! that worker's share, and results are bit-identical for any budget.

use super::gcn::GraphConv;
use super::sage::SageConv;
use crate::engine::{AggCache, Engine};
use crate::graph::{EdgeType, NodeType};
use crate::sched::{run_lanes, ScheduleMode};
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// The §3.4 lane schedule an engine's parallel flag selects.
fn schedule_of(engine: &Engine) -> ScheduleMode {
    if engine.is_parallel() {
        ScheduleMode::Parallel
    } else {
        ScheduleMode::Sequential
    }
}

/// One heterogeneous convolution block.
#[derive(Clone, Debug)]
pub struct HeteroConv {
    /// cell→cell module.
    pub near: GraphConv,
    /// net→cell module.
    pub pinned: SageConv,
    /// cell→net module.
    pub pins: SageConv,
    /// Cached argmax mask of the cell-side max merge.
    mask: Option<Matrix>,
    caches: Option<[AggCache; 3]>,
}

impl HeteroConv {
    /// `d_cell`/`d_net` input widths; both outputs have width `d_out`.
    pub fn new(d_cell: usize, d_net: usize, d_out: usize, rng: &mut Rng) -> HeteroConv {
        HeteroConv {
            near: GraphConv::new(d_cell, d_out, rng),
            pinned: SageConv::new(d_net, d_cell, d_out, rng),
            pins: SageConv::new(d_cell, d_net, d_out, rng),
            mask: None,
            caches: None,
        }
    }

    /// Forward: returns `(y_cell, y_net)`.
    pub fn forward(
        &mut self,
        engine: &Engine,
        x_cell: &Matrix,
        x_net: &Matrix,
    ) -> (Matrix, Matrix) {
        // D-ReLU once per node type (paper §3.1), then three independent
        // SpMM aggregations — the §3.4 concurrency opportunity, dispatched
        // through the scheduler's one lane primitive.
        let prep_cell = engine.sparsify(x_cell, NodeType::Cell);
        let prep_net = engine.sparsify(x_net, NodeType::Net);
        let results = run_lanes(
            schedule_of(engine),
            vec![
                Box::new(|| engine.aggregate_with(EdgeType::Near, x_cell, prep_cell.as_ref()))
                    as Box<dyn FnOnce() -> (Matrix, AggCache) + Send>,
                Box::new(|| engine.aggregate_with(EdgeType::Pinned, x_net, prep_net.as_ref())),
                Box::new(|| engine.aggregate_with(EdgeType::Pins, x_cell, prep_cell.as_ref())),
            ],
        );
        let mut it = results.into_iter();
        let [(h_near, c_near), (h_pinned, c_pinned), (h_pins, c_pins)] =
            [it.next().unwrap(), it.next().unwrap(), it.next().unwrap()];
        let y_near = self.near.forward_from_agg(h_near);
        let y_pinned = self.pinned.forward_from_agg(x_cell, h_pinned);
        let y_net = self.pins.forward_from_agg(x_net, h_pins);
        // eq. 8: cell receives max(near-update, pinned-update).
        let (y_cell, mask) = y_near.max_merge(&y_pinned);
        self.mask = Some(mask);
        self.caches = Some([c_near, c_pinned, c_pins]);
        (y_cell, y_net)
    }

    /// Cache-free forward for checkpointed training: identical arithmetic
    /// to [`HeteroConv::forward`] (same lanes, same merge) but nothing is
    /// retained — no argmax mask, no aggregation caches, no module caches.
    /// Deterministic kernels make the outputs bit-identical, so a later
    /// recompute via the caching [`HeteroConv::forward`] on the same inputs
    /// rebuilds exactly the state this call skipped.
    pub fn forward_inference(
        &self,
        engine: &Engine,
        x_cell: &Matrix,
        x_net: &Matrix,
    ) -> (Matrix, Matrix) {
        let prep_cell = engine.sparsify(x_cell, NodeType::Cell);
        let prep_net = engine.sparsify(x_net, NodeType::Net);
        let results = run_lanes(
            schedule_of(engine),
            vec![
                Box::new(|| engine.aggregate_with(EdgeType::Near, x_cell, prep_cell.as_ref()))
                    as Box<dyn FnOnce() -> (Matrix, AggCache) + Send>,
                Box::new(|| engine.aggregate_with(EdgeType::Pinned, x_net, prep_net.as_ref())),
                Box::new(|| engine.aggregate_with(EdgeType::Pins, x_cell, prep_cell.as_ref())),
            ],
        );
        let mut it = results.into_iter();
        let [(h_near, _), (h_pinned, _), (h_pins, _)] =
            [it.next().unwrap(), it.next().unwrap(), it.next().unwrap()];
        let y_near = self.near.forward_from_agg_inference(&h_near);
        let y_pinned = self.pinned.forward_from_agg_inference(x_cell, &h_pinned);
        let y_net = self.pins.forward_from_agg_inference(x_net, &h_pins);
        let (y_cell, _mask) = y_near.max_merge(&y_pinned);
        (y_cell, y_net)
    }

    /// Backward: returns `(dx_cell, dx_net)` and accumulates module grads.
    pub fn backward(
        &mut self,
        engine: &Engine,
        dy_cell: &Matrix,
        dy_net: &Matrix,
    ) -> (Matrix, Matrix) {
        let mask = self.mask.take().expect("backward before forward");
        let caches = self.caches.take().expect("backward before forward");
        // eqs. 12–14: route the cell gradient through the max mask.
        let d_near_out = dy_cell.hadamard(&mask);
        let d_pinned_out = dy_cell.zip_map(&mask, |g, m| g * (1.0 - m));

        // Module backward up to the aggregations (dense matmuls).
        let dh_near = self.near.backward_to_agg(&d_near_out);
        let (dx_cell_self, dh_pinned) = self.pinned.backward_to_agg(&d_pinned_out);
        let (dx_net_self, dh_pins) = self.pins.backward_to_agg(dy_net);

        // Aggregation backward (the SpMM-heavy part) — same lanes.
        let [c_near, c_pinned, c_pins] = &caches;
        let results = run_lanes(
            schedule_of(engine),
            vec![
                Box::new(|| engine.aggregate_backward(EdgeType::Near, &dh_near, c_near))
                    as Box<dyn FnOnce() -> Matrix + Send>,
                Box::new(|| engine.aggregate_backward(EdgeType::Pinned, &dh_pinned, c_pinned)),
                Box::new(|| engine.aggregate_backward(EdgeType::Pins, &dh_pins, c_pins)),
            ],
        );
        let mut it = results.into_iter();
        let (g_near, g_pinned, g_pins) =
            (it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
        // dX_cell: near aggregation (cell src) + pinned self-path (cell dst)
        //          + pins aggregation (cell src).
        let mut dx_cell = g_near;
        dx_cell.add_inplace(&dx_cell_self);
        dx_cell.add_inplace(&g_pins);
        // dX_net: pinned aggregation (net src) + pins self-path (net dst).
        let mut dx_net = g_pinned;
        dx_net.add_inplace(&dx_net_self);
        (dx_cell, dx_net)
    }

    pub fn params_mut(&mut self) -> Vec<&mut super::Param> {
        let mut p = self.near.params_mut();
        p.extend(self.pinned.params_mut());
        p.extend(self.pins.params_mut());
        p
    }

    pub fn numel(&self) -> usize {
        self.near.numel() + self.pinned.numel() + self.pins.numel()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineBuilder;
    use crate::graph::{Csr, HeteroGraph};
    use crate::sparse::GnnaConfig;
    use crate::util::math::assert_allclose;

    fn toy() -> HeteroGraph {
        let near = Csr::from_triplets(
            3,
            3,
            &[(0, 1, 1.0), (1, 0, 1.0), (1, 2, 1.0), (2, 1, 1.0)],
        );
        let pins =
            Csr::from_triplets(2, 3, &[(0, 0, 1.0), (0, 1, 1.0), (1, 1, 1.0), (1, 2, 1.0)]);
        let pinned = pins.transpose();
        HeteroGraph {
            id: 0,
            n_cells: 3,
            n_nets: 2,
            near,
            pins,
            pinned,
            x_cell: Matrix::from_vec(3, 4, (0..12).map(|i| (i as f32) / 6.0 - 1.0).collect()),
            x_net: Matrix::from_vec(2, 4, (0..8).map(|i| (i as f32) / 4.0 - 1.0).collect()),
            y_cell: Matrix::zeros(3, 1),
        }
    }

    #[test]
    fn forward_shapes_all_engines() {
        let g = toy();
        let mut rng = Rng::new(1);
        for builder in [
            EngineBuilder::csr(),
            EngineBuilder::gnna(GnnaConfig::default()),
            EngineBuilder::dr(2, 2),
        ] {
            let engine = builder.build(&g);
            let mut layer = HeteroConv::new(4, 4, 5, &mut rng);
            let (yc, yn) = layer.forward(&engine, &g.x_cell, &g.x_net);
            assert_eq!((yc.rows, yc.cols), (3, 5), "{}", engine.describe());
            assert_eq!((yn.rows, yn.cols), (2, 5), "{}", engine.describe());
        }
    }

    #[test]
    fn parallel_forward_bitwise_equals_sequential() {
        let g = toy();
        let mut rng = Rng::new(2);
        let layer = HeteroConv::new(4, 4, 6, &mut rng);
        for builder in [EngineBuilder::csr(), EngineBuilder::dr(2, 3)] {
            let seq_engine = builder.clone().parallel(false).build(&g);
            let par_engine = builder.parallel(true).build(&g);
            let mut seq = layer.clone();
            let mut par = layer.clone();
            let (yc1, yn1) = seq.forward(&seq_engine, &g.x_cell, &g.x_net);
            let (yc2, yn2) = par.forward(&par_engine, &g.x_cell, &g.x_net);
            assert_eq!(yc1.data, yc2.data, "{}", seq_engine.describe());
            assert_eq!(yn1.data, yn2.data);
            // And backward too.
            let dyc = Matrix::ones(3, 6);
            let dyn_ = Matrix::ones(2, 6);
            let (a1, b1) = seq.backward(&seq_engine, &dyc, &dyn_);
            let (a2, b2) = par.backward(&par_engine, &dyc, &dyn_);
            assert_eq!(a1.data, a2.data);
            assert_eq!(b1.data, b2.data);
        }
    }

    /// Constraining the thread budget reschedules the lanes/kernels but
    /// must not change a single bit of the outputs or gradients.
    #[test]
    fn forward_backward_bitwise_invariant_under_budget() {
        use crate::util::pool::Budget;
        let g = toy();
        let mut rng = Rng::new(8);
        let layer0 = HeteroConv::new(4, 4, 5, &mut rng);
        let engine = EngineBuilder::dr(2, 2).parallel(true).build(&g);
        let dyc = Matrix::ones(3, 5);
        let dyn_ = Matrix::ones(2, 5);
        let mut full = layer0.clone();
        let (yc_full, yn_full) = full.forward(&engine, &g.x_cell, &g.x_net);
        let (dc_full, dn_full) = full.backward(&engine, &dyc, &dyn_);
        for budget in [1, 2] {
            let mut constrained = layer0.clone();
            let ((yc, yn), (dc, dn)) = Budget::new(budget).with(|| {
                let fwd = constrained.forward(&engine, &g.x_cell, &g.x_net);
                let bwd = constrained.backward(&engine, &dyc, &dyn_);
                (fwd, bwd)
            });
            assert_eq!(yc.data, yc_full.data, "budget={budget}");
            assert_eq!(yn.data, yn_full.data, "budget={budget}");
            assert_eq!(dc.data, dc_full.data, "budget={budget}");
            assert_eq!(dn.data, dn_full.data, "budget={budget}");
        }
    }

    /// Finite-difference check of the full block (inputs + a weight) for
    /// the dense engine (the DR engine's D-ReLU is piecewise constant in
    /// its index set, so FD holds a.e. — checked separately with fixed k=D).
    #[test]
    fn finite_difference_inputs_csr_engine() {
        let g = toy();
        let engine = EngineBuilder::csr().build(&g);
        let mut rng = Rng::new(3);
        let layer0 = HeteroConv::new(4, 4, 3, &mut rng);
        let mut layer = layer0.clone();
        let _ = layer.forward(&engine, &g.x_cell, &g.x_net);
        let dyc = Matrix::ones(3, 3);
        let dyn_ = Matrix::ones(2, 3);
        let (dxc, dxn) = layer.backward(&engine, &dyc, &dyn_);
        let eps = 1e-3f32;
        let loss = |xc: &Matrix, xn: &Matrix| -> f32 {
            let mut l = layer0.clone();
            let (yc, yn) = l.forward(&engine, xc, xn);
            yc.data.iter().sum::<f32>() + yn.data.iter().sum::<f32>()
        };
        for i in 0..g.x_cell.data.len() {
            let mut xp = g.x_cell.clone();
            xp.data[i] += eps;
            let mut xm = g.x_cell.clone();
            xm.data[i] -= eps;
            let fd = (loss(&xp, &g.x_net) - loss(&xm, &g.x_net)) / (2.0 * eps);
            assert!((fd - dxc.data[i]).abs() < 3e-2, "dx_cell[{i}]: {fd} vs {}", dxc.data[i]);
        }
        for i in 0..g.x_net.data.len() {
            let mut xp = g.x_net.clone();
            xp.data[i] += eps;
            let mut xm = g.x_net.clone();
            xm.data[i] -= eps;
            let fd = (loss(&g.x_cell, &xp) - loss(&g.x_cell, &xm)) / (2.0 * eps);
            assert!((fd - dxn.data[i]).abs() < 3e-2, "dx_net[{i}]: {fd} vs {}", dxn.data[i]);
        }
    }

    /// The cache-free inference forward must be bit-identical to the
    /// caching forward on every engine family.
    #[test]
    fn inference_forward_bitwise_equals_caching_forward() {
        let g = toy();
        let mut rng = Rng::new(9);
        let layer0 = HeteroConv::new(4, 4, 5, &mut rng);
        for builder in [
            EngineBuilder::csr(),
            EngineBuilder::gnna(GnnaConfig::default()),
            EngineBuilder::dr(2, 2),
        ] {
            let engine = builder.build(&g);
            let mut caching = layer0.clone();
            let (yc1, yn1) = caching.forward(&engine, &g.x_cell, &g.x_net);
            let (yc2, yn2) = layer0.forward_inference(&engine, &g.x_cell, &g.x_net);
            assert_eq!(yc1.data, yc2.data, "{}", engine.describe());
            assert_eq!(yn1.data, yn2.data, "{}", engine.describe());
        }
    }

    /// With k = D the DR engine must agree exactly with the dense path.
    #[test]
    fn dr_engine_full_k_matches_csr_engine() {
        let g = toy();
        let csr = EngineBuilder::csr().build(&g);
        let dr = EngineBuilder::dr(4, 4).build(&g);
        let mut rng = Rng::new(4);
        let layer0 = HeteroConv::new(4, 4, 3, &mut rng);
        let mut a = layer0.clone();
        let mut b = layer0.clone();
        let (yc1, yn1) = a.forward(&csr, &g.x_cell, &g.x_net);
        let (yc2, yn2) = b.forward(&dr, &g.x_cell, &g.x_net);
        assert_allclose(&yc1.data, &yc2.data, 1e-5, 1e-5);
        assert_allclose(&yn1.data, &yn2.data, 1e-5, 1e-5);
        let dyc = Matrix::ones(3, 3);
        let dyn_ = Matrix::ones(2, 3);
        let (ga1, gb1) = a.backward(&csr, &dyc, &dyn_);
        let (ga2, gb2) = b.backward(&dr, &dyc, &dyn_);
        assert_allclose(&ga1.data, &ga2.data, 1e-5, 1e-5);
        assert_allclose(&gb1.data, &gb2.data, 1e-5, 1e-5);
    }

    #[test]
    fn dr_engine_gradient_masked_to_support() {
        let g = toy();
        let engine = EngineBuilder::dr(2, 2).build(&g);
        let (_, cache) = engine.aggregate(EdgeType::Near, &g.x_cell);
        let dh = Matrix::ones(3, 4);
        let dx = engine.aggregate_backward(EdgeType::Near, &dh, &cache);
        // Each source row's gradient support ≤ k = 2.
        for r in 0..3 {
            assert!(dx.row(r).iter().filter(|&&v| v != 0.0).count() <= 2);
        }
    }

    /// A mixed engine (different kernel per edge type) runs end to end.
    #[test]
    fn mixed_per_edge_kernels_forward_backward() {
        let g = toy();
        let engine = Engine::builder()
            .kernel_for(EdgeType::Near, "dr")
            .kernel_for(EdgeType::Pins, "csr")
            .kernel_for(EdgeType::Pinned, "gnna")
            .k_cell(2)
            .k_net(2)
            .build(&g);
        let mut rng = Rng::new(5);
        let mut layer = HeteroConv::new(4, 4, 3, &mut rng);
        let (yc, yn) = layer.forward(&engine, &g.x_cell, &g.x_net);
        assert!(yc.data.iter().all(|v| v.is_finite()));
        assert!(yn.data.iter().all(|v| v.is_finite()));
        let (dxc, dxn) = layer.backward(&engine, &Matrix::ones(3, 3), &Matrix::ones(2, 3));
        assert_eq!((dxc.rows, dxc.cols), (3, 4));
        assert_eq!((dxn.rows, dxn.cols), (2, 4));
    }
}
