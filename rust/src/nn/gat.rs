//! Single-head GAT layer (homogeneous baseline, paper Table 2).
//!
//! `h = X·W`, attention logits `z_ij = LeakyReLU(h_i·a_dst + h_j·a_src)` for
//! edge j→i, `α_i,: = softmax_{j∈N(i)} z_ij`, output `y_i = Σ_j α_ij h_j`.
//! Backward is hand-derived through the softmax and verified with finite
//! differences.

use super::Param;
use crate::graph::Csr;
use crate::tensor::{matmul, matmul_a_bt, matmul_at_b, Matrix};
use crate::util::rng::Rng;

const LEAKY_SLOPE: f32 = 0.2;

#[derive(Clone, Debug)]
pub struct GatConv {
    pub w: Param,
    /// Destination attention vector (d_out × 1).
    pub a_dst: Param,
    /// Source attention vector (d_out × 1).
    pub a_src: Param,
    cache: Option<GatCache>,
}

#[derive(Clone, Debug)]
struct GatCache {
    x: Matrix,
    h: Matrix,
    /// Per-edge softmaxed attention (aligned with adj storage order).
    alpha: Vec<f32>,
    /// Per-edge pre-activation logits.
    z: Vec<f32>,
}

impl GatConv {
    pub fn new(d_in: usize, d_out: usize, rng: &mut Rng) -> GatConv {
        GatConv {
            w: Param::new(Matrix::he_init(d_in, d_out, rng)),
            a_dst: Param::new(Matrix::randn(d_out, 1, 0.1, rng)),
            a_src: Param::new(Matrix::randn(d_out, 1, 0.1, rng)),
            cache: None,
        }
    }

    pub fn forward(&mut self, adj: &Csr, x: &Matrix) -> Matrix {
        assert_eq!(adj.rows, adj.cols, "GAT expects a square (homogeneous) adjacency");
        assert_eq!(adj.rows, x.rows);
        let h = matmul(x, &self.w.value);
        let d = h.cols;
        // Node-level attention scores.
        let s_dst: Vec<f32> =
            (0..h.rows).map(|i| dot(h.row(i), &self.a_dst.value.data)).collect();
        let s_src: Vec<f32> =
            (0..h.rows).map(|j| dot(h.row(j), &self.a_src.value.data)).collect();
        let mut alpha = vec![0f32; adj.nnz()];
        let mut z = vec![0f32; adj.nnz()];
        let mut y = Matrix::zeros(h.rows, d);
        for i in 0..adj.rows {
            let range = adj.row_range(i);
            if range.is_empty() {
                continue;
            }
            // Logits with LeakyReLU, then a stable softmax over N(i).
            let mut maxz = f32::NEG_INFINITY;
            for p in range.clone() {
                let j = adj.indices[p] as usize;
                let raw = s_dst[i] + s_src[j];
                let zz = if raw > 0.0 { raw } else { LEAKY_SLOPE * raw };
                z[p] = zz;
                maxz = maxz.max(zz);
            }
            let mut denom = 0f32;
            for p in range.clone() {
                let e = (z[p] - maxz).exp();
                alpha[p] = e;
                denom += e;
            }
            let yrow = y.row_mut(i);
            for p in range {
                alpha[p] /= denom;
                let j = adj.indices[p] as usize;
                let a = alpha[p];
                for (o, hv) in yrow.iter_mut().zip(h.row(j)) {
                    *o += a * hv;
                }
            }
        }
        self.cache = Some(GatCache { x: x.clone(), h, alpha, z });
        y
    }

    /// Backward: accumulates dW, da_dst, da_src; returns dX.
    pub fn backward(&mut self, adj: &Csr, dy: &Matrix) -> Matrix {
        let cache = self.cache.take().expect("backward before forward");
        let GatCache { x, h, alpha, z } = cache;
        let d = h.cols;
        let n = h.rows;
        let mut dh = Matrix::zeros(n, d);
        let mut ds_dst = vec![0f32; n];
        let mut ds_src = vec![0f32; n];
        for i in 0..n {
            let range = adj.row_range(i);
            if range.is_empty() {
                continue;
            }
            let dyrow = dy.row(i);
            // dα_ij = dY_i · h_j ; also dh_j += α_ij dY_i.
            let mut dalpha = Vec::with_capacity(range.len());
            for p in range.clone() {
                let j = adj.indices[p] as usize;
                dalpha.push(dot(dyrow, h.row(j)));
                let a = alpha[p];
                for (g, dv) in dh.row_mut(j).iter_mut().zip(dyrow) {
                    *g += a * dv;
                }
            }
            // Softmax backward: de = α ⊙ (dα - Σ α dα).
            let inner: f32 = range
                .clone()
                .zip(&dalpha)
                .map(|(p, &da)| alpha[p] * da)
                .sum();
            for (p, &da) in range.clone().zip(&dalpha) {
                let de = alpha[p] * (da - inner);
                // LeakyReLU backward on the raw logit.
                let slope = if z[p] > 0.0 { 1.0 } else { LEAKY_SLOPE };
                let dz = de * slope;
                let j = adj.indices[p] as usize;
                ds_dst[i] += dz;
                ds_src[j] += dz;
            }
        }
        // s_dst_i = h_i · a_dst → dh_i += ds_dst_i · a_dst; da_dst += Σ ds_dst_i h_i.
        for i in 0..n {
            if ds_dst[i] != 0.0 {
                for (g, &av) in dh.row_mut(i).iter_mut().zip(&self.a_dst.value.data) {
                    *g += ds_dst[i] * av;
                }
                for (ga, hv) in self.a_dst.grad.data.iter_mut().zip(h.row(i)) {
                    *ga += ds_dst[i] * hv;
                }
            }
            if ds_src[i] != 0.0 {
                for (g, &av) in dh.row_mut(i).iter_mut().zip(&self.a_src.value.data) {
                    *g += ds_src[i] * av;
                }
                for (ga, hv) in self.a_src.grad.data.iter_mut().zip(h.row(i)) {
                    *ga += ds_src[i] * hv;
                }
            }
        }
        // h = x·W → dW = xᵀ dh, dX = dh Wᵀ.
        self.w.grad.add_inplace(&matmul_at_b(&x, &dh));
        matmul_a_bt(&dh, &self.w.value)
    }

    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        vec![&mut self.w, &mut self.a_dst, &mut self.a_src]
    }

    pub fn numel(&self) -> usize {
        self.w.numel() + self.a_dst.numel() + self.a_src.numel()
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_graph() -> Csr {
        Csr::from_triplets(
            4,
            4,
            &[
                (0, 1, 1.0),
                (0, 2, 1.0),
                (1, 0, 1.0),
                (1, 2, 1.0),
                (1, 3, 1.0),
                (2, 3, 1.0),
                (3, 0, 1.0),
            ],
        )
    }

    #[test]
    fn attention_rows_sum_to_one() {
        let mut rng = Rng::new(1);
        let mut layer = GatConv::new(3, 4, &mut rng);
        let adj = small_graph();
        let x = Matrix::randn(4, 3, 1.0, &mut rng);
        let _ = layer.forward(&adj, &x);
        let cache = layer.cache.as_ref().unwrap();
        for i in 0..4 {
            let s: f32 = adj.row_range(i).map(|p| cache.alpha[p]).sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} alpha sum {s}");
        }
    }

    #[test]
    fn finite_difference_all_params_and_input() {
        let mut rng = Rng::new(2);
        let adj = small_graph();
        let mut layer = GatConv::new(3, 2, &mut rng);
        let x = Matrix::randn(4, 3, 1.0, &mut rng);
        let _ = layer.forward(&adj, &x);
        let dy = Matrix::ones(4, 2);
        let mut l2 = layer.clone();
        let dx = l2.backward(&adj, &dy);
        let eps = 1e-3f32;
        let loss = |l: &GatConv, xx: &Matrix| -> f32 {
            let mut lc = l.clone();
            lc.forward(&adj, xx).data.iter().sum()
        };
        for i in 0..layer.w.value.data.len() {
            let mut lp = layer.clone();
            lp.w.value.data[i] += eps;
            let mut lm = layer.clone();
            lm.w.value.data[i] -= eps;
            let fd = (loss(&lp, &x) - loss(&lm, &x)) / (2.0 * eps);
            assert!((fd - l2.w.grad.data[i]).abs() < 3e-2, "dW[{i}]: fd {fd} vs {}", l2.w.grad.data[i]);
        }
        for i in 0..layer.a_dst.value.data.len() {
            let mut lp = layer.clone();
            lp.a_dst.value.data[i] += eps;
            let mut lm = layer.clone();
            lm.a_dst.value.data[i] -= eps;
            let fd = (loss(&lp, &x) - loss(&lm, &x)) / (2.0 * eps);
            assert!((fd - l2.a_dst.grad.data[i]).abs() < 3e-2, "da_dst[{i}]");
        }
        for i in 0..layer.a_src.value.data.len() {
            let mut lp = layer.clone();
            lp.a_src.value.data[i] += eps;
            let mut lm = layer.clone();
            lm.a_src.value.data[i] -= eps;
            let fd = (loss(&lp, &x) - loss(&lm, &x)) / (2.0 * eps);
            assert!((fd - l2.a_src.grad.data[i]).abs() < 3e-2, "da_src[{i}]");
        }
        for i in 0..x.data.len() {
            let mut xp = x.clone();
            xp.data[i] += eps;
            let mut xm = x.clone();
            xm.data[i] -= eps;
            let fd = (loss(&layer, &xp) - loss(&layer, &xm)) / (2.0 * eps);
            assert!((fd - dx.data[i]).abs() < 3e-2, "dX[{i}]: fd {fd} vs {}", dx.data[i]);
        }
    }

    #[test]
    fn isolated_node_gets_zero_output() {
        let mut rng = Rng::new(3);
        let adj = Csr::from_triplets(3, 3, &[(0, 1, 1.0)]);
        let mut layer = GatConv::new(2, 2, &mut rng);
        let x = Matrix::randn(3, 2, 1.0, &mut rng);
        let y = layer.forward(&adj, &x);
        assert_eq!(y.row(1), &[0.0, 0.0]);
        assert_eq!(y.row(2), &[0.0, 0.0]);
    }
}
