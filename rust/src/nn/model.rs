//! The paper's models.
//!
//! * [`DrCircuitGnn`] — Fig. 1: per-type input Linear → HeteroConv ×2 →
//!   output Linear head on cell nodes (congestion regression). All
//!   aggregations dispatch through the [`Engine`] passed to
//!   `forward`/`backward`, which owns the per-edge-type kernel choice
//!   (cuSPARSE-analog / GNNA-analog / DR-SpMM / auto) and the §3.4
//!   parallel mode.
//! * [`HomoGnn`] — the Table-2 homogeneous baselines: 3-layer GCN / SAGE /
//!   GAT over the homogenised circuit graph (cells and nets merged into one
//!   node set with type-flag features).

use super::activation::Relu;
use super::gat::GatConv;
use super::gcn::GraphConv;
use super::hetero_conv::HeteroConv;
use super::linear::Linear;
use super::sage::SageConv;
use super::Param;
use crate::engine::{CsrKernel, Engine, KernelPlan, SpmmKernel};
use crate::graph::{Csr, HeteroGraph, NodeType};
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// Per-layer checkpoints of the activation-recompute mode: only the
/// *inputs at layer boundaries* survive the forward pass; everything a
/// layer caches internally (aggregation CBSRs, aggregated features, argmax
/// masks, ReLU masks) is rebuilt during backward, one layer at a time.
#[derive(Clone, Debug)]
struct Checkpoints {
    /// Inputs of the per-type input Linears.
    x_cell: Matrix,
    x_net: Matrix,
    /// Input of the conv1 + inter-layer-activation block.
    xc0: Matrix,
    xn0: Matrix,
    /// Input of conv2 (post-activation).
    c1a: Matrix,
    n1a: Matrix,
    /// Input of the output head.
    c2: Matrix,
}

/// DR-CircuitGNN (two HeteroConv layers, Fig. 1).
#[derive(Clone, Debug)]
pub struct DrCircuitGnn {
    pub lin_cell: Linear,
    pub lin_net: Linear,
    pub conv1: HeteroConv,
    pub conv2: HeteroConv,
    pub out: Linear,
    relu_cell: Relu,
    relu_net: Relu,
    hidden: usize,
    checkpoint: bool,
    ckpt: Option<Checkpoints>,
}

impl DrCircuitGnn {
    pub fn new(d_cell_raw: usize, d_net_raw: usize, hidden: usize, rng: &mut Rng) -> DrCircuitGnn {
        DrCircuitGnn {
            lin_cell: Linear::new(d_cell_raw, hidden, rng),
            lin_net: Linear::new(d_net_raw, hidden, rng),
            conv1: HeteroConv::new(hidden, hidden, hidden, rng),
            conv2: HeteroConv::new(hidden, hidden, hidden, rng),
            out: Linear::new(hidden, 1, rng),
            relu_cell: Relu::new(),
            relu_net: Relu::new(),
            hidden,
            checkpoint: false,
            ckpt: None,
        }
    }

    /// Switch activation checkpointing on or off (`--checkpoint on|off`).
    /// When on, forward stores only layer-boundary activations and backward
    /// recomputes each layer's internal state right before differentiating
    /// it — trading ≈ one extra forward pass for dropping every intra-layer
    /// cache. Deterministic kernels make the result bit-identical to the
    /// uncheckpointed path.
    pub fn set_checkpoint(&mut self, on: bool) {
        self.checkpoint = on;
        self.ckpt = None;
    }

    /// Whether activation checkpointing is enabled.
    pub fn checkpointing(&self) -> bool {
        self.checkpoint
    }

    /// Forward over one graph; returns per-cell congestion prediction (C×1).
    ///
    /// Activation is decided *per node type*: a type the engine sparsifies
    /// gets its activation from the D-ReLU inside its aggregations (§3.1);
    /// an unsparsified type gets the baselines' plain inter-layer ReLU.
    /// This keeps pure-CSR/GNNA and pure-DR engines on their paper paths
    /// and gives mixed per-edge engines the right activation per tensor.
    pub fn forward(&mut self, engine: &Engine, g: &HeteroGraph) -> Matrix {
        self.forward_on(engine, &g.x_cell, &g.x_net)
    }

    /// Forward on explicit input features (the graph's raw `x_cell`/`x_net`
    /// or bit-identical staged copies of them). This is the entry the fleet
    /// epoch pipeline's execute stage uses: the prepare stage deep-copies
    /// the features (§3.4 host-side init), and because a copy is exact the
    /// prediction is bit-identical to [`DrCircuitGnn::forward`] on the
    /// graph itself.
    pub fn forward_on(&mut self, engine: &Engine, x_cell: &Matrix, x_net: &Matrix) -> Matrix {
        if self.checkpoint {
            return self.forward_checkpointed(engine, x_cell, x_net);
        }
        let xc0 = self.lin_cell.forward(x_cell);
        let xn0 = self.lin_net.forward(x_net);
        let (c1, n1) = self.conv1.forward(engine, &xc0, &xn0);
        let c1a = if engine.sparsifies(NodeType::Cell) {
            c1
        } else {
            self.relu_cell.forward(&c1)
        };
        let n1a = if engine.sparsifies(NodeType::Net) {
            n1
        } else {
            self.relu_net.forward(&n1)
        };
        let (c2, _n2) = self.conv2.forward(engine, &c1a, &n1a);
        self.out.forward(&c2)
    }

    /// Checkpointed forward: every layer runs its cache-free inference
    /// variant and only the boundary activations are kept. The arithmetic
    /// is the caching forward's, so the prediction is bit-identical.
    fn forward_checkpointed(&mut self, engine: &Engine, x_cell: &Matrix, x_net: &Matrix) -> Matrix {
        let xc0 = self.lin_cell.forward_inference(x_cell);
        let xn0 = self.lin_net.forward_inference(x_net);
        let (c1, n1) = self.conv1.forward_inference(engine, &xc0, &xn0);
        let c1a = if engine.sparsifies(NodeType::Cell) {
            c1
        } else {
            self.relu_cell.forward_inference(&c1)
        };
        let n1a = if engine.sparsifies(NodeType::Net) {
            n1
        } else {
            self.relu_net.forward_inference(&n1)
        };
        let (c2, _n2) = self.conv2.forward_inference(engine, &c1a, &n1a);
        let pred = self.out.forward_inference(&c2);
        self.ckpt = Some(Checkpoints {
            x_cell: x_cell.clone(),
            x_net: x_net.clone(),
            xc0,
            xn0,
            c1a,
            n1a,
            c2,
        });
        pred
    }

    /// Backward from the prediction gradient; accumulates all param grads.
    pub fn backward(&mut self, engine: &Engine, d_pred: &Matrix) {
        if self.checkpoint {
            return self.backward_checkpointed(engine, d_pred);
        }
        let dc2 = self.out.backward(d_pred);
        // Net output of the last layer feeds nothing: zero gradient.
        let dn2 = Matrix::zeros(engine.n_nets(), self.hidden);
        let (dc1a, dn1a) = self.conv2.backward(engine, &dc2, &dn2);
        let dc1 = if engine.sparsifies(NodeType::Cell) {
            dc1a
        } else {
            self.relu_cell.backward(&dc1a)
        };
        let dn1 = if engine.sparsifies(NodeType::Net) {
            dn1a
        } else {
            self.relu_net.backward(&dn1a)
        };
        let (dxc0, dxn0) = self.conv1.backward(engine, &dc1, &dn1);
        self.lin_cell.backward(&dxc0);
        self.lin_net.backward(&dxn0);
    }

    /// Checkpointed backward: walk the layers in reverse, re-running each
    /// one's *caching* forward from its checkpointed input immediately
    /// before its backward. Kernels are deterministic, so the rebuilt
    /// caches (aggregation CBSRs, argmax/ReLU masks, cached inputs) match
    /// the uncheckpointed run bit for bit — and therefore so do all
    /// gradients (asserted by tests against the uncheckpointed path). At
    /// most one layer's internal state is live at any time.
    fn backward_checkpointed(&mut self, engine: &Engine, d_pred: &Matrix) {
        let ckpt = self.ckpt.take().expect("backward before forward");
        // Output head.
        let _ = self.out.forward(&ckpt.c2);
        let dc2 = self.out.backward(d_pred);
        // conv2 (its recompute also frees the head's cache slot).
        let _ = self.conv2.forward(engine, &ckpt.c1a, &ckpt.n1a);
        let dn2 = Matrix::zeros(engine.n_nets(), self.hidden);
        let (dc1a, dn1a) = self.conv2.backward(engine, &dc2, &dn2);
        // conv1 + inter-layer activation: the ReLU masks are rebuilt from
        // conv1's recomputed outputs (bit-identical to the forward pass).
        let (c1, n1) = self.conv1.forward(engine, &ckpt.xc0, &ckpt.xn0);
        let dc1 = if engine.sparsifies(NodeType::Cell) {
            dc1a
        } else {
            let _ = self.relu_cell.forward(&c1);
            self.relu_cell.backward(&dc1a)
        };
        let dn1 = if engine.sparsifies(NodeType::Net) {
            dn1a
        } else {
            let _ = self.relu_net.forward(&n1);
            self.relu_net.backward(&dn1a)
        };
        let (dxc0, dxn0) = self.conv1.backward(engine, &dc1, &dn1);
        // Input Linears.
        let _ = self.lin_cell.forward(&ckpt.x_cell);
        let _ = self.lin_net.forward(&ckpt.x_net);
        self.lin_cell.backward(&dxc0);
        self.lin_net.backward(&dxn0);
    }

    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        let mut p = self.lin_cell.params_mut();
        p.extend(self.lin_net.params_mut());
        p.extend(self.conv1.params_mut());
        p.extend(self.conv2.params_mut());
        p.extend(self.out.params_mut());
        p
    }

    pub fn numel(&mut self) -> usize {
        self.params_mut().iter().map(|p| p.numel()).sum()
    }
}

/// Homogenised view of a heterograph: one node set (cells then nets).
#[derive(Clone, Debug)]
pub struct HomoView {
    pub n: usize,
    pub n_cells: usize,
    /// GCN-normalised adjacency, planned for the cuSPARSE-analog kernel.
    pub gcn_plan: KernelPlan,
    /// Mean-normalised adjacency (for SAGE), planned likewise.
    pub mean_plan: KernelPlan,
    /// Unnormalised adjacency (for GAT attention).
    pub adj_raw: Csr,
    /// Node features `[x_cell | 0 | 1,0]` / `[0 | x_net | 0,1]`.
    pub x: Matrix,
}

/// Merge cells and nets into one homogeneous graph (the paper's dataset
/// preprocessing "fits both formats"; this is the homogeneous format).
pub fn homogenize(g: &HeteroGraph) -> HomoView {
    let c = g.n_cells;
    let n = c + g.n_nets;
    let mut t: Vec<(usize, usize, f32)> = Vec::new();
    for r in 0..g.near.rows {
        for p in g.near.row_range(r) {
            t.push((r, g.near.indices[p] as usize, 1.0));
        }
    }
    // pins: destination nets (offset by C), source cells.
    for net in 0..g.pins.rows {
        for p in g.pins.row_range(net) {
            let cell = g.pins.indices[p] as usize;
            t.push((c + net, cell, 1.0));
            t.push((cell, c + net, 1.0)); // pinned direction
        }
    }
    let adj_raw = Csr::from_triplets(n, n, &t);
    let mut adj_gcn = adj_raw.clone();
    adj_gcn.normalize_gcn();
    let mut adj_mean = adj_raw.clone();
    adj_mean.normalize_rows();
    // Features: [cell feats | zeros | 1 0] and [zeros | net feats | 0 1].
    let (dc, dn) = (g.x_cell.cols, g.x_net.cols);
    let width = dc + dn + 2;
    let mut x = Matrix::zeros(n, width);
    for i in 0..c {
        x.row_mut(i)[..dc].copy_from_slice(g.x_cell.row(i));
        x.row_mut(i)[dc + dn] = 1.0;
    }
    for j in 0..g.n_nets {
        x.row_mut(c + j)[dc..dc + dn].copy_from_slice(g.x_net.row(j));
        x.row_mut(c + j)[dc + dn + 1] = 1.0;
    }
    HomoView {
        n,
        n_cells: c,
        gcn_plan: CsrKernel.plan(adj_gcn),
        mean_plan: CsrKernel.plan(adj_mean),
        adj_raw,
        x,
    }
}

/// Baseline family (paper Table 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HomoKind {
    Gcn,
    Sage,
    Gat,
}

impl HomoKind {
    pub fn name(&self) -> &'static str {
        match self {
            HomoKind::Gcn => "GCN",
            HomoKind::Sage => "SAGE",
            HomoKind::Gat => "GAT",
        }
    }

    pub fn parse(s: &str) -> Option<HomoKind> {
        match s.to_ascii_lowercase().as_str() {
            "gcn" => Some(HomoKind::Gcn),
            "sage" | "graphsage" => Some(HomoKind::Sage),
            "gat" => Some(HomoKind::Gat),
            _ => None,
        }
    }
}

/// Three-layer homogeneous GNN with ReLU between layers and a linear head.
#[derive(Clone, Debug)]
pub struct HomoGnn {
    pub kind: HomoKind,
    gcn: Vec<GraphConv>,
    sage: Vec<SageConv>,
    gat: Vec<GatConv>,
    relus: Vec<Relu>,
    pub out: Linear,
    n_layers: usize,
}

impl HomoGnn {
    pub fn new(kind: HomoKind, d_in: usize, hidden: usize, rng: &mut Rng) -> HomoGnn {
        let n_layers = 3;
        let mut gcn = Vec::new();
        let mut sage = Vec::new();
        let mut gat = Vec::new();
        for l in 0..n_layers {
            let din = if l == 0 { d_in } else { hidden };
            match kind {
                HomoKind::Gcn => gcn.push(GraphConv::new(din, hidden, rng)),
                HomoKind::Sage => sage.push(SageConv::new(din, din, hidden, rng)),
                HomoKind::Gat => gat.push(GatConv::new(din, hidden, rng)),
            }
        }
        HomoGnn {
            kind,
            gcn,
            sage,
            gat,
            relus: vec![Relu::new(); n_layers],
            out: Linear::new(hidden, 1, rng),
            n_layers,
        }
    }

    /// Forward; returns per-cell prediction (first `n_cells` rows of the head).
    pub fn forward(&mut self, view: &HomoView) -> Matrix {
        let mut h = view.x.clone();
        for l in 0..self.n_layers {
            h = match self.kind {
                HomoKind::Gcn => self.gcn[l].forward(&view.gcn_plan, &h),
                HomoKind::Sage => self.sage[l].forward(&view.mean_plan, &h, &h),
                HomoKind::Gat => self.gat[l].forward(&view.adj_raw, &h),
            };
            h = self.relus[l].forward(&h);
        }
        let pred_all = self.out.forward(&h);
        pred_all.gather_rows(&(0..view.n_cells).collect::<Vec<_>>())
    }

    /// Backward from the per-cell prediction gradient.
    pub fn backward(&mut self, view: &HomoView, d_pred_cells: &Matrix) {
        // Scatter the cell gradient into the full node set.
        let mut d_pred = Matrix::zeros(view.n, 1);
        for i in 0..view.n_cells {
            d_pred.data[i] = d_pred_cells.data[i];
        }
        let mut dh = self.out.backward(&d_pred);
        for l in (0..self.n_layers).rev() {
            dh = self.relus[l].backward(&dh);
            dh = match self.kind {
                HomoKind::Gcn => self.gcn[l].backward(&view.gcn_plan, &dh),
                HomoKind::Sage => {
                    let (d_dst, d_src) = self.sage[l].backward(&view.mean_plan, &dh);
                    d_dst.add(&d_src)
                }
                HomoKind::Gat => self.gat[l].backward(&view.adj_raw, &dh),
            };
        }
    }

    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        let mut p: Vec<&mut Param> = Vec::new();
        for l in self.gcn.iter_mut() {
            p.extend(l.params_mut());
        }
        for l in self.sage.iter_mut() {
            p.extend(l.params_mut());
        }
        for l in self.gat.iter_mut() {
            p.extend(l.params_mut());
        }
        p.extend(self.out.params_mut());
        p
    }

    pub fn numel(&mut self) -> usize {
        self.params_mut().iter().map(|p| p.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineBuilder;
    use crate::nn::loss::mse;

    fn toy() -> HeteroGraph {
        let near = Csr::from_triplets(
            4,
            4,
            &[(0, 1, 1.0), (1, 0, 1.0), (1, 2, 1.0), (2, 1, 1.0), (2, 3, 1.0), (3, 2, 1.0)],
        );
        let pins =
            Csr::from_triplets(2, 4, &[(0, 0, 1.0), (0, 1, 1.0), (1, 2, 1.0), (1, 3, 1.0)]);
        let pinned = pins.transpose();
        let mut rng = Rng::new(99);
        HeteroGraph {
            id: 0,
            n_cells: 4,
            n_nets: 2,
            near,
            pins,
            pinned,
            x_cell: Matrix::randn(4, 6, 1.0, &mut rng),
            x_net: Matrix::randn(2, 6, 1.0, &mut rng),
            y_cell: Matrix::from_vec(4, 1, vec![0.1, 0.9, 0.5, 0.2]),
        }
    }

    #[test]
    fn dr_model_trains_loss_down() {
        let g = toy();
        let engine = EngineBuilder::dr(4, 4).build(&g);
        let mut rng = Rng::new(1);
        let mut model = DrCircuitGnn::new(6, 6, 8, &mut rng);
        let mut opt = super::super::adam::Adam::new(0.01, 0.0);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..60 {
            let pred = model.forward(&engine, &g);
            let (loss, dp) = mse(&pred, &g.y_cell);
            model.backward(&engine, &dp);
            opt.step(&mut model.params_mut());
            super::super::adam::Adam::zero_grad(&mut model.params_mut());
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < first.unwrap() * 0.5, "loss {first:?} → {last}");
    }

    #[test]
    fn dr_model_with_csr_engine_also_trains() {
        let g = toy();
        let engine = EngineBuilder::csr().build(&g);
        let mut rng = Rng::new(2);
        let mut model = DrCircuitGnn::new(6, 6, 8, &mut rng);
        let mut opt = super::super::adam::Adam::new(0.01, 0.0);
        let mut losses = Vec::new();
        for _ in 0..50 {
            let pred = model.forward(&engine, &g);
            let (loss, dp) = mse(&pred, &g.y_cell);
            model.backward(&engine, &dp);
            opt.step(&mut model.params_mut());
            super::super::adam::Adam::zero_grad(&mut model.params_mut());
            losses.push(loss);
        }
        assert!(losses.last().unwrap() < &(losses[0] * 0.6));
    }

    #[test]
    fn homogenize_structure() {
        let g = toy();
        let v = homogenize(&g);
        assert_eq!(v.n, 6);
        assert_eq!(v.n_cells, 4);
        // near edges + 2 per pin
        assert_eq!(v.adj_raw.nnz(), g.near.nnz() + 2 * g.pins.nnz());
        // Type flags.
        assert_eq!(v.x.at(0, 6 + 6), 1.0);
        assert_eq!(v.x.at(4, 6 + 6 + 1), 1.0);
        // Homogeneous adjacency is symmetric.
        assert!(v.adj_raw.is_transpose_of(&v.adj_raw));
        // Plans share the structure, with their own normalisations.
        assert_eq!(v.gcn_plan.adj.nnz(), v.adj_raw.nnz());
        assert_eq!(v.mean_plan.adj.nnz(), v.adj_raw.nnz());
    }

    #[test]
    fn homo_baselines_train() {
        let g = toy();
        let v = homogenize(&g);
        for kind in [HomoKind::Gcn, HomoKind::Sage, HomoKind::Gat] {
            let mut rng = Rng::new(3);
            let mut model = HomoGnn::new(kind, v.x.cols, 8, &mut rng);
            let mut opt = super::super::adam::Adam::new(0.01, 0.0);
            let mut losses = Vec::new();
            for _ in 0..40 {
                let pred = model.forward(&v);
                assert_eq!(pred.rows, 4);
                let (loss, dp) = mse(&pred, &g.y_cell);
                model.backward(&v, &dp);
                opt.step(&mut model.params_mut());
                super::super::adam::Adam::zero_grad(&mut model.params_mut());
                losses.push(loss);
            }
            assert!(
                losses.last().unwrap() < &(losses[0] * 0.8),
                "{}: {losses:?}",
                kind.name()
            );
        }
    }

    #[test]
    fn dr_model_param_count_doubles_vs_homo() {
        // The paper notes DR-CircuitGNN has ≈2× the baselines' params.
        let g = toy();
        let v = homogenize(&g);
        let mut rng = Rng::new(4);
        let mut dr = DrCircuitGnn::new(6, 6, 16, &mut rng);
        let mut homo = HomoGnn::new(HomoKind::Gcn, v.x.cols, 16, &mut rng);
        assert!(dr.numel() > homo.numel(), "{} vs {}", dr.numel(), homo.numel());
    }

    /// Mixed per-edge engines keep a per-node-type activation: the net
    /// tensor (no DR consumer here) still gets the inter-layer ReLU, and
    /// the model trains.
    #[test]
    fn mixed_engine_keeps_per_node_type_activation() {
        let g = toy();
        let engine = Engine::builder()
            .kernel("dr")
            .kernel_spec_for(crate::graph::EdgeType::Pinned, crate::engine::KernelSpec::Csr)
            .k_cell(4)
            .k_net(4)
            .build(&g);
        // pins (cell→net) runs DR → cell sparsified; pinned runs CSR and is
        // the only net consumer → net is NOT sparsified, so it must take
        // the plain-ReLU branch.
        assert!(engine.sparsifies(NodeType::Cell));
        assert!(!engine.sparsifies(NodeType::Net));
        let mut rng = Rng::new(7);
        let mut model = DrCircuitGnn::new(6, 6, 8, &mut rng);
        let mut opt = super::super::adam::Adam::new(0.01, 0.0);
        let mut losses = Vec::new();
        for _ in 0..40 {
            let pred = model.forward(&engine, &g);
            let (loss, dp) = mse(&pred, &g.y_cell);
            model.backward(&engine, &dp);
            opt.step(&mut model.params_mut());
            super::super::adam::Adam::zero_grad(&mut model.params_mut());
            losses.push(loss);
        }
        assert!(losses.last().unwrap() < &(losses[0] * 0.8), "{losses:?}");
    }

    /// The checkpointed path must be indistinguishable from the default
    /// path at the bit level: same predictions, same gradients, and —
    /// after optimizer steps — same parameters, across engine families.
    #[test]
    fn checkpointed_training_bitwise_equals_uncheckpointed() {
        let g = toy();
        for builder in
            [EngineBuilder::csr(), EngineBuilder::gnna(Default::default()), EngineBuilder::dr(4, 4)]
        {
            let engine = builder.build(&g);
            let mut rng = Rng::new(6);
            let base = DrCircuitGnn::new(6, 6, 8, &mut rng);
            let mut plain = base.clone();
            let mut ckpt = base.clone();
            ckpt.set_checkpoint(true);
            assert!(ckpt.checkpointing() && !plain.checkpointing());
            let mut opt_p = super::super::adam::Adam::new(0.01, 1e-4);
            let mut opt_c = super::super::adam::Adam::new(0.01, 1e-4);
            for step in 0..5 {
                let pp = plain.forward(&engine, &g);
                let pc = ckpt.forward(&engine, &g);
                assert_eq!(pp.data, pc.data, "step {step}: predictions diverge");
                let (_, dp) = mse(&pp, &g.y_cell);
                let (_, dc) = mse(&pc, &g.y_cell);
                plain.backward(&engine, &dp);
                ckpt.backward(&engine, &dc);
                for (a, b) in plain.params_mut().iter().zip(ckpt.params_mut().iter()) {
                    assert_eq!(a.grad.data, b.grad.data, "step {step}: gradients diverge");
                }
                opt_p.step(&mut plain.params_mut());
                opt_c.step(&mut ckpt.params_mut());
                super::super::adam::Adam::zero_grad(&mut plain.params_mut());
                super::super::adam::Adam::zero_grad(&mut ckpt.params_mut());
            }
            for (a, b) in plain.params_mut().iter().zip(ckpt.params_mut().iter()) {
                assert_eq!(a.value.data, b.value.data, "params diverge after training");
            }
        }
    }

    #[test]
    #[should_panic(expected = "backward before forward")]
    fn checkpointed_backward_without_forward_panics() {
        let g = toy();
        let engine = EngineBuilder::csr().build(&g);
        let mut rng = Rng::new(11);
        let mut model = DrCircuitGnn::new(6, 6, 8, &mut rng);
        model.set_checkpoint(true);
        model.backward(&engine, &Matrix::ones(4, 1));
    }

    #[test]
    fn parallel_mode_consistent_predictions() {
        let g = toy();
        let seq_engine = EngineBuilder::dr(3, 3).build(&g);
        let par_engine = EngineBuilder::dr(3, 3).parallel(true).build(&g);
        let mut rng = Rng::new(5);
        let model = DrCircuitGnn::new(6, 6, 8, &mut rng);
        let mut seq = model.clone();
        let mut par = model.clone();
        let a = seq.forward(&seq_engine, &g);
        let b = par.forward(&par_engine, &g);
        assert_eq!(a.data, b.data);
    }
}
