//! Adam optimizer with decoupled weight decay (the paper trains with
//! lr 2e-4, weight decay 1e-5 for DR-CircuitGNN; 1e-3 / 2e-4 for baselines).

use super::Param;

#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// First/second moment per parameter tensor.
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: u64,
}

impl Adam {
    pub fn new(lr: f32, weight_decay: f32) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay,
            m: Vec::new(),
            v: Vec::new(),
            t: 0,
        }
    }

    /// Apply one update to the given parameter list. The list must have the
    /// same structure on every call (moments are positional).
    pub fn step(&mut self, params: &mut [&mut Param]) {
        if self.m.is_empty() {
            self.m = params.iter().map(|p| vec![0.0; p.numel()]).collect();
            self.v = params.iter().map(|p| vec![0.0; p.numel()]).collect();
        }
        assert_eq!(self.m.len(), params.len(), "parameter structure changed");
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for (pi, p) in params.iter_mut().enumerate() {
            assert_eq!(self.m[pi].len(), p.numel(), "parameter {pi} changed size");
            let (m, v) = (&mut self.m[pi], &mut self.v[pi]);
            for i in 0..p.numel() {
                let g = p.grad.data[i] + self.weight_decay * p.value.data[i];
                m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * g;
                v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * g * g;
                let mhat = m[i] / b1t;
                let vhat = v[i] / b2t;
                p.value.data[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
            }
        }
    }

    /// Zero all parameter gradients (call before each backward).
    pub fn zero_grad(params: &mut [&mut Param]) {
        for p in params.iter_mut() {
            p.zero_grad();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;

    /// Adam must descend a simple quadratic.
    #[test]
    fn minimises_quadratic() {
        let mut p = Param::new(Matrix::from_vec(1, 2, vec![3.0, -2.0]));
        let mut opt = Adam::new(0.1, 0.0);
        for _ in 0..300 {
            // loss = 0.5 * ||x||² → grad = x
            p.grad = p.value.clone();
            opt.step(&mut [&mut p]);
            p.zero_grad();
        }
        assert!(p.value.data.iter().all(|&x| x.abs() < 1e-2), "{:?}", p.value.data);
    }

    #[test]
    fn weight_decay_shrinks_without_gradient() {
        let mut p = Param::new(Matrix::from_vec(1, 1, vec![1.0]));
        let mut opt = Adam::new(0.01, 0.1);
        let before = p.value.data[0];
        for _ in 0..50 {
            opt.step(&mut [&mut p]); // grad stays zero; decay acts
        }
        assert!(p.value.data[0] < before);
    }

    #[test]
    fn first_step_magnitude_close_to_lr() {
        // Adam's bias correction makes the first step ≈ lr in magnitude.
        let mut p = Param::new(Matrix::from_vec(1, 1, vec![0.0]));
        p.grad = Matrix::from_vec(1, 1, vec![5.0]);
        let mut opt = Adam::new(0.01, 0.0);
        opt.step(&mut [&mut p]);
        assert!((p.value.data[0].abs() - 0.01).abs() < 1e-4);
    }

    #[test]
    #[should_panic(expected = "parameter structure changed")]
    fn structure_change_panics() {
        let mut a = Param::new(Matrix::zeros(1, 1));
        let mut b = Param::new(Matrix::zeros(1, 1));
        let mut opt = Adam::new(0.01, 0.0);
        opt.step(&mut [&mut a]);
        opt.step(&mut [&mut a, &mut b]);
    }
}
