//! GraphConv (GCN) layer — the HeteroConv's `near` module (Fig. 1).
//!
//! `Y = Â · X · W + b` where Â is the (pre-normalised) adjacency. Backward:
//! `dW = (ÂX)ᵀ · dY`, `dX = Âᵀ · (dY · Wᵀ)`.
//!
//! The heterogeneous path aggregates through an [`crate::engine::Engine`]
//! and calls [`GraphConv::forward_from_agg`]; the homogeneous baselines use
//! the fused [`GraphConv::forward`], which runs the cuSPARSE-analog kernel
//! against a cached [`KernelPlan`].

use super::Param;
use crate::engine::{AggCache, CsrKernel, KernelPlan, SpmmKernel};
use crate::tensor::{matmul, matmul_a_bt, matmul_at_b, Matrix};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct GraphConv {
    pub w: Param,
    pub b: Param,
    /// Cached aggregate H = Â·X.
    cached_h: Option<Matrix>,
}

impl GraphConv {
    pub fn new(d_in: usize, d_out: usize, rng: &mut Rng) -> GraphConv {
        GraphConv {
            w: Param::new(Matrix::he_init(d_in, d_out, rng)),
            b: Param::new(Matrix::zeros(1, d_out)),
            cached_h: None,
        }
    }

    /// Forward with a pluggable aggregation result: callers that use
    /// DR-SpMM pass the aggregated `h` directly (see `hetero_conv`).
    pub fn forward_from_agg(&mut self, h: Matrix) -> Matrix {
        let y = matmul(&h, &self.w.value).add_bias(&self.b.value.data);
        self.cached_h = Some(h);
        y
    }

    /// Cache-free variant of [`GraphConv::forward_from_agg`] for
    /// checkpointed forwards (bit-identical output, nothing stored).
    pub fn forward_from_agg_inference(&self, h: &Matrix) -> Matrix {
        matmul(h, &self.w.value).add_bias(&self.b.value.data)
    }

    /// Fused dense-aggregation forward against a planned adjacency.
    pub fn forward(&mut self, plan: &KernelPlan, x: &Matrix) -> Matrix {
        let (h, _) = CsrKernel.forward(plan, x, None);
        self.forward_from_agg(h)
    }

    /// Backward up to the aggregation: accumulates dW/db and returns
    /// `dH = dY · Wᵀ` (gradient w.r.t. the aggregated features). The caller
    /// completes `dX = Âᵀ · dH` with its kernel of choice.
    pub fn backward_to_agg(&mut self, dy: &Matrix) -> Matrix {
        let h = self.cached_h.as_ref().expect("backward before forward");
        self.w.grad.add_inplace(&matmul_at_b(h, dy));
        for (g, d) in self.b.grad.data.iter_mut().zip(dy.col_sum()) {
            *g += d;
        }
        matmul_a_bt(dy, &self.w.value)
    }

    /// Full dense backward against the planned adjacency: returns dX.
    pub fn backward(&mut self, plan: &KernelPlan, dy: &Matrix) -> Matrix {
        let dh = self.backward_to_agg(dy);
        CsrKernel.backward(plan, &dh, &AggCache::None).into_dense()
    }

    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        vec![&mut self.w, &mut self.b]
    }

    pub fn numel(&self) -> usize {
        self.w.numel() + self.b.numel()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;

    fn ring(n: usize) -> KernelPlan {
        let t: Vec<_> = (0..n).map(|r| (r, (r + 1) % n, 1.0f32)).collect();
        CsrKernel.plan(Csr::from_triplets(n, n, &t))
    }

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::new(1);
        let mut layer = GraphConv::new(4, 3, &mut rng);
        let plan = ring(5);
        let x = Matrix::randn(5, 4, 1.0, &mut rng);
        let y = layer.forward(&plan, &x);
        assert_eq!((y.rows, y.cols), (5, 3));
    }

    #[test]
    fn finite_difference_w_and_x() {
        let mut rng = Rng::new(2);
        let mut layer = GraphConv::new(3, 2, &mut rng);
        let plan = ring(4);
        let x = Matrix::randn(4, 3, 1.0, &mut rng);
        let _y = layer.forward(&plan, &x);
        let dy = Matrix::ones(4, 2);
        let dx = layer.backward(&plan, &dy);
        let eps = 1e-3f32;
        let loss = |l: &GraphConv, xx: &Matrix| -> f32 {
            let (h, _) = CsrKernel.forward(&plan, xx, None);
            matmul(&h, &l.w.value).add_bias(&l.b.value.data).data.iter().sum()
        };
        for i in 0..layer.w.value.data.len() {
            let mut lp = layer.clone();
            lp.w.value.data[i] += eps;
            let mut lm = layer.clone();
            lm.w.value.data[i] -= eps;
            let fd = (loss(&lp, &x) - loss(&lm, &x)) / (2.0 * eps);
            assert!((fd - layer.w.grad.data[i]).abs() < 2e-2, "dW[{i}]");
        }
        for i in 0..x.data.len() {
            let mut xp = x.clone();
            xp.data[i] += eps;
            let mut xm = x.clone();
            xm.data[i] -= eps;
            let fd = (loss(&layer, &xp) - loss(&layer, &xm)) / (2.0 * eps);
            assert!((fd - dx.data[i]).abs() < 2e-2, "dX[{i}]");
        }
    }

    #[test]
    fn agg_split_path_equals_fused() {
        let mut rng = Rng::new(3);
        let mut a = GraphConv::new(3, 2, &mut rng);
        let mut b = a.clone();
        let plan = ring(6);
        let x = Matrix::randn(6, 3, 1.0, &mut rng);
        let y1 = a.forward(&plan, &x);
        let (h, _) = CsrKernel.forward(&plan, &x, None);
        let y2 = b.forward_from_agg(h);
        assert_eq!(y1.data, y2.data);
    }
}
