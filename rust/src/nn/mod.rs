//! Neural-network layers with hand-written forward/backward passes.
//!
//! The paper bypasses framework autograd for its message-passing op (custom
//! CUDA backward, Alg. 2); we extend that approach to the whole model: every
//! layer caches what its backward needs and exposes `backward` returning
//! input gradients. Gradients are verified against finite differences in
//! each module's tests.
//!
//! Layers:
//! * [`Linear`] — dense projection.
//! * [`GraphConv`] — GCN convolution `Â X W` (the HeteroConv's third module).
//! * [`SageConv`] — GraphSAGE-mean `X W_self + (ĀX) W_neigh`.
//! * [`GatConv`] — single-head graph attention (homogeneous baseline).
//! * [`HeteroConv`] — the paper's block: two SageConv (pins, pinned) + one
//!   GraphConv (near), cell outputs merged with element-wise max (eq. 8).
//! * [`DReluGate`] — the D-ReLU activation wired to CBSR outputs.
//!
//! Models in [`model`]: `DrCircuitGnn` (2-layer HeteroConv, Fig. 1) and the
//! homogeneous baselines (3-layer GCN / SAGE / GAT).
//!
//! Aggregation kernels are not chosen here: every SpMM dispatches through
//! [`crate::engine`] (an [`crate::engine::Engine`] built per graph), which
//! owns the per-edge-type kernel selection, D-ReLU sharing and the §3.4
//! parallel schedule.

pub mod activation;
pub mod adam;
pub mod gat;
pub mod gcn;
pub mod hetero_conv;
pub mod linear;
pub mod loss;
pub mod model;
pub mod sage;

pub use activation::{DReluGate, Relu};
pub use adam::Adam;
pub use gat::GatConv;
pub use gcn::GraphConv;
pub use hetero_conv::HeteroConv;
pub use linear::Linear;
pub use loss::mse;
pub use model::{homogenize, DrCircuitGnn, HomoGnn, HomoKind};
pub use sage::SageConv;

/// A trainable parameter: value + accumulated gradient.
#[derive(Clone, Debug)]
pub struct Param {
    pub value: crate::tensor::Matrix,
    pub grad: crate::tensor::Matrix,
}

impl Param {
    pub fn new(value: crate::tensor::Matrix) -> Param {
        let grad = crate::tensor::Matrix::zeros(value.rows, value.cols);
        Param { value, grad }
    }

    pub fn zero_grad(&mut self) {
        for g in self.grad.data.iter_mut() {
            *g = 0.0;
        }
    }

    pub fn numel(&self) -> usize {
        self.value.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;

    #[test]
    fn param_zero_grad() {
        let mut p = Param::new(Matrix::ones(2, 2));
        p.grad = Matrix::ones(2, 2);
        p.zero_grad();
        assert!(p.grad.data.iter().all(|&g| g == 0.0));
        assert_eq!(p.numel(), 4);
    }
}
