//! Minimal data-parallel substrate (rayon is unavailable offline).
//!
//! `parallel_for` splits an index range into contiguous chunks executed on
//! scoped OS threads; `parallel_map` collects per-index results. Both fall
//! back to inline execution for small ranges so unit tests and tiny graphs
//! don't pay thread spawn costs.
//!
//! This is also the substrate the §3.4 scheduler builds on: the "CPU
//! multi-thread initialization" side of the paper maps to scoped threads
//! here, while the cudaStream analog lives in [`crate::sched`].

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (can be overridden with the
/// `DRCG_THREADS` environment variable; defaults to available parallelism).
pub fn num_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let cached = CACHED.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let n = std::env::var("DRCG_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        });
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Threshold below which parallel dispatch is not worth a thread spawn.
const SEQ_CUTOFF: usize = 256;

/// Run `f(i)` for every `i in 0..n`, in parallel chunks.
///
/// `f` must be `Sync` (shared across threads); disjoint output writes should
/// go through raw pointers or per-chunk slices — see `parallel_for_chunks`.
pub fn parallel_for<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    parallel_for_chunks(n, |lo, hi| {
        for i in lo..hi {
            f(i);
        }
    });
}

/// Run `f(lo, hi)` over a contiguous partition of `0..n`. This is the
/// building block used by the kernels: each worker owns `[lo, hi)` rows.
pub fn parallel_for_chunks<F>(n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = num_threads().min(n);
    if workers <= 1 || n < SEQ_CUTOFF {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        for w in 0..workers {
            let lo = w * chunk;
            let hi = ((w + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            scope.spawn(move || f(lo, hi));
        }
    });
}

/// Work-stealing-ish dynamic scheduling: workers pull blocks of `grain`
/// indices from a shared atomic counter. Used where per-index cost is
/// highly skewed (power-law rows) and static chunking would tail-lag —
/// exactly the "evil row" effect §2.3 of the paper describes.
pub fn parallel_for_dynamic<F>(n: usize, grain: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = num_threads().min(n);
    if workers <= 1 || n < SEQ_CUTOFF {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let grain = grain.max(1);
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let f = &f;
            let cursor = &cursor;
            scope.spawn(move || loop {
                let lo = cursor.fetch_add(grain, Ordering::Relaxed);
                if lo >= n {
                    break;
                }
                let hi = (lo + grain).min(n);
                for i in lo..hi {
                    f(i);
                }
            });
        }
    });
}

/// Dynamic scheduling over an explicit item slice (used by the DR-SpMM
/// degree-bucket schedule: items are row ids in bucket order).
pub fn parallel_for_dynamic_order<T: Sync, F>(items: &[T], grain: usize, f: F)
where
    F: Fn(&T) + Sync,
{
    let n = items.len();
    if n == 0 {
        return;
    }
    let workers = num_threads().min(n);
    if workers <= 1 || n < SEQ_CUTOFF.min(grain * 2) {
        for it in items {
            f(it);
        }
        return;
    }
    let grain = grain.max(1);
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let f = &f;
            let cursor = &cursor;
            scope.spawn(move || loop {
                let lo = cursor.fetch_add(grain, Ordering::Relaxed);
                if lo >= n {
                    break;
                }
                for it in &items[lo..(lo + grain).min(n)] {
                    f(it);
                }
            });
        }
    });
}

/// Parallel map collecting results in index order.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        parallel_for_chunks(n, |lo, hi| {
            let p = out_ptr; // copy the Send wrapper into the closure
            for i in lo..hi {
                // SAFETY: each index is written by exactly one worker.
                unsafe { *p.0.add(i) = f(i) };
            }
        });
    }
    out
}

/// Pointer wrapper asserting disjoint-index write safety across threads.
pub struct SendPtr<T>(pub *mut T);
// Manual impls: derives would add a spurious `T: Copy` bound.
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Run `n` independent tasks on at most `workers` threads, collecting
/// results in task order. Tasks are pulled from a shared atomic cursor
/// (dynamic assignment — skewed task costs don't tail-lag a static stride),
/// but because each task's output is written to its own slot, the result is
/// identical for every worker count. This is the fleet's substrate: one
/// task per subgraph, graph-level parallelism on top of the kernels' own
/// `parallel_for` and the §3.4 edge lanes.
pub fn bounded_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let workers = workers.clamp(1, n.max(1));
    if workers <= 1 {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = Some(f(i));
        }
    } else {
        let cursor = AtomicUsize::new(0);
        let out_ptr = SendPtr(out.as_mut_ptr());
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let f = &f;
                let cursor = &cursor;
                scope.spawn(move || {
                    let p = out_ptr;
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        // SAFETY: the cursor hands each index to exactly
                        // one worker, so every slot is written once.
                        unsafe { *p.0.add(i) = Some(f(i)) };
                    }
                });
            }
        });
    }
    out.into_iter().map(|x| x.expect("bounded_map: unfilled slot")).collect()
}

/// Run a set of independent closures concurrently, one thread each
/// (the CPU-side "three threads for three subgraphs" of paper Fig. 9b).
pub fn join_all<T: Send, F: FnOnce() -> T + Send>(tasks: Vec<F>) -> Vec<T> {
    std::thread::scope(|scope| {
        let handles: Vec<_> = tasks.into_iter().map(|t| scope.spawn(t)).collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_all() {
        let hits = AtomicU64::new(0);
        parallel_for(10_000, |i| {
            hits.fetch_add(i as u64 + 1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), (10_000u64 * 10_001) / 2);
    }

    #[test]
    fn parallel_for_empty() {
        parallel_for(0, |_| panic!("must not run"));
    }

    #[test]
    fn parallel_map_ordered() {
        let v = parallel_map(5_000, |i| i * 2);
        assert!(v.iter().enumerate().all(|(i, &x)| x == i * 2));
    }

    #[test]
    fn dynamic_visits_all_once() {
        let n = 20_000;
        let flags: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for_dynamic(n, 64, |i| {
            flags[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(flags.iter().all(|f| f.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn join_all_returns_in_order() {
        let results = join_all(vec![|| 1, || 2, || 3]);
        assert_eq!(results, vec![1, 2, 3]);
    }

    #[test]
    fn bounded_map_matches_sequential_for_any_worker_count() {
        let want: Vec<usize> = (0..97).map(|i| i * i).collect();
        for workers in [1, 2, 3, 8, 200] {
            let got = bounded_map(97, workers, |i| i * i);
            assert_eq!(got, want, "workers={workers}");
        }
        assert!(bounded_map(0, 4, |i| i).is_empty());
    }

    #[test]
    fn chunks_partition_exactly() {
        let seen = AtomicU64::new(0);
        parallel_for_chunks(1_000, |lo, hi| {
            seen.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        });
        assert_eq!(seen.load(Ordering::Relaxed), 1_000);
    }
}
