//! Minimal data-parallel substrate (rayon is unavailable offline) with a
//! **cooperative thread budget**.
//!
//! `parallel_for` splits an index range into contiguous chunks executed on
//! scoped OS threads; `parallel_map` collects per-index results. Both fall
//! back to inline execution for small ranges so unit tests and tiny graphs
//! don't pay thread spawn costs.
//!
//! This is also the substrate the §3.4 scheduler builds on: the "CPU
//! multi-thread initialization" side of the paper maps to scoped threads
//! here, while the cudaStream analog lives in [`crate::sched`].
//!
//! # The thread budget
//!
//! The paper's §3.4 speedups come from *controlled* concurrency — a fixed
//! set of threads feeding a fixed set of streams — but naive nesting
//! multiplies thread counts at every level: fleet workers × edge lanes ×
//! kernel `parallel_for` can put `W × 3 × num_threads()` runnable threads
//! behind `num_threads()` cores, destroying the overlap it was meant to
//! buy. The fix is a cooperative [`Budget`]:
//!
//! * The **root budget** is [`num_threads`] (`DRCG_THREADS`, the
//!   `--threads` flag via [`set_root_threads`], or the machine's available
//!   parallelism). It is initialized exactly once per process — the first
//!   read freezes it.
//! * Every primitive in this module consults the **ambient budget** of its
//!   calling thread ([`Budget::current`], a thread-local; unset ⇒ root)
//!   instead of the global `num_threads()`.
//! * A primitive running on a thread with budget `b` uses at most `b`
//!   threads *total*: it spawns `w − 1` workers and the calling thread
//!   itself runs the remaining share (callers participate, they never idle
//!   behind their own children). The `w` participants split the budget
//!   exactly — `⌊b/w⌋` each, the `b mod w` leftover threads going to the
//!   first participants — so nested primitives subdivide the same
//!   allowance rather than re-expanding to `num_threads()`, and no thread
//!   of the budget is stranded.
//!
//! By induction, a tree of nested primitives rooted at a thread with
//! budget `b` keeps at most `b − 1` spawned threads live at any instant
//! (the participant shares sum to `b`, and each participant's subtree
//! spawns at most its share minus the participant itself), i.e. at most
//! `b` runnable threads counting the root caller. The live/peak counters ([`live_workers`],
//! [`peak_workers`]) instrument exactly this invariant; it is asserted in
//! `tests/thread_budget.rs` for fleet × lanes × kernels under every kernel
//! mix. Budgets change scheduling only — every primitive writes each
//! result to a caller-indexed slot, so outputs are bit-identical for any
//! budget (the `fleet(N) ≡ sequential` guarantee survives).

use crate::util::sync::{Condvar, Mutex};
use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// The process-wide root thread budget. `0` = not yet initialized.
static ROOT_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Root thread budget: the total number of threads the process may keep
/// runnable, counting the calling thread.
///
/// Resolution order: [`set_root_threads`] (the `--threads` flag) if it ran
/// first, else the `DRCG_THREADS` environment variable, else the machine's
/// available parallelism. The first read **freezes** the value for the
/// process lifetime — this is the budget root's initialization, so a
/// later `DRCG_THREADS` change or `set_root_threads` call cannot
/// retroactively resize budgets already handed out.
///
/// Panics if `DRCG_THREADS` is set but is not a positive integer: a
/// mistyped cap silently falling back to all cores is exactly the
/// oversubscription bug the budget exists to prevent.
pub fn num_threads() -> usize {
    let cached = ROOT_THREADS.load(Ordering::Acquire);
    if cached != 0 {
        return cached;
    }
    let n = root_from_env();
    // First initializer wins. Racing initializers compute the same value,
    // so the losing store is harmless.
    match ROOT_THREADS.compare_exchange(0, n, Ordering::AcqRel, Ordering::Acquire) {
        Ok(_) => n,
        Err(existing) => existing,
    }
}

fn root_from_env() -> usize {
    match std::env::var("DRCG_THREADS") {
        Ok(s) => match s.trim().parse::<usize>() {
            Ok(n) if n > 0 => n,
            _ => panic!(
                "DRCG_THREADS must be a positive integer, got '{s}' \
                 (unset it to use the machine's available parallelism)"
            ),
        },
        Err(std::env::VarError::NotUnicode(_)) => {
            panic!("DRCG_THREADS must be valid unicode")
        }
        Err(std::env::VarError::NotPresent) => {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        }
    }
}

/// Set the root thread budget programmatically (the `--threads` flag).
///
/// Must run before the root budget's first read ([`num_threads`]); the
/// budget initializes once and first-use wins. Returns `Err` when `n` is
/// zero or the root was already initialized to a different value —
/// callers should surface that loudly rather than proceed with a budget
/// the user didn't ask for.
pub fn set_root_threads(n: usize) -> Result<(), String> {
    if n == 0 {
        return Err("thread budget must be ≥ 1".to_string());
    }
    match ROOT_THREADS.compare_exchange(0, n, Ordering::AcqRel, Ordering::Acquire) {
        Ok(_) => Ok(()),
        Err(existing) if existing == n => Ok(()),
        Err(existing) => Err(format!(
            "root thread budget already initialized to {existing} (first use wins); \
             set it before any parallel work runs"
        )),
    }
}

thread_local! {
    /// Ambient budget of the current thread. `0` = unset ⇒ root budget.
    static AMBIENT: Cell<usize> = const { Cell::new(0) };
}

/// A cooperative thread allowance: how many threads the current scope may
/// keep runnable, *counting the thread that holds it*.
///
/// Parents split their budget across concurrent children ([`Budget::lease`])
/// and the primitives in this module install each child's share as that
/// worker thread's ambient budget, so nesting levels — fleet workers, §3.4
/// edge lanes, kernel `parallel_for` — subdivide one allowance instead of
/// multiplying. See the module docs for the invariant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Budget(usize);

impl Budget {
    /// A budget of `threads` (clamped to ≥ 1: a thread can always run its
    /// own work inline).
    pub fn new(threads: usize) -> Budget {
        Budget(threads.max(1))
    }

    /// The process root budget ([`num_threads`]).
    pub fn root() -> Budget {
        Budget(num_threads())
    }

    /// The calling thread's ambient budget. Threads that no pool primitive
    /// spawned (the main thread, test-harness threads) default to the root
    /// budget; pool workers carry the share their parent leased to them.
    pub fn current() -> Budget {
        AMBIENT.with(|c| match c.get() {
            0 => Budget::root(),
            n => Budget(n),
        })
    }

    /// Number of threads this budget allows (≥ 1).
    pub fn threads(self) -> usize {
        self.0
    }

    /// Split the budget across up to `children` concurrent participants:
    /// returns `(concurrency, floor share)` with
    /// `concurrency × share.threads() ≤ self.threads()`. Concurrency never
    /// exceeds the budget; each share is ≥ 1. The primitives hand the
    /// `threads mod concurrency` leftover out via [`Budget::share_of`], so
    /// no thread of the budget is stranded — the floor share returned here
    /// is the *minimum* any participant gets.
    pub fn lease(self, children: usize) -> (usize, Budget) {
        let conc = self.0.min(children.max(1));
        (conc, Budget(self.0 / conc))
    }

    /// Ambient share of participant `i` of `workers`: `⌊b/w⌋`, plus one of
    /// the `b mod w` leftover threads for the first participants, so the
    /// shares sum to exactly the budget instead of stranding the
    /// remainder (e.g. a budget of 8 split 5 ways hands out 2,2,2,1,1).
    /// Crate-visible so the scheduler's epoch pipeline can hand its two
    /// stages the same shares the data-parallel primitives would.
    pub(crate) fn share_of(self, workers: usize, i: usize) -> Budget {
        let w = workers.max(1);
        Budget((self.0 / w + usize::from(i < self.0 % w)).max(1))
    }

    /// Run `f` with this budget installed as the calling thread's ambient
    /// budget, restoring the previous ambient afterwards (also on panic).
    pub fn with<R>(self, f: impl FnOnce() -> R) -> R {
        struct Restore(usize);
        impl Drop for Restore {
            fn drop(&mut self) {
                AMBIENT.with(|c| c.set(self.0));
            }
        }
        let prev = AMBIENT.with(|c| c.replace(self.0));
        let _restore = Restore(prev);
        f()
    }

    /// Worker count for an `n`-element data-parallel dispatch: 1 (inline)
    /// below the given sequential cutoff, else `min(budget, n)`.
    fn workers_for(self, n: usize, cutoff: usize) -> usize {
        if n < cutoff {
            1
        } else {
            self.0.min(n)
        }
    }

}

/// The budget layer's one documented sequential-cutoff rule.
///
/// Static chunking ([`parallel_for`] / [`parallel_for_chunks`]) runs
/// inline below `SEQ_CUTOFF` indices. The grained dynamic primitives
/// ([`parallel_for_dynamic`] / [`parallel_for_dynamic_order`]) share the
/// same rule scaled by [`grained_cutoff`]: `grain` is the scheduler's
/// per-item cost hint (small grain ⇒ expensive items — the DR-SpMM evil
/// rows are dispatched one-by-one precisely because each is worth a
/// thread), so the inline threshold shrinks with it,
/// `min(SEQ_CUTOFF, 2·grain)`. Historically the two dynamic primitives
/// disagreed (`parallel_for_dynamic` ignored grain in its cutoff); both
/// now go through [`grained_cutoff`]. Task-level primitives
/// ([`bounded_map`], [`join_all`]) have no cutoff — their items are whole
/// subgraph steps or edge lanes, always worth a thread when the budget
/// allows one.
const SEQ_CUTOFF: usize = 256;

/// Sequential cutoff for a grained dynamic dispatch (see [`SEQ_CUTOFF`]):
/// at least two items so a lone item never pays a spawn.
fn grained_cutoff(grain: usize) -> usize {
    SEQ_CUTOFF.min(grain.saturating_mul(2)).max(2)
}

// ---------------------------------------------------------------------------
// Thread accounting
// ---------------------------------------------------------------------------

/// Live worker threads spawned by this module (process-wide).
static LIVE_WORKERS: AtomicUsize = AtomicUsize::new(0);
/// High-water mark of [`LIVE_WORKERS`] since the last reset.
static PEAK_WORKERS: AtomicUsize = AtomicUsize::new(0);

/// Worker threads this module currently keeps alive, process-wide. The
/// initiating (caller) threads are not counted — they participate in the
/// work instead of idling, so `live_workers() + 1 ≤ budget` whenever a
/// single budget tree is running.
pub fn live_workers() -> usize {
    LIVE_WORKERS.load(Ordering::SeqCst)
}

/// High-water mark of [`live_workers`] since the last
/// [`reset_peak_workers`]. Process-global: meaningful only while one
/// budget tree runs at a time (see `tests/thread_budget.rs`, which
/// serializes for exactly this reason).
pub fn peak_workers() -> usize {
    PEAK_WORKERS.load(Ordering::SeqCst)
}

/// Reset the peak to the current live count.
pub fn reset_peak_workers() {
    PEAK_WORKERS.store(LIVE_WORKERS.load(Ordering::SeqCst), Ordering::SeqCst);
}

/// RAII live/peak bookkeeping for one spawned worker thread.
struct WorkerGuard;

impl WorkerGuard {
    fn enter() -> WorkerGuard {
        let live = LIVE_WORKERS.fetch_add(1, Ordering::SeqCst) + 1;
        PEAK_WORKERS.fetch_max(live, Ordering::SeqCst);
        WorkerGuard
    }
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        LIVE_WORKERS.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Spawn one accounted worker carrying `share` as its ambient budget.
/// Crate-visible so long-lived stage workers (the epoch pipeline's prepare
/// thread) participate in the same live/peak accounting as pool workers.
pub(crate) fn spawn_worker<'scope, 'env, F>(
    scope: &'scope std::thread::Scope<'scope, 'env>,
    share: Budget,
    f: F,
) where
    F: FnOnce() + Send + 'scope,
{
    scope.spawn(move || {
        let _live = WorkerGuard::enter();
        share.with(f);
    });
}

// ---------------------------------------------------------------------------
// Data-parallel primitives
// ---------------------------------------------------------------------------

/// Run `f(i)` for every `i in 0..n`, in parallel chunks.
///
/// `f` must be `Sync` (shared across threads); disjoint output writes should
/// go through raw pointers or per-chunk slices — see `parallel_for_chunks`.
pub fn parallel_for<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    parallel_for_chunks(n, |lo, hi| {
        for i in lo..hi {
            f(i);
        }
    });
}

/// Run `f(lo, hi)` over a contiguous partition of `0..n`. This is the
/// building block used by the kernels: each worker owns `[lo, hi)` rows.
/// Uses at most the ambient [`Budget`] worth of threads, caller included
/// (the caller runs the first chunk itself).
pub fn parallel_for_chunks<F>(n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let budget = Budget::current();
    let workers = budget.workers_for(n, SEQ_CUTOFF);
    if workers <= 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        for w in 1..workers {
            let lo = w * chunk;
            let hi = ((w + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            spawn_worker(scope, budget.share_of(workers, w), move || f(lo, hi));
        }
        // Caller participates: chunk 0 runs here, under its own share, so
        // total runnable threads never exceed the budget.
        budget.share_of(workers, 0).with(|| f(0, chunk.min(n)));
    });
}

/// Pull blocks of `grain` indices from a shared cursor until `0..n` drains.
fn drain_indices<F: Fn(usize)>(cursor: &AtomicUsize, n: usize, grain: usize, f: &F) {
    loop {
        let lo = cursor.fetch_add(grain, Ordering::Relaxed);
        if lo >= n {
            break;
        }
        for i in lo..(lo + grain).min(n) {
            f(i);
        }
    }
}

/// Work-stealing-ish dynamic scheduling: workers pull blocks of `grain`
/// indices from a shared atomic counter. Used where per-index cost is
/// highly skewed (power-law rows) and static chunking would tail-lag —
/// exactly the "evil row" effect §2.3 of the paper describes.
pub fn parallel_for_dynamic<F>(n: usize, grain: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let grain = grain.max(1);
    let budget = Budget::current();
    // No more participants than there are grain blocks to pull — extra
    // workers would spawn only to find the cursor drained, and their
    // shares are better spent widening the real participants.
    let workers = budget.workers_for(n, grained_cutoff(grain)).min(n.div_ceil(grain));
    if workers <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for w in 1..workers {
            let f = &f;
            let cursor = &cursor;
            spawn_worker(scope, budget.share_of(workers, w), move || {
                drain_indices(cursor, n, grain, f)
            });
        }
        budget.share_of(workers, 0).with(|| drain_indices(&cursor, n, grain, &f));
    });
}

/// Pull blocks of `grain` items from a shared cursor until `items` drains.
fn drain_items<T, F: Fn(&T)>(cursor: &AtomicUsize, items: &[T], grain: usize, f: &F) {
    let n = items.len();
    loop {
        let lo = cursor.fetch_add(grain, Ordering::Relaxed);
        if lo >= n {
            break;
        }
        for it in &items[lo..(lo + grain).min(n)] {
            f(it);
        }
    }
}

/// Dynamic scheduling over an explicit item slice (used by the DR-SpMM
/// degree-bucket schedule: items are row ids in bucket order). Shares the
/// one documented cutoff rule ([`SEQ_CUTOFF`] / [`grained_cutoff`]) with
/// [`parallel_for_dynamic`]: a two-row evil bucket (grain 1) still earns
/// two threads, a tiny cheap bucket runs inline.
pub fn parallel_for_dynamic_order<T: Sync, F>(items: &[T], grain: usize, f: F)
where
    F: Fn(&T) + Sync,
{
    let n = items.len();
    if n == 0 {
        return;
    }
    let grain = grain.max(1);
    let budget = Budget::current();
    // See parallel_for_dynamic: participants capped at the block count.
    let workers = budget.workers_for(n, grained_cutoff(grain)).min(n.div_ceil(grain));
    if workers <= 1 {
        for it in items {
            f(it);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for w in 1..workers {
            let f = &f;
            let cursor = &cursor;
            spawn_worker(scope, budget.share_of(workers, w), move || {
                drain_items(cursor, items, grain, f)
            });
        }
        budget.share_of(workers, 0).with(|| drain_items(&cursor, items, grain, &f));
    });
}

/// Parallel map collecting results in index order.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        parallel_for_chunks(n, |lo, hi| {
            let p = out_ptr; // copy the Send wrapper into the closure
            for i in lo..hi {
                // SAFETY: each index is written by exactly one worker.
                unsafe { *p.0.add(i) = f(i) };
            }
        });
    }
    out
}

/// Pointer wrapper asserting disjoint-index write safety across threads.
///
/// # Contract
///
/// Constructing a `SendPtr` is a promise about every write made through
/// it while more than one thread holds a copy:
///
/// * **Disjoint index ranges per worker.** Each participating thread
///   writes only through `ptr.add(i)` for indices `i` in a set no other
///   participant writes (or reads) concurrently — one worker per output
///   row, one writer per slot. Overlapping rows are a data race and
///   undefined behavior.
/// * **In-bounds.** Every index stays within the allocation the wrapped
///   pointer was derived from, which the caller must keep alive (and not
///   reallocate) for as long as any copy of the wrapper can be used.
/// * **Synchronized handback.** The owner re-reads the data only after
///   the writing threads are joined (the scoped-thread primitives in this
///   module provide that happens-before edge at scope exit).
///
/// Kernel code consumes `SendPtr` only inside this module's budgeted
/// primitives; minting new cross-thread capabilities (`unsafe impl
/// Send/Sync`) outside `util::pool` is rejected by lint rule R2
/// (`docs/ANALYSIS.md`).
pub struct SendPtr<T>(pub *mut T);
// Manual impls: derives would add a spurious `T: Copy` bound.
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
// SAFETY: sending the wrapper only moves the address; the contract above
// (disjoint index ranges per worker, no overlapping rows, join-before-read)
// is what makes the cross-thread *writes* race-free. Upheld by every
// construction site, each carrying its own SAFETY comment (lint rule R1).
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: `&SendPtr` only exposes the raw address (`Copy` read of field 0);
// aliased writes through it are governed by the same disjointness contract
// as `Send` above.
unsafe impl<T> Sync for SendPtr<T> {}

/// Run `n` independent tasks on at most `workers` threads (further capped
/// by the ambient [`Budget`]), collecting results in task order. Tasks are
/// pulled from a shared atomic cursor (dynamic assignment — skewed task
/// costs don't tail-lag a static stride), but because each task's output
/// is written to its own slot, the result is identical for every worker
/// count and every budget. This is the fleet's substrate: one task per
/// subgraph, graph-level parallelism on top of the kernels' own
/// `parallel_for` and the §3.4 edge lanes — each participant inherits an
/// equal share of the caller's budget, so the levels never multiply.
pub fn bounded_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let budget = Budget::current();
    let (workers, _) = budget.lease(workers.clamp(1, n.max(1)));
    if workers <= 1 {
        // Sequential: each task in turn keeps the caller's whole budget.
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = Some(f(i));
        }
    } else {
        let cursor = AtomicUsize::new(0);
        let out_ptr = SendPtr(out.as_mut_ptr());
        let drain = || {
            let p = out_ptr;
            loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                // SAFETY: the cursor hands each index to exactly one
                // participant, so every slot is written once.
                unsafe { *p.0.add(i) = Some(f(i)) };
            }
        };
        std::thread::scope(|scope| {
            for w in 1..workers {
                spawn_worker(scope, budget.share_of(workers, w), &drain);
            }
            budget.share_of(workers, 0).with(&drain);
        });
    }
    out.into_iter().map(|x| x.expect("bounded_map: unfilled slot")).collect()
}

/// Run a set of independent closures concurrently (the CPU-side "three
/// threads for three subgraphs" of paper Fig. 9b), at most the ambient
/// [`Budget`] of them at a time — the §3.4 edge lanes draw from the same
/// allowance as everything else. Results come back in task order for any
/// budget; with a budget of 1 every task runs inline on the caller.
pub fn join_all<T: Send, F: FnOnce() -> T + Send>(tasks: Vec<F>) -> Vec<T> {
    let n = tasks.len();
    if n == 0 {
        return Vec::new();
    }
    let budget = Budget::current();
    let (conc, _) = budget.lease(n);
    if conc <= 1 {
        // Sequential: each task in turn keeps the caller's whole budget.
        return tasks.into_iter().map(|t| t()).collect();
    }
    let mut slots: Vec<Option<F>> = tasks.into_iter().map(Some).collect();
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let cursor = AtomicUsize::new(0);
    let task_ptr = SendPtr(slots.as_mut_ptr());
    let out_ptr = SendPtr(out.as_mut_ptr());
    let drain = || {
        let tp = task_ptr;
        let op = out_ptr;
        loop {
            let i = cursor.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            // SAFETY: the cursor hands each index to exactly one
            // participant: the task is taken once, its slot written once.
            let task = unsafe { (*tp.0.add(i)).take().expect("join_all: task reused") };
            let result = task();
            // SAFETY: same single-owner index i as above — output slot i
            // is written exactly once, by this participant.
            unsafe { *op.0.add(i) = Some(result) };
        }
    };
    std::thread::scope(|scope| {
        for w in 1..conc {
            spawn_worker(scope, budget.share_of(conc, w), &drain);
        }
        budget.share_of(conc, 0).with(&drain);
    });
    out.into_iter().map(|x| x.expect("join_all: unfilled slot")).collect()
}

// ---------------------------------------------------------------------------
// Stage handoff
// ---------------------------------------------------------------------------

/// A single-slot blocking handoff between one producer and one consumer —
/// the substrate of the scheduler's epoch pipeline
/// ([`crate::sched::run_epoch_pipeline`]).
///
/// The slot holds at most one value: [`Handoff::put`] blocks while it is
/// full, [`Handoff::take`] blocks while it is empty. Together with the
/// producer computing its *next* value while the previous one sits in the
/// slot, this double-buffers the stream — the producer side keeps at most
/// two values alive (one in the slot, one in flight; plus whatever the
/// consumer still holds of the value it took), bounding memory however
/// far the producer could otherwise run ahead.
///
/// Both sides [`Handoff::close`] the slot when they finish *or unwind*:
/// a closed slot makes `put` return the value back (`Err`) and `take`
/// return `None`, so a panicking stage wakes its peer instead of
/// deadlocking it. Thread accounting is the caller's job — the pipeline
/// spawns its producer through [`spawn_worker`] on a leased
/// [`Budget`] share.
pub struct Handoff<T> {
    slot: Mutex<HandoffSlot<T>>,
    cond: Condvar,
}

struct HandoffSlot<T> {
    value: Option<T>,
    closed: bool,
}

impl<T> Default for Handoff<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Handoff<T> {
    pub fn new() -> Handoff<T> {
        Handoff {
            slot: Mutex::new(HandoffSlot { value: None, closed: false }),
            cond: Condvar::new(),
        }
    }

    /// Block until the slot is free, then deposit `v`. Returns `Err(v)` if
    /// the handoff was closed (the consumer is gone — stop producing).
    ///
    /// Poisoning policy (repo-wide, lint rule R3): recover the guard with
    /// `into_inner()`. Every slot transition here is a single field write,
    /// so a peer that panicked mid-critical-section cannot have left a
    /// half-updated invariant — and a panicking pipeline stage closes the
    /// handoff on unwind ([`HandoffCloser`]), so the recovered state is
    /// already marked closed by the time we observe it.
    pub fn put(&self, v: T) -> Result<(), T> {
        let mut slot = self.slot.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if slot.closed {
                return Err(v);
            }
            if slot.value.is_none() {
                slot.value = Some(v);
                self.cond.notify_all();
                return Ok(());
            }
            slot = self.cond.wait(slot).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Block until a value arrives, then take it. Returns `None` once the
    /// handoff is closed *and* drained (the producer is gone).
    ///
    /// Poisoning policy: recover via `into_inner()` — see [`Handoff::put`].
    pub fn take(&self) -> Option<T> {
        let mut slot = self.slot.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(v) = slot.value.take() {
                self.cond.notify_all();
                return Some(v);
            }
            if slot.closed {
                return None;
            }
            slot = self.cond.wait(slot).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Close the handoff, waking any blocked peer. Values already in the
    /// slot stay takeable (close-then-drain); new `put`s are refused.
    ///
    /// Poisoning policy: recover via `into_inner()` — this is the method
    /// [`HandoffCloser`] runs *during unwind*, so it must keep working
    /// after the panicking thread poisoned the lock (see [`Handoff::put`]).
    pub fn close(&self) {
        self.slot.lock().unwrap_or_else(|e| e.into_inner()).closed = true;
        self.cond.notify_all();
    }
}

/// RAII closer: closes the handoff when dropped — including on unwind, so
/// a panicking pipeline stage releases its blocked peer.
pub struct HandoffCloser<'a, T>(pub &'a Handoff<T>);

impl<T> Drop for HandoffCloser<'_, T> {
    fn drop(&mut self) {
        self.0.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_all() {
        let hits = AtomicU64::new(0);
        parallel_for(10_000, |i| {
            hits.fetch_add(i as u64 + 1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), (10_000u64 * 10_001) / 2);
    }

    #[test]
    fn parallel_for_empty() {
        parallel_for(0, |_| panic!("must not run"));
    }

    #[test]
    fn parallel_map_ordered() {
        let v = parallel_map(5_000, |i| i * 2);
        assert!(v.iter().enumerate().all(|(i, &x)| x == i * 2));
    }

    #[test]
    fn dynamic_visits_all_once() {
        let n = 20_000;
        let flags: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for_dynamic(n, 64, |i| {
            flags[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(flags.iter().all(|f| f.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn dynamic_order_visits_all_once() {
        let items: Vec<usize> = (0..5_000).collect();
        let flags: Vec<AtomicU64> = items.iter().map(|_| AtomicU64::new(0)).collect();
        parallel_for_dynamic_order(&items, 16, |&i| {
            flags[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(flags.iter().all(|f| f.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn join_all_returns_in_order() {
        let results = join_all(vec![|| 1, || 2, || 3]);
        assert_eq!(results, vec![1, 2, 3]);
    }

    #[test]
    fn join_all_order_for_many_tasks_and_any_budget() {
        let want: Vec<usize> = (0..37).collect();
        for b in [1, 2, 3, 64] {
            let tasks: Vec<_> = (0..37).map(|i| move || i).collect();
            let got = Budget::new(b).with(|| join_all(tasks));
            assert_eq!(got, want, "budget={b}");
        }
    }

    #[test]
    fn bounded_map_matches_sequential_for_any_worker_count() {
        let want: Vec<usize> = (0..97).map(|i| i * i).collect();
        for workers in [1, 2, 3, 8, 200] {
            let got = bounded_map(97, workers, |i| i * i);
            assert_eq!(got, want, "workers={workers}");
        }
        assert!(bounded_map(0, 4, |i| i).is_empty());
    }

    #[test]
    fn chunks_partition_exactly() {
        let seen = AtomicU64::new(0);
        parallel_for_chunks(1_000, |lo, hi| {
            seen.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        });
        assert_eq!(seen.load(Ordering::Relaxed), 1_000);
    }

    /// The one cutoff rule: grain scales the dynamic primitives' inline
    /// threshold (small grain = expensive items ⇒ parallelize earlier),
    /// and both dynamic primitives agree on it.
    #[test]
    fn grained_cutoff_scales_with_item_cost() {
        assert_eq!(grained_cutoff(1), 2); // evil rows: ≥ 2 earn threads
        assert_eq!(grained_cutoff(8), 16);
        assert_eq!(grained_cutoff(128), SEQ_CUTOFF);
        assert_eq!(grained_cutoff(usize::MAX), SEQ_CUTOFF); // no overflow
        // grain=1, n=4 (far below SEQ_CUTOFF) must still go parallel when
        // the budget allows it: with budget 4 each item may land on a
        // distinct participant, and all items run exactly once.
        let flags: Vec<AtomicU64> = (0..4).map(|_| AtomicU64::new(0)).collect();
        Budget::new(4).with(|| {
            parallel_for_dynamic(4, 1, |i| {
                flags[i].fetch_add(1, Ordering::Relaxed);
            });
        });
        assert!(flags.iter().all(|f| f.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn lease_arithmetic_never_exceeds_budget() {
        for threads in 1..=16usize {
            for children in 0..=20usize {
                let (conc, share) = Budget::new(threads).lease(children);
                assert!(conc >= 1 && conc <= threads);
                assert!(conc <= children.max(1));
                assert!(share.threads() >= 1);
                assert!(
                    conc * share.threads() <= threads,
                    "budget {threads} × {children}: {conc} × {}",
                    share.threads()
                );
            }
        }
    }

    #[test]
    fn ambient_budget_nests_and_restores() {
        let outer = Budget::current();
        Budget::new(5).with(|| {
            assert_eq!(Budget::current().threads(), 5);
            Budget::new(2).with(|| assert_eq!(Budget::current().threads(), 2));
            assert_eq!(Budget::current().threads(), 5);
        });
        assert_eq!(Budget::current(), outer);
    }

    /// Budget 1 must degenerate every primitive to inline execution on the
    /// calling thread — no spawns at all (`DRCG_THREADS=1` semantics).
    #[test]
    fn budget_one_degenerates_every_primitive_to_inline() {
        Budget::new(1).with(|| {
            let me = std::thread::current().id();
            let on_caller = |ok: bool| assert!(ok, "work left the calling thread");
            parallel_for(10_000, |_| on_caller(std::thread::current().id() == me));
            parallel_for_chunks(10_000, |_, _| on_caller(std::thread::current().id() == me));
            parallel_for_dynamic(10_000, 16, |_| on_caller(std::thread::current().id() == me));
            let items: Vec<u32> = (0..2_000).collect();
            parallel_for_dynamic_order(&items, 1, |_| {
                on_caller(std::thread::current().id() == me)
            });
            let v = bounded_map(9, 8, |i| {
                on_caller(std::thread::current().id() == me);
                i
            });
            assert_eq!(v, (0..9).collect::<Vec<_>>());
            let tasks: Vec<_> = (0..4)
                .map(|i| {
                    move || {
                        on_caller(std::thread::current().id() == me);
                        i * 3
                    }
                })
                .collect();
            assert_eq!(join_all(tasks), vec![0, 3, 6, 9]);
        });
    }

    /// Nested primitives subdivide the parent's budget: a worker of a
    /// 4-thread `bounded_map` sees an ambient share of 1, not the root.
    #[test]
    fn workers_inherit_their_share() {
        Budget::new(4).with(|| {
            let shares = bounded_map(4, 4, |_| Budget::current().threads());
            assert_eq!(shares, vec![1; 4]);
            let shares = bounded_map(2, 2, |_| Budget::current().threads());
            assert_eq!(shares, vec![2; 2]);
        });
        // A non-dividing budget distributes its remainder instead of
        // stranding it: 5 across 2 participants is {3, 2} (which tasks a
        // participant drains is scheduling-dependent, so only the share
        // *values* are deterministic).
        Budget::new(5).with(|| {
            let shares = bounded_map(2, 2, |_| Budget::current().threads());
            assert!(shares.iter().all(|&s| s == 2 || s == 3), "{shares:?}");
        });
        for b in 1..=9usize {
            for w in 1..=b {
                let total: usize = (0..w).map(|i| Budget::new(b).share_of(w, i).threads()).sum();
                assert_eq!(total, b, "shares must sum to the budget ({b} across {w})");
            }
        }
    }

    #[test]
    fn handoff_passes_values_in_order() {
        let h = Handoff::new();
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..100 {
                    h.put(i).expect("consumer alive");
                }
                h.close();
            });
            let mut got = Vec::new();
            while let Some(v) = h.take() {
                got.push(v);
            }
            assert_eq!(got, (0..100).collect::<Vec<_>>());
        });
    }

    #[test]
    fn handoff_close_drains_pending_value_then_ends() {
        let h = Handoff::new();
        h.put(7).unwrap();
        h.close();
        assert_eq!(h.take(), Some(7), "close-then-drain keeps the slot value");
        assert_eq!(h.take(), None);
        assert_eq!(h.put(8), Err(8), "closed handoff refuses new values");
    }

    #[test]
    fn handoff_closer_releases_blocked_producer_on_consumer_exit() {
        let h: Handoff<usize> = Handoff::new();
        std::thread::scope(|s| {
            let producer = s.spawn(|| {
                let _close = HandoffCloser(&h);
                let mut sent = 0usize;
                for i in 0.. {
                    if h.put(i).is_err() {
                        break; // consumer closed — stop, don't deadlock
                    }
                    sent += 1;
                }
                sent
            });
            {
                let _close = HandoffCloser(&h);
                assert_eq!(h.take(), Some(0)); // take one, then "die"
            }
            let sent = producer.join().unwrap();
            assert!(sent >= 1, "producer must have delivered the taken value");
        });
    }
}
