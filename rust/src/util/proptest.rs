//! Miniature property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a property over `cases` seeded random
//! instances; on failure it reports the failing case index and seed so the
//! instance can be replayed deterministically. Shrinking is approximated by
//! re-running the generator with a "size" knob that grows from small to
//! large, so the *first* failure tends to be a small instance.

use super::rng::Rng;

/// Context handed to a property: an RNG plus a size hint in `[0, 1]` that
/// grows over the run (small cases first).
pub struct Gen {
    pub rng: Rng,
    pub size: f64,
    pub case: usize,
}

impl Gen {
    /// Integer in `[lo, hi]` scaled by the size knob: early cases stay near
    /// `lo`, later cases span the full range.
    pub fn sized(&mut self, lo: usize, hi: usize) -> usize {
        let span = ((hi - lo) as f64 * self.size).round() as usize;
        self.rng.range(lo, lo + span.max(1) + 1).min(hi)
    }

    pub fn usize_in(&mut self, lo: usize, hi_inclusive: usize) -> usize {
        self.rng.range(lo, hi_inclusive + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    /// Random f32 vector with entries from N(0, 1).
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal()).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Run a property over `cases` random instances. Panics with a replayable
/// seed on the first failure.
pub fn check<F>(name: &str, cases: usize, base_seed: u64, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen {
            rng: Rng::new(seed),
            size: (case as f64 + 1.0) / cases as f64,
            case,
        };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed=0x{seed:016x}): {msg}"
            );
        }
    }
}

/// Convenience: property that asserts two f32 slices are close.
pub fn prop_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol || !x.is_finite() || !y.is_finite() {
            return Err(format!("idx {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always-true", 25, 1, |_| Ok(()));
        // count is not shared into the closure above; run again with capture:
        let counter = std::cell::Cell::new(0usize);
        check("counting", 25, 1, |_| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, 2, |g| {
            if g.case == 3 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn sized_grows() {
        let mut small = Gen { rng: Rng::new(1), size: 0.01, case: 0 };
        let mut large = Gen { rng: Rng::new(1), size: 1.0, case: 99 };
        let s: usize = (0..100).map(|_| small.sized(1, 1000)).sum();
        let l: usize = (0..100).map(|_| large.sized(1, 1000)).sum();
        assert!(s < l);
    }

    #[test]
    fn prop_allclose_detects_mismatch() {
        assert!(prop_allclose(&[1.0], &[1.0], 1e-6, 0.0).is_ok());
        assert!(prop_allclose(&[1.0], &[2.0], 1e-6, 0.0).is_err());
        assert!(prop_allclose(&[1.0], &[1.0, 2.0], 1e-6, 0.0).is_err());
    }
}
