//! Synchronization substrate switch: `std::sync` normally, `loom::sync`
//! under `--cfg loom`.
//!
//! The concurrency core's blocking primitives — [`crate::util::pool::Handoff`]
//! and [`crate::serve::Queue`] — import `Mutex`/`Condvar` from here instead
//! of `std::sync`, so the *production implementations themselves* (not
//! copies) compile against loom's model-checked types when the loom cfg is
//! set. `tests/loom_models.rs` then explores every interleaving of their
//! protocols (put/take/close, push/pop/shutdown) under loom's C11 memory
//! model. See `docs/ANALYSIS.md` for how to run the models.
//!
//! Normal builds see plain re-exports of `std::sync` and compile to exactly
//! the code this module replaced; loom is declared as a
//! `[target.'cfg(loom)'.dependencies]` entry, so it is never downloaded or
//! built unless the cfg is on.
//!
//! Both substrates share the `std::sync` poisoning API surface (`lock()`
//! returns `LockResult`), so the repo-wide poisoning policy — recover with
//! `unwrap_or_else(|e| e.into_inner())`, never bare `.lock().unwrap()`
//! (lint rule R3) — compiles identically under either.

#[cfg(loom)]
pub(crate) use loom::sync::{Condvar, Mutex};

#[cfg(not(loom))]
pub(crate) use std::sync::{Condvar, Mutex};
