//! Deterministic pseudo-random number generation.
//!
//! A PCG-XSH-RR 64/32 generator seeded through SplitMix64. Every stochastic
//! component in the crate (data generation, weight init, property tests)
//! derives from an explicit seed so runs are exactly reproducible.

/// PCG-XSH-RR 64/32 pseudo-random generator.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let init_inc = splitmix64(&mut sm) | 1;
        let mut rng = Rng { state: 0, inc: init_inc };
        rng.state = init_state.wrapping_add(init_inc);
        rng.next_u32();
        rng
    }

    /// Derive an independent stream (for per-thread / per-subgraph RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with f64 precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire rejection-free is overkill;
    /// simple modulo bias is acceptable for bounds ≪ 2^32 used here, but we
    /// still use the widening-multiply trick for uniformity).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        (((self.next_u32() as u64) * (bound as u64)) >> 32) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Uniform in `[lo, hi)` as f32.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f32();
            if u1 > 1e-7 {
                let u2 = self.f32();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Sample from a discrete power law on `[xmin, xmax]` with exponent
    /// `alpha` (>1): p(x) ∝ x^-alpha. Used to draw circuit node degrees —
    /// §2.3 of the paper observes power-law neighbor counts with
    /// edge-type-specific peaks ("evil rows").
    pub fn power_law(&mut self, xmin: f64, xmax: f64, alpha: f64) -> f64 {
        debug_assert!(alpha > 1.0 && xmax > xmin && xmin > 0.0);
        let a1 = 1.0 - alpha;
        let u = self.f64();
        ((xmin.powf(a1)) * (1.0 - u) + (xmax.powf(a1)) * u).powf(1.0 / a1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k ≤ n), order randomized.
    ///
    /// Sparse partial Fisher–Yates: O(k log k) time and O(k) space instead
    /// of materialising the full `(0..n)` vector — at n = 10⁶ the dense
    /// init dominated every mini-batch draw. The swap map records only the
    /// displaced entries of the virtual index vector, so the RNG call
    /// sequence and the output are identical to the dense algorithm
    /// (pinned by `sample_indices_matches_dense_reference`).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut swapped: std::collections::BTreeMap<usize, usize> = std::collections::BTreeMap::new();
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = self.range(i, n);
            // Virtual idx[j] (displaced value if some earlier swap moved one here).
            let vj = swapped.get(&j).copied().unwrap_or(j);
            // Virtual idx[i] moves to slot j; slot i is never read again (j ≥ i).
            let vi = swapped.get(&i).copied().unwrap_or(i);
            swapped.insert(j, vi);
            out.push(vj);
        }
        out
    }

    /// Fill a slice with He-initialised weights (normal, std = sqrt(2/fan_in)).
    pub fn fill_he(&mut self, xs: &mut [f32], fan_in: usize) {
        let std = (2.0 / fan_in.max(1) as f32).sqrt();
        for x in xs.iter_mut() {
            *x = self.normal() * std;
        }
    }

    /// Fill with Xavier/Glorot uniform.
    pub fn fill_xavier(&mut self, xs: &mut [f32], fan_in: usize, fan_out: usize) {
        let limit = (6.0 / (fan_in + fan_out).max(1) as f32).sqrt();
        for x in xs.iter_mut() {
            *x = self.uniform(-limit, limit);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_respected() {
        let mut r = Rng::new(9);
        for bound in [1usize, 2, 3, 17, 1000] {
            for _ in 0..1000 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn below_covers_small_range() {
        let mut r = Rng::new(11);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut sum, mut sq) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn power_law_bounds_and_skew() {
        let mut r = Rng::new(13);
        let mut below_mid = 0;
        for _ in 0..10_000 {
            let x = r.power_law(1.0, 100.0, 2.5);
            assert!((1.0..=100.0).contains(&x));
            if x < 50.5 {
                below_mid += 1;
            }
        }
        // Heavy-tailed: almost all mass near xmin.
        assert!(below_mid > 9_000);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(21);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(3);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 30);
    }

    /// The sparse swap-map implementation must reproduce the dense partial
    /// Fisher–Yates exactly — same RNG draws, same output order — across
    /// seeds and (n, k) shapes including k = 0, k = n, and k ≪ n.
    #[test]
    fn sample_indices_matches_dense_reference() {
        fn dense_reference(rng: &mut Rng, n: usize, k: usize) -> Vec<usize> {
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = rng.range(i, n);
                idx.swap(i, j);
            }
            idx.truncate(k);
            idx
        }
        for seed in [0u64, 3, 42, 0xDEAD] {
            for &(n, k) in
                &[(1usize, 0usize), (1, 1), (10, 10), (100, 30), (1000, 1), (5000, 64)]
            {
                let mut a = Rng::new(seed);
                let mut b = Rng::new(seed);
                let got = a.sample_indices(n, k);
                let want = dense_reference(&mut b, n, k);
                assert_eq!(got, want, "seed {seed} n {n} k {k}");
                // Both consumed the same number of draws.
                assert_eq!(a.next_u64(), b.next_u64(), "seed {seed} n {n} k {k}: rng state");
            }
        }
    }

    #[test]
    fn sample_indices_sparse_at_scale() {
        // The whole point of the sparse rewrite: a large-n draw must not
        // cost O(n). This finishes instantly; the dense init would still
        // pass but this pins the distinctness contract at scale.
        let mut r = Rng::new(17);
        let s = r.sample_indices(1 << 20, 256);
        assert_eq!(s.len(), 256);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 256);
        assert!(s.iter().all(|&i| i < (1 << 20)));
    }

    #[test]
    fn fork_independent() {
        let mut root = Rng::new(99);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
