//! Leveled stderr logger with wall-clock offsets.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();

/// Set the global log level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Parse a level from `debug|info|warn|error`.
pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "debug" => Some(Level::Debug),
        "info" => Some(Level::Info),
        "warn" => Some(Level::Warn),
        "error" => Some(Level::Error),
        _ => None,
    }
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, args: std::fmt::Arguments) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match level {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:9.3}s {tag}] {args}");
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Error, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("INFO"), Some(Level::Info));
        assert_eq!(parse_level("nope"), None);
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
    }
}
