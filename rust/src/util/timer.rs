//! Wall-clock timing and summary statistics for the bench harness.

use std::time::{Duration, Instant};

/// A simple stopwatch with named lap capture.
pub struct Stopwatch {
    start: Instant,
    laps: Vec<(String, Duration)>,
    last: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch { start: now, laps: Vec::new(), last: now }
    }

    /// Record a named lap since the previous lap (or start).
    pub fn lap(&mut self, name: &str) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        self.laps.push((name.to_string(), d));
        d
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn laps(&self) -> &[(String, Duration)] {
        &self.laps
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Robust summary of repeated timing samples (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimingStats {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    /// Median absolute deviation — robust spread estimate.
    pub mad: f64,
}

impl TimingStats {
    pub fn from_samples(samples: &[f64]) -> TimingStats {
        assert!(!samples.is_empty());
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        let median = percentile_sorted(&s, 0.5);
        let mean = s.iter().sum::<f64>() / n as f64;
        let mut dev: Vec<f64> = s.iter().map(|x| (x - median).abs()).collect();
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        TimingStats {
            n,
            mean,
            median,
            min: s[0],
            max: s[n - 1],
            mad: percentile_sorted(&dev, 0.5),
        }
    }
}

fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = q * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Format seconds human-readably (µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_constant() {
        let s = TimingStats::from_samples(&[2.0, 2.0, 2.0]);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.mad, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
    }

    #[test]
    fn median_odd_even() {
        let s = TimingStats::from_samples(&[1.0, 2.0, 9.0]);
        assert_eq!(s.median, 2.0);
        let s = TimingStats::from_samples(&[1.0, 2.0, 3.0, 10.0]);
        assert!((s.median - 2.5).abs() < 1e-12);
    }

    #[test]
    fn median_robust_to_outlier() {
        let s = TimingStats::from_samples(&[1.0, 1.0, 1.0, 1.0, 100.0]);
        assert_eq!(s.median, 1.0);
        assert!(s.mean > 10.0);
    }

    #[test]
    fn stopwatch_laps_accumulate() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(2));
        sw.lap("a");
        std::thread::sleep(Duration::from_millis(2));
        sw.lap("b");
        assert_eq!(sw.laps().len(), 2);
        assert!(sw.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }
}
