//! Infrastructure substrates.
//!
//! The offline build environment provides no `rayon`, `clap`, `serde`,
//! `criterion` or `proptest`, so this module implements the minimal
//! equivalents the rest of the crate needs: a counter-based RNG, a scoped
//! thread pool with `parallel_for` behind a cooperative thread [`Budget`],
//! wall-clock timing statistics, a leveled logger, a CLI argument parser,
//! a TOML-subset config reader and a tiny property-testing harness.

pub mod cli;
pub mod configfile;
pub mod logger;
pub mod math;
pub mod pool;
pub mod proptest;
pub mod rng;
pub(crate) mod sync;
pub mod timer;

pub use pool::{num_threads, parallel_for, parallel_map, Budget};
pub use rng::Rng;
pub use timer::Stopwatch;
