//! Small numeric helpers shared across modules.

/// Max absolute difference between two slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Relative L2 error ‖a-b‖ / (‖b‖ + eps).
pub fn rel_l2(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    let den: f32 = b.iter().map(|y| y * y).sum();
    (num.sqrt()) / (den.sqrt() + 1e-12)
}

/// Assert two slices are close (used heavily by kernel tests).
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "idx {i}: {x} vs {y} (|diff|={} > tol={tol})",
            (x - y).abs()
        );
    }
}

/// Next power of two ≥ x.
pub fn next_pow2(x: usize) -> usize {
    x.next_power_of_two()
}

/// Integer ceil division.
pub const fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Arithmetic mean of an f64 slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean — the right average for speedup ratios.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diff_helpers() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 2.5]), 0.5);
        assert!(rel_l2(&[1.0, 0.0], &[1.0, 0.0]) < 1e-9);
    }

    #[test]
    fn allclose_passes_and_fails() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-6, 2.0], 1e-5, 0.0);
        let r = std::panic::catch_unwind(|| assert_allclose(&[1.0], &[2.0], 1e-5, 0.0));
        assert!(r.is_err());
    }

    #[test]
    fn means() {
        assert_eq!(mean(&[1.0, 3.0]), 2.0);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn ceil_div_cases() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(next_pow2(33), 64);
    }
}
