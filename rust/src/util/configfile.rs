//! TOML-subset configuration parser (serde/toml unavailable offline).
//!
//! Supports what the config system needs: `[section]` headers, `key = value`
//! with string / integer / float / boolean / flat arrays, `#` comments.
//! Values are stored as strings with typed getters; sections flatten into
//! dotted keys (`section.key`).

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone, PartialEq)]
pub struct ConfigFile {
    values: BTreeMap<String, String>,
}

impl ConfigFile {
    pub fn parse(text: &str) -> Result<ConfigFile, String> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(format!("line {}: unterminated section header", lineno + 1));
                }
                section = line[1..line.len() - 1].trim().to_string();
                if section.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            values.insert(key, unquote(v.trim()));
        }
        Ok(ConfigFile { values })
    }

    pub fn load(path: &std::path::Path) -> Result<ConfigFile, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Option<Result<usize, String>> {
        self.get(key).map(|v| v.parse().map_err(|_| format!("{key}: bad integer '{v}'")))
    }

    pub fn get_f32(&self, key: &str) -> Option<Result<f32, String>> {
        self.get(key).map(|v| v.parse().map_err(|_| format!("{key}: bad float '{v}'")))
    }

    pub fn get_bool(&self, key: &str) -> Option<Result<bool, String>> {
        self.get(key).map(|v| match v {
            "true" | "1" | "yes" => Ok(true),
            "false" | "0" | "no" => Ok(false),
            _ => Err(format!("{key}: bad bool '{v}'")),
        })
    }

    /// Arrays like `ks = [2, 4, 8]`.
    pub fn get_usize_list(&self, key: &str) -> Option<Result<Vec<usize>, String>> {
        self.get(key).map(|v| {
            let inner = v.trim().trim_start_matches('[').trim_end_matches(']');
            inner
                .split(',')
                .filter(|t| !t.trim().is_empty())
                .map(|t| t.trim().parse().map_err(|_| format!("{key}: bad integer '{t}'")))
                .collect()
        })
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }

    /// Overlay: values from `other` replace this one's.
    pub fn merged_with(mut self, other: &ConfigFile) -> ConfigFile {
        for (k, v) in &other.values {
            self.values.insert(k.clone(), v.clone());
        }
        self
    }

    pub fn insert(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside quotes.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> String {
    let v = v.trim();
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        v[1..v.len() - 1].to_string()
    } else {
        v.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# global
seed = 42
name = "mini circuit"  # inline comment

[train]
lr = 0.0002
epochs = 50
parallel = true
ks = [2, 4, 8]
"#;

    #[test]
    fn parse_sections_and_types() {
        let c = ConfigFile::parse(SAMPLE).unwrap();
        assert_eq!(c.get_usize("seed").unwrap().unwrap(), 42);
        assert_eq!(c.get("name"), Some("mini circuit"));
        assert_eq!(c.get_f32("train.lr").unwrap().unwrap(), 0.0002);
        assert_eq!(c.get_usize("train.epochs").unwrap().unwrap(), 50);
        assert!(c.get_bool("train.parallel").unwrap().unwrap());
        assert_eq!(c.get_usize_list("train.ks").unwrap().unwrap(), vec![2, 4, 8]);
    }

    #[test]
    fn missing_key_is_none() {
        let c = ConfigFile::parse(SAMPLE).unwrap();
        assert!(c.get("nope").is_none());
        assert!(c.get_usize("train.nope").is_none());
    }

    #[test]
    fn bad_values_error() {
        let c = ConfigFile::parse("x = abc").unwrap();
        assert!(c.get_usize("x").unwrap().is_err());
        assert!(c.get_bool("x").unwrap().is_err());
    }

    #[test]
    fn malformed_lines_error() {
        assert!(ConfigFile::parse("[open").is_err());
        assert!(ConfigFile::parse("novalue").is_err());
        assert!(ConfigFile::parse("[]").is_err());
    }

    #[test]
    fn merge_overrides() {
        let base = ConfigFile::parse("a = 1\nb = 2").unwrap();
        let over = ConfigFile::parse("b = 3\nc = 4").unwrap();
        let m = base.merged_with(&over);
        assert_eq!(m.get("a"), Some("1"));
        assert_eq!(m.get("b"), Some("3"));
        assert_eq!(m.get("c"), Some("4"));
    }

    #[test]
    fn hash_inside_quotes_kept() {
        let c = ConfigFile::parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(c.get("tag"), Some("a#b"));
    }
}
