//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments,
//! with typed getters and a usage printer. Subcommand dispatch lives in
//! `main.rs`.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    /// Declared options for usage/validation: (name, help, takes_value).
    spec: Vec<(String, String, bool)>,
}

impl Args {
    /// Declare an option (for `usage()` and unknown-option detection).
    pub fn declare(mut self, name: &str, help: &str, takes_value: bool) -> Self {
        self.spec.push((name.to_string(), help.to_string(), takes_value));
        self
    }

    /// Parse raw arguments. Options may appear as `--k v` or `--k=v`;
    /// declared no-value options are flags.
    pub fn parse(mut self, raw: &[String]) -> Result<Self, String> {
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    self.opts.insert(k.to_string(), v.to_string());
                } else {
                    let takes_value = self
                        .spec
                        .iter()
                        .find(|(n, _, _)| n == name)
                        .map(|(_, _, tv)| *tv)
                        // Undeclared options: guess from the next token.
                        .unwrap_or_else(|| {
                            raw.get(i + 1).map(|n| !n.starts_with("--")).unwrap_or(false)
                        });
                    if takes_value {
                        let v = raw
                            .get(i + 1)
                            .ok_or_else(|| format!("option --{name} expects a value"))?;
                        self.opts.insert(name.to_string(), v.clone());
                        i += 1;
                    } else {
                        self.flags.push(name.to_string());
                    }
                }
            } else {
                self.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(self)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.opts.get(name).is_some_and(|v| v == "true")
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: expected integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: expected float, got '{v}'")),
        }
    }

    pub fn get_f32(&self, name: &str, default: f32) -> Result<f32, String> {
        Ok(self.get_f64(name, default as f64)? as f32)
    }

    /// Comma-separated list of integers, e.g. `--ks 2,4,8`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, String> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| t.trim().parse().map_err(|_| format!("--{name}: bad integer '{t}'")))
                .collect(),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn usage(&self, prog: &str) -> String {
        let mut out = format!("usage: {prog} [options]\n");
        for (name, help, tv) in &self.spec {
            let arg = if *tv { format!("--{name} <v>") } else { format!("--{name}") };
            out.push_str(&format!("  {arg:<24} {help}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_key_value_both_styles() {
        let a = Args::default()
            .declare("dim", "embedding dim", true)
            .parse(&raw(&["--dim", "64", "--k=8"]))
            .unwrap();
        assert_eq!(a.get("dim"), Some("64"));
        assert_eq!(a.get_usize("k", 0).unwrap(), 8);
    }

    #[test]
    fn parse_flags_and_positionals() {
        let a = Args::default()
            .declare("fast", "quick mode", false)
            .parse(&raw(&["train", "--fast", "out.txt"]))
            .unwrap();
        assert!(a.flag("fast"));
        assert_eq!(a.positional(), &["train".to_string(), "out.txt".to_string()]);
    }

    #[test]
    fn typed_defaults_and_errors() {
        let a = Args::default().parse(&raw(&["--lr", "0.01"])).unwrap();
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.01);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        let b = Args::default().parse(&raw(&["--n", "abc"])).unwrap();
        assert!(b.get_usize("n", 0).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = Args::default().parse(&raw(&["--ks", "2,4, 8"])).unwrap();
        assert_eq!(a.get_usize_list("ks", &[]).unwrap(), vec![2, 4, 8]);
        let d = Args::default().parse(&raw(&[])).unwrap();
        assert_eq!(d.get_usize_list("ks", &[1, 2]).unwrap(), vec![1, 2]);
    }

    #[test]
    fn missing_value_is_error() {
        let r = Args::default().declare("out", "path", true).parse(&raw(&["--out"]));
        assert!(r.is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let a = Args::default().declare("dim", "embedding dim", true);
        assert!(a.usage("prog").contains("--dim"));
    }
}
