//! drcg-lint CLI: scan `rust/src/**` with the in-repo static-analysis
//! rules (R1–R5) and fail on any finding the allowlist does not justify.
//!
//! Usage:
//!
//! ```text
//! drcg-lint [--src <dir>] [--allow <file>] [--list-rules]
//! ```
//!
//! Defaults resolve from the working directory: `src/` (when run from
//! `rust/`, as CI does) or `rust/src/` (from the repo root), with the
//! allowlist at `lint-allow.txt` beside the source root's parent. Exit
//! code 0 only when the tree is clean AND every allowlist entry still
//! covers a finding — stale exemptions fail too, so the allowlist can
//! only shrink unless a new justification is written. See
//! `docs/ANALYSIS.md` for the rule catalog.

use dr_circuitgnn::analysis::{lint_tree, Allowlist};
use std::path::PathBuf;
use std::process::ExitCode;

const RULES: &[(&str, &str)] = &[
    ("R1", "every `unsafe` carries a `// SAFETY:` disjointness contract"),
    ("R2", "thread fan-out and Send/Sync capabilities confined to util::pool"),
    ("R3", "locks recover from poisoning via into_inner(); no bare lock-unwrap"),
    ("R4", "no nondeterminism sources in golden-trace paths"),
    ("R5", "every KernelSpec variant has a plan-store serializer arm"),
];

fn main() -> ExitCode {
    let mut src: Option<PathBuf> = None;
    let mut allow: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--src" => src = args.next().map(PathBuf::from),
            "--allow" => allow = args.next().map(PathBuf::from),
            "--list-rules" => {
                for (id, what) in RULES {
                    println!("{id}  {what}");
                }
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("drcg-lint: unknown argument '{other}'");
                eprintln!("usage: drcg-lint [--src <dir>] [--allow <file>] [--list-rules]");
                return ExitCode::FAILURE;
            }
        }
    }
    let src = src.unwrap_or_else(|| {
        if PathBuf::from("src/lib.rs").exists() {
            PathBuf::from("src")
        } else {
            PathBuf::from("rust/src")
        }
    });
    let allow_path = allow.unwrap_or_else(|| {
        src.parent().map(|p| p.join("lint-allow.txt")).unwrap_or_else(|| "lint-allow.txt".into())
    });

    let allowlist = match Allowlist::load(&allow_path) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("drcg-lint: {e}");
            return ExitCode::FAILURE;
        }
    };
    let report = match lint_tree(&src, &allowlist) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("drcg-lint: {e}");
            return ExitCode::FAILURE;
        }
    };

    for d in &report.diagnostics {
        println!("{d}");
        if !d.excerpt.is_empty() {
            println!("    --> {}", d.excerpt);
        }
    }
    for a in &report.stale {
        println!(
            "{}: stale allowlist entry [{} {} {}] covers nothing — remove it ({})",
            allow_path.display(),
            a.rule,
            a.path,
            a.needle,
            a.reason
        );
    }
    println!(
        "drcg-lint: {} files, {} finding(s), {} allowlisted, {} stale allowlist entr(ies)",
        report.files_scanned,
        report.diagnostics.len(),
        report.allowlisted.len(),
        report.stale.len()
    );
    if report.is_clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
