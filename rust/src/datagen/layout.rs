//! Stage (a): cell placement on the die (paper Fig. 3a).
//!
//! Cells land in the unit square as a mixture of a uniform background and
//! several Gaussian density hotspots — real placements cluster standard
//! cells around macros, which is what gives the `near` graph its heavy
//! degree tail ("evil rows", §2.3).

use crate::util::rng::Rng;

/// A placed cell.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Cell {
    pub x: f32,
    pub y: f32,
    /// Hotspot id (usize::MAX = background).
    pub cluster: usize,
}

/// Cell placement with a uniform spatial bin index for neighbor queries.
#[derive(Clone, Debug)]
pub struct Placement {
    pub cells: Vec<Cell>,
    /// Bin side length.
    pub bin: f32,
    /// Bins per axis.
    pub grid: usize,
    /// Cell ids per bin, row-major `grid × grid`.
    pub bins: Vec<Vec<u32>>,
    /// Die side length. The Table-1 tiers place into the unit square; the
    /// Full tier grows the die with `sqrt(n)` so cell *density* (and with
    /// it the near-degree distribution) stays at the paper's shape instead
    /// of collapsing a million cells into one unit of area.
    pub extent: f32,
}

/// Fraction of cells placed in hotspots.
const HOTSPOT_FRACTION: f64 = 0.45;
/// Hotspot standard deviation.
const HOTSPOT_SIGMA: f32 = 0.06;

/// Place `n` cells in the unit die: uniform background plus 4–8 Gaussian
/// hotspots.
pub fn place_cells(n: usize, rng: &mut Rng) -> Placement {
    place_cells_in(n, 1.0, rng)
}

/// Place `n` cells in an `extent × extent` die. Hotspot *density per unit
/// area* is held constant (4–8 hotspots per unit of area, σ = 0.06
/// absolute), so a Full-tier die is a tiling of Table-1-like neighborhoods
/// rather than one stretched layout. `extent = 1.0` is bit-identical to
/// [`place_cells`].
pub fn place_cells_in(n: usize, extent: f32, rng: &mut Rng) -> Placement {
    assert!(extent >= 1.0, "die extent must be ≥ 1.0, got {extent}");
    let area = extent as f64 * extent as f64;
    let hotspots_per_unit = rng.range(4, 9);
    let n_hotspots = ((hotspots_per_unit as f64 * area).round() as usize).max(1);
    let centers: Vec<(f32, f32)> = (0..n_hotspots)
        .map(|_| {
            (rng.uniform(0.12 * extent, 0.88 * extent), rng.uniform(0.12 * extent, 0.88 * extent))
        })
        .collect();
    let hi = 0.999_9 * extent;
    let mut cells = Vec::with_capacity(n);
    for _ in 0..n {
        if rng.f64() < HOTSPOT_FRACTION {
            let c = rng.below(n_hotspots);
            let (cx, cy) = centers[c];
            let x = (cx + rng.normal() * HOTSPOT_SIGMA).clamp(0.0, hi);
            let y = (cy + rng.normal() * HOTSPOT_SIGMA).clamp(0.0, hi);
            cells.push(Cell { x, y, cluster: c });
        } else {
            cells.push(Cell {
                x: rng.uniform(0.0, hi),
                y: rng.uniform(0.0, hi),
                cluster: usize::MAX,
            });
        }
    }
    // Bin size targets O(10) cells/bin for neighbor queries.
    let grid = ((n as f64 / 10.0).sqrt().ceil() as usize).max(1);
    let bin = extent / grid as f32;
    let mut bins = vec![Vec::new(); grid * grid];
    for (i, c) in cells.iter().enumerate() {
        bins[bin_index_in(c.x, c.y, grid, extent)].push(i as u32);
    }
    Placement { cells, bin, grid, bins, extent }
}

#[inline]
pub fn bin_index(x: f32, y: f32, grid: usize) -> usize {
    let bx = ((x * grid as f32) as usize).min(grid - 1);
    let by = ((y * grid as f32) as usize).min(grid - 1);
    by * grid + bx
}

/// Bin index in an `extent × extent` die (`extent = 1.0` ≡ [`bin_index`] —
/// division by 1.0 is exact).
#[inline]
pub fn bin_index_in(x: f32, y: f32, grid: usize, extent: f32) -> usize {
    let bx = (((x / extent) * grid as f32) as usize).min(grid - 1);
    let by = (((y / extent) * grid as f32) as usize).min(grid - 1);
    by * grid + bx
}

impl Placement {
    /// Visit every cell within `radius` of cell `i` (excluding `i`).
    pub fn for_neighbors_within(&self, i: usize, radius: f32, mut f: impl FnMut(usize, f32)) {
        let c = self.cells[i];
        let r2 = radius * radius;
        let reach = (radius / self.bin).ceil() as isize;
        let bx = (((c.x / self.extent) * self.grid as f32) as isize).min(self.grid as isize - 1);
        let by = (((c.y / self.extent) * self.grid as f32) as isize).min(self.grid as isize - 1);
        for dy in -reach..=reach {
            for dx in -reach..=reach {
                let (nx, ny) = (bx + dx, by + dy);
                if nx < 0 || ny < 0 || nx >= self.grid as isize || ny >= self.grid as isize {
                    continue;
                }
                for &j in &self.bins[ny as usize * self.grid + nx as usize] {
                    let j = j as usize;
                    if j == i {
                        continue;
                    }
                    let o = self.cells[j];
                    let d2 = (o.x - c.x) * (o.x - c.x) + (o.y - c.y) * (o.y - c.y);
                    if d2 <= r2 {
                        f(j, d2.sqrt());
                    }
                }
            }
        }
    }

    /// Local density: cells within `radius`, normalised by the max observed.
    pub fn densities(&self, radius: f32) -> Vec<f32> {
        let mut counts = vec![0usize; self.cells.len()];
        for (i, count) in counts.iter_mut().enumerate() {
            let mut c = 0usize;
            self.for_neighbors_within(i, radius, |_, _| c += 1);
            *count = c;
        }
        let max = *counts.iter().max().unwrap_or(&1) as f32;
        counts.iter().map(|&c| c as f32 / max.max(1.0)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn places_all_cells_in_unit_square() {
        let mut rng = Rng::new(1);
        let p = place_cells(500, &mut rng);
        assert_eq!(p.cells.len(), 500);
        for c in &p.cells {
            assert!((0.0..1.0).contains(&c.x) && (0.0..1.0).contains(&c.y));
        }
        let binned: usize = p.bins.iter().map(|b| b.len()).sum();
        assert_eq!(binned, 500);
    }

    #[test]
    fn neighbor_query_matches_bruteforce() {
        let mut rng = Rng::new(2);
        let p = place_cells(300, &mut rng);
        let radius = 0.08;
        for i in [0usize, 57, 123, 299] {
            let mut fast: Vec<usize> = Vec::new();
            p.for_neighbors_within(i, radius, |j, _| fast.push(j));
            fast.sort_unstable();
            let c = p.cells[i];
            let mut brute: Vec<usize> = (0..p.cells.len())
                .filter(|&j| {
                    j != i && {
                        let o = p.cells[j];
                        (o.x - c.x).powi(2) + (o.y - c.y).powi(2) <= radius * radius
                    }
                })
                .collect();
            brute.sort_unstable();
            assert_eq!(fast, brute, "cell {i}");
        }
    }

    #[test]
    fn hotspots_create_density_skew() {
        let mut rng = Rng::new(3);
        let p = place_cells(2000, &mut rng);
        let d = p.densities(0.05);
        let mean = d.iter().sum::<f32>() / d.len() as f32;
        // Clustered layout: the max-density cell sees far more neighbors
        // than average (this is what produces Fig. 4's near-degree tail).
        assert!(mean < 0.5, "density should be skewed, mean={mean}");
    }

    #[test]
    fn bin_index_corners() {
        assert_eq!(bin_index(0.0, 0.0, 10), 0);
        assert_eq!(bin_index(0.999, 0.999, 10), 99);
        assert_eq!(bin_index(0.999, 0.0, 10), 9);
    }

    /// `extent = 1.0` must be the identity refactor: same cells, same bins,
    /// same RNG consumption as the original unit-die `place_cells`.
    #[test]
    fn unit_extent_is_bit_identical_to_place_cells() {
        let mut r1 = Rng::new(77);
        let mut r2 = Rng::new(77);
        let a = place_cells(400, &mut r1);
        let b = place_cells_in(400, 1.0, &mut r2);
        assert_eq!(a.cells, b.cells);
        assert_eq!(a.bins, b.bins);
        assert_eq!(a.bin.to_bits(), b.bin.to_bits());
        assert_eq!(a.extent, 1.0);
        assert_eq!(r1.next_u64(), r2.next_u64(), "rng draw counts must match");
    }

    #[test]
    fn scaled_extent_places_in_die_and_queries_match_bruteforce() {
        let mut rng = Rng::new(8);
        let extent = 3.0f32;
        let p = place_cells_in(900, extent, &mut rng);
        assert!(p.cells.iter().all(|c| (0.0..extent).contains(&c.x) && (0.0..extent).contains(&c.y)));
        assert!(
            p.cells.iter().any(|c| c.x > 1.0 || c.y > 1.0),
            "a 3×3 die must actually use the area beyond the unit square"
        );
        let binned: usize = p.bins.iter().map(|b| b.len()).sum();
        assert_eq!(binned, 900);
        let radius = 0.15;
        for i in [0usize, 123, 456, 899] {
            let mut fast: Vec<usize> = Vec::new();
            p.for_neighbors_within(i, radius, |j, _| fast.push(j));
            fast.sort_unstable();
            let c = p.cells[i];
            let mut brute: Vec<usize> = (0..p.cells.len())
                .filter(|&j| {
                    j != i && {
                        let o = p.cells[j];
                        (o.x - c.x).powi(2) + (o.y - c.y).powi(2) <= radius * radius
                    }
                })
                .collect();
            brute.sort_unstable();
            assert_eq!(fast, brute, "cell {i}");
        }
    }
}
