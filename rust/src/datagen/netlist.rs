//! Stage (b): topological links — netlist construction (paper Fig. 3b).
//!
//! Each net selects a locality-biased group of cells to pin into. Fanouts
//! are power-law distributed (most nets touch 2–4 cells, a few fan out to
//! dozens — clock/reset-like nets), then nudged so the total pin count hits
//! `target_pins` exactly, matching Table 1's `edges-pins` column.

use super::layout::Placement;
use crate::graph::Csr;
use crate::util::rng::Rng;

/// One net: the set of cells it pins into.
#[derive(Clone, Debug)]
pub struct Net {
    pub cells: Vec<u32>,
}

/// Minimum/maximum net fanout.
const FANOUT_MIN: usize = 2;
const FANOUT_MAX: usize = 64;
/// Power-law exponent for fanout (heavier than near's spatial tail).
const FANOUT_ALPHA: f64 = 2.6;

/// Build `n_nets` nets over the placed cells with Σ fanout = `target_pins`.
pub fn build_netlist(
    placement: &Placement,
    n_nets: usize,
    target_pins: usize,
    rng: &mut Rng,
) -> Vec<Net> {
    let n_cells = placement.cells.len();
    assert!(n_cells >= FANOUT_MIN, "need at least {FANOUT_MIN} cells");
    assert!(
        target_pins >= n_nets * FANOUT_MIN,
        "target_pins {target_pins} below minimum {}",
        n_nets * FANOUT_MIN
    );

    // Draw fanouts from the power law, then adjust the total to the target.
    let mut fanouts: Vec<usize> = (0..n_nets)
        .map(|_| {
            (rng.power_law(FANOUT_MIN as f64, FANOUT_MAX as f64, FANOUT_ALPHA).round()
                as usize)
                .clamp(FANOUT_MIN, FANOUT_MAX.min(n_cells))
        })
        .collect();
    let mut total: isize = fanouts.iter().sum::<usize>() as isize;
    let target = target_pins as isize;
    // Deterministic adjustment: sweep nets in a shuffled order, nudging
    // fanouts toward the target until the total matches exactly. (A purely
    // random walk can fail to converge when the adjustable nets thin out.)
    let mut order: Vec<usize> = (0..n_nets).collect();
    rng.shuffle(&mut order);
    let fan_cap = FANOUT_MAX.min(n_cells);
    while total != target {
        let before = total;
        for &i in &order {
            if total == target {
                break;
            }
            if total < target && fanouts[i] < fan_cap {
                fanouts[i] += 1;
                total += 1;
            } else if total > target && fanouts[i] > FANOUT_MIN {
                fanouts[i] -= 1;
                total -= 1;
            }
        }
        if total == before {
            // No net is adjustable: the target is infeasible at these
            // bounds; the caller's assert above makes this unreachable for
            // the low side, the cap bounds the high side.
            break;
        }
    }

    // Each net pins a seed cell plus nearby cells (locality), falling back
    // to uniform picks when the neighborhood is too small.
    let mut nets = Vec::with_capacity(n_nets);
    for &fanout in &fanouts {
        let seed = rng.below(n_cells);
        let mut chosen = vec![seed as u32];
        let mut candidates: Vec<u32> = Vec::new();
        // Gather a local candidate pool around the seed. The growth cap
        // scales with the die so Full-tier seeds in sparse corners can
        // still assemble a pool (identical at the unit extent).
        let mut radius = 0.03f32;
        while candidates.len() < fanout * 3 && radius < 1.5 * placement.extent {
            candidates.clear();
            placement.for_neighbors_within(seed, radius, |j, _| candidates.push(j as u32));
            radius *= 2.0;
        }
        while chosen.len() < fanout {
            let pick = if !candidates.is_empty() && rng.f32() < 0.8 {
                candidates[rng.below(candidates.len())]
            } else {
                rng.below(n_cells) as u32
            };
            if !chosen.contains(&pick) {
                chosen.push(pick);
            }
        }
        nets.push(Net { cells: chosen });
    }
    nets
}

/// Destination-major pins adjacency: rows = nets, cols = cells.
pub fn pins_matrix(nets: &[Net], n_cells: usize, n_nets: usize) -> Csr {
    assert_eq!(nets.len(), n_nets);
    let mut triplets = Vec::new();
    for (net_id, net) in nets.iter().enumerate() {
        for &c in &net.cells {
            triplets.push((net_id, c as usize, 1.0));
        }
    }
    Csr::from_triplets(n_nets, n_cells, &triplets)
}

#[cfg(test)]
mod tests {
    use super::super::layout::place_cells;
    use super::*;

    #[test]
    fn total_pins_hits_target_exactly() {
        let mut rng = Rng::new(1);
        let p = place_cells(500, &mut rng);
        let nets = build_netlist(&p, 200, 700, &mut rng);
        let total: usize = nets.iter().map(|n| n.cells.len()).sum();
        assert_eq!(total, 700);
    }

    #[test]
    fn fanouts_within_bounds_and_distinct_cells() {
        let mut rng = Rng::new(2);
        let p = place_cells(300, &mut rng);
        let nets = build_netlist(&p, 100, 350, &mut rng);
        for net in &nets {
            assert!(net.cells.len() >= FANOUT_MIN);
            assert!(net.cells.len() <= FANOUT_MAX);
            let mut s = net.cells.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), net.cells.len(), "duplicate pins in a net");
        }
    }

    #[test]
    fn pins_matrix_shape_and_nnz() {
        let mut rng = Rng::new(3);
        let p = place_cells(120, &mut rng);
        let nets = build_netlist(&p, 50, 160, &mut rng);
        let m = pins_matrix(&nets, 120, 50);
        assert_eq!(m.rows, 50);
        assert_eq!(m.cols, 120);
        assert_eq!(m.nnz(), 160);
    }

    #[test]
    fn fanout_distribution_is_heavy_tailed() {
        let mut rng = Rng::new(4);
        let p = place_cells(2000, &mut rng);
        // avg fanout 3 → power-law leaves most nets at 2, some much larger.
        let nets = build_netlist(&p, 1000, 3000, &mut rng);
        let at_min = nets.iter().filter(|n| n.cells.len() <= 3).count();
        let max = nets.iter().map(|n| n.cells.len()).max().unwrap();
        assert!(at_min > 600, "most nets should be small, got {at_min}");
        assert!(max >= 10, "tail too light, max={max}");
    }

    #[test]
    #[should_panic(expected = "below minimum")]
    fn infeasible_target_panics() {
        let mut rng = Rng::new(5);
        let p = place_cells(50, &mut rng);
        build_netlist(&p, 100, 100, &mut rng);
    }
}
