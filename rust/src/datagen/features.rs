//! Stage (d): node features and congestion labels.
//!
//! Features mix physical layout quantities (position, local density),
//! topological quantities (degrees, fanouts) and noise padding up to the
//! requested width — mirroring CircuitNet's physical + topological encoding.
//!
//! The congestion label is a synthetic-but-physical model: routing demand at
//! a cell grows with (i) the fanout of the nets crossing it (topological
//! demand, cf. RUDY-style estimators) and (ii) local placement density
//! (geometric contention), smoothed over the `near` neighborhood. This makes
//! the target *learnable from exactly the signals the HGNN aggregates*, so
//! rank-correlation metrics behave like the paper's.

use super::layout::Placement;
use super::netlist::Net;
use crate::graph::Csr;
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// Build (x_cell, x_net, y_cell).
#[allow(clippy::too_many_arguments)]
pub fn build_features(
    placement: &Placement,
    nets: &[Net],
    near: &Csr,
    pins: &Csr,
    d_cell: usize,
    d_net: usize,
    rng: &mut Rng,
) -> (Matrix, Matrix, Matrix) {
    let n_cells = placement.cells.len();
    let n_nets = nets.len();
    assert!(d_cell >= 4 && d_net >= 4, "need at least 4 feature dims");

    let density = placement.densities(0.05);

    // Per-cell topological demand: Σ over incident nets of (fanout - 1).
    let mut demand = vec![0f32; n_cells];
    for net in nets {
        let w = (net.cells.len() as f32 - 1.0).max(0.0);
        for &c in &net.cells {
            demand[c as usize] += w;
        }
    }
    let max_demand = demand.iter().cloned().fold(1.0, f32::max);

    // Cell features: [x, y, density, near_deg/max, demand/max, noise...]
    // Positions are normalised by the die extent so Full-tier features stay
    // in [0, 1) like the unit-die tiers (x / 1.0 is bitwise exact, so the
    // Table-1 tiers are untouched).
    let extent = placement.extent;
    let max_near = near.max_degree().max(1) as f32;
    let mut x_cell = Matrix::zeros(n_cells, d_cell);
    for i in 0..n_cells {
        let c = placement.cells[i];
        let row = x_cell.row_mut(i);
        row[0] = c.x / extent;
        row[1] = c.y / extent;
        row[2] = density[i];
        row[3] = near.degree(i) as f32 / max_near;
        if d_cell > 4 {
            row[4] = demand[i] / max_demand;
        }
        for v in row.iter_mut().skip(5) {
            *v = rng.normal() * 0.1;
        }
    }

    // Net features: [fanout/max, bbox_w, bbox_h, centroid density, noise...]
    let max_fanout = nets.iter().map(|n| n.cells.len()).max().unwrap_or(1) as f32;
    let mut x_net = Matrix::zeros(n_nets, d_net);
    for (i, net) in nets.iter().enumerate() {
        let (mut xmin, mut xmax, mut ymin, mut ymax) = (extent, 0f32, extent, 0f32);
        let mut dens = 0f32;
        for &c in &net.cells {
            let cell = placement.cells[c as usize];
            xmin = xmin.min(cell.x);
            xmax = xmax.max(cell.x);
            ymin = ymin.min(cell.y);
            ymax = ymax.max(cell.y);
            dens += density[c as usize];
        }
        let row = x_net.row_mut(i);
        row[0] = net.cells.len() as f32 / max_fanout;
        row[1] = (xmax - xmin).max(0.0) / extent;
        row[2] = (ymax - ymin).max(0.0) / extent;
        row[3] = dens / net.cells.len().max(1) as f32;
        for v in row.iter_mut().skip(4) {
            *v = rng.normal() * 0.1;
        }
    }

    // Congestion label: demand × density, smoothed over near neighbors.
    let mut raw = vec![0f32; n_cells];
    for i in 0..n_cells {
        raw[i] = 0.6 * (demand[i] / max_demand) + 0.4 * density[i];
    }
    let mut y = Matrix::zeros(n_cells, 1);
    for i in 0..n_cells {
        let mut acc = raw[i];
        let mut cnt = 1.0f32;
        for q in near.row_range(i) {
            acc += raw[near.indices[q] as usize];
            cnt += 1.0;
        }
        // Mild observation noise keeps the task non-trivial.
        y.data[i] = (acc / cnt + rng.normal() * 0.01).clamp(0.0, 1.5);
    }
    debug_assert_eq!(pins.rows, n_nets);
    (x_cell, x_net, y)
}

#[cfg(test)]
mod tests {
    use super::super::layout::place_cells;
    use super::super::netlist::{build_netlist, pins_matrix};
    use super::super::window::near_edges;
    use super::*;

    fn setup() -> (Matrix, Matrix, Matrix, Csr) {
        let mut rng = Rng::new(1);
        let p = place_cells(400, &mut rng);
        let near = near_edges(&p, 8000, &mut rng);
        let nets = build_netlist(&p, 150, 500, &mut rng);
        let pins = pins_matrix(&nets, 400, 150);
        let (xc, xn, y) = build_features(&p, &nets, &near, &pins, 8, 8, &mut rng);
        (xc, xn, y, near)
    }

    #[test]
    fn shapes_match() {
        let (xc, xn, y, _) = setup();
        assert_eq!((xc.rows, xc.cols), (400, 8));
        assert_eq!((xn.rows, xn.cols), (150, 8));
        assert_eq!((y.rows, y.cols), (400, 1));
    }

    #[test]
    fn labels_bounded_and_varying() {
        let (_, _, y, _) = setup();
        assert!(y.data.iter().all(|&v| (0.0..=1.5).contains(&v)));
        let mean = y.mean();
        let var: f32 =
            y.data.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / y.data.len() as f32;
        assert!(var > 1e-5, "labels must vary, var={var}");
    }

    #[test]
    fn informative_dims_in_unit_ranges() {
        let (xc, xn, _, _) = setup();
        for r in 0..xc.rows {
            assert!((0.0..=1.0).contains(&xc.at(r, 2)), "density normalized");
            assert!((0.0..=1.0).contains(&xc.at(r, 3)), "degree normalized");
        }
        for r in 0..xn.rows {
            assert!((0.0..=1.0).contains(&xn.at(r, 0)), "fanout normalized");
        }
    }

    #[test]
    fn label_correlates_with_density_signal() {
        // Pearson between density feature and label should be positive:
        // the model is learnable from the given features.
        let (xc, _, y, _) = setup();
        let n = xc.rows as f32;
        let dens_mean: f32 = (0..xc.rows).map(|r| xc.at(r, 2)).sum::<f32>() / n;
        let y_mean = y.mean();
        let mut cov = 0f32;
        let mut vd = 0f32;
        let mut vy = 0f32;
        for r in 0..xc.rows {
            let a = xc.at(r, 2) - dens_mean;
            let b = y.data[r] - y_mean;
            cov += a * b;
            vd += a * a;
            vy += b * b;
        }
        let pearson = cov / (vd.sqrt() * vy.sqrt() + 1e-9);
        assert!(pearson > 0.2, "expected positive correlation, got {pearson}");
    }

    /// On a scaled die the position/bbox features must still land in unit
    /// ranges (they are normalised by the extent).
    #[test]
    fn scaled_die_features_stay_in_unit_ranges() {
        let mut rng = Rng::new(6);
        let p = super::super::layout::place_cells_in(900, 3.0, &mut rng);
        let near = near_edges(&p, 9_000, &mut rng);
        let nets = build_netlist(&p, 300, 950, &mut rng);
        let pins = pins_matrix(&nets, 900, 300);
        let (xc, xn, _y) = build_features(&p, &nets, &near, &pins, 8, 8, &mut rng);
        for r in 0..xc.rows {
            assert!((0.0..1.0).contains(&xc.at(r, 0)), "x position normalized");
            assert!((0.0..1.0).contains(&xc.at(r, 1)), "y position normalized");
        }
        for r in 0..xn.rows {
            assert!((0.0..=1.0).contains(&xn.at(r, 1)), "bbox width normalized");
            assert!((0.0..=1.0).contains(&xn.at(r, 2)), "bbox height normalized");
        }
    }

    #[test]
    #[should_panic(expected = "at least 4 feature dims")]
    fn tiny_dims_panics() {
        let mut rng = Rng::new(2);
        let p = place_cells(10, &mut rng);
        let near = near_edges(&p, 20, &mut rng);
        let nets = build_netlist(&p, 4, 10, &mut rng);
        let pins = pins_matrix(&nets, 10, 4);
        build_features(&p, &nets, &near, &pins, 2, 8, &mut rng);
    }
}
