//! Synthetic CircuitNet generator.
//!
//! The paper evaluates on CircuitNet (10k+ commercial designs, not shipped
//! here), so this module builds the closest synthetic equivalent per the
//! substitution rule in DESIGN.md §2: a layout-driven generator whose output
//! matches the *published statistics* — Table 1 node/edge counts for the
//! three representative designs, and the Fig. 4 degree distributions
//! (`near` peaked ≈50 with a tail past 250; `pins`/`pinned` concentrated at
//! 2–4 with a power-law tail).
//!
//! The generation pipeline mirrors Fig. 3 of the paper:
//!   (a) layout   — cells placed in a unit die with density hotspots
//!   (b) netlist  — nets pin into locality-biased cell groups (topological)
//!   (c) window   — shifting-window proximity links between cells (geometric)
//!   (d) features + congestion labels derived from both.

pub mod designs;
pub mod eco;
pub mod features;
pub mod layout;
pub mod netlist;
pub mod window;

use crate::graph::{Csr, HeteroGraph};
use crate::util::rng::Rng;

/// Specification of one heterograph partition.
#[derive(Clone, Debug)]
pub struct GraphSpec {
    pub n_cells: usize,
    pub n_nets: usize,
    /// Target nnz of the (directed-count) near adjacency.
    pub target_near: usize,
    /// Target nnz of pins (= pinned).
    pub target_pins: usize,
    /// Raw feature widths.
    pub d_cell: usize,
    pub d_net: usize,
}

/// Cell density the Table-1 tiers place at (≈10k cells per unit of die
/// area); the die grows past the unit square above this so Full-tier
/// graphs keep the paper's near-degree shape instead of densifying.
const CELLS_PER_UNIT_AREA: f64 = 10_000.0;

/// Near targets at or above this use the streaming generator
/// ([`window::near_edges_streaming`]) — all Table-1-sized specs sit far
/// below it, so their output is untouched.
const STREAMING_NEAR_THRESHOLD: usize = 2_000_000;

impl GraphSpec {
    /// Die side length for this partition: 1.0 (the unit square) up to
    /// [`CELLS_PER_UNIT_AREA`] cells, then growing with `sqrt(n)` to hold
    /// placement density constant. Derived, not stored, so every existing
    /// spec literal keeps its exact behavior.
    pub fn extent(&self) -> f32 {
        (self.n_cells as f64 / CELLS_PER_UNIT_AREA).sqrt().max(1.0) as f32
    }

    /// Whether [`generate_graph`] will build `near` via the streaming
    /// (no-materialised-pairs) path.
    pub fn streams_near(&self) -> bool {
        self.target_near >= STREAMING_NEAR_THRESHOLD
    }
}

/// Specification of a design = a set of partitions (paper §2.2: each design
/// is evenly partitioned into ~10k-node graphs).
#[derive(Clone, Debug)]
pub struct DesignSpec {
    pub name: String,
    pub seed: u64,
    pub graphs: Vec<GraphSpec>,
}

/// Generate one heterograph from a spec.
///
/// Table-1-sized specs run the exact pre-Full-tier pipeline (unit die,
/// materialised pair down-sampling) bit-for-bit; specs past the streaming
/// threshold place on a `sqrt(n)`-scaled die and build `near` without ever
/// materialising the candidate pair list.
pub fn generate_graph(spec: &GraphSpec, id: usize, rng: &mut Rng) -> HeteroGraph {
    let placement = layout::place_cells_in(spec.n_cells, spec.extent(), rng);
    let near = if spec.streams_near() {
        crate::info!(
            "datagen: streaming near generation for graph {id} ({} cells, target_near {}, \
             die extent {:.2})",
            spec.n_cells,
            spec.target_near,
            spec.extent()
        );
        window::near_edges_streaming(&placement, spec.target_near, rng)
    } else {
        window::near_edges(&placement, spec.target_near, rng)
    };
    let nets = netlist::build_netlist(&placement, spec.n_nets, spec.target_pins, rng);
    let pins = netlist::pins_matrix(&nets, spec.n_cells, spec.n_nets);
    let pinned = pins.transpose();
    let (x_cell, x_net, y_cell) =
        features::build_features(&placement, &nets, &near, &pins, spec.d_cell, spec.d_net, rng);
    let g = HeteroGraph {
        id,
        n_cells: spec.n_cells,
        n_nets: spec.n_nets,
        near,
        pins,
        pinned,
        x_cell,
        x_net,
        y_cell,
    };
    debug_assert!(g.validate().is_ok(), "generated graph failed validation");
    g
}

/// Generate a full design (all partitions).
pub fn generate_design(spec: &DesignSpec) -> Vec<HeteroGraph> {
    let mut rng = Rng::new(spec.seed);
    spec.graphs
        .iter()
        .enumerate()
        .map(|(i, gs)| {
            let mut sub = rng.fork(i as u64);
            generate_graph(gs, i, &mut sub)
        })
        .collect()
}

/// A generated dataset of designs (each a Vec of heterograph partitions).
pub struct Dataset {
    pub name: String,
    pub designs: Vec<(String, Vec<HeteroGraph>)>,
}

impl Dataset {
    pub fn total_graphs(&self) -> usize {
        self.designs.iter().map(|(_, gs)| gs.len()).sum()
    }

    pub fn graphs(&self) -> impl Iterator<Item = &HeteroGraph> {
        self.designs.iter().flat_map(|(_, gs)| gs.iter())
    }
}

/// Mini-CircuitNet (paper §4.1): `n_designs` sampled designs, scaled by
/// `scale` (1.0 = paper-scale 5–10k nodes; benches/tests use smaller).
/// Returns (train, test) split 5:1 like the paper's 100/20.
///
/// The test set is never empty: the `d % 6 == 5` rule only assigns a test
/// design from the sixth on, so smaller datasets move their last train
/// design to test instead — Table-2 eval then always averages over ≥ 1
/// design rather than silently reporting `EvalScores::default()`. Needs
/// `n_designs ≥ 2` (one train + one test); fewer is a loud panic.
pub fn mini_circuitnet(
    n_designs: usize,
    scale: f64,
    seed: u64,
) -> (Dataset, Dataset) {
    assert!(
        n_designs >= 2,
        "mini_circuitnet needs n_designs ≥ 2 (one train + one test design), got {n_designs}"
    );
    let mut rng = Rng::new(seed);
    let mut train = Vec::new();
    let mut test = Vec::new();
    for d in 0..n_designs {
        let spec = designs::random_design_spec(&format!("mini-{d:03}"), scale, &mut rng);
        let graphs = generate_design(&spec);
        if d % 6 == 5 {
            test.push((spec.name.clone(), graphs));
        } else {
            train.push((spec.name.clone(), graphs));
        }
    }
    if test.is_empty() {
        // n_designs < 6: generation order and specs are unchanged; only
        // the split assignment of the final design moves.
        test.push(train.pop().expect("n_designs ≥ 2 leaves a train design to move"));
    }
    (
        Dataset { name: "mini-train".into(), designs: train },
        Dataset { name: "mini-test".into(), designs: test },
    )
}

/// Re-export: the three Table-1 designs.
pub use designs::{full_design, table1_design, table1_designs, DesignSize};
pub use eco::{generate_eco, EcoSpec};
pub use window::{sample_windows, WindowSpec};

/// Convenience: percentage difference of generated vs target counts.
pub fn count_error(actual: usize, target: usize) -> f64 {
    if target == 0 {
        return 0.0;
    }
    (actual as f64 - target as f64).abs() / target as f64
}

#[allow(unused)]
fn unused_csr_reference(_c: &Csr) {}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> GraphSpec {
        GraphSpec {
            n_cells: 600,
            n_nets: 320,
            target_near: 18_000,
            target_pins: 900,
            d_cell: 8,
            d_net: 8,
        }
    }

    #[test]
    fn generated_graph_is_valid_and_close_to_targets() {
        let mut rng = Rng::new(42);
        let g = generate_graph(&small_spec(), 0, &mut rng);
        g.validate().unwrap();
        assert_eq!(g.n_cells, 600);
        assert_eq!(g.n_nets, 320);
        assert!(count_error(g.near.nnz(), 18_000) < 0.05, "near nnz {}", g.near.nnz());
        assert!(count_error(g.pins.nnz(), 900) < 0.05, "pins nnz {}", g.pins.nnz());
    }

    #[test]
    fn generation_is_deterministic() {
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let a = generate_graph(&small_spec(), 0, &mut r1);
        let b = generate_graph(&small_spec(), 0, &mut r2);
        assert_eq!(a.near.indices, b.near.indices);
        assert_eq!(a.pins.indices, b.pins.indices);
        assert_eq!(a.x_cell.data, b.x_cell.data);
    }

    #[test]
    fn near_is_symmetric() {
        let mut rng = Rng::new(11);
        let g = generate_graph(&small_spec(), 0, &mut rng);
        assert!(g.near.is_transpose_of(&g.near), "near must be symmetric");
    }

    #[test]
    fn mini_dataset_split() {
        let (train, test) = mini_circuitnet(12, 0.05, 3);
        assert_eq!(train.designs.len(), 10);
        assert_eq!(test.designs.len(), 2);
        for g in train.graphs() {
            g.validate().unwrap();
        }
    }

    /// Every dataset size ≥ 2 must yield at least one test design —
    /// the `d % 6 == 5` rule alone left the test set empty below 6
    /// designs and Table-2 eval averaged nothing.
    #[test]
    fn mini_dataset_small_sizes_keep_a_test_design() {
        for n in 2..=7 {
            let (train, test) = mini_circuitnet(n, 0.02, 3);
            assert!(!test.designs.is_empty(), "n_designs={n}: empty test set");
            assert!(!train.designs.is_empty(), "n_designs={n}: empty train set");
            assert_eq!(train.designs.len() + test.designs.len(), n);
        }
        // The move must not disturb the ≥6 split.
        let (train, test) = mini_circuitnet(6, 0.02, 3);
        assert_eq!((train.designs.len(), test.designs.len()), (5, 1));
    }

    #[test]
    #[should_panic(expected = "n_designs ≥ 2")]
    fn mini_dataset_rejects_single_design() {
        mini_circuitnet(1, 0.02, 3);
    }

    #[test]
    fn extent_grows_past_table1_scale() {
        let mut small = small_spec();
        assert_eq!(small.extent(), 1.0, "Table-1-sized specs stay on the unit die");
        assert!(!small.streams_near());
        small.n_cells = 1_000_000;
        small.target_near = 50_000_000;
        assert!((small.extent() - 10.0).abs() < 1e-5, "10⁶ cells → 10×10 die");
        assert!(small.streams_near());
    }

    /// The streaming and dense near generators agree on the statistics the
    /// rest of the pipeline consumes (symmetry, canonical form, target
    /// count) for the same placement.
    #[test]
    fn streaming_near_matches_dense_statistics_in_pipeline() {
        let spec = small_spec();
        let mut rng = Rng::new(21);
        let placement = layout::place_cells_in(spec.n_cells, spec.extent(), &mut rng);
        let dense = window::near_edges(&placement, spec.target_near, &mut rng.fork(0));
        let streamed =
            window::near_edges_streaming(&placement, spec.target_near, &mut rng.fork(1));
        assert!(streamed.is_canonical());
        assert!(streamed.is_transpose_of(&streamed));
        assert!(count_error(streamed.nnz(), spec.target_near) < 0.05);
        assert!(count_error(dense.nnz(), spec.target_near) < 0.05);
    }

    #[test]
    fn degree_distribution_shapes_match_fig4() {
        // pins/pinned concentrated low, near substantially denser.
        let mut rng = Rng::new(5);
        let g = generate_graph(&small_spec(), 0, &mut rng);
        let near_avg = g.near.avg_degree();
        let pins_avg = g.pins.avg_degree();
        assert!(near_avg > 10.0 * pins_avg, "near {near_avg} vs pins {pins_avg}");
        // power-law-ish tail: max pin fanout well above the mean
        assert!(g.pins.max_degree() as f64 > 3.0 * pins_avg);
    }
}
