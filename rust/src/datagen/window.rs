//! Stage (c): geometric `near` links via a shifting window (paper Fig. 3c,
//! after Swin-transformer-style windows [18]).
//!
//! Cells within a window radius are linked symmetrically. The radius is
//! calibrated so the directed edge count hits `target_near`; excess pairs
//! are randomly down-sampled (keeping symmetry) so Table-1 counts are met
//! within a tight tolerance while the hotspot layout keeps the degree
//! distribution heavy-tailed as in Fig. 4.
//!
//! Two generation strategies share that calibration:
//! * [`near_edges`] materialises the candidate pair list — exact
//!   down-sampling, right for Table-1-sized partitions;
//! * [`near_edges_streaming`] never materialises it — two counting passes
//!   plus a deterministic per-pair hash thinning build the CSR directly,
//!   which is what makes the `Full` (≈10⁶-cell) tier generable.
//!
//! This module also owns window *sampling* ([`WindowSpec`],
//! [`sample_windows`]): seeded, deterministic per-epoch mini-batch
//! subgraphs cut from a parent graph for the fleet's sampled training mode.

use super::layout::Placement;
use crate::graph::hetero::HeteroGraph;
use crate::graph::partition::cut_partition;
use crate::graph::Csr;
use crate::util::rng::Rng;

/// Calibrate the link radius: grow from the density estimate until the
/// undirected pair count reaches `target_pairs` or the radius covers the
/// whole die (no further pairs exist). Returns `(radius, pair_count)`.
/// Pure counting — draws no RNG, materialises nothing.
fn calibrate_radius(placement: &Placement, target_nnz: usize, target_pairs: usize) -> (f32, usize) {
    let n = placement.cells.len();
    // Initial radius from a uniform-density estimate: avg_deg = ρ·π·r² with
    // ρ = n / area. The pre-extent code divided by `n` assuming a unit die;
    // on a Full-tier die that underestimated r by the extent factor and the
    // growth loop burned all its attempts recovering.
    let avg_deg = target_nnz as f64 / n as f64;
    let area = placement.extent as f64 * placement.extent as f64;
    let mut radius = (avg_deg * area / (std::f64::consts::PI * n as f64)).sqrt() as f32;
    let diagonal = placement.extent * std::f32::consts::SQRT_2;
    loop {
        let mut pairs = 0usize;
        for i in 0..n {
            placement.for_neighbors_within(i, radius, |j, _| {
                if j > i {
                    pairs += 1;
                }
            });
        }
        if pairs >= target_pairs || radius >= diagonal {
            return (radius, pairs);
        }
        radius *= 1.35;
    }
}

fn warn_shortfall(kind: &str, achieved_nnz: usize, target_nnz: usize) {
    crate::warn!(
        "near_edges ({kind}): placement cannot reach target_near {target_nnz} — achieved \
         {achieved_nnz} stored entries ({:.1}% short) even with the window radius grown to \
         the full die; Table-1/Fig-4 statistics for this graph will be off by that factor",
        100.0 * super::count_error(achieved_nnz, target_nnz)
    );
}

/// Build the symmetric `near` adjacency with ≈`target_nnz` stored entries
/// (each undirected link contributes two). Undershoot is loud: if even a
/// die-spanning radius cannot produce `target_nnz / 2` pairs the shortfall
/// is `warn!`ed with the achieved-vs-target error instead of silently
/// returning a thinner graph.
pub fn near_edges(placement: &Placement, target_nnz: usize, rng: &mut Rng) -> Csr {
    let n = placement.cells.len();
    if n == 0 || target_nnz == 0 {
        return Csr::from_triplets(n, n, &[]);
    }
    let target_pairs = target_nnz / 2;
    let (radius, _) = calibrate_radius(placement, target_nnz, target_pairs);
    let mut pairs: Vec<(u32, u32)> = Vec::new();
    for i in 0..n {
        placement.for_neighbors_within(i, radius, |j, _| {
            if j > i {
                pairs.push((i as u32, j as u32));
            }
        });
    }
    if pairs.len() < target_pairs {
        warn_shortfall("dense", pairs.len() * 2, target_nnz);
    }
    if pairs.len() > target_pairs {
        // Down-sample pairs uniformly (partial Fisher–Yates).
        for i in 0..target_pairs {
            let j = rng.range(i, pairs.len());
            pairs.swap(i, j);
        }
        pairs.truncate(target_pairs);
    }
    let mut triplets = Vec::with_capacity(pairs.len() * 2);
    for &(a, b) in &pairs {
        triplets.push((a as usize, b as usize, 1.0));
        triplets.push((b as usize, a as usize, 1.0));
    }
    Csr::from_triplets(n, n, &triplets)
}

/// Symmetric per-pair keep decision: a SplitMix64-style mix of the seed and
/// the *unordered* pair, so both directions of a link always agree without
/// any shared state between rows.
#[inline]
fn pair_hash(seed: u64, a: u32, b: u32) -> u64 {
    let (lo, hi) = if a < b { (a, b) } else { (b, a) };
    let mut z = seed ^ (((hi as u64) << 32) | lo as u64).wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Streaming variant of [`near_edges`] for Full-tier graphs: the candidate
/// pair list (which can be tens of millions of entries before
/// down-sampling) is never materialised. After the counting calibration,
/// excess pairs are thinned by a deterministic symmetric hash with keep
/// probability `target_pairs / candidates`, and the CSR is built directly
/// with two per-row passes (count → fill), peak memory O(nnz) instead of
/// O(candidate pairs × triplet expansion).
///
/// The thinned count is binomial around the target (the exact-count
/// Fisher–Yates would need the materialised list); [`super::count_error`]
/// against `target_nnz` stays within the generator's usual tolerance.
pub fn near_edges_streaming(placement: &Placement, target_nnz: usize, rng: &mut Rng) -> Csr {
    let n = placement.cells.len();
    if n == 0 || target_nnz == 0 {
        return Csr::from_triplets(n, n, &[]);
    }
    let target_pairs = target_nnz / 2;
    let (radius, candidates) = calibrate_radius(placement, target_nnz, target_pairs);
    if candidates < target_pairs {
        warn_shortfall("streaming", candidates * 2, target_nnz);
    }
    let seed = rng.next_u64();
    // Keep threshold on the hash's full u64 range; keep-all when the
    // calibration landed at or under the target.
    let keep_all = candidates <= target_pairs;
    let threshold = if keep_all {
        u64::MAX
    } else {
        ((target_pairs as f64 / candidates as f64) * u64::MAX as f64) as u64
    };
    let keep = |i: u32, j: u32| keep_all || pair_hash(seed, i, j) <= threshold;

    // Pass A: per-row kept degrees → indptr.
    let mut indptr = vec![0usize; n + 1];
    for i in 0..n {
        let mut deg = 0usize;
        placement.for_neighbors_within(i, radius, |j, _| {
            if keep(i as u32, j as u32) {
                deg += 1;
            }
        });
        indptr[i + 1] = indptr[i] + deg;
    }
    let nnz = indptr[n];
    // Pass B: fill and sort each row (bin iteration order is spatial, not
    // by index).
    let mut indices = vec![0u32; nnz];
    for i in 0..n {
        let mut p = indptr[i];
        placement.for_neighbors_within(i, radius, |j, _| {
            if keep(i as u32, j as u32) {
                indices[p] = j as u32;
                p += 1;
            }
        });
        debug_assert_eq!(p, indptr[i + 1]);
        indices[indptr[i]..p].sort_unstable();
    }
    let csr = Csr { rows: n, cols: n, indptr, indices, values: vec![1.0; nnz] };
    debug_assert!(csr.is_canonical(), "streaming near must build canonical CSR directly");
    csr
}

/// A parsed window-sampling selection — the single parse point for the
/// `--window` CLI flag and the `window` config key (mirroring
/// [`crate::fleet::FleetSpec`]'s grammar discipline).
///
/// Grammar (case-insensitive): `off` (also `none`, `0`) or
/// `<count>x<cells>` — `count` windows of `cells` cells sampled per parent
/// graph per epoch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WindowSpec {
    /// Full-graph training (the default).
    Off,
    /// Sampled training: per epoch, each parent graph contributes `count`
    /// windows of `cells` contiguous cells (clamped to the graph).
    On { count: usize, cells: usize },
}

impl WindowSpec {
    /// Parse a window setting. This is the only parse point in the crate.
    pub fn parse(s: &str) -> Result<WindowSpec, String> {
        let t = s.trim().to_ascii_lowercase();
        if t == "off" || t == "none" || t == "0" {
            return Ok(WindowSpec::Off);
        }
        let bad =
            || format!("invalid window spec '{s}' (expected: off | <count>x<cells>, e.g. 4x2000)");
        let (c, w) = t.split_once('x').ok_or_else(bad)?;
        let count: usize = c.trim().parse().map_err(|_| bad())?;
        let cells: usize = w.trim().parse().map_err(|_| bad())?;
        if count == 0 || cells == 0 {
            return Err(bad());
        }
        Ok(WindowSpec::On { count, cells })
    }

    pub fn is_on(&self) -> bool {
        matches!(self, WindowSpec::On { .. })
    }

    /// One-line description for logs and tables.
    pub fn describe(&self) -> String {
        match self {
            WindowSpec::Off => "off".to_string(),
            WindowSpec::On { count, cells } => format!("{count} windows × {cells} cells"),
        }
    }
}

/// Sample `count` window subgraphs of `cells` contiguous cells from `g`,
/// deterministically from `(seed, epoch, g.id)` — weight-independent, so
/// the fleet's prepare stage can run it ahead of the optimizer without
/// breaking the no-weight-reads invariant, and reproducible for any worker
/// count or thread budget.
///
/// Windows are cut with [`cut_partition`] (cell-contiguous range, the nets
/// touching it, gathered features/labels), so a window is exactly the kind
/// of subgraph the fleet already schedules. Window `w` of the result keeps
/// `id = w`; callers batching windows from several parents re-assign ids.
pub fn sample_windows(
    g: &HeteroGraph,
    count: usize,
    cells: usize,
    seed: u64,
    epoch: usize,
) -> Vec<HeteroGraph> {
    assert!(count > 0 && cells > 0, "window spec must be positive");
    assert!(g.n_cells > 0, "cannot sample windows from an empty graph");
    let win = cells.min(g.n_cells);
    // Independent stream per (seed, epoch, graph): re-derived from scratch
    // each call so sampling is stateless and schedule-independent.
    let mut root = Rng::new(seed);
    let mut per_epoch = root.fork(epoch as u64);
    let mut rng = per_epoch.fork(g.id as u64);
    (0..count)
        .map(|w| {
            let start = rng.below(g.n_cells - win + 1);
            cut_partition(g, start, start + win, w).0
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::layout::{place_cells, place_cells_in};
    use super::*;

    #[test]
    fn hits_target_within_tolerance() {
        let mut rng = Rng::new(1);
        let p = place_cells(800, &mut rng);
        let target = 24_000;
        let near = near_edges(&p, target, &mut rng);
        let err = (near.nnz() as f64 - target as f64).abs() / target as f64;
        assert!(err < 0.02, "nnz={} target={target}", near.nnz());
    }

    #[test]
    fn hits_target_on_scaled_extent() {
        // The area-aware radius estimate: on a 3×3 die the old unit-area
        // formula starts 3× too small; the calibration must still converge
        // to the target without a fixed attempt cap biting.
        let mut rng = Rng::new(6);
        let p = place_cells_in(900, 3.0, &mut rng);
        let target = 27_000;
        let near = near_edges(&p, target, &mut rng);
        let err = (near.nnz() as f64 - target as f64).abs() / target as f64;
        assert!(err < 0.02, "nnz={} target={target}", near.nnz());
    }

    #[test]
    fn symmetric_no_self_loops() {
        let mut rng = Rng::new(2);
        let p = place_cells(400, &mut rng);
        let near = near_edges(&p, 8_000, &mut rng);
        assert!(near.is_transpose_of(&near));
        for r in 0..near.rows {
            for q in near.row_range(r) {
                assert_ne!(near.indices[q] as usize, r, "self loop at {r}");
            }
        }
    }

    #[test]
    fn empty_target_gives_empty_matrix() {
        let mut rng = Rng::new(3);
        let p = place_cells(100, &mut rng);
        let near = near_edges(&p, 0, &mut rng);
        assert_eq!(near.nnz(), 0);
    }

    #[test]
    fn infeasible_target_terminates_with_all_pairs() {
        // 10 cells support at most 45 undirected pairs; asking for 400
        // stored entries must terminate (radius capped at the die diagonal)
        // and return every possible pair rather than looping or silently
        // returning an arbitrary subset.
        let mut rng = Rng::new(5);
        let p = place_cells(10, &mut rng);
        let near = near_edges(&p, 400, &mut rng);
        assert_eq!(near.nnz(), 90, "all 45 pairs, both directions");
    }

    #[test]
    fn degree_tail_exceeds_mode() {
        // Hotspots should create rows with degree several times the average.
        let mut rng = Rng::new(4);
        let p = place_cells(1500, &mut rng);
        let near = near_edges(&p, 60_000, &mut rng);
        let avg = near.avg_degree();
        assert!(near.max_degree() as f64 > 2.0 * avg, "max {} avg {avg}", near.max_degree());
    }

    #[test]
    fn streaming_matches_dense_statistics() {
        let mut rng = Rng::new(9);
        let p = place_cells(800, &mut rng);
        let target = 24_000;
        let near = near_edges_streaming(&p, target, &mut rng);
        assert!(near.is_canonical());
        assert!(near.is_transpose_of(&near), "streaming near must stay symmetric");
        for r in 0..near.rows {
            for q in near.row_range(r) {
                assert_ne!(near.indices[q] as usize, r, "self loop at {r}");
            }
        }
        // Hash thinning is binomial around the target — allow a looser but
        // still tight tolerance.
        let err = (near.nnz() as f64 - target as f64).abs() / target as f64;
        assert!(err < 0.05, "nnz={} target={target}", near.nnz());
    }

    #[test]
    fn streaming_is_deterministic() {
        let mut r1 = Rng::new(12);
        let mut r2 = Rng::new(12);
        let p1 = place_cells(500, &mut r1);
        let p2 = place_cells(500, &mut r2);
        let a = near_edges_streaming(&p1, 10_000, &mut r1);
        let b = near_edges_streaming(&p2, 10_000, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn window_spec_grammar() {
        assert_eq!(WindowSpec::parse("off").unwrap(), WindowSpec::Off);
        assert_eq!(WindowSpec::parse("none").unwrap(), WindowSpec::Off);
        assert_eq!(WindowSpec::parse("0").unwrap(), WindowSpec::Off);
        assert_eq!(
            WindowSpec::parse(" 4x2000 ").unwrap(),
            WindowSpec::On { count: 4, cells: 2000 }
        );
        assert_eq!(WindowSpec::parse("2X64").unwrap(), WindowSpec::On { count: 2, cells: 64 });
        for bad in ["", "x", "4x", "x2", "4x0", "0x2", "4", "fast", "4x2x1"] {
            let err = WindowSpec::parse(bad).unwrap_err();
            assert!(err.contains("<count>x<cells>"), "{bad}: {err}");
        }
        assert!(WindowSpec::On { count: 4, cells: 2000 }.is_on());
        assert!(!WindowSpec::Off.is_on());
        assert!(WindowSpec::On { count: 4, cells: 2000 }.describe().contains("4 windows"));
    }

    fn sample_parent() -> HeteroGraph {
        use super::super::{generate_graph, GraphSpec};
        generate_graph(
            &GraphSpec {
                n_cells: 300,
                n_nets: 150,
                target_near: 6_000,
                target_pins: 450,
                d_cell: 6,
                d_net: 6,
            },
            7,
            &mut Rng::new(31),
        )
    }

    #[test]
    fn sampled_windows_are_valid_and_deterministic() {
        let g = sample_parent();
        let a = sample_windows(&g, 3, 64, 42, 1);
        let b = sample_windows(&g, 3, 64, 42, 1);
        assert_eq!(a.len(), 3);
        for (w, sub) in a.iter().enumerate() {
            sub.validate().unwrap();
            assert_eq!(sub.id, w);
            assert_eq!(sub.n_cells, 64);
        }
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.near, y.near);
            assert_eq!(x.pins, y.pins);
            assert_eq!(x.x_cell.data, y.x_cell.data);
            assert_eq!(x.y_cell.data, y.y_cell.data);
        }
    }

    #[test]
    fn sampling_varies_with_epoch_and_seed() {
        let g = sample_parent();
        let e1 = sample_windows(&g, 4, 64, 42, 1);
        let e2 = sample_windows(&g, 4, 64, 42, 2);
        let s2 = sample_windows(&g, 4, 64, 43, 1);
        let starts = |ws: &[HeteroGraph]| -> Vec<Vec<u32>> {
            ws.iter().map(|w| w.near.indices.clone()).collect()
        };
        assert_ne!(starts(&e1), starts(&e2), "epochs must sample different windows");
        assert_ne!(starts(&e1), starts(&s2), "seeds must sample different windows");
    }

    #[test]
    fn oversized_window_clamps_to_whole_graph() {
        let g = sample_parent();
        let ws = sample_windows(&g, 2, 10_000, 1, 0);
        for w in &ws {
            assert_eq!(w.n_cells, g.n_cells);
            assert_eq!(w.near, g.near);
        }
    }
}
