//! Stage (c): geometric `near` links via a shifting window (paper Fig. 3c,
//! after Swin-transformer-style windows [18]).
//!
//! Cells within a window radius are linked symmetrically. The radius is
//! calibrated so the directed edge count hits `target_near`; excess pairs
//! are randomly down-sampled (keeping symmetry) so Table-1 counts are met
//! within a tight tolerance while the hotspot layout keeps the degree
//! distribution heavy-tailed as in Fig. 4.

use super::layout::Placement;
use crate::graph::Csr;
use crate::util::rng::Rng;

/// Build the symmetric `near` adjacency with ≈`target_nnz` stored entries
/// (each undirected link contributes two).
pub fn near_edges(placement: &Placement, target_nnz: usize, rng: &mut Rng) -> Csr {
    let n = placement.cells.len();
    if n == 0 || target_nnz == 0 {
        return Csr::from_triplets(n, n, &[]);
    }
    let target_pairs = target_nnz / 2;
    // Initial radius from a uniform-density estimate: avg_deg = n·π·r².
    let avg_deg = target_nnz as f64 / n as f64;
    let mut radius = (avg_deg / (std::f64::consts::PI * n as f64)).sqrt() as f32;
    let mut pairs: Vec<(u32, u32)> = Vec::new();
    // Clustering concentrates mass, so the uniform estimate usually
    // overshoots pair counts; iterate radius until we have enough pairs.
    for _attempt in 0..12 {
        pairs.clear();
        for i in 0..n {
            placement.for_neighbors_within(i, radius, |j, _| {
                if j > i {
                    pairs.push((i as u32, j as u32));
                }
            });
        }
        if pairs.len() >= target_pairs {
            break;
        }
        radius *= 1.35;
    }
    if pairs.len() > target_pairs {
        // Down-sample pairs uniformly (partial Fisher–Yates).
        for i in 0..target_pairs {
            let j = rng.range(i, pairs.len());
            pairs.swap(i, j);
        }
        pairs.truncate(target_pairs);
    }
    let mut triplets = Vec::with_capacity(pairs.len() * 2);
    for &(a, b) in &pairs {
        triplets.push((a as usize, b as usize, 1.0));
        triplets.push((b as usize, a as usize, 1.0));
    }
    Csr::from_triplets(n, n, &triplets)
}

#[cfg(test)]
mod tests {
    use super::super::layout::place_cells;
    use super::*;

    #[test]
    fn hits_target_within_tolerance() {
        let mut rng = Rng::new(1);
        let p = place_cells(800, &mut rng);
        let target = 24_000;
        let near = near_edges(&p, target, &mut rng);
        let err = (near.nnz() as f64 - target as f64).abs() / target as f64;
        assert!(err < 0.02, "nnz={} target={target}", near.nnz());
    }

    #[test]
    fn symmetric_no_self_loops() {
        let mut rng = Rng::new(2);
        let p = place_cells(400, &mut rng);
        let near = near_edges(&p, 8_000, &mut rng);
        assert!(near.is_transpose_of(&near));
        for r in 0..near.rows {
            for q in near.row_range(r) {
                assert_ne!(near.indices[q] as usize, r, "self loop at {r}");
            }
        }
    }

    #[test]
    fn empty_target_gives_empty_matrix() {
        let mut rng = Rng::new(3);
        let p = place_cells(100, &mut rng);
        let near = near_edges(&p, 0, &mut rng);
        assert_eq!(near.nnz(), 0);
    }

    #[test]
    fn degree_tail_exceeds_mode() {
        // Hotspots should create rows with degree several times the average.
        let mut rng = Rng::new(4);
        let p = place_cells(1500, &mut rng);
        let near = near_edges(&p, 60_000, &mut rng);
        let avg = near.avg_degree();
        assert!(near.max_degree() as f64 > 2.0 * avg, "max {} avg {avg}", near.max_degree());
    }
}
