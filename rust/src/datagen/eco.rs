//! Seeded ECO (engineering change order) generator (ISSUE 8).
//!
//! Real ECOs are small, local edits to a placed design: a buffer inserted
//! or removed (near edges appear/disappear), a net rewired to a different
//! cell (one pin moves), a cell resized (its features change). This module
//! synthesizes such edits against any generated heterograph as a
//! [`DeltaPatch`], at a configurable churn rate, fully determined by a
//! seed — the fig14 bench and the delta proptests replay identical ECOs
//! on both the incremental and the from-scratch path.
//!
//! The generator preserves the graph's invariants by construction: near
//! edits are mirrored (a symmetric near matrix stays symmetric), pin
//! rewires move a pin rather than delete a net's last one, and every op
//! targets a distinct edge (patches reject duplicate targets). The
//! resulting patch always applies cleanly: `apply_delta(g, &generate_eco(
//! g, &spec))` is `Ok` for every generated graph.

use crate::graph::{Csr, DeltaPatch, EdgeType, HeteroGraph};
use crate::util::rng::Rng;
use std::collections::BTreeSet;

/// Shape of a synthetic ECO.
#[derive(Clone, Copy, Debug)]
pub struct EcoSpec {
    /// Approximate fraction of each adjacency's nonzeros the ECO touches
    /// (split across removals, additions, rewires, and reweights). Typical
    /// real-world churn is well under 1%; the fig14 sweep uses 0.2%–5%.
    pub churn: f64,
    /// Seed: equal specs generate equal patches on equal graphs.
    pub seed: u64,
}

impl EcoSpec {
    pub fn new(churn: f64, seed: u64) -> EcoSpec {
        EcoSpec { churn, seed }
    }
}

/// A random existing edge, uniform over nonzeros.
fn pick_edge(adj: &Csr, rng: &mut Rng) -> Option<(usize, usize)> {
    if adj.nnz() == 0 {
        return None;
    }
    let q = rng.below(adj.nnz());
    let r = adj.indptr.partition_point(|&p| p <= q) - 1;
    Some((r, adj.indices[q] as usize))
}

/// Generate one ECO against `g`. See the module docs for the edit mix;
/// `spec.churn` scales the op count, `spec.seed` fixes every choice.
pub fn generate_eco(g: &HeteroGraph, spec: &EcoSpec) -> DeltaPatch {
    assert!(spec.churn >= 0.0 && spec.churn <= 1.0, "churn must be in [0, 1]");
    let mut rng = Rng::new(spec.seed);
    let mut patch = DeltaPatch::new();
    // Every op must target a distinct (row, col); these sets also keep
    // mirrored edits consistent (never add over a removal and vice versa).
    let mut near_touched: BTreeSet<(usize, usize)> = BTreeSet::new();
    let mut pins_touched: BTreeSet<(usize, usize)> = BTreeSet::new();
    let mut cells_touched: BTreeSet<usize> = BTreeSet::new();

    let near_budget = ((g.near.nnz() as f64 * spec.churn).round() as usize).max(2);
    let pin_budget = (((g.pins.nnz() as f64 * spec.churn) / 2.0).round() as usize).max(1);

    // Near removals (~1/4 of the near budget), mirrored: a dropped
    // proximity link disappears in both directions.
    let mut removed = 0usize;
    for _ in 0..near_budget * 4 {
        if removed * 4 >= near_budget {
            break;
        }
        let Some((r, c)) = pick_edge(&g.near, &mut rng) else { break };
        if near_touched.contains(&(r, c)) || near_touched.contains(&(c, r)) {
            continue;
        }
        near_touched.insert((r, c));
        patch = patch.remove_edge(EdgeType::Near, r, c);
        removed += 1;
        if r != c && g.near.get(c, r).is_some() {
            near_touched.insert((c, r));
            patch = patch.remove_edge(EdgeType::Near, c, r);
        }
        cells_touched.insert(r);
        cells_touched.insert(c);
    }

    // Near additions (~1/4), mirrored: new proximity from a placement
    // shift.
    let mut added = 0usize;
    for _ in 0..near_budget * 4 {
        if added * 4 >= near_budget || g.n_cells < 2 {
            break;
        }
        let r = rng.below(g.n_cells);
        let c = rng.below(g.n_cells);
        if r == c
            || g.near.get(r, c).is_some()
            || near_touched.contains(&(r, c))
            || near_touched.contains(&(c, r))
        {
            continue;
        }
        let w = rng.uniform(0.5, 1.5);
        near_touched.insert((r, c));
        near_touched.insert((c, r));
        patch = patch.add_edge(EdgeType::Near, r, c, w).add_edge(EdgeType::Near, c, r, w);
        added += 1;
        cells_touched.insert(r);
        cells_touched.insert(c);
    }

    // Near reweights (the rest): distance drift without topology change.
    let mut reweighed = 0usize;
    for _ in 0..near_budget * 4 {
        if reweighed * 2 >= near_budget {
            break;
        }
        let Some((r, c)) = pick_edge(&g.near, &mut rng) else { break };
        if near_touched.contains(&(r, c)) || near_touched.contains(&(c, r)) {
            continue;
        }
        let w = rng.uniform(0.5, 1.5);
        near_touched.insert((r, c));
        patch = patch.reweight_edge(EdgeType::Near, r, c, w);
        reweighed += 1;
        if r != c && g.near.get(c, r).is_some() {
            near_touched.insert((c, r));
            patch = patch.reweight_edge(EdgeType::Near, c, r, w);
        }
    }

    // Pin rewires: move one pin of a multi-pin net to a currently
    // unconnected cell (the classic ECO: a net re-routed to a different
    // driver/sink). Multi-pin only, so no net ever loses its last pin.
    let mut rewired = 0usize;
    for _ in 0..pin_budget * 8 {
        if rewired >= pin_budget || g.n_nets == 0 || g.n_cells < 2 {
            break;
        }
        let net = rng.below(g.n_nets);
        let deg = g.pins.row_range(net).len();
        if deg < 2 {
            continue;
        }
        let q = g.pins.row_range(net).start + rng.below(deg);
        let c_old = g.pins.indices[q] as usize;
        let c_new = rng.below(g.n_cells);
        if g.pins.get(net, c_new).is_some()
            || pins_touched.contains(&(net, c_old))
            || pins_touched.contains(&(net, c_new))
        {
            continue;
        }
        pins_touched.insert((net, c_old));
        pins_touched.insert((net, c_new));
        patch = patch
            .remove_edge(EdgeType::Pins, net, c_old)
            .add_edge(EdgeType::Pins, net, c_new, rng.uniform(0.5, 1.5));
        rewired += 1;
        cells_touched.insert(c_old);
        cells_touched.insert(c_new);
    }

    // Feature/label drift on a few edited cells (resized cells change
    // their raw features and congestion labels).
    for (i, &cell) in cells_touched.iter().enumerate() {
        if i >= 4 {
            break;
        }
        let row: Vec<f32> =
            g.x_cell.row(cell).iter().map(|v| v + 0.1 * rng.normal()).collect();
        patch = patch.set_x_cell(cell, row);
        if i == 0 {
            patch = patch.set_y_cell(cell, g.y_cell.row(cell)[0] + 0.05);
        }
    }

    patch
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate_graph, GraphSpec};
    use crate::graph::apply_delta;

    fn test_graph(seed: u64) -> HeteroGraph {
        let mut rng = Rng::new(seed);
        generate_graph(
            &GraphSpec {
                n_cells: 120,
                n_nets: 60,
                target_near: 600,
                target_pins: 150,
                d_cell: 4,
                d_net: 4,
            },
            0,
            &mut rng,
        )
    }

    #[test]
    fn generated_ecos_apply_cleanly_and_are_deterministic() {
        let g = test_graph(3);
        for seed in 0..8 {
            let spec = EcoSpec::new(0.02, seed);
            let patch = generate_eco(&g, &spec);
            assert!(!patch.is_empty(), "seed {seed}");
            let patched = apply_delta(&g, &patch)
                .unwrap_or_else(|e| panic!("seed {seed}: {e}\n{}", patch.describe()));
            assert_ne!(patched.adjacency_hash(), g.adjacency_hash(), "seed {seed}");
            assert_eq!(patch, generate_eco(&g, &spec), "same seed, same patch");
        }
        assert_ne!(
            generate_eco(&g, &EcoSpec::new(0.02, 1)),
            generate_eco(&g, &EcoSpec::new(0.02, 2)),
            "different seeds should differ"
        );
    }

    #[test]
    fn churn_scales_the_op_count() {
        let g = test_graph(4);
        let small = generate_eco(&g, &EcoSpec::new(0.005, 9));
        let large = generate_eco(&g, &EcoSpec::new(0.1, 9));
        assert!(
            large.n_edge_ops() > 2 * small.n_edge_ops(),
            "{} vs {}",
            large.n_edge_ops(),
            small.n_edge_ops()
        );
    }

    /// Symmetric near matrices stay symmetric: the patched near must equal
    /// its own transpose (the generator mirrors every near edit).
    #[test]
    fn near_edits_preserve_symmetry() {
        let g = test_graph(5);
        assert!(g.near.is_transpose_of(&g.near), "fixture sanity: generated near is symmetric");
        let patched = apply_delta(&g, &generate_eco(&g, &EcoSpec::new(0.05, 11))).unwrap();
        assert!(patched.near.is_transpose_of(&patched.near), "symmetry lost");
    }
}
