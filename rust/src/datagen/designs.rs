//! Design presets.
//!
//! `table1_designs()` reproduces the three representative CircuitNet designs
//! of paper Table 1 — same graph counts and node/edge targets per partition.
//! `random_design_spec` draws Mini-CircuitNet-style designs with the same
//! statistical profile at a configurable scale.

use super::{DesignSpec, GraphSpec};
use crate::util::rng::Rng;

/// Paper-named design sizes. `Full` is the CircuitNet-scale tier (≈10⁶
/// cells across its partitions at scale 1.0) used for the window-sampling
/// and checkpointing experiments; the other three are the Table-1 seeds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DesignSize {
    Small,
    Medium,
    Large,
    Full,
}

impl DesignSize {
    pub fn paper_name(&self) -> &'static str {
        match self {
            DesignSize::Small => "9282-zero",
            DesignSize::Medium => "2216-RISCY",
            DesignSize::Large => "7598-zero",
            DesignSize::Full => "circuitnet-full",
        }
    }
}

/// Raw feature widths used throughout (projected to 64/128 by the model).
pub const D_CELL_RAW: usize = 16;
pub const D_NET_RAW: usize = 16;

fn spec(n_nets: usize, n_cells: usize, pins: usize, near: usize) -> GraphSpec {
    GraphSpec {
        n_cells,
        n_nets,
        target_near: near,
        target_pins: pins,
        d_cell: D_CELL_RAW,
        d_net: D_NET_RAW,
    }
}

/// The three Table-1 designs with exact published node/edge targets.
///
/// Columns per graph: (nodes-net, nodes-cell, edges-pins(=pinned), edges-near).
pub fn table1_designs(scale: f64) -> Vec<DesignSpec> {
    let s = |x: usize| ((x as f64 * scale).round() as usize).max(8);
    let e = |x: usize| ((x as f64 * scale).round() as usize).max(32);
    vec![
        DesignSpec {
            name: "9282-zero".into(),
            seed: 9282,
            graphs: vec![
                spec(s(4628), s(7767), e(10013), e(338050)),
                spec(s(3269), s(7347), e(7580), e(282216)),
            ],
        },
        DesignSpec {
            name: "2216-RISCY".into(),
            seed: 2216,
            graphs: vec![
                spec(s(5331), s(9493), e(12382), e(432187)),
                spec(s(7271), s(9733), e(18814), e(444258)),
                spec(s(6461), s(9590), e(19227), e(409581)),
            ],
        },
        DesignSpec {
            name: "7598-zero".into(),
            seed: 7598,
            graphs: vec![
                spec(s(5883), s(9816), e(16605), e(455383)),
                spec(s(6183), s(9399), e(17394), e(449466)),
                spec(s(9100), s(9579), e(34748), e(440481)),
                spec(s(7146), s(9341), e(22056), e(483638)),
            ],
        },
    ]
}

/// Pick one design by size (`Full` routes to [`full_design`]; the rest are
/// Table-1 entries).
pub fn table1_design(size: DesignSize, scale: f64) -> DesignSpec {
    let idx = match size {
        DesignSize::Small => 0,
        DesignSize::Medium => 1,
        DesignSize::Large => 2,
        DesignSize::Full => return full_design(scale),
    };
    table1_designs(scale).swap_remove(idx)
}

/// The Full tier: a CircuitNet-sized design of ≈10⁶ cells at scale 1.0,
/// split into 8 partitions of ~125k cells. Per-partition `target_near`
/// (near-degree ≈ 50, as in Fig. 4) sits at ~6.3M — past
/// `STREAMING_NEAR_THRESHOLD`, so generation takes the streaming path and
/// never materialises the candidate pair list. Partition sizes vary
/// slightly (fixed offsets, not RNG) so partitions are not clones of each
/// other, mirroring how real designs split unevenly.
pub fn full_design(scale: f64) -> DesignSpec {
    let s = |x: usize| ((x as f64 * scale).round() as usize).max(8);
    let e = |x: usize| ((x as f64 * scale).round() as usize).max(32);
    // (cells, nets) per partition; totals 1_001_000 cells / 487_000 nets.
    const PARTS: [(usize, usize); 8] = [
        (127_400, 61_900),
        (123_800, 60_300),
        (126_100, 62_800),
        (124_500, 59_600),
        (125_900, 61_200),
        (124_200, 60_700),
        (126_700, 61_500),
        (122_400, 59_000),
    ];
    let graphs = PARTS
        .iter()
        .map(|&(cells, nets)| GraphSpec {
            n_cells: s(cells),
            n_nets: s(nets),
            // near-degree ≈ 50, pin fanout ≈ 3 — the Fig. 4 shape.
            target_near: e(cells * 50),
            target_pins: e(nets * 3),
            d_cell: D_CELL_RAW,
            d_net: D_NET_RAW,
        })
        .collect();
    DesignSpec { name: "circuitnet-full".into(), seed: 10_617, graphs }
}

/// Random design with CircuitNet-like proportions at `scale`
/// (scale 1.0 ≈ 5–10k nodes/type per graph, near-degree ≈ 40–55,
/// pin fanout ≈ 2–4).
pub fn random_design_spec(name: &str, scale: f64, rng: &mut Rng) -> DesignSpec {
    let n_graphs = rng.range(1, 4);
    let mut graphs = Vec::with_capacity(n_graphs);
    for _ in 0..n_graphs {
        let n_cells = ((rng.range(7_000, 10_000) as f64 * scale) as usize).max(64);
        let n_nets = ((rng.range(3_000, 9_000) as f64 * scale) as usize).max(32);
        let near_deg = rng.uniform(38.0, 55.0) as f64;
        let pin_fanout = rng.uniform(2.1, 3.9) as f64;
        graphs.push(GraphSpec {
            n_cells,
            n_nets,
            target_near: (n_cells as f64 * near_deg) as usize,
            target_pins: ((n_nets as f64 * pin_fanout) as usize).max(n_nets * 2),
            d_cell: D_CELL_RAW,
            d_net: D_NET_RAW,
        });
    }
    DesignSpec { name: name.to_string(), seed: rng.next_u64(), graphs }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_counts_at_full_scale() {
        let designs = table1_designs(1.0);
        assert_eq!(designs.len(), 3);
        assert_eq!(designs[0].graphs.len(), 2);
        assert_eq!(designs[1].graphs.len(), 3);
        assert_eq!(designs[2].graphs.len(), 4);
        // Spot-check the published numbers.
        assert_eq!(designs[0].graphs[0].n_nets, 4628);
        assert_eq!(designs[0].graphs[0].n_cells, 7767);
        assert_eq!(designs[0].graphs[0].target_pins, 10013);
        assert_eq!(designs[0].graphs[0].target_near, 338050);
        assert_eq!(designs[2].graphs[2].target_pins, 34748);
        assert_eq!(designs[1].graphs[1].target_near, 444258);
    }

    #[test]
    fn scaling_shrinks_proportionally() {
        let full = table1_designs(1.0);
        let tenth = table1_designs(0.1);
        let f = full[0].graphs[0].n_cells as f64;
        let t = tenth[0].graphs[0].n_cells as f64;
        assert!((t / f - 0.1).abs() < 0.01);
    }

    #[test]
    fn size_lookup() {
        assert_eq!(table1_design(DesignSize::Small, 1.0).name, "9282-zero");
        assert_eq!(table1_design(DesignSize::Medium, 1.0).name, "2216-RISCY");
        assert_eq!(table1_design(DesignSize::Large, 1.0).name, "7598-zero");
        assert_eq!(DesignSize::Large.paper_name(), "7598-zero");
    }

    #[test]
    fn full_tier_is_million_scale_and_streams() {
        let d = full_design(1.0);
        assert_eq!(d.name, "circuitnet-full");
        assert_eq!(d.graphs.len(), 8);
        let cells: usize = d.graphs.iter().map(|g| g.n_cells).sum();
        assert!(
            (990_000..=1_010_000).contains(&cells),
            "Full tier must total ≈10⁶ cells, got {cells}"
        );
        for g in &d.graphs {
            assert!(g.streams_near(), "every Full partition must stream near generation");
            assert!(g.extent() > 3.0, "Full partitions must grow the die past the unit square");
            // Fig. 4 shape: near much denser than pins.
            assert!(g.target_near > 5 * g.target_pins);
        }
        assert_eq!(table1_design(DesignSize::Full, 1.0).name, "circuitnet-full");
        assert_eq!(DesignSize::Full.paper_name(), "circuitnet-full");
    }

    #[test]
    fn full_tier_scales_down_without_streaming() {
        // Bench scales shrink below the streaming threshold and the unit
        // die — same code path as the Table-1 tiers.
        let d = full_design(0.005);
        for g in &d.graphs {
            assert!(!g.streams_near());
            assert_eq!(g.extent(), 1.0);
            assert!(g.n_cells >= 8 && g.target_near >= 32);
        }
    }

    #[test]
    fn random_spec_profile() {
        let mut rng = Rng::new(10);
        for i in 0..20 {
            let d = random_design_spec(&format!("d{i}"), 0.1, &mut rng);
            assert!(!d.graphs.is_empty() && d.graphs.len() <= 3);
            for g in &d.graphs {
                // near much denser than pins, as in Fig. 4.
                assert!(g.target_near > 5 * g.target_pins);
                assert!(g.target_pins >= 2 * g.n_nets);
            }
        }
    }
}
