//! DR-CircuitGNN launcher (Layer-3 coordinator entrypoint).
//!
//! Subcommands:
//!   gen-data   — generate the synthetic CircuitNet designs; print Table-1
//!                style statistics and Fig.-4 degree histograms.
//!   train      — train DR-CircuitGNN (or a homogeneous baseline) on
//!                Mini-CircuitNet; report Table-2 metrics.
//!   profile-k  — the §4.3 preprocessing pass: per-subgraph optimal K
//!                (persisted to `--plan-store` for the auto policy).
//!   serve      — resident serve loop: jobs from `--serve <file>` through
//!                a bounded queue over one shared plan cache.
//!   e2e        — one end-to-end step per Table-1 graph under each engine
//!                and schedule; report Table-3 style speedups.
//!   runtime    — inspect and smoke-run AOT artifacts via PJRT.
//!
//! `--plan-store <dir>` (train / profile-k / serve) persists kernel plans
//! and K profiles keyed by adjacency content-hash + engine signature, so
//! a second run warm-starts Alg. 1 stage 1 from disk.
//!
//! Run `dr-circuitgnn help` for options.

use dr_circuitgnn::bench::{fmt_speedup, Table};
use dr_circuitgnn::config::Config;
use dr_circuitgnn::datagen::{self, mini_circuitnet, table1_designs};
use dr_circuitgnn::engine::{auto_select, EngineBuilder, PlanStore};
use dr_circuitgnn::fleet::{CacheStats, PlanCache};
use dr_circuitgnn::graph::stats::{degree_report, ImbalanceStats};
use dr_circuitgnn::nn::HomoKind;
use dr_circuitgnn::runtime::{ArtifactRegistry, Runtime};
use dr_circuitgnn::sched::{run_e2e_step, ScheduleMode};
use dr_circuitgnn::serve::{parse_jobs, ServeConfig, Server};
use dr_circuitgnn::sparse::GnnaConfig;
use dr_circuitgnn::train::{kprofile, TrainConfig, Trainer};
use dr_circuitgnn::util::cli::Args;
use dr_circuitgnn::util::logger;
use std::sync::Arc;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::default()
        .declare("config", "config file (TOML subset)", true)
        .declare("scale", "dataset scale factor (0,1]", true)
        .declare("designs", "number of Mini-CircuitNet designs", true)
        .declare("epochs", "training epochs", true)
        .declare("hidden", "hidden width", true)
        .declare("lr", "learning rate", true)
        .declare("kernel", "csr | gnna | dr | auto (engine registry names)", true)
        .declare("model", "dr | gcn | sage | gat (train)", true)
        .declare("k-cell", "D-ReLU K for cell embeddings", true)
        .declare("k-net", "D-ReLU K for net embeddings", true)
        .declare("dim", "embedding width for kernel benches", true)
        .declare("seed", "RNG seed", true)
        .declare("parallel", "enable §3.4 parallel schedule", false)
        .declare("sequential", "disable §3.4 parallel schedule", false)
        .declare("fleet", "fleet mode: off | <workers> | <workers>x<parts>", true)
        .declare(
            "epoch-pipeline",
            "on | off: overlap design N+1's prepare with design N's step (fleet mode)",
            true,
        )
        .declare(
            "window",
            "off | <count>x<cells>: train on sampled windows per design per epoch (fleet mode)",
            true,
        )
        .declare(
            "checkpoint",
            "on | off: recompute activations in backward (layer-peak memory, bit-identical)",
            true,
        )
        .declare("threads", "root thread budget (default: DRCG_THREADS or all cores)", true)
        .declare("plan-store", "persistent plan store directory (warm-starts Alg. 1 stage 1)", true)
        .declare("serve", "jobs file for serve mode (one design=… job per line)", true)
        .declare("serve-workers", "concurrent serve job workers (default 2)", true)
        .declare("queue-cap", "serve queue capacity (default 16)", true)
        .declare("artifacts", "artifacts directory", true)
        .declare("log", "log level: debug|info|warn|error", true)
        .parse(&raw)
    {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Some(level) = args.get("log").and_then(logger::parse_level) {
        logger::set_level(level);
    }
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    let cfg = match Config::resolve(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            std::process::exit(2);
        }
    };
    // The one budget root: every nesting level (fleet workers × §3.4 edge
    // lanes × kernel parallel_for) subdivides this cap. Must be installed
    // before any parallel work reads it (first use wins).
    if let Some(t) = cfg.threads {
        if let Err(e) = dr_circuitgnn::util::pool::set_root_threads(t) {
            eprintln!("--threads: {e}");
            std::process::exit(2);
        }
    }
    let code = match cmd {
        "gen-data" => cmd_gen_data(&cfg),
        "train" => cmd_train(&cfg, &args),
        "profile-k" => cmd_profile_k(&cfg),
        "serve" => cmd_serve(&cfg),
        "e2e" => cmd_e2e(&cfg),
        "runtime" => cmd_runtime(&cfg),
        _ => {
            println!(
                "dr-circuitgnn — heterogeneous circuit GNN training acceleration\n\n\
                 commands: gen-data | train | profile-k | serve | e2e | runtime\n\n{}",
                args.usage("dr-circuitgnn <command>")
            );
            0
        }
    };
    std::process::exit(code);
}

fn cmd_gen_data(cfg: &Config) -> i32 {
    let mut table = Table::new(
        &format!("Table 1 — design statistics (scale {})", cfg.scale),
        &[
            "design", "graph", "nodes-net", "nodes-cell", "e-pinned", "e-near", "e-pins",
            "total-n", "total-e",
        ],
    );
    for spec in table1_designs(cfg.scale) {
        let graphs = datagen::generate_design(&spec);
        for g in &graphs {
            let s = g.stats_row();
            table.row(&[
                spec.name.clone(),
                s.id.to_string(),
                s.nodes_net.to_string(),
                s.nodes_cell.to_string(),
                s.edges_pinned.to_string(),
                s.edges_near.to_string(),
                s.edges_pins.to_string(),
                s.total_nodes().to_string(),
                s.total_edges().to_string(),
            ]);
        }
        // Fig. 4 degree summary for the first graph of each design, plus
        // what the engine's "auto" policy would pick per edge type.
        let g = &graphs[0];
        for (edge, hist) in degree_report(g, 4) {
            let imb = ImbalanceStats::of(g.adj(edge));
            let auto = auto_select(g.adj(edge), edge);
            dr_circuitgnn::info!(
                "{} {}: mode≈{} max={} avg={:.1} imbalance={:.1} {} | auto→{} ({})",
                spec.name,
                edge.name(),
                hist.mode_degree(),
                hist.max_degree,
                hist.avg_degree,
                imb.imbalance,
                hist.sparkline(32),
                auto.spec.name(),
                auto.reason
            );
        }
    }
    table.print();
    0
}

fn cmd_train(cfg: &Config, args: &Args) -> i32 {
    let (train, test) = mini_circuitnet(cfg.n_designs, cfg.scale, cfg.seed);
    dr_circuitgnn::info!(
        "Mini-CircuitNet: {} train / {} test designs ({} graphs)",
        train.designs.len(),
        test.designs.len(),
        train.total_graphs() + test.total_graphs()
    );
    let tc = TrainConfig {
        epochs: cfg.epochs,
        lr: cfg.lr,
        weight_decay: cfg.weight_decay,
        hidden: cfg.hidden,
        seed: cfg.seed,
        parallel: cfg.parallel,
        epoch_pipeline: cfg.epoch_pipeline,
        window: cfg.window,
        checkpoint: cfg.checkpoint,
        log_every: 5,
    };
    let model_kind = args.get_or("model", "dr").to_string();
    let (scores, secs, params) = if model_kind == "dr" {
        // All DR paths run through one plan cache (disk-backed when
        // --plan-store is set) so warm starts and cache traffic are
        // observable regardless of fleet mode.
        let cache = match make_cache(cfg) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("--plan-store: {e}");
                return 1;
            }
        };
        let (_, report) = if cfg.fleet.is_on() {
            dr_circuitgnn::info!(
                "fleet mode: {}{}{}{}",
                cfg.fleet.describe(),
                if cfg.epoch_pipeline { ", epoch pipeline on" } else { "" },
                if cfg.window.is_on() {
                    format!(", window {}", cfg.window.describe())
                } else {
                    String::new()
                },
                if cfg.checkpoint { ", checkpoint on" } else { "" }
            );
            Trainer::train_dr_fleet_cached(
                &train,
                &test,
                &cfg.engine_builder(),
                &tc,
                &cfg.fleet,
                &cache,
            )
        } else {
            Trainer::train_dr_cached(&train, &test, &cfg.engine_builder(), &tc, &cache)
        };
        print_plan_line(&report.plan_cache);
        if !report.epoch_overlap.is_empty() {
            let best = report.epoch_overlap.iter().cloned().fold(0.0, f64::max);
            let mean = report.epoch_overlap.iter().sum::<f64>()
                / report.epoch_overlap.len() as f64;
            dr_circuitgnn::info!(
                "epoch pipeline overlap: mean {mean:.2}×, best {best:.2}× \
                 (prepare stage overlapped with execute; 1.0 = fully serial)"
            );
        }
        (report.test_scores, report.train_seconds, report.params)
    } else if cfg.fleet.is_on() {
        eprintln!("--fleet applies to the DR model only (got --model {model_kind})");
        return 2;
    } else {
        let kind = match HomoKind::parse(&model_kind) {
            Some(k) => k,
            None => {
                eprintln!("--model: unknown '{model_kind}'");
                return 2;
            }
        };
        let mut tc = tc;
        tc.lr = 1e-3;
        tc.weight_decay = 2e-4;
        let (_, report) = Trainer::train_homo(kind, &train, &test, &tc);
        (report.test_scores, report.train_seconds, report.params)
    };
    let mut t = Table::new(
        &format!("Congestion prediction — {model_kind} ({} epochs)", cfg.epochs),
        &["model", "Pearson", "Spear.", "Ken.", "MAE", "RMSE", "params", "train-s"],
    );
    t.row(&[
        model_kind,
        format!("{:.3}", scores.pearson),
        format!("{:.3}", scores.spearman),
        format!("{:.3}", scores.kendall),
        format!("{:.3}", scores.mae),
        format!("{:.3}", scores.rmse),
        params.to_string(),
        format!("{secs:.1}"),
    ]);
    t.print();
    0
}

/// The one plan cache a command multiplexes through: disk-backed when
/// `--plan-store` is set, in-memory otherwise. Built over the config's
/// engine builder so every cached trainer call is plan-compatible.
fn make_cache(cfg: &Config) -> Result<Arc<PlanCache>, String> {
    let builder = cfg.engine_builder();
    Ok(Arc::new(match &cfg.plan_store {
        Some(dir) => PlanCache::backed_by(builder, dir)?,
        None => PlanCache::new(builder),
    }))
}

/// Stable, machine-greppable warm-start summary (CI asserts the second
/// `--plan-store` run reports `0 plans built cold`).
fn print_plan_line(stats: &CacheStats) {
    println!(
        "plan store: {} plans built cold, {} loaded warm, {} memory hits, {} persisted",
        stats.misses, stats.disk_loads, stats.hits, stats.disk_stores
    );
}

fn cmd_profile_k(cfg: &Config) -> i32 {
    let store = match &cfg.plan_store {
        Some(dir) => match PlanStore::open(dir, &cfg.engine_builder()) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("--plan-store: {e}");
                return 1;
            }
        },
        None => None,
    };
    let designs = table1_designs(cfg.scale);
    let mut t = Table::new(
        &format!("§4.3 optimal-K profile (dim {})", cfg.dim),
        &["design", "graph", "edge", "best-K", "timings (k: ms)"],
    );
    let mut persisted = 0usize;
    for spec in &designs {
        let graphs = datagen::generate_design(spec);
        for g in &graphs {
            let profiles = kprofile::profile_optimal_k(g, cfg.dim, 3, cfg.seed);
            if let Some(store) = &store {
                // Persist the measured profile keyed by adjacency hash;
                // the plan cache's `auto` policy reads it back on the
                // next cold build or warm load of this graph.
                let rec = kprofile::to_record(&profiles);
                match store.store_profile(g.adjacency_hash(), &rec) {
                    Ok(_) => persisted += 1,
                    Err(e) => {
                        eprintln!("profile store failed: {e}");
                        return 1;
                    }
                }
            }
            for p in &profiles {
                let detail = p
                    .timings
                    .iter()
                    .map(|(k, s)| format!("{k}:{:.2}", s * 1e3))
                    .collect::<Vec<_>>()
                    .join(" ");
                t.row(&[
                    spec.name.clone(),
                    g.id.to_string(),
                    p.edge.name().to_string(),
                    p.best_k.to_string(),
                    detail,
                ]);
            }
        }
    }
    t.print();
    if let Some(store) = &store {
        println!("K profiles: {persisted} persisted to {}", store.dir().display());
    }
    0
}

fn cmd_serve(cfg: &Config) -> i32 {
    let jobs_path = match &cfg.serve_jobs {
        Some(p) => p,
        None => {
            eprintln!("serve requires --serve <jobs-file> (one design=… job per line)");
            return 2;
        }
    };
    let text = match std::fs::read_to_string(jobs_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("cannot read {}: {e}", jobs_path.display());
            return 1;
        }
    };
    let jobs = match parse_jobs(&text) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let cache = match make_cache(cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("--plan-store: {e}");
            return 1;
        }
    };
    // The design catalog the service holds resident: the Mini-CircuitNet
    // training split, addressed by design name from job lines.
    let (train, _test) = mini_circuitnet(cfg.n_designs, cfg.scale, cfg.seed);
    dr_circuitgnn::info!(
        "serving {} jobs over {} designs ({} workers, queue cap {})",
        jobs.len(),
        train.designs.len(),
        cfg.serve_workers,
        cfg.queue_cap
    );
    let server = Server::new(&train.designs, cache);
    let serve_cfg = ServeConfig { workers: cfg.serve_workers, queue_cap: cfg.queue_cap };
    let report = match server.run(&jobs, &serve_cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("serve failed: {e}");
            return 1;
        }
    };
    let mut t = Table::new(
        &format!("serve report — {} jobs, {} workers", report.results.len(), report.workers),
        &["job", "design", "epochs", "seed", "queue-s", "train-s", "MAE", "cold", "warm", "hits"],
    );
    for r in &report.results {
        t.row(&[
            r.id.to_string(),
            r.job.design.clone(),
            r.job.epochs.to_string(),
            r.job.seed.to_string(),
            format!("{:.3}", r.queue_seconds),
            format!("{:.3}", r.train_seconds),
            format!("{:.3}", r.report.test_scores.mae),
            r.cache.misses.to_string(),
            r.cache.disk_loads.to_string(),
            r.cache.hits.to_string(),
        ]);
    }
    t.print();
    println!(
        "served {} jobs in {:.2}s ({} workers, warm rate {:.0}%)",
        report.results.len(),
        report.wall_seconds,
        report.workers,
        report.warm_rate() * 100.0
    );
    print_plan_line(&report.cache);
    0
}

fn cmd_e2e(cfg: &Config) -> i32 {
    let designs = table1_designs(cfg.scale);
    let mut t = Table::new(
        &format!("Table 3 — end-to-end speedups (dim {}, scale {})", cfg.dim, cfg.scale),
        &["design", "graph", "cuSPARSE-seq", "GNNA-seq", "DR-par", "vs cuSPARSE", "vs GNNA"],
    );
    for spec in &designs {
        let graphs = datagen::generate_design(spec);
        for g in &graphs {
            let base =
                run_e2e_step(g, cfg.dim, &EngineBuilder::csr(), ScheduleMode::Sequential, cfg.seed);
            let gnna = run_e2e_step(
                g,
                cfg.dim,
                &EngineBuilder::gnna(GnnaConfig::default()),
                ScheduleMode::Sequential,
                cfg.seed,
            );
            let ours = run_e2e_step(g, cfg.dim, &cfg.engine_builder(), cfg.schedule(), cfg.seed);
            t.row(&[
                spec.name.clone(),
                g.id.to_string(),
                format!("{:.1}ms", base.total * 1e3),
                format!("{:.1}ms", gnna.total * 1e3),
                format!("{:.1}ms", ours.total * 1e3),
                fmt_speedup(base.total, ours.total),
                fmt_speedup(gnna.total, ours.total),
            ]);
        }
    }
    t.print();
    0
}

fn cmd_runtime(cfg: &Config) -> i32 {
    let reg = match ArtifactRegistry::scan(&cfg.artifacts_dir) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("artifact scan failed: {e}");
            return 1;
        }
    };
    if reg.names().is_empty() {
        eprintln!("no artifacts in {} — run `make artifacts` first", cfg.artifacts_dir.display());
        return 1;
    }
    println!("artifacts in {}:", cfg.artifacts_dir.display());
    for name in reg.names() {
        let meta = reg.meta(name).unwrap();
        println!(
            "  {name}: {} inputs, {} outputs {}",
            meta.inputs.len(),
            meta.outputs.len(),
            meta.notes.first().map(|n| format!("({n})")).unwrap_or_default()
        );
    }
    match Runtime::cpu() {
        Ok(rt) => {
            println!("PJRT: platform={} devices={}", rt.platform(), rt.device_count());
            0
        }
        Err(e) => {
            eprintln!("PJRT init failed: {e}");
            1
        }
    }
}
