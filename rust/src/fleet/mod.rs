//! Batched multi-subgraph execution (paper §3.4 at design scale).
//!
//! The paper's headline end-to-end numbers come from running a design's
//! *independent subgraphs* concurrently: multi-threaded CPU initialization
//! overlapped with per-stream kernel execution. PR 1's [`Engine`] is
//! strictly per-graph; this subsystem is the layer above it:
//!
//! * [`Fleet`] / [`FleetBuilder`] — one engine per subgraph of a design,
//!   built through a [`PlanCache`] keyed by adjacency content-hash so
//!   content-identical subgraphs plan once (Alg. 1 stage 1 deduplicated);
//! * [`Fleet::step`] — one training step over all subgraphs on a bounded
//!   worker pool ([`crate::util::pool::bounded_map`]), with **deterministic
//!   gradient reduction**: per-subgraph gradients are reduced in subgraph
//!   index order, so losses and gradients are bit-identical for every
//!   worker count (the `fleet(N) ≡ sequential` guarantee asserted in
//!   `tests/integration_fleet.rs` and `tests/proptests.rs`). Bit-exactness
//!   holds for kernels whose accumulation is scheduling-independent (csr,
//!   dr — each output row written by one thread); the GNNA analog's
//!   shared evil rows accumulate through atomic f32 adds whose order can
//!   vary, so its guarantee is within-tolerance, not bitwise;
//! * [`FleetSpec`] — the single parse point for `--fleet` / `fleet`
//!   settings, mirroring the engine's kernel registry.
//!
//! Inside each worker the §3.4 edge-level lanes still apply (the engine's
//! `parallel` flag, dispatched via [`crate::sched::run_lanes`]), giving the
//! graph-level × edge-level parallelism of Fig. 9b — but the levels
//! **share one thread budget** ([`crate::util::pool::Budget`]): `step`
//! leases `min(workers, budget)` shares, every worker's lanes and kernels
//! inherit that worker's share, so total live threads never exceed the
//! root budget however high `--fleet` is set. See `docs/FLEET.md`.

pub mod cache;
pub mod spec;

pub use cache::{CacheStats, PlanCache};
pub use spec::FleetSpec;

use crate::engine::{Engine, EngineBuilder};
use crate::graph::{partition_with_map, HeteroGraph};
use crate::nn::{mse, Adam, DrCircuitGnn};
use crate::tensor::Matrix;
use crate::util::pool::bounded_map;
use std::borrow::Cow;
use std::sync::Arc;

/// Reusable fleet configuration: an engine configuration plus the fleet
/// shape (worker count, optional re-partitioning). One builder can `build`
/// a fleet per design of a dataset.
#[derive(Clone, Debug)]
pub struct FleetBuilder {
    engine: EngineBuilder,
    workers: usize,
    parts: Option<usize>,
}

impl FleetBuilder {
    pub fn new(engine: EngineBuilder) -> FleetBuilder {
        FleetBuilder { engine, workers: 1, parts: None }
    }

    /// Worker-pool width for per-subgraph steps. This is a *request*: the
    /// pool clamps it to the subgraph count and leases it against the
    /// ambient thread budget at run time (see [`Fleet::effective_workers`]).
    /// More workers than subgraphs or than the budget is fine. Results
    /// never depend on this.
    pub fn workers(mut self, workers: usize) -> FleetBuilder {
        self.workers = workers.max(1);
        self
    }

    /// Re-partition each input graph into `parts` independent subgraphs
    /// (cell-contiguous, stable remapping — see
    /// [`crate::graph::partition_with_map`]).
    pub fn parts(mut self, parts: usize) -> FleetBuilder {
        self.parts = Some(parts.max(1));
        self
    }

    /// Apply a parsed [`FleetSpec`] (the CLI/config surface).
    pub fn spec(mut self, spec: &FleetSpec) -> FleetBuilder {
        self.workers = spec.workers();
        self.parts = spec.parts();
        self
    }

    /// Build a fleet over a design's graphs: optionally re-partition, then
    /// resolve one engine per subgraph through the shared plan cache.
    ///
    /// Without re-partitioning the fleet *borrows* the input graphs (no
    /// duplication of the dataset's adjacencies/features — a design-scale
    /// training run holds one copy); with `parts` set, the freshly cut
    /// subgraphs are owned and get fleet-wide ids.
    pub fn build<'a>(&self, graphs: &'a [HeteroGraph]) -> Fleet<'a> {
        let subgraphs: Vec<Cow<'a, HeteroGraph>> = match self.parts {
            None => graphs.iter().map(Cow::Borrowed).collect(),
            Some(p) => {
                let mut out: Vec<Cow<'a, HeteroGraph>> = Vec::new();
                for g in graphs {
                    for (mut sub, _) in partition_with_map(g, p) {
                        sub.id = out.len(); // fleet-wide ids, stable across builds
                        out.push(Cow::Owned(sub));
                    }
                }
                out
            }
        };
        assert!(!subgraphs.is_empty(), "fleet needs at least one subgraph");
        let total_cells: usize = subgraphs.iter().map(|g| g.n_cells).sum();
        let mut cache = PlanCache::new(self.engine.clone());
        let units = subgraphs
            .into_iter()
            .map(|g| {
                let engine = cache.engine_for(&g);
                let weight = g.n_cells as f32 / total_cells.max(1) as f32;
                FleetUnit { graph: g, engine, weight }
            })
            .collect();
        Fleet { units, workers: self.workers, cache_stats: cache.stats() }
    }
}

/// One subgraph with its (possibly shared) engine and its loss weight.
/// Borrowed for a design's native graphs, owned when freshly partitioned.
struct FleetUnit<'a> {
    graph: Cow<'a, HeteroGraph>,
    engine: Arc<Engine>,
    /// Cell share of the design: the fleet loss is the cell-count-weighted
    /// mean of per-subgraph MSEs, i.e. exactly the MSE over the union of
    /// all cells.
    weight: f32,
}

/// A design-bound fleet: every subgraph paired with a planned engine.
pub struct Fleet<'a> {
    units: Vec<FleetUnit<'a>>,
    workers: usize,
    cache_stats: CacheStats,
}

/// The fleet gradient of one model state: per-subgraph losses plus the
/// parameter gradients reduced in subgraph index order.
pub struct FleetGradients {
    /// Cell-weighted design loss (= MSE over all cells of the design).
    pub loss: f64,
    /// Unweighted per-subgraph MSE, in subgraph order.
    pub subgraph_losses: Vec<f64>,
    /// One gradient matrix per model parameter (the order of
    /// `DrCircuitGnn::params_mut`).
    pub grads: Vec<Matrix>,
}

/// Result of one [`Fleet::step`].
#[derive(Clone, Debug)]
pub struct FleetStep {
    pub loss: f64,
    pub subgraph_losses: Vec<f64>,
}

impl<'a> Fleet<'a> {
    /// Start configuring a fleet.
    pub fn builder(engine: EngineBuilder) -> FleetBuilder {
        FleetBuilder::new(engine)
    }

    pub fn n_subgraphs(&self) -> usize {
        self.units.len()
    }

    /// The *requested* worker-pool width.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The concurrency a `step`/`gradients` call gets right now: the
    /// requested width leased against the subgraph count and the caller's
    /// ambient thread budget ([`crate::util::pool::Budget::current`]).
    /// Purely informational (the pool re-leases on every call) — useful
    /// for logs and the fig13 sweep's budget-utilization column.
    pub fn effective_workers(&self) -> usize {
        let (conc, _) = crate::util::pool::Budget::current()
            .lease(self.workers.clamp(1, self.units.len().max(1)));
        conc
    }

    /// Plan-cache statistics of the build (`unique()` = engines planned).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache_stats
    }

    pub fn subgraphs(&self) -> impl Iterator<Item = &HeteroGraph> {
        self.units.iter().map(|u| u.graph.as_ref())
    }

    /// The engine driving a subgraph (shared between content-identical
    /// subgraphs).
    pub fn engine(&self, i: usize) -> &Arc<Engine> {
        &self.units[i].engine
    }

    /// Compute the fleet gradient without applying an update.
    ///
    /// Each subgraph runs forward + backward on a model replica (engines
    /// and kernels are deterministic, so replicas on worker threads give
    /// bit-identical results to a sequential loop); gradients are then
    /// reduced in subgraph index order. The per-subgraph prediction
    /// gradient is scaled by the subgraph's cell share so the summed
    /// gradient is the gradient of the design-wide cell MSE.
    ///
    /// Threading: `bounded_map` leases the requested `workers` against the
    /// ambient thread budget and installs an equal share as each worker's
    /// ambient budget — the worker's edge lanes and kernel `parallel_for`
    /// calls subdivide that share, so `--fleet 8` on an 8-thread budget
    /// runs 8×1-thread workers, not 8×3×8 runnable threads. Budgets change
    /// scheduling only; gradients stay bit-identical.
    pub fn gradients(&self, model: &DrCircuitGnn) -> FleetGradients {
        let per_unit: Vec<(Vec<Matrix>, f32)> =
            bounded_map(self.units.len(), self.workers, |i| {
                let unit = &self.units[i];
                let mut replica = model.clone();
                // The clone carries the caller's accumulated grads; drop
                // them so the reduction sees this subgraph's alone.
                Adam::zero_grad(&mut replica.params_mut());
                let pred = replica.forward(&unit.engine, &unit.graph);
                let (loss, dp) = mse(&pred, &unit.graph.y_cell);
                replica.backward(&unit.engine, &dp.scale(unit.weight));
                let grads = replica
                    .params_mut()
                    .iter_mut()
                    .map(|p| std::mem::replace(&mut p.grad, Matrix::zeros(0, 0)))
                    .collect();
                (grads, loss)
            });
        let mut loss = 0f64;
        let mut subgraph_losses = Vec::with_capacity(self.units.len());
        let mut grads: Option<Vec<Matrix>> = None;
        // Deterministic reduction: subgraph index order, whatever the
        // worker count or completion order was.
        for (i, (unit_grads, unit_loss)) in per_unit.into_iter().enumerate() {
            loss += self.units[i].weight as f64 * unit_loss as f64;
            subgraph_losses.push(unit_loss as f64);
            match &mut grads {
                None => grads = Some(unit_grads),
                Some(acc) => {
                    for (a, g) in acc.iter_mut().zip(&unit_grads) {
                        a.add_inplace(g);
                    }
                }
            }
        }
        FleetGradients { loss, subgraph_losses, grads: grads.unwrap_or_default() }
    }

    /// One fleet training step: compute the design gradient (concurrently,
    /// deterministically reduced) and apply one optimizer update.
    pub fn step(&self, model: &mut DrCircuitGnn, opt: &mut Adam) -> FleetStep {
        let FleetGradients { loss, subgraph_losses, grads } = self.gradients(model);
        let mut params = model.params_mut();
        assert_eq!(params.len(), grads.len(), "fleet gradient structure mismatch");
        for (p, g) in params.iter_mut().zip(grads) {
            p.grad = g;
        }
        opt.step(&mut params);
        Adam::zero_grad(&mut params);
        FleetStep { loss, subgraph_losses }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate_graph, GraphSpec};
    use crate::util::rng::Rng;

    fn test_graph(n_cells: usize, seed: u64) -> HeteroGraph {
        let mut rng = Rng::new(seed);
        generate_graph(
            &GraphSpec {
                n_cells,
                n_nets: n_cells / 2,
                target_near: n_cells * 8,
                target_pins: n_cells,
                d_cell: 6,
                d_net: 6,
            },
            0,
            &mut rng,
        )
    }

    #[test]
    fn build_shapes_and_weights() {
        let g = test_graph(120, 1);
        let fleet = Fleet::builder(EngineBuilder::dr(3, 3)).parts(4).workers(2).build(
            std::slice::from_ref(&g),
        );
        assert_eq!(fleet.n_subgraphs(), 4);
        assert_eq!(fleet.workers(), 2);
        // Requested workers lease against the ambient budget.
        crate::util::pool::Budget::new(1)
            .with(|| assert_eq!(fleet.effective_workers(), 1));
        crate::util::pool::Budget::new(16)
            .with(|| assert_eq!(fleet.effective_workers(), 2));
        let w: f32 = fleet.units.iter().map(|u| u.weight).sum();
        assert!((w - 1.0).abs() < 1e-6);
        let ids: Vec<usize> = fleet.subgraphs().map(|s| s.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn gradients_are_worker_count_invariant() {
        let g = test_graph(90, 2);
        let mut rng = Rng::new(7);
        let model = DrCircuitGnn::new(6, 6, 8, &mut rng);
        let builder = Fleet::builder(EngineBuilder::dr(3, 3)).parts(3);
        let reference = builder.clone().workers(1).build(std::slice::from_ref(&g));
        let base = reference.gradients(&model);
        for workers in [2, 5, 16] {
            let fleet = builder.clone().workers(workers).build(std::slice::from_ref(&g));
            let got = fleet.gradients(&model);
            assert_eq!(got.loss, base.loss, "workers={workers}");
            for (a, b) in got.grads.iter().zip(&base.grads) {
                assert_eq!(a.data, b.data, "workers={workers}");
            }
        }
    }

    #[test]
    fn step_descends_and_reports_per_subgraph_losses() {
        let g = test_graph(80, 3);
        let fleet =
            Fleet::builder(EngineBuilder::dr(4, 4)).parts(2).workers(2).build(
                std::slice::from_ref(&g),
            );
        let mut rng = Rng::new(5);
        let mut model = DrCircuitGnn::new(6, 6, 8, &mut rng);
        let mut opt = Adam::new(5e-3, 0.0);
        let first = fleet.step(&mut model, &mut opt);
        assert_eq!(first.subgraph_losses.len(), 2);
        let mut last = first.loss;
        for _ in 0..15 {
            last = fleet.step(&mut model, &mut opt).loss;
        }
        assert!(last < first.loss, "{} -> {last}", first.loss);
    }

    #[test]
    fn spec_round_trips_into_builder() {
        let b = FleetBuilder::new(EngineBuilder::csr())
            .spec(&FleetSpec::parse("4x2").unwrap());
        assert_eq!(b.workers, 4);
        assert_eq!(b.parts, Some(2));
        let b = b.spec(&FleetSpec::Off);
        assert_eq!(b.workers, 1);
        assert_eq!(b.parts, None);
    }
}
