//! Batched multi-subgraph execution (paper §3.4 at design scale).
//!
//! The paper's headline end-to-end numbers come from running a design's
//! *independent subgraphs* concurrently: multi-threaded CPU initialization
//! overlapped with per-stream kernel execution. PR 1's [`Engine`] is
//! strictly per-graph; this subsystem is the layer above it:
//!
//! * [`Fleet`] / [`FleetBuilder`] — one engine per subgraph of a design,
//!   built through a [`PlanCache`] keyed by adjacency content-hash so
//!   content-identical subgraphs plan once (Alg. 1 stage 1 deduplicated);
//! * [`Fleet::step`] — one training step over all subgraphs, split into
//!   two explicit stages: a pure-CPU **prepare** stage ([`Fleet::prepare`]
//!   → [`StagedDesign`]: feature staging; plan resolution happens at build
//!   through the cache) that reads *no* model or optimizer state, and an
//!   **execute** stage ([`Fleet::execute`]: SpMM lanes + backward on a
//!   bounded worker pool, **deterministic gradient reduction** in subgraph
//!   index order, optimizer update). Losses and gradients are
//!   bit-identical for every worker count (the `fleet(N) ≡ sequential`
//!   guarantee asserted in `tests/integration_fleet.rs` and
//!   `tests/proptests.rs`), and the stage split lets
//!   [`crate::sched::run_epoch_pipeline`] overlap design N+1's prepare
//!   with design N's execute without changing a bit (gated by
//!   `tests/integration_golden.rs`). Bit-exactness
//!   holds for kernels whose accumulation is scheduling-independent (csr,
//!   dr — each output row written by one thread); the GNNA analog's
//!   shared evil rows accumulate through atomic f32 adds whose order can
//!   vary, so its guarantee is within-tolerance, not bitwise;
//! * [`FleetSpec`] — the single parse point for `--fleet` / `fleet`
//!   settings, mirroring the engine's kernel registry;
//! * [`apply_eco`] — incremental ECO tracking: a [`crate::graph::DeltaPatch`]
//!   against an already-partitioned design restages only the partitions it
//!   touches, repairing cached plans instead of cold-building them (see
//!   [`eco`] and `docs/DELTA.md`).
//!
//! Inside each worker the §3.4 edge-level lanes still apply (the engine's
//! `parallel` flag, dispatched via [`crate::sched::run_lanes`]), giving the
//! graph-level × edge-level parallelism of Fig. 9b — but the levels
//! **share one thread budget** ([`crate::util::pool::Budget`]): `step`
//! leases `min(workers, budget)` shares, every worker's lanes and kernels
//! inherit that worker's share, so total live threads never exceed the
//! root budget however high `--fleet` is set. See `docs/FLEET.md`.

pub mod cache;
pub mod eco;
pub mod spec;

pub use cache::{CacheStats, Lookup, PlanCache};
pub use eco::{apply_eco, EcoOutcome, EcoReport, EcoSubgraph};
pub use spec::FleetSpec;

use crate::engine::{Engine, EngineBuilder};
use crate::graph::{partition_with_map, HeteroGraph};
use crate::nn::{mse, Adam, DrCircuitGnn};
use crate::sched::{pipeline_will_overlap, run_epoch_pipeline, PipelineRun, ScheduleMode};
use crate::tensor::Matrix;
use crate::util::pool::bounded_map;
use std::borrow::Cow;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

/// Monotone source of fleet identity stamps (see [`Fleet`] / the
/// [`StagedDesign`] mix-up check in [`Fleet::gradients_staged`]).
static FLEET_STAMP: AtomicU64 = AtomicU64::new(0);

/// Reusable fleet configuration: an engine configuration plus the fleet
/// shape (worker count, optional re-partitioning). One builder can `build`
/// a fleet per design of a dataset.
#[derive(Clone, Debug)]
pub struct FleetBuilder {
    engine: EngineBuilder,
    workers: usize,
    parts: Option<usize>,
}

impl FleetBuilder {
    pub fn new(engine: EngineBuilder) -> FleetBuilder {
        FleetBuilder { engine, workers: 1, parts: None }
    }

    /// Worker-pool width for per-subgraph steps. This is a *request*: the
    /// pool clamps it to the subgraph count and leases it against the
    /// ambient thread budget at run time (see [`Fleet::effective_workers`]).
    /// More workers than subgraphs or than the budget is fine. Results
    /// never depend on this.
    pub fn workers(mut self, workers: usize) -> FleetBuilder {
        self.workers = workers.max(1);
        self
    }

    /// Re-partition each input graph into `parts` independent subgraphs
    /// (cell-contiguous, stable remapping — see
    /// [`crate::graph::partition_with_map`]).
    pub fn parts(mut self, parts: usize) -> FleetBuilder {
        self.parts = Some(parts.max(1));
        self
    }

    /// Apply a parsed [`FleetSpec`] (the CLI/config surface).
    pub fn spec(mut self, spec: &FleetSpec) -> FleetBuilder {
        self.workers = spec.workers();
        self.parts = spec.parts();
        self
    }

    /// Build a fleet over a design's graphs: optionally re-partition, then
    /// resolve one engine per subgraph through a fresh plan cache.
    ///
    /// Without re-partitioning the fleet *borrows* the input graphs (no
    /// duplication of the dataset's adjacencies/features — a design-scale
    /// training run holds one copy); with `parts` set, the freshly cut
    /// subgraphs are owned and get fleet-wide ids.
    pub fn build<'a>(&self, graphs: &'a [HeteroGraph]) -> Fleet<'a> {
        let cache = PlanCache::new(self.engine.clone());
        self.build_with_cache(graphs, &cache)
    }

    /// [`FleetBuilder::build`] against a caller-owned, possibly *shared*
    /// [`PlanCache`]: content-identical subgraphs plan once **across
    /// designs**, not just within one. This is what the epoch-pipelined
    /// trainer and the serve loop use — every design's fleet resolves
    /// through one cache, so design N+1's prepare stage skips Alg. 1
    /// stage 1 for any adjacency an earlier design (or job) already
    /// planned. The cache is internally synchronized; concurrent builds
    /// through one cache are fine.
    ///
    /// The cache must have been created from the same engine configuration
    /// (`PlanCache::compatible_with`); a mismatch panics rather than
    /// serving engines planned under different kernels/K/schedule
    /// settings. `Fleet::cache_stats` reports only this build's lookups
    /// (tallied per lookup, not diffed from the global counters — exact
    /// even when other threads use the cache concurrently).
    pub fn build_with_cache<'a>(
        &self,
        graphs: &'a [HeteroGraph],
        cache: &PlanCache,
    ) -> Fleet<'a> {
        assert!(
            cache.compatible_with(&self.engine),
            "shared plan cache built from a different engine configuration"
        );
        let subgraphs: Vec<Cow<'a, HeteroGraph>> = match self.parts {
            None => graphs.iter().map(Cow::Borrowed).collect(),
            Some(p) => {
                let mut out: Vec<Cow<'a, HeteroGraph>> = Vec::new();
                for g in graphs {
                    for (mut sub, _) in partition_with_map(g, p) {
                        sub.id = out.len(); // fleet-wide ids, stable across builds
                        out.push(Cow::Owned(sub));
                    }
                }
                out
            }
        };
        self.finish(subgraphs, cache)
    }

    /// Build a fleet that **owns** its subgraphs (`Fleet<'static>`) — the
    /// window-sampling trainer's path. Each epoch cuts a fresh set of
    /// window subgraphs per design ([`crate::datagen::sample_windows`]), so
    /// the fleet cannot borrow from the dataset, and each build plans
    /// through its own fresh [`PlanCache`]: window adjacencies change every
    /// epoch, so a cache shared across epochs would only accumulate dead
    /// plans without ever hitting. `parts` is *not* applied — the windows
    /// already are the subgraphs (callers warn when a parts request is
    /// dropped).
    pub fn build_owned(&self, graphs: Vec<HeteroGraph>) -> Fleet<'static> {
        let cache = PlanCache::new(self.engine.clone());
        let subgraphs: Vec<Cow<'static, HeteroGraph>> =
            graphs.into_iter().map(Cow::Owned).collect();
        self.finish(subgraphs, &cache)
    }

    /// Shared tail of every build path: resolve one engine per subgraph
    /// through the cache and assemble the fleet.
    fn finish<'a>(&self, subgraphs: Vec<Cow<'a, HeteroGraph>>, cache: &PlanCache) -> Fleet<'a> {
        assert!(!subgraphs.is_empty(), "fleet needs at least one subgraph");
        let total_cells: usize = subgraphs.iter().map(|g| g.n_cells).sum();
        let mut cache_stats = CacheStats::default();
        let units = subgraphs
            .into_iter()
            .map(|g| {
                let (engine, lookup) = cache.engine_for_traced(&g);
                cache_stats.record(lookup);
                let weight = g.n_cells as f32 / total_cells.max(1) as f32;
                FleetUnit { graph: g, engine, weight }
            })
            .collect();
        Fleet {
            units,
            workers: self.workers,
            cache_stats,
            stamp: FLEET_STAMP.fetch_add(1, Ordering::Relaxed),
        }
    }
}

/// One subgraph with its (possibly shared) engine and its loss weight.
/// Borrowed for a design's native graphs, owned when freshly partitioned.
struct FleetUnit<'a> {
    graph: Cow<'a, HeteroGraph>,
    engine: Arc<Engine>,
    /// Cell share of the design: the fleet loss is the cell-count-weighted
    /// mean of per-subgraph MSEs, i.e. exactly the MSE over the union of
    /// all cells.
    weight: f32,
}

/// A design-bound fleet: every subgraph paired with a planned engine.
pub struct Fleet<'a> {
    units: Vec<FleetUnit<'a>>,
    workers: usize,
    cache_stats: CacheStats,
    /// Process-unique build identity: a [`StagedDesign`] carries the stamp
    /// of the fleet that prepared it, so executing it against a *different*
    /// fleet (even one with the same subgraph count) fails loudly instead
    /// of silently training on the wrong design's features.
    stamp: u64,
}

/// The fleet gradient of one model state: per-subgraph losses plus the
/// parameter gradients reduced in subgraph index order.
pub struct FleetGradients {
    /// Cell-weighted design loss (= MSE over all cells of the design).
    pub loss: f64,
    /// Unweighted per-subgraph MSE, in subgraph order.
    pub subgraph_losses: Vec<f64>,
    /// One gradient matrix per model parameter (the order of
    /// `DrCircuitGnn::params_mut`).
    pub grads: Vec<Matrix>,
}

/// Result of one [`Fleet::step`].
#[derive(Clone, Debug)]
pub struct FleetStep {
    pub loss: f64,
    pub subgraph_losses: Vec<f64>,
}

/// One subgraph's staged inputs: deep copies of the features and labels
/// the execute stage reads — the §3.4 host-side init (data loading /
/// memory allocation / transfer) made explicit. Copies are exact, so
/// executing on them is bit-identical to executing on the graph.
struct StagedUnit {
    x_cell: Matrix,
    x_net: Matrix,
    y_cell: Matrix,
}

/// The output of [`Fleet::prepare`]: everything CPU-side a step needs that
/// does **not** depend on the model or optimizer. Produced by the prepare
/// stage (possibly on another thread, overlapping an earlier design's
/// execute), consumed by [`Fleet::execute`].
///
/// The no-weight-reads invariant: building a `StagedDesign` touches only
/// dataset state (graphs, engines, plans) — never `DrCircuitGnn`
/// parameters or `Adam` state. D-ReLU row masks are *not* staged because
/// they are functions of the hidden activations, i.e. of the weights
/// (§3.1: D-ReLU is the model's activation); they are built inside
/// execute, which is exactly why overlapping design N+1's prepare with
/// design N's optimizer step cannot change a single bit.
pub struct StagedDesign {
    /// Stamp of the fleet that prepared this design (mix-up guard).
    stamp: u64,
    n_subgraphs: usize,
    /// `Some` = thread-decoupling deep copies ([`Fleet::prepare`], for the
    /// pipelined schedule where prepare runs on another thread); `None` =
    /// the zero-cost in-place handle ([`Fleet::prepare_in_place`], for
    /// same-thread schedules — execute reads the graphs directly). Both
    /// are bit-identical: the copies exist to decouple threads, not to
    /// change semantics.
    copies: Option<Vec<StagedUnit>>,
}

impl StagedDesign {
    pub fn n_subgraphs(&self) -> usize {
        self.n_subgraphs
    }

    /// Whether this design carries staged copies (vs the in-place handle).
    pub fn is_copied(&self) -> bool {
        self.copies.is_some()
    }
}

impl<'a> Fleet<'a> {
    /// Start configuring a fleet.
    pub fn builder(engine: EngineBuilder) -> FleetBuilder {
        FleetBuilder::new(engine)
    }

    pub fn n_subgraphs(&self) -> usize {
        self.units.len()
    }

    /// The *requested* worker-pool width.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The concurrency a `step`/`gradients` call gets right now: the
    /// requested width leased against the subgraph count and the caller's
    /// ambient thread budget ([`crate::util::pool::Budget::current`]).
    /// Purely informational (the pool re-leases on every call) — useful
    /// for logs and the fig13 sweep's budget-utilization column.
    pub fn effective_workers(&self) -> usize {
        let (conc, _) = crate::util::pool::Budget::current()
            .lease(self.workers.clamp(1, self.units.len().max(1)));
        conc
    }

    /// Plan-cache statistics of the build (`unique()` = engines planned).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache_stats
    }

    pub fn subgraphs(&self) -> impl Iterator<Item = &HeteroGraph> {
        self.units.iter().map(|u| u.graph.as_ref())
    }

    /// The engine driving a subgraph (shared between content-identical
    /// subgraphs).
    pub fn engine(&self, i: usize) -> &Arc<Engine> {
        &self.units[i].engine
    }

    /// **Prepare stage** of a step: stage every subgraph's inputs (deep
    /// feature/label copies — the §3.4 host-side init analog) on the
    /// bounded worker pool. Pure CPU work over dataset state only: no
    /// model parameter or optimizer state is read, so a `StagedDesign`
    /// for design N+1 can be built *while design N executes* (the epoch
    /// pipeline, [`crate::sched::run_epoch_pipeline`]) without changing
    /// any result bit. Plan resolution — the other weight-independent
    /// cost — happens at fleet build time through the [`PlanCache`];
    /// the epoch-pipelined trainer places that build inside the prepare
    /// stage too (lazy first-epoch builds against a shared cache).
    pub fn prepare(&self) -> StagedDesign {
        let units = bounded_map(self.units.len(), self.workers, |i| {
            let g = self.units[i].graph.as_ref();
            StagedUnit {
                x_cell: g.x_cell.clone(),
                x_net: g.x_net.clone(),
                y_cell: g.y_cell.clone(),
            }
        });
        StagedDesign { stamp: self.stamp, n_subgraphs: self.units.len(), copies: Some(units) }
    }

    /// Zero-cost staged handle for **same-thread** schedules: execute
    /// reads the graphs in place instead of copies. The sequential epoch
    /// schedule uses this — its prepare and execute share the caller, so
    /// there is no thread boundary for copies to decouple and staging
    /// would be pure overhead. Bit-identical to [`Fleet::prepare`].
    pub fn prepare_in_place(&self) -> StagedDesign {
        StagedDesign { stamp: self.stamp, n_subgraphs: self.units.len(), copies: None }
    }

    /// Compute the fleet gradient without applying an update. This is the
    /// *fused* path: producer and consumer are the same thread, so the
    /// inputs are read from the graphs in place — no staging copy is paid
    /// (the staged path exists for the epoch pipeline, where prepare runs
    /// on another thread; both are bit-identical because staged inputs are
    /// exact copies, asserted in `prepare_execute_split_matches_fused_step`).
    pub fn gradients(&self, model: &DrCircuitGnn) -> FleetGradients {
        self.gradients_impl(None, model)
    }

    /// Compute the fleet gradient over previously staged inputs.
    ///
    /// Each subgraph runs forward + backward on a model replica (engines
    /// and kernels are deterministic, so replicas on worker threads give
    /// bit-identical results to a sequential loop); gradients are then
    /// reduced in subgraph index order. The per-subgraph prediction
    /// gradient is scaled by the subgraph's cell share so the summed
    /// gradient is the gradient of the design-wide cell MSE.
    ///
    /// Threading: `bounded_map` leases the requested `workers` against the
    /// ambient thread budget and installs an equal share as each worker's
    /// ambient budget — the worker's edge lanes and kernel `parallel_for`
    /// calls subdivide that share, so `--fleet 8` on an 8-thread budget
    /// runs 8×1-thread workers, not 8×3×8 runnable threads. Budgets change
    /// scheduling only; gradients stay bit-identical.
    pub fn gradients_staged(
        &self,
        staged: &StagedDesign,
        model: &DrCircuitGnn,
    ) -> FleetGradients {
        assert_eq!(
            staged.stamp, self.stamp,
            "staged design was prepared by a different fleet"
        );
        self.gradients_impl(staged.copies.as_deref(), model)
    }

    /// The one gradient computation behind both input paths: staged copies
    /// (epoch pipeline) or the graphs in place (fused `step`/`gradients`
    /// and the in-place staged handle). Copies are exact, so the two paths
    /// are bit-identical.
    fn gradients_impl(
        &self,
        staged: Option<&[StagedUnit]>,
        model: &DrCircuitGnn,
    ) -> FleetGradients {
        let per_unit: Vec<(Vec<Matrix>, f32)> =
            bounded_map(self.units.len(), self.workers, |i| {
                let unit = &self.units[i];
                let (x_cell, x_net, y_cell) = match staged {
                    Some(units) => {
                        let su = &units[i];
                        (&su.x_cell, &su.x_net, &su.y_cell)
                    }
                    None => {
                        let g = unit.graph.as_ref();
                        (&g.x_cell, &g.x_net, &g.y_cell)
                    }
                };
                let mut replica = model.clone();
                // The clone carries the caller's accumulated grads; drop
                // them so the reduction sees this subgraph's alone.
                Adam::zero_grad(&mut replica.params_mut());
                let pred = replica.forward_on(&unit.engine, x_cell, x_net);
                let (loss, dp) = mse(&pred, y_cell);
                replica.backward(&unit.engine, &dp.scale(unit.weight));
                let grads = replica
                    .params_mut()
                    .iter_mut()
                    .map(|p| std::mem::replace(&mut p.grad, Matrix::zeros(0, 0)))
                    .collect();
                (grads, loss)
            });
        let mut loss = 0f64;
        let mut subgraph_losses = Vec::with_capacity(self.units.len());
        let mut grads: Option<Vec<Matrix>> = None;
        // Deterministic reduction: subgraph index order, whatever the
        // worker count or completion order was.
        for (i, (unit_grads, unit_loss)) in per_unit.into_iter().enumerate() {
            loss += self.units[i].weight as f64 * unit_loss as f64;
            subgraph_losses.push(unit_loss as f64);
            match &mut grads {
                None => grads = Some(unit_grads),
                Some(acc) => {
                    for (a, g) in acc.iter_mut().zip(&unit_grads) {
                        a.add_inplace(g);
                    }
                }
            }
        }
        FleetGradients { loss, subgraph_losses, grads: grads.unwrap_or_default() }
    }

    /// Apply one optimizer update from an already-reduced fleet gradient
    /// (the tail of the execute stage, split out so harnesses — the golden
    /// trace generator, the proptests — can observe the gradient between
    /// reduction and update).
    pub fn apply_update(
        &self,
        model: &mut DrCircuitGnn,
        opt: &mut Adam,
        gradients: FleetGradients,
    ) -> FleetStep {
        let FleetGradients { loss, subgraph_losses, grads } = gradients;
        let mut params = model.params_mut();
        assert_eq!(params.len(), grads.len(), "fleet gradient structure mismatch");
        for (p, g) in params.iter_mut().zip(grads) {
            p.grad = g;
        }
        opt.step(&mut params);
        Adam::zero_grad(&mut params);
        FleetStep { loss, subgraph_losses }
    }

    /// **Execute stage** of a step: forward + backward over the staged
    /// inputs (SpMM lanes, deterministic subgraph-index-order reduction)
    /// plus the optimizer update. This is the only stage that reads or
    /// writes model/optimizer state.
    pub fn execute(
        &self,
        staged: &StagedDesign,
        model: &mut DrCircuitGnn,
        opt: &mut Adam,
    ) -> FleetStep {
        let gradients = self.gradients_staged(staged, model);
        self.apply_update(model, opt, gradients)
    }

    /// One fleet training step — semantically [`Fleet::prepare`] then
    /// [`Fleet::execute`], fused: because both stages run on the caller,
    /// the staging copy is skipped and the inputs are read in place
    /// (bit-identical to the staged path — copies are exact; asserted in
    /// `prepare_execute_split_matches_fused_step`). The epoch pipeline
    /// runs the two stages explicitly with prepare shifted one design
    /// ahead; that is also bit-identical because prepare reads nothing
    /// execute writes.
    pub fn step(&self, model: &mut DrCircuitGnn, opt: &mut Adam) -> FleetStep {
        let gradients = self.gradients_impl(None, model);
        self.apply_update(model, opt, gradients)
    }
}

/// The one per-design epoch driver every epoch schedule goes through —
/// the trainer's fleet mode (serial *and* pipelined), the
/// `fig13_fleet` epoch sweep, the golden-trace harness, and the
/// pipeline proptests all run this exact layout, so a scheduler change
/// cannot drift between what ships and what the gates test.
///
/// One fleet per design, built **lazily inside the prepare stage** on the
/// design's first visit, through a single [`PlanCache`] shared across all
/// designs (content-identical subgraphs of different designs plan Alg. 1
/// stage 1 once). Epochs run through
/// [`crate::sched::run_epoch_pipeline`]:
///
/// * [`ScheduleMode::Sequential`] — the serial reference: prepare and
///   execute inline, in design order;
/// * [`ScheduleMode::Parallel`] — design N+1's prepare (lazy build +
///   feature staging) on a leased budget share while design N executes on
///   the caller.
///
/// `execute` always runs on the calling thread in design order, and
/// prepare reads no model/optimizer state, so both modes produce
/// bit-identical results (gated by `tests/integration_golden.rs`).
pub struct FleetPipeline<'a> {
    builder: FleetBuilder,
    designs: Vec<&'a [HeteroGraph]>,
    cache: Arc<PlanCache>,
    fleets: Vec<OnceLock<Fleet<'a>>>,
}

impl<'a> FleetPipeline<'a> {
    /// One fleet configuration over a list of designs (each a slice of
    /// subgraphs). Nothing is planned yet — builds happen lazily in the
    /// prepare stage of each design's first epoch.
    pub fn new(builder: FleetBuilder, designs: Vec<&'a [HeteroGraph]>) -> FleetPipeline<'a> {
        let cache = Arc::new(PlanCache::new(builder.engine.clone()));
        Self::with_cache(builder, designs, cache)
    }

    /// [`FleetPipeline::new`] over a caller-owned cache — possibly
    /// disk-backed ([`PlanCache::backed_by`]) and possibly shared with
    /// other pipelines or serve jobs running concurrently. The cache is
    /// internally synchronized; it must have been created from the same
    /// engine configuration (panics otherwise, like
    /// [`FleetBuilder::build_with_cache`]).
    pub fn with_cache(
        builder: FleetBuilder,
        designs: Vec<&'a [HeteroGraph]>,
        cache: Arc<PlanCache>,
    ) -> FleetPipeline<'a> {
        assert!(
            cache.compatible_with(&builder.engine),
            "shared plan cache built from a different engine configuration"
        );
        let fleets = designs.iter().map(|_| OnceLock::new()).collect();
        FleetPipeline { builder, designs, cache, fleets }
    }

    pub fn n_designs(&self) -> usize {
        self.designs.len()
    }

    /// The shared plan cache this pipeline resolves engines through.
    pub fn cache(&self) -> &Arc<PlanCache> {
        &self.cache
    }

    /// The (lazily built) fleet for a design, if its first prepare ran.
    pub fn fleet(&self, d: usize) -> Option<&Fleet<'a>> {
        self.fleets[d].get()
    }

    /// Force every per-design fleet build now (through the shared cache).
    /// The serial trainer calls this before its timed epoch loop so
    /// Alg. 1 stage 1 planning stays out of `train_seconds` (the same
    /// measurement boundary `train_dr` uses); the pipelined schedule
    /// skips it — overlapping epoch-0 planning with execution is part of
    /// what it buys and measures.
    pub fn build_all(&self) {
        for d in 0..self.designs.len() {
            self.fleets[d]
                .get_or_init(|| self.builder.build_with_cache(self.designs[d], &self.cache));
        }
    }

    /// Run one epoch under `mode`; `execute(d, fleet, staged)` is called
    /// on the calling thread, in design order.
    ///
    /// Feature copies ([`Fleet::prepare`]) are staged only when the
    /// pipeline will genuinely overlap — they exist to decouple the
    /// prepare worker from the executing caller. Whenever the schedule
    /// runs inline (sequential mode, a single design, or a 1-thread
    /// budget degenerating the parallel mode), execute gets the zero-cost
    /// in-place handle ([`Fleet::prepare_in_place`]) instead — same
    /// thread, nothing to decouple, no copy paid. Bit-identical either
    /// way.
    pub fn run_epoch<R, E>(&self, mode: ScheduleMode, mut execute: E) -> PipelineRun<R>
    where
        E: FnMut(usize, &Fleet<'a>, &StagedDesign) -> R,
    {
        let stage_copies = pipeline_will_overlap(self.designs.len(), mode);
        run_epoch_pipeline(
            self.designs.len(),
            mode,
            |d| {
                let fleet = self.fleets[d]
                    .get_or_init(|| self.builder.build_with_cache(self.designs[d], &self.cache));
                if stage_copies {
                    fleet.prepare()
                } else {
                    fleet.prepare_in_place()
                }
            },
            |d, staged| {
                let fleet = self.fleets[d].get().expect("prepared before execute");
                execute(d, fleet, &staged)
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate_graph, GraphSpec};
    use crate::util::rng::Rng;

    fn test_graph(n_cells: usize, seed: u64) -> HeteroGraph {
        let mut rng = Rng::new(seed);
        generate_graph(
            &GraphSpec {
                n_cells,
                n_nets: n_cells / 2,
                target_near: n_cells * 8,
                target_pins: n_cells,
                d_cell: 6,
                d_net: 6,
            },
            0,
            &mut rng,
        )
    }

    #[test]
    fn build_shapes_and_weights() {
        let g = test_graph(120, 1);
        let fleet = Fleet::builder(EngineBuilder::dr(3, 3)).parts(4).workers(2).build(
            std::slice::from_ref(&g),
        );
        assert_eq!(fleet.n_subgraphs(), 4);
        assert_eq!(fleet.workers(), 2);
        // Requested workers lease against the ambient budget.
        crate::util::pool::Budget::new(1)
            .with(|| assert_eq!(fleet.effective_workers(), 1));
        crate::util::pool::Budget::new(16)
            .with(|| assert_eq!(fleet.effective_workers(), 2));
        let w: f32 = fleet.units.iter().map(|u| u.weight).sum();
        assert!((w - 1.0).abs() < 1e-6);
        let ids: Vec<usize> = fleet.subgraphs().map(|s| s.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn gradients_are_worker_count_invariant() {
        let g = test_graph(90, 2);
        let mut rng = Rng::new(7);
        let model = DrCircuitGnn::new(6, 6, 8, &mut rng);
        let builder = Fleet::builder(EngineBuilder::dr(3, 3)).parts(3);
        let reference = builder.clone().workers(1).build(std::slice::from_ref(&g));
        let base = reference.gradients(&model);
        for workers in [2, 5, 16] {
            let fleet = builder.clone().workers(workers).build(std::slice::from_ref(&g));
            let got = fleet.gradients(&model);
            assert_eq!(got.loss, base.loss, "workers={workers}");
            for (a, b) in got.grads.iter().zip(&base.grads) {
                assert_eq!(a.data, b.data, "workers={workers}");
            }
        }
    }

    #[test]
    fn step_descends_and_reports_per_subgraph_losses() {
        let g = test_graph(80, 3);
        let fleet =
            Fleet::builder(EngineBuilder::dr(4, 4)).parts(2).workers(2).build(
                std::slice::from_ref(&g),
            );
        let mut rng = Rng::new(5);
        let mut model = DrCircuitGnn::new(6, 6, 8, &mut rng);
        let mut opt = Adam::new(5e-3, 0.0);
        let first = fleet.step(&mut model, &mut opt);
        assert_eq!(first.subgraph_losses.len(), 2);
        let mut last = first.loss;
        for _ in 0..15 {
            last = fleet.step(&mut model, &mut opt).loss;
        }
        assert!(last < first.loss, "{} -> {last}", first.loss);
    }

    /// An owned fleet over sampled window subgraphs (the window-training
    /// path) keeps the deterministic-reduction guarantee: gradients are
    /// bit-identical for any worker count.
    #[test]
    fn owned_window_fleet_is_worker_invariant() {
        let g = test_graph(120, 40);
        let mut windows = crate::datagen::sample_windows(&g, 3, 40, 7, 0);
        for (i, w) in windows.iter_mut().enumerate() {
            w.id = i;
        }
        let builder = Fleet::builder(EngineBuilder::dr(3, 3));
        let mut rng = Rng::new(3);
        let model = DrCircuitGnn::new(6, 6, 8, &mut rng);
        let reference = builder.clone().workers(1).build_owned(windows.clone());
        assert_eq!(reference.n_subgraphs(), 3);
        let base = reference.gradients(&model);
        for workers in [2, 5] {
            let fleet = builder.clone().workers(workers).build_owned(windows.clone());
            let got = fleet.gradients(&model);
            assert_eq!(got.loss, base.loss, "workers={workers}");
            for (a, b) in got.grads.iter().zip(&base.grads) {
                assert_eq!(a.data, b.data, "workers={workers}");
            }
        }
    }

    /// The stage split is behavior-preserving: running prepare and execute
    /// explicitly (as the epoch pipeline does) updates the model exactly
    /// like the fused `step`, and a staged design prepared *before* other
    /// steps mutate the model still executes identically — prepare holds
    /// no weight-derived state.
    #[test]
    fn prepare_execute_split_matches_fused_step() {
        let g = test_graph(100, 4);
        let fleet = Fleet::builder(EngineBuilder::dr(3, 3)).parts(3).workers(2).build(
            std::slice::from_ref(&g),
        );
        let mut rng = Rng::new(9);
        let model0 = DrCircuitGnn::new(6, 6, 8, &mut rng);

        let mut fused = model0.clone();
        let mut fused_opt = Adam::new(5e-3, 0.0);
        let fused_losses: Vec<f64> =
            (0..3).map(|_| fleet.step(&mut fused, &mut fused_opt).loss).collect();

        let mut staged_model = model0.clone();
        let mut staged_opt = Adam::new(5e-3, 0.0);
        // Stage once up front: the inputs are model-independent, so one
        // staging is valid for every subsequent execute.
        let staged = fleet.prepare();
        assert_eq!(staged.n_subgraphs(), 3);
        assert!(staged.is_copied());
        let staged_losses: Vec<f64> = (0..3)
            .map(|_| fleet.execute(&staged, &mut staged_model, &mut staged_opt).loss)
            .collect();
        assert_eq!(fused_losses, staged_losses);

        // The zero-cost in-place handle (the sequential schedule's staged
        // design) is a third bit-identical route to the same updates.
        let mut inplace_model = model0.clone();
        let mut inplace_opt = Adam::new(5e-3, 0.0);
        let handle = fleet.prepare_in_place();
        assert_eq!(handle.n_subgraphs(), 3);
        assert!(!handle.is_copied());
        let inplace_losses: Vec<f64> = (0..3)
            .map(|_| fleet.execute(&handle, &mut inplace_model, &mut inplace_opt).loss)
            .collect();
        assert_eq!(fused_losses, inplace_losses);
        let mut a = fused;
        let mut b = staged_model;
        for (pa, pb) in a.params_mut().iter().zip(b.params_mut().iter()) {
            assert_eq!(pa.value.data, pb.value.data);
        }
    }

    /// A staged design is bound to the fleet that prepared it: executing
    /// it against a different fleet — even one with the same subgraph
    /// count and shapes — must fail loudly, not train on wrong features.
    #[test]
    #[should_panic(expected = "prepared by a different fleet")]
    fn staged_design_rejects_foreign_fleet() {
        let g = test_graph(80, 21);
        let builder = Fleet::builder(EngineBuilder::dr(3, 3)).parts(2);
        let a = builder.build(std::slice::from_ref(&g));
        let b = builder.build(std::slice::from_ref(&g));
        let mut rng = Rng::new(1);
        let model = DrCircuitGnn::new(6, 6, 8, &mut rng);
        let staged = a.prepare();
        let _ = b.gradients_staged(&staged, &model);
    }

    /// Both FleetPipeline modes produce bit-identical losses and build
    /// each design's fleet exactly once (lazily, via the shared cache).
    #[test]
    fn fleet_pipeline_modes_are_bit_identical() {
        let g0 = test_graph(90, 30);
        let g1 = test_graph(110, 31);
        let designs = [vec![g0], vec![g1]];
        let mut rng = Rng::new(2);
        let model0 = DrCircuitGnn::new(6, 6, 8, &mut rng);
        let run = |mode: ScheduleMode| {
            let pipeline = FleetPipeline::new(
                Fleet::builder(EngineBuilder::dr(3, 3)).parts(2).workers(2),
                designs.iter().map(|gs| gs.as_slice()).collect(),
            );
            assert_eq!(pipeline.n_designs(), 2);
            assert!(pipeline.fleet(0).is_none(), "builds must be lazy");
            let mut model = model0.clone();
            let mut opt = Adam::new(5e-3, 0.0);
            let mut losses = Vec::new();
            for _ in 0..3 {
                let run = pipeline.run_epoch(mode, |_, fleet, staged| {
                    fleet.execute(staged, &mut model, &mut opt).loss
                });
                losses.extend(run.results);
            }
            assert_eq!(pipeline.fleet(0).unwrap().n_subgraphs(), 2);
            losses
        };
        let serial = run(ScheduleMode::Sequential);
        let piped = run(ScheduleMode::Parallel);
        assert_eq!(serial, piped);
    }

    #[test]
    fn shared_cache_dedupes_across_designs() {
        let g = test_graph(120, 6);
        let builder = Fleet::builder(EngineBuilder::dr(3, 3)).parts(2);
        let cache = PlanCache::new(EngineBuilder::dr(3, 3));
        // Two "designs" over the same graph: identical partitions, so the
        // second build must be all cache hits.
        let first = builder.build_with_cache(std::slice::from_ref(&g), &cache);
        let second = builder.build_with_cache(std::slice::from_ref(&g), &cache);
        assert_eq!(first.cache_stats().lookups(), 2);
        assert_eq!(second.cache_stats().misses, 0, "cross-design reuse");
        assert_eq!(second.cache_stats().hits, 2);
        for i in 0..second.n_subgraphs() {
            assert!(Arc::ptr_eq(first.engine(i), second.engine(i)));
        }
    }

    #[test]
    #[should_panic(expected = "different engine configuration")]
    fn shared_cache_rejects_mismatched_configuration() {
        let g = test_graph(60, 8);
        let cache = PlanCache::new(EngineBuilder::csr());
        let _ = Fleet::builder(EngineBuilder::dr(3, 3))
            .build_with_cache(std::slice::from_ref(&g), &cache);
    }

    #[test]
    fn spec_round_trips_into_builder() {
        let b = FleetBuilder::new(EngineBuilder::csr())
            .spec(&FleetSpec::parse("4x2").unwrap());
        assert_eq!(b.workers, 4);
        assert_eq!(b.parts, Some(2));
        let b = b.spec(&FleetSpec::Off);
        assert_eq!(b.workers, 1);
        assert_eq!(b.parts, None);
    }
}
