//! Incremental ECO tracking for a partitioned fleet (ISSUE 8 tentpole).
//!
//! An ECO (engineering change order) arrives as a [`DeltaPatch`] against a
//! *parent* design that has already been partitioned, planned, and trained
//! on. Rebuilding everything from scratch would repeat Alg. 1 stage 1 for
//! every partition; [`apply_eco`] instead routes the delta through the
//! partition maps ([`crate::graph::route_patch`]) and gives each partition
//! the cheapest treatment its classification allows:
//!
//! * **Untouched** — the old subgraph and map are kept as-is and the plan
//!   cache serves its existing engine (a [`Lookup::Hit`]);
//! * **Patch** — the localized delta is applied to the old subgraph and
//!   the cached engine is *repaired* incrementally
//!   ([`PlanCache::engine_for_patched`] →
//!   [`crate::engine::EngineBuilder::repair`]): untouched edge types keep
//!   their plans by pointer, touched ones splice only dirty rows/columns;
//! * **Restage** — the partition's net *set* changed, so its local net ids
//!   are no longer stable. The partition is re-cut from the patched parent
//!   ([`crate::graph::cut_partition`]) over its original cell range and
//!   planned cold. Only these partitions pay the full price.
//!
//! Stale plan-cache entries — the pre-patch adjacency hashes of patched
//! and restaged partitions — are evicted so the cache tracks the design
//! as it now exists. Untouched partitions' entries survive, which is the
//! cache-level statement of "restage only touched subgraphs".
//!
//! The output is guaranteed equivalent to re-partitioning the patched
//! parent from scratch: same subgraphs (bit-identical adjacencies,
//! features, labels), same maps. `benches/fig14_eco_delta.rs` measures
//! the speedup; `tests/integration_delta.rs` gates the equivalence.

use crate::engine::{Engine, RepairStats};
use crate::fleet::cache::{CacheStats, Lookup, PlanCache};
use crate::graph::{
    apply_delta, cut_partition, route_patch, DeltaPatch, HeteroGraph, PartitionMap, RoutedPatch,
};
use std::sync::Arc;

/// How each partition of an ECO was treated, plus the aggregate cost
/// evidence: cache lookups and incremental-repair statistics. A delta
/// replan shows up here as `untouched + patched ≫ restaged` with
/// `repair.plans_reused` high and `cache.misses` equal to what the
/// restaged partitions alone require.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EcoReport {
    /// Partitions the delta never touched (kept verbatim, cache hit).
    pub untouched: usize,
    /// Partitions updated in place via a localized patch + plan repair.
    pub patched: usize,
    /// Partitions re-cut from the patched parent and planned cold.
    pub restaged: usize,
    /// Pre-patch plan-cache hashes invalidated (≤ patched + restaged —
    /// a patch that leaves the adjacency hash unchanged evicts nothing).
    pub evicted: usize,
    /// Near ops dropped by the router because their endpoints live in
    /// different partitions (cross-partition near edges don't exist in
    /// any subgraph — see [`crate::graph::RoutedDelta::dropped_near`]).
    pub dropped_near: usize,
    /// Aggregate incremental-repair statistics over all patched
    /// partitions (plans reused by pointer vs repaired vs rebuilt,
    /// dirty-row/column splice counts).
    pub repair: RepairStats,
    /// Plan-cache lookups this ECO performed, tallied locally (exact even
    /// when other threads share the cache).
    pub cache: CacheStats,
}

impl EcoReport {
    /// One-line summary for logs.
    pub fn describe(&self) -> String {
        format!(
            "eco: {} untouched / {} patched / {} restaged partition(s), {} cache \
             entr{} evicted; {}",
            self.untouched,
            self.patched,
            self.restaged,
            self.evicted,
            if self.evicted == 1 { "y" } else { "ies" },
            self.repair.describe(),
        )
    }
}

/// One post-ECO partition: the (possibly new) subgraph, its parent
/// mapping, the engine serving it, and how the plan cache satisfied the
/// lookup.
pub struct EcoSubgraph {
    pub graph: HeteroGraph,
    pub map: PartitionMap,
    pub engine: Arc<Engine>,
    pub lookup: Lookup,
}

/// The result of [`apply_eco`]: the patched parent (the new baseline for
/// the *next* ECO), every partition brought up to date, and the cost
/// evidence.
pub struct EcoOutcome {
    pub parent: HeteroGraph,
    pub subgraphs: Vec<EcoSubgraph>,
    pub report: EcoReport,
}

/// Apply an ECO to a partitioned design incrementally. `parent` is the
/// pre-patch design, `subs` its current partitions with their maps (as
/// produced by [`crate::graph::partition_with_map`], in partition order),
/// `patch` the ECO in parent coordinates, and `cache` the plan cache the
/// fleet resolves engines through (ideally already warm with the
/// pre-patch engines — a cold cache still works, the patched partitions
/// just fall back to cold builds instead of repairs).
///
/// Errors if the patch doesn't apply to the parent (or a routed local
/// patch doesn't apply to its partition — impossible for correctly
/// routed patches, reported rather than unwrapped anyway). On error
/// nothing is evicted and no state has changed.
pub fn apply_eco(
    parent: &HeteroGraph,
    subs: &[(HeteroGraph, PartitionMap)],
    patch: &DeltaPatch,
    cache: &PlanCache,
) -> Result<EcoOutcome, String> {
    let patched_parent = apply_delta(parent, patch)?;
    let maps: Vec<PartitionMap> = subs.iter().map(|(_, m)| m.clone()).collect();
    let routed = route_patch(parent, patch, &maps);
    debug_assert_eq!(routed.parts.len(), subs.len());

    let mut report = EcoReport { dropped_near: routed.dropped_near, ..EcoReport::default() };
    let mut subgraphs = Vec::with_capacity(subs.len());
    for (i, routing) in routed.parts.iter().enumerate() {
        let (old_sub, old_map) = &subs[i];
        let sub = match routing {
            RoutedPatch::Untouched => {
                report.untouched += 1;
                let (engine, lookup) = cache.engine_for_traced(old_sub);
                report.cache.record(lookup);
                EcoSubgraph { graph: old_sub.clone(), map: old_map.clone(), engine, lookup }
            }
            RoutedPatch::Patch(local) => {
                report.patched += 1;
                let graph = local.apply(old_sub).map_err(|e| {
                    format!("routed patch failed on partition {i} ({}): {e}", local.describe())
                })?;
                if graph.adjacency_hash() != old_sub.adjacency_hash() {
                    report.evicted += 1; // engine_for_patched evicts it
                }
                let (engine, lookup, stats) = cache.engine_for_patched(old_sub, &graph, local);
                report.cache.record(lookup);
                if let Some(stats) = stats {
                    report.repair = report.repair.plus(&stats);
                }
                // The net set is stable by construction (that's what the
                // router's restage rule protects), so the old map still
                // describes the patched subgraph.
                EcoSubgraph { graph, map: old_map.clone(), engine, lookup }
            }
            RoutedPatch::Restage => {
                report.restaged += 1;
                // The cell range is stable (range partitioning); only the
                // net-id side of the map went stale. Re-cut exactly this
                // range from the patched parent, keeping the fleet id.
                let lo = old_map.cell_ids[0];
                let hi = lo + old_map.cell_ids.len();
                let (graph, map) = cut_partition(&patched_parent, lo, hi, old_sub.id);
                let old_hash = old_sub.adjacency_hash();
                if graph.adjacency_hash() != old_hash {
                    cache.evict(old_hash);
                    report.evicted += 1;
                }
                let (engine, lookup) = cache.engine_for_traced(&graph);
                report.cache.record(lookup);
                EcoSubgraph { graph, map, engine, lookup }
            }
        };
        subgraphs.push(sub);
    }
    Ok(EcoOutcome { parent: patched_parent, subgraphs, report })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineBuilder;
    use crate::graph::{partition_with_map, Csr, EdgeType};
    use crate::tensor::Matrix;

    /// The same shape as partition.rs's routing fixture: 6 cells / 4 nets,
    /// cut into two partitions of 3 cells. Net 0 pins {0,1}, net 1 pins
    /// {2,3} (spans both partitions), net 2 pins {4,5}, net 3 pins {1}.
    fn fixture() -> HeteroGraph {
        let near = Csr::from_triplets(
            6,
            6,
            &[
                (0, 1, 1.0),
                (1, 0, 1.0),
                (1, 2, 1.0),
                (2, 1, 1.0),
                (2, 3, 1.0),
                (3, 2, 1.0),
                (3, 4, 1.0),
                (4, 3, 1.0),
            ],
        );
        let pins = Csr::from_triplets(
            4,
            6,
            &[
                (0, 0, 1.0),
                (0, 1, 1.0),
                (1, 2, 1.0),
                (1, 3, 1.0),
                (2, 4, 1.0),
                (2, 5, 1.0),
                (3, 1, 1.0),
            ],
        );
        let pinned = pins.transpose();
        HeteroGraph {
            id: 7,
            n_cells: 6,
            n_nets: 4,
            near,
            pins,
            pinned,
            x_cell: Matrix::from_fn(6, 3, |r, c| (r * 3 + c) as f32),
            x_net: Matrix::from_fn(4, 3, |r, c| 0.5 + (r * 3 + c) as f32),
            y_cell: Matrix::from_fn(6, 1, |r, _| r as f32),
        }
    }

    fn assert_matches_full_repartition(outcome: &EcoOutcome, parts: usize) {
        let fresh = partition_with_map(&outcome.parent, parts);
        assert_eq!(outcome.subgraphs.len(), fresh.len());
        for (got, (want, want_map)) in outcome.subgraphs.iter().zip(&fresh) {
            assert_eq!(got.graph.adjacency_hash(), want.adjacency_hash());
            assert_eq!(got.graph.x_cell.data, want.x_cell.data);
            assert_eq!(got.graph.x_net.data, want.x_net.data);
            assert_eq!(got.graph.y_cell.data, want.y_cell.data);
            assert_eq!(got.map.cell_ids, want_map.cell_ids);
            assert_eq!(got.map.net_ids, want_map.net_ids);
        }
    }

    #[test]
    fn eco_patches_only_the_touched_partition() {
        let parent = fixture();
        let subs = partition_with_map(&parent, 2);
        let cache = PlanCache::new(EngineBuilder::dr(2, 2));
        let warm: Vec<_> = subs.iter().map(|(g, _)| cache.engine_for(g)).collect();

        // A symmetric near edge inside partition 1 (cells 3..6).
        let patch = DeltaPatch::new()
            .add_edge(EdgeType::Near, 3, 5, 0.5)
            .add_edge(EdgeType::Near, 5, 3, 0.5);
        let outcome = apply_eco(&parent, &subs, &patch, &cache).unwrap();

        let r = &outcome.report;
        assert_eq!((r.untouched, r.patched, r.restaged), (1, 1, 0), "{}", r.describe());
        assert_eq!(r.evicted, 1);
        assert_eq!(r.dropped_near, 0);
        // Untouched partition: same engine object, served as a hit.
        assert_eq!(outcome.subgraphs[0].lookup, Lookup::Hit);
        assert!(Arc::ptr_eq(&outcome.subgraphs[0].engine, &warm[0]));
        // Patched partition: repaired, not cold-built. Only near changed,
        // so the pins/pinned plans are reused by pointer.
        assert_eq!(outcome.subgraphs[1].lookup, Lookup::Repaired { stored: false });
        assert_eq!(r.repair.plans_reused, 2, "{}", r.repair.describe());
        assert_eq!(r.repair.plans_repaired, 1);
        assert_eq!(r.repair.plans_rebuilt, 0);
        assert!(Arc::ptr_eq(
            outcome.subgraphs[1].engine.plan_shared(EdgeType::Pins),
            warm[1].plan_shared(EdgeType::Pins)
        ));
        assert_eq!(r.cache, CacheStats { hits: 1, repairs: 1, ..CacheStats::default() });
        // The old hash is gone from the cache, the new one serves hits.
        assert!(cache.peek(subs[1].0.adjacency_hash()).is_none());
        assert!(cache.peek(outcome.subgraphs[1].graph.adjacency_hash()).is_some());

        assert_matches_full_repartition(&outcome, 2);
    }

    #[test]
    fn eco_restages_partitions_whose_net_set_changes() {
        let parent = fixture();
        let subs = partition_with_map(&parent, 2);
        let cache = PlanCache::new(EngineBuilder::dr(2, 2));
        for (g, _) in &subs {
            cache.engine_for(g);
        }

        // Net 3 currently pins only cell 1 (partition 0). Pinning cell 4
        // introduces it to partition 1 → partition 1's local net ids
        // shift → restage. Partition 0's pin set is untouched.
        let patch = DeltaPatch::new().add_edge(EdgeType::Pins, 3, 4, 1.0);
        let outcome = apply_eco(&parent, &subs, &patch, &cache).unwrap();

        let r = &outcome.report;
        assert_eq!((r.untouched, r.patched, r.restaged), (1, 0, 1), "{}", r.describe());
        assert_eq!(r.evicted, 1);
        assert_eq!(outcome.subgraphs[0].lookup, Lookup::Hit);
        // Restaged partition is planned cold (a miss), never repaired.
        assert_eq!(outcome.subgraphs[1].lookup, Lookup::Built { stored: false });
        assert_eq!(r.repair, RepairStats::default());
        assert_eq!(outcome.subgraphs[1].graph.n_nets, 3, "net 3 joined partition 1");
        assert!(cache.peek(subs[1].0.adjacency_hash()).is_none(), "stale entry evicted");

        assert_matches_full_repartition(&outcome, 2);
    }

    #[test]
    fn identity_eco_is_all_hits_and_evicts_nothing() {
        let parent = fixture();
        let subs = partition_with_map(&parent, 2);
        let cache = PlanCache::new(EngineBuilder::csr());
        let warm: Vec<_> = subs.iter().map(|(g, _)| cache.engine_for(g)).collect();

        let outcome = apply_eco(&parent, &subs, &DeltaPatch::new(), &cache).unwrap();
        let r = &outcome.report;
        assert_eq!((r.untouched, r.patched, r.restaged, r.evicted), (2, 0, 0, 0));
        assert_eq!(outcome.parent.adjacency_hash(), parent.adjacency_hash());
        for (i, sub) in outcome.subgraphs.iter().enumerate() {
            assert_eq!(sub.lookup, Lookup::Hit);
            assert!(Arc::ptr_eq(&sub.engine, &warm[i]));
        }
        assert_matches_full_repartition(&outcome, 2);
    }

    #[test]
    fn bad_patch_reports_instead_of_panicking() {
        let parent = fixture();
        let subs = partition_with_map(&parent, 2);
        let cache = PlanCache::new(EngineBuilder::csr());
        // Edge already present in the parent → apply fails up front.
        let patch = DeltaPatch::new().add_edge(EdgeType::Near, 0, 1, 1.0);
        let err = apply_eco(&parent, &subs, &patch, &cache).unwrap_err();
        assert!(err.contains("already exists"), "{err}");
        assert_eq!(cache.stats(), CacheStats::default(), "error path touched the cache");
    }
}
