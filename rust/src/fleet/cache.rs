//! Shared plan cache: adjacency content-hash → planned [`Engine`].
//!
//! `EngineBuilder::build` pays Alg. 1 stage 1 (normalisation, CSC
//! transposition, kernel schedules) per graph. Real designs repeat
//! structure — evenly partitioned CircuitNet designs produce many
//! content-identical subgraphs — so the fleet keys engines by
//! [`HeteroGraph::adjacency_hash`] and plans each *unique* adjacency
//! exactly once; content-identical subgraphs share one `Arc<Engine>`.
//! Features and labels are not part of the key because plans depend only
//! on the adjacency. Any mutation of an edge, weight or shape changes the
//! hash and therefore misses the cache (verified in
//! `tests/integration_fleet.rs` via `engine::plan_counters`).

use crate::engine::{Engine, EngineBuilder};
use crate::graph::HeteroGraph;
use std::collections::HashMap;
use std::sync::Arc;

/// Hit/miss counters of a [`PlanCache`]; `misses` equals the number of
/// unique adjacencies planned.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: usize,
    pub misses: usize,
}

impl CacheStats {
    /// Unique engines built (one per distinct adjacency).
    pub fn unique(&self) -> usize {
        self.misses
    }

    pub fn lookups(&self) -> usize {
        self.hits + self.misses
    }

    /// Lookups recorded after the `earlier` snapshot (counters are
    /// monotone). Lets a fleet built through a *shared* cache report its
    /// own hits/misses rather than the cache's lifetime totals.
    pub fn since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats { hits: self.hits - earlier.hits, misses: self.misses - earlier.misses }
    }
}

/// Content-addressed engine cache used while building a fleet.
pub struct PlanCache {
    builder: EngineBuilder,
    entries: HashMap<u64, Arc<Engine>>,
    stats: CacheStats,
}

impl PlanCache {
    pub fn new(builder: EngineBuilder) -> PlanCache {
        PlanCache { builder, entries: HashMap::new(), stats: CacheStats::default() }
    }

    /// The engine for a subgraph: cached when a content-identical adjacency
    /// was already planned, freshly planned (and cached) otherwise.
    pub fn engine_for(&mut self, g: &HeteroGraph) -> Arc<Engine> {
        let key = g.adjacency_hash();
        if let Some(engine) = self.entries.get(&key) {
            self.stats.hits += 1;
            return Arc::clone(engine);
        }
        self.stats.misses += 1;
        let engine = Arc::new(self.builder.build(g));
        self.entries.insert(key, Arc::clone(&engine));
        engine
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Diagnostic signature of the builder this cache plans with (the
    /// full configuration, Debug-rendered).
    pub fn signature(&self) -> String {
        format!("{:?}", self.builder)
    }

    /// Whether this cache was created from (a clone of) `builder`.
    ///
    /// Cached engines embed the builder's kernel choices, K values and
    /// schedule mode, so a cache shared across designs (the epoch
    /// pipeline's prepare stage) must only serve fleets built from the
    /// same configuration — `FleetBuilder::build_with_cache` checks this
    /// and panics on a mismatch instead of silently handing out engines
    /// planned under different settings. Structural equality, no
    /// allocation.
    pub fn compatible_with(&self, builder: &EngineBuilder) -> bool {
        self.builder == *builder
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::partition::partition;
    use crate::graph::Csr;
    use crate::tensor::Matrix;

    fn toy(seed_val: f32) -> HeteroGraph {
        let near = Csr::from_triplets(
            4,
            4,
            &[(0, 1, 1.0), (1, 0, 1.0), (2, 3, 1.0), (3, 2, 1.0)],
        );
        let pins =
            Csr::from_triplets(2, 4, &[(0, 0, 1.0), (0, 1, 1.0), (1, 2, 1.0), (1, 3, 1.0)]);
        let pinned = pins.transpose();
        HeteroGraph {
            id: 0,
            n_cells: 4,
            n_nets: 2,
            near,
            pins,
            pinned,
            x_cell: Matrix::from_fn(4, 3, |r, c| seed_val + (r * 3 + c) as f32),
            x_net: Matrix::ones(2, 3),
            y_cell: Matrix::zeros(4, 1),
        }
    }

    #[test]
    fn identical_adjacencies_share_one_engine() {
        let mut cache = PlanCache::new(EngineBuilder::dr(2, 2));
        let a = toy(0.0);
        let b = toy(5.0); // different features, same adjacency
        let ea = cache.engine_for(&a);
        let eb = cache.engine_for(&b);
        assert!(Arc::ptr_eq(&ea, &eb));
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1 });
        assert_eq!(cache.stats().unique(), 1);
    }

    #[test]
    fn mutated_adjacency_misses() {
        let mut cache = PlanCache::new(EngineBuilder::csr());
        let a = toy(0.0);
        let mut b = toy(0.0);
        b.near.values[0] = 0.5;
        let ea = cache.engine_for(&a);
        let eb = cache.engine_for(&b);
        assert!(!Arc::ptr_eq(&ea, &eb));
        assert_eq!(cache.stats().unique(), 2);
    }

    #[test]
    fn stats_since_and_signature() {
        let mut cache = PlanCache::new(EngineBuilder::dr(2, 2));
        let a = toy(0.0);
        cache.engine_for(&a);
        let snap = cache.stats();
        cache.engine_for(&a); // hit
        let mut b = toy(0.0);
        b.near.values[0] = 0.25; // miss
        cache.engine_for(&b);
        assert_eq!(cache.stats().since(&snap), CacheStats { hits: 1, misses: 1 });
        // Compatibility separates configurations, not instances.
        assert!(cache.compatible_with(&EngineBuilder::dr(2, 2)));
        assert!(!cache.compatible_with(&EngineBuilder::csr()));
        assert!(!cache.compatible_with(&EngineBuilder::dr(2, 3)), "K is part of the config");
        assert_eq!(cache.signature(), PlanCache::new(EngineBuilder::dr(2, 2)).signature());
    }

    #[test]
    fn symmetric_partition_halves_plan_work() {
        // toy()'s two halves are content-identical after partitioning, so a
        // 2-way split plans once.
        let g = toy(0.0);
        let subs = partition(&g, 2);
        assert_eq!(subs.len(), 2);
        assert_eq!(subs[0].adjacency_hash(), subs[1].adjacency_hash());
        let mut cache = PlanCache::new(EngineBuilder::dr(2, 2));
        for s in &subs {
            cache.engine_for(s);
        }
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1 });
    }
}
