//! Shared plan cache: adjacency content-hash → planned [`Engine`].
//!
//! `EngineBuilder::build` pays Alg. 1 stage 1 (normalisation, CSC
//! transposition, kernel schedules) per graph. Real designs repeat
//! structure — evenly partitioned CircuitNet designs produce many
//! content-identical subgraphs — so the fleet keys engines by
//! [`HeteroGraph::adjacency_hash`] and plans each *unique* adjacency
//! exactly once; content-identical subgraphs share one `Arc<Engine>`.
//! Features and labels are not part of the key because plans depend only
//! on the adjacency. Any mutation of an edge, weight or shape changes the
//! hash and therefore misses the cache (verified in
//! `tests/integration_fleet.rs` via `engine::plan_counters`).
//!
//! The cache is **internally synchronized**: `engine_for(&self)` takes a
//! shared reference, so serve workers and
//! [`FleetPipeline`](crate::fleet::FleetPipeline) share one
//! `Arc<PlanCache>` without an external mutex. The entry map holds one
//! `OnceLock` cell per adjacency hash — distinct adjacencies plan
//! concurrently, racing requests for the same adjacency coalesce onto a
//! single build.
//!
//! With [`PlanCache::backed_by`], misses first consult a persistent
//! [`PlanStore`]: hash-matching plans load from disk (zero plan builds)
//! and freshly planned engines are written back, so a later process
//! warm-starts Alg. 1 stage 1 for free. Corrupted or stale files are
//! logged loudly and rebuilt cold — never silently trusted.

use crate::engine::{Engine, EngineBuilder, PlanStore};
use crate::graph::HeteroGraph;
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Lookup counters of a [`PlanCache`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from memory.
    pub hits: usize,
    /// Lookups that built a fresh plan (cold).
    pub misses: usize,
    /// Lookups served by deserializing a stored plan (warm, zero builds).
    pub disk_loads: usize,
    /// Freshly built plans persisted to the backing store.
    pub disk_stores: usize,
    /// Lookups served by incrementally repairing a cached engine from an
    /// ECO delta ([`PlanCache::engine_for_patched`]) — no cold build.
    pub repairs: usize,
}

impl CacheStats {
    /// Unique engines materialised (one per distinct adjacency), whether
    /// built cold, loaded from the store, or repaired from a predecessor.
    pub fn unique(&self) -> usize {
        self.misses + self.disk_loads + self.repairs
    }

    pub fn lookups(&self) -> usize {
        self.hits + self.misses + self.disk_loads + self.repairs
    }

    /// Lookups recorded after the `earlier` snapshot (counters are
    /// monotone). Lets a fleet built through a *shared* cache report its
    /// own hits/misses rather than the cache's lifetime totals.
    pub fn since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            disk_loads: self.disk_loads - earlier.disk_loads,
            disk_stores: self.disk_stores - earlier.disk_stores,
            repairs: self.repairs - earlier.repairs,
        }
    }

    /// Sum of two deltas (aggregating per-fleet or per-job stats).
    pub fn plus(&self, other: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits + other.hits,
            misses: self.misses + other.misses,
            disk_loads: self.disk_loads + other.disk_loads,
            disk_stores: self.disk_stores + other.disk_stores,
            repairs: self.repairs + other.repairs,
        }
    }

    /// Fold one traced lookup into a local tally. Concurrent users of a
    /// shared cache count their own lookups this way instead of diffing
    /// the global stats, which would attribute other threads' traffic.
    pub fn record(&mut self, lookup: Lookup) {
        match lookup {
            Lookup::Hit => self.hits += 1,
            Lookup::Loaded => self.disk_loads += 1,
            Lookup::Built { stored } => {
                self.misses += 1;
                if stored {
                    self.disk_stores += 1;
                }
            }
            Lookup::Repaired { stored } => {
                self.repairs += 1;
                if stored {
                    self.disk_stores += 1;
                }
            }
        }
    }
}

/// How one [`PlanCache::engine_for_traced`] lookup was satisfied.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lookup {
    /// Served from memory.
    Hit,
    /// Deserialized from the backing store (zero plan builds).
    Loaded,
    /// Built cold; `stored` says whether it was persisted to the store.
    Built { stored: bool },
    /// Incrementally repaired from the cached pre-patch engine
    /// ([`PlanCache::engine_for_patched`]) — zero cold plan builds;
    /// `stored` says whether the repaired plan was persisted.
    Repaired { stored: bool },
}

/// Content-addressed engine cache used while building fleets and serving
/// jobs. Internally synchronized — share it as `Arc<PlanCache>`.
pub struct PlanCache {
    builder: EngineBuilder,
    store: Option<PlanStore>,
    entries: Mutex<HashMap<u64, Arc<OnceLock<(Arc<Engine>, Lookup)>>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    disk_loads: AtomicUsize,
    disk_stores: AtomicUsize,
    repairs: AtomicUsize,
}

impl PlanCache {
    pub fn new(builder: EngineBuilder) -> PlanCache {
        PlanCache {
            builder,
            store: None,
            entries: Mutex::new(HashMap::new()),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            disk_loads: AtomicUsize::new(0),
            disk_stores: AtomicUsize::new(0),
            repairs: AtomicUsize::new(0),
        }
    }

    /// A cache whose misses read from / write to a persistent [`PlanStore`]
    /// at `dir` (created if absent). Stored plans are keyed by adjacency
    /// hash plus the builder's configuration signature, so one directory
    /// can back many configurations.
    pub fn backed_by(builder: EngineBuilder, dir: &Path) -> Result<PlanCache, String> {
        let store = PlanStore::open(dir, &builder)?;
        let mut cache = PlanCache::new(builder);
        cache.store = Some(store);
        Ok(cache)
    }

    /// The backing store, when this cache was created with
    /// [`backed_by`](Self::backed_by).
    pub fn store(&self) -> Option<&PlanStore> {
        self.store.as_ref()
    }

    /// The engine for a subgraph: cached when a content-identical adjacency
    /// was already materialised, loaded from the backing store when a
    /// hash-matching plan is on disk, freshly planned (and persisted)
    /// otherwise.
    pub fn engine_for(&self, g: &HeteroGraph) -> Arc<Engine> {
        self.engine_for_traced(g).0
    }

    /// [`engine_for`](Self::engine_for) plus how this lookup was satisfied.
    pub fn engine_for_traced(&self, g: &HeteroGraph) -> (Arc<Engine>, Lookup) {
        let key = g.adjacency_hash();
        let cell = {
            let mut map = self.entries.lock().unwrap_or_else(|e| e.into_inner());
            Arc::clone(map.entry(key).or_default())
        };
        // Materialise outside the map lock: distinct adjacencies plan in
        // parallel; racing requests for the same one coalesce on the cell.
        let mut initialized_here = false;
        let (engine, first_lookup) = cell.get_or_init(|| {
            initialized_here = true;
            self.materialise(g)
        });
        let lookup = if initialized_here {
            *first_lookup
        } else {
            self.hits.fetch_add(1, Ordering::Relaxed);
            Lookup::Hit
        };
        (Arc::clone(engine), lookup)
    }

    /// Load-or-build on a confirmed in-memory miss, updating the global
    /// counters for the outcome.
    fn materialise(&self, g: &HeteroGraph) -> (Arc<Engine>, Lookup) {
        if let Some(store) = &self.store {
            // The effective builder applies a measured §4.3 K profile when
            // one is stored — identically for loads and cold builds, so
            // warm and cold runs stay bit-identical.
            let eff = store.effective_builder(&self.builder, g);
            match store.load(g, &eff) {
                Ok(Some(engine)) => {
                    self.disk_loads.fetch_add(1, Ordering::Relaxed);
                    return (Arc::new(engine), Lookup::Loaded);
                }
                Ok(None) => {}
                Err(e) => crate::warn!("{e}; rebuilding cold"),
            }
            self.misses.fetch_add(1, Ordering::Relaxed);
            let engine = Arc::new(eff.build(g));
            let stored = match store.store(g, &engine) {
                Ok(_) => {
                    self.disk_stores.fetch_add(1, Ordering::Relaxed);
                    true
                }
                Err(e) => {
                    crate::warn!("{e}; plan stays in-memory only");
                    false
                }
            };
            (engine, Lookup::Built { stored })
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            (Arc::new(self.builder.build(g)), Lookup::Built { stored: false })
        }
    }

    /// An already-materialised engine for an adjacency hash, without
    /// triggering a build. The ECO path uses this to find the pre-patch
    /// engine worth repairing.
    pub fn peek(&self, hash: u64) -> Option<Arc<Engine>> {
        let map = self.entries.lock().unwrap_or_else(|e| e.into_inner());
        map.get(&hash).and_then(|cell| cell.get()).map(|(e, _)| Arc::clone(e))
    }

    /// Drop the cache entry for an adjacency hash (the ECO path evicts
    /// exactly the hashes a delta invalidated — untouched entries stay).
    /// Engines already handed out stay alive through their `Arc`s; a later
    /// lookup for the same hash re-materialises. Returns whether an entry
    /// was present.
    pub fn evict(&self, hash: u64) -> bool {
        let mut map = self.entries.lock().unwrap_or_else(|e| e.into_inner());
        map.remove(&hash).is_some()
    }

    /// The engine for a *patched* subgraph, repairing the cached pre-patch
    /// engine incrementally when possible (see
    /// [`EngineBuilder::repair`](crate::engine::repair)) instead of
    /// cold-building. `old_g`/`new_g` are the pre-/post-patch graphs and
    /// `patch` the delta between them. The pre-patch hash is evicted —
    /// that adjacency no longer exists in the design. Falls back to
    /// [`engine_for_traced`](Self::engine_for_traced) when the pre-patch
    /// engine isn't cached (never materialises the old graph just to
    /// repair it). Returns the repair stats when a repair happened.
    pub fn engine_for_patched(
        &self,
        old_g: &HeteroGraph,
        new_g: &HeteroGraph,
        patch: &crate::graph::DeltaPatch,
    ) -> (Arc<Engine>, Lookup, Option<crate::engine::RepairStats>) {
        let old_key = old_g.adjacency_hash();
        let new_key = new_g.adjacency_hash();
        let old_engine = self.peek(old_key);
        let cell = {
            let mut map = self.entries.lock().unwrap_or_else(|e| e.into_inner());
            Arc::clone(map.entry(new_key).or_default())
        };
        let mut initialized_here = false;
        let mut repair_stats = None;
        let (engine, first_lookup) = cell.get_or_init(|| {
            initialized_here = true;
            let Some(prev) = &old_engine else {
                return self.materialise(new_g);
            };
            // Same effective-builder rule as cold materialisation: a
            // stored §4.3 K profile applies to repairs too, so repaired
            // and cold engines stay bit-identical.
            let eff = match &self.store {
                Some(store) => store.effective_builder(&self.builder, new_g),
                None => self.builder.clone(),
            };
            let (engine, stats) = eff.repair(prev, new_g, patch);
            repair_stats = Some(stats);
            self.repairs.fetch_add(1, Ordering::Relaxed);
            let engine = Arc::new(engine);
            let stored = match &self.store {
                Some(store) => match store.store(new_g, &engine) {
                    Ok(_) => {
                        self.disk_stores.fetch_add(1, Ordering::Relaxed);
                        true
                    }
                    Err(e) => {
                        crate::warn!("{e}; repaired plan stays in-memory only");
                        false
                    }
                },
                None => false,
            };
            (engine, Lookup::Repaired { stored })
        });
        let lookup = if initialized_here {
            *first_lookup
        } else {
            self.hits.fetch_add(1, Ordering::Relaxed);
            Lookup::Hit
        };
        if old_key != new_key {
            self.evict(old_key);
        }
        (Arc::clone(engine), lookup, repair_stats)
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            disk_loads: self.disk_loads.load(Ordering::Relaxed),
            disk_stores: self.disk_stores.load(Ordering::Relaxed),
            repairs: self.repairs.load(Ordering::Relaxed),
        }
    }

    /// Configuration signature of the builder this cache plans with — the
    /// explicit versioned [`EngineBuilder::signature`], the same key the
    /// on-disk [`PlanStore`] files are named by.
    pub fn signature(&self) -> String {
        self.builder.signature()
    }

    /// The configuration this cache plans with.
    pub fn builder(&self) -> &EngineBuilder {
        &self.builder
    }

    /// Whether this cache was created from (a clone of) `builder`.
    ///
    /// Cached engines embed the builder's kernel choices, K values and
    /// schedule mode, so a cache shared across designs (the epoch
    /// pipeline's prepare stage, the serve loop) must only serve fleets
    /// built from the same configuration — `FleetBuilder::build_with_cache`
    /// checks this and panics on a mismatch instead of silently handing
    /// out engines planned under different settings. Structural equality,
    /// no allocation.
    pub fn compatible_with(&self, builder: &EngineBuilder) -> bool {
        self.builder == *builder
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::partition::partition;
    use crate::graph::Csr;
    use crate::tensor::Matrix;
    use std::path::PathBuf;

    fn toy(seed_val: f32) -> HeteroGraph {
        let near = Csr::from_triplets(
            4,
            4,
            &[(0, 1, 1.0), (1, 0, 1.0), (2, 3, 1.0), (3, 2, 1.0)],
        );
        let pins =
            Csr::from_triplets(2, 4, &[(0, 0, 1.0), (0, 1, 1.0), (1, 2, 1.0), (1, 3, 1.0)]);
        let pinned = pins.transpose();
        HeteroGraph {
            id: 0,
            n_cells: 4,
            n_nets: 2,
            near,
            pins,
            pinned,
            x_cell: Matrix::from_fn(4, 3, |r, c| seed_val + (r * 3 + c) as f32),
            x_net: Matrix::ones(2, 3),
            y_cell: Matrix::zeros(4, 1),
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("drcg-cache-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn identical_adjacencies_share_one_engine() {
        let cache = PlanCache::new(EngineBuilder::dr(2, 2));
        let a = toy(0.0);
        let b = toy(5.0); // different features, same adjacency
        let (ea, la) = cache.engine_for_traced(&a);
        let (eb, lb) = cache.engine_for_traced(&b);
        assert!(Arc::ptr_eq(&ea, &eb));
        assert_eq!(la, Lookup::Built { stored: false });
        assert_eq!(lb, Lookup::Hit);
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1, ..Default::default() });
        assert_eq!(cache.stats().unique(), 1);
    }

    #[test]
    fn mutated_adjacency_misses() {
        let cache = PlanCache::new(EngineBuilder::csr());
        let a = toy(0.0);
        let mut b = toy(0.0);
        b.near.values[0] = 0.5;
        let ea = cache.engine_for(&a);
        let eb = cache.engine_for(&b);
        assert!(!Arc::ptr_eq(&ea, &eb));
        assert_eq!(cache.stats().unique(), 2);
    }

    #[test]
    fn stats_since_and_signature() {
        let cache = PlanCache::new(EngineBuilder::dr(2, 2));
        let a = toy(0.0);
        cache.engine_for(&a);
        let snap = cache.stats();
        cache.engine_for(&a); // hit
        let mut b = toy(0.0);
        b.near.values[0] = 0.25; // miss
        cache.engine_for(&b);
        assert_eq!(
            cache.stats().since(&snap),
            CacheStats { hits: 1, misses: 1, ..Default::default() }
        );
        // Compatibility separates configurations, not instances.
        assert!(cache.compatible_with(&EngineBuilder::dr(2, 2)));
        assert!(!cache.compatible_with(&EngineBuilder::csr()));
        assert!(!cache.compatible_with(&EngineBuilder::dr(2, 3)), "K is part of the config");
        assert_eq!(cache.signature(), PlanCache::new(EngineBuilder::dr(2, 2)).signature());
    }

    #[test]
    fn symmetric_partition_halves_plan_work() {
        // toy()'s two halves are content-identical after partitioning, so a
        // 2-way split plans once.
        let g = toy(0.0);
        let subs = partition(&g, 2);
        assert_eq!(subs.len(), 2);
        assert_eq!(subs[0].adjacency_hash(), subs[1].adjacency_hash());
        let cache = PlanCache::new(EngineBuilder::dr(2, 2));
        for s in &subs {
            cache.engine_for(s);
        }
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1, ..Default::default() });
    }

    #[test]
    fn shared_reference_works_across_threads() {
        let cache = Arc::new(PlanCache::new(EngineBuilder::dr(2, 2)));
        let g = toy(0.0);
        let engines: Vec<_> = std::thread::scope(|s| {
            (0..4)
                .map(|_| {
                    let cache = Arc::clone(&cache);
                    let g = g.clone();
                    s.spawn(move || cache.engine_for(&g))
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        // Racing lookups coalesce: one build, everyone shares the result.
        assert!(engines.windows(2).all(|w| Arc::ptr_eq(&w[0], &w[1])));
        let stats = cache.stats();
        assert_eq!(stats.misses, 1);
        assert_eq!(stats.hits, 3);
    }

    #[test]
    fn backed_cache_stores_then_loads() {
        let dir = tmp_dir("warm");
        let g = toy(0.0);
        let builder = EngineBuilder::dr(2, 2);

        let cold = PlanCache::backed_by(builder.clone(), &dir).unwrap();
        let (_, lookup) = cold.engine_for_traced(&g);
        assert_eq!(lookup, Lookup::Built { stored: true });
        assert_eq!(
            cold.stats(),
            CacheStats { misses: 1, disk_stores: 1, ..Default::default() }
        );

        // A fresh cache over the same directory warm-starts: disk load,
        // zero cold builds.
        let warm = PlanCache::backed_by(builder, &dir).unwrap();
        let (_, lookup) = warm.engine_for_traced(&g);
        assert_eq!(lookup, Lookup::Loaded);
        assert_eq!(warm.stats(), CacheStats { disk_loads: 1, ..Default::default() });
        assert_eq!(warm.stats().unique(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn record_tallies_lookups() {
        let mut local = CacheStats::default();
        local.record(Lookup::Hit);
        local.record(Lookup::Loaded);
        local.record(Lookup::Built { stored: true });
        local.record(Lookup::Built { stored: false });
        local.record(Lookup::Repaired { stored: true });
        assert_eq!(
            local,
            CacheStats { hits: 1, misses: 2, disk_loads: 1, disk_stores: 2, repairs: 1 }
        );
        assert_eq!(local.plus(&local).lookups(), 10);
    }

    #[test]
    fn peek_and_evict() {
        let cache = PlanCache::new(EngineBuilder::csr());
        let g = toy(0.0);
        let key = g.adjacency_hash();
        assert!(cache.peek(key).is_none());
        assert!(!cache.evict(key));
        let e = cache.engine_for(&g);
        let peeked = cache.peek(key).expect("materialised entry is peekable");
        assert!(Arc::ptr_eq(&e, &peeked));
        assert!(cache.evict(key));
        assert!(cache.peek(key).is_none());
        // Re-lookup after eviction is a fresh miss, not a poisoned entry.
        let e2 = cache.engine_for(&g);
        assert!(!Arc::ptr_eq(&e, &e2));
        assert_eq!(cache.stats().misses, 2);
    }

    #[test]
    fn patched_lookup_repairs_instead_of_cold_building() {
        use crate::engine::plan_counters;
        use crate::graph::{DeltaPatch, EdgeType};
        let cache = PlanCache::new(EngineBuilder::dr(2, 2));
        let g = toy(0.0);
        let patch = DeltaPatch::new().add_edge(EdgeType::Near, 0, 2, 0.5);
        let patched = patch.apply(&g).unwrap();

        // Pre-patch engine not cached yet → falls back to a cold build.
        let (_, lookup, stats) = cache.engine_for_patched(&g, &patched, &patch);
        assert_eq!(lookup, Lookup::Built { stored: false });
        assert!(stats.is_none());
        cache.evict(patched.adjacency_hash());

        // With the pre-patch engine cached, the lookup repairs.
        let old_engine = cache.engine_for(&g);
        let before = plan_counters();
        let (repaired, lookup, stats) = cache.engine_for_patched(&g, &patched, &patch);
        assert_eq!(lookup, Lookup::Repaired { stored: false });
        let stats = stats.expect("repair stats on a repaired lookup");
        assert_eq!(stats.plans_reused, 2, "pins/pinned untouched: {stats:?}");
        assert_eq!(stats.plans_repaired, 1);
        let during = plan_counters().since(&before);
        assert!(during.repairs >= 1, "{during:?}");
        // The old hash was evicted, the new hash serves hits.
        assert!(cache.peek(g.adjacency_hash()).is_none());
        let (again, lookup2, _) = cache.engine_for_patched(&g, &patched, &patch);
        assert_eq!(lookup2, Lookup::Hit);
        assert!(Arc::ptr_eq(&repaired, &again));
        // Repaired ≡ cold-built, bitwise, for the near plan that changed.
        let cold = EngineBuilder::dr(2, 2).build(&patched);
        assert_eq!(repaired.plan(EdgeType::Near).adj, cold.plan(EdgeType::Near).adj);
        assert_eq!(
            repaired.plan(EdgeType::Near).csc.indices,
            cold.plan(EdgeType::Near).csc.indices
        );
        // Untouched plans are shared with the pre-patch engine by pointer.
        assert!(Arc::ptr_eq(
            repaired.plan_shared(EdgeType::Pins),
            old_engine.plan_shared(EdgeType::Pins)
        ));
        assert_eq!(cache.stats().repairs, 1);
    }
}
