//! Fleet settings — the single place fleet strings are interpreted.
//!
//! Every surface that accepts a fleet setting (the `--fleet` CLI flag, the
//! `fleet` config key, bench environment knobs) parses through
//! [`FleetSpec::parse`], mirroring how kernel names go through the engine
//! registry's `KernelSpec::parse`: one grammar, one error message, listed
//! in one place.

/// A parsed fleet selection.
///
/// Grammar (case-insensitive):
/// * `off` (also `0`, `none`) — fleet mode disabled;
/// * `<workers>` — fleet mode over the design's native subgraphs, with at
///   most `workers` concurrent per-subgraph steps;
/// * `<workers>x<parts>` — additionally re-partition each input graph into
///   `parts` independent subgraphs first.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FleetSpec {
    /// Per-graph training (the PR-1 path).
    Off,
    /// Fleet training: concurrent per-subgraph steps with deterministic
    /// gradient reduction.
    On {
        /// Worker-pool width (≥ 1). A request, not a thread grant: at run
        /// time the pool leases it against the root thread budget
        /// (`--threads` / `DRCG_THREADS`, see [`crate::util::pool::Budget`]),
        /// so oversized values cannot oversubscribe the machine. Results
        /// are worker-count invariant either way.
        workers: usize,
        /// Optional re-partitioning of each input graph.
        parts: Option<usize>,
    },
}

impl FleetSpec {
    /// Parse a fleet setting. This is the only parse point in the crate.
    pub fn parse(s: &str) -> Result<FleetSpec, String> {
        let t = s.trim().to_ascii_lowercase();
        if t == "off" || t == "none" || t == "0" {
            return Ok(FleetSpec::Off);
        }
        let bad = || {
            format!("invalid fleet spec '{s}' (expected: off | <workers> | <workers>x<parts>)")
        };
        let (w, p) = match t.split_once('x') {
            None => (t.as_str(), None),
            Some((w, p)) => (w, Some(p)),
        };
        let workers: usize = w.trim().parse().map_err(|_| bad())?;
        if workers == 0 {
            return Err(bad());
        }
        let parts = match p {
            None => None,
            Some(p) => {
                let parts: usize = p.trim().parse().map_err(|_| bad())?;
                if parts == 0 {
                    return Err(bad());
                }
                Some(parts)
            }
        };
        Ok(FleetSpec::On { workers, parts })
    }

    pub fn is_on(&self) -> bool {
        matches!(self, FleetSpec::On { .. })
    }

    /// Worker-pool width (1 when off).
    pub fn workers(&self) -> usize {
        match self {
            FleetSpec::Off => 1,
            FleetSpec::On { workers, .. } => *workers,
        }
    }

    /// Re-partition factor, if any.
    pub fn parts(&self) -> Option<usize> {
        match self {
            FleetSpec::Off => None,
            FleetSpec::On { parts, .. } => *parts,
        }
    }

    /// One-line description for logs and tables.
    pub fn describe(&self) -> String {
        match self {
            FleetSpec::Off => "off".to_string(),
            FleetSpec::On { workers, parts: None } => format!("{workers} workers"),
            FleetSpec::On { workers, parts: Some(p) } => {
                format!("{workers} workers × {p} parts/graph")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_grammar() {
        assert_eq!(FleetSpec::parse("off").unwrap(), FleetSpec::Off);
        assert_eq!(FleetSpec::parse("none").unwrap(), FleetSpec::Off);
        assert_eq!(FleetSpec::parse("0").unwrap(), FleetSpec::Off);
        assert_eq!(
            FleetSpec::parse("4").unwrap(),
            FleetSpec::On { workers: 4, parts: None }
        );
        assert_eq!(
            FleetSpec::parse(" 4x2 ").unwrap(),
            FleetSpec::On { workers: 4, parts: Some(2) }
        );
        assert_eq!(
            FleetSpec::parse("8X3").unwrap(),
            FleetSpec::On { workers: 8, parts: Some(3) }
        );
    }

    #[test]
    fn parse_rejects_junk_with_grammar() {
        for bad in ["", "x", "4x", "x2", "4x0", "0x2", "-1", "fast", "4x2x1"] {
            let err = FleetSpec::parse(bad).unwrap_err();
            assert!(err.contains("<workers>"), "{bad}: {err}");
        }
    }

    #[test]
    fn accessors_and_describe() {
        assert!(!FleetSpec::Off.is_on());
        assert_eq!(FleetSpec::Off.workers(), 1);
        assert_eq!(FleetSpec::Off.describe(), "off");
        let on = FleetSpec::parse("4x2").unwrap();
        assert!(on.is_on());
        assert_eq!(on.workers(), 4);
        assert_eq!(on.parts(), Some(2));
        assert!(on.describe().contains("4 workers"));
    }
}
