//! Fleet settings — the single place fleet strings are interpreted.
//!
//! Every surface that accepts a fleet setting (the `--fleet` CLI flag, the
//! `fleet` config key, bench environment knobs) parses through
//! [`FleetSpec::parse`], mirroring how kernel names go through the engine
//! registry's `KernelSpec::parse`: one grammar, one error message, listed
//! in one place.

/// A parsed fleet selection.
///
/// Grammar (case-insensitive):
/// * `off` (also `0`, `none`) — fleet mode disabled;
/// * `<workers>` — fleet mode over the design's native subgraphs, with at
///   most `workers` concurrent per-subgraph steps;
/// * `<workers>x<parts>` — additionally re-partition each input graph into
///   `parts` independent subgraphs first.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FleetSpec {
    /// Per-graph training (the PR-1 path).
    Off,
    /// Fleet training: concurrent per-subgraph steps with deterministic
    /// gradient reduction.
    On {
        /// Worker-pool width (≥ 1). A request, not a thread grant: at run
        /// time the pool leases it against the root thread budget
        /// (`--threads` / `DRCG_THREADS`, see [`crate::util::pool::Budget`]),
        /// so oversized values cannot oversubscribe the machine. Results
        /// are worker-count invariant either way.
        workers: usize,
        /// Optional re-partitioning of each input graph.
        parts: Option<usize>,
    },
}

impl FleetSpec {
    /// Parse a fleet setting. This is the only parse point in the crate.
    pub fn parse(s: &str) -> Result<FleetSpec, String> {
        let t = s.trim().to_ascii_lowercase();
        if t == "off" || t == "none" || t == "0" {
            return Ok(FleetSpec::Off);
        }
        let bad = || {
            format!("invalid fleet spec '{s}' (expected: off | <workers> | <workers>x<parts>)")
        };
        let (w, p) = match t.split_once('x') {
            None => (t.as_str(), None),
            Some((w, p)) => (w, Some(p)),
        };
        let workers: usize = w.trim().parse().map_err(|_| bad())?;
        if workers == 0 {
            return Err(bad());
        }
        let parts = match p {
            None => None,
            Some(p) => {
                let parts: usize = p.trim().parse().map_err(|_| bad())?;
                if parts == 0 {
                    return Err(bad());
                }
                Some(parts)
            }
        };
        Ok(FleetSpec::On { workers, parts })
    }

    pub fn is_on(&self) -> bool {
        matches!(self, FleetSpec::On { .. })
    }

    /// Worker-pool width (1 when off).
    pub fn workers(&self) -> usize {
        match self {
            FleetSpec::Off => 1,
            FleetSpec::On { workers, .. } => *workers,
        }
    }

    /// Re-partition factor, if any.
    pub fn parts(&self) -> Option<usize> {
        match self {
            FleetSpec::Off => None,
            FleetSpec::On { parts, .. } => *parts,
        }
    }

    /// The partition count a graph of `n_cells` cells *actually* gets
    /// under this spec. A `<workers>x<parts>` request is capped by the
    /// cell count: `partition_with_map` cannot cut more cell-contiguous
    /// partitions than there are cells, and it warns loudly when it has
    /// to truncate (see [`crate::graph::partition_with_map`]). Sweeps and
    /// logs should report this, not the requested number — fig13/fig14
    /// emit both so a config can't silently lie about its shape.
    pub fn effective_parts(&self, n_cells: usize) -> usize {
        match self.parts() {
            None => 1,
            Some(parts) => {
                if n_cells == 0 {
                    return 0;
                }
                // Mirrors the partitioner: ranges of ceil(n_cells/parts)
                // cells, empty trailing ranges dropped.
                let per = n_cells.div_ceil(parts);
                n_cells.div_ceil(per)
            }
        }
    }

    /// One-line description for logs and tables.
    pub fn describe(&self) -> String {
        match self {
            FleetSpec::Off => "off".to_string(),
            FleetSpec::On { workers, parts: None } => format!("{workers} workers"),
            FleetSpec::On { workers, parts: Some(p) } => {
                format!("{workers} workers × {p} parts/graph")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_grammar() {
        assert_eq!(FleetSpec::parse("off").unwrap(), FleetSpec::Off);
        assert_eq!(FleetSpec::parse("none").unwrap(), FleetSpec::Off);
        assert_eq!(FleetSpec::parse("0").unwrap(), FleetSpec::Off);
        assert_eq!(
            FleetSpec::parse("4").unwrap(),
            FleetSpec::On { workers: 4, parts: None }
        );
        assert_eq!(
            FleetSpec::parse(" 4x2 ").unwrap(),
            FleetSpec::On { workers: 4, parts: Some(2) }
        );
        assert_eq!(
            FleetSpec::parse("8X3").unwrap(),
            FleetSpec::On { workers: 8, parts: Some(3) }
        );
    }

    #[test]
    fn parse_rejects_junk_with_grammar() {
        for bad in ["", "x", "4x", "x2", "4x0", "0x2", "-1", "fast", "4x2x1"] {
            let err = FleetSpec::parse(bad).unwrap_err();
            assert!(err.contains("<workers>"), "{bad}: {err}");
        }
    }

    /// `effective_parts` must agree with what the partitioner produces.
    #[test]
    fn effective_parts_matches_the_partitioner() {
        use crate::datagen::{generate_graph, GraphSpec};
        use crate::graph::partition_with_map;
        use crate::util::rng::Rng;
        let g = generate_graph(
            &GraphSpec {
                n_cells: 13,
                n_nets: 6,
                target_near: 40,
                target_pins: 13,
                d_cell: 3,
                d_net: 3,
            },
            0,
            &mut Rng::new(1),
        );
        for parts in [1usize, 2, 3, 5, 13, 20, 100] {
            let spec = FleetSpec::On { workers: 1, parts: Some(parts) };
            assert_eq!(
                spec.effective_parts(g.n_cells),
                partition_with_map(&g, parts).len(),
                "parts={parts}"
            );
        }
        assert_eq!(FleetSpec::Off.effective_parts(13), 1);
        assert_eq!(FleetSpec::On { workers: 2, parts: None }.effective_parts(13), 1);
        assert_eq!(FleetSpec::On { workers: 2, parts: Some(4) }.effective_parts(0), 0);
    }

    #[test]
    fn accessors_and_describe() {
        assert!(!FleetSpec::Off.is_on());
        assert_eq!(FleetSpec::Off.workers(), 1);
        assert_eq!(FleetSpec::Off.describe(), "off");
        let on = FleetSpec::parse("4x2").unwrap();
        assert!(on.is_on());
        assert_eq!(on.workers(), 4);
        assert_eq!(on.parts(), Some(2));
        assert!(on.describe().contains("4 workers"));
    }
}
