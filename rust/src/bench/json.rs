//! Machine-readable bench artifacts (serde is unavailable offline).
//!
//! Benches print ASCII tables for humans; this module writes the same
//! numbers as `BENCH_<name>.json` so plotting and regression scripts can
//! consume them without scraping tables. The value model is the minimal
//! JSON subset the benches need — objects, arrays, strings, numbers,
//! booleans — with deterministic key order (insertion order) so reruns of
//! a deterministic bench produce byte-identical files.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// A JSON value. Build with the `From` impls and [`Json::obj`] /
/// [`Json::arr`]; render with [`Json::render`].
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered, so output is deterministic.
    Obj(Vec<(String, Json)>),
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

impl Json {
    /// Empty object; chain [`Json::set`].
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    /// Insert (or replace) a key. Panics on non-objects — a bench bug.
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(entries) => {
                let value = value.into();
                if let Some(e) = entries.iter_mut().find(|(k, _)| k == key) {
                    e.1 = value;
                } else {
                    entries.push((key.to_string(), value));
                }
                self
            }
            other => panic!("Json::set on non-object {other:?}"),
        }
    }

    /// Render as compact JSON. Non-finite numbers become `null` (JSON has
    /// no NaN/Inf); integral floats print without a fraction so counts
    /// stay readable.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    out.push_str("null");
                } else if *x == x.trunc() && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(entries) => {
                out.push('{');
                for (i, (k, v)) in entries.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Where bench JSON lands: `$DRCG_BENCH_JSON_DIR` if set, else the
/// current directory.
pub fn bench_json_dir() -> PathBuf {
    std::env::var_os("DRCG_BENCH_JSON_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("."))
}

/// Write `value` as `BENCH_<name>.json` under [`bench_json_dir`] and
/// report where it went. Failures warn but don't kill the bench — the
/// table already printed.
pub fn write_bench_json(name: &str, value: &Json) -> Option<PathBuf> {
    let path = bench_json_dir().join(format!("BENCH_{name}.json"));
    write_bench_json_to(&path, value)
}

fn write_bench_json_to(path: &Path, value: &Json) -> Option<PathBuf> {
    let mut text = value.render();
    text.push('\n');
    match std::fs::write(path, text) {
        Ok(()) => {
            println!("bench json: {}", path.display());
            Some(path.to_path_buf())
        }
        Err(e) => {
            crate::warn!("bench json write to {} failed: {e}", path.display());
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_the_subset_compactly() {
        let j = Json::obj()
            .set("name", "fig12")
            .set("reps", 5usize)
            .set("ok", true)
            .set("median", 0.25)
            .set("series", vec![1.0, 2.5])
            .set("none", Json::Null);
        assert_eq!(
            j.render(),
            r#"{"name":"fig12","reps":5,"ok":true,"median":0.25,"series":[1,2.5],"none":null}"#
        );
    }

    #[test]
    fn escapes_strings_and_maps_nonfinite_to_null() {
        let j = Json::arr(vec![
            Json::from("a\"b\\c\nd"),
            Json::Num(f64::NAN),
            Json::Num(f64::INFINITY),
        ]);
        assert_eq!(j.render(), r#"["a\"b\\c\nd",null,null]"#);
    }

    #[test]
    fn set_replaces_existing_keys_in_place() {
        let j = Json::obj().set("k", 1usize).set("other", 2usize).set("k", 3usize);
        assert_eq!(j.render(), r#"{"k":3,"other":2}"#);
    }

    #[test]
    fn writes_a_bench_file() {
        let dir = std::env::temp_dir()
            .join(format!("drcg-json-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_demo.json");
        let j = Json::obj().set("x", 1usize);
        let written = write_bench_json_to(&path, &j).unwrap();
        assert_eq!(std::fs::read_to_string(written).unwrap(), "{\"x\":1}\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
