//! Shared bench workloads.
//!
//! The kernel and e2e benches all run over the three Table-1 designs. At
//! full scale a single `near` matrix holds ~0.5M nnz; benches default to a
//! configurable scale (env `DRCG_BENCH_SCALE`, default 0.25) so the whole
//! suite completes in minutes while preserving the degree distributions
//! that drive the results. Set `DRCG_BENCH_SCALE=1.0` for paper-scale runs.

use crate::datagen::{generate_design, table1_designs, DesignSpec};
use crate::graph::HeteroGraph;
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// Bench scale factor.
pub fn bench_scale() -> f64 {
    std::env::var("DRCG_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .filter(|&s| s > 0.0 && s <= 1.0)
        .unwrap_or(0.15)
}

/// Repetitions for timed sections (env `DRCG_BENCH_REPS`, default 5).
pub fn bench_reps() -> usize {
    std::env::var("DRCG_BENCH_REPS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&r| r > 0)
        .unwrap_or(3)
}

/// All graphs of the three representative designs: (design name, graphs).
pub fn table1_graphs(scale: f64) -> Vec<(String, Vec<HeteroGraph>)> {
    table1_designs(scale)
        .into_iter()
        .map(|spec: DesignSpec| {
            let name = spec.name.clone();
            (name, generate_design(&spec))
        })
        .collect()
}

/// Random dense embedding for a node count.
pub fn embedding(n: usize, dim: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::randn(n, dim, 1.0, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_env_bounds() {
        // default path (env var not set in tests)
        let s = bench_scale();
        assert!(s > 0.0 && s <= 1.0);
        assert!(bench_reps() >= 1);
    }

    #[test]
    fn table1_graphs_generate_at_tiny_scale() {
        let designs = table1_graphs(0.01);
        assert_eq!(designs.len(), 3);
        assert_eq!(designs[0].1.len(), 2);
        assert_eq!(designs[1].1.len(), 3);
        assert_eq!(designs[2].1.len(), 4);
        for (_, graphs) in &designs {
            for g in graphs {
                g.validate().unwrap();
            }
        }
    }

    #[test]
    fn embedding_deterministic() {
        let a = embedding(10, 4, 1);
        let b = embedding(10, 4, 1);
        assert_eq!(a.data, b.data);
    }
}
