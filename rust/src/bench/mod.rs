//! Benchmark harness (criterion is unavailable offline).
//!
//! * [`measure`] — warmup + repeated timing with robust statistics.
//! * [`Table`] — aligned ASCII table printer for the paper-figure benches.
//! * [`json`] — machine-readable `BENCH_<name>.json` artifacts next to the
//!   tables (`DRCG_BENCH_JSON_DIR` overrides the destination).
//! * [`workloads`] — shared workload builders (the three Table-1 designs at
//!   a bench-friendly scale, plus embedding/gradient generators).

pub mod json;
pub mod workloads;

pub use json::{write_bench_json, Json};

use crate::util::timer::TimingStats;

/// Measure a closure: `warmup` unrecorded runs then `reps` timed runs.
pub fn measure<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> TimingStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = std::time::Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    TimingStats::from_samples(&samples)
}

/// Simple aligned table printer.
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n=== {} ===\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a speedup ratio.
pub fn fmt_speedup(baseline: f64, ours: f64) -> String {
    if ours <= 0.0 {
        return "n/a".into();
    }
    format!("{:.2}x", baseline / ours)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_runs() {
        let mut calls = 0usize;
        let stats = measure(2, 5, || {
            calls += 1;
        });
        assert_eq!(calls, 7);
        assert_eq!(stats.n, 5);
        assert!(stats.median >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(&["alpha".into(), "1.0".into()]);
        t.row(&["b".into(), "22.5".into()]);
        let s = t.render();
        assert!(s.contains("Demo"));
        assert!(s.contains("alpha"));
        assert!(s.contains("22.5"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn speedup_formatting() {
        assert_eq!(fmt_speedup(2.0, 1.0), "2.00x");
        assert_eq!(fmt_speedup(1.0, 0.0), "n/a");
    }
}
