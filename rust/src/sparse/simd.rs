//! Feature-dimension register blocking shared by every SpMM inner loop.
//!
//! Each kernel's hot loop is the same rank-1 update: `acc[0..d] += a *
//! x[0..d]` for one edge `(i, j, a)` against a dense feature row. The
//! profitable shape — four independent f32 lanes per iteration, proven by
//! `dr_spmm`'s hand-unrolled k-loop — is factored here once so `spmm_csr`,
//! `spmm_csr_bwd`, the GNNA group loop, and the ELL/blocked-CSR kernels all
//! get it. Four accumulators with no cross-lane dependency autovectorize to
//! one 128-bit mul+add per step (and unblock wider units via unrolling)
//! instead of a scalar chain.
//!
//! Numerics: each output element still receives exactly one `a * x` product
//! per edge, added in the same per-element order as the scalar loop —
//! unrolling is across *independent* elements, never across a single
//! element's summation chain. Results are therefore bit-identical to the
//! pre-SIMD kernels, which is what keeps `tests/golden/` traces byte-stable
//! (asserted by `axpy_matches_scalar_bitwise` below and the golden harness).

/// `acc[i] += a * x[i]` over equal-length slices, register-blocked four
/// f32 lanes at a time.
#[inline(always)]
pub fn axpy(acc: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len(), "axpy: slice lengths differ");
    let n = acc.len().min(x.len());
    let blocked = n - n % 4;
    let (acc_b, acc_tail) = acc[..n].split_at_mut(blocked);
    let (x_b, x_tail) = x[..n].split_at(blocked);
    for (yc, xc) in acc_b.chunks_exact_mut(4).zip(x_b.chunks_exact(4)) {
        // Four independent lanes: no dependency chain between elements.
        yc[0] += a * xc[0];
        yc[1] += a * xc[1];
        yc[2] += a * xc[2];
        yc[3] += a * xc[3];
    }
    for (y, xv) in acc_tail.iter_mut().zip(x_tail) {
        *y += a * xv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn scalar_axpy(acc: &mut [f32], a: f32, x: &[f32]) {
        for (y, xv) in acc.iter_mut().zip(x) {
            *y += a * xv;
        }
    }

    #[test]
    fn axpy_matches_scalar_bitwise() {
        let mut rng = Rng::new(7);
        // Cover the blocked body, the tail, and the empty/short cases.
        for d in [0usize, 1, 2, 3, 4, 5, 7, 8, 15, 16, 17, 64, 129] {
            let x: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let base: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            for a in [0.0f32, -1.5, 0.37, 1e-8, rng.normal()] {
                let mut got = base.clone();
                let mut want = base.clone();
                axpy(&mut got, a, &x);
                scalar_axpy(&mut want, a, &x);
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "d={d} a={a}: blocked axpy must be bit-identical");
            }
        }
    }

    #[test]
    fn repeated_axpy_accumulates() {
        let mut acc = vec![0f32; 6];
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        axpy(&mut acc, 2.0, &x);
        axpy(&mut acc, -1.0, &x);
        assert_eq!(acc, x);
    }
}
