//! Warp-level scheduling model (paper Alg. 1 stages 1–2).
//!
//! The CUDA kernel assigns one warp per neighbor group (NG) and partitions
//! each warp into `⌈32/K⌉` parts so that small K lets one warp serve several
//! neighbors at once. On CPU the execution resource is a worker thread with
//! SIMD lanes; the *scheduling policy* carries over:
//!
//! * rows are classified into degree buckets (low / medium / high — the
//!   paper's three NG classes),
//! * within a bucket, rows are dispatched dynamically with a grain inversely
//!   proportional to the bucket's work so "evil rows" (§2.3) cannot tail-lag
//!   a statically-chunked worker.

use crate::graph::Csr;

/// CUDA warp width — kept as the unit of the lane model.
pub const WARP_SIZE: usize = 32;

/// The paper's three neighbor-group classes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DegreeClass {
    Low,
    Medium,
    High,
}

impl DegreeClass {
    pub fn name(&self) -> &'static str {
        match self {
            DegreeClass::Low => "low",
            DegreeClass::Medium => "medium",
            DegreeClass::High => "high",
        }
    }
}

/// Degree-bucketed row schedule.
#[derive(Clone, Debug)]
pub struct DegreeBuckets {
    /// Row ids ordered low-bucket first, then medium, then high.
    pub order: Vec<u32>,
    /// (start offset in `order`, dispatch grain) per class.
    pub low: (usize, usize),
    pub medium: (usize, usize),
    pub high: (usize, usize),
    /// Degree thresholds used: deg < t_low → Low, deg < t_high → Medium.
    pub t_low: usize,
    pub t_high: usize,
}

impl DegreeBuckets {
    /// Default thresholds: low < 8, medium < 64, high ≥ 64 — chosen so the
    /// `pins`/`pinned` matrices land in Low and `near`'s hubs in High.
    pub fn build(adj: &Csr) -> DegreeBuckets {
        Self::build_with(adj, 8, 64)
    }

    pub fn build_with(adj: &Csr, t_low: usize, t_high: usize) -> DegreeBuckets {
        assert!(t_low < t_high);
        let mut low = Vec::new();
        let mut med = Vec::new();
        let mut high = Vec::new();
        for r in 0..adj.rows {
            let d = adj.degree(r);
            if d < t_low {
                low.push(r as u32);
            } else if d < t_high {
                med.push(r as u32);
            } else {
                high.push(r as u32);
            }
        }
        let mut order = Vec::with_capacity(adj.rows);
        let lo_start = 0;
        order.extend_from_slice(&low);
        let med_start = order.len();
        order.extend_from_slice(&med);
        let high_start = order.len();
        order.extend_from_slice(&high);
        // Grains: cheap rows dispatched in large blocks, evil rows one by one.
        DegreeBuckets {
            order,
            low: (lo_start, 256),
            medium: (med_start, 16),
            high: (high_start, 1),
            t_low,
            t_high,
        }
    }

    pub fn classify(&self, degree: usize) -> DegreeClass {
        if degree < self.t_low {
            DegreeClass::Low
        } else if degree < self.t_high {
            DegreeClass::Medium
        } else {
            DegreeClass::High
        }
    }

    /// Number of rows in each class (low, medium, high).
    pub fn counts(&self) -> (usize, usize, usize) {
        (
            self.medium.0 - self.low.0,
            self.high.0 - self.medium.0,
            self.order.len() - self.high.0,
        )
    }

    /// Warp partition factor for a given K (paper: a warp splits into
    /// ⌈32/K⌉ parts, each serving one neighbor's K surviving features).
    pub fn partition_factor(k: usize) -> usize {
        WARP_SIZE.div_ceil(k.max(1))
    }

    /// Iterate (class, rows-slice, grain).
    pub fn segments(&self) -> [(DegreeClass, &[u32], usize); 3] {
        let (l, m, h) = (self.low.0, self.medium.0, self.high.0);
        [
            (DegreeClass::Low, &self.order[l..m], self.low.1),
            (DegreeClass::Medium, &self.order[m..h], self.medium.1),
            (DegreeClass::High, &self.order[h..], self.high.1),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph_with_degrees(degs: &[usize]) -> Csr {
        let cols = *degs.iter().max().unwrap_or(&1) + 1;
        let mut t = Vec::new();
        for (r, &d) in degs.iter().enumerate() {
            for c in 0..d {
                t.push((r, c, 1.0));
            }
        }
        Csr::from_triplets(degs.len(), cols, &t)
    }

    #[test]
    fn buckets_partition_all_rows() {
        let adj = graph_with_degrees(&[2, 3, 10, 20, 100, 7, 64]);
        let b = DegreeBuckets::build(&adj);
        let (l, m, h) = b.counts();
        assert_eq!(l + m + h, 7);
        assert_eq!(l, 3); // degrees 2, 3, 7
        assert_eq!(m, 2); // 10, 20
        assert_eq!(h, 2); // 100, 64
        let mut sorted = b.order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..7).collect::<Vec<u32>>());
    }

    #[test]
    fn classification_matches_thresholds() {
        let adj = graph_with_degrees(&[1]);
        let b = DegreeBuckets::build_with(&adj, 4, 32);
        assert_eq!(b.classify(3), DegreeClass::Low);
        assert_eq!(b.classify(4), DegreeClass::Medium);
        assert_eq!(b.classify(31), DegreeClass::Medium);
        assert_eq!(b.classify(32), DegreeClass::High);
    }

    #[test]
    fn partition_factor_table() {
        // ⌈32/K⌉ — the paper's warp split counts.
        assert_eq!(DegreeBuckets::partition_factor(2), 16);
        assert_eq!(DegreeBuckets::partition_factor(8), 4);
        assert_eq!(DegreeBuckets::partition_factor(32), 1);
        assert_eq!(DegreeBuckets::partition_factor(64), 1);
    }

    #[test]
    fn segments_cover_order() {
        let adj = graph_with_degrees(&[2, 50, 100, 3]);
        let b = DegreeBuckets::build(&adj);
        let total: usize = b.segments().iter().map(|(_, s, _)| s.len()).sum();
        assert_eq!(total, 4);
        // grains decrease with degree class
        let segs = b.segments();
        assert!(segs[0].2 > segs[1].2 && segs[1].2 > segs[2].2);
    }
}
