//! D-ReLU — row-wise dynamic top-k activation (paper §3.1, eqs. 2–3).
//!
//! For each embedding row, the threshold `th_i = min(topk(X_i, k))` keeps
//! exactly the k largest entries (ties broken by column order) and zeroes
//! the rest, producing a [`Cbsr`] whose *balanced* sparsity the DR-SpMM
//! kernels exploit. Unlike ReLU, negative values can survive when the row
//! has fewer than k positive entries — D-ReLU is a ranking filter, not a
//! sign filter; its job is workload regularisation.

use crate::graph::Cbsr;
use crate::tensor::Matrix;
use crate::util::pool::{parallel_for_chunks, SendPtr};

/// Forward: compress `x` (n×D) to exactly-k-per-row CBSR.
pub fn drelu(x: &Matrix, k: usize) -> Cbsr {
    let (n, dim) = (x.rows, x.cols);
    assert!(k > 0 && k <= dim, "drelu: need 0 < k ≤ D (k={k}, D={dim})");
    let mut out = Cbsr::zeros(n, dim, k);
    let vptr = SendPtr(out.values.as_mut_ptr());
    let iptr = SendPtr(out.indices.as_mut_ptr());
    parallel_for_chunks(n, |lo, hi| {
        let vp = vptr;
        let ip = iptr;
        // Scratch buffers reused across the chunk's rows.
        let mut heap: Vec<(f32, u32)> = Vec::with_capacity(k);
        for r in lo..hi {
            let row = x.row(r);
            select_topk(row, k, &mut heap);
            // SAFETY: rows [lo,hi) exclusively owned by this worker.
            let vals = unsafe { std::slice::from_raw_parts_mut(vp.0.add(r * k), k) };
            // SAFETY: same disjoint [lo,hi) row ownership as `vals`.
            let idxs = unsafe { std::slice::from_raw_parts_mut(ip.0.add(r * k), k) };
            for (t, &(v, c)) in heap.iter().enumerate() {
                vals[t] = v;
                idxs[t] = c;
            }
        }
    });
    debug_assert!(out.validate().is_ok());
    out
}

/// Select the k largest entries of `row` (ties → smaller column index wins),
/// output sorted by column index ascending into `out`.
///
/// Implementation (§Perf L3-5): each (value, column) pair is packed into one
/// `u64` key — the float mapped to a total order, inverted for descending
/// value, with the column in the low bits for the tiebreak — so a single
/// `select_nth_unstable` (O(D) quickselect) partitions the top-k. ~4×
/// faster than the earlier streaming min-heap on D = 64–128 rows.
fn select_topk(row: &[f32], k: usize, out: &mut Vec<(f32, u32)>) {
    out.clear();
    if k >= row.len() {
        out.extend(row.iter().enumerate().map(|(c, &v)| (v, c as u32)));
        return;
    }
    // Monotone map f32 → u32 (IEEE total order), inverted for descending.
    #[inline]
    fn desc_key(v: f32, col: u32) -> u64 {
        let bits = v.to_bits();
        let mono = if bits & 0x8000_0000 != 0 { !bits } else { bits | 0x8000_0000 };
        (((!mono) as u64) << 32) | col as u64
    }
    KEYS.with(|cell| {
        let keys = &mut *cell.borrow_mut();
        keys.clear();
        keys.extend(row.iter().enumerate().map(|(c, &v)| desc_key(v, c as u32)));
        keys.select_nth_unstable(k - 1);
        let top = &mut keys[..k];
        top.sort_unstable_by_key(|&key| (key & 0xFFFF_FFFF) as u32);
        out.extend(top.iter().map(|&key| {
            let c = (key & 0xFFFF_FFFF) as u32;
            (row[c as usize], c)
        }));
    });
}

thread_local! {
    /// Per-thread scratch for select_topk (avoids a per-row allocation).
    static KEYS: std::cell::RefCell<Vec<u64>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// Backward: gradients flow only through the kept positions (the CBSR mask
/// preserved from the forward pass). Given dense upstream `dy` (n×D) and
/// the forward-pass CBSR, returns the dense gradient w.r.t. the D-ReLU
/// input (n×D, zero outside kept indices).
pub fn drelu_backward(dy: &Matrix, fwd: &Cbsr) -> Matrix {
    assert_eq!(dy.rows, fwd.n);
    assert_eq!(dy.cols, fwd.dim);
    let mut dx = Matrix::zeros(dy.rows, dy.cols);
    let ptr = SendPtr(dx.data.as_mut_ptr());
    let d = dy.cols;
    parallel_for_chunks(dy.rows, |lo, hi| {
        let dp = ptr;
        for r in lo..hi {
            // SAFETY: parallel_for_chunks hands each worker a disjoint
            // [lo, hi) row range, so row r's d-wide slice of dx is owned
            // exclusively by this worker; dx outlives the scoped threads.
            let dxrow = unsafe { std::slice::from_raw_parts_mut(dp.0.add(r * d), d) };
            let dyrow = dy.row(r);
            for &c in fwd.row_indices(r) {
                dxrow[c as usize] = dyrow[c as usize];
            }
        }
    });
    dx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn keeps_exactly_k_largest() {
        let x = Matrix::from_vec(1, 6, vec![0.5, -1.0, 3.0, 2.0, -0.1, 1.0]);
        let c = drelu(&x, 3);
        assert_eq!(c.row_indices(0), &[2, 3, 5]); // values 3.0, 2.0, 1.0
        assert_eq!(c.row_values(0), &[3.0, 2.0, 1.0]);
    }

    #[test]
    fn negative_values_survive_when_needed() {
        let x = Matrix::from_vec(1, 4, vec![-1.0, -2.0, -3.0, -4.0]);
        let c = drelu(&x, 2);
        assert_eq!(c.row_indices(0), &[0, 1]);
        assert_eq!(c.row_values(0), &[-1.0, -2.0]);
    }

    #[test]
    fn ties_prefer_earlier_columns() {
        let x = Matrix::from_vec(1, 5, vec![1.0, 1.0, 1.0, 1.0, 1.0]);
        let c = drelu(&x, 2);
        assert_eq!(c.row_indices(0), &[0, 1]);
    }

    #[test]
    fn k_equals_dim_is_identity() {
        let mut rng = Rng::new(1);
        let x = Matrix::randn(7, 5, 1.0, &mut rng);
        let c = drelu(&x, 5);
        assert_eq!(c.to_dense().data, x.data);
    }

    #[test]
    fn matches_sort_reference_on_random_rows() {
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let dim = rng.range(2, 40);
            let k = rng.range(1, dim + 1);
            let x = Matrix::randn(3, dim, 1.0, &mut rng);
            let c = drelu(&x, k);
            c.validate().unwrap();
            for r in 0..3 {
                // Reference: threshold = k-th largest value.
                let mut sorted: Vec<f32> = x.row(r).to_vec();
                sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
                let th = sorted[k - 1];
                // All kept values ≥ th, and sum of kept == sum of top-k.
                let kept_sum: f32 = c.row_values(r).iter().sum();
                let top_sum: f32 = sorted[..k].iter().sum();
                assert!((kept_sum - top_sum).abs() < 1e-4, "row {r}: {kept_sum} vs {top_sum}");
                assert!(c.row_values(r).iter().all(|&v| v >= th - 1e-6));
            }
        }
    }

    #[test]
    fn dense_round_trip_is_masked_input() {
        let mut rng = Rng::new(3);
        let x = Matrix::randn(10, 16, 1.0, &mut rng);
        let k = 4;
        let c = drelu(&x, k);
        let d = c.to_dense();
        for r in 0..10 {
            for col in 0..16 {
                let v = d.at(r, col);
                if v != 0.0 {
                    assert_eq!(v, x.at(r, col));
                }
            }
            // Exactly k entries are kept per row; the dense round trip
            // shows k nonzeros except where a *kept* value is itself 0.0
            // (D-ReLU is a ranking filter — zeros can rank in the top k).
            assert_eq!(c.row_values(r).len(), k);
            let kept_zeros = c.row_values(r).iter().filter(|&&v| v == 0.0).count();
            let nonzeros = d.row(r).iter().filter(|&&v| v != 0.0).count();
            assert_eq!(nonzeros, k - kept_zeros, "row {r}");
        }
    }

    #[test]
    fn backward_masks_gradient() {
        let x = Matrix::from_vec(2, 4, vec![5.0, 1.0, 3.0, 0.0, 0.0, 2.0, 9.0, 4.0]);
        let c = drelu(&x, 2);
        let dy = Matrix::ones(2, 4);
        let dx = drelu_backward(&dy, &c);
        // Row 0 keeps cols {0, 2}; row 1 keeps cols {2, 3}.
        assert_eq!(dx.row(0), &[1.0, 0.0, 1.0, 0.0]);
        assert_eq!(dx.row(1), &[0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "drelu")]
    fn zero_k_panics() {
        drelu(&Matrix::ones(1, 4), 0);
    }
}
