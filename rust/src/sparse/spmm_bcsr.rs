//! Blocked-CSR SpMM: row-block × feature-tile cache tiling.
//!
//! Plain row-parallel CSR streams `X` rows through cache once per output
//! row: with wide feature dims, a popular source row is evicted between
//! the destination rows that read it. This kernel tiles the computation in
//! two dimensions instead:
//!
//! * **row blocks** — contiguous destination-row ranges balanced by nnz
//!   (a block covers ≈ [`BCSR_TARGET_BLOCK_NNZ`] edges), so the `X` rows a
//!   neighborhood-local block touches stay resident in L1/L2 while every
//!   row of the block reads them;
//! * **feature tiles** — the inner loops run [`BCSR_FEATURE_TILE`]-wide
//!   column slices, bounding the working set per pass on wide embeddings.
//!
//! Each output element still accumulates its row's neighbors in CSR order
//! (tiling splits the feature dimension, never one element's summation
//! chain), so results are **bit-identical** to
//! [`spmm_csr`](crate::sparse::spmm_csr)/[`spmm_csr_bwd`]
//! (crate::sparse::spmm_csr_bwd) — asserted in the tests below. Blocks
//! cover disjoint row ranges, so the dispatch needs no atomics; workers
//! come from the ambient [`crate::util::pool::Budget`].

use crate::graph::{Csc, Csr};
use crate::sparse::simd::axpy;
use crate::tensor::Matrix;
use crate::util::pool::{parallel_for_dynamic, SendPtr};

/// Edges per row block: sized so a block's source-row working set
/// (≈ target_nnz distinct rows in the worst case, far fewer on
/// neighborhood-local circuit graphs) fits mid-level cache.
pub const BCSR_TARGET_BLOCK_NNZ: usize = 4096;

/// Feature columns per inner tile (f32 lanes): 64 floats = 256 bytes per
/// row slice, four cache lines — small enough that a block's slices of
/// `Y` and the hot `X` rows coexist in L1.
pub const BCSR_FEATURE_TILE: usize = 64;

/// The blocked-CSR plan payload: nnz-balanced row-block boundaries for the
/// forward (over the adjacency) and backward (over the CSC) traversals,
/// plus the feature-tile width. Stored in the
/// [`KernelPlan`](crate::engine::KernelPlan) and serialized by the plan
/// store.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlockSchedule {
    /// Forward row-block boundaries: block `b` covers rows
    /// `fwd[b]..fwd[b+1]`; `fwd[0] == 0`, `fwd.last() == adj.rows`.
    pub fwd: Vec<u32>,
    /// Backward column-block boundaries over the CSC (same convention).
    pub bwd: Vec<u32>,
    /// Feature-dimension tile width.
    pub tile: usize,
}

impl BlockSchedule {
    /// Build both traversal schedules for one adjacency.
    pub fn build(adj: &Csr, csc: &Csc) -> BlockSchedule {
        BlockSchedule {
            fwd: blocks_from_indptr(&adj.indptr, BCSR_TARGET_BLOCK_NNZ),
            bwd: blocks_from_indptr(&csc.indptr, BCSR_TARGET_BLOCK_NNZ),
            tile: BCSR_FEATURE_TILE,
        }
    }
}

/// Split a pointered dimension into contiguous blocks of ≈ `target_nnz`
/// edges (≥ 1 row each): a block closes as soon as it reaches the target,
/// so hub-heavy stretches get short blocks and sparse stretches get long
/// ones — the same load-balancing idea as DR's degree buckets, applied to
/// contiguous ranges so cache locality survives.
pub fn blocks_from_indptr(indptr: &[usize], target_nnz: usize) -> Vec<u32> {
    let rows = indptr.len().saturating_sub(1);
    let target = target_nnz.max(1);
    let mut bounds = vec![0u32];
    let mut start = 0usize;
    for r in 0..rows {
        if indptr[r + 1] - indptr[start] >= target {
            bounds.push((r + 1) as u32);
            start = r + 1;
        }
    }
    if *bounds.last().unwrap() as usize != rows {
        bounds.push(rows as u32);
    }
    bounds
}

/// Forward: `Y = A · X`, tiled rows × feature-dim per the schedule.
pub fn spmm_bcsr(a: &Csr, x: &Matrix, sched: &BlockSchedule) -> Matrix {
    assert_eq!(a.cols, x.rows, "spmm_bcsr: A cols {} vs X rows {}", a.cols, x.rows);
    assert_eq!(
        sched.fwd.last().copied().unwrap_or(0) as usize,
        a.rows,
        "spmm_bcsr: schedule covers {} rows, adjacency has {}",
        sched.fwd.last().copied().unwrap_or(0),
        a.rows
    );
    tiled_spmm(a.rows, &a.indptr, &a.indices, &a.values, x, &sched.fwd, sched.tile)
}

/// Backward: `dX = Aᵀ · dY` over the CSC columns, same tiling.
pub fn spmm_bcsr_bwd(a_csc: &Csc, dy: &Matrix, sched: &BlockSchedule) -> Matrix {
    assert_eq!(
        a_csc.rows, dy.rows,
        "spmm_bcsr_bwd: A rows {} vs dY rows {}",
        a_csc.rows, dy.rows
    );
    assert_eq!(
        sched.bwd.last().copied().unwrap_or(0) as usize,
        a_csc.cols,
        "spmm_bcsr_bwd: schedule covers {} cols, CSC has {}",
        sched.bwd.last().copied().unwrap_or(0),
        a_csc.cols
    );
    tiled_spmm(a_csc.cols, &a_csc.indptr, &a_csc.indices, &a_csc.values, dy, &sched.bwd, sched.tile)
}

/// The shared blocked kernel over raw pointered storage: one parallel work
/// item per row block, feature tiles innermost-but-one so the block's hot
/// `x` rows are re-read while still cached.
fn tiled_spmm(
    out_rows: usize,
    indptr: &[usize],
    indices: &[u32],
    values: &[f32],
    x: &Matrix,
    bounds: &[u32],
    tile: usize,
) -> Matrix {
    let d = x.cols;
    let tile = tile.max(1);
    let mut y = Matrix::zeros(out_rows, d);
    let y_ptr = SendPtr(y.data.as_mut_ptr());
    let n_blocks = bounds.len().saturating_sub(1);
    parallel_for_dynamic(n_blocks, 1, |b| {
        let (lo, hi) = (bounds[b] as usize, bounds[b + 1] as usize);
        let yp = y_ptr;
        let mut c0 = 0;
        while c0 < d {
            let c1 = (c0 + tile).min(d);
            for i in lo..hi {
                // SAFETY: rows [lo, hi) belong to block b alone.
                let yrow = unsafe { std::slice::from_raw_parts_mut(yp.0.add(i * d), d) };
                for p in indptr[i]..indptr[i + 1] {
                    let j = indices[p] as usize;
                    axpy(&mut yrow[c0..c1], values[p], &x.row(j)[c0..c1]);
                }
            }
            c0 = c1;
        }
    });
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::spmm_csr::{spmm_csr, spmm_csr_bwd};
    use crate::util::rng::Rng;

    fn random_csr(rows: usize, cols: usize, max_deg: usize, rng: &mut Rng) -> Csr {
        let mut t = Vec::new();
        for r in 0..rows {
            for _ in 0..rng.range(0, max_deg + 1) {
                t.push((r, rng.below(cols), rng.uniform(0.5, 1.5)));
            }
        }
        Csr::from_triplets(rows, cols, &t)
    }

    #[test]
    fn blocks_partition_and_balance() {
        // Degrees 10,10,10,1,1,1,1,1,1,10 with target 20.
        let degs = [10usize, 10, 10, 1, 1, 1, 1, 1, 1, 10];
        let mut indptr = vec![0usize];
        for d in degs {
            indptr.push(indptr.last().unwrap() + d);
        }
        let b = blocks_from_indptr(&indptr, 20);
        assert_eq!(b.first(), Some(&0));
        assert_eq!(b.last().copied(), Some(degs.len() as u32));
        assert!(b.windows(2).all(|w| w[0] < w[1]), "blocks must be non-empty: {b:?}");
        // Dense stretch closes at 20 edges after two rows; the sparse
        // stretch runs until row 9's edges push it past the target.
        assert_eq!(b, vec![0, 2, 10]);
        // Degenerate shapes.
        assert_eq!(blocks_from_indptr(&[0], 8), vec![0]);
        assert_eq!(blocks_from_indptr(&[0, 0, 0], 8), vec![0, 2]);
    }

    #[test]
    fn forward_and_backward_are_bitwise_csr() {
        let mut rng = Rng::new(3);
        for (m, n, d) in [(5, 7, 3), (40, 30, 16), (90, 80, 70), (64, 64, 130)] {
            let a = random_csr(m, n, 6, &mut rng);
            let csc = a.to_csc();
            // Tiny block/tile sizes so the schedule actually splits.
            let sched = BlockSchedule {
                fwd: blocks_from_indptr(&a.indptr, 8),
                bwd: blocks_from_indptr(&csc.indptr, 8),
                tile: 5,
            };
            let x = Matrix::randn(n, d, 1.0, &mut rng);
            assert_eq!(spmm_bcsr(&a, &x, &sched).data, spmm_csr(&a, &x).data);
            let dy = Matrix::randn(m, d, 1.0, &mut rng);
            assert_eq!(
                spmm_bcsr_bwd(&csc, &dy, &sched).data,
                spmm_csr_bwd(&csc, &dy).data
            );
        }
    }

    #[test]
    fn default_schedule_covers_everything() {
        let mut rng = Rng::new(4);
        let a = random_csr(50, 40, 5, &mut rng);
        let sched = BlockSchedule::build(&a, &a.to_csc());
        assert_eq!(sched.fwd.last().copied(), Some(50));
        assert_eq!(sched.bwd.last().copied(), Some(40));
        let x = Matrix::randn(40, 12, 1.0, &mut rng);
        assert_eq!(spmm_bcsr(&a, &x, &sched).data, spmm_csr(&a, &x).data);
    }

    #[test]
    #[should_panic(expected = "spmm_bcsr")]
    fn stale_schedule_panics() {
        let a = random_csr(10, 10, 3, &mut Rng::new(5));
        let other = random_csr(20, 10, 3, &mut Rng::new(6));
        let sched = BlockSchedule::build(&other, &other.to_csc());
        spmm_bcsr(&a, &Matrix::zeros(10, 4), &sched);
    }
}
