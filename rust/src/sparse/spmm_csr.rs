//! cuSPARSE-analog baseline SpMM.
//!
//! The algorithm shape of `cusparseSpMM` with CSR/row-major operands:
//! one output row per work unit, dense `D`-wide inner accumulation, static
//! row→worker chunking. No sparsity awareness in the embedding, no degree
//! awareness in the schedule — exactly what the paper baselines against.
//! Worker counts come from the ambient thread
//! [`crate::util::pool::Budget`] (the caller's share, not the machine).

use crate::graph::{Csc, Csr};
use crate::sparse::simd::axpy;
use crate::tensor::Matrix;
use crate::util::pool::{parallel_for_chunks, SendPtr};

/// Forward: `Y = A · X`, A is `M×N` CSR, X is `N×D` dense, Y is `M×D`.
pub fn spmm_csr(a: &Csr, x: &Matrix) -> Matrix {
    assert_eq!(a.cols, x.rows, "spmm_csr: A cols {} vs X rows {}", a.cols, x.rows);
    let d = x.cols;
    let mut y = Matrix::zeros(a.rows, d);
    let y_ptr = SendPtr(y.data.as_mut_ptr());
    parallel_for_chunks(a.rows, |lo, hi| {
        let yp = y_ptr;
        for i in lo..hi {
            // SAFETY: row i written only by this worker's chunk.
            let yrow = unsafe { std::slice::from_raw_parts_mut(yp.0.add(i * d), d) };
            for p in a.row_range(i) {
                let j = a.indices[p] as usize;
                axpy(yrow, a.values[p], x.row(j));
            }
        }
    });
    y
}

/// Backward: `dX = Aᵀ · dY` via CSC traversal (column-major like cuSPARSE
/// would run on the transposed descriptor). dY is `M×D`, dX is `N×D`.
pub fn spmm_csr_bwd(a_csc: &Csc, dy: &Matrix) -> Matrix {
    assert_eq!(a_csc.rows, dy.rows, "spmm_csr_bwd: A rows {} vs dY rows {}", a_csc.rows, dy.rows);
    let d = dy.cols;
    let mut dx = Matrix::zeros(a_csc.cols, d);
    let dx_ptr = SendPtr(dx.data.as_mut_ptr());
    parallel_for_chunks(a_csc.cols, |lo, hi| {
        let dp = dx_ptr;
        for j in lo..hi {
            // SAFETY: the CSC traversal writes dX by *column* j of A, and
            // parallel_for_chunks gives each worker a disjoint [lo, hi)
            // column range — row j of dX has exactly one writer; dx
            // outlives the scoped threads.
            let dxrow = unsafe { std::slice::from_raw_parts_mut(dp.0.add(j * d), d) };
            for p in a_csc.col_range(j) {
                let i = a_csc.indices[p] as usize;
                axpy(dxrow, a_csc.values[p], dy.row(i));
            }
        }
    });
    dx
}

/// Naive dense reference (tests): `Y = dense(A) · X`.
pub fn spmm_dense_ref(a: &Csr, x: &Matrix) -> Matrix {
    assert_eq!(a.cols, x.rows);
    let ad = a.to_dense();
    let mut y = Matrix::zeros(a.rows, x.cols);
    for i in 0..a.rows {
        for kk in 0..a.cols {
            let v = ad[i * a.cols + kk];
            if v == 0.0 {
                continue;
            }
            for c in 0..x.cols {
                *y.at_mut(i, c) += v * x.at(kk, c);
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::assert_allclose;
    use crate::util::rng::Rng;

    fn random_csr(rows: usize, cols: usize, avg_deg: usize, rng: &mut Rng) -> Csr {
        let mut t = Vec::new();
        for r in 0..rows {
            let deg = rng.range(0, avg_deg * 2 + 1);
            for _ in 0..deg {
                t.push((r, rng.below(cols), rng.uniform(0.5, 1.5)));
            }
        }
        Csr::from_triplets(rows, cols, &t)
    }

    #[test]
    fn forward_matches_dense_reference() {
        let mut rng = Rng::new(1);
        for (m, n, d) in [(5, 7, 3), (40, 30, 16), (100, 100, 64)] {
            let a = random_csr(m, n, 4, &mut rng);
            let x = Matrix::randn(n, d, 1.0, &mut rng);
            let fast = spmm_csr(&a, &x);
            let slow = spmm_dense_ref(&a, &x);
            assert_allclose(&fast.data, &slow.data, 1e-4, 1e-4);
        }
    }

    #[test]
    fn backward_equals_transpose_forward() {
        let mut rng = Rng::new(2);
        let a = random_csr(30, 20, 3, &mut rng);
        let dy = Matrix::randn(30, 8, 1.0, &mut rng);
        let via_csc = spmm_csr_bwd(&a.to_csc(), &dy);
        let via_t = spmm_csr(&a.transpose(), &dy);
        assert_allclose(&via_csc.data, &via_t.data, 1e-4, 1e-4);
    }

    #[test]
    fn empty_rows_produce_zero_rows() {
        let a = Csr::from_triplets(3, 2, &[(0, 0, 2.0)]);
        let x = Matrix::ones(2, 4);
        let y = spmm_csr(&a, &x);
        assert_eq!(y.row(0), &[2.0, 2.0, 2.0, 2.0]);
        assert_eq!(y.row(1), &[0.0; 4]);
        assert_eq!(y.row(2), &[0.0; 4]);
    }

    #[test]
    fn rectangular_hetero_shapes() {
        // pins-like: more columns (cells) than rows (nets).
        let mut rng = Rng::new(3);
        let a = random_csr(10, 50, 3, &mut rng);
        let x = Matrix::randn(50, 6, 1.0, &mut rng);
        let y = spmm_csr(&a, &x);
        assert_eq!((y.rows, y.cols), (10, 6));
        assert_allclose(&y.data, &spmm_dense_ref(&a, &x).data, 1e-4, 1e-4);
    }

    #[test]
    #[should_panic(expected = "spmm_csr")]
    fn shape_mismatch_panics() {
        spmm_csr(&Csr::from_triplets(2, 3, &[]), &Matrix::zeros(4, 2));
    }
}
