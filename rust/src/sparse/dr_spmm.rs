//! DR-SpMM forward kernel (paper §3.2, Alg. 1).
//!
//! `Y = A · X̃` where `X̃` is the D-ReLU-compressed CBSR embedding: each
//! neighbor contributes only its `k` surviving (value, column) pairs, so the
//! per-edge work drops from `D` to `k` — the kernel's FLOP/byte saving.
//!
//! Scheduling follows Alg. 1 stage 2: rows are processed in degree-bucket
//! order with a dynamic dispatch grain per bucket (evil rows go one-by-one,
//! cheap rows in large blocks), eliminating the tail-lag a static
//! row→worker mapping suffers on power-law graphs. Each bucket dispatch
//! sizes itself to the ambient [`crate::util::pool::Budget`] under the
//! pool's one grain-aware cutoff rule — tiny cheap buckets run inline,
//! while even a two-row evil bucket (grain 1) earns two threads — and
//! nested schedulers (fleet workers × edge lanes) never oversubscribe.

use crate::graph::{Cbsr, Csr};
use crate::tensor::Matrix;
use crate::util::pool::{parallel_for_dynamic_order, SendPtr};

use super::warp::DegreeBuckets;

/// Forward DR-SpMM: `Y[i,:] = Σ_{j∈N(i)} A_ij · scatter(vals_j, idx_j)`.
///
/// * `a` — destination-major adjacency (`M×N`)
/// * `x` — CBSR source embeddings (`N` rows, width `D`, `k` kept)
/// * `buckets` — degree schedule built once per graph (Alg. 1 stage 1).
pub fn dr_spmm(a: &Csr, x: &Cbsr, buckets: &DegreeBuckets) -> Matrix {
    assert_eq!(a.cols, x.n, "dr_spmm: A cols {} vs CBSR rows {}", a.cols, x.n);
    assert_eq!(buckets.order.len(), a.rows, "buckets must be built for this adjacency");
    let d = x.dim;
    let k = x.k;
    let mut y = Matrix::zeros(a.rows, d);
    let y_ptr = SendPtr(y.data.as_mut_ptr());
    for (_class, rows, grain) in buckets.segments() {
        parallel_for_dynamic_order(rows, grain, |&row| {
            let i = row as usize;
            let yp = y_ptr;
            // SAFETY: each destination row appears exactly once across all
            // bucket segments, so this worker owns row i exclusively.
            let yrow = unsafe { std::slice::from_raw_parts_mut(yp.0.add(i * d), d) };
            // k-sparse scatter-accumulate: D/k fewer FLOPs than dense.
            // SAFETY: CBSR validation guarantees indices < D = yrow.len()
            // and row ids < x.n; raw-pointer walk removes bounds checks and
            // slice construction from the per-edge path (§Perf L3-1/L3-3).
            unsafe {
                let ai = a.indices.as_ptr();
                let av_ptr = a.values.as_ptr();
                let xv = x.values.as_ptr();
                let xi = x.indices.as_ptr();
                let yp0 = yrow.as_mut_ptr();
                // (§Perf L3-4: explicit software prefetch of the next
                // neighbor's CBSR row was tried here and REVERTED — it
                // cost ~15% on this core; the hardware prefetcher already
                // covers the small sequential k-row reads.)
                let range = a.row_range(i);
                for p in range {
                    let j = *ai.add(p) as usize;
                    let av = *av_ptr.add(p);
                    let vals = xv.add(j * k);
                    let idxs = xi.add(j * k);
                    let mut t = 0;
                    // 4-way unroll hides the load-address latency chain.
                    while t + 4 <= k {
                        let c0 = *idxs.add(t) as usize;
                        let c1 = *idxs.add(t + 1) as usize;
                        let c2 = *idxs.add(t + 2) as usize;
                        let c3 = *idxs.add(t + 3) as usize;
                        *yp0.add(c0) += av * *vals.add(t);
                        *yp0.add(c1) += av * *vals.add(t + 1);
                        *yp0.add(c2) += av * *vals.add(t + 2);
                        *yp0.add(c3) += av * *vals.add(t + 3);
                        t += 4;
                    }
                    while t < k {
                        *yp0.add(*idxs.add(t) as usize) += av * *vals.add(t);
                        t += 1;
                    }
                }
            }
        });
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::drelu::drelu;
    use crate::sparse::spmm_csr::spmm_csr;
    use crate::util::math::assert_allclose;
    use crate::util::rng::Rng;

    fn random_csr(rows: usize, cols: usize, max_deg: usize, rng: &mut Rng) -> Csr {
        let mut t = Vec::new();
        for r in 0..rows {
            for _ in 0..rng.range(0, max_deg + 1) {
                t.push((r, rng.below(cols), rng.uniform(0.5, 1.5)));
            }
        }
        Csr::from_triplets(rows, cols, &t)
    }

    #[test]
    fn matches_dense_spmm_on_decompressed_input() {
        let mut rng = Rng::new(1);
        for (m, n, d, k) in [(8, 6, 8, 2), (50, 40, 32, 8), (100, 80, 64, 16)] {
            let a = random_csr(m, n, 6, &mut rng);
            let x = Matrix::randn(n, d, 1.0, &mut rng);
            let xc = drelu(&x, k);
            let buckets = DegreeBuckets::build(&a);
            let fast = dr_spmm(&a, &xc, &buckets);
            let reference = spmm_csr(&a, &xc.to_dense());
            assert_allclose(&fast.data, &reference.data, 1e-4, 1e-4);
        }
    }

    #[test]
    fn k_equals_dim_matches_plain_spmm() {
        let mut rng = Rng::new(2);
        let a = random_csr(20, 15, 4, &mut rng);
        let x = Matrix::randn(15, 12, 1.0, &mut rng);
        let xc = drelu(&x, 12);
        let buckets = DegreeBuckets::build(&a);
        let y = dr_spmm(&a, &xc, &buckets);
        assert_allclose(&y.data, &spmm_csr(&a, &x).data, 1e-4, 1e-4);
    }

    #[test]
    fn evil_row_graph_correct() {
        // One row with 500 neighbors among degree-1 rows.
        let mut rng = Rng::new(3);
        let mut t = vec![];
        for c in 0..500usize {
            t.push((0usize, c, 1.0));
        }
        for r in 1..300usize {
            t.push((r, rng.below(500), 1.0));
        }
        let a = Csr::from_triplets(300, 500, &t);
        let x = Matrix::randn(500, 16, 1.0, &mut rng);
        let xc = drelu(&x, 4);
        let buckets = DegreeBuckets::build(&a);
        let y = dr_spmm(&a, &xc, &buckets);
        assert_allclose(&y.data, &spmm_csr(&a, &xc.to_dense()).data, 1e-3, 1e-3);
    }

    #[test]
    fn empty_adjacency_gives_zeros() {
        let a = Csr::from_triplets(4, 4, &[]);
        let x = drelu(&Matrix::ones(4, 8), 2);
        let buckets = DegreeBuckets::build(&a);
        let y = dr_spmm(&a, &x, &buckets);
        assert!(y.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic(expected = "buckets must be built")]
    fn wrong_buckets_panics() {
        let a = Csr::from_triplets(3, 3, &[(0, 1, 1.0)]);
        let b = Csr::from_triplets(5, 3, &[(0, 1, 1.0)]);
        let x = drelu(&Matrix::ones(3, 4), 2);
        dr_spmm(&a, &x, &DegreeBuckets::build(&b));
    }
}
