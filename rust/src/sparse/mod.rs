//! The SpMM kernel zoo (paper §3).
//!
//! All kernels compute neighbor aggregation `Y = A · X` (forward) or
//! `dX = Aᵀ · dY` (backward) where `A` is a circuit-graph adjacency.
//! Three implementations are compared, mirroring the paper's evaluation:
//!
//! * [`spmm_csr`] — the **cuSPARSE-analog baseline**: row-parallel CSR
//!   row-product over *dense* embeddings, static row→worker mapping.
//! * [`spmm_gnna`] — the **GNNAdvisor analog**: neighbor-group (NG) kernel
//!   executed under an explicit warp lock-step model (fixed 32-slot groups,
//!   predicated lanes), dimension-worker splitting, atomic accumulation for
//!   rows spanning several groups. Faithful to GNNA's behaviour, including
//!   its poor fit for the low-degree `pins`/`pinned` matrices.
//! * [`dr_spmm`] / [`dr_spmm_bwd`] — **the paper's kernels**: embeddings
//!   sparsified to CBSR by [`drelu`], forward aggregation touching only `k`
//!   of `D` columns per neighbor, degree-bucketed dynamic scheduling
//!   (Alg. 1 stage 2), and a column-major (CSC) backward that reuses the
//!   forward CBSR indices (Alg. 2).
//!
//! * [`spmm_ell`] — **width-capped lossless ELL**: dense `rows × width`
//!   slot layout with a branch-free inner loop and a CSR-style overflow
//!   side-list for edges past the cap (generalizes the padded
//!   `runtime::pad::to_ell` bucket layout without dropping edges).
//! * [`spmm_bcsr`] / [`spmm_bcsr_bwd`] — **blocked CSR**: nnz-balanced
//!   row blocks × feature-dim tiles so hot `X` rows stay in L1/L2 across
//!   a block; bit-identical to the CSR baseline.
//!
//! The dense f32 rank-1 update shared by all of these lives in
//! [`simd::axpy`] (4-lane feature-dim register blocking).
//!
//! These are the raw kernels; everything above this layer dispatches them
//! through [`crate::engine`], which owns kernel selection (by name or
//! per-edge-type `"auto"` policy) and the plan/execute split that caches
//! the per-graph schedules ([`DegreeBuckets`], [`NeighborGroups`],
//! [`EllLayout`], [`BlockSchedule`], CSC).

pub mod dr_spmm;
pub mod dr_spmm_bwd;
pub mod drelu;
pub mod simd;
pub mod spmm_bcsr;
pub mod spmm_csr;
pub mod spmm_ell;
pub mod spmm_gnna;
pub mod warp;

pub use dr_spmm::dr_spmm;
pub use dr_spmm_bwd::{dr_spmm_bwd, dr_spmm_bwd_dense};
pub use drelu::{drelu, drelu_backward};
pub use simd::axpy;
pub use spmm_bcsr::{
    blocks_from_indptr, spmm_bcsr, spmm_bcsr_bwd, BlockSchedule, BCSR_FEATURE_TILE,
    BCSR_TARGET_BLOCK_NNZ,
};
pub use spmm_csr::{spmm_csr, spmm_csr_bwd, spmm_dense_ref};
pub use spmm_ell::{spmm_ell, EllLayout, ELL_WIDTH_CAP_FACTOR};
pub use spmm_gnna::{
    spmm_gnna, spmm_gnna_bwd, spmm_gnna_bwd_planned, spmm_gnna_planned, GnnaConfig, NeighborGroups,
};
pub use warp::{DegreeBuckets, DegreeClass, WARP_SIZE};
