//! The SpMM kernel zoo (paper §3).
//!
//! All kernels compute neighbor aggregation `Y = A · X` (forward) or
//! `dX = Aᵀ · dY` (backward) where `A` is a circuit-graph adjacency.
//! Three implementations are compared, mirroring the paper's evaluation:
//!
//! * [`spmm_csr`] — the **cuSPARSE-analog baseline**: row-parallel CSR
//!   row-product over *dense* embeddings, static row→worker mapping.
//! * [`spmm_gnna`] — the **GNNAdvisor analog**: neighbor-group (NG) kernel
//!   executed under an explicit warp lock-step model (fixed 32-slot groups,
//!   predicated lanes), dimension-worker splitting, atomic accumulation for
//!   rows spanning several groups. Faithful to GNNA's behaviour, including
//!   its poor fit for the low-degree `pins`/`pinned` matrices.
//! * [`dr_spmm`] / [`dr_spmm_bwd`] — **the paper's kernels**: embeddings
//!   sparsified to CBSR by [`drelu`], forward aggregation touching only `k`
//!   of `D` columns per neighbor, degree-bucketed dynamic scheduling
//!   (Alg. 1 stage 2), and a column-major (CSC) backward that reuses the
//!   forward CBSR indices (Alg. 2).

pub mod dr_spmm;
pub mod dr_spmm_bwd;
pub mod drelu;
pub mod spmm_csr;
pub mod spmm_gnna;
pub mod warp;

pub use dr_spmm::dr_spmm;
pub use dr_spmm_bwd::{dr_spmm_bwd, dr_spmm_bwd_dense};
pub use drelu::{drelu, drelu_backward};
pub use spmm_csr::{spmm_csr, spmm_csr_bwd, spmm_dense_ref};
pub use spmm_gnna::{spmm_gnna, spmm_gnna_bwd, GnnaConfig};
pub use warp::{DegreeBuckets, DegreeClass, WARP_SIZE};

/// Which kernel family to use — threaded through configs and benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// cuSPARSE-analog baseline.
    Csr,
    /// GNNAdvisor analog.
    Gnna,
    /// DR-SpMM (requires D-ReLU sparsified embeddings).
    DrSpmm,
}

impl KernelKind {
    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Csr => "cuSPARSE",
            KernelKind::Gnna => "GNNA",
            KernelKind::DrSpmm => "DR-SpMM",
        }
    }

    pub fn parse(s: &str) -> Option<KernelKind> {
        match s.to_ascii_lowercase().as_str() {
            "csr" | "cusparse" => Some(KernelKind::Csr),
            "gnna" | "gnnadvisor" => Some(KernelKind::Gnna),
            "dr" | "drspmm" | "dr-spmm" => Some(KernelKind::DrSpmm),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_kind_parse_and_name() {
        assert_eq!(KernelKind::parse("cusparse"), Some(KernelKind::Csr));
        assert_eq!(KernelKind::parse("GNNA"), Some(KernelKind::Gnna));
        assert_eq!(KernelKind::parse("dr-spmm"), Some(KernelKind::DrSpmm));
        assert_eq!(KernelKind::parse("???"), None);
        assert_eq!(KernelKind::DrSpmm.name(), "DR-SpMM");
    }
}
