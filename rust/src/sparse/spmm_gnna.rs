//! GNNAdvisor-analog SpMM (paper baseline [15]).
//!
//! GNNAdvisor tiles each row's neighbor list into fixed-size *neighbor
//! groups* and assigns one warp per group; threads within the warp split
//! the feature dimension ("dimension workers"), and groups belonging to the
//! same row accumulate into the output with atomics.
//!
//! The CPU analog keeps the execution semantics rather than hand-waving a
//! slowdown: groups are materialised as fixed 32-slot records processed in
//! lock-step (predicated slots compute a zero contribution, as idle CUDA
//! lanes occupy issue slots), and multi-group rows accumulate through
//! atomic f32 CAS. On the low-degree `pins`/`pinned` matrices most slots
//! are padding — the same under-utilisation that makes GNNA lose to
//! cuSPARSE on heterogeneous circuit graphs (paper Table 3).

use crate::graph::{Csc, Csr};
use crate::tensor::Matrix;
use crate::util::pool::{parallel_for_dynamic, SendPtr};
use std::sync::atomic::{AtomicU32, Ordering};

/// GNNAdvisor runtime parameters (its "2D workload management").
#[derive(Clone, Copy, Debug)]
pub struct GnnaConfig {
    /// Neighbor-group size (warp slots per group).
    pub group_size: usize,
    /// Feature chunk processed per lock-step round (dimension workers).
    pub dim_worker: usize,
}

impl Default for GnnaConfig {
    fn default() -> Self {
        // GNNAdvisor defaults: warp-width groups, 32 dimension workers.
        GnnaConfig { group_size: 32, dim_worker: 32 }
    }
}

/// One neighbor group: a row tile of ≤ `group_size` edges.
struct Group {
    row: u32,
    start: u32,
    len: u32,
    /// Whether this row is split across several groups (needs atomics).
    shared: bool,
}

fn build_groups(a: &Csr, cfg: &GnnaConfig) -> Vec<Group> {
    let mut groups = Vec::with_capacity(a.nnz() / cfg.group_size + a.rows);
    for r in 0..a.rows {
        let range = a.row_range(r);
        let deg = range.len();
        if deg == 0 {
            continue;
        }
        let n_groups = deg.div_ceil(cfg.group_size);
        for g in 0..n_groups {
            let start = range.start + g * cfg.group_size;
            let len = cfg.group_size.min(range.end - start);
            groups.push(Group {
                row: r as u32,
                start: start as u32,
                len: len as u32,
                shared: n_groups > 1,
            });
        }
    }
    groups
}

/// Forward: `Y = A · X` with neighbor-group scheduling.
pub fn spmm_gnna(a: &Csr, x: &Matrix, cfg: &GnnaConfig) -> Matrix {
    assert_eq!(a.cols, x.rows, "spmm_gnna: A cols {} vs X rows {}", a.cols, x.rows);
    let d = x.cols;
    let groups = build_groups(a, cfg);
    let mut y = Matrix::zeros(a.rows, d);
    let y_ptr = SendPtr(y.data.as_mut_ptr());
    let gs = cfg.group_size;
    parallel_for_dynamic(groups.len(), 8, |gi| {
        let g = &groups[gi];
        let row = g.row as usize;
        // Warp-local partial sum (the CUDA kernel's shared-memory tile).
        let mut partial = vec![0f32; d];
        // Lock-step over the fixed 32 slots; predicated slots contribute 0
        // but still occupy the round, mirroring idle-lane issue slots.
        for slot in 0..gs {
            let (av, j) = if slot < g.len as usize {
                let p = g.start as usize + slot;
                (a.values[p], a.indices[p] as usize)
            } else {
                (0.0f32, 0usize)
            };
            let xrow = x.row(j);
            // Dimension workers: process D in dim_worker-wide rounds.
            let mut c = 0;
            while c < d {
                let hi = (c + cfg.dim_worker).min(d);
                for cc in c..hi {
                    partial[cc] += av * xrow[cc];
                }
                c = hi;
            }
        }
        let yp = y_ptr;
        if g.shared {
            // Multi-group rows: atomic accumulate (f32 CAS on the bits).
            for (c, &v) in partial.iter().enumerate() {
                if v != 0.0 {
                    atomic_add_f32(unsafe { &*(yp.0.add(row * d + c) as *const AtomicU32) }, v);
                }
            }
        } else {
            // SAFETY: single-group rows are touched by exactly one group.
            let yrow = unsafe { std::slice::from_raw_parts_mut(yp.0.add(row * d), d) };
            for (o, &v) in yrow.iter_mut().zip(&partial) {
                *o += v;
            }
        }
    });
    y
}

/// Backward: `dX = Aᵀ · dY`, same group machinery over the CSC columns.
pub fn spmm_gnna_bwd(a_csc: &Csc, dy: &Matrix, cfg: &GnnaConfig) -> Matrix {
    assert_eq!(a_csc.rows, dy.rows, "spmm_gnna_bwd: A rows {} vs dY rows {}", a_csc.rows, dy.rows);
    // Treat the CSC as a CSR of the transpose and reuse the forward kernel.
    let at = Csr {
        rows: a_csc.cols,
        cols: a_csc.rows,
        indptr: a_csc.indptr.clone(),
        indices: a_csc.indices.clone(),
        values: a_csc.values.clone(),
    };
    spmm_gnna(&at, dy, cfg)
}

#[inline]
fn atomic_add_f32(cell: &AtomicU32, v: f32) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let new = f32::from_bits(cur) + v;
        match cell.compare_exchange_weak(
            cur,
            new.to_bits(),
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return,
            Err(actual) => cur = actual,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::spmm_csr::{spmm_csr, spmm_dense_ref};
    use crate::util::math::assert_allclose;
    use crate::util::rng::Rng;

    fn random_csr(rows: usize, cols: usize, max_deg: usize, rng: &mut Rng) -> Csr {
        let mut t = Vec::new();
        for r in 0..rows {
            for _ in 0..rng.range(0, max_deg + 1) {
                t.push((r, rng.below(cols), rng.uniform(0.5, 1.5)));
            }
        }
        Csr::from_triplets(rows, cols, &t)
    }

    #[test]
    fn matches_reference_small_groups() {
        let mut rng = Rng::new(1);
        let cfg = GnnaConfig { group_size: 4, dim_worker: 8 };
        for (m, n, d) in [(6, 5, 4), (30, 25, 16), (60, 60, 32)] {
            let a = random_csr(m, n, 10, &mut rng);
            let x = Matrix::randn(n, d, 1.0, &mut rng);
            let y = spmm_gnna(&a, &x, &cfg);
            assert_allclose(&y.data, &spmm_dense_ref(&a, &x).data, 1e-3, 1e-3);
        }
    }

    #[test]
    fn matches_reference_default_config() {
        let mut rng = Rng::new(2);
        let a = random_csr(50, 40, 40, &mut rng); // rows spanning groups
        let x = Matrix::randn(40, 24, 1.0, &mut rng);
        let y = spmm_gnna(&a, &x, &GnnaConfig::default());
        assert_allclose(&y.data, &spmm_csr(&a, &x).data, 1e-3, 1e-3);
    }

    #[test]
    fn multi_group_rows_accumulate_atomically() {
        // Single row with 100 neighbors and group_size 8 → 13 groups.
        let mut rng = Rng::new(3);
        let t: Vec<_> = (0..100).map(|c| (0usize, c, 1.0f32)).collect();
        let a = Csr::from_triplets(1, 100, &t);
        let x = Matrix::randn(100, 8, 1.0, &mut rng);
        let cfg = GnnaConfig { group_size: 8, dim_worker: 4 };
        let y = spmm_gnna(&a, &x, &cfg);
        assert_allclose(&y.data, &spmm_csr(&a, &x).data, 1e-3, 1e-3);
    }

    #[test]
    fn backward_matches_transpose_forward() {
        let mut rng = Rng::new(4);
        let a = random_csr(20, 15, 5, &mut rng);
        let dy = Matrix::randn(20, 12, 1.0, &mut rng);
        let cfg = GnnaConfig::default();
        let via_gnna = spmm_gnna_bwd(&a.to_csc(), &dy, &cfg);
        let via_t = spmm_csr(&a.transpose(), &dy);
        assert_allclose(&via_gnna.data, &via_t.data, 1e-3, 1e-3);
    }

    #[test]
    fn group_construction_counts() {
        let a = Csr::from_triplets(
            3,
            40,
            &(0..40usize)
                .map(|c| (if c < 33 { 0usize } else { 1 }, c, 1.0f32))
                .collect::<Vec<_>>(),
        );
        // row0: 33 nbrs → 2 groups (32+1); row1: 7 → 1 group; row2: 0 → none.
        let groups = build_groups(&a, &GnnaConfig::default());
        assert_eq!(groups.len(), 3);
        assert!(groups[0].shared && groups[1].shared);
        assert!(!groups[2].shared);
    }

    #[test]
    fn atomic_add_f32_sums() {
        let cell = AtomicU32::new(0f32.to_bits());
        for _ in 0..100 {
            atomic_add_f32(&cell, 0.5);
        }
        assert_eq!(f32::from_bits(cell.load(Ordering::Relaxed)), 50.0);
    }
}
