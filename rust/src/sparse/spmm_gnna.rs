//! GNNAdvisor-analog SpMM (paper baseline [15]).
//!
//! GNNAdvisor tiles each row's neighbor list into fixed-size *neighbor
//! groups* and assigns one warp per group; threads within the warp split
//! the feature dimension ("dimension workers"), and groups belonging to the
//! same row accumulate into the output with atomics.
//!
//! The CPU analog keeps the execution semantics rather than hand-waving a
//! slowdown: groups are materialised as fixed 32-slot records processed in
//! lock-step (predicated slots compute a zero contribution, as idle CUDA
//! lanes occupy issue slots), and multi-group rows accumulate through
//! atomic f32 CAS. On the low-degree `pins`/`pinned` matrices most slots
//! are padding — the same under-utilisation that makes GNNA lose to
//! cuSPARSE on heterogeneous circuit graphs (paper Table 3). Group
//! dispatch draws threads from the ambient
//! [`crate::util::pool::Budget`], so nested schedulers (fleet × lanes)
//! never multiply its worker count.

use crate::graph::{Csc, Csr};
use crate::sparse::simd::axpy;
use crate::tensor::Matrix;
use crate::util::pool::{parallel_for_dynamic, SendPtr};
use std::sync::atomic::{AtomicU32, Ordering};

/// GNNAdvisor runtime parameters (its "2D workload management").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GnnaConfig {
    /// Neighbor-group size (warp slots per group).
    pub group_size: usize,
    /// Feature chunk processed per lock-step round (dimension workers).
    pub dim_worker: usize,
}

impl Default for GnnaConfig {
    fn default() -> Self {
        // GNNAdvisor defaults: warp-width groups, 32 dimension workers.
        GnnaConfig { group_size: 32, dim_worker: 32 }
    }
}

/// One neighbor group: a row tile of ≤ `group_size` edges.
#[derive(Clone, Debug)]
struct Group {
    row: u32,
    start: u32,
    len: u32,
    /// Whether this row is split across several groups (needs atomics).
    shared: bool,
}

/// The materialised neighbor-group schedule for one adjacency — GNNAdvisor's
/// "2D workload management" precomputed once per graph (the `engine` layer
/// caches this in its [`KernelPlan`](crate::engine::KernelPlan) so group
/// construction is not paid per layer per step).
#[derive(Clone, Debug)]
pub struct NeighborGroups {
    groups: Vec<Group>,
    group_size: usize,
}

impl NeighborGroups {
    /// Tile every row's neighbor list into ≤ `cfg.group_size` groups.
    pub fn build(a: &Csr, cfg: &GnnaConfig) -> NeighborGroups {
        Self::build_from_indptr(&a.indptr, cfg)
    }

    /// Build from a row-pointer array alone (the only structure grouping
    /// needs). Passing a CSC's `indptr` yields the *transpose's* schedule —
    /// how the backward reuses the CSC without materialising a second copy.
    pub fn build_from_indptr(indptr: &[usize], cfg: &GnnaConfig) -> NeighborGroups {
        let rows = indptr.len().saturating_sub(1);
        let nnz = indptr.last().copied().unwrap_or(0);
        let mut groups = Vec::with_capacity(nnz / cfg.group_size + rows);
        for r in 0..rows {
            let (start_p, end_p) = (indptr[r], indptr[r + 1]);
            let deg = end_p - start_p;
            if deg == 0 {
                continue;
            }
            let n_groups = deg.div_ceil(cfg.group_size);
            for g in 0..n_groups {
                let start = start_p + g * cfg.group_size;
                let len = cfg.group_size.min(end_p - start);
                groups.push(Group {
                    row: r as u32,
                    start: start as u32,
                    len: len as u32,
                    shared: n_groups > 1,
                });
            }
        }
        NeighborGroups { groups, group_size: cfg.group_size }
    }

    pub fn len(&self) -> usize {
        self.groups.len()
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }

    /// Group size this schedule was tiled with (must match the executing
    /// [`GnnaConfig`]; `spmm_groups_core` asserts it).
    pub fn group_size(&self) -> usize {
        self.group_size
    }

    /// Flatten the schedule into `(row, start, len, shared)` tuples for
    /// serialization (the plan store writes these verbatim).
    pub fn export(&self) -> Vec<(u32, u32, u32, bool)> {
        self.groups.iter().map(|g| (g.row, g.start, g.len, g.shared)).collect()
    }

    /// Rebuild a schedule from [`export`](Self::export)ed tuples. The caller
    /// is responsible for pairing it with the same `group_size` config it
    /// was built under; the execute path re-checks that invariant.
    pub fn from_parts(group_size: usize, parts: &[(u32, u32, u32, bool)]) -> NeighborGroups {
        let groups = parts
            .iter()
            .map(|&(row, start, len, shared)| Group { row, start, len, shared })
            .collect();
        NeighborGroups { groups, group_size }
    }
}

/// Forward: `Y = A · X` with neighbor-group scheduling (builds the group
/// schedule ad hoc; planned callers use [`spmm_gnna_planned`]).
pub fn spmm_gnna(a: &Csr, x: &Matrix, cfg: &GnnaConfig) -> Matrix {
    let groups = NeighborGroups::build(a, cfg);
    spmm_gnna_planned(a, x, cfg, &groups)
}

/// Forward with a prebuilt group schedule (the plan/execute hot path).
pub fn spmm_gnna_planned(
    a: &Csr,
    x: &Matrix,
    cfg: &GnnaConfig,
    schedule: &NeighborGroups,
) -> Matrix {
    assert_eq!(a.cols, x.rows, "spmm_gnna: A cols {} vs X rows {}", a.cols, x.rows);
    spmm_groups_core(a.rows, &a.values, &a.indices, x, cfg, schedule)
}

/// The lock-step group kernel over raw CSR/CSC storage. `out_rows` is the
/// destination row count; `values`/`indices` are the edge arrays the
/// schedule's group offsets index into.
fn spmm_groups_core(
    out_rows: usize,
    values: &[f32],
    indices: &[u32],
    x: &Matrix,
    cfg: &GnnaConfig,
    schedule: &NeighborGroups,
) -> Matrix {
    assert_eq!(
        schedule.group_size, cfg.group_size,
        "spmm_gnna: schedule built with group_size {}, config says {}",
        schedule.group_size, cfg.group_size
    );
    let d = x.cols;
    let groups = &schedule.groups;
    let mut y = Matrix::zeros(out_rows, d);
    let y_ptr = SendPtr(y.data.as_mut_ptr());
    let gs = cfg.group_size;
    parallel_for_dynamic(groups.len(), 8, |gi| {
        let g = &groups[gi];
        let row = g.row as usize;
        // Warp-local partial sum (the CUDA kernel's shared-memory tile).
        let mut partial = vec![0f32; d];
        // Lock-step over the fixed 32 slots; predicated slots contribute 0
        // but still occupy the round, mirroring idle-lane issue slots.
        for slot in 0..gs {
            let (av, j) = if slot < g.len as usize {
                let p = g.start as usize + slot;
                (values[p], indices[p] as usize)
            } else {
                (0.0f32, 0usize)
            };
            let xrow = x.row(j);
            // Dimension workers: process D in dim_worker-wide rounds.
            let mut c = 0;
            while c < d {
                let hi = (c + cfg.dim_worker).min(d);
                axpy(&mut partial[c..hi], av, &xrow[c..hi]);
                c = hi;
            }
        }
        let yp = y_ptr;
        if g.shared {
            // Multi-group rows: atomic accumulate (f32 CAS on the bits).
            for (c, &v) in partial.iter().enumerate() {
                if v != 0.0 {
                    // SAFETY: shared rows are written by several groups
                    // concurrently, so *every* access to them goes through
                    // this AtomicU32 view of the f32 cell — no plain
                    // reference to a shared row exists while the dispatch
                    // runs (the non-shared branch below handles only rows
                    // with a single owner). f32 and AtomicU32 have the
                    // same size/alignment; y outlives the scoped threads.
                    let cell = unsafe { &*(yp.0.add(row * d + c) as *const AtomicU32) };
                    atomic_add_f32(cell, v);
                }
            }
        } else {
            // SAFETY: single-group rows are touched by exactly one group.
            let yrow = unsafe { std::slice::from_raw_parts_mut(yp.0.add(row * d), d) };
            for (o, &v) in yrow.iter_mut().zip(&partial) {
                *o += v;
            }
        }
    });
    y
}

/// Backward: `dX = Aᵀ · dY`, same group machinery over the CSC columns
/// (builds the transpose schedule ad hoc; planned callers use
/// [`spmm_gnna_bwd_planned`]).
pub fn spmm_gnna_bwd(a_csc: &Csc, dy: &Matrix, cfg: &GnnaConfig) -> Matrix {
    let schedule = NeighborGroups::build_from_indptr(&a_csc.indptr, cfg);
    spmm_gnna_bwd_planned(a_csc, dy, cfg, &schedule)
}

/// Backward with a prebuilt transpose schedule (see
/// [`NeighborGroups::build_from_indptr`]): the CSC's column arrays *are*
/// the transpose's CSR arrays, so no second copy of the matrix is needed.
pub fn spmm_gnna_bwd_planned(
    a_csc: &Csc,
    dy: &Matrix,
    cfg: &GnnaConfig,
    schedule: &NeighborGroups,
) -> Matrix {
    assert_eq!(a_csc.rows, dy.rows, "spmm_gnna_bwd: A rows {} vs dY rows {}", a_csc.rows, dy.rows);
    spmm_groups_core(a_csc.cols, &a_csc.values, &a_csc.indices, dy, cfg, schedule)
}

#[inline]
fn atomic_add_f32(cell: &AtomicU32, v: f32) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let new = f32::from_bits(cur) + v;
        match cell.compare_exchange_weak(
            cur,
            new.to_bits(),
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return,
            Err(actual) => cur = actual,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::spmm_csr::{spmm_csr, spmm_dense_ref};
    use crate::util::math::assert_allclose;
    use crate::util::rng::Rng;

    fn random_csr(rows: usize, cols: usize, max_deg: usize, rng: &mut Rng) -> Csr {
        let mut t = Vec::new();
        for r in 0..rows {
            for _ in 0..rng.range(0, max_deg + 1) {
                t.push((r, rng.below(cols), rng.uniform(0.5, 1.5)));
            }
        }
        Csr::from_triplets(rows, cols, &t)
    }

    #[test]
    fn matches_reference_small_groups() {
        let mut rng = Rng::new(1);
        let cfg = GnnaConfig { group_size: 4, dim_worker: 8 };
        for (m, n, d) in [(6, 5, 4), (30, 25, 16), (60, 60, 32)] {
            let a = random_csr(m, n, 10, &mut rng);
            let x = Matrix::randn(n, d, 1.0, &mut rng);
            let y = spmm_gnna(&a, &x, &cfg);
            assert_allclose(&y.data, &spmm_dense_ref(&a, &x).data, 1e-3, 1e-3);
        }
    }

    #[test]
    fn matches_reference_default_config() {
        let mut rng = Rng::new(2);
        let a = random_csr(50, 40, 40, &mut rng); // rows spanning groups
        let x = Matrix::randn(40, 24, 1.0, &mut rng);
        let y = spmm_gnna(&a, &x, &GnnaConfig::default());
        assert_allclose(&y.data, &spmm_csr(&a, &x).data, 1e-3, 1e-3);
    }

    #[test]
    fn multi_group_rows_accumulate_atomically() {
        // Single row with 100 neighbors and group_size 8 → 13 groups.
        let mut rng = Rng::new(3);
        let t: Vec<_> = (0..100).map(|c| (0usize, c, 1.0f32)).collect();
        let a = Csr::from_triplets(1, 100, &t);
        let x = Matrix::randn(100, 8, 1.0, &mut rng);
        let cfg = GnnaConfig { group_size: 8, dim_worker: 4 };
        let y = spmm_gnna(&a, &x, &cfg);
        assert_allclose(&y.data, &spmm_csr(&a, &x).data, 1e-3, 1e-3);
    }

    #[test]
    fn backward_matches_transpose_forward() {
        let mut rng = Rng::new(4);
        let a = random_csr(20, 15, 5, &mut rng);
        let dy = Matrix::randn(20, 12, 1.0, &mut rng);
        let cfg = GnnaConfig::default();
        let via_gnna = spmm_gnna_bwd(&a.to_csc(), &dy, &cfg);
        let via_t = spmm_csr(&a.transpose(), &dy);
        assert_allclose(&via_gnna.data, &via_t.data, 1e-3, 1e-3);
    }

    #[test]
    fn group_construction_counts() {
        let a = Csr::from_triplets(
            3,
            40,
            &(0..40usize)
                .map(|c| (if c < 33 { 0usize } else { 1 }, c, 1.0f32))
                .collect::<Vec<_>>(),
        );
        // row0: 33 nbrs → 2 groups (32+1); row1: 7 → 1 group; row2: 0 → none.
        let schedule = NeighborGroups::build(&a, &GnnaConfig::default());
        assert_eq!(schedule.len(), 3);
        assert!(schedule.groups[0].shared && schedule.groups[1].shared);
        assert!(!schedule.groups[2].shared);
    }

    #[test]
    fn planned_forward_matches_ad_hoc() {
        let mut rng = Rng::new(5);
        let a = random_csr(25, 20, 6, &mut rng);
        let x = Matrix::randn(20, 10, 1.0, &mut rng);
        let cfg = GnnaConfig { group_size: 4, dim_worker: 8 };
        let schedule = NeighborGroups::build(&a, &cfg);
        let y1 = spmm_gnna(&a, &x, &cfg);
        let y2 = spmm_gnna_planned(&a, &x, &cfg, &schedule);
        assert_eq!(y1.data, y2.data);
    }

    #[test]
    #[should_panic(expected = "schedule built with group_size")]
    fn mismatched_schedule_panics() {
        let a = Csr::from_triplets(2, 2, &[(0, 1, 1.0)]);
        let x = Matrix::ones(2, 3);
        let schedule = NeighborGroups::build(&a, &GnnaConfig { group_size: 4, dim_worker: 8 });
        spmm_gnna_planned(&a, &x, &GnnaConfig::default(), &schedule);
    }

    #[test]
    fn export_round_trips_and_executes_identically() {
        let mut rng = Rng::new(6);
        let a = random_csr(25, 20, 12, &mut rng);
        let x = Matrix::randn(20, 10, 1.0, &mut rng);
        let cfg = GnnaConfig { group_size: 8, dim_worker: 8 };
        let schedule = NeighborGroups::build(&a, &cfg);
        let rebuilt = NeighborGroups::from_parts(schedule.group_size(), &schedule.export());
        assert_eq!(rebuilt.len(), schedule.len());
        assert_eq!(rebuilt.group_size(), cfg.group_size);
        assert_eq!(rebuilt.export(), schedule.export());
        let y1 = spmm_gnna_planned(&a, &x, &cfg, &schedule);
        let y2 = spmm_gnna_planned(&a, &x, &cfg, &rebuilt);
        assert_eq!(y1.data, y2.data);
    }

    #[test]
    fn atomic_add_f32_sums() {
        let cell = AtomicU32::new(0f32.to_bits());
        for _ in 0..100 {
            atomic_add_f32(&cell, 0.5);
        }
        assert_eq!(f32::from_bits(cell.load(Ordering::Relaxed)), 50.0);
    }
}
