//! ELL (ELLPACK) SpMM: width-capped dense slot layout + lossless overflow.
//!
//! ELL stores each row's neighbors in a fixed number of dense slots
//! (`rows × width` index/value arrays), so the inner loop is branch-free:
//! every row executes the same `width` slot iterations, padding slots
//! contribute `0 · x` — the Pallas/accelerator-style layout the AOT padded
//! path (`runtime::pad::to_ell`) already feeds PJRT. The classic ELL
//! failure mode is the width cap: a GPU bucket truncates over-wide rows,
//! which silently drops edges. Training must not drop edges, so
//! [`EllLayout`] generalizes the bucket layout into a **lossless** one: the
//! dense part is capped near the average degree and everything beyond the
//! cap goes to a CSR-style overflow side-list walked after the dense pass.
//! On the low-variance dense profiles `auto` routes here (max ≈ avg), the
//! overflow is empty and the whole matrix runs the branch-free loop.
//!
//! Numerics: each output element accumulates its row's neighbors in CSR
//! order (dense slots are the row prefix, the overflow is the row tail), so
//! ELL matches [`spmm_csr`](crate::sparse::spmm_csr) per element up to the
//! sign of zero (padding slots add `±0.0`, which can turn an exact `-0.0`
//! sum into `+0.0` but never changes a nonzero value).

use crate::graph::Csr;
use crate::sparse::simd::axpy;
use crate::tensor::Matrix;
use crate::util::pool::{parallel_for_chunks, SendPtr};

/// Dense-slot cap as a multiple of the average degree: rows keep at most
/// `ceil(ELL_WIDTH_CAP_FACTOR × avg_degree)` dense slots (at least 1), the
/// rest overflows. At the `auto` policy's admission bound (max/avg ≤ 1.5)
/// every row fits its dense slots, so padding waste is bounded by the cap
/// factor and the overflow list stays empty.
pub const ELL_WIDTH_CAP_FACTOR: f64 = 2.0;

/// A width-capped, lossless ELL encoding of one adjacency.
#[derive(Clone, Debug, PartialEq)]
pub struct EllLayout {
    pub rows: usize,
    pub cols: usize,
    /// Dense slots per row (0 for an empty adjacency).
    pub width: usize,
    /// `rows × width` neighbor indices; padding slots point at column 0.
    pub idx: Vec<u32>,
    /// `rows × width` edge values; padding slots hold 0.0.
    pub val: Vec<f32>,
    /// CSR-style overflow row pointers (`rows + 1` entries) for edges
    /// beyond `width` — the lossless tail of over-wide rows.
    pub ofl_indptr: Vec<usize>,
    pub ofl_indices: Vec<u32>,
    pub ofl_values: Vec<f32>,
}

impl EllLayout {
    /// The width the plan-time layout uses for an adjacency: the max degree,
    /// capped near the average so one evil row cannot inflate every row's
    /// slot count (its tail lands in the overflow list instead).
    pub fn capped_width(adj: &Csr) -> usize {
        let max_deg = adj.max_degree();
        if max_deg == 0 {
            return 0;
        }
        let cap = (adj.avg_degree() * ELL_WIDTH_CAP_FACTOR).ceil() as usize;
        max_deg.min(cap.max(1))
    }

    /// Encode an adjacency at a given dense width. Every edge lands either
    /// in a dense slot (the first `width` of its row, CSR order) or in the
    /// overflow list (the rest of the row) — nothing is dropped.
    pub fn build(adj: &Csr, width: usize) -> EllLayout {
        let rows = adj.rows;
        let mut idx = vec![0u32; rows * width];
        let mut val = vec![0f32; rows * width];
        let mut ofl_indptr = Vec::with_capacity(rows + 1);
        let mut ofl_indices = Vec::new();
        let mut ofl_values = Vec::new();
        ofl_indptr.push(0);
        for r in 0..rows {
            for (slot, p) in adj.row_range(r).enumerate() {
                if slot < width {
                    idx[r * width + slot] = adj.indices[p];
                    val[r * width + slot] = adj.values[p];
                } else {
                    ofl_indices.push(adj.indices[p]);
                    ofl_values.push(adj.values[p]);
                }
            }
            ofl_indptr.push(ofl_indices.len());
        }
        EllLayout {
            rows,
            cols: adj.cols,
            width,
            idx,
            val,
            ofl_indptr,
            ofl_indices,
            ofl_values,
        }
    }

    /// Edges held in the overflow side-list (0 on low-variance profiles).
    pub fn overflow_nnz(&self) -> usize {
        self.ofl_indptr.last().copied().unwrap_or(0)
    }
}

/// Forward: `Y = A · X` over the ELL layout — branch-free dense slots
/// first, then the (usually empty) overflow tail per row.
pub fn spmm_ell(ell: &EllLayout, x: &Matrix) -> Matrix {
    assert_eq!(ell.cols, x.rows, "spmm_ell: A cols {} vs X rows {}", ell.cols, x.rows);
    let d = x.cols;
    let w = ell.width;
    let mut y = Matrix::zeros(ell.rows, d);
    let y_ptr = SendPtr(y.data.as_mut_ptr());
    parallel_for_chunks(ell.rows, |lo, hi| {
        let yp = y_ptr;
        for i in lo..hi {
            // SAFETY: row i written only by this worker's chunk.
            let yrow = unsafe { std::slice::from_raw_parts_mut(yp.0.add(i * d), d) };
            // Branch-free over the fixed slots: padding contributes 0 · x.
            for s in 0..w {
                let j = ell.idx[i * w + s] as usize;
                axpy(yrow, ell.val[i * w + s], x.row(j));
            }
            for p in ell.ofl_indptr[i]..ell.ofl_indptr[i + 1] {
                axpy(yrow, ell.ofl_values[p], x.row(ell.ofl_indices[p] as usize));
            }
        }
    });
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::spmm_csr::{spmm_csr, spmm_dense_ref};
    use crate::util::math::assert_allclose;
    use crate::util::rng::Rng;

    fn random_csr(rows: usize, cols: usize, max_deg: usize, rng: &mut Rng) -> Csr {
        let mut t = Vec::new();
        for r in 0..rows {
            for _ in 0..rng.range(0, max_deg + 1) {
                t.push((r, rng.below(cols), rng.uniform(0.5, 1.5)));
            }
        }
        Csr::from_triplets(rows, cols, &t)
    }

    #[test]
    fn layout_is_lossless_at_any_width() {
        let mut rng = Rng::new(1);
        let adj = random_csr(20, 15, 9, &mut rng);
        for width in [0usize, 1, 2, 4, 9, 16] {
            let ell = EllLayout::build(&adj, width);
            let dense_kept: usize =
                (0..adj.rows).map(|r| adj.row_range(r).len().min(width)).sum();
            assert_eq!(dense_kept + ell.overflow_nnz(), adj.nnz(), "width {width}");
            assert_eq!(ell.idx.len(), adj.rows * width);
            assert_eq!(ell.ofl_indptr.len(), adj.rows + 1);
        }
    }

    #[test]
    fn capped_width_tracks_avg_not_hubs() {
        // Uniform rows: width = the common degree, no overflow.
        let uniform = Csr::from_triplets(
            4,
            8,
            &(0..4usize)
                .flat_map(|r| (0..3usize).map(move |c| (r, c, 1.0f32)))
                .collect::<Vec<_>>(),
        );
        assert_eq!(EllLayout::capped_width(&uniform), 3);
        assert_eq!(
            EllLayout::build(&uniform, EllLayout::capped_width(&uniform)).overflow_nnz(),
            0
        );
        // One hub row: cap stays near the average, the hub tail overflows.
        let mut t: Vec<(usize, usize, f32)> =
            (0..30usize).map(|c| (0usize, c, 1.0f32)).collect();
        for r in 1..10 {
            t.push((r, 0, 1.0));
        }
        let skewed = Csr::from_triplets(10, 30, &t);
        let w = EllLayout::capped_width(&skewed);
        assert!(w < 30, "cap must not follow the hub row (got {w})");
        let ell = EllLayout::build(&skewed, w);
        assert_eq!(ell.overflow_nnz(), 30 - w);
        // Empty adjacency → zero width.
        assert_eq!(EllLayout::capped_width(&Csr::from_triplets(3, 3, &[])), 0);
    }

    #[test]
    fn forward_matches_csr_and_dense_reference() {
        let mut rng = Rng::new(2);
        for (m, n, d, w) in [(5, 7, 3, 2), (30, 25, 16, 4), (40, 40, 33, 6)] {
            let a = random_csr(m, n, 8, &mut rng);
            let x = Matrix::randn(n, d, 1.0, &mut rng);
            let ell = EllLayout::build(&a, w);
            let got = spmm_ell(&ell, &x);
            assert_allclose(&got.data, &spmm_dense_ref(&a, &x).data, 1e-4, 1e-4);
            assert_allclose(&got.data, &spmm_csr(&a, &x).data, 1e-6, 1e-6);
        }
    }

    #[test]
    fn empty_and_padded_rows_stay_zero() {
        let a = Csr::from_triplets(3, 2, &[(0, 0, 2.0)]);
        let ell = EllLayout::build(&a, EllLayout::capped_width(&a));
        let x = Matrix::ones(2, 4);
        let y = spmm_ell(&ell, &x);
        assert_eq!(y.row(0), &[2.0, 2.0, 2.0, 2.0]);
        assert_eq!(y.row(1), &[0.0; 4]);
        assert_eq!(y.row(2), &[0.0; 4]);
    }

    #[test]
    #[should_panic(expected = "spmm_ell")]
    fn shape_mismatch_panics() {
        let ell = EllLayout::build(&Csr::from_triplets(2, 3, &[]), 0);
        spmm_ell(&ell, &Matrix::zeros(4, 2));
    }
}
