//! DR-SpMM backward kernel (paper §3.3, Alg. 2).
//!
//! Computes `dX = Aᵀ · dY` by traversing the adjacency in CSC order
//! (column-major neighbor indexing — Alg. 2 stage 1) and *reusing the CBSR
//! indices preserved from the forward pass*: since the forward input was
//! k-sparse, only the k kept coordinates of each source row can receive
//! gradient, so the kernel gathers exactly `k` of `D` columns per edge.
//! The compressed gradient comes back in CBSR layout aligned with the
//! forward activation, ready for the D-ReLU backward mask.
//!
//! Parallelism comes from `parallel_for_dynamic`, which sizes itself to
//! the caller's ambient thread [`crate::util::pool::Budget`] — inside a
//! fleet worker or an edge lane this kernel uses that scope's share, not
//! the whole machine.

use crate::graph::{Cbsr, Csc};
use crate::tensor::Matrix;
use crate::util::pool::{parallel_for_dynamic, SendPtr};

/// Backward DR-SpMM producing the compressed gradient.
///
/// * `a_csc` — the forward adjacency (`M×N`) in CSC form
/// * `dy` — dense upstream gradient (`M×D`)
/// * `fwd` — the forward-pass CBSR of the source embedding (`N` rows),
///   whose indices select which columns receive gradient.
///
/// Returns a CBSR with the same (n, dim, k, indices) as `fwd` and
/// `values[j,t] = Σ_{i∈Nᵀ(j)} A_ij · dY[i, idx_{j,t}]`.
pub fn dr_spmm_bwd(a_csc: &Csc, dy: &Matrix, fwd: &Cbsr) -> Cbsr {
    assert_eq!(a_csc.rows, dy.rows, "dr_spmm_bwd: A rows {} vs dY rows {}", a_csc.rows, dy.rows);
    assert_eq!(a_csc.cols, fwd.n, "dr_spmm_bwd: A cols {} vs CBSR rows {}", a_csc.cols, fwd.n);
    assert_eq!(dy.cols, fwd.dim, "dr_spmm_bwd: dY width {} vs CBSR dim {}", dy.cols, fwd.dim);
    let k = fwd.k;
    let mut out = Cbsr {
        n: fwd.n,
        dim: fwd.dim,
        k,
        values: vec![0.0; fwd.n * k],
        indices: fwd.indices.clone(),
    };
    let vptr = SendPtr(out.values.as_mut_ptr());
    // Dynamic dispatch: column degrees are as skewed as row degrees.
    parallel_for_dynamic(a_csc.cols, 32, |j| {
        let vp = vptr;
        // SAFETY: column j's k-slot output owned exclusively by this call.
        let grad = unsafe { std::slice::from_raw_parts_mut(vp.0.add(j * k), k) };
        let idxs = fwd.row_indices(j);
        // Gather only the k forward-kept coordinates per incident edge.
        // SAFETY: CBSR indices validated < D; raw pointers drop bounds
        // checks and per-edge slice construction (§Perf L3-1/L3-3).
        unsafe {
            let ci = a_csc.indices.as_ptr();
            let cv = a_csc.values.as_ptr();
            let dyp = dy.data.as_ptr();
            let d = dy.cols;
            let gp = grad.as_mut_ptr();
            let ip = idxs.as_ptr();
            for p in a_csc.col_range(j) {
                let i = *ci.add(p) as usize;
                let av = *cv.add(p);
                let dyrow = dyp.add(i * d);
                let mut t = 0;
                while t + 4 <= k {
                    *gp.add(t) += av * *dyrow.add(*ip.add(t) as usize);
                    *gp.add(t + 1) += av * *dyrow.add(*ip.add(t + 1) as usize);
                    *gp.add(t + 2) += av * *dyrow.add(*ip.add(t + 2) as usize);
                    *gp.add(t + 3) += av * *dyrow.add(*ip.add(t + 3) as usize);
                    t += 4;
                }
                while t < k {
                    *gp.add(t) += av * *dyrow.add(*ip.add(t) as usize);
                    t += 1;
                }
            }
        }
    });
    out
}

/// Dense-output variant: decompressed `dX` (`N×D`), used where the consumer
/// needs the dense gradient (e.g. feeding a dense Linear backward).
pub fn dr_spmm_bwd_dense(a_csc: &Csc, dy: &Matrix, fwd: &Cbsr) -> Matrix {
    dr_spmm_bwd(a_csc, dy, fwd).to_dense()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;
    use crate::sparse::drelu::drelu;
    use crate::sparse::spmm_csr::{spmm_csr, spmm_csr_bwd};
    use crate::util::math::assert_allclose;
    use crate::util::rng::Rng;

    fn random_csr(rows: usize, cols: usize, max_deg: usize, rng: &mut Rng) -> Csr {
        let mut t = Vec::new();
        for r in 0..rows {
            for _ in 0..rng.range(1, max_deg + 1) {
                t.push((r, rng.below(cols), rng.uniform(0.5, 1.5)));
            }
        }
        Csr::from_triplets(rows, cols, &t)
    }

    /// dX_dense masked to the forward CBSR indices must equal the full
    /// dense backward Aᵀ·dY at those positions — and be zero elsewhere.
    #[test]
    fn compressed_grad_matches_masked_dense_backward() {
        let mut rng = Rng::new(1);
        for (m, n, d, k) in [(10, 8, 8, 3), (40, 30, 32, 8), (80, 60, 64, 16)] {
            let a = random_csr(m, n, 5, &mut rng);
            let x = Matrix::randn(n, d, 1.0, &mut rng);
            let fwd = drelu(&x, k);
            let dy = Matrix::randn(m, d, 1.0, &mut rng);
            let full = spmm_csr_bwd(&a.to_csc(), &dy); // N×D dense Aᵀ·dY
            let comp = dr_spmm_bwd(&a.to_csc(), &dy, &fwd);
            for j in 0..n {
                for (t, &c) in comp.row_indices(j).iter().enumerate() {
                    let got = comp.row_values(j)[t];
                    let want = full.at(j, c as usize);
                    assert!(
                        (got - want).abs() <= 1e-4 + 1e-4 * want.abs(),
                        "row {j} slot {t}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn dense_variant_zero_outside_mask() {
        let mut rng = Rng::new(2);
        let a = random_csr(12, 10, 4, &mut rng);
        let x = Matrix::randn(10, 16, 1.0, &mut rng);
        let fwd = drelu(&x, 4);
        let dy = Matrix::randn(12, 16, 1.0, &mut rng);
        let dx = dr_spmm_bwd_dense(&a.to_csc(), &dy, &fwd);
        for j in 0..10 {
            let kept: Vec<usize> = fwd.row_indices(j).iter().map(|&c| c as usize).collect();
            for c in 0..16 {
                if !kept.contains(&c) {
                    assert_eq!(dx.at(j, c), 0.0, "row {j} col {c} must be masked");
                }
            }
        }
    }

    /// Chain rule check: forward through dr_spmm then sum-loss; the
    /// compressed backward must equal the finite-difference gradient on the
    /// kept values.
    #[test]
    fn finite_difference_gradient() {
        let mut rng = Rng::new(3);
        let a = random_csr(6, 5, 3, &mut rng);
        let x = Matrix::randn(5, 8, 1.0, &mut rng);
        let fwd = drelu(&x, 3);
        let buckets = crate::sparse::warp::DegreeBuckets::build(&a);
        // loss = sum(Y); dY = ones.
        let dy = Matrix::ones(6, 8);
        let grad = dr_spmm_bwd(&a.to_csc(), &dy, &fwd);
        let eps = 1e-2f32;
        for j in 0..5 {
            for t in 0..3 {
                let mut plus = fwd.clone();
                plus.values[j * 3 + t] += eps;
                let mut minus = fwd.clone();
                minus.values[j * 3 + t] -= eps;
                let yp: f32 = crate::sparse::dr_spmm(&a, &plus, &buckets).data.iter().sum();
                let ym: f32 = crate::sparse::dr_spmm(&a, &minus, &buckets).data.iter().sum();
                let fd = (yp - ym) / (2.0 * eps);
                let an = grad.values[j * 3 + t];
                assert!((fd - an).abs() < 1e-2, "({j},{t}): fd {fd} vs analytic {an}");
            }
        }
    }

    #[test]
    fn transpose_consistency_with_forward() {
        // <A·X, dY> == <X, Aᵀ·dY> restricted to the CBSR support.
        let mut rng = Rng::new(4);
        let a = random_csr(15, 12, 4, &mut rng);
        let x = Matrix::randn(12, 10, 1.0, &mut rng);
        let fwd = drelu(&x, 4);
        let buckets = crate::sparse::warp::DegreeBuckets::build(&a);
        let y = crate::sparse::dr_spmm(&a, &fwd, &buckets);
        let dy = Matrix::randn(15, 10, 1.0, &mut rng);
        let lhs: f32 = y.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum();
        let gx = dr_spmm_bwd(&a.to_csc(), &dy, &fwd);
        let rhs: f32 = gx.values.iter().zip(&fwd.values).map(|(g, v)| g * v).sum();
        assert!((lhs - rhs).abs() < 1e-2 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }

    #[test]
    fn backward_matches_cusparse_on_full_k() {
        let mut rng = Rng::new(5);
        let a = random_csr(10, 8, 3, &mut rng);
        let x = Matrix::randn(8, 6, 1.0, &mut rng);
        let fwd = drelu(&x, 6); // k = D: no masking
        let dy = Matrix::randn(10, 6, 1.0, &mut rng);
        let dense = spmm_csr_bwd(&a.to_csc(), &dy);
        let comp = dr_spmm_bwd_dense(&a.to_csc(), &dy, &fwd);
        assert_allclose(&comp.data, &dense.data, 1e-4, 1e-4);
    }

    #[test]
    fn forward_backward_roundtrip_on_spmm() {
        // sanity: spmm_csr forward equals dr path with k=D even via spmm.
        let mut rng = Rng::new(6);
        let a = random_csr(7, 7, 3, &mut rng);
        let x = Matrix::randn(7, 5, 1.0, &mut rng);
        let y1 = spmm_csr(&a, &x);
        let fwd = drelu(&x, 5);
        let buckets = crate::sparse::warp::DegreeBuckets::build(&a);
        let y2 = crate::sparse::dr_spmm(&a, &fwd, &buckets);
        assert_allclose(&y1.data, &y2.data, 1e-4, 1e-4);
    }
}
