//! drcg-lint: the repo's in-tree static-analysis pass (`docs/ANALYSIS.md`).
//!
//! The hot paths that make this reproduction *provably* deterministic —
//! bit-identical golden traces across the sequential, fleet, and pipelined
//! schedules — are hand-rolled `unsafe` disjoint-row writes and hand-rolled
//! concurrency primitives. This module machine-checks the invariants those
//! paths rely on, as five greppable rules over `rust/src/**`:
//!
//! * **R1** — every `unsafe` block / `unsafe impl` carries a `// SAFETY:`
//!   comment (within [`SAFETY_WINDOW`] lines above it) stating its
//!   disjointness contract.
//! * **R2** — raw fan-out is confined to `util::pool`: `thread::spawn` /
//!   `thread::scope` and new `unsafe impl Send`/`Sync` capabilities appear
//!   nowhere else; everything goes through the budgeted primitives.
//! * **R3** — one mutex-poisoning policy: locks recover with
//!   `unwrap_or_else(|e| e.into_inner())` (as `fleet::cache` always has);
//!   bare `.lock().unwrap()` / `.lock().expect(...)` is rejected.
//! * **R4** — no nondeterminism sources (`HashMap`/`HashSet`,
//!   `Instant::now`, thread-id-dependent logic) in the kernel / reduction /
//!   hash paths that feed the golden traces ([`R4_SCOPED_DIRS`]).
//! * **R5** — registry/plan-store exhaustiveness: every `KernelSpec`
//!   variant declared in `engine/registry.rs` has a serializer/validation
//!   arm (`KernelSpec::<Variant>`) in `engine/planstore.rs`.
//!
//! `#[cfg(test)]` and `#[cfg(loom)]` regions are exempt from R2–R4 (tests
//! may spawn scratch threads and use wall clocks; loom models use loom's
//! own thread API), but **not** from R1 — unsafe code is documented
//! everywhere. Findings that are individually justified live in the
//! allowlist file (`rust/lint-allow.txt`, format in [`Allowlist::parse`]);
//! stale entries are themselves errors, so the allowlist can only shrink
//! unless a new justification is written down.
//!
//! The scanner is deliberately line-based and std-only (the offline build
//! has no syn/proc-macro stack): it strips `//` comments with a
//! string-literal-aware scan, tracks brace depth for cfg regions, and
//! matches rule patterns textually. `tests/lint_selftest.rs` pins both
//! directions of every rule against fixture files. The scanner skips
//! `src/analysis/` and `src/bin/` — this module's own rule tables and the
//! CLI necessarily spell out the forbidden patterns.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// How many lines above an `unsafe` occurrence R1 searches for `SAFETY:`
/// (prose contracts run several comment lines; the marker sits on the
/// first of them).
pub const SAFETY_WINDOW: usize = 8;

/// Directories (relative to `src/`) whose non-test code feeds the golden
/// traces and therefore must be free of R4 nondeterminism sources.
pub const R4_SCOPED_DIRS: &[&str] =
    &["sparse/", "tensor/", "nn/", "graph/", "engine/", "train/"];

/// The one module allowed to spawn threads and mint Send/Sync capabilities.
const POOL_PATH: &str = "util/pool.rs";

/// One lint finding. Renders as `path:line: RULE: message` — greppable by
/// rule id, stable across runs (files are scanned in sorted order).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub rule: &'static str,
    /// Path relative to the scanned source root (e.g. `sparse/drelu.rs`).
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
    /// The trimmed offending source line (allowlist needles match this).
    pub excerpt: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {}: {}", self.path, self.line, self.rule, self.message)
    }
}

/// One justified exemption: `<rule> <path-suffix> <needle> -- <reason>`.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub rule: String,
    pub path: String,
    pub needle: String,
    pub reason: String,
}

/// The parsed allowlist file.
#[derive(Debug, Default)]
pub struct Allowlist {
    pub entries: Vec<AllowEntry>,
}

impl Allowlist {
    pub fn empty() -> Allowlist {
        Allowlist { entries: Vec::new() }
    }

    /// Parse the allowlist format: one entry per line,
    ///
    /// ```text
    /// # comment / blank lines ignored
    /// R2 sched/pipeline.rs std::thread::scope -- stages spawn through pool::spawn_worker
    /// ```
    ///
    /// `rule` is the rule id, `path-suffix` matches the end of the
    /// diagnostic's path, `needle` must occur in the offending source
    /// line, and the reason after `--` is mandatory — an exemption
    /// without a written justification is rejected.
    pub fn parse(text: &str) -> Result<Allowlist, String> {
        let mut entries = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (head, reason) = line
                .split_once(" -- ")
                .ok_or_else(|| format!("allowlist line {}: missing ` -- <reason>`", i + 1))?;
            let mut parts = head.split_whitespace();
            let (rule, path, needle) = match (parts.next(), parts.next(), parts.next()) {
                (Some(r), Some(p), Some(n)) => (r, p, n),
                _ => {
                    return Err(format!(
                        "allowlist line {}: expected `<rule> <path> <needle> -- <reason>`",
                        i + 1
                    ))
                }
            };
            if parts.next().is_some() {
                return Err(format!(
                    "allowlist line {}: needle must be a single token (got extra fields)",
                    i + 1
                ));
            }
            if reason.trim().is_empty() {
                return Err(format!("allowlist line {}: empty reason", i + 1));
            }
            entries.push(AllowEntry {
                rule: rule.to_string(),
                path: path.to_string(),
                needle: needle.to_string(),
                reason: reason.trim().to_string(),
            });
        }
        Ok(Allowlist { entries })
    }

    pub fn load(path: &Path) -> Result<Allowlist, String> {
        match fs::read_to_string(path) {
            Ok(text) => Self::parse(&text),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Self::empty()),
            Err(e) => Err(format!("cannot read {}: {e}", path.display())),
        }
    }

    /// Index of the first entry covering `d`, if any.
    fn covers(&self, d: &Diagnostic) -> Option<usize> {
        self.entries.iter().position(|a| {
            a.rule == d.rule && d.path.ends_with(&a.path) && d.excerpt.contains(&a.needle)
        })
    }
}

/// Result of a whole-tree scan.
#[derive(Debug, Default)]
pub struct LintReport {
    /// Findings not covered by the allowlist — these fail the run.
    pub diagnostics: Vec<Diagnostic>,
    /// Findings suppressed by an allowlist entry.
    pub allowlisted: Vec<Diagnostic>,
    /// Allowlist entries that covered nothing — stale, also fail the run.
    pub stale: Vec<AllowEntry>,
    pub files_scanned: usize,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty() && self.stale.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Line classification
// ---------------------------------------------------------------------------

/// The code portion of a line: everything before a `//` comment, with
/// string literals respected so a `"//"` inside a string does not cut the
/// line. Char-level scan; `\"` escapes are honoured.
fn code_of(line: &str) -> &str {
    let bytes = line.as_bytes();
    let mut in_str = false;
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'\\' if in_str => i += 1, // skip the escaped char
            b'"' => in_str = !in_str,
            b'/' if !in_str && i + 1 < bytes.len() && bytes[i + 1] == b'/' => {
                return &line[..i];
            }
            _ => {}
        }
        i += 1;
    }
    line
}

/// Does `hay` contain `needle` as a standalone word (not part of a longer
/// identifier)?
fn contains_word(hay: &str, needle: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = hay[start..].find(needle) {
        let at = start + pos;
        let before_ok = at == 0
            || !hay.as_bytes()[at - 1].is_ascii_alphanumeric() && hay.as_bytes()[at - 1] != b'_';
        let after = at + needle.len();
        let after_ok = after >= hay.len()
            || !hay.as_bytes()[after].is_ascii_alphanumeric() && hay.as_bytes()[after] != b'_';
        if before_ok && after_ok {
            return true;
        }
        start = at + needle.len();
    }
    false
}

/// Tracks `#[cfg(test)]` / `#[cfg(loom)]` regions by brace depth, so rules
/// R2–R4 can exempt test and model code. A `#![cfg(test)]`/`#![cfg(loom)]`
/// inner attribute exempts the whole file.
struct ExemptTracker {
    depth: usize,
    /// Depth at which the current exempt region opened.
    exempt_at: Option<usize>,
    /// An exempting attribute was seen; the region starts at the next `{`.
    pending: bool,
    whole_file: bool,
}

impl ExemptTracker {
    fn new() -> ExemptTracker {
        ExemptTracker { depth: 0, exempt_at: None, pending: false, whole_file: false }
    }

    /// Feed one line's code portion; returns whether the *line itself* is
    /// inside (or opens) an exempt region.
    fn feed(&mut self, code: &str) -> bool {
        let trimmed = code.trim();
        let exempting = |s: &str| {
            (s.contains("(test)") || s.contains("(loom)")) && !s.contains("not(")
        };
        if trimmed.starts_with("#![cfg(") && exempting(trimmed) {
            self.whole_file = true;
        }
        if trimmed.starts_with("#[cfg(") && exempting(trimmed) {
            self.pending = true;
        }
        let was_exempt = self.exempt_at.is_some();
        let mut in_str = false;
        let bytes = code.as_bytes();
        let mut i = 0;
        while i < bytes.len() {
            match bytes[i] {
                b'\\' if in_str => i += 1,
                b'"' => in_str = !in_str,
                b'{' if !in_str => {
                    if self.pending && self.exempt_at.is_none() {
                        self.exempt_at = Some(self.depth);
                        self.pending = false;
                    }
                    self.depth += 1;
                }
                b'}' if !in_str => {
                    self.depth = self.depth.saturating_sub(1);
                    if self.exempt_at == Some(self.depth) {
                        self.exempt_at = None;
                    }
                }
                _ => {}
            }
            i += 1;
        }
        self.whole_file || was_exempt || self.exempt_at.is_some() || self.pending
    }
}

// ---------------------------------------------------------------------------
// Per-file rules (R1–R4)
// ---------------------------------------------------------------------------

/// Lint one file's source. `relpath` is relative to the source root (it
/// drives the per-path rule scoping). Returns raw findings; allowlist
/// filtering happens in [`lint_tree`].
pub fn lint_file(relpath: &str, source: &str) -> Vec<Diagnostic> {
    let lines: Vec<&str> = source.lines().collect();
    let mut out = Vec::new();
    let mut exempt = ExemptTracker::new();
    let is_pool = relpath.ends_with(POOL_PATH);
    let r4_scoped = R4_SCOPED_DIRS.iter().any(|d| relpath.starts_with(d));

    for (idx, raw) in lines.iter().enumerate() {
        let line_no = idx + 1;
        let code = code_of(raw);
        let line_exempt = exempt.feed(code);
        let excerpt = raw.trim().to_string();
        let mut push = |rule: &'static str, message: String| {
            out.push(Diagnostic {
                rule,
                path: relpath.to_string(),
                line: line_no,
                message,
                excerpt: excerpt.clone(),
            });
        };

        // R1 — applies everywhere, tests included: undocumented unsafe.
        if contains_word(code, "unsafe") {
            let documented = (idx.saturating_sub(SAFETY_WINDOW)..=idx)
                .any(|j| lines[j].contains("SAFETY:"));
            if !documented {
                push(
                    "R1",
                    format!(
                        "`unsafe` without a `// SAFETY:` comment within {SAFETY_WINDOW} lines \
                         above it — state the disjointness contract"
                    ),
                );
            }
        }

        if line_exempt {
            continue; // R2–R4 exempt test / loom-model regions
        }

        // R2 — fan-out and Send/Sync capabilities confined to util::pool.
        if !is_pool {
            if code.contains("thread::spawn(") || code.contains("thread::scope(") {
                push(
                    "R2",
                    "raw thread fan-out outside util::pool — go through the budgeted \
                     primitives (parallel_for / bounded_map / join_all / spawn_worker)"
                        .to_string(),
                );
            }
            if code.contains("unsafe impl Send") || code.contains("unsafe impl Sync") {
                push(
                    "R2",
                    "new cross-thread capability (`unsafe impl Send/Sync`) outside \
                     util::pool — SendPtr is the one sanctioned wrapper"
                        .to_string(),
                );
            }
        }

        // R3 — the one mutex-poisoning policy.
        {
            // A `.lock()` (or `.into_inner()` / condvar `.wait(..)`) must
            // not be followed by `.unwrap()` / `.expect(` — recover with
            // `unwrap_or_else(|e| e.into_inner())` instead. Handles the
            // builder-style split where the consumer sits on the next line.
            let consumer_after = |after: &str| -> bool {
                let mut rest = after.trim_start();
                if rest.is_empty() {
                    // Consumer may start the next non-empty code line.
                    rest = lines[idx + 1..]
                        .iter()
                        .map(|l| code_of(l).trim_start())
                        .find(|l| !l.is_empty())
                        .unwrap_or("");
                }
                rest.starts_with(".unwrap()") || rest.starts_with(".expect(")
            };
            for pat in [".lock()", ".into_inner()"] {
                if let Some(pos) = code.find(pat) {
                    if consumer_after(&code[pos + pat.len()..]) {
                        push(
                            "R3",
                            format!(
                                "bare `{pat}.unwrap()` — one panicking thread poisons the lock \
                                 and cascades; recover with `unwrap_or_else(|e| e.into_inner())` \
                                 and document why the state is panic-safe"
                            ),
                        );
                    }
                }
            }
            if code.contains(".wait(") && code.contains(".unwrap()") {
                push(
                    "R3",
                    "condvar wait unwraps the poison flag — recover with \
                     `unwrap_or_else(|e| e.into_inner())` like every lock site"
                        .to_string(),
                );
            }
        }

        // R4 — determinism of trace-feeding paths.
        if r4_scoped {
            for (pat, word, why) in [
                ("HashMap", true, "iteration order is randomized per process"),
                ("HashSet", true, "iteration order is randomized per process"),
                ("Instant::now", false, "wall-clock reads are nondeterministic"),
                ("SystemTime::now", false, "wall-clock reads are nondeterministic"),
                ("thread::current(", false, "thread identity varies per schedule"),
                ("ThreadId", true, "thread identity varies per schedule"),
            ] {
                let hit = if word { contains_word(code, pat) } else { code.contains(pat) };
                if hit {
                    push(
                        "R4",
                        format!(
                            "nondeterminism source `{pat}` in a golden-trace path ({why}) — \
                             use BTreeMap/Vec, pass times in, or move this out of \
                             sparse/tensor/nn/graph/engine/train"
                        ),
                    );
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Cross-file rule (R5)
// ---------------------------------------------------------------------------

/// Variant names of `enum KernelSpec { ... }` as declared in
/// `engine/registry.rs`.
pub fn kernel_spec_variants(registry_src: &str) -> Vec<String> {
    let mut variants = Vec::new();
    let mut in_enum = false;
    for raw in registry_src.lines() {
        let code = code_of(raw).trim();
        if !in_enum {
            if code.contains("enum KernelSpec") {
                in_enum = true;
            }
            continue;
        }
        if code.starts_with('}') {
            break;
        }
        let ident = code.trim_end_matches(',');
        if !ident.is_empty()
            && ident.chars().next().is_some_and(|c| c.is_ascii_uppercase())
            && ident.chars().all(|c| c.is_ascii_alphanumeric())
        {
            variants.push(ident.to_string());
        }
    }
    variants
}

/// R5: every `KernelSpec` variant declared in the registry has a
/// serializer/validation arm (`KernelSpec::<Variant>`) in the plan store —
/// a backend that can be selected but not persisted/validated is exactly
/// the half-registered state the registry's own exhaustiveness tests
/// exist to prevent.
pub fn check_registry_planstore(registry_src: &str, planstore_src: &str) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let variants = kernel_spec_variants(registry_src);
    if variants.is_empty() {
        out.push(Diagnostic {
            rule: "R5",
            path: "engine/registry.rs".to_string(),
            line: 1,
            message: "could not parse `enum KernelSpec` variants — R5 cannot verify \
                      plan-store exhaustiveness"
                .to_string(),
            excerpt: String::new(),
        });
        return out;
    }
    // Anchor missing-arm findings at the validation function when present.
    let anchor = planstore_src
        .lines()
        .position(|l| l.contains("fn missing_payload"))
        .map(|i| i + 1)
        .unwrap_or(1);
    for v in &variants {
        let arm = format!("KernelSpec::{v}");
        if !planstore_src.contains(&arm) {
            out.push(Diagnostic {
                rule: "R5",
                path: "engine/planstore.rs".to_string(),
                line: anchor,
                message: format!(
                    "registry variant `{arm}` has no serializer/validation arm in the plan \
                     store — decide its on-disk payload in `missing_payload`"
                ),
                excerpt: "fn missing_payload".to_string(),
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Tree walk
// ---------------------------------------------------------------------------

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        fs::read_dir(dir).map_err(|e| format!("cannot read {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("cannot read {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Scan every `.rs` file under `src_root` (sorted, deterministic), apply
/// rules R1–R4 per file and R5 across `engine/registry.rs` /
/// `engine/planstore.rs`, and partition findings by the allowlist.
///
/// The scanner's own home (`analysis/`) and the CLI shims (`bin/`) are
/// skipped: their rule tables and usage strings necessarily spell the
/// forbidden patterns out.
pub fn lint_tree(src_root: &Path, allow: &Allowlist) -> Result<LintReport, String> {
    let mut files = Vec::new();
    collect_rs_files(src_root, &mut files)?;
    files.sort();

    let mut report = LintReport::default();
    let mut used = vec![false; allow.entries.len()];
    let mut registry_src = None;
    let mut planstore_src = None;

    let mut findings = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(src_root)
            .map_err(|_| "walked file outside the source root".to_string())?
            .to_string_lossy()
            .replace('\\', "/");
        if rel.starts_with("analysis/") || rel.starts_with("bin/") {
            continue;
        }
        let source =
            fs::read_to_string(path).map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        if rel == "engine/registry.rs" {
            registry_src = Some(source.clone());
        }
        if rel == "engine/planstore.rs" {
            planstore_src = Some(source.clone());
        }
        findings.extend(lint_file(&rel, &source));
        report.files_scanned += 1;
    }
    if let (Some(reg), Some(ps)) = (&registry_src, &planstore_src) {
        findings.extend(check_registry_planstore(reg, ps));
    }

    for d in findings {
        match allow.covers(&d) {
            Some(i) => {
                used[i] = true;
                report.allowlisted.push(d);
            }
            None => report.diagnostics.push(d),
        }
    }
    report.stale = allow
        .entries
        .iter()
        .zip(&used)
        .filter(|(_, &u)| !u)
        .map(|(a, _)| a.clone())
        .collect();
    Ok(report)
}
