//! End-to-end subgraph pipeline (paper Fig. 9, Fig. 12's measurement rig).
//!
//! One "e2e step" per graph covers everything the paper's end-to-end
//! numbers include: per-subgraph initialization (lane-local adjacency copy
//! — the UVM-transfer analog — plus the kernel's *plan*: CSC transposition
//! for the backward pass and schedule construction), the forward
//! aggregation kernel and the backward aggregation kernel for each of the
//! three edge types, plus the final cell-side merge.
//!
//! Kernels come from an [`EngineBuilder`]: each lane resolves its edge
//! type's kernel (so `"auto"` or per-edge overrides give heterogeneous
//! lanes) and re-plans it per step by design — the per-step init cost is
//! exactly what this rig measures, in contrast to the training path where
//! `EngineBuilder::build` plans once per graph.
//!
//! `ScheduleMode::Sequential` executes lanes one after another (DGL-style);
//! `ScheduleMode::Parallel` gives each edge type its own thread — the
//! multi-threaded CPU init + concurrent kernel launch of §3.4.

use super::timeline::Timeline;
use crate::engine::{kernel_label, normalized_adjacencies, EngineBuilder, SpmmKernel};
use crate::graph::{Cbsr, Csr, EdgeType, HeteroGraph, NodeType};
use crate::sparse::drelu;
use crate::tensor::Matrix;
use crate::util::pool::{bounded_map, join_all, Budget, Handoff, HandoffCloser};
use crate::util::rng::Rng;
use std::sync::Arc;

/// Lane scheduling policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleMode {
    Sequential,
    Parallel,
}

impl ScheduleMode {
    pub fn name(&self) -> &'static str {
        match self {
            ScheduleMode::Sequential => "sequential",
            ScheduleMode::Parallel => "parallel",
        }
    }
}

/// Run one closure per lane under a schedule mode: `Sequential` executes
/// them in lane order on the caller's thread, `Parallel` runs them
/// concurrently (the §3.4 cudaStream analog) on the caller's share of the
/// cooperative thread budget — [`crate::util::pool::join_all`] leases the
/// ambient [`crate::util::pool::Budget`] across the lanes, and each lane's
/// kernels inherit the remainder, so fleet workers × lanes × kernel
/// `parallel_for` subdivide one allowance instead of multiplying. Results
/// come back in lane order either way, so callers are mode-oblivious, and
/// outputs are bit-identical for any budget.
///
/// This is the one lane-scheduling primitive in the crate: `run_e2e_step`
/// drives its three edge-type lanes through it, `HeteroConv` uses it for
/// the model's aggregations, and fleet workers compose it with
/// [`crate::util::pool::bounded_map`] for graph-level × edge-level
/// parallelism (see [`run_fleet_e2e_steps`]).
pub fn run_lanes<T, F>(mode: ScheduleMode, tasks: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    match mode {
        ScheduleMode::Sequential => tasks.into_iter().map(|t| t()).collect(),
        ScheduleMode::Parallel => join_all(tasks),
    }
}

/// One e2e step per subgraph, spread over a bounded worker pool — the
/// fleet rig: graph-level parallelism stacked on the per-step edge lanes.
/// `workers` is a request: the pool leases `min(workers, budget)` shares
/// of the ambient thread budget and each worker's lanes/kernels run inside
/// its share. Results come back in subgraph order regardless of `workers`
/// or budget.
pub fn run_fleet_e2e_steps(
    graphs: &[HeteroGraph],
    dim: usize,
    engine: &EngineBuilder,
    mode: ScheduleMode,
    workers: usize,
    seed: u64,
) -> Vec<E2eTiming> {
    bounded_map(graphs.len(), workers, |i| {
        run_e2e_step(&graphs[i], dim, engine, mode, seed.wrapping_add(i as u64))
    })
}

/// Timeline lane of the epoch pipeline's execute stage.
pub const EXECUTE_LANE: usize = 0;
/// Timeline lane of the epoch pipeline's prepare stage.
pub const PREPARE_LANE: usize = 1;

/// Result of [`run_epoch_pipeline`]: per-item execute results (in item
/// order) plus the two-lane timeline of the run. `overlap_factor() > 1`
/// on the timeline means prepare spans genuinely overlapped execute spans.
#[derive(Debug)]
pub struct PipelineRun<R> {
    pub results: Vec<R>,
    pub timeline: Timeline,
}

impl<R> PipelineRun<R> {
    /// Busy/makespan over both stages (see [`Timeline::overlap_factor`]).
    pub fn overlap_factor(&self) -> f64 {
        self.timeline.overlap_factor()
    }
}

/// Whether [`run_epoch_pipeline`] will actually overlap its stages for
/// this `(n, mode)` under the calling thread's current ambient
/// [`Budget`] — `false` means it will degenerate to the inline
/// prepare-then-execute loop on the caller. Callers whose prepare stage
/// has a cheaper same-thread variant (the fleet's in-place staging) use
/// this to skip work that only pays off when the stages truly decouple.
pub fn pipeline_will_overlap(n: usize, mode: ScheduleMode) -> bool {
    mode == ScheduleMode::Parallel && n >= 2 && Budget::current().lease(2).0 >= 2
}

/// Two-stage epoch pipeline (the fleet-level analog of §3.4's CPU-init /
/// kernel-execution overlap): run `prepare(i)` → `execute(i, prepared)`
/// for every `i in 0..n`, overlapping item `i+1`'s prepare with item `i`'s
/// execute under `ScheduleMode::Parallel`.
///
/// * **Stages.** `prepare` must be a *pure* function of `i` with respect
///   to everything `execute` mutates — in the fleet pipeline it resolves
///   plans and stages features but never reads model weights or optimizer
///   state (the no-weight-reads invariant, see `docs/FLEET.md`). `execute`
///   runs on the calling thread, in item order, and may freely mutate
///   captured state (the model, the optimizer). Under this contract the
///   results are **bit-identical** to the sequential schedule for either
///   mode, any budget, any machine.
/// * **Double buffering.** The stages meet at a single-slot
///   [`Handoff`]: the prepare worker computes item `i+2` while item `i+1`
///   sits in the slot and item `i` executes — at most three prepared
///   items alive at any instant (executing + slotted + in flight),
///   however far ahead the producer could otherwise run. A panicking
///   stage closes the slot and releases its peer.
/// * **Budget.** The pipeline leases the ambient [`Budget`] across its
///   two stages (`Budget::lease(2)`): the prepare worker runs on one
///   share, the caller executes under the other, and each stage's inner
///   primitives subdivide that share — the pipeline composes with fleet
///   workers × edge lanes × kernel `parallel_for` without oversubscribing.
///   A budget of 1 (or `n < 2`, or `ScheduleMode::Sequential`) degenerates
///   to the inline prepare-then-execute loop on the caller.
///
/// Both stages record timeline spans (`"prep"` on [`PREPARE_LANE`],
/// `"exec"` on [`EXECUTE_LANE`]), so [`PipelineRun::overlap_factor`]
/// measures the achieved overlap exactly like the Fig. 9 lane rig.
pub fn run_epoch_pipeline<T, R, P, E>(
    n: usize,
    mode: ScheduleMode,
    prepare: P,
    mut execute: E,
) -> PipelineRun<R>
where
    T: Send,
    P: Fn(usize) -> T + Sync,
    E: FnMut(usize, T) -> R,
{
    let tl = Timeline::new();
    let mut results = Vec::with_capacity(n);
    let budget = Budget::current();
    if !pipeline_will_overlap(n, mode) {
        // Inline schedule: each stage in turn keeps the caller's whole
        // budget (the same degeneration rule as the pool primitives).
        for i in 0..n {
            let staged = tl.record(PREPARE_LANE, "prep", || prepare(i));
            results.push(tl.record(EXECUTE_LANE, "exec", || execute(i, staged)));
        }
        return PipelineRun { results, timeline: tl };
    }
    let slot: Handoff<T> = Handoff::new();
    std::thread::scope(|scope| {
        let (tl_ref, prepare_ref, slot_ref) = (&tl, &prepare, &slot);
        crate::util::pool::spawn_worker(scope, budget.share_of(2, 1), move || {
            let _close = HandoffCloser(slot_ref);
            for i in 0..n {
                let staged = tl_ref.record(PREPARE_LANE, "prep", || prepare_ref(i));
                if slot_ref.put(staged).is_err() {
                    break; // consumer gone (panic unwound) — stop preparing
                }
            }
        });
        // Closing on unwind releases a producer blocked in `put`.
        let _close = HandoffCloser(&slot);
        budget.share_of(2, 0).with(|| {
            for i in 0..n {
                let staged = slot.take().unwrap_or_else(|| {
                    panic!("epoch pipeline: prepare stage died after {i} of {n} items")
                });
                results.push(tl.record(EXECUTE_LANE, "exec", || execute(i, staged)));
            }
        });
    });
    PipelineRun { results, timeline: tl }
}

/// Timing result of one e2e step.
#[derive(Debug)]
pub struct E2eTiming {
    pub mode: ScheduleMode,
    /// Display name(s) of the resolved kernels (one per edge type when
    /// they differ).
    pub engine: String,
    /// Wall-clock seconds for the full step.
    pub total: f64,
    /// Σ of per-lane busy time (sequential-equivalent work).
    pub busy: f64,
    pub timeline: Timeline,
    /// Per-lane (init, forward, backward) seconds.
    pub lane_phases: Vec<(f64, f64, f64)>,
}

struct LaneInput<'a> {
    /// Pre-normalised adjacency (normalisation happens once per graph at
    /// dataset preprocessing, like the paper's pipeline — it is NOT part
    /// of the per-step cost; the plan built from the lane-local copy is).
    adj: &'a Csr,
    /// The lane's resolved kernel.
    kernel: Arc<dyn SpmmKernel>,
    x_src: &'a Matrix,
    /// Pre-sparsified source (DR lanes): D-ReLU runs once per node type
    /// before the lanes (paper Fig. 5), its CBSR shared by all consumers.
    cbsr: Option<&'a Arc<Cbsr>>,
    dy: &'a Matrix,
}

/// Everything one lane does per step: init (the paper's "data loading,
/// memory allocation, host-to-device transfer" — modeled as a deep copy of
/// the subgraph into lane-local memory + the kernel's plan: CSC transpose
/// and schedule construction) → forward kernel → backward kernel.
fn run_lane(
    lane_id: usize,
    input: &LaneInput<'_>,
    tl: &Timeline,
) -> ((f64, f64, f64), Matrix) {
    let t0 = std::time::Instant::now();
    let plan = tl.record(lane_id, "init", || {
        // Lane-local copy = the UVM transfer analog of Fig. 9's Init; the
        // plan is the per-step CSC/schedule construction.
        input.kernel.plan(input.adj.clone())
    });
    let t_init = t0.elapsed().as_secs_f64();

    // --- forward kernel.
    let t1 = std::time::Instant::now();
    let (h, cache) =
        tl.record(lane_id, "fwd", || input.kernel.forward(&plan, input.x_src, input.cbsr));
    let t_fwd = t1.elapsed().as_secs_f64();

    // --- backward kernel (native gradient representation — compressed
    // for DR, matching the paper's Alg. 2 output).
    let t2 = std::time::Instant::now();
    tl.record(lane_id, "bwd", || {
        let _ = input.kernel.backward(&plan, input.dy, &cache);
    });
    let t_bwd = t2.elapsed().as_secs_f64();
    ((t_init, t_fwd, t_bwd), h)
}

/// Activation stage for one node type (paper Fig. 5).
///
/// Sparsifying consumers share one CBSR built by D-ReLU from the **raw
/// pre-activation** values (D-ReLU replaces ReLU for those lanes, §3.1);
/// if any consumer is dense, `x` is additionally ReLU-activated in place —
/// dense lanes must always read activated features, regardless of what
/// other lanes consuming the same node type need. The CBSR is computed
/// first so both views derive from the same pre-activation input.
pub(crate) fn activate(
    x: &mut Matrix,
    k: usize,
    sparsified: bool,
    dense: bool,
) -> Option<Arc<Cbsr>> {
    let cbsr = sparsified.then(|| Arc::new(drelu(x, k.clamp(1, x.cols))));
    if dense {
        x.map_inplace(|v| v.max(0.0));
    }
    cbsr
}

/// Run one end-to-end step over a graph's three subgraphs.
///
/// `dim` is the embedding width; random embeddings/gradients stand in for
/// the model state (the kernels are data-oblivious).
pub fn run_e2e_step(
    g: &HeteroGraph,
    dim: usize,
    engine: &EngineBuilder,
    mode: ScheduleMode,
    seed: u64,
) -> E2eTiming {
    let mut rng = Rng::new(seed);
    let mut x_cell = Matrix::randn(g.n_cells, dim, 1.0, &mut rng);
    let mut x_net = Matrix::randn(g.n_nets, dim, 1.0, &mut rng);
    let dy_cell = Matrix::randn(g.n_cells, dim, 1.0, &mut rng);
    let dy_net = Matrix::randn(g.n_nets, dim, 1.0, &mut rng);

    // Per-graph preprocessing (normalisation + kernel resolution) — done
    // once per dataset like paper Alg. 1 stage 1; excluded from the step.
    // Shared helpers keep the rig on the exact matrices and labels the
    // training path uses.
    let [near, pins, pinned] = normalized_adjacencies(g);
    let k_near = engine.resolve_kernel(EdgeType::Near, &near);
    let k_pinned = engine.resolve_kernel(EdgeType::Pinned, &pinned);
    let k_pins = engine.resolve_kernel(EdgeType::Pins, &pins);
    let engine_label = kernel_label([&*k_near, &*k_pins, &*k_pinned]);
    // Per-node-type consumer mix: which lanes read the D-ReLU CBSR and
    // which read the dense tensor. `x_cell` feeds both `near` and `pins`,
    // so a mixed engine (e.g. `near=dr,pins=csr`) needs both forms.
    let cell_sparsified = k_near.needs_sparsified() || k_pins.needs_sparsified();
    let cell_dense = !k_near.needs_sparsified() || !k_pins.needs_sparsified();
    let net_sparsified = k_pinned.needs_sparsified();
    let net_dense = !k_pinned.needs_sparsified();

    let tl = Timeline::new();
    let t0 = std::time::Instant::now();

    // Activation stage (paper Fig. 5): one activation per node type —
    // D-ReLU → CBSR shared by every sparsifying consumer, in-place ReLU
    // for dense consumers. A mixed consumer set gets both, so a dense
    // lane never reads raw pre-activation features just because a sibling
    // lane sparsifies the same node type.
    let (cbsr_cell, cbsr_net) = tl.record(3, "act", || {
        let cbsr_cell = activate(
            &mut x_cell,
            engine.k_for(NodeType::Cell),
            cell_sparsified,
            cell_dense,
        );
        let cbsr_net =
            activate(&mut x_net, engine.k_for(NodeType::Net), net_sparsified, net_dense);
        (cbsr_cell, cbsr_net)
    });

    let inputs = [
        LaneInput {
            adj: &near,
            kernel: k_near,
            x_src: &x_cell,
            cbsr: cbsr_cell.as_ref(),
            dy: &dy_cell,
        },
        LaneInput {
            adj: &pinned,
            kernel: k_pinned,
            x_src: &x_net,
            cbsr: cbsr_net.as_ref(),
            dy: &dy_cell,
        },
        LaneInput {
            adj: &pins,
            kernel: k_pins,
            x_src: &x_cell,
            cbsr: cbsr_cell.as_ref(),
            dy: &dy_net,
        },
    ];
    let mut lane_phases = vec![(0.0, 0.0, 0.0); 3];
    let mut outputs: Vec<Matrix> = Vec::with_capacity(3);
    let tasks: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(i, input)| {
            let tl = &tl;
            move || run_lane(i, input, tl)
        })
        .collect();
    for (i, (phases, h)) in run_lanes(mode, tasks).into_iter().enumerate() {
        lane_phases[i] = phases;
        outputs.push(h);
    }
    // Final merge (eq. 8) — the only cross-lane dependency.
    let (merged, _mask) = outputs[0].max_merge(&outputs[1]);
    std::hint::black_box(&merged);
    let total = t0.elapsed().as_secs_f64();
    E2eTiming {
        mode,
        engine: engine_label,
        total,
        busy: tl.busy_time(),
        timeline: tl,
        lane_phases,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate_graph, GraphSpec};
    use crate::engine::EngineBuilder;
    use crate::sparse::GnnaConfig;

    fn test_graph(scale: usize) -> HeteroGraph {
        let mut rng = Rng::new(3);
        generate_graph(
            &GraphSpec {
                n_cells: scale,
                n_nets: scale / 2,
                target_near: scale * 30,
                target_pins: scale,
                d_cell: 8,
                d_net: 8,
            },
            0,
            &mut rng,
        )
    }

    #[test]
    fn both_modes_complete_all_engines() {
        let g = test_graph(300);
        for engine in [
            EngineBuilder::csr(),
            EngineBuilder::gnna(GnnaConfig::default()),
            EngineBuilder::dr(4, 4),
            EngineBuilder::auto(),
        ] {
            for mode in [ScheduleMode::Sequential, ScheduleMode::Parallel] {
                let t = run_e2e_step(&g, 16, &engine, mode, 7);
                assert!(t.total > 0.0);
                assert_eq!(t.lane_phases.len(), 3);
                assert_eq!(t.timeline.events().len(), 10, "act + 3 lanes × 3 phases");
                assert!(!t.engine.is_empty());
            }
        }
    }

    #[test]
    fn parallel_overlaps_lanes() {
        if crate::util::pool::num_threads() < 2 {
            // Single-core box: lanes interleave but cannot truly overlap.
            return;
        }
        // Take the best overlap of several attempts: the unit-test runner
        // itself runs tests concurrently, so a single run can be starved.
        let g = test_graph(1500);
        let best = (0..4)
            .map(|r| {
                run_e2e_step(&g, 64, &EngineBuilder::csr(), ScheduleMode::Parallel, 7 + r)
                    .timeline
                    .overlap_factor()
            })
            .fold(0.0, f64::max);
        assert!(best > 1.1, "best overlap factor {best}");
    }

    #[test]
    fn sequential_busy_approximates_total() {
        let g = test_graph(800);
        let t = run_e2e_step(&g, 32, &EngineBuilder::csr(), ScheduleMode::Sequential, 7);
        // Sequential: busy time ≈ makespan (no overlap).
        assert!(t.timeline.overlap_factor() < 1.15, "{}", t.timeline.overlap_factor());
    }

    #[test]
    fn phases_positive() {
        let g = test_graph(200);
        let t = run_e2e_step(&g, 16, &EngineBuilder::dr(4, 4), ScheduleMode::Sequential, 9);
        for (i, f, b) in &t.lane_phases {
            assert!(*i > 0.0 && *f >= 0.0 && *b >= 0.0);
        }
        assert_eq!(t.engine, "DR-SpMM");
    }

    #[test]
    fn run_lanes_preserves_order_in_both_modes() {
        for mode in [ScheduleMode::Sequential, ScheduleMode::Parallel] {
            let tasks: Vec<_> = (0..5).map(|i| move || i * 10).collect();
            assert_eq!(run_lanes(mode, tasks), vec![0, 10, 20, 30, 40], "{}", mode.name());
        }
    }

    #[test]
    fn fleet_e2e_steps_cover_every_subgraph() {
        let g = test_graph(300);
        let subs = crate::graph::partition::partition(&g, 3);
        for workers in [1, 4] {
            let timings = run_fleet_e2e_steps(
                &subs,
                16,
                &EngineBuilder::dr(4, 4),
                ScheduleMode::Sequential,
                workers,
                11,
            );
            assert_eq!(timings.len(), subs.len());
            for t in &timings {
                assert!(t.total > 0.0);
                assert_eq!(t.lane_phases.len(), 3);
            }
        }
    }

    /// Busy-wait for roughly `ms` milliseconds — unlike `thread::sleep`
    /// this keeps the stage's span visible to the timeline even when the
    /// OS delays wakeups, making overlap assertions robust.
    fn spin_ms(ms: u64) {
        let t0 = std::time::Instant::now();
        while t0.elapsed() < std::time::Duration::from_millis(ms) {
            std::hint::black_box(());
        }
    }

    #[test]
    fn epoch_pipeline_results_match_sequential_in_both_modes() {
        for mode in [ScheduleMode::Sequential, ScheduleMode::Parallel] {
            let mut trace = Vec::new();
            let run = run_epoch_pipeline(
                7,
                mode,
                |i| i * 10,
                |i, staged| {
                    trace.push(i);
                    staged + i
                },
            );
            assert_eq!(run.results, vec![0, 11, 22, 33, 44, 55, 66], "{}", mode.name());
            assert_eq!(trace, (0..7).collect::<Vec<_>>(), "execute must run in order");
            assert_eq!(run.timeline.events().len(), 14, "7 prep + 7 exec spans");
        }
    }

    #[test]
    fn epoch_pipeline_budget_one_degenerates_inline() {
        crate::util::pool::Budget::new(1).with(|| {
            let me = std::thread::current().id();
            let run = run_epoch_pipeline(
                5,
                ScheduleMode::Parallel,
                |i| {
                    assert_eq!(std::thread::current().id(), me, "prepare left the caller");
                    i
                },
                |_, staged| staged * 2,
            );
            assert_eq!(run.results, vec![0, 2, 4, 6, 8]);
        });
    }

    #[test]
    fn epoch_pipeline_empty_and_single_item() {
        let run =
            run_epoch_pipeline(0, ScheduleMode::Parallel, |i| i, |_, s: usize| s);
        assert!(run.results.is_empty());
        let run = run_epoch_pipeline(1, ScheduleMode::Parallel, |i| i + 1, |_, s| s);
        assert_eq!(run.results, vec![1]);
    }

    /// The satellite timeline assertion: pipelined epochs overlap prepare
    /// with execute (`overlap_factor() > 1.1` on a multi-core box), the
    /// sequential schedule stays ≈ 1.0. Stage durations are synthetic
    /// (spin loops) so the assertion doesn't depend on workload balance;
    /// the retry pattern mirrors `parallel_overlaps_lanes` above — the
    /// test harness itself runs suites concurrently, so a single run can
    /// be starved.
    #[test]
    fn epoch_pipeline_overlaps_stages_only_in_parallel_mode() {
        let seq = run_epoch_pipeline(
            4,
            ScheduleMode::Sequential,
            |i| spin_ms(4 + (i % 2) as u64),
            |_, ()| spin_ms(4),
        );
        assert!(seq.overlap_factor() < 1.15, "sequential overlap {}", seq.overlap_factor());
        if crate::util::pool::num_threads() < 2 {
            return; // single-core: stages interleave but cannot overlap
        }
        let best = (0..4)
            .map(|_| {
                run_epoch_pipeline(
                    4,
                    ScheduleMode::Parallel,
                    |i| spin_ms(4 + (i % 2) as u64),
                    |_, ()| spin_ms(4),
                )
                .overlap_factor()
            })
            .fold(0.0, f64::max);
        assert!(best > 1.1, "pipelined overlap best {best}");
    }

    /// Mixed-engine activation: a node type that is sparsified for one
    /// consumer (near=dr) but read densely by another (pins=csr) must hand
    /// the dense lane **activated** features — the historical bug left
    /// `x_cell` raw whenever any consumer sparsified it.
    #[test]
    fn mixed_engine_activation_feeds_dense_lanes_relu() {
        let mut rng = Rng::new(11);
        let x0 = Matrix::randn(40, 8, 1.0, &mut rng);
        assert!(x0.data.iter().any(|&v| v < 0.0), "input must contain negatives");

        // Mixed consumers (sparsified + dense), the near=dr / pins=csr case.
        let mut x_mixed = x0.clone();
        let cbsr = activate(&mut x_mixed, 3, true, true).expect("sparsified ⇒ CBSR");
        // The CBSR is D-ReLU of the raw pre-activation input…
        let reference = drelu(&x0, 3);
        assert_eq!(cbsr.values, reference.values);
        assert_eq!(cbsr.indices, reference.indices);
        // …and the dense view is bit-identical to the pure-dense path.
        let mut x_dense = x0.clone();
        assert!(activate(&mut x_dense, 3, false, true).is_none());
        assert_eq!(x_mixed.data, x_dense.data);
        assert!(x_mixed.data.iter().all(|&v| v >= 0.0), "dense view must be activated");
        assert_ne!(x_mixed.data, x0.data, "raw features must not reach dense lanes");

        // All-sparsified consumers: D-ReLU *is* the activation, the dense
        // tensor stays untouched (no lane reads it).
        let mut x_dr = x0.clone();
        assert!(activate(&mut x_dr, 3, true, false).is_some());
        assert_eq!(x_dr.data, x0.data);
    }

    /// Lane-level parity: the csr `pins` lane of a mixed engine computes
    /// exactly what it computes in an all-dense engine, because both read
    /// the same ReLU-activated features.
    #[test]
    fn mixed_engine_dense_lane_matches_pure_dense_engine() {
        let g = test_graph(300);
        let [_, pins, _] = normalized_adjacencies(&g);
        let kernel = EngineBuilder::csr().resolve_kernel(EdgeType::Pins, &pins);
        let plan = kernel.plan(pins.clone());
        let mut rng = Rng::new(7);
        let x0 = Matrix::randn(g.n_cells, 16, 1.0, &mut rng);

        // Mixed engine: the cell type is sparsified for near=dr AND kept
        // dense for pins=csr.
        let mut x_mixed = x0.clone();
        let _cbsr = activate(&mut x_mixed, 4, true, true);
        let (h_mixed, _) = kernel.forward(&plan, &x_mixed, None);

        // Pure-dense reference.
        let mut x_ref = x0.clone();
        let _ = activate(&mut x_ref, 4, false, true);
        let (h_ref, _) = kernel.forward(&plan, &x_ref, None);
        assert_eq!(h_mixed.data, h_ref.data);
    }

    #[test]
    fn mixed_engine_lanes_run() {
        let g = test_graph(250);
        let engine = EngineBuilder::csr()
            .kernel_for(EdgeType::Near, "dr")
            .k_cell(4);
        let t = run_e2e_step(&g, 16, &engine, ScheduleMode::Sequential, 5);
        assert!(t.engine.contains("near=dr"), "{}", t.engine);
        assert!(t.total > 0.0);
    }
}
