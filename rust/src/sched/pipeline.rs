//! End-to-end subgraph pipeline (paper Fig. 9, Fig. 12's measurement rig).
//!
//! One "e2e step" per graph covers everything the paper's end-to-end
//! numbers include: per-subgraph initialization (adjacency normalisation,
//! CSC transposition for the backward pass, degree-bucket construction),
//! the forward aggregation kernel and the backward aggregation kernel for
//! each of the three edge types, plus the final cell-side merge.
//!
//! `ScheduleMode::Sequential` executes lanes one after another (DGL-style);
//! `ScheduleMode::Parallel` gives each edge type its own thread — the
//! multi-threaded CPU init + concurrent kernel launch of §3.4.

use super::timeline::Timeline;
use crate::graph::{Csr, HeteroGraph};
use crate::sparse::{
    dr_spmm, dr_spmm_bwd, drelu, spmm_csr, spmm_csr_bwd, spmm_gnna, spmm_gnna_bwd, DegreeBuckets,
};
use crate::nn::MessageEngine;
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// Lane scheduling policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleMode {
    Sequential,
    Parallel,
}

impl ScheduleMode {
    pub fn name(&self) -> &'static str {
        match self {
            ScheduleMode::Sequential => "sequential",
            ScheduleMode::Parallel => "parallel",
        }
    }
}

/// Timing result of one e2e step.
#[derive(Debug)]
pub struct E2eTiming {
    pub mode: ScheduleMode,
    pub engine: String,
    /// Wall-clock seconds for the full step.
    pub total: f64,
    /// Σ of per-lane busy time (sequential-equivalent work).
    pub busy: f64,
    pub timeline: Timeline,
    /// Per-lane (init, forward, backward) seconds.
    pub lane_phases: Vec<(f64, f64, f64)>,
}

struct LaneInput<'a> {
    /// Pre-normalised adjacency (normalisation/CSC happen once per graph
    /// at dataset preprocessing, like the paper's pipeline — they are NOT
    /// part of the per-step cost).
    adj: &'a Csr,
    csc: &'a crate::graph::Csc,
    x_src: &'a Matrix,
    /// Pre-sparsified source (Dr engine): D-ReLU runs once per node type
    /// before the lanes (paper Fig. 5), its CBSR shared by all consumers.
    cbsr: Option<&'a crate::graph::Cbsr>,
    dy: &'a Matrix,
}

/// Everything one lane does per step: init (the paper's "data loading,
/// memory allocation, host-to-device transfer" — modeled as a deep copy of
/// the subgraph into lane-local memory + schedule construction) → forward
/// kernel → backward kernel.
fn run_lane(
    lane_id: usize,
    input: &LaneInput<'_>,
    engine: &MessageEngine,
    tl: &Timeline,
) -> ((f64, f64, f64), Matrix) {
    let t0 = std::time::Instant::now();
    let (adj, csc, buckets) = tl.record(lane_id, "init", || {
        // Lane-local copies = the UVM transfer analog of Fig. 9's Init.
        let adj = input.adj.clone();
        let csc = input.csc.clone();
        let buckets = DegreeBuckets::build(&adj);
        (adj, csc, buckets)
    });
    let t_init = t0.elapsed().as_secs_f64();

    // --- forward kernel. Baselines apply the plain-ReLU activation the
    // DGL pipeline runs before aggregation; the DR path replaces it with
    // D-ReLU (paper §3.1) — both sides pay their activation here so the
    // comparison matches the paper's end-to-end accounting.
    let t1 = std::time::Instant::now();
    let h = tl.record(lane_id, "fwd", || match engine {
        MessageEngine::Csr => spmm_csr(&adj, input.x_src),
        MessageEngine::Gnna(cfg) => spmm_gnna(&adj, input.x_src, cfg),
        MessageEngine::Dr { .. } => {
            dr_spmm(&adj, input.cbsr.expect("DR lane needs a CBSR"), &buckets)
        }
    });
    let t_fwd = t1.elapsed().as_secs_f64();

    // --- backward kernel.
    let t2 = std::time::Instant::now();
    tl.record(lane_id, "bwd", || match engine {
        MessageEngine::Csr => {
            let _ = spmm_csr_bwd(&csc, input.dy);
        }
        MessageEngine::Gnna(cfg) => {
            let _ = spmm_gnna_bwd(&csc, input.dy, cfg);
        }
        MessageEngine::Dr { .. } => {
            let _ = dr_spmm_bwd(&csc, input.dy, input.cbsr.unwrap());
        }
    });
    let t_bwd = t2.elapsed().as_secs_f64();
    ((t_init, t_fwd, t_bwd), h)
}

/// Run one end-to-end step over a graph's three subgraphs.
///
/// `dim` is the embedding width; random embeddings/gradients stand in for
/// the model state (the kernels are data-oblivious).
pub fn run_e2e_step(
    g: &HeteroGraph,
    dim: usize,
    engine: &MessageEngine,
    mode: ScheduleMode,
    seed: u64,
) -> E2eTiming {
    let mut rng = Rng::new(seed);
    let mut x_cell = Matrix::randn(g.n_cells, dim, 1.0, &mut rng);
    let mut x_net = Matrix::randn(g.n_nets, dim, 1.0, &mut rng);
    let dy_cell = Matrix::randn(g.n_cells, dim, 1.0, &mut rng);
    let dy_net = Matrix::randn(g.n_nets, dim, 1.0, &mut rng);

    // Per-graph preprocessing (normalisation + CSC transposition) — done
    // once per dataset like paper Alg. 1 stage 1; excluded from the step.
    let mut near = g.near.clone();
    near.normalize_gcn();
    let mut pinned = g.pinned.clone();
    pinned.normalize_rows();
    let mut pins = g.pins.clone();
    pins.normalize_rows();
    let (near_csc, pinned_csc, pins_csc) = (near.to_csc(), pinned.to_csc(), pins.to_csc());

    let tl = Timeline::new();
    let t0 = std::time::Instant::now();

    // Activation stage (paper Fig. 5): baselines run plain ReLU, the DR
    // engine runs D-ReLU once per node type — the CBSR (values + indices)
    // is then shared by every consuming edge lane, forward and backward.
    let (cbsr_cell, cbsr_net) = tl.record(3, "act", || match engine {
        MessageEngine::Dr { k_cell, k_net } => {
            let kc = (*k_cell).clamp(1, dim);
            let kn = (*k_net).clamp(1, dim);
            (Some(drelu(&x_cell, kc)), Some(drelu(&x_net, kn)))
        }
        _ => {
            x_cell.map_inplace(|v| v.max(0.0));
            x_net.map_inplace(|v| v.max(0.0));
            (None, None)
        }
    });

    let inputs = [
        LaneInput {
            adj: &near,
            csc: &near_csc,
            x_src: &x_cell,
            cbsr: cbsr_cell.as_ref(),
            dy: &dy_cell,
        },
        LaneInput {
            adj: &pinned,
            csc: &pinned_csc,
            x_src: &x_net,
            cbsr: cbsr_net.as_ref(),
            dy: &dy_cell,
        },
        LaneInput {
            adj: &pins,
            csc: &pins_csc,
            x_src: &x_cell,
            cbsr: cbsr_cell.as_ref(),
            dy: &dy_net,
        },
    ];
    let mut lane_phases = vec![(0.0, 0.0, 0.0); 3];
    let mut outputs: Vec<Matrix> = Vec::with_capacity(3);
    match mode {
        ScheduleMode::Sequential => {
            for (i, input) in inputs.iter().enumerate() {
                let (phases, h) = run_lane(i, input, engine, &tl);
                lane_phases[i] = phases;
                outputs.push(h);
            }
        }
        ScheduleMode::Parallel => {
            let results = std::thread::scope(|scope| {
                let handles: Vec<_> = inputs
                    .iter()
                    .enumerate()
                    .map(|(i, input)| {
                        let tl = &tl;
                        let engine = engine.clone();
                        scope.spawn(move || run_lane(i, input, &engine, tl))
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
            });
            for (i, (phases, h)) in results.into_iter().enumerate() {
                lane_phases[i] = phases;
                outputs.push(h);
            }
        }
    }
    // Final merge (eq. 8) — the only cross-lane dependency.
    let (merged, _mask) = outputs[0].max_merge(&outputs[1]);
    std::hint::black_box(&merged);
    let total = t0.elapsed().as_secs_f64();
    E2eTiming {
        mode,
        engine: engine.name().to_string(),
        total,
        busy: tl.busy_time(),
        timeline: tl,
        lane_phases,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate_graph, GraphSpec};

    fn test_graph(scale: usize) -> HeteroGraph {
        let mut rng = Rng::new(3);
        generate_graph(
            &GraphSpec {
                n_cells: scale,
                n_nets: scale / 2,
                target_near: scale * 30,
                target_pins: scale,
                d_cell: 8,
                d_net: 8,
            },
            0,
            &mut rng,
        )
    }

    #[test]
    fn both_modes_complete_all_engines() {
        let g = test_graph(300);
        for engine in [
            MessageEngine::Csr,
            MessageEngine::Gnna(Default::default()),
            MessageEngine::dr(4, 4),
        ] {
            for mode in [ScheduleMode::Sequential, ScheduleMode::Parallel] {
                let t = run_e2e_step(&g, 16, &engine, mode, 7);
                assert!(t.total > 0.0);
                assert_eq!(t.lane_phases.len(), 3);
                assert_eq!(t.timeline.events().len(), 10, "act + 3 lanes × 3 phases");
            }
        }
    }

    #[test]
    fn parallel_overlaps_lanes() {
        if crate::util::pool::num_threads() < 2 {
            // Single-core box: lanes interleave but cannot truly overlap.
            return;
        }
        // Take the best overlap of several attempts: the unit-test runner
        // itself runs tests concurrently, so a single run can be starved.
        let g = test_graph(1500);
        let best = (0..4)
            .map(|r| {
                run_e2e_step(&g, 64, &MessageEngine::Csr, ScheduleMode::Parallel, 7 + r)
                    .timeline
                    .overlap_factor()
            })
            .fold(0.0, f64::max);
        assert!(best > 1.1, "best overlap factor {best}");
    }

    #[test]
    fn sequential_busy_approximates_total() {
        let g = test_graph(800);
        let t = run_e2e_step(&g, 32, &MessageEngine::Csr, ScheduleMode::Sequential, 7);
        // Sequential: busy time ≈ makespan (no overlap).
        assert!(t.timeline.overlap_factor() < 1.15, "{}", t.timeline.overlap_factor());
    }

    #[test]
    fn phases_positive() {
        let g = test_graph(200);
        let t = run_e2e_step(&g, 16, &MessageEngine::dr(4, 4), ScheduleMode::Sequential, 9);
        for (i, f, b) in &t.lane_phases {
            assert!(*i > 0.0 && *f >= 0.0 && *b >= 0.0);
        }
    }
}
