//! Parallel subgraph scheduling (paper §3.4, Figs. 9 & 12).
//!
//! A circuit graph's three edge-type subgraphs are computationally
//! independent until the cell-side merge, yet DGL processes them
//! sequentially (Fig. 9a). This module implements both schedules:
//!
//! * **Sequential** — init → forward → backward per subgraph, one after
//!   another (the baseline timeline).
//! * **Parallel** — each subgraph gets its own lane: a dedicated CPU thread
//!   performs initialization (the lane-local copy plus its kernel's *plan* —
//!   CSC transposition and schedule construction, the paper's "data loading,
//!   memory allocation" phase) and then drives its kernels through the
//!   [`crate::engine`] plan/execute API. Lanes are the cudaStream analog;
//!   the only barrier is the final merge.
//!
//! [`timeline`] captures per-lane events to render Fig. 9-style charts and
//! compute the Fig. 12 savings breakdown.
//!
//! On top of the per-step lanes sits the **epoch pipeline**
//! ([`run_epoch_pipeline`]): a two-stage prepare/execute schedule that
//! overlaps design N+1's CPU-side preparation (plan resolution, feature
//! staging) with design N's execute + optimizer step — the fleet-level
//! extension of the same §3.4 overlap, bit-identical to the sequential
//! schedule because prepare reads no state execute writes.

pub mod pipeline;
pub mod timeline;

pub use pipeline::{
    pipeline_will_overlap, run_e2e_step, run_epoch_pipeline, run_fleet_e2e_steps, run_lanes,
    E2eTiming, PipelineRun, ScheduleMode, EXECUTE_LANE, PREPARE_LANE,
};
pub use timeline::{Timeline, TimelineEvent};
