//! Per-lane event capture and ASCII rendering (paper Fig. 9).

use std::sync::Mutex;
use std::time::Instant;

/// One recorded span.
#[derive(Clone, Debug, PartialEq)]
pub struct TimelineEvent {
    pub lane: usize,
    pub label: String,
    /// Seconds relative to the timeline origin.
    pub start: f64,
    pub end: f64,
}

/// Thread-safe event collector.
#[derive(Debug)]
pub struct Timeline {
    origin: Instant,
    events: Mutex<Vec<TimelineEvent>>,
}

impl Default for Timeline {
    fn default() -> Self {
        Self::new()
    }
}

impl Timeline {
    pub fn new() -> Timeline {
        Timeline { origin: Instant::now(), events: Mutex::new(Vec::new()) }
    }

    /// Record a span around a closure.
    ///
    /// Poisoning policy (repo-wide, lint rule R3): recover the event list
    /// with `into_inner()`. A lane that panics poisons the lock *between*
    /// pushes — each push is a single `Vec` operation, so the recovered
    /// Vec is always a well-formed prefix of the events; losing the
    /// panicked lane's span must not take the whole Fig. 9 chart (or the
    /// surviving lanes' makespan accounting) down with it.
    pub fn record<T>(&self, lane: usize, label: &str, f: impl FnOnce() -> T) -> T {
        let start = self.origin.elapsed().as_secs_f64();
        let out = f();
        let end = self.origin.elapsed().as_secs_f64();
        self.events.lock().unwrap_or_else(|e| e.into_inner()).push(TimelineEvent {
            lane,
            label: label.to_string(),
            start,
            end,
        });
        out
    }

    pub fn events(&self) -> Vec<TimelineEvent> {
        // Poisoning: recover via `into_inner()` — see [`Timeline::record`].
        let mut e = self.events.lock().unwrap_or_else(|e| e.into_inner()).clone();
        e.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        e
    }

    /// Wall-clock makespan (max end over events).
    pub fn makespan(&self) -> f64 {
        // Poisoning: recover via `into_inner()` — see [`Timeline::record`].
        let guard = self.events.lock().unwrap_or_else(|e| e.into_inner());
        guard.iter().map(|e| e.end).fold(0.0, f64::max)
    }

    /// Sum of event durations (the sequential-equivalent busy time).
    pub fn busy_time(&self) -> f64 {
        // Poisoning: recover via `into_inner()` — see [`Timeline::record`].
        let guard = self.events.lock().unwrap_or_else(|e| e.into_inner());
        guard.iter().map(|e| e.end - e.start).sum()
    }

    /// Overlap factor = busy / makespan; 1.0 ⇒ fully serial, `L` ⇒ perfect
    /// overlap across `L` lanes.
    pub fn overlap_factor(&self) -> f64 {
        let m = self.makespan();
        if m <= 0.0 {
            return 1.0;
        }
        self.busy_time() / m
    }

    /// ASCII chart: one row per lane, `width` columns spanning the makespan.
    pub fn render(&self, width: usize) -> String {
        let events = self.events();
        if events.is_empty() {
            return String::new();
        }
        let makespan = self.makespan().max(1e-12);
        let n_lanes = events.iter().map(|e| e.lane).max().unwrap() + 1;
        let mut rows = vec![vec![' '; width]; n_lanes];
        for e in &events {
            let s = ((e.start / makespan) * width as f64) as usize;
            let t = (((e.end / makespan) * width as f64).ceil() as usize).clamp(s + 1, width);
            let c = e.label.chars().next().unwrap_or('#');
            for cell in rows[e.lane][s.min(width - 1)..t].iter_mut() {
                *cell = c;
            }
        }
        let mut out = String::new();
        for (lane, row) in rows.iter().enumerate() {
            out.push_str(&format!("lane {lane}: "));
            out.extend(row.iter());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn records_spans_in_order() {
        let tl = Timeline::new();
        tl.record(0, "init", || std::thread::sleep(Duration::from_millis(2)));
        tl.record(0, "fwd", || std::thread::sleep(Duration::from_millis(2)));
        let events = tl.events();
        assert_eq!(events.len(), 2);
        assert!(events[0].end <= events[1].start + 1e-4);
        assert!(tl.makespan() >= 0.004);
    }

    #[test]
    fn overlap_factor_parallel_spans() {
        let tl = Timeline::new();
        std::thread::scope(|s| {
            for lane in 0..3 {
                let tl = &tl;
                s.spawn(move || {
                    tl.record(lane, "work", || std::thread::sleep(Duration::from_millis(8)));
                });
            }
        });
        // Three 8ms spans overlapping: busy ≈ 24ms, makespan ≈ 8–12ms.
        assert!(tl.overlap_factor() > 1.5, "overlap {}", tl.overlap_factor());
    }

    #[test]
    fn render_contains_lanes() {
        let tl = Timeline::new();
        tl.record(0, "a", || {});
        tl.record(1, "b", || std::thread::sleep(Duration::from_millis(1)));
        let chart = tl.render(40);
        assert!(chart.contains("lane 0:"));
        assert!(chart.contains("lane 1:"));
        assert!(chart.contains('b'));
    }

    #[test]
    fn empty_timeline() {
        let tl = Timeline::new();
        assert_eq!(tl.render(10), "");
        assert_eq!(tl.makespan(), 0.0);
        assert_eq!(tl.overlap_factor(), 1.0);
    }
}
