//! The heterogeneous circuit graph (paper §2.2).
//!
//! Two node types — `cell` and `net` — and three edge types:
//! * `near`   ⊆ cell × cell (geometric links from the shifting window)
//! * `pins`   ⊆ cell → net  (topological: cell pins into a net)
//! * `pinned` ⊆ net → cell  (the transpose of `pins`)
//!
//! Adjacency matrices are stored destination-major (rows = destination
//! nodes), matching the forward aggregation direction `Y_i = Σ_j A_ij X_j`.

use super::csr::Csr;
use crate::tensor::Matrix;

/// Node types of the circuit heterograph.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NodeType {
    Cell,
    Net,
}

/// Edge types of the circuit heterograph.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EdgeType {
    /// cell → cell geometric proximity.
    Near,
    /// cell → net topological connection (source cell, destination net).
    Pins,
    /// net → cell, the transpose of `Pins`.
    Pinned,
}

impl EdgeType {
    pub const ALL: [EdgeType; 3] = [EdgeType::Near, EdgeType::Pins, EdgeType::Pinned];

    pub fn name(&self) -> &'static str {
        match self {
            EdgeType::Near => "near",
            EdgeType::Pins => "pins",
            EdgeType::Pinned => "pinned",
        }
    }

    /// (source node type, destination node type).
    pub fn endpoints(&self) -> (NodeType, NodeType) {
        match self {
            EdgeType::Near => (NodeType::Cell, NodeType::Cell),
            EdgeType::Pins => (NodeType::Cell, NodeType::Net),
            EdgeType::Pinned => (NodeType::Net, NodeType::Cell),
        }
    }
}

/// One heterogeneous circuit graph (one partition of a design).
#[derive(Clone, Debug)]
pub struct HeteroGraph {
    /// Graph id within its design.
    pub id: usize,
    pub n_cells: usize,
    pub n_nets: usize,
    /// cell→cell adjacency, rows = destination cells. Square.
    pub near: Csr,
    /// cell→net adjacency stored destination-major: rows = nets, cols = cells.
    pub pins: Csr,
    /// net→cell adjacency destination-major: rows = cells, cols = nets.
    pub pinned: Csr,
    /// Cell node features (n_cells × d_cell).
    pub x_cell: Matrix,
    /// Net node features (n_nets × d_net).
    pub x_net: Matrix,
    /// Per-cell congestion label (n_cells × 1).
    pub y_cell: Matrix,
}

impl HeteroGraph {
    /// Adjacency matrix for an edge type (destination-major).
    pub fn adj(&self, e: EdgeType) -> &Csr {
        match e {
            EdgeType::Near => &self.near,
            EdgeType::Pins => &self.pins,
            EdgeType::Pinned => &self.pinned,
        }
    }

    /// Source-node features for an edge type.
    pub fn src_features(&self, e: EdgeType) -> &Matrix {
        match e.endpoints().0 {
            NodeType::Cell => &self.x_cell,
            NodeType::Net => &self.x_net,
        }
    }

    pub fn total_nodes(&self) -> usize {
        self.n_cells + self.n_nets
    }

    pub fn total_edges(&self) -> usize {
        self.near.nnz() + self.pins.nnz() + self.pinned.nnz()
    }

    /// Validate shape/typing invariants from §2.2 including pins = pinnedᵀ.
    pub fn validate(&self) -> Result<(), String> {
        let c = self.n_cells;
        let n = self.n_nets;
        if self.near.rows != c || self.near.cols != c {
            return Err(format!("near must be {c}×{c}, got {}×{}", self.near.rows, self.near.cols));
        }
        if self.pins.rows != n || self.pins.cols != c {
            return Err(format!("pins must be {n}×{c}, got {}×{}", self.pins.rows, self.pins.cols));
        }
        if self.pinned.rows != c || self.pinned.cols != n {
            return Err(format!(
                "pinned must be {c}×{n}, got {}×{}",
                self.pinned.rows, self.pinned.cols
            ));
        }
        if !self.pinned.is_transpose_of(&self.pins) {
            return Err("pinned must equal pinsᵀ".into());
        }
        if self.x_cell.rows != c || self.x_net.rows != n {
            return Err("feature row counts must match node counts".into());
        }
        if self.y_cell.rows != c || self.y_cell.cols != 1 {
            return Err("labels must be n_cells × 1".into());
        }
        Ok(())
    }

    /// Content hash of the graph's *adjacency* (all three edge types plus
    /// the node counts), composed from [`Csr::content_hash`]. Features and
    /// labels are deliberately excluded: engines and their kernel plans
    /// depend only on the adjacency, so this is the key under which the
    /// fleet's shared plan cache deduplicates content-identical subgraphs.
    pub fn adjacency_hash(&self) -> u64 {
        let mut h = super::csr::fnv_mix(super::csr::FNV_OFFSET, self.n_cells as u64);
        h = super::csr::fnv_mix(h, self.n_nets as u64);
        for adj in [&self.near, &self.pins, &self.pinned] {
            h = super::csr::fnv_mix(h, adj.content_hash());
        }
        h
    }

    /// Compact statistics line (Table-1 style).
    pub fn stats_row(&self) -> GraphStats {
        GraphStats {
            id: self.id,
            nodes_net: self.n_nets,
            nodes_cell: self.n_cells,
            edges_pinned: self.pinned.nnz(),
            edges_near: self.near.nnz(),
            edges_pins: self.pins.nnz(),
        }
    }
}

/// Per-graph statistics matching the columns of paper Table 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GraphStats {
    pub id: usize,
    pub nodes_net: usize,
    pub nodes_cell: usize,
    pub edges_pinned: usize,
    pub edges_near: usize,
    pub edges_pins: usize,
}

impl GraphStats {
    pub fn total_nodes(&self) -> usize {
        self.nodes_net + self.nodes_cell
    }
    pub fn total_edges(&self) -> usize {
        self.edges_pinned + self.edges_near + self.edges_pins
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny 3-cell / 2-net graph used across the test suite.
    pub fn toy_graph() -> HeteroGraph {
        let n_cells = 3;
        let n_nets = 2;
        // near: cell 0 <-> 1, 1 <-> 2
        let near = Csr::from_triplets(
            3,
            3,
            &[(0, 1, 1.0), (1, 0, 1.0), (1, 2, 1.0), (2, 1, 1.0)],
        );
        // pins rows = nets: net0 <- cells {0,1}, net1 <- cells {1,2}
        let pins =
            Csr::from_triplets(2, 3, &[(0, 0, 1.0), (0, 1, 1.0), (1, 1, 1.0), (1, 2, 1.0)]);
        let pinned = pins.transpose();
        HeteroGraph {
            id: 0,
            n_cells,
            n_nets,
            near,
            pins,
            pinned,
            x_cell: Matrix::ones(3, 4),
            x_net: Matrix::ones(2, 4),
            y_cell: Matrix::zeros(3, 1),
        }
    }

    #[test]
    fn toy_is_valid() {
        toy_graph().validate().unwrap();
    }

    #[test]
    fn edge_type_endpoints() {
        assert_eq!(EdgeType::Near.endpoints(), (NodeType::Cell, NodeType::Cell));
        assert_eq!(EdgeType::Pins.endpoints(), (NodeType::Cell, NodeType::Net));
        assert_eq!(EdgeType::Pinned.endpoints(), (NodeType::Net, NodeType::Cell));
        assert_eq!(EdgeType::ALL.len(), 3);
    }

    #[test]
    fn adj_and_features_routing() {
        let g = toy_graph();
        assert_eq!(g.adj(EdgeType::Pins).rows, g.n_nets);
        assert_eq!(g.adj(EdgeType::Pinned).rows, g.n_cells);
        assert_eq!(g.src_features(EdgeType::Pins).rows, g.n_cells);
        assert_eq!(g.src_features(EdgeType::Pinned).rows, g.n_nets);
    }

    #[test]
    fn validate_rejects_broken_transpose() {
        let mut g = toy_graph();
        g.pinned = Csr::from_triplets(3, 2, &[(0, 0, 1.0)]);
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_shapes() {
        let mut g = toy_graph();
        g.x_cell = Matrix::ones(5, 4);
        assert!(g.validate().is_err());
    }

    #[test]
    fn adjacency_hash_ignores_features_but_not_edges() {
        let g = toy_graph();
        let h0 = g.adjacency_hash();
        // Features/labels are not part of the key.
        let mut f = g.clone();
        f.x_cell = Matrix::zeros(3, 7);
        f.y_cell = Matrix::ones(3, 1);
        assert_eq!(f.adjacency_hash(), h0);
        // Any adjacency mutation invalidates it.
        let mut m = g.clone();
        m.near.values[0] = 2.0;
        assert_ne!(m.adjacency_hash(), h0);
        let mut m = g;
        m.pins = Csr::from_triplets(2, 3, &[(0, 0, 1.0)]);
        assert_ne!(m.adjacency_hash(), h0);
    }

    #[test]
    fn stats_row_counts() {
        let s = toy_graph().stats_row();
        assert_eq!(s.total_nodes(), 5);
        assert_eq!(s.edges_pins, s.edges_pinned);
        assert_eq!(s.total_edges(), 4 + 4 + 4);
    }
}
