//! Design partitioner (paper §2.2 item 1).
//!
//! CircuitNet partitions each design evenly into graphs of roughly 10k
//! nodes. Our generator produces partitions directly, but this module also
//! provides the inverse operation — splitting one large heterograph into
//! balanced partitions — so the pipeline matches the paper's preprocessing
//! and so tests can check conservation invariants.

use super::csr::Csr;
use super::delta::DeltaPatch;
use super::hetero::{EdgeType, HeteroGraph};

/// Stable node remapping of one partition back to its parent graph:
/// `cell_ids[i]` / `net_ids[j]` are the parent indices of local cell `i` /
/// local net `j`. Cell ids are contiguous ranges (range partitioning) and
/// net ids are in first-touch order, both fully determined by the parent
/// graph and the partition count — the fleet relies on this stability to
/// reduce per-subgraph results deterministically.
#[derive(Clone, Debug)]
pub struct PartitionMap {
    pub cell_ids: Vec<usize>,
    pub net_ids: Vec<usize>,
}

/// Split a heterograph into `parts` cell-contiguous partitions. Cells are
/// range-partitioned; each partition keeps the nets that touch its cells.
/// Edges crossing partition boundaries are dropped (the paper's partitions
/// are likewise independent graphs).
pub fn partition(g: &HeteroGraph, parts: usize) -> Vec<HeteroGraph> {
    partition_with_map(g, parts).into_iter().map(|(sub, _)| sub).collect()
}

/// [`partition`], additionally returning each subgraph's [`PartitionMap`]
/// so per-subgraph outputs (predictions, gradients) can be scattered back
/// to parent node indices.
pub fn partition_with_map(g: &HeteroGraph, parts: usize) -> Vec<(HeteroGraph, PartitionMap)> {
    assert!(parts >= 1);
    let per = g.n_cells.div_ceil(parts);
    let mut out = Vec::with_capacity(parts);
    for p in 0..parts {
        let cell_lo = p * per;
        let cell_hi = ((p + 1) * per).min(g.n_cells);
        if cell_lo >= cell_hi {
            break;
        }
        out.push(cut_partition(g, cell_lo, cell_hi, p));
    }
    if out.len() < parts {
        crate::warn!(
            "partition_with_map: requested {parts} partitions but design {} has only \
             {} cells — producing {} partition(s); downstream fleet runs with the \
             effective count",
            g.id,
            g.n_cells,
            out.len()
        );
    }
    out
}

/// Cut the single partition covering parent cells `[cell_lo, cell_hi)` out
/// of `g`, keeping the nets that touch those cells. This is the unit of
/// work [`partition_with_map`] loops over; the fleet's ECO path
/// ([`crate::fleet::eco`]) calls it directly to re-cut *one* restaged
/// partition from a patched parent, using the cell range recorded in the
/// old [`PartitionMap`], without re-cutting its untouched siblings.
pub fn cut_partition(
    g: &HeteroGraph,
    cell_lo: usize,
    cell_hi: usize,
    id: usize,
) -> (HeteroGraph, PartitionMap) {
    assert!(cell_lo < cell_hi && cell_hi <= g.n_cells);
    let n_cells = cell_hi - cell_lo;

    // near: keep edges with both endpoints inside.
    let mut near_t = Vec::new();
    for r in cell_lo..cell_hi {
        for q in g.near.row_range(r) {
            let c = g.near.indices[q] as usize;
            if (cell_lo..cell_hi).contains(&c) {
                near_t.push((r - cell_lo, c - cell_lo, g.near.values[q]));
            }
        }
    }

    // Nets touched by this partition's cells (via pins: rows = nets).
    // Local net ids are assigned in ascending parent-net order, so they are
    // fully determined by the *set* of nets present — the stability the
    // delta router's restage rule protects.
    let mut net_map = vec![usize::MAX; g.n_nets];
    let mut n_nets = 0usize;
    let mut pins_t = Vec::new();
    for net in 0..g.n_nets {
        for q in g.pins.row_range(net) {
            let cell = g.pins.indices[q] as usize;
            if (cell_lo..cell_hi).contains(&cell) {
                if net_map[net] == usize::MAX {
                    net_map[net] = n_nets;
                    n_nets += 1;
                }
                pins_t.push((net_map[net], cell - cell_lo, g.pins.values[q]));
            }
        }
    }

    let near = Csr::from_triplets(n_cells, n_cells, &near_t);
    let pins = Csr::from_triplets(n_nets, n_cells, &pins_t);
    let pinned = pins.transpose();

    // Feature/label slices.
    let cell_idx: Vec<usize> = (cell_lo..cell_hi).collect();
    let mut net_idx = vec![0usize; n_nets];
    for (old, &new) in net_map.iter().enumerate() {
        if new != usize::MAX {
            net_idx[new] = old;
        }
    }
    (
        HeteroGraph {
            id,
            n_cells,
            n_nets,
            near,
            pins,
            pinned,
            x_cell: g.x_cell.gather_rows(&cell_idx),
            x_net: g.x_net.gather_rows(&net_idx),
            y_cell: g.y_cell.gather_rows(&cell_idx),
        },
        PartitionMap { cell_ids: cell_idx, net_ids: net_idx },
    )
}

/// What one partition must do to track a parent ECO.
#[derive(Clone, Debug)]
pub enum RoutedPatch {
    /// No parent op lands inside this partition — keep graph and plan.
    Untouched,
    /// Every op landing here maps to stable local ids — apply this local
    /// delta and repair the plan incrementally.
    Patch(DeltaPatch),
    /// The partition's net set changes (a net gains its first / loses its
    /// last pin here), so local net ids shift — re-cut from the patched
    /// parent via [`cut_partition`] and rebuild cold.
    Restage,
}

impl RoutedPatch {
    pub fn is_untouched(&self) -> bool {
        matches!(self, RoutedPatch::Untouched)
    }
}

/// A parent ECO routed through partition maps: one verdict per partition,
/// plus the count of `near` ops dropped because they cross a partition
/// boundary (cross-partition edges are dropped by [`partition_with_map`]
/// itself, so the routed subgraphs still mirror a full re-partition).
#[derive(Clone, Debug)]
pub struct RoutedDelta {
    pub parts: Vec<RoutedPatch>,
    pub dropped_near: usize,
}

/// Route a parent-graph ECO onto the partitions described by `maps`
/// (as returned by [`partition_with_map`] for the *pre-patch* parent).
///
/// The contract — asserted by proptests — is that applying each routed
/// local patch to its old subgraph (and re-cutting `Restage`d ones from
/// the patched parent) reproduces, bit-identically, what
/// `partition_with_map(apply(g, patch))` would build from scratch.
///
/// Per-op routing rules:
/// * `near (r, c)` — both cells in one partition → local op; the edge
///   crosses a boundary → dropped (counted in `dropped_near`).
/// * `pins (net, cell)` — routed to `cell`'s owner. If the partition's
///   net *set* would change (first pin added / last pin removed, counting
///   every op of this patch on that net) the partition is `Restage`d,
///   because local net ids are assigned by ascending parent-net order over
///   the present set; otherwise the op maps to stable local ids.
/// * feature/label rows — `x_cell`/`y_cell` go to the owning partition;
///   `x_net` goes to every partition where the net is present.
pub fn route_patch(g: &HeteroGraph, patch: &DeltaPatch, maps: &[PartitionMap]) -> RoutedDelta {
    // Cell ownership: maps hold contiguous ascending ranges.
    let ranges: Vec<(usize, usize)> = maps
        .iter()
        .map(|m| {
            let lo = *m.cell_ids.first().expect("partition owns at least one cell");
            debug_assert!(m.cell_ids.windows(2).all(|w| w[1] == w[0] + 1));
            (lo, lo + m.cell_ids.len())
        })
        .collect();
    let owner = |cell: usize| -> usize {
        ranges
            .iter()
            .position(|&(lo, hi)| (lo..hi).contains(&cell))
            .expect("cell ranges cover the parent")
    };
    // Local net id in partition p, if present: net_ids is ascending
    // (assignment order is ascending parent-net order).
    let local_net = |p: usize, net: usize| maps[p].net_ids.binary_search(&net).ok();
    // Pins of `net` inside partition p's cell range, in the pre-patch parent.
    let pre_pins = |p: usize, net: usize| -> usize {
        let (lo, hi) = ranges[p];
        g.pins
            .row_range(net)
            .filter(|&q| (lo..hi).contains(&(g.pins.indices[q] as usize)))
            .count()
    };

    let mut local: Vec<DeltaPatch> = vec![DeltaPatch::new(); maps.len()];
    let mut restage = vec![false; maps.len()];
    let mut dropped_near = 0usize;

    for op in patch.ops(EdgeType::Near) {
        let (r, c) = op.target();
        let p = owner(r);
        if p == owner(c) {
            let lo = ranges[p].0;
            local[p] = std::mem::take(&mut local[p]).edge(
                EdgeType::Near,
                shift(op, lo, lo),
            );
        } else {
            dropped_near += 1;
        }
    }

    // Net-presence bookkeeping: pin-count delta per (partition, net) from
    // *all* ops of this patch, so removing a 2-pin net's pins one op at a
    // time still restages.
    let pins_ops = patch.ops(EdgeType::Pins);
    let mut delta: std::collections::BTreeMap<(usize, usize), isize> =
        std::collections::BTreeMap::new();
    for op in &pins_ops {
        let (net, cell) = op.target();
        let p = owner(cell);
        let d = match op {
            super::delta::EdgeOp::Add { w, .. } => {
                if *w == 0.0 {
                    0
                } else {
                    1
                }
            }
            super::delta::EdgeOp::Remove { .. } => -1,
            super::delta::EdgeOp::Reweight { w, .. } => {
                if *w == 0.0 {
                    -1
                } else {
                    0
                }
            }
        };
        *delta.entry((p, net)).or_insert(0) += d;
    }
    for (&(p, net), &d) in &delta {
        let before = pre_pins(p, net);
        let after = (before as isize + d).max(0) as usize;
        if (before == 0) != (after == 0) {
            restage[p] = true;
        }
    }
    for op in &pins_ops {
        let (net, cell) = op.target();
        let p = owner(cell);
        if restage[p] {
            continue;
        }
        // A net absent from a stable partition can only be targeted by
        // no-op edits (zero-weight Add) — nothing to express locally.
        let Some(ln) = local_net(p, net) else { continue };
        let lo = ranges[p].0;
        local[p] = std::mem::take(&mut local[p]).edge(EdgeType::Pins, relabel(*op, ln, cell - lo));
    }

    for (cell, row) in patch.x_cell_updates() {
        let p = owner(*cell);
        if !restage[p] {
            local[p] = std::mem::take(&mut local[p]).set_x_cell(cell - ranges[p].0, row.clone());
        }
    }
    for (net, row) in patch.x_net_updates() {
        for p in 0..maps.len() {
            if restage[p] {
                continue;
            }
            if let Some(ln) = local_net(p, *net) {
                local[p] = std::mem::take(&mut local[p]).set_x_net(ln, row.clone());
            }
        }
    }
    for &(cell, y) in patch.y_cell_updates() {
        let p = owner(cell);
        if !restage[p] {
            local[p] = std::mem::take(&mut local[p]).set_y_cell(cell - ranges[p].0, y);
        }
    }

    let parts = local
        .into_iter()
        .zip(&restage)
        .map(|(patch, &rs)| {
            if rs {
                RoutedPatch::Restage
            } else if patch.is_empty() {
                RoutedPatch::Untouched
            } else {
                RoutedPatch::Patch(patch)
            }
        })
        .collect();
    RoutedDelta { parts, dropped_near }
}

/// Shift a near op's endpoints into local coordinates.
fn shift(op: super::delta::EdgeOp, row_off: usize, col_off: usize) -> super::delta::EdgeOp {
    use super::delta::EdgeOp;
    match op {
        EdgeOp::Add { row, col, w } => EdgeOp::Add { row: row - row_off, col: col - col_off, w },
        EdgeOp::Remove { row, col } => EdgeOp::Remove { row: row - row_off, col: col - col_off },
        EdgeOp::Reweight { row, col, w } => {
            EdgeOp::Reweight { row: row - row_off, col: col - col_off, w }
        }
    }
}

/// Re-target a pins op at explicit local (net, cell) ids.
fn relabel(op: super::delta::EdgeOp, net: usize, cell: usize) -> super::delta::EdgeOp {
    use super::delta::EdgeOp;
    match op {
        EdgeOp::Add { w, .. } => EdgeOp::Add { row: net, col: cell, w },
        EdgeOp::Remove { .. } => EdgeOp::Remove { row: net, col: cell },
        EdgeOp::Reweight { w, .. } => EdgeOp::Reweight { row: net, col: cell, w },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    fn random_graph(n_cells: usize, n_nets: usize, seed: u64) -> HeteroGraph {
        let mut rng = Rng::new(seed);
        let mut near_t = Vec::new();
        for r in 0..n_cells {
            for _ in 0..3 {
                let c = rng.below(n_cells);
                if c != r {
                    near_t.push((r, c, 1.0));
                }
            }
        }
        let mut pins_t = Vec::new();
        for net in 0..n_nets {
            for _ in 0..2 {
                pins_t.push((net, rng.below(n_cells), 1.0));
            }
        }
        let near = Csr::from_triplets(n_cells, n_cells, &near_t);
        let pins = Csr::from_triplets(n_nets, n_cells, &pins_t);
        let pinned = pins.transpose();
        HeteroGraph {
            id: 0,
            n_cells,
            n_nets,
            near,
            pins,
            pinned,
            x_cell: Matrix::randn(n_cells, 4, 1.0, &mut rng),
            x_net: Matrix::randn(n_nets, 4, 1.0, &mut rng),
            y_cell: Matrix::randn(n_cells, 1, 1.0, &mut rng),
        }
    }

    #[test]
    fn partitions_are_valid_and_cover_cells() {
        let g = random_graph(100, 40, 5);
        let parts = partition(&g, 4);
        assert_eq!(parts.len(), 4);
        let total: usize = parts.iter().map(|p| p.n_cells).sum();
        assert_eq!(total, 100);
        for p in &parts {
            p.validate().unwrap();
        }
    }

    #[test]
    fn partition_preserves_features() {
        let g = random_graph(50, 20, 6);
        let parts = partition(&g, 2);
        // First cell of second partition is cell 25 of the original.
        assert_eq!(parts[1].x_cell.row(0), g.x_cell.row(25));
        assert_eq!(parts[1].y_cell.row(0), g.y_cell.row(25));
    }

    #[test]
    fn single_partition_keeps_all_near_edges() {
        let g = random_graph(30, 10, 7);
        let parts = partition(&g, 1);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].near.nnz(), g.near.nnz());
        assert_eq!(parts[0].pins.nnz(), g.pins.nnz());
    }

    #[test]
    fn cross_edges_dropped_monotonically() {
        let g = random_graph(60, 25, 8);
        let p2: usize = partition(&g, 2).iter().map(|p| p.near.nnz()).sum();
        let p6: usize = partition(&g, 6).iter().map(|p| p.near.nnz()).sum();
        assert!(p2 <= g.near.nnz());
        assert!(p6 <= p2);
    }

    #[test]
    fn maps_are_stable_and_consistent_with_slices() {
        let g = random_graph(60, 22, 10);
        let a = partition_with_map(&g, 3);
        let b = partition_with_map(&g, 3);
        for ((pa, ma), (pb, mb)) in a.iter().zip(&b) {
            assert_eq!(ma.cell_ids, mb.cell_ids, "cell remap must be deterministic");
            assert_eq!(ma.net_ids, mb.net_ids, "net remap must be deterministic");
            assert_eq!(pa.adjacency_hash(), pb.adjacency_hash());
        }
        for (sub, map) in &a {
            assert_eq!(map.cell_ids.len(), sub.n_cells);
            assert_eq!(map.net_ids.len(), sub.n_nets);
            for (local, &parent) in map.cell_ids.iter().enumerate() {
                assert_eq!(sub.x_cell.row(local), g.x_cell.row(parent));
            }
            for (local, &parent) in map.net_ids.iter().enumerate() {
                assert_eq!(sub.x_net.row(local), g.x_net.row(parent));
            }
        }
        // Cell ranges are contiguous and cover the parent exactly once.
        let all: Vec<usize> = a.iter().flat_map(|(_, m)| m.cell_ids.clone()).collect();
        assert_eq!(all, (0..60).collect::<Vec<_>>());
    }

    #[test]
    fn requesting_more_parts_than_cells_truncates_loudly_but_correctly() {
        let g = random_graph(3, 2, 11);
        // 8 requested, 3 producible — the count is clamped (and warned
        // about at runtime), never padded with empty partitions.
        let parts = partition_with_map(&g, 8);
        assert_eq!(parts.len(), 3);
        let total: usize = parts.iter().map(|(p, _)| p.n_cells).sum();
        assert_eq!(total, 3);
    }

    #[test]
    fn cut_partition_matches_partition_with_map() {
        let g = random_graph(50, 20, 12);
        let whole = partition_with_map(&g, 3);
        for (p, (sub, map)) in whole.iter().enumerate() {
            let lo = map.cell_ids[0];
            let hi = lo + map.cell_ids.len();
            let (cut, cut_map) = cut_partition(&g, lo, hi, p);
            assert_eq!(cut.adjacency_hash(), sub.adjacency_hash());
            assert_eq!(cut.near, sub.near);
            assert_eq!(cut.pins, sub.pins);
            assert_eq!(cut_map.cell_ids, map.cell_ids);
            assert_eq!(cut_map.net_ids, map.net_ids);
        }
    }

    /// Fixed 6-cell / 4-net graph with known partition structure at
    /// parts = 2 (cells [0,3) and [3,6)):
    /// part 0 nets {0, 1, 3}, part 1 nets {1, 2}.
    fn routed_fixture() -> HeteroGraph {
        let near = Csr::from_triplets(
            6,
            6,
            &[
                (0, 1, 1.0),
                (1, 0, 1.0),
                (1, 2, 1.0),
                (2, 1, 1.0),
                (2, 3, 1.0),
                (3, 2, 1.0),
                (3, 4, 1.0),
                (4, 3, 1.0),
            ],
        );
        let pins = Csr::from_triplets(
            4,
            6,
            &[
                (0, 0, 1.0),
                (0, 1, 1.0),
                (1, 2, 1.0),
                (1, 3, 1.0),
                (2, 4, 1.0),
                (2, 5, 1.0),
                (3, 1, 1.0),
            ],
        );
        let pinned = pins.transpose();
        HeteroGraph {
            id: 0,
            n_cells: 6,
            n_nets: 4,
            near,
            pins,
            pinned,
            x_cell: Matrix::from_fn(6, 3, |r, c| (r * 3 + c) as f32),
            x_net: Matrix::from_fn(4, 3, |r, c| -((r * 3 + c) as f32)),
            y_cell: Matrix::zeros(6, 1),
        }
    }

    /// Replay a routed delta: untouched partitions are cloned, patched
    /// ones delta-applied, restaged ones re-cut from the patched parent.
    fn apply_routed(
        patched_parent: &HeteroGraph,
        old: &[(HeteroGraph, PartitionMap)],
        routed: &RoutedDelta,
    ) -> Vec<(HeteroGraph, PartitionMap)> {
        old.iter()
            .zip(&routed.parts)
            .enumerate()
            .map(|(p, ((sub, map), verdict))| match verdict {
                RoutedPatch::Untouched => (sub.clone(), map.clone()),
                RoutedPatch::Patch(local) => (local.apply(sub).unwrap(), map.clone()),
                RoutedPatch::Restage => {
                    let lo = map.cell_ids[0];
                    cut_partition(patched_parent, lo, lo + map.cell_ids.len(), p)
                }
            })
            .collect()
    }

    fn assert_same_partitions(
        got: &[(HeteroGraph, PartitionMap)],
        want: &[(HeteroGraph, PartitionMap)],
    ) {
        assert_eq!(got.len(), want.len());
        for ((ga, ma), (gb, mb)) in got.iter().zip(want) {
            assert_eq!(ga.adjacency_hash(), gb.adjacency_hash());
            assert_eq!(ga.near, gb.near);
            assert_eq!(ga.pins, gb.pins);
            assert_eq!(ga.pinned, gb.pinned);
            assert_eq!(ga.x_cell.data, gb.x_cell.data);
            assert_eq!(ga.x_net.data, gb.x_net.data);
            assert_eq!(ga.y_cell.data, gb.y_cell.data);
            assert_eq!(ma.cell_ids, mb.cell_ids);
            assert_eq!(ma.net_ids, mb.net_ids);
        }
    }

    #[test]
    fn routed_local_patches_reproduce_full_repartition() {
        use crate::graph::delta::{apply, DeltaPatch};
        let g = routed_fixture();
        let old = partition_with_map(&g, 2);
        // Net sets stay stable: near edits inside each half, a pin
        // reweight, and feature/label updates on both sides.
        let patch = DeltaPatch::new()
            .reweight_edge(EdgeType::Near, 0, 1, 2.5)
            .add_edge(EdgeType::Near, 4, 5, 0.75)
            .reweight_edge(EdgeType::Pins, 2, 4, 3.0)
            .set_x_cell(4, vec![9.0, 9.0, 9.0])
            .set_x_net(1, vec![7.0, 7.0, 7.0])
            .set_y_cell(0, 0.5);
        let patched = apply(&g, &patch).unwrap();

        let routed = route_patch(&g, &patch, &[old[0].1.clone(), old[1].1.clone()]);
        assert_eq!(routed.dropped_near, 0);
        assert!(matches!(routed.parts[0], RoutedPatch::Patch(_)));
        assert!(matches!(routed.parts[1], RoutedPatch::Patch(_)));
        // x_net update on net 1 must land in BOTH partitions (it spans).
        if let RoutedPatch::Patch(p0) = &routed.parts[0] {
            assert_eq!(p0.x_net_updates().len(), 1);
        }

        let got = apply_routed(&patched, &old, &routed);
        let want = partition_with_map(&patched, 2);
        assert_same_partitions(&got, &want);
    }

    #[test]
    fn cross_partition_near_ops_are_dropped_and_counted() {
        use crate::graph::delta::DeltaPatch;
        let g = routed_fixture();
        let maps: Vec<PartitionMap> =
            partition_with_map(&g, 2).into_iter().map(|(_, m)| m).collect();
        // (2,3) crosses the boundary; its removal never reaches a subgraph
        // (the partitioner dropped the edge at cut time already).
        let patch = DeltaPatch::new()
            .remove_edge(EdgeType::Near, 2, 3)
            .add_edge(EdgeType::Near, 0, 5, 1.0);
        let routed = route_patch(&g, &patch, &maps);
        assert_eq!(routed.dropped_near, 2);
        assert!(routed.parts.iter().all(|p| p.is_untouched()));
    }

    #[test]
    fn net_set_changes_force_restage() {
        use crate::graph::delta::{apply, DeltaPatch};
        let g = routed_fixture();
        let old = partition_with_map(&g, 2);
        let maps: Vec<PartitionMap> = old.iter().map(|(_, m)| m.clone()).collect();

        // Net 3 gains its first pin in partition 1 → restage part 1 only.
        let grow = DeltaPatch::new().add_edge(EdgeType::Pins, 3, 5, 1.0);
        let routed = route_patch(&g, &grow, &maps);
        assert!(routed.parts[0].is_untouched());
        assert!(matches!(routed.parts[1], RoutedPatch::Restage));
        let patched = apply(&g, &grow).unwrap();
        assert_same_partitions(
            &apply_routed(&patched, &old, &routed),
            &partition_with_map(&patched, 2),
        );

        // Net 3 loses its only pin in partition 0 → restage part 0.
        let shrink = DeltaPatch::new().remove_edge(EdgeType::Pins, 3, 1);
        let routed = route_patch(&g, &shrink, &maps);
        assert!(matches!(routed.parts[0], RoutedPatch::Restage));
        assert!(routed.parts[1].is_untouched());
        let patched = apply(&g, &shrink).unwrap();
        assert_same_partitions(
            &apply_routed(&patched, &old, &routed),
            &partition_with_map(&patched, 2),
        );

        // Reweight-to-zero is a removal for presence purposes too.
        let zeroed = DeltaPatch::new().reweight_edge(EdgeType::Pins, 3, 1, 0.0);
        let routed = route_patch(&g, &zeroed, &maps);
        assert!(matches!(routed.parts[0], RoutedPatch::Restage));

        // Rewiring a pin within one partition while the net keeps
        // another pin there stays a local patch (net 0: cells 0 and 1).
        let rewire = DeltaPatch::new()
            .remove_edge(EdgeType::Pins, 0, 0)
            .add_edge(EdgeType::Pins, 0, 2, 1.0);
        let routed = route_patch(&g, &rewire, &maps);
        assert!(matches!(routed.parts[0], RoutedPatch::Patch(_)));
        let patched = apply(&g, &rewire).unwrap();
        assert_same_partitions(
            &apply_routed(&patched, &old, &routed),
            &partition_with_map(&patched, 2),
        );
    }

    #[test]
    fn nets_not_duplicated_within_partition() {
        let g = random_graph(40, 15, 9);
        for p in partition(&g, 3) {
            // each partition's nets have at least one pin
            for net in 0..p.n_nets {
                assert!(p.pins.degree(net) >= 1);
            }
        }
    }
}
